"""Kernel-level microbench: Pallas syrk / gemm_tn (interpret mode on CPU)
vs their pure-jnp oracles, plus the analytic MXU-work saving of the
triangular grid (lower blocks only — the paper's low(C) saving at tile
level). Interpret-mode timings are NOT hardware numbers (the kernel body
runs in Python); the derived column therefore reports the *structural*
quantities the TPU run would inherit: grid sizes and flop fractions.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import gemm_tn, syrk
from repro.kernels.ref import gemm_tn_ref, syrk_ref


def run():
    rng = np.random.default_rng(2)
    m, n = 512, 512
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    bm, bn = 256, 128
    nb = -(-n // bn)
    tri = nb * (nb + 1) // 2
    t = time_fn(lambda a: syrk(a, blocks=(bm, bn), interpret=True), a, iters=2, warmup=1)
    emit(
        f"kernel_syrk_{m}x{n}",
        t,
        f"grid_tiles={tri} full_tiles={nb*nb} "
        f"mxu_work_fraction={tri/(nb*nb):.3f} interpret=True",
    )
    b = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    t = time_fn(lambda a, b: gemm_tn(a, b, blocks=(bm, bn, bn), interpret=True),
                a, b, iters=2, warmup=1)
    emit(f"kernel_gemm_tn_{m}x{n}", t, f"grid_tiles={nb*nb} interpret=True")
    # correctness cross-check in the bench harness itself
    err = float(jnp.abs(syrk(a, blocks=(bm, bn), interpret=True) - syrk_ref(a)).max())
    emit("kernel_syrk_maxerr", 0.0, f"max_abs_err={err:.2e}")


if __name__ == "__main__":
    run()
