"""Kernel-level microbench: Pallas syrk / gemm_tn (interpret mode on CPU)
vs their pure-jnp oracles, plus the analytic MXU-work and HBM-write savings
of the triangular grid (lower blocks only — the paper's low(C) saving at
tile level, now kept through the output: packed storage or in-kernel
dual-write, no mirror post-pass). Interpret-mode timings are NOT hardware
numbers (the kernel body runs in Python); the derived column therefore
reports the *structural* quantities the TPU run would inherit: grid sizes,
flop fractions, and modeled HBM write bytes per output mode.

Block shapes come from the planner (``tune.plan(...).syrk_blocks`` /
``.gemm_blocks``); the kernels clamp them to this bench's deliberately
small operands.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, smoke, time_fn
from repro import tune
from repro.analysis.roofline import syrk_write_traffic
from repro.kernels import gemm_tn, syrk
from repro.kernels.ref import syrk_ref


def run():
    rng = np.random.default_rng(2)
    m, n = (256, 256) if smoke() else (512, 512)
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    plan = tune.plan(op="ata", m=m, n=n)
    bm, bn = plan.syrk_blocks
    bm, bn = min(bm, m), min(bn, n)
    nb = -(-n // bn)
    tri = nb * (nb + 1) // 2
    wr = {mode: syrk_write_traffic(n, bn, mode) for mode in ("packed", "dual", "mirror")}
    t = time_fn(lambda a: syrk(a, plan=plan, interpret=True), a, iters=2, warmup=1)
    emit(
        f"kernel_syrk_{m}x{n}",
        t,
        f"grid_tiles={tri} full_tiles={nb*nb} "
        f"mxu_work_fraction={tri/(nb*nb):.3f} "
        f"write_bytes_dual={wr['dual']} write_bytes_seed_mirror={wr['mirror']} "
        f"blocks=({bm},{bn}) interpret=True",
        shape=(m, n),
        mode="dense",
        grid_tiles=tri,
        write_bytes=wr["dual"],
        blocks=[bm, bn],
    )
    t_packed = time_fn(
        lambda a: syrk(a, plan=plan, interpret=True, out="packed"),
        a, iters=2, warmup=1,
    )
    emit(
        f"kernel_syrk_packed_{m}x{n}",
        t_packed,
        f"out_blocks={tri} dense_blocks={nb*nb} "
        f"write_bytes={wr['packed']} write_fraction_vs_dual="
        f"{wr['packed']/wr['dual']:.3f} interpret=True",
        shape=(m, n),
        mode="packed",
        grid_tiles=tri,
        write_bytes=wr["packed"],
    )
    # batched: one launch over a leading batch grid dimension (no vmap)
    ab = jnp.asarray(rng.standard_normal((4, m // 2, n // 2)), jnp.float32)
    t_b = time_fn(
        lambda x: syrk(x, plan=plan, interpret=True, out="packed"),
        ab, iters=2, warmup=1,
    )
    emit(
        f"kernel_syrk_batched_4x{m//2}x{n//2}",
        t_b,
        "batch_grid=leading-dim interpret=True",
        shape=(4, m // 2, n // 2),
        mode="packed",
    )
    b = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    gplan = tune.plan(op="gemm_tn", m=m, n=n, k=n)
    t = time_fn(lambda a, b: gemm_tn(a, b, plan=gplan, interpret=True),
                a, b, iters=2, warmup=1)
    emit(f"kernel_gemm_tn_{m}x{n}", t, f"grid_tiles={nb*nb} interpret=True",
         shape=(m, n))
    # correctness cross-checks in the bench harness itself
    err = float(jnp.abs(syrk(a, plan=plan, interpret=True) - syrk_ref(a)).max())
    emit("kernel_syrk_maxerr", 0.0, f"max_abs_err={err:.2e}")
    err_p = float(
        jnp.abs(
            syrk(a, plan=plan, interpret=True, out="packed").to_dense()
            - syrk_ref(a)
        ).max()
    )
    emit("kernel_syrk_packed_maxerr", 0.0, f"max_abs_err={err_p:.2e}")


if __name__ == "__main__":
    run()
