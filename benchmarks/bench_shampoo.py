"""Framework-integration bench: ATA-powered Shampoo gram statistics.

The production consumer of the paper's algorithm — per-step preconditioner
statistics L = G·Gᵀ, R = GᵀG over blocked parameters. Three measurements:

  * gram products: batched-ATA (one trace, leading batch dim) vs plain
    batched matmul, dense and packed output;
  * a full optimizer step with ``packed_grams=True`` vs ``False`` —
    updates must match (allclose, f32) while the resident L/R statistics
    memory drops ~2×;
  * the analytic flop ratio (approaches 2/3·Strassen as blocks grow).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, smoke, time_fn
from repro import tune
from repro.core import ata_batched
from repro.core.reference import ata_flops, classical_syrk_flops
from repro.optim import constant
from repro.optim.shampoo import shampoo


def _gram_bench():
    rng = np.random.default_rng(3)
    cases = [(8, 512), (2, 1024), (1, 2048)]
    if smoke():
        cases = [(8, 512)]
    for nb, blk in cases:
        g = jnp.asarray(rng.standard_normal((nb, blk, blk)), jnp.float32)
        plan = tune.plan(op="ata", m=blk, n=blk, batch=nb)
        f_ata = jax.jit(lambda x: ata_batched(x, plan=plan))
        f_packed = jax.jit(lambda x: ata_batched(x, plan=plan, out="packed"))
        f_ref = jax.jit(lambda x: jnp.einsum("bmi,bmj->bij", x, x))
        t_ata = time_fn(f_ata, g)
        t_packed = time_fn(f_packed, g)
        t_ref = time_fn(f_ref, g)
        ratio = ata_flops(blk, blk, plan.n_base) / classical_syrk_flops(blk, blk)
        emit(
            f"shampoo_grams_{nb}x{blk}",
            t_ata,
            f"packed_us={t_packed*1e6:.1f} ref_us={t_ref*1e6:.1f} "
            f"speedup={t_ref/t_ata:.3f} flop_ratio={ratio:.3f}",
            shape=(nb, blk, blk),
            packed_seconds=t_packed,
            ref_seconds=t_ref,
        )


def _stat_bytes(state):
    """Resident bytes of the L/R gram statistics in an optimizer state."""
    total = 0
    for s in jax.tree.leaves(
        state["shampoo"],
        is_leaf=lambda x: isinstance(x, dict) and "l" in x,
    ):
        if isinstance(s, dict):
            total += s["l"].nbytes + s["r"].nbytes
    return total


def _step_bench():
    rng = np.random.default_rng(4)
    params = {
        "w1": jnp.asarray(rng.standard_normal((1024, 512)), jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((512, 512)), jnp.float32),
    }
    grads = jax.tree.map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32) * 1e-2,
        params,
    )
    results, bytes_, times = {}, {}, {}
    for packed in (True, False):
        # update_every=1 so the grams actually flow through the inverse-root
        # refresh into the update — the allclose below then certifies the
        # packed path end-to-end, not just the decay accumulation.
        opt = shampoo(
            constant(1e-3), block=512, update_every=1, packed_grams=packed,
        )
        state = opt.init(params)
        step = jax.jit(lambda g, s, p: opt.update(g, s, p))
        u, new_state = step(grads, state, params)
        jax.block_until_ready(u)
        times[packed] = time_fn(step, grads, state, params, iters=2, warmup=0)
        results[packed] = u
        bytes_[packed] = _stat_bytes(new_state)
    diff = max(
        float(jnp.abs(results[True][k] - results[False][k]).max()) for k in params
    )
    ok = all(
        np.allclose(results[True][k], results[False][k], rtol=1e-4, atol=1e-5)
        for k in params
    )
    emit(
        "shampoo_step_packed_vs_dense",
        times[True],
        f"dense_us={times[False]*1e6:.1f} "
        f"gram_state_bytes_packed={bytes_[True]} "
        f"gram_state_bytes_dense={bytes_[False]} "
        f"memory_ratio={bytes_[True]/bytes_[False]:.3f} "
        f"max_update_diff={diff:.2e} allclose={ok}",
        gram_state_bytes_packed=bytes_[True],
        gram_state_bytes_dense=bytes_[False],
        memory_ratio=round(bytes_[True] / bytes_[False], 4),
        updates_allclose=ok,
    )
    if not ok:
        raise AssertionError(
            f"packed and dense Shampoo updates diverged (max diff {diff:.2e})"
        )


def run():
    _gram_bench()
    _step_bench()


if __name__ == "__main__":
    run()
