"""Framework-integration bench: ATA-powered Shampoo gram statistics.

The production consumer of the paper's algorithm — per-step preconditioner
statistics L = G·Gᵀ, R = GᵀG over blocked parameters. Compares the
vmapped-ATA path against plain matmul grams at Shampoo block sizes, and
reports the analytic flop ratio (approaches 2/3·Strassen as blocks grow).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import ata
from repro.core.reference import ata_flops, classical_syrk_flops


def run():
    rng = np.random.default_rng(3)
    for nb, blk in [(8, 512), (2, 1024), (1, 2048)]:
        g = jnp.asarray(rng.standard_normal((nb, blk, blk)), jnp.float32)
        f_ata = jax.jit(jax.vmap(lambda x: ata(x, n_base=256)))
        f_ref = jax.jit(jax.vmap(lambda x: x.T @ x))
        t_ata = time_fn(f_ata, g)
        t_ref = time_fn(f_ref, g)
        ratio = ata_flops(blk, blk, 256) / classical_syrk_flops(blk, blk)
        emit(
            f"shampoo_grams_{nb}x{blk}",
            t_ata,
            f"ref_us={t_ref*1e6:.1f} speedup={t_ref/t_ata:.3f} "
            f"flop_ratio={ratio:.3f}",
        )


if __name__ == "__main__":
    run()
