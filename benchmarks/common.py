"""Shared benchmark helpers: timing, CSV output, effective-GFLOPs metric,
and machine-readable row collection (``BENCH_*.json``, written by ``run.py``).

The warmup/median timing discipline itself lives in
``repro.tune.search`` — one implementation shared by the measured
autotuner and every benchmark, re-exported here unchanged."""

from __future__ import annotations

import os

# single timing discipline, shared with the measured autotuner
from repro.tune.search import time_fn, time_pair  # noqa: F401  (re-export)

__all__ = [
    "time_fn",
    "time_pair",
    "effective_gflops",
    "emit",
    "drain_rows",
    "smoke",
    "SMOKE",
]

# rows emitted since the last drain — run.py drains after each bench module
# and writes them to BENCH_<module>.json so the perf trajectory is tracked.
_ROWS: list = []

# --smoke (run.py) / REPRO_BENCH_SMOKE=1: bench modules shrink their shape
# sweeps and iteration counts to CI scale.
SMOKE = False


def smoke() -> bool:
    return SMOKE or os.environ.get("REPRO_BENCH_SMOKE") == "1"


def effective_gflops(m: int, n: int, seconds: float, r: int = 1, k: int | None = None) -> float:
    """Paper Eq. (9) with the *actual* rectangular shape: ``r·m·n·k / time``.

    ``r=1`` for AᵀA-specialized algorithms (A is m×n, C is n×n → m·n² useful
    flops), ``r=2`` for general matmul — comparable across classical & fast
    algorithms. ``k`` defaults to ``n`` (the syrk case); pass it explicitly
    for rectangular gemm outputs. The seed used ``n³`` regardless of shape,
    which overstated tall-skinny syrk GFLOPs by m/n.
    """
    k = n if k is None else k
    return r * m * n * k / (seconds * 1e9)


def emit(name: str, seconds: float, derived: str, *, shape=None, gflops=None, **extra):
    """CSV row ``name,us_per_call,derived`` + JSON row for BENCH_*.json."""
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)
    row = {"name": name, "seconds": seconds, "derived": derived}
    if shape is not None:
        row["shape"] = list(shape)
    if gflops is not None:
        row["gflops"] = round(float(gflops), 3)
    row.update(extra)
    _ROWS.append(row)


def drain_rows() -> list:
    """Return and clear rows emitted since the last drain."""
    rows = list(_ROWS)
    _ROWS.clear()
    return rows
