"""Shared benchmark helpers: timing, CSV output, effective-GFLOPs metric,
and machine-readable row collection (``BENCH_*.json``, written by ``run.py``).

The warmup/median timing discipline itself lives in
``repro.tune.search`` — one implementation shared by the measured
autotuner and every benchmark, re-exported here unchanged.

Every emitted row carries structured **backend metadata**
(:func:`backend_meta`: ``backend``/``device_kind``/``jax_version``/
``interpret``) so BENCH_*.json trajectories are comparable across machines
— previously "interpret=True" was buried in free-text ``derived`` strings.
"""

from __future__ import annotations

import os

# single timing discipline, shared with the measured autotuner
from repro.tune.search import time_fn, time_pair  # noqa: F401  (re-export)

__all__ = [
    "time_fn",
    "time_pair",
    "effective_gflops",
    "backend_meta",
    "recursion_plan",
    "batched_recursion_plan",
    "emit",
    "drain_rows",
    "smoke",
    "SMOKE",
]

# rows emitted since the last drain — run.py drains after each bench module
# and writes them to BENCH_<module>.json so the perf trajectory is tracked.
_ROWS: list = []

# --smoke (run.py) / REPRO_BENCH_SMOKE=1: bench modules shrink their shape
# sweeps and iteration counts to CI scale.
SMOKE = False

_META: dict | None = None


def smoke() -> bool:
    return SMOKE or os.environ.get("REPRO_BENCH_SMOKE") == "1"


def backend_meta() -> dict:
    """Structured runtime identity stamped on every BENCH row.

    ``backend``: ``jax.default_backend()``; ``device_kind``: the first
    device's hardware name; ``jax_version``: the runtime (it is part of the
    plan-cache key for the same reason); ``interpret``: whether the Pallas
    kernels run in interpret mode here (``kernels.ops.interpret_default``)
    — kernel-path numbers from an interpret-mode machine are correctness
    signals, not performance signals, and now say so machine-readably.
    """
    global _META
    if _META is None:
        import jax

        from repro.kernels.ops import interpret_default

        dev = jax.devices()[0]
        _META = {
            "backend": jax.default_backend(),
            "device_kind": getattr(dev, "device_kind", type(dev).__name__),
            "jax_version": jax.__version__,
            "interpret": bool(interpret_default()),
        }
    return dict(_META)


def recursion_plan(op: str, m: int, n: int, k: int | None = None,
                   *, leaf_dispatch: str = "batched",
                   backend: str | None = None):
    """The planner's best *actually-recursing* candidate with the requested
    leaf dispatch, for the leaf-dispatch BENCH rows — shared by
    ``bench_ata``/``bench_strassen`` so each bench's "batched row"/"fused
    row" means the same thing. The planner's argmin may be a degenerate
    single-leaf (or dense) dispatch, which has nothing to contrast; the
    fallback then forces a couple of levels (classical variant — the one
    every dispatch supports)."""
    import dataclasses

    from repro import tune

    dims = (m, n, k) if op == "gemm_tn" else (m, n)
    kw = {} if backend is None else {"backend": backend}
    cands = tune.candidates(op=op, m=m, n=n, k=k, **kw)
    for cand in cands:
        if (
            cand.algorithm != "dense"
            and cand.leaf_dispatch == leaf_dispatch
            and cand.n_base < min(dims)
        ):
            return cand
    return dataclasses.replace(
        cands[0], algorithm="strassen", n_base=max(128, min(dims) // 4),
        leaf_dispatch=leaf_dispatch,
    )


def batched_recursion_plan(op: str, m: int, n: int, k: int | None = None,
                           *, backend: str | None = None):
    """Pre-fused-PR name for :func:`recursion_plan` at its default dispatch."""
    return recursion_plan(op, m, n, k, leaf_dispatch="batched", backend=backend)


def effective_gflops(m: int, n: int, seconds: float, r: int = 1, k: int | None = None) -> float:
    """Paper Eq. (9) with the *actual* rectangular shape: ``r·m·n·k / time``.

    ``r=1`` for AᵀA-specialized algorithms (A is m×n, C is n×n → m·n² useful
    flops), ``r=2`` for general matmul — comparable across classical & fast
    algorithms. ``k`` defaults to ``n`` (the syrk case); pass it explicitly
    for rectangular gemm outputs. The seed used ``n³`` regardless of shape,
    which overstated tall-skinny syrk GFLOPs by m/n.
    """
    k = n if k is None else k
    return r * m * n * k / (seconds * 1e9)


def emit(name: str, seconds: float, derived: str, *, shape=None, gflops=None, **extra):
    """CSV row ``name,us_per_call,derived`` + JSON row for BENCH_*.json.

    The JSON row always carries :func:`backend_meta`; ``extra`` keys land
    on top (and may override it, e.g. a subprocess bench reporting the
    device count it forced).
    """
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)
    row = {"name": name, "seconds": seconds, "derived": derived}
    row.update(backend_meta())
    if shape is not None:
        row["shape"] = list(shape)
    if gflops is not None:
        row["gflops"] = round(float(gflops), 3)
    row.update(extra)
    _ROWS.append(row)


def drain_rows() -> list:
    """Return and clear rows emitted since the last drain."""
    rows = list(_ROWS)
    _ROWS.clear()
    return rows
