"""Shared benchmark helpers: timing, CSV output, effective-GFLOPs metric,
and machine-readable row collection (``BENCH_*.json``, written by ``run.py``)."""

from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["time_fn", "time_pair", "effective_gflops", "emit", "drain_rows"]

# rows emitted since the last drain — run.py drains after each bench module
# and writes them to BENCH_<module>.json so the perf trajectory is tracked.
_ROWS: list = []


def time_fn(fn, *args, iters: int = 5, warmup: int = 2):
    """Median wall time (s) of fn(*args) with device sync."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_pair(fn_a, fn_b, *args, iters: int = 7, warmup: int = 2):
    """Median wall times of two functions measured **interleaved** (A, B,
    A, B, …) so background load drift hits both equally — use this when the
    quantity of interest is the ratio between the two (e.g. packed vs dense
    on a shared, throttled CPU container)."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)), float(np.median(tb))


def effective_gflops(m: int, n: int, seconds: float, r: int = 1, k: int | None = None) -> float:
    """Paper Eq. (9) with the *actual* rectangular shape: ``r·m·n·k / time``.

    ``r=1`` for AᵀA-specialized algorithms (A is m×n, C is n×n → m·n² useful
    flops), ``r=2`` for general matmul — comparable across classical & fast
    algorithms. ``k`` defaults to ``n`` (the syrk case); pass it explicitly
    for rectangular gemm outputs. The seed used ``n³`` regardless of shape,
    which overstated tall-skinny syrk GFLOPs by m/n.
    """
    k = n if k is None else k
    return r * m * n * k / (seconds * 1e9)


def emit(name: str, seconds: float, derived: str, *, shape=None, gflops=None, **extra):
    """CSV row ``name,us_per_call,derived`` + JSON row for BENCH_*.json."""
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)
    row = {"name": name, "seconds": seconds, "derived": derived}
    if shape is not None:
        row["shape"] = list(shape)
    if gflops is not None:
        row["gflops"] = round(float(gflops), 3)
    row.update(extra)
    _ROWS.append(row)


def drain_rows() -> list:
    """Return and clear rows emitted since the last drain."""
    rows = list(_ROWS)
    _ROWS.clear()
    return rows
