"""Shared benchmark helpers: timing, CSV output, effective-GFLOPs metric."""

from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["time_fn", "effective_gflops", "emit"]


def time_fn(fn, *args, iters: int = 5, warmup: int = 2):
    """Median wall time (s) of fn(*args) with device sync."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def effective_gflops(n: int, seconds: float, r: int = 1) -> float:
    """Paper Eq. (9): r·n³ / (time·1e9); r=1 for AᵀA-specialized algorithms,
    r=2 for general matmul — comparable across classical & fast algorithms."""
    return r * n**3 / (seconds * 1e9)


def emit(name: str, seconds: float, derived: str):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)
