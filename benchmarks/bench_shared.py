"""Paper Figure 5: shared-memory ATA-S scaling with thread/device count.

The shared-memory analogue on this container: ``ata_tile_parallel`` over a
P-device host-platform mesh (XLA CPU devices = threads on shared memory).
Each P runs in a subprocess (device count is fixed at jax init). Reported:
measured time, measured speedup vs P=1, and the paper's task-tree model
speedup (Eq. 8 via the LPT makespan) for the same P.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

from benchmarks.common import emit
from repro.core.task_tree import ell_shared, modeled_speedup

_CHILD = r"""
import jax, jax.numpy as jnp, numpy as np, time
from repro.compat import make_mesh
from repro.core.distributed import ata_tile_parallel
mesh = make_mesh((len(jax.devices()),), ("model",))
r = np.random.default_rng(0)
a = jnp.asarray(r.standard_normal(({m}, {n})), jnp.float32)
f = jax.jit(lambda a: ata_tile_parallel(a, mesh, task_axis="model"))
out = f(a); jax.block_until_ready(out)
ts = []
for _ in range(5):
    t0 = time.perf_counter(); jax.block_until_ready(f(a)); ts.append(time.perf_counter() - t0)
print("TIME", float(np.median(ts)))
"""


def _run_child(p: int, m: int, n: int) -> float:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run(
        [sys.executable, "-c", _CHILD.format(m=m, n=n)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    mt = re.search(r"TIME ([0-9.e-]+)", out.stdout)
    if not mt:
        raise RuntimeError(f"child failed: {out.stderr[-500:]}")
    return float(mt.group(1))


def run():
    m, n = 2048, 2048
    t1 = None
    for p in [1, 2, 4, 8]:
        t = _run_child(p, m, n)
        t1 = t1 or t
        emit(
            f"fig5_atas_P{p}_{m}x{n}",
            t,
            f"speedup={t1/t:.2f} modeled={modeled_speedup(n, p):.2f} "
            f"ell={ell_shared(p)}",
        )


if __name__ == "__main__":
    run()
