"""Planner benchmark: measured autotuned plans vs the hardcoded defaults.

For every benchmarked shape, run the measured autotuner
(`repro.tune.plan(..., autotune=True)`, persisted in a run-local cache
file) and report its **own interleaved measurement** against the
pre-tune-subsystem hardcoded configuration: the autotuner times every
candidate `time_pair`-interleaved with the default plan (load drift hits
both equally) and only displaces the default on a win beyond its noise
margin. `speedup_vs_default` is therefore ≥ 1.0 *by construction*: exactly
1.0 when the default survives the sweep, > 1 + margin when a candidate
genuinely beat it. (A fresh independent re-measure on this ±20-30%-jitter
container would be a coin flip, not information — see the timing notes in
``repro.tune.search``.)

Rows land in ``BENCH_tune.json`` with the full chosen plan; the tuned-plan
cache file is the artifact DESIGN.md §7 describes regenerating.
"""

from __future__ import annotations

import os
import tempfile

from benchmarks.common import emit, smoke
from repro import tune


def run():
    shapes = [(512, 512), (1024, 1024), (2048, 512), (4096, 1024)]
    if smoke():
        shapes = [(512, 512), (1024, 256)]

    cache_file = os.environ.get("REPRO_TUNE_CACHE")
    if cache_file is None:
        # BENCH_tune.json tracks the perf trajectory across PRs, so every
        # un-configured run must RE-tune: drop the scratch cache from any
        # previous run. Set REPRO_TUNE_CACHE to opt into persistence.
        cache_file = os.path.join(
            tempfile.gettempdir(), "repro_bench_tune_cache.json"
        )
        if os.path.exists(cache_file):
            os.remove(cache_file)

    for m, n in shapes:
        tuned = tune.plan(
            op="ata", m=m, n=n, autotune=True, cache_file=cache_file,
        )
        t_tuned = tuned.measured_s or 0.0
        t_def = tuned.baseline_s or t_tuned
        ratio = t_def / t_tuned if t_tuned else 1.0
        base = tune.cost.default_plan("ata", m, n)
        kept_default = tune.search._same_dispatch(tuned, base)
        emit(
            f"tune_ata_{m}x{n}",
            t_tuned,
            f"algo={tuned.algorithm} n_base={tuned.n_base} out={tuned.out} "
            f"src={tuned.source} default_us={t_def*1e6:.1f} "
            f"speedup_vs_default={ratio:.3f} kept_default={kept_default}",
            shape=(m, n),
            default_seconds=t_def,
            speedup_vs_default=round(ratio, 4),
            kept_default=kept_default,
            plan=tuned.to_json(),
            default_plan=base.to_json(),
        )

    emit(
        "tune_cache_file",
        0.0,
        f"cache={cache_file} entries={len(tune.cache.load_cache(cache_file))}",
        cache_file=cache_file,
    )


if __name__ == "__main__":
    run()
