"""Paper Figure 6 + Table 1: distributed ATA-D vs baselines.

Distributed analogue on host-platform devices: the two-level schedule
(rows over 'data' × tiles over 'model' — ATA-D's layout) vs the plain
single-device classical gram ("1-rank baseline"), including the
distribute/retrieve cost (device_put of A + full gather of C), which is
what the paper's shaded areas measure. Also reports the analytic
latency/bandwidth model of Prop. 4.2 for the same (n, P).

The **packed-retrieval comparison** (smoke-safe: compile-only, no timing
loop) lowers the dense and packed output modes of ``ata_tile_parallel``
and ``gram_rowshard`` on an 8-fake-device mesh and records the per-device
collective bytes from the compiled HLO — the Prop. 4.2 low(C) saving as
measured collective payload, tracked in ``BENCH_distributed.json``.

The **BFS/DFS rows** (``collectives_bfsdfs_*``, also compile-only and
smoke-safe) lower the CAPS-style schedule with the *planner-selected*
interleaving at three mesh shapes and record its collective bytes next to
the per-level ``prop42_msgs``/``prop42_words`` attribution of
``tune.cost.comm_levels`` — the perf-diff surface that catches
communication regressions, not just wall clock. ``fig6_bfsdfs_P*`` times
the planned front door (``tune.apply.ata_distributed_with_plan``)
end-to-end against the same 1-rank baseline as ``fig6_atad_P*``.
"""

from __future__ import annotations

import json
import os
import re
import subprocess
import sys

from benchmarks.common import emit, smoke
from repro.core.task_tree import ell_distributed

_CHILD = r"""
import jax, jax.numpy as jnp, numpy as np, time
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import ata_tile_parallel
devs = len(jax.devices())
d = {d}; m = devs // d
from repro.compat import make_mesh
mesh = make_mesh((d, m), ("data", "model"))
r = np.random.default_rng(0)
a_host = r.standard_normal(({m_}, {n})).astype(np.float32)
f = jax.jit(lambda a: ata_tile_parallel(a, mesh, task_axis="model",
                                        row_axis="data"))
sh = NamedSharding(mesh, P("data", None))
# warm
a = jax.device_put(jnp.asarray(a_host), sh); jax.block_until_ready(f(a))
tc, tt = [], []
for _ in range(5):
    t0 = time.perf_counter()
    a = jax.device_put(jnp.asarray(a_host), sh)      # distribute
    c = f(a)                                          # compute
    jax.block_until_ready(c)
    t1 = time.perf_counter()
    _ = np.asarray(c)                                 # retrieve to host
    t2 = time.perf_counter()
    tc.append(t1 - t0); tt.append(t2 - t0)
print("TIME", float(np.median(tc)), float(np.median(tt)))
"""


def _run_child(p: int, d: int, m: int, n: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run(
        [sys.executable, "-c", _CHILD.format(d=d, m_=m, n=n)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    mt = re.search(r"TIME ([0-9.e-]+) ([0-9.e-]+)", out.stdout)
    if not mt:
        raise RuntimeError(f"child failed (P={p}): {out.stderr[-500:]}")
    return float(mt.group(1)), float(mt.group(2))


# compile-only child: per-device collective bytes of dense vs packed
# retrieval (token-templated — the script body contains dict braces).
_COLLECTIVES_CHILD = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.analysis.hlo import collective_bytes, compiled_text
from repro.core.distributed import ata_tile_parallel, gram_rowshard
from repro.obs import metrics as obs_metrics
m, n = @M@, @N@
mesh = make_mesh((2, 4), ("data", "model"))
a_abs = jax.ShapeDtypeStruct((m, n), jnp.float32)
sh = NamedSharding(mesh, P("data", None))
out = {}
for mode in ("dense", "packed"):
    f = jax.jit(
        lambda a, mode=mode: ata_tile_parallel(
            a, mesh, task_axis="model", row_axis="data", out=mode),
        in_shardings=(sh,),
    )
    hlo = compiled_text(f, a_abs)
    obs_metrics.record_collective_bytes(hlo, prefix="collective_bytes.tile_" + mode)
    out["tile_" + mode] = collective_bytes(hlo)
row_abs = jax.ShapeDtypeStruct((m, n), jnp.float32)
for mode in ("dense", "packed"):
    out_spec = P(None, None, None) if mode == "packed" else P(None, None)
    f = jax.jit(shard_map(
        lambda x, mode=mode: gram_rowshard(x, "data", out=mode),
        mesh=make_mesh((8,), ("data",)),
        in_specs=(P("data", None),), out_specs=out_spec))
    hlo = compiled_text(f, row_abs)
    obs_metrics.record_collective_bytes(hlo, prefix="collective_bytes.rowshard_" + mode)
    out["rowshard_" + mode] = collective_bytes(hlo)
print("BYTES " + json.dumps(out))
"""


def _run_collectives_child(p: int, m: int, n: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.abspath("src")
    script = _COLLECTIVES_CHILD.replace("@M@", str(m)).replace("@N@", str(n))
    out = subprocess.run(
        [sys.executable, "-c", script],
        env=env, capture_output=True, text=True, timeout=900,
    )
    mt = re.search(r"BYTES (\{.*\})", out.stdout)
    if not mt:
        raise RuntimeError(f"collectives child failed: {out.stderr[-800:]}")
    return json.loads(mt.group(1))


def run_collectives(m: int = 1024, n: int = 1024):
    """Packed vs dense retrieval: collective bytes from compiled HLO."""
    bytes_by = _run_collectives_child(8, m, n)
    for schedule in ("tile", "rowshard"):
        dense = sum(bytes_by[f"{schedule}_dense"].values())
        packed = sum(bytes_by[f"{schedule}_packed"].values())
        ratio = packed / dense if dense else float("nan")
        emit(
            f"collectives_{schedule}_{m}x{n}",
            0.0,
            f"dense_bytes={dense} packed_bytes={packed} ratio={ratio:.3f}",
            shape=(m, n),
            dense_bytes=dense,
            packed_bytes=packed,
            packed_over_dense=round(ratio, 4),
        )


# compile-only child: the BFS/DFS schedule with planner-selected
# interleaving at several mesh shapes (token-templated like above).
_BFSDFS_CHILD = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.analysis.hlo import collective_bytes, compiled_text
from repro.core.distributed import ata_bfs_dfs
from repro.obs import metrics as obs_metrics
from repro.tune import cost
m, n = @M@, @N@
out = {}
for dd, dm in ((2, 4), (4, 2), (8, 1)):
    mesh = make_mesh((dd, dm), ("data", "model"))
    sh = NamedSharding(mesh, P("data", None))
    a_abs = jax.ShapeDtypeStruct((m, n), jnp.float32)
    for mode in ("dense", "packed"):
        plans = cost.candidates("ata", m, n, out=mode, backend="cpu",
                                devices=dm, row_devices=dd)
        top = next((p for p in plans
                    if p.comm_schedule and "B" in p.comm_schedule), None)
        if top is None:
            continue
        f = jax.jit(
            lambda a, top=top, mesh=mesh, mode=mode: ata_bfs_dfs(
                a, mesh, task_axis="model", row_axis="data",
                interleaving=top.comm_schedule, nb=top.nb,
                packed_block=top.packed_block, out=mode),
            in_shardings=(sh,),
        )
        hlo = compiled_text(f, a_abs)
        key = "bfsdfs_%dx%d_%s" % (dd, dm, mode)
        obs_metrics.record_collective_bytes(
            hlo, prefix="collective_bytes." + key)
        levels = cost.comm_levels(top.comm_schedule, top.nb, top.tile_w,
                                  dm, dd, out=mode)
        out[key] = dict(bytes=collective_bytes(hlo), cs=top.comm_schedule,
                        nb=top.nb, tile_w=top.tile_w, levels=levels)
print("BYTES " + json.dumps(out))
"""


def _run_bfsdfs_child(p: int, m: int, n: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.abspath("src")
    script = _BFSDFS_CHILD.replace("@M@", str(m)).replace("@N@", str(n))
    out = subprocess.run(
        [sys.executable, "-c", script],
        env=env, capture_output=True, text=True, timeout=900,
    )
    mt = re.search(r"BYTES (\{.*\})", out.stdout)
    if not mt:
        raise RuntimeError(f"bfsdfs child failed: {out.stderr[-800:]}")
    return json.loads(mt.group(1))


def run_collectives_bfsdfs(m: int = 1024, n: int = 1024):
    """BFS/DFS collective bytes + per-level α-β attribution, per mesh."""
    data = _run_bfsdfs_child(8, m, n)
    for dd, dm in ((2, 4), (4, 2), (8, 1)):
        kd, kp = f"bfsdfs_{dd}x{dm}_dense", f"bfsdfs_{dd}x{dm}_packed"
        if kd not in data or kp not in data:
            continue
        dense = sum(data[kd]["bytes"].values())
        packed = sum(data[kp]["bytes"].values())
        ratio = packed / dense if dense else float("nan")
        lv = data[kp]["levels"]
        msgs = [round(l["msgs"], 1) for l in lv]
        words = [int(round(l["words"])) for l in lv]
        tags = "".join(l["tag"] for l in lv)
        emit(
            f"collectives_bfsdfs_{dd}x{dm}_{m}x{n}",
            0.0,
            f"cs={data[kp]['cs']} nb={data[kp]['nb']} "
            f"dense_bytes={dense} packed_bytes={packed} ratio={ratio:.3f} "
            f"levels={tags} prop42_msgs={msgs} prop42_words={words}",
            shape=(m, n),
            comm_schedule=data[kp]["cs"],
            nb=data[kp]["nb"],
            tile_w=data[kp]["tile_w"],
            dense_bytes=dense,
            packed_bytes=packed,
            packed_over_dense=round(ratio, 4),
            prop42_msgs=msgs,
            prop42_words=words,
        )


_BFS_FIG6_CHILD = r"""
import jax, jax.numpy as jnp, numpy as np, time
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.tune import cost
from repro.tune.apply import ata_distributed_with_plan
devs = len(jax.devices())
d = {d}; m = devs // d
mesh = make_mesh((d, m), ("data", "model"))
plans = cost.candidates("ata", {m_}, {n}, out="packed", backend="cpu",
                        devices=m, row_devices=d)
top = next(p for p in plans if p.comm_schedule and "B" in p.comm_schedule)
r = np.random.default_rng(0)
a_host = r.standard_normal(({m_}, {n})).astype(np.float32)
f = jax.jit(lambda a: ata_distributed_with_plan(
    a, mesh, top, task_axis="model", row_axis="data"))
sh = NamedSharding(mesh, P("data", None))
a = jax.device_put(jnp.asarray(a_host), sh)
jax.block_until_ready(f(a).blocks)
tc, tt = [], []
for _ in range(5):
    t0 = time.perf_counter()
    a = jax.device_put(jnp.asarray(a_host), sh)      # distribute
    c = f(a)                                          # compute
    jax.block_until_ready(c.blocks)
    t1 = time.perf_counter()
    _ = np.asarray(c.blocks)                          # retrieve (packed)
    t2 = time.perf_counter()
    tc.append(t1 - t0); tt.append(t2 - t0)
print("PLAN", top.comm_schedule, top.nb)
print("TIME", float(np.median(tc)), float(np.median(tt)))
"""


def _run_bfs_fig6_child(p: int, d: int, m: int, n: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run(
        [sys.executable, "-c", _BFS_FIG6_CHILD.format(d=d, m_=m, n=n)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    mt = re.search(r"TIME ([0-9.e-]+) ([0-9.e-]+)", out.stdout)
    pl = re.search(r"PLAN (\S+) (\d+)", out.stdout)
    if not mt or not pl:
        raise RuntimeError(f"bfs fig6 child failed (P={p}): {out.stderr[-500:]}")
    return float(mt.group(1)), float(mt.group(2)), pl.group(1), int(pl.group(2))


def _prop42(n: int, p: int):
    """Prop. 4.2 analytic latency (messages) and bandwidth (words)."""
    ell = ell_distributed(p)
    lat = 2 * (7 * max(ell - 1, 0) + 5)
    bw = 6 * (n / 2) ** 2 + n * (n + 2) / 2
    if ell >= 2:
        bw += 7 / 6 * n**2 * (1 - 1 / 4 ** (ell - 2))
    return lat, bw


def run():
    # packed-vs-dense collective bytes: cheap (compile-only), runs in
    # --smoke too — this is the CI-tracked Prop. 4.2 retrieval number,
    # and the BFS/DFS rows are the communication-regression surface.
    run_collectives()
    run_collectives_bfsdfs()
    if smoke():
        return
    m, n = 4096, 2048
    base_c, base_t = _run_child(1, 1, m, n)
    emit(f"fig6_atad_P1_{m}x{n}", base_t, f"compute_us={base_c*1e6:.0f} speedup=1.00")
    for p, d in [(2, 2), (4, 2), (8, 2)]:
        tc, tt = _run_child(p, d, m, n)
        lat, bw = _prop42(n, p)
        emit(
            f"fig6_atad_P{p}_{m}x{n}",
            tt,
            f"compute_us={tc*1e6:.0f} speedup={base_t/tt:.2f} "
            f"ell={ell_distributed(p)} prop42_msgs={lat} prop42_words={bw:.2e}",
        )
    # the BFS/DFS schedule through the planned front door, same baseline:
    # packed-native retrieval (the schedule's root mode) + the tri-direct
    # reduce-scatter replacing the psum + root-gather pair.
    for p, d in [(2, 2), (4, 2), (8, 2)]:
        tc, tt, cs, nb_sel = _run_bfs_fig6_child(p, d, m, n)
        emit(
            f"fig6_bfsdfs_P{p}_{m}x{n}",
            tt,
            f"compute_us={tc*1e6:.0f} speedup={base_t/tt:.2f} "
            f"cs={cs} nb={nb_sel}",
        )
    # Table 1 analogue: SM (all devices one task axis) vs DM (2-level) at
    # growing n — speedup of the 2-level layout including retrieval.
    for nn in [1024, 2048]:
        sm_c, sm_t = _run_child(8, 1, 2 * nn, nn)
        dm_c, dm_t = _run_child(8, 2, 2 * nn, nn)
        emit(
            f"table1_sm_vs_dm_n{nn}", dm_t,
            f"sm_us={sm_t*1e6:.0f} dm_us={dm_t*1e6:.0f} speedup={sm_t/dm_t:.2f}",
        )


if __name__ == "__main__":
    run()
