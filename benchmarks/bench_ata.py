"""Paper Figure 3: sequential ATA vs the classical syrk (`dsyrk` analogue).

Compares ``repro.core.ata`` (Strassen-based, 2/3·T_S flops) against the
XLA-native classical ``AᵀA`` on square and tall matrices of growing size,
in three flavours:

  * ``dense``  — full square, one root mirror, dispatched exactly as the
    planner says (the plan's ``leaf_dispatch`` included);
  * ``packed`` — mirror-free ``SymmetricMatrix`` output (the storage half of
    the paper's symmetry claim). Must be at parity or faster than dense;
  * ``batched`` — the recursion with **batched leaf dispatch** against the
    same recursion unrolled, on one recursion-forcing plan per shape: the
    level-synchronous formulation's whole point is to stop losing the
    paper's flop saving to per-leaf dispatch overhead, so this row records
    the Strassen-vs-dot speedup both ways;
  * ``fused``  — the recursion with **fused-operand leaf dispatch** (the ±1
    combinations folded into the leaf products, zero materialized operand
    stacks) against the same recursion unrolled, interleaved.

Derived column: effective GFLOPs (Eq. 9 with the actual m·n² shape, r=1)
for each path, the measured speedups, and the analytic flop ratio at that
size/cutoff.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    effective_gflops,
    emit,
    recursion_plan,
    smoke,
    time_fn,
    time_pair,
)
from repro import tune
from repro.core import ata
from repro.core.reference import ata_flops, classical_syrk_flops


def run():
    rng = np.random.default_rng(0)
    shapes = [(512, 512), (1024, 1024), (2048, 2048), (4096, 1024), (2048, 512)]
    if smoke():
        shapes = [(512, 512), (1024, 1024)]
    for m, n in shapes:
        a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)

        # one planner decision per shape (analytic model / plan cache);
        # the packed run shares the plan's recursion bitwise.
        plan = tune.plan(op="ata", m=m, n=n)
        f_ata = jax.jit(lambda a: ata(a, plan=plan))
        f_packed = jax.jit(lambda a: ata(a, plan=plan, out="packed"))
        f_ref = jax.jit(
            lambda a: jax.lax.dot_general(
                a, a, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
        )
        # dense/packed interleaved: their *ratio* is the claim under test,
        # and this container's background load drifts on a seconds scale.
        t_ata, t_packed = time_pair(f_ata, f_packed, a)
        t_ref = time_fn(f_ref, a)
        flop_ratio = ata_flops(m, n, plan.n_base) / classical_syrk_flops(m, n)
        emit(
            f"fig3_ata_{m}x{n}",
            t_ata,
            f"eff_gflops={effective_gflops(m, n, t_ata):.2f} "
            f"ref_gflops={effective_gflops(m, n, t_ref):.2f} "
            f"speedup={t_ref / t_ata:.3f} flop_ratio={flop_ratio:.3f}",
            shape=(m, n),
            gflops=effective_gflops(m, n, t_ata),
            mode="dense",
            ref_seconds=t_ref,
            n_base=plan.n_base,
            algorithm=plan.algorithm,
            leaf_dispatch=plan.leaf_dispatch,
        )
        emit(
            f"fig3_ata_packed_{m}x{n}",
            t_packed,
            f"eff_gflops={effective_gflops(m, n, t_packed):.2f} "
            f"vs_dense={t_ata / t_packed:.3f} "
            f"speedup={t_ref / t_packed:.3f} flop_ratio={flop_ratio:.3f}",
            shape=(m, n),
            gflops=effective_gflops(m, n, t_packed),
            mode="packed",
            dense_seconds=t_ata,
            packed_vs_dense_speedup=round(t_ata / t_packed, 4),
        )

        # leaf-dispatch comparison: the SAME recursion, unrolled vs batched,
        # interleaved (the ratio is the claim; see tune.search.time_pair).
        plan_b = recursion_plan(
            "ata", m, n, leaf_dispatch="batched", backend=plan.backend
        )
        plan_u = dataclasses.replace(plan_b, leaf_dispatch="unrolled")
        f_unr = jax.jit(lambda a: ata(a, plan=plan_u))
        f_bat = jax.jit(lambda a: ata(a, plan=plan_b))
        t_unr, t_bat = time_pair(f_unr, f_bat, a)
        emit(
            f"fig3_ata_batched_{m}x{n}",
            t_bat,
            f"eff_gflops={effective_gflops(m, n, t_bat):.2f} "
            f"speedup={t_ref / t_bat:.3f} unrolled_speedup={t_ref / t_unr:.3f} "
            f"batched_vs_unrolled={t_unr / t_bat:.3f} n_base={plan_u.n_base}",
            shape=(m, n),
            gflops=effective_gflops(m, n, t_bat),
            mode="dense",
            ref_seconds=t_ref,
            unrolled_seconds=t_unr,
            batched_vs_unrolled=round(t_unr / t_bat, 4),
            n_base=plan_u.n_base,
            algorithm=plan_u.algorithm,
            leaf_dispatch="batched",
        )

        # fused vs unrolled on the planner's best fused recursion,
        # interleaved — the zero-operand-stack leaf combine
        plan_f = recursion_plan(
            "ata", m, n, leaf_dispatch="fused", backend=plan.backend
        )
        plan_uf = dataclasses.replace(plan_f, leaf_dispatch="unrolled")
        f_unr_f = jax.jit(lambda a: ata(a, plan=plan_uf))
        f_fus = jax.jit(lambda a: ata(a, plan=plan_f))
        t_unr_f, t_fus = time_pair(f_unr_f, f_fus, a)
        emit(
            f"fig3_ata_fused_{m}x{n}",
            t_fus,
            f"eff_gflops={effective_gflops(m, n, t_fus):.2f} "
            f"speedup={t_ref / t_fus:.3f} unrolled_speedup={t_ref / t_unr_f:.3f} "
            f"fused_vs_unrolled={t_unr_f / t_fus:.3f} n_base={plan_f.n_base}",
            shape=(m, n),
            gflops=effective_gflops(m, n, t_fus),
            mode="dense",
            ref_seconds=t_ref,
            unrolled_seconds=t_unr_f,
            fused_vs_unrolled=round(t_unr_f / t_fus, 4),
            n_base=plan_f.n_base,
            algorithm=plan_f.algorithm,
            leaf_dispatch="fused",
        )


if __name__ == "__main__":
    run()
