"""Paper Figure 3: sequential ATA vs the classical syrk (`dsyrk` analogue).

Compares ``repro.core.ata`` (Strassen-based, 2/3·T_S flops) against the
XLA-native classical ``AᵀA`` on square and tall matrices of growing size.
Derived column: effective GFLOPs (Eq. 9, r=1) for both, the measured
speedup, and the analytic flop ratio at that size/cutoff.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import effective_gflops, emit, time_fn
from repro.core import ata
from repro.core.reference import ata_flops, classical_syrk_flops

N_BASE = 256


def run():
    rng = np.random.default_rng(0)
    for m, n in [(512, 512), (1024, 1024), (2048, 2048), (4096, 1024), (2048, 512)]:
        a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)

        f_ata = jax.jit(lambda a: ata(a, n_base=N_BASE))
        f_ref = jax.jit(
            lambda a: jax.lax.dot_general(
                a, a, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
        )
        t_ata = time_fn(f_ata, a)
        t_ref = time_fn(f_ref, a)
        flop_ratio = ata_flops(m, n, N_BASE) / classical_syrk_flops(m, n)
        emit(
            f"fig3_ata_{m}x{n}",
            t_ata,
            f"eff_gflops={effective_gflops(n, t_ata):.2f} "
            f"ref_gflops={effective_gflops(n, t_ref):.2f} "
            f"speedup={t_ref / t_ata:.3f} flop_ratio={flop_ratio:.3f}",
        )


if __name__ == "__main__":
    run()
