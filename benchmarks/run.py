"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run fig3 fig5  # filter by prefix
"""

from __future__ import annotations

import sys
import traceback

BENCHES = [
    ("fig3_ata_vs_syrk", "benchmarks.bench_ata"),
    ("fig4_faststrassen_vs_gemm", "benchmarks.bench_strassen"),
    ("fig5_shared_memory_scaling", "benchmarks.bench_shared"),
    ("fig6_distributed_scaling", "benchmarks.bench_distributed"),
    ("kernels_pallas", "benchmarks.bench_kernels"),
    ("shampoo_integration", "benchmarks.bench_shampoo"),
]


def main() -> None:
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    failed = []
    for name, module in BENCHES:
        if filters and not any(f in name for f in filters):
            continue
        print(f"# --- {name} ({module}) ---", flush=True)
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
        except Exception as e:
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
