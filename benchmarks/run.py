"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and, per module, writes a
machine-readable ``BENCH_<key>.json`` (list of ``{name, shape, seconds,
gflops, ...}`` rows — every row stamped with the backend metadata from
``benchmarks.common.backend_meta``) so the perf trajectory is tracked
across PRs.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run fig3 fig5  # filter by prefix
    PYTHONPATH=src python -m benchmarks.run --out results/bench
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI-scale subset
    PYTHONPATH=src python -m benchmarks.run --profile  # + obs & traces

``--smoke`` shrinks every module's shape sweep/iteration count
(``common.smoke()``) and skips the subprocess-per-device-count modules
(fig5/fig6) — minutes of wall time instead of tens.

``--profile`` turns the ``repro.obs`` subsystem on for the whole run and
wraps each bench module in ``jax.profiler.trace`` (guarded: containers
whose jax build lacks a working profiler just skip the trace, never
crash), writing trace artifacts under ``<out>/benchmarks/profiles/<key>/``
and one ``BENCH_obs.json`` metrics+calibration snapshot for the run; the
calibration drift report prints at the end (DESIGN.md §8).

After each module, fresh rows are diffed against the **committed**
``BENCH_<key>.json`` baseline (``repro.analysis.perf_diff.bench_diff``)
and the table printed — report-only, never failing, in ``--smoke``/CI runs
included. Cross-machine deltas are flagged via the rows' backend metadata.
"""

from __future__ import annotations

import json
import os
import sys
import traceback

from benchmarks import common

# (display name, module, BENCH json key)
BENCHES = [
    ("fig3_ata_vs_syrk", "benchmarks.bench_ata", "ata"),
    ("fig4_faststrassen_vs_gemm", "benchmarks.bench_strassen", "strassen"),
    ("fig5_shared_memory_scaling", "benchmarks.bench_shared", "shared"),
    ("fig6_distributed_scaling", "benchmarks.bench_distributed", "distributed"),
    ("kernels_pallas", "benchmarks.bench_kernels", "kernels"),
    ("shampoo_integration", "benchmarks.bench_shampoo", "shampoo"),
    ("tune_planner", "benchmarks.bench_tune", "tune"),
    ("solve_normal_equations", "benchmarks.bench_solve", "solve"),
    ("serve_gram_service", "benchmarks.bench_serve", "serve"),
]

# multi-process device sweeps — too slow for the CI smoke job.
# (fig6 is NOT skipped: in smoke mode bench_distributed runs only its
# compile-only packed-vs-dense collective-bytes comparison.)
_SKIP_IN_SMOKE = {"fig5_shared_memory_scaling"}

# committed baselines live next to this package, at the repo root
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_baseline_rows(key: str) -> list:
    try:
        with open(os.path.join(_REPO_ROOT, f"BENCH_{key}.json")) as f:
            return json.load(f).get("rows", [])
    except (OSError, json.JSONDecodeError):
        return []


def _report_diff(key: str, rows: list) -> None:
    """Print the fresh-vs-committed diff table. Report-only by contract:
    any failure here is reported as a note, never propagated."""
    try:
        from repro.analysis.perf_diff import bench_diff, print_bench_diff

        baseline = _load_baseline_rows(key)
        if baseline:
            print_bench_diff(key, bench_diff(baseline, rows))
    except Exception as e:  # pragma: no cover - must never fail the bench
        print(f"# perf diff for {key} unavailable: {type(e).__name__}: {e}")


class _profile_trace:
    """``jax.profiler.trace`` for one bench module, tolerated to fail.

    Interpret-mode CPU containers (and stripped jax builds) can lack a
    working profiler backend; a profiling *bench* run must still produce
    its timing rows, so any profiler error downgrades to a note.
    """

    def __init__(self, log_dir: str):
        self.log_dir = log_dir
        self._active = False

    def __enter__(self):
        try:
            import jax

            os.makedirs(self.log_dir, exist_ok=True)
            jax.profiler.start_trace(self.log_dir)
            self._active = True
        except Exception as e:
            print(f"# profiler trace unavailable: {type(e).__name__}: {e}")
        return self

    def __exit__(self, *exc):
        if self._active:
            try:
                import jax

                jax.profiler.stop_trace()
            except Exception as e:
                print(f"# profiler stop failed: {type(e).__name__}: {e}")
        return False


def main() -> None:
    args = sys.argv[1:]
    out_dir = "."
    profile = False
    if "--smoke" in args:
        args.remove("--smoke")
        common.SMOKE = True
        os.environ["REPRO_BENCH_SMOKE"] = "1"  # reaches bench subprocesses
    if "--profile" in args:
        args.remove("--profile")
        profile = True
    if "--out" in args:
        i = args.index("--out")
        if i + 1 >= len(args) or args[i + 1].startswith("-"):
            raise SystemExit(
                "usage: benchmarks.run [--smoke] [--profile] [--out DIR] [filter ...]"
            )
        out_dir = args[i + 1]
        args = args[:i] + args[i + 2 :]
        os.makedirs(out_dir, exist_ok=True)
    if profile:
        from repro import obs

        obs.enable()
    filters = [a for a in args if not a.startswith("-")]
    print("name,us_per_call,derived")
    failed = []
    for name, module, key in BENCHES:
        if filters and not any(f in name for f in filters):
            continue
        if common.SMOKE and not filters and name in _SKIP_IN_SMOKE:
            print(f"# --- {name} skipped (--smoke) ---", flush=True)
            continue
        print(f"# --- {name} ({module}) ---", flush=True)
        common.drain_rows()  # isolate rows per module
        path = os.path.join(out_dir, f"BENCH_{key}.json")
        try:
            mod = __import__(module, fromlist=["run"])
            if profile:
                profile_dir = os.path.join(out_dir, "benchmarks", "profiles", key)
                with _profile_trace(profile_dir):
                    mod.run()
            else:
                mod.run()
        except Exception as e:
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
            # never leave a stale passing JSON behind a failed bench
            with open(path, "w") as f:
                json.dump(
                    {"error": f"{type(e).__name__}: {e}", "rows": common.drain_rows()},
                    f, indent=1,
                )
            continue
        rows = common.drain_rows()
        _report_diff(key, rows)  # diff BEFORE overwriting a root baseline
        with open(path, "w") as f:
            json.dump({"rows": rows}, f, indent=1)
        print(f"# wrote {path} ({len(rows)} rows)", flush=True)
    if profile:
        from repro import obs

        obs_path = os.path.join(out_dir, "BENCH_obs.json")
        obs.metrics.export_json(obs_path)
        print(f"# wrote {obs_path}", flush=True)
        print(obs.report(), flush=True)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
