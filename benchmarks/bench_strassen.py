"""Paper Figure 4: FastStrassen vs the classical gemm (`dgemm` analogue).

``strassen_tn`` (classical variant — the paper's FastStrassen) against
XLA's native TN matmul, plus the Winograd variant (beyond-paper, 15 adds).
The paper's pre-allocation lesson (Section 3.3) maps to trace-time
recursion + XLA buffer reuse, so there is no separate "naive allocation"
curve — its analogue (per-call retrace/realloc, `no_jit`) is reported to
show the same effect.

The ``fig4_strassen_batched_*`` rows run the SAME planned recursion with
``leaf_dispatch='batched'`` (all 7^L leaves in one batched TN dot) against
the unrolled form, interleaved — the dispatch-overhead claim of the
batched-leaf PR: the recursion's speedup-vs-dot must come from flops, not
be eaten by per-leaf launches. The ``fig4_strassen_fused_*`` rows do the
same for ``leaf_dispatch='fused'`` (the ±1 operand combinations folded
into the leaf products, zero materialized operand stacks) — the
fused-leaf PR's claim that removing the combine traffic beats both the
per-leaf launches of unrolled *and* the stack materialization of batched.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    effective_gflops,
    emit,
    recursion_plan,
    smoke,
    time_fn,
    time_pair,
)
from repro import tune
from repro.core import strassen_tn
from repro.core.reference import classical_gemm_flops, strassen_tn_flops


def run():
    rng = np.random.default_rng(1)
    shapes = [(1024, 1024, 1024), (2048, 2048, 2048), (4096, 1024, 1024)]
    if smoke():
        shapes = [(1024, 1024, 1024)]
    for m, n, k in shapes:
        a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

        # planner decision per shape; Strassen/Winograd compared on the
        # same planned cutoff (the figure contrasts the two schedules).
        plan = tune.plan(op="gemm_tn", m=m, n=n, k=k)
        if plan.algorithm == "dense":  # figure needs the recursion itself
            plan = dataclasses.replace(plan, algorithm="strassen")
        plan = dataclasses.replace(plan, leaf_dispatch="unrolled")
        plan_wg = dataclasses.replace(plan, algorithm="winograd")
        # the batched row runs the planner's best batched recursive
        # candidate (its argmin may be the plain dense dot); the unrolled
        # twin flips only leaf_dispatch so their ratio isolates dispatch.
        plan_bat = recursion_plan(
            "gemm_tn", m, n, k, leaf_dispatch="batched", backend=plan.backend
        )
        plan_ubat = dataclasses.replace(plan_bat, leaf_dispatch="unrolled")
        plan_fus = recursion_plan(
            "gemm_tn", m, n, k, leaf_dispatch="fused", backend=plan.backend
        )
        plan_ufus = dataclasses.replace(plan_fus, leaf_dispatch="unrolled")
        f_st = jax.jit(lambda a, b: strassen_tn(a, b, plan=plan))
        f_wg = jax.jit(lambda a, b: strassen_tn(a, b, plan=plan_wg))
        f_bat = jax.jit(lambda a, b: strassen_tn(a, b, plan=plan_bat))
        f_ubat = jax.jit(lambda a, b: strassen_tn(a, b, plan=plan_ubat))
        f_ref = jax.jit(
            lambda a, b: jax.lax.dot_general(
                a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
        )
        t_st = time_fn(f_st, a, b)
        t_wg = time_fn(f_wg, a, b)
        t_ref = time_fn(f_ref, a, b)
        # the "naive Strassen" analogue: retrace + realloc every call
        t_nojit = time_fn(lambda a, b: strassen_tn(a, b, plan=plan), a, b, iters=3)
        ratio = strassen_tn_flops(m, n, k, plan.n_base) / classical_gemm_flops(m, n, k)
        emit(
            f"fig4_strassen_{m}x{n}x{k}",
            t_st,
            f"eff_gflops={effective_gflops(m, n, t_st, r=2, k=k):.2f} "
            f"winograd_us={t_wg*1e6:.1f} ref_us={t_ref*1e6:.1f} "
            f"nojit_us={t_nojit*1e6:.1f} speedup={t_ref/t_st:.3f} "
            f"flop_ratio={ratio:.3f}",
            shape=(m, n, k),
            gflops=effective_gflops(m, n, t_st, r=2, k=k),
            n_base=plan.n_base,
            leaf_dispatch="unrolled",
        )
        # batched vs unrolled leaf dispatch of the identical plan,
        # interleaved (their ratio is the claim under test)
        t_unr, t_bat = time_pair(f_ubat, f_bat, a, b)
        emit(
            f"fig4_strassen_batched_{m}x{n}x{k}",
            t_bat,
            f"eff_gflops={effective_gflops(m, n, t_bat, r=2, k=k):.2f} "
            f"speedup={t_ref/t_bat:.3f} unrolled_speedup={t_ref/t_unr:.3f} "
            f"batched_vs_unrolled={t_unr/t_bat:.3f} n_base={plan_bat.n_base}",
            shape=(m, n, k),
            gflops=effective_gflops(m, n, t_bat, r=2, k=k),
            ref_seconds=t_ref,
            unrolled_seconds=t_unr,
            batched_vs_unrolled=round(t_unr / t_bat, 4),
            n_base=plan_bat.n_base,
            leaf_dispatch="batched",
        )
        # fused vs unrolled on the planner's best fused recursion,
        # interleaved — zero operand-add stacks vs per-leaf combines
        f_fus = jax.jit(lambda a, b: strassen_tn(a, b, plan=plan_fus))
        f_ufus = jax.jit(lambda a, b: strassen_tn(a, b, plan=plan_ufus))
        t_unr_f, t_fus = time_pair(f_ufus, f_fus, a, b)
        emit(
            f"fig4_strassen_fused_{m}x{n}x{k}",
            t_fus,
            f"eff_gflops={effective_gflops(m, n, t_fus, r=2, k=k):.2f} "
            f"speedup={t_ref/t_fus:.3f} unrolled_speedup={t_ref/t_unr_f:.3f} "
            f"fused_vs_unrolled={t_unr_f/t_fus:.3f} n_base={plan_fus.n_base}",
            shape=(m, n, k),
            gflops=effective_gflops(m, n, t_fus, r=2, k=k),
            ref_seconds=t_ref,
            unrolled_seconds=t_unr_f,
            fused_vs_unrolled=round(t_unr_f / t_fus, 4),
            n_base=plan_fus.n_base,
            leaf_dispatch="fused",
        )


if __name__ == "__main__":
    run()
