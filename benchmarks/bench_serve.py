"""Serve-path benchmark: warm-vs-cold request latency, SLO percentiles,
throughput (``BENCH_serve.json``).

The serving story in numbers (ROADMAP open item 2): a **cold** request —
fresh server, no pre-warm — pays trace + plan + XLA compile on the
request path; a **warm** request pays a dictionary lookup plus one
batched launch. The headline row is the ratio between the two on the same
bucket (the PR's acceptance floor is 10x; interpret-mode CPU containers
measure it in the hundreds).

Rows:

* ``serve_cold_first_request``  — fresh server, first request, untraced
* ``serve_warm_request``        — warmed server, single-request median
* ``serve_warm_vs_cold``        — the ratio row (``ratio`` field)
* ``serve_workload_p50/p95/p99``— mixed-workload request-latency SLOs
* ``serve_throughput``          — requests/s over the mixed workload
* ``serve_obs_snapshot``        — obs snapshot validation (``valid`` +
  ``serve.*`` counters present — the telemetry contract)

Smoke mode shrinks the workload, not the contract.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, smoke


# the bucket both sides of the ratio are measured on — the smallest of
# the shared smoke lattice, so cold compile stays CI-cheap
_RATIO_BUCKET = dict(op="lstsq", m=48, n=32, r=4)


def _one_request(rng, op="lstsq", m=48, n=32, r=4, ridge=0.0):
    from repro.serve.queue import Request

    a = rng.standard_normal((m, n)).astype("float32")
    rows = m if op == "lstsq" else n
    b = rng.standard_normal((rows, r)).astype("float32")
    return Request(op=op, a=a, b=b, ridge=ridge)


def _timed_single(server, rng, **shape) -> float:
    t0 = time.perf_counter()
    server.submit(_one_request(rng, **shape))
    server.drain()
    return time.perf_counter() - t0


def run() -> None:
    from repro.obs import metrics as obs_metrics
    from repro.serve import metrics as serve_metrics
    from repro.serve.engine import Server, smoke_config

    cfg = smoke_config()
    rng = np.random.default_rng(0)
    n_requests = 40 if smoke() else 200
    warm_reps = 5 if smoke() else 20

    # --- cold: fresh server, nothing traced, first request pays it all
    cold_server = Server(cfg)
    cold_s = _timed_single(cold_server, rng, **_RATIO_BUCKET)
    emit("serve_cold_first_request", cold_s, "untraced first request",
         shape=(_RATIO_BUCKET["m"], _RATIO_BUCKET["n"], _RATIO_BUCKET["r"]))

    # --- warm: pre-warmed server, same bucket, single-request median
    server = Server(cfg)
    t0 = time.perf_counter()
    server.warm()
    warm_pass_s = time.perf_counter() - t0
    singles = sorted(
        _timed_single(server, rng, **_RATIO_BUCKET) for _ in range(warm_reps))
    warm_s = singles[len(singles) // 2]
    emit("serve_warm_request", warm_s,
         f"median of {warm_reps} (warm pass {warm_pass_s:.2f}s)",
         shape=(_RATIO_BUCKET["m"], _RATIO_BUCKET["n"], _RATIO_BUCKET["r"]))

    ratio = cold_s / warm_s
    emit("serve_warm_vs_cold", cold_s - warm_s,
         f"cold/warm = {ratio:.0f}x", ratio=round(ratio, 1),
         cold_seconds=cold_s, warm_seconds=warm_s)

    # --- mixed workload on the warmed server: SLO percentiles + throughput
    from repro.serve.__main__ import _mixed_workload, _run_workload

    # the reservoirs are process-global: drop the cold/warm phases' samples
    # so the SLO rows measure the workload, not the measurement rig
    serve_metrics.reset()
    t0 = time.perf_counter()
    served, rejected = _run_workload(server, _mixed_workload(n_requests, 1))
    wall = time.perf_counter() - t0
    pct = serve_metrics.percentiles("request") or {}
    for key in ("p50", "p95", "p99"):
        emit(f"serve_workload_{key}", pct.get(key, float("nan")),
             f"request latency {key} over {len(served)} requests")
    emit("serve_throughput", wall / max(len(served), 1),
         f"{len(served)/wall:.1f} req/s ({rejected} rejected, "
         f"{server.retraces()} retraces)",
         requests_per_s=round(len(served) / wall, 2),
         retraces=server.retraces())

    # --- the telemetry contract: snapshot validates, serve.* present
    serve_metrics.publish_percentiles()
    snap = obs_metrics.validate_snapshot(obs_metrics.snapshot())
    has_counters = any(k.startswith("serve.") for k in snap["counters"])
    has_gauges = any(k.startswith("serve.latency.") for k in snap["gauges"])
    if not (has_counters and has_gauges):
        raise RuntimeError(
            f"obs snapshot missing serve metrics (counters={has_counters}, "
            f"gauges={has_gauges})")
    emit("serve_obs_snapshot", 0.0, "valid",
         serve_counters=sum(k.startswith("serve.") for k in snap["counters"]),
         serve_gauges=sum(k.startswith("serve.latency.")
                          for k in snap["gauges"]))


if __name__ == "__main__":
    run()
