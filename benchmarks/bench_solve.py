"""Normal-equations time-to-solution: packed ``solve.lstsq`` vs baselines.

The paper frames ``AᵀA`` as "an intermediate operation in the solution of
a wide set of problems"; this bench measures the whole solution, on the
fig-3 shape grid:

  * ``packed``  — ``solve.lstsq``: planned ``ata(out='packed')`` → packed
    blocked Cholesky → two packed substitutions (the repro.solve pipeline;
    no dense ``(n, n)`` anywhere);
  * ``dense_chol`` — the classical normal-equations baseline: one dense
    gram + ``jnp.linalg.cholesky`` + ``cho_solve``-style triangular
    solves (what a user writes without the packed stack);
  * ``jnp_lstsq`` — ``jnp.linalg.lstsq`` (SVD-based; the robustness
    gold standard, expected slowest) — skipped at the largest shapes in
    smoke mode;
  * ``cg``      — the planner's matrix-free alternative, recorded with its
    iteration budget for the shape.

Packed vs dense-Cholesky runs interleaved (``time_pair``) — their ratio is
the claim under test. Derived columns report residual parity: every method
must reach the dense baseline's residual within fp tolerance, so the
speedup rows compare equal-quality solutions.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, smoke, time_fn, time_pair
from repro import solve, tune
from repro.core.reference import (
    blocked_potrf_flops,
    classical_syrk_flops,
    trsm_flops,
)


def _residual(a, b, x):
    r = a @ x - b
    return float(jnp.linalg.norm(r) / jnp.linalg.norm(b))


def run():
    rng = np.random.default_rng(0)
    shapes = [(512, 512), (1024, 1024), (2048, 2048), (4096, 1024), (2048, 512)]
    if smoke():
        shapes = [(512, 512), (1024, 1024)]
    rhs = 16
    ridge = 1e-4

    for m, n in shapes:
        a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((m, rhs)), jnp.float32)

        plan = tune.plan(op="solve", m=m, n=n, k=rhs, out="packed")
        # the packed row measures the FACTOR pipeline even where the
        # planner's argmin is CG (recorded as planner_method) — the cg row
        # already covers that dispatch, and the packed-vs-dense-Cholesky
        # ratio is only meaningful between two factorizations.
        fplan = dataclasses.replace(plan, method="factor")
        f_packed = jax.jit(lambda a, b: solve.lstsq(a, b, ridge=ridge, plan=fplan))

        def dense_chol(a, b):
            g = jax.lax.dot_general(
                a, a, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) + ridge * jnp.eye(n, dtype=jnp.float32)
            l = jnp.linalg.cholesky(g)
            y = jax.lax.linalg.triangular_solve(
                l, a.T @ b, left_side=True, lower=True
            )
            return jax.lax.linalg.triangular_solve(
                l, y, left_side=True, lower=True, transpose_a=True
            )

        f_dense = jax.jit(dense_chol)
        f_cg = jax.jit(lambda a, b: solve.lstsq(a, b, ridge=ridge, method="cg"))

        # packed vs dense-Cholesky interleaved: the ratio is the claim.
        t_packed, t_dense = time_pair(f_packed, f_dense, a, b)
        t_cg = time_fn(f_cg, a, b)
        x_p, x_d, x_c = f_packed(a, b), f_dense(a, b), f_cg(a, b)
        res_p, res_d, res_c = (_residual(a, b, x) for x in (x_p, x_d, x_c))

        solve_flops = (
            classical_syrk_flops(m, n)
            + blocked_potrf_flops(n, plan.packed_block)
            + 2 * trsm_flops(n, rhs)
        )
        emit(
            f"solve_lstsq_packed_{m}x{n}",
            t_packed,
            f"gflops={solve_flops / t_packed / 1e9:.2f} "
            f"vs_dense_chol={t_dense / t_packed:.3f} vs_cg={t_cg / t_packed:.3f} "
            f"residual={res_p:.2e} planner_method={plan.method}",
            shape=(m, n),
            gflops=solve_flops / t_packed / 1e9,
            mode="packed",
            rhs=rhs,
            dense_seconds=t_dense,
            cg_seconds=t_cg,
            packed_vs_dense_speedup=round(t_dense / t_packed, 4),
            residual=res_p,
            residual_dense=res_d,
            planner_method=plan.method,
            algorithm=plan.algorithm,
            n_base=plan.n_base,
            packed_block=plan.packed_block,
        )
        emit(
            f"solve_cg_{m}x{n}",
            t_cg,
            f"vs_packed={t_packed / t_cg:.3f} residual={res_c:.2e}",
            shape=(m, n),
            mode="cg",
            rhs=rhs,
            residual=res_c,
        )

        # SVD gold standard — heavy; in smoke mode only at the smallest shape
        if not smoke() or (m, n) == shapes[0]:
            f_svd = jax.jit(lambda a, b: jnp.linalg.lstsq(a, b)[0])
            t_svd = time_fn(f_svd, a, b, iters=3, warmup=1)
            res_s = _residual(a, b, f_svd(a, b))
            emit(
                f"solve_jnp_lstsq_{m}x{n}",
                t_svd,
                f"vs_packed={t_packed / t_svd:.3f} residual={res_s:.2e}",
                shape=(m, n),
                mode="jnp_lstsq",
                rhs=rhs,
                packed_seconds=t_packed,
                residual=res_s,
            )


if __name__ == "__main__":
    run()
