"""Dry-run machinery tests.

One real (reduced-cost) dry-run cell runs in a subprocess with the full
512-fake-device production mesh — the minimal end-to-end proof that the
lower+compile pipeline works inside the test suite. The full 40-cell × 2-mesh
sweep runs via ``python -m repro.launch.dryrun --all`` (results recorded in
EXPERIMENTS.md §Dry-run).
"""

import json
import os
import subprocess
import sys

import pytest

from repro.analysis.hlo import collective_bytes, collective_seconds


def test_collective_bytes_parsing():
    hlo = """
  %ar = f32[128,1024]{1,0} all-reduce(f32[128,1024]{1,0} %p0), replica_groups=
  %ag.1 = bf16[64,64]{1,0} all-gather-start(bf16[32,64]{1,0} %p1), dim=0
  %ag.2 = bf16[64,64]{1,0} all-gather-done(bf16[64,64]{1,0} %ag.1)
  %tup = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(f32[8,8] %a, f32[8,8] %b)
  %cp = u8[16]{0} collective-permute(u8[16]{0} %x), source_target_pairs=
  %rs = f32[4,4]{1,0} reduce-scatter(f32[16,4]{1,0} %y), dimensions={0}
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 1024 * 4
    assert got["all-gather"] == 64 * 64 * 2          # start only, not done
    assert got["all-to-all"] == 2 * 8 * 8 * 4        # tuple result
    assert got["collective-permute"] == 16
    assert got["reduce-scatter"] == 4 * 4 * 4


def test_collective_seconds_model():
    t = collective_seconds({"all-reduce": 100e9, "all-gather": 50e9}, link_bw=50e9)
    assert t == pytest.approx(2 * 2.0 + 1.0)  # AR counts 2×


def test_roofline_affine_composition():
    from repro.analysis.roofline import _affine, _cost_vec, _hybrid

    a1 = {"cost": {"flops": 10.0, "bytes_accessed": 100.0},
          "collectives": {"all-reduce": 4}}
    a2 = {"cost": {"flops": 16.0, "bytes_accessed": 160.0},
          "collectives": {"all-reduce": 6}}
    v = _affine(_cost_vec(a1), _cost_vec(a2), 10)
    assert v["flops"] == pytest.approx(4 + 10 * 6)       # fix=4, layer=6
    assert v["bytes"] == pytest.approx(40 + 10 * 60)
    assert v["coll_all-reduce"] == pytest.approx(2 + 10 * 2)

    # hybrid: fix=5, g=7, s=3
    g1 = {"cost": {"flops": 12.0, "bytes_accessed": 0.0}, "collectives": {}}
    gs2 = {"cost": {"flops": 15.0, "bytes_accessed": 0.0}, "collectives": {}}
    ss2 = {"cost": {"flops": 11.0, "bytes_accessed": 0.0}, "collectives": {}}
    v = _hybrid(_cost_vec(g1), _cost_vec(gs2), _cost_vec(ss2), n_g=3, n_s=29)
    assert v["flops"] == pytest.approx(5 + 3 * 7 + 29 * 3)


@pytest.mark.slow
def test_one_production_cell_compiles():
    """qwen1.5-0.5b × decode_32k on the 16×16 mesh, end to end (subprocess)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    out_dir = "/tmp/repro_dryrun_test"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen1.5-0.5b", "--shape", "decode_32k",
         "--mesh", "single", "--no-analysis", "--out", out_dir],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(os.path.join(out_dir, "qwen1.5-0.5b__decode_32k__single.json")))
    assert rec["status"] == "ok"
    mem = rec["artifacts"]["main"]["memory"]
    assert 0 < mem["peak_bytes_est"] < 16 * 2**30  # fits a v5e chip
