"""Per-architecture smoke tests: reduced configs, one forward/train/decode
step on CPU, asserting output shapes and finiteness (no NaNs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, SMOKES, get_smoke, input_specs, cell_supported
from repro.models.transformer import forward_decode, forward_train, init, init_cache

ALL_ARCHS = sorted(ARCHS)


def _smoke_batch(cfg, b=2, s=32, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {}
    if cfg.modality == "vision_text":
        n_img = cfg.num_patches
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s - n_img)), jnp.int32
        )
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((b, n_img, cfg.d_model)), jnp.bfloat16
        )
        batch["labels"] = batch["tokens"]
    elif cfg.num_codebooks > 1:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s, cfg.num_codebooks)), jnp.int32
        )
        batch["labels"] = batch["tokens"]
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32
        )
        batch["labels"] = batch["tokens"]
    return batch


def _loss(params, batch, cfg):
    logits, aux = forward_train(params, batch, cfg, compute_dtype=jnp.float32)
    labels = batch["labels"]
    v = logits.shape[-1]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
    return nll + 0.01 * aux


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    params = init(jax.random.key(0), cfg)
    batch = _smoke_batch(cfg)
    logits, aux = forward_train(params, batch, cfg, compute_dtype=jnp.float32)
    b = batch["tokens"].shape[0]
    s_out = batch["labels"].shape[1]
    if cfg.num_codebooks > 1:
        assert logits.shape == (b, s_out, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, s_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step_finite(arch):
    cfg = get_smoke(arch)
    params = init(jax.random.key(1), cfg)
    batch = _smoke_batch(cfg)
    loss, grads = jax.value_and_grad(_loss)(params, batch, cfg)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients"
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()
    # one SGD step moves the loss
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = _loss(new_params, batch, cfg)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch):
    cfg = get_smoke(arch)
    params = init(jax.random.key(2), cfg)
    b, max_seq = 2, 64
    cache = init_cache(cfg, b, max_seq, dtype=jnp.float32)
    if cfg.num_codebooks > 1:
        tokens = jnp.zeros((b, 1, cfg.num_codebooks), jnp.int32)
    else:
        tokens = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    logits, new_cache = forward_decode(
        params, tokens, cache, pos, cfg, compute_dtype=jnp.float32
    )
    if cfg.num_codebooks > 1:
        assert logits.shape == (b, 1, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b-like", "mamba2-like", "hymba-like"])
def test_decode_matches_train_forward(arch):
    """Teacher-forced decode must reproduce the training forward logits."""
    key = {"qwen1.5-0.5b-like": "qwen1.5-0.5b", "mamba2-like": "mamba2-1.3b",
           "hymba-like": "hymba-1.5b"}[arch]
    cfg = get_smoke(key)
    params = init(jax.random.key(3), cfg)
    rng = np.random.default_rng(4)
    b, s = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_train, _ = forward_train(
        params, {"tokens": tokens}, cfg, compute_dtype=jnp.float32
    )
    cache = init_cache(cfg, b, max_seq=64, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = forward_decode(
            params, tokens[:, t : t + 1], cache,
            jnp.full((b,), t, jnp.int32), cfg, compute_dtype=jnp.float32,
        )
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_train), rtol=2e-3, atol=2e-3
    )


def test_cell_supported_matrix():
    """long_500k is only runnable for sub-quadratic archs (SSM/hybrid-SWA)."""
    runnable = {
        a for a in ALL_ARCHS if cell_supported(ARCHS[a], SHAPES["long_500k"])[0]
    }
    assert runnable == {"mamba2-1.3b", "hymba-1.5b"}
    for a in ALL_ARCHS:  # every other shape runs everywhere
        for sh in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = cell_supported(ARCHS[a], SHAPES[sh])
            assert ok


def test_input_specs_all_cells():
    """input_specs builds stand-ins for all 40 cells without allocation."""
    n = 0
    for a in ALL_ARCHS:
        for sh in SHAPES.values():
            specs = input_specs(ARCHS[a], sh)
            assert all(isinstance(s, jax.ShapeDtypeStruct) for s in specs.values())
            n += 1
    assert n == 40


def test_param_counts_sane():
    """Analytic param counts should be in the advertised ballpark."""
    import math

    expected = {
        "deepseek-moe-16b": (14e9, 20e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "mamba2-1.3b": (1.0e9, 1.7e9),
        "qwen1.5-4b": (3.0e9, 4.5e9),
        "qwen1.5-0.5b": (0.35e9, 0.7e9),
        "command-r-plus-104b": (95e9, 115e9),
        "gemma-7b": (7.0e9, 10e9),
        "hymba-1.5b": (1.0e9, 2.0e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
        "musicgen-medium": (1.2e9, 2.2e9),
    }
    for a, (lo, hi) in expected.items():
        n = ARCHS[a].num_params()
        assert lo <= n <= hi, f"{a}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
