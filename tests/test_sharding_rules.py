"""Unit tests for the sharding rules — divisibility fallbacks across the
whole architecture pool, padding helpers, ZeRO-1 spec derivation.

These run against *abstract* meshes only (no >1-device requirement):
``jax.sharding.Mesh`` accepts a numpy array of devices for spec math, but
jax.make_mesh needs real devices — so we validate the pure logic through
the spec functions with a mocked mesh shape via AbstractMesh.
"""

import jax
import jax.numpy as jnp
import pytest

try:
    from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P
except ImportError:  # AxisType landed in jax 0.5.x; skip cleanly on older jax
    pytest.skip(
        "jax.sharding.AxisType not available on this JAX version "
        f"({jax.__version__}) — sharding-rule specs need explicit axis types",
        allow_module_level=True,
    )

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.models.transformer import init, init_cache
from repro.parallel.sharding import (
    batch_input_specs,
    batch_spec,
    cache_specs,
    pad_experts,
    pad_vocab,
    param_specs,
)


def abstract_mesh(multi=False):
    if multi:
        return AbstractMesh(
            (2, 16, 16), ("pod", "data", "model"),
            axis_types=(AxisType.Auto,) * 3,
        )
    return AbstractMesh((16, 16), ("data", "model"), axis_types=(AxisType.Auto,) * 2)


@pytest.mark.parametrize("multi", [False, True], ids=["single", "multi"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_match_param_tree(arch, multi):
    """Every param leaf gets a spec whose partitioned dims divide evenly."""
    cfg = ARCHS[arch]
    mesh = abstract_mesh(multi)
    params_abs = jax.eval_shape(
        lambda k: init(k, cfg, mesh), jax.random.key(0)
    )
    specs = param_specs(mesh, cfg)
    # same tree structure
    assert jax.tree.structure(
        jax.tree.map(lambda x: 0, params_abs)
    ) == jax.tree.structure(
        jax.tree.map(lambda s: 0, specs, is_leaf=lambda x: isinstance(x, P))
    )

    def check(ab, spec):
        assert len(spec) <= ab.ndim, f"{arch}: spec {spec} rank > {ab.shape}"
        for dim, axes in zip(ab.shape, tuple(spec) + (None,) * ab.ndim):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, f"{arch}: dim {dim} not divisible by {axes}"

    jax.tree.map(check, params_abs, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_pad_vocab_and_experts():
    mesh = abstract_mesh()
    assert pad_vocab(50280, mesh) % (16 * 128) == 0
    assert pad_vocab(50280, mesh) >= 50280
    assert pad_vocab(32001, mesh) == 34816 - 2048  # 32768? computed: ceil to 2048
    assert pad_experts(60, mesh) == 64
    assert pad_experts(64, mesh) == 64


@pytest.mark.parametrize("shape_name", sorted(SHAPES))
def test_batch_spec_divisible(shape_name):
    mesh = abstract_mesh(multi=True)
    shape = SHAPES[shape_name]
    spec = batch_spec(mesh, shape)
    dp_size = 32  # pod × data
    if spec[0] is not None:
        assert shape.global_batch % dp_size == 0
    elif spec[1] is not None:
        assert shape.seq_len % dp_size == 0


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-1.3b", "hymba-1.5b",
                                  "command-r-plus-104b"])
def test_cache_specs_divisible(arch):
    cfg = ARCHS[arch]
    mesh = abstract_mesh()
    cache_abs = jax.eval_shape(
        lambda: init_cache(cfg, 128, 32768, mesh, dtype=jnp.bfloat16)
    )
    specs = cache_specs(mesh, cfg, cache_abs)

    def check(ab, spec):
        for dim, axes in zip(ab.shape, tuple(spec) + (None,) * ab.ndim):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, f"{arch}: {ab.shape} {spec}"

    jax.tree.map(check, cache_abs, specs,
                 is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # attention KV leaves must be sequence-sharded over model (SP decode)
    layers = specs["layers"]
    k_spec = (layers.get("k") if isinstance(layers, dict) else
              layers[0].get("k") if layers and isinstance(layers[0], dict) else None)
    if k_spec is not None:
        seq_axis = tuple(k_spec)[-3]
        assert seq_axis == "model", f"{arch}: KV cache seq not model-sharded: {k_spec}"


def test_zero1_spec_adds_data_axis():
    from repro.train.train_step import _zero1

    mesh = abstract_mesh()
    assert _zero1(P(None, None), (1024, 64), mesh) == P("data", None)
    # dim0 taken by model → data goes to dim1
    assert _zero1(P("model", None), (64, 1024), mesh) == P("model", "data")
    # nothing divisible → unchanged
    assert _zero1(P(None,), (7,), mesh) == P(None)


def test_batch_input_specs_long_context():
    mesh = abstract_mesh()
    specs = batch_input_specs(
        mesh,
        {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)},
    )
    assert specs["tokens"] == P(None, ("data",))  # seq-sharded (B=1)
