"""Substrate tests: data pipeline, checkpointing, fault tolerance, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import get_smoke
from repro.data.pipeline import SyntheticLM, make_batch
from repro.runtime.elastic import microbatches_for, remesh_plan
from repro.runtime.fault_tolerance import Heartbeat, PreemptionGuard, run_with_restarts

SMALL = ShapeConfig("small", 64, 8, "train")


# --- data pipeline -----------------------------------------------------------


def test_batches_deterministic_by_step():
    cfg = get_smoke("qwen1.5-0.5b")
    b1 = make_batch(cfg, SMALL, seed=7, step=3)
    b2 = make_batch(cfg, SMALL, seed=7, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, SMALL, seed=7, step=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_host_sharding_disjoint_and_stable():
    cfg = get_smoke("qwen1.5-0.5b")
    h0 = make_batch(cfg, SMALL, seed=1, step=0, host_index=0, host_count=4)
    h1 = make_batch(cfg, SMALL, seed=1, step=0, host_index=1, host_count=4)
    assert h0["tokens"].shape[0] == SMALL.global_batch // 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_pipeline_prefetch_and_resume():
    cfg = get_smoke("qwen1.5-0.5b")
    it = SyntheticLM(cfg, SMALL, seed=3, start_step=0)
    first = [next(it) for _ in range(3)]
    state = it.state()
    it.close()
    # resume from recorded state reproduces the upcoming stream
    it2 = SyntheticLM(cfg, SMALL, seed=state["seed"], start_step=state["next_step"])
    nxt = next(it2)
    it2.close()
    expected = make_batch(cfg, SMALL, seed=3, step=state["next_step"])
    np.testing.assert_array_equal(nxt["tokens"], expected["tokens"])


def test_vlm_batch_has_image_embeds():
    cfg = get_smoke("llava-next-mistral-7b")
    b = make_batch(cfg, SMALL, seed=0, step=0)
    assert "image_embeds" in b
    assert b["image_embeds"].shape[1] == cfg.num_patches
    assert b["tokens"].shape[1] + cfg.num_patches == SMALL.seq_len


# --- checkpoint --------------------------------------------------------------


def _tree(x=1.0):
    return {
        "w": jnp.full((4, 3), x, jnp.float32),
        "opt": {"m": jnp.full((4, 3), 2 * x), "step": jnp.asarray(5, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(3.5)
    ckpt.save(10, tree, extra={"data_step": 10})
    restored, step = ckpt.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), restored, tree)
    assert ckpt.extra()["data_step"] == 10


def test_checkpoint_keep_n_gc(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ckpt.save(s, _tree(float(s)))
    assert ckpt.steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    ckpt.save(1, _tree(1.0), blocking=False)
    ckpt.wait()
    assert ckpt.latest_step() == 1


def test_checkpoint_uncommitted_ignored(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    ckpt.save(1, _tree(1.0))
    # simulate a crash mid-save: directory exists but no _COMMITTED marker
    os.makedirs(tmp_path / "step_000000002")
    assert ckpt.latest_step() == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore({"w": jnp.zeros((5,))})


# --- fault tolerance ---------------------------------------------------------


def test_preemption_guard_flag():
    g = PreemptionGuard(signals=())
    assert not g.preempted
    g.request()
    assert g.preempted


def test_heartbeat_staleness(tmp_path):
    path = str(tmp_path / "hb")
    hb = Heartbeat(path, interval=0.05).start()
    import time

    time.sleep(0.15)
    assert not Heartbeat.is_stale(path, timeout=5.0)
    hb.stop()
    assert Heartbeat.is_stale(path, timeout=0.0)


def test_crash_restart_resumes_bitwise(tmp_path):
    """Kill at step 7, restart, and verify the final state is identical to an
    uninterrupted run — checkpoints + step-indexed data give exact resume."""
    cfg = get_smoke("qwen1.5-0.5b")

    def run(crash_at):
        ckpt = CheckpointManager(str(tmp_path / f"c{crash_at}"), keep=2)

        def make_state():
            state = {"acc": jnp.zeros((4,), jnp.float32), "step": jnp.asarray(0)}
            latest = ckpt.latest_step()
            if latest is not None:
                state, _ = ckpt.restore(state)
                return state, latest
            return state, 0

        def step_fn(state, step):
            batch = make_batch(cfg, SMALL, seed=9, step=step)
            delta = jnp.asarray(batch["tokens"][:4, 0], jnp.float32)
            return {"acc": state["acc"] + delta, "step": state["step"] + 1}

        final, restarts = run_with_restarts(
            make_state, step_fn, ckpt, total_steps=20, save_every=5,
            inject_crash_at=crash_at,
        )
        return final, restarts

    clean, r0 = run(crash_at=None)
    crashed, r1 = run(crash_at=7)
    assert r0 == 0 and r1 == 1
    np.testing.assert_array_equal(np.asarray(clean["acc"]), np.asarray(crashed["acc"]))
    assert int(clean["step"]) == int(crashed["step"]) == 20


# --- elastic -----------------------------------------------------------------


def test_remesh_plan_prefers_model_axis():
    assert remesh_plan(256) == ((16, 16), ("data", "model"))
    assert remesh_plan(128) == ((8, 16), ("data", "model"))
    assert remesh_plan(24) == ((3, 8), ("data", "model"))
    assert remesh_plan(1) == ((1, 1), ("data", "model"))


def test_microbatches_constant_global_batch():
    assert microbatches_for(256, 1, 16) == 16
    assert microbatches_for(256, 1, 8) == 32  # half the pods → 2× microbatches
    with pytest.raises(ValueError):
        microbatches_for(250, 1, 16)


def test_elastic_reshard_checkpoint(tmp_path):
    """Save on one layout, restore re-placed onto a different mesh."""
    from jax.sharding import PartitionSpec as P

    ckpt = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    ckpt.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    restored, _ = ckpt.restore_sharded(
        jax.tree.map(jnp.zeros_like, tree),
        {"w": jax.sharding.NamedSharding(mesh, P("data", None))},
    )
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
