"""Bench-mode perf diff (`analysis.perf_diff.bench_diff`): the report-only
fresh-vs-committed table `benchmarks/run.py` prints after every module."""

from repro.analysis.perf_diff import bench_diff, print_bench_diff


def test_bench_diff_matches_by_name_and_flags_metadata():
    base = [
        {"name": "a", "seconds": 1.0, "backend": "cpu", "jax_version": "0.4.37"},
        {"name": "gone", "seconds": 9.0},
        {"no_name": True},
    ]
    fresh = [
        {"name": "a", "seconds": 2.0, "backend": "tpu", "jax_version": "0.4.37"},
        {"name": "new_row", "seconds": 3.0},
    ]
    recs = bench_diff(base, fresh)
    assert [r["name"] for r in recs] == ["a", "new_row"]
    a, new = recs
    assert a["delta_pct"] == 100.0
    # only the keys that actually disagree; absent keys are not mismatches
    assert a["meta_changed"] == ["backend"]
    assert new["base_s"] is None and new["delta_pct"] is None


def test_bench_diff_pre_metadata_baselines_stay_comparable():
    """Committed baselines predate the backend-metadata satellite; their
    rows must diff cleanly (no mismatch flags for absent keys)."""
    base = [{"name": "a", "seconds": 1.0}]
    fresh = [{"name": "a", "seconds": 0.5, "backend": "cpu", "interpret": True}]
    (rec,) = bench_diff(base, fresh)
    assert rec["delta_pct"] == -50.0 and rec["meta_changed"] == []


def test_print_bench_diff_never_raises_on_marker_rows():
    """Zero-seconds baselines (marker rows like tune_cache_file) produced a
    None delta — the printer must render them, not TypeError (regression)."""
    base = [{"name": "marker", "seconds": 0.0}]
    fresh = [{"name": "marker", "seconds": 0.1}]
    lines = []
    print_bench_diff("x", bench_diff(base, fresh), print_fn=lines.append)
    assert any("n/a" in ln for ln in lines)
    # empty record list prints nothing at all
    lines2 = []
    print_bench_diff("x", [], print_fn=lines2.append)
    assert lines2 == []
