"""Tests for the ``repro.obs`` observability subsystem (DESIGN.md §8).

The load-bearing guarantee: with obs **disabled** (the default) the
instrumented dispatch paths are strict no-ops — same jaxpr, bitwise-same
values — and even **enabled**, spans never add an op to the traced program
(``jax.named_scope`` is metadata-only). Plus the registry/calibration
contracts and the `analysis.hlo.collective_bytes` edge cases the metrics
wiring depends on.
"""

import json
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.analysis.hlo import collective_bytes
from repro.core.ata import ata
from repro.core.strassen import strassen_tn
from repro.obs import calibrate, metrics, trace
from repro.tune import cache as tune_cache
from repro.tune import cost


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts disabled with empty registries and leaves no state."""
    was_enabled = trace.enabled()
    trace.disable()
    trace.reset()
    metrics.reset()
    calibrate.reset()
    yield
    trace.enable() if was_enabled else trace.disable()
    trace.reset()
    metrics.reset()
    calibrate.reset()


def _rng(shape, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape), jnp.float32
    )


# ---------------------------------------------------------------------------
# spans: disabled = strict no-op; enabled = zero jaxpr ops
# ---------------------------------------------------------------------------


def test_span_disabled_is_shared_noop():
    s1 = obs.span("anything", attr=1)
    s2 = obs.span("else")
    assert s1 is s2  # one shared null object — no per-call allocation
    with s1:
        pass
    assert trace.span_counts() == {}


def test_spans_add_zero_ops_to_jaxpr():
    a = _rng((96, 64))

    # two distinct function objects: jax caches traces per (fun, args), so
    # reusing one would hand back the first trace without re-entering ata
    def f_off(x):
        return ata(x, n_base=16, variant="strassen", leaf_dispatch="batched")

    def f_on(x):
        return ata(x, n_base=16, variant="strassen", leaf_dispatch="batched")

    jaxpr_off = jax.make_jaxpr(f_off)(a)
    trace.enable()
    try:
        jaxpr_on = jax.make_jaxpr(f_on)(a)
        assert trace.span_counts()  # spans really fired during tracing
    finally:
        trace.disable()
    assert len(jaxpr_off.eqns) == len(jaxpr_on.eqns)
    assert str(jaxpr_off) == str(jaxpr_on)


def test_enabled_results_bitwise_identical():
    a = _rng((80, 48))
    b = _rng((80, 32), seed=1)
    off_ata = ata(a, n_base=16, variant="strassen")
    off_tn = strassen_tn(a, b, n_base=16, variant="strassen")
    trace.enable()
    try:
        on_ata = ata(a, n_base=16, variant="strassen")
        on_tn = strassen_tn(a, b, n_base=16, variant="strassen")
    finally:
        trace.disable()
    np.testing.assert_array_equal(np.asarray(off_ata), np.asarray(on_ata))
    np.testing.assert_array_equal(np.asarray(off_tn), np.asarray(on_tn))


def test_span_nesting_depth_and_events():
    trace.enable()
    try:
        with obs.span("outer", k=1):
            with obs.span("inner"):
                pass
        with obs.span("outer"):
            pass
    finally:
        trace.disable()
    assert trace.span_counts() == {"outer": 2, "inner": 1}
    events = trace.span_events()
    assert ("outer", 0, {"k": 1}) in events
    assert ("inner", 1, {}) in events


def test_level_spans_cover_every_recursion_level():
    a = _rng((128, 128))
    trace.enable()
    try:
        ata(a, n_base=32, variant="strassen", leaf_dispatch="batched")
    finally:
        trace.disable()
    spans = trace.span_counts()
    L = 2  # 128 / 2^2 = 32 = n_base
    for lev in range(1, L + 1):
        assert f"ata.encode.L{lev}" in spans
        assert f"ata.decode.L{lev}" in spans
    assert "ata.leaf_dot" in spans and "ata.syrk_batch" in spans


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_registry_roundtrip(tmp_path):
    metrics.inc("x.count")
    metrics.inc("x.count", 4)
    metrics.set_gauge("x.gauge", 2.5)
    for v in (1.0, 3.0, 2.0):
        metrics.observe("x.hist", v)
    assert metrics.get("x.count") == 5
    assert metrics.counters("x.") == {"x.count": 5}
    assert metrics.gauges()["x.gauge"] == 2.5
    h = metrics.histograms()["x.hist"]
    assert (h["count"], h["sum"], h["min"], h["max"]) == (3, 6.0, 1.0, 3.0)

    snap = metrics.validate_snapshot(metrics.snapshot())
    out = metrics.export_json(str(tmp_path / "obs.json"))
    with open(out) as f:
        disk = json.load(f)
    assert disk["schema"] == metrics.SNAPSHOT_SCHEMA
    assert disk["counters"] == snap["counters"]


def test_validate_snapshot_rejects_bad_schema():
    snap = metrics.snapshot()
    snap["schema"] = "bogus"
    with pytest.raises(ValueError, match="schema"):
        metrics.validate_snapshot(snap)
    with pytest.raises(ValueError, match="meta"):
        metrics.validate_snapshot({"schema": metrics.SNAPSHOT_SCHEMA})


def test_record_collective_bytes_folds_into_registry():
    hlo = "%ar = f32[16,16]{1,0} all-reduce(f32[16,16]{1,0} %p0)"
    by_kind = metrics.record_collective_bytes(hlo)
    assert by_kind == {"all-reduce": 16 * 16 * 4}
    assert metrics.get("collective_bytes.all-reduce") == 16 * 16 * 4


def test_dispatch_counters_always_on():
    a = _rng((64, 48))
    ata(a, n_base=16, variant="strassen", leaf_dispatch="unrolled")
    assert metrics.get("dispatch.ata.unrolled") == 1
    assert metrics.get("ata.leaves.syrk") > 0
    b = _rng((64, 24), seed=2)
    strassen_tn(a, b, n_base=16, variant="strassen", leaf_dispatch="batched")
    assert metrics.get("dispatch.gemm_tn.batched") == 1
    assert metrics.get("gemm_tn.leaves") >= 7


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------


def _plan(predicted=1e-3, **kw):
    base = cost.default_plan("ata", 256, 128, backend="cpu")
    import dataclasses

    return dataclasses.replace(base, predicted_s=predicted, **kw)


def test_calibrate_records_against_prediction():
    calibrate.record(_plan(), 2e-3)
    calibrate.record(_plan(predicted=None), 5.0)   # no prediction: skipped
    calibrate.record(_plan(), -1.0)                # non-positive: skipped
    rows = calibrate.rows()
    assert len(rows) == 1
    table = calibrate.drift_table()
    assert table[0]["ratio"] == pytest.approx(2.0)
    assert "geomean measured/predicted" in calibrate.report()


def test_calibrate_drift_aggregates_per_key():
    for meas in (2e-3, 8e-3):
        calibrate.record(_plan(), meas)
    (g,) = calibrate.drift_table(backend="cpu")
    assert g["n"] == 2
    assert g["measured_s"] == pytest.approx(2e-3)   # min over rows
    assert g["ratio"] == pytest.approx(4.0)         # geomean of 2 and 8


def test_eager_planned_dispatch_records_calibration_row():
    import dataclasses

    a = _rng((192, 96))
    plan = dataclasses.replace(
        cost.analytic_plan(
            "ata", 192, 96, dtype="float32", backend=jax.default_backend()
        ),
        algorithm="strassen", n_base=32, leaf_dispatch="batched",
    )
    assert plan.predicted_s is not None
    trace.enable()
    try:
        ata(a, plan=plan)
    finally:
        trace.disable()
    rows = calibrate.rows()
    assert len(rows) == 1 and rows[0]["op"] == "ata"
    assert rows[0]["measured_s"] > 0


def test_no_calibration_under_jit_tracing():
    import dataclasses

    a = _rng((96, 64))
    plan = dataclasses.replace(
        cost.analytic_plan(
            "ata", 96, 64, dtype="float32", backend=jax.default_backend()
        ),
        algorithm="strassen", n_base=32,
    )
    trace.enable()
    try:
        jax.jit(lambda x: ata(x, plan=plan))(a)
    finally:
        trace.disable()
    # inside jit the region runs at trace time — wall clock there would be
    # compile time, so the dispatch site must not record
    assert calibrate.rows() == []


# ---------------------------------------------------------------------------
# plan-cache counters (tune.cache satellite)
# ---------------------------------------------------------------------------


def test_cache_stats_miss_then_memo_hit(tmp_path):
    cache_file = str(tmp_path / "plans.json")
    tune_cache.clear_memo()
    tune_cache.plan(op="ata", m=512, n=256, cache_file=cache_file)
    stats = tune_cache.cache_stats()
    assert stats["miss"] == 1 and stats["memo_hit"] == 0
    tune_cache.plan(op="ata", m=512, n=256, cache_file=cache_file)
    assert tune_cache.cache_stats()["memo_hit"] == 1


def test_cache_load_failure_counted_and_logged(tmp_path, caplog):
    bad = tmp_path / "corrupt.json"
    bad.write_text("{not json")
    with caplog.at_level(logging.WARNING, logger="repro.tune.cache"):
        assert tune_cache.load_cache(str(bad)) == {}
    assert tune_cache.cache_stats()["load_failure"] == 1
    assert any("unreadable" in r.message for r in caplog.records)
    # a missing file stays the silent first-run path, not a failure
    caplog.clear()
    assert tune_cache.load_cache(str(tmp_path / "absent.json")) == {}
    assert tune_cache.cache_stats()["load_failure"] == 1
    assert not caplog.records


def test_cache_migration_sanitization_and_skip_counters(tmp_path, caplog):
    plan = cost.default_plan("ata", 128, 128, backend="cpu")
    good = plan.to_json()
    weird = dict(good, leaf_dispatch="quantum")
    payload = {
        "schema": "v4",
        "plans": {
            "v1|ata|old-schema-key": good,       # migrated
            "v4|ata|weird-dispatch": weird,      # sanitized
            "v4|ata|broken": {"nonsense": 1},    # skipped
        },
    }
    path = tmp_path / "plans.json"
    path.write_text(json.dumps(payload))
    with caplog.at_level(logging.WARNING, logger="repro.tune.cache"):
        plans = tune_cache.load_cache(str(path))
    assert set(plans) == {"v4|ata|old-schema-key", "v4|ata|weird-dispatch"}
    assert plans["v4|ata|weird-dispatch"].leaf_dispatch == "unrolled"
    stats = tune_cache.cache_stats()
    assert stats["migrated"] == 1
    assert stats["sanitized"] == 1
    assert stats["skipped_entries"] == 1
    assert any("skipped 1" in r.message for r in caplog.records)


# ---------------------------------------------------------------------------
# analysis.hlo collective-bytes edge cases
# ---------------------------------------------------------------------------


def test_collective_bytes_async_tuple_start_counts_output_only():
    # async tuple form: (operand, result) — the operand element aliases the
    # input buffer and must not be double-counted
    hlo = """
  %ag.s = (f32[32,64]{1,0}, f32[128,64]{1,0}) all-gather-start(f32[32,64] %p), dim=0
  %ag.d = f32[128,64]{1,0} all-gather-done((f32[32,64], f32[128,64]) %ag.s)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 128 * 64 * 4


def test_collective_bytes_async_nontuple_start_and_done_dedup():
    hlo = """
  %ar.s = bf16[64,64]{1,0} all-reduce-start(bf16[64,64]{1,0} %p)
  %ar.d = bf16[64,64]{1,0} all-reduce-done(bf16[64,64]{1,0} %ar.s)
"""
    assert collective_bytes(hlo)["all-reduce"] == 64 * 64 * 2


def test_collective_bytes_variadic_tuple_sums_all_elements():
    hlo = (
        "%aa = (f32[8,8]{1,0}, bf16[4,4]{1,0}, s8[16]{0}) "
        "all-to-all(f32[8,8] %a, bf16[4,4] %b, s8[16] %c)"
    )
    got = collective_bytes(hlo)
    assert got["all-to-all"] == 8 * 8 * 4 + 4 * 4 * 2 + 16


def test_collective_bytes_unknown_dtypes_skipped():
    hlo = """
  %t = token[] all-reduce(token[] %tok)
  %m = (f32[4]{0}, token[]) all-to-all(f32[4] %x, token[] %tok)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 0
    assert got["all-to-all"] == 4 * 4   # token element contributes nothing


def test_collective_bytes_start_tuple_with_context_elements():
    # some async lowerings append context/scratch elements after the result
    hlo = (
        "%cp.s = (u8[16]{0}, u8[16]{0}, u32[], u32[]) "
        "collective-permute-start(u8[16] %x)"
    )
    assert collective_bytes(hlo)["collective-permute"] == 16


# ---------------------------------------------------------------------------
# snapshot composition: spans + calibration ride along
# ---------------------------------------------------------------------------


def test_snapshot_includes_spans_and_calibration():
    trace.enable()
    try:
        with obs.span("demo"):
            pass
    finally:
        trace.disable()
    calibrate.record_pair("k", "ata", "cpu", 1e-3, 2e-3)
    snap = metrics.validate_snapshot(metrics.snapshot())
    assert snap["spans"] == {"demo": 1}
    assert snap["calibration"][0]["key"] == "k"
