"""Train-step and serve-step integration tests (single-device smoke configs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_smoke
from repro.data.pipeline import make_batch
from repro.models.transformer import forward_train, init, init_cache
from repro.train.serve_step import make_decode_step, make_prefill_step, sample_logits
from repro.train.train_step import cross_entropy, make_train_step

SMALL = ShapeConfig("small", 64, 4, "train")


def _run_cfg(cfg, micro=1, opt="adamw"):
    return RunConfig(
        model=cfg, shape=SMALL,
        optimizer=OptimizerConfig(name=opt, lr=1e-3, warmup_steps=5),
        remat="none", microbatch=micro, compute_dtype="float32",
    )


def test_train_step_decreases_loss():
    cfg = get_smoke("qwen1.5-0.5b")
    run = _run_cfg(cfg)
    step_fn, opt = make_train_step(cfg, None, run, total_steps=50)
    params = init(jax.random.key(0), cfg)
    state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SMALL, 0, i).items()}
        state, m = jitted(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses
    assert int(state["step"]) == 25


def test_microbatch_grad_equivalence():
    """microbatch=2 must produce (numerically) the same update as 1."""
    cfg = get_smoke("qwen1.5-0.5b")
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SMALL, 1, 0).items()}

    results = {}
    for micro in (1, 2):
        run = _run_cfg(cfg, micro=micro)
        step_fn, opt = make_train_step(cfg, None, run, total_steps=50)
        params = init(jax.random.key(2), cfg)
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        new_state, m = jax.jit(step_fn)(state, batch)
        results[micro] = (new_state["params"], float(m["loss"]))

    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5),
        results[1][0], results[2][0],
    )
    assert results[1][1] == pytest.approx(results[2][1], rel=2e-4)


def test_cross_entropy_matches_naive_with_padded_vocab():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((2, 8, 40)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 32, (2, 8)), jnp.int32)
    got = cross_entropy(logits, labels, vocab_real=32)
    masked = np.array(logits)  # writable copy
    masked[..., 32:] = -1e30
    logp = jax.nn.log_softmax(jnp.asarray(masked), -1)
    want = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
    assert float(got) == pytest.approx(float(want), rel=1e-5)


# (MoE archs are excluded: top-k capacity dropping is computed over the
# visible token set, which legitimately differs between a prefill batch and
# a single decode step — exact teacher-forced equivalence doesn't hold.)
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-1.3b", "hymba-1.5b",
                                  "musicgen-medium"])
def test_prefill_then_decode_matches_forward(arch):
    """Serve path: prefill a prompt, decode the next tokens teacher-forced;
    logits must match the train forward over the whole sequence."""
    cfg = get_smoke(arch)
    params = init(jax.random.key(3), cfg)
    rng = np.random.default_rng(4)
    b, s_p, s_d = 2, 24, 8
    s = s_p + s_d
    shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)

    full_logits, _ = forward_train(
        params, {"tokens": tokens}, cfg, compute_dtype=jnp.float32
    )

    prefill = make_prefill_step(cfg, compute_dtype=jnp.float32, cache_len=s)
    decode = make_decode_step(cfg, compute_dtype=jnp.float32)
    lg, cache = prefill(params, {"tokens": tokens[:, :s_p]})
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full_logits[:, s_p - 1]),
        rtol=2e-3, atol=2e-3,
    )
    for t in range(s_p, s - 1):
        lg, cache = decode(
            params, tokens[:, t : t + 1], cache, jnp.full((b,), t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch} step {t}",
        )


def test_sample_logits_greedy_and_mask():
    logits = jnp.asarray([[[0.1, 3.0, 0.2, 9.9]]])  # (B=1, 1, V=4)
    tok = sample_logits(logits, jax.random.key(0), temperature=0.0)
    assert int(tok[0, 0]) == 3
    # padded-vocab mask: index 3 is padding → argmax must avoid it
    tok = sample_logits(logits, jax.random.key(0), temperature=0.0, vocab_real=3)
    assert int(tok[0, 0]) == 1
    # sampling stays within the real vocab
    toks = [int(sample_logits(logits, jax.random.key(i), 2.0, vocab_real=3)[0, 0])
            for i in range(20)]
    assert max(toks) <= 2


def test_remat_policies_same_loss():
    cfg = get_smoke("gemma-7b")
    params = init(jax.random.key(5), cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SMALL, 2, 0).items()}
    outs = {}
    for remat in ("none", "dots", "full"):
        logits, _ = forward_train(
            params, batch, cfg, remat=remat, compute_dtype=jnp.float32
        )
        outs[remat] = np.asarray(logits)
    np.testing.assert_allclose(outs["none"], outs["dots"], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs["none"], outs["full"], rtol=1e-5, atol=1e-5)


def test_pad_slots_keeps_shapes_static_without_rng_waste():
    """The queue tail pads with zeros instead of prefilling fresh prompts:
    shapes stay static (no tail retrace) and the prompt RNG advances only
    for requested slots — a 10-request run with batch 4 must generate
    exactly 10 prompts' worth of randomness, not 12."""
    from repro.launch.serve import _pad_slots

    rng = np.random.default_rng(0)
    real = rng.integers(0, 64, size=(2, 8)).astype(np.int32)
    padded = _pad_slots(real, 4)
    assert padded.shape == (4, 8) and padded.dtype == real.dtype
    np.testing.assert_array_equal(padded[:2], real)
    assert not padded[2:].any()                    # zero slots, not prompts
    full = rng.integers(0, 64, size=(4, 8)).astype(np.int32)
    assert _pad_slots(full, 4) is full             # full batches untouched

    # the reproducibility property the fix buys: the tail no longer
    # consumes RNG for slots nobody requested
    def draws(n_requests, b):
        g = np.random.default_rng(7)
        seen = []
        remaining = n_requests
        while remaining:
            n = min(b, remaining)
            seen.append(_pad_slots(g.integers(0, 64, size=(n, 8)), b))
            remaining -= n
        return g.integers(0, 64, size=(1, 8))      # next draw after serving

    np.testing.assert_array_equal(draws(10, 4), draws(10, 2))
