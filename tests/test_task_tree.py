"""Tests for the paper-faithful task-tree scheduler (paper §4.1)."""

import numpy as np
import pytest

from repro.core.task_tree import (
    Task,
    assign_tasks,
    build_task_tree,
    ell_distributed,
    ell_shared,
    modeled_speedup,
    task_flops,
)


# --- Eq. (5)/(6) level formulas -------------------------------------------


def test_ell_distributed_base_cases():
    assert ell_distributed(1) == 0
    for p in range(2, 7):
        assert ell_distributed(p) == 1


def test_ell_distributed_complete_levels():
    # P = 32: P/4 = 8 = 8^1 exactly → k=1, rem=0 → ℓ=2 (complete level)
    assert ell_distributed(32) == 2
    # P = 7: P/4 = 1.75, k=0, rem>0 → ℓ=2
    assert ell_distributed(7) == 2
    # complete third level: P/4 = 64 → P = 256, k=2, rem 0 → ℓ=3
    assert ell_distributed(256) == 3
    # P = 64: P/4 = 16 is a multiple of 8 → complete level, ℓ=2; the paper's
    # formula is deliberately non-injective/step-wise (§4.2.2, Fig. 6) —
    # incomplete levels (e.g. P=63) add a partial extra level.
    assert ell_distributed(64) == 2
    assert ell_distributed(63) == 3


def _brute_force_depth(p: int, mode: str) -> int:
    """Brute-force parallel-level count: BFS-expand the actual task tree
    until it has ≥ P leaves (exactly what ``build_task_tree`` does) and
    count the levels — the deepest leaf depth."""
    leaves = build_task_tree(256, 256, p, mode=mode)
    return max(t.depth for t in leaves)


@pytest.mark.parametrize("mode,ell", [
    ("distributed", ell_distributed), ("shared", ell_shared),
])
def test_ell_tracks_brute_force_tree_depth(mode, ell):
    """Eq. (5)/(6) vs the brute-force tree depth for every P ≤ 64.

    The paper's closed forms count *complete* levels of the idealized
    geometric expansion (4·8^k / 2·4^k tasks), while the real BFS tree
    interleaves ATA and ATB fanouts — so on partial levels the formula may
    sit one level off the constructed tree (it is deliberately step-wise
    and non-injective, cf. Fig. 5/6). The invariants that must hold
    brute-force exactly: agreement within one partial level everywhere,
    exact agreement on the base cases, and a non-decreasing brute-force
    depth (more processes can never need fewer levels)."""
    prev_bf = 0
    for p in range(1, 65):
        bf = _brute_force_depth(p, mode)
        assert abs(ell(p) - bf) <= 1, (mode, p, ell(p), bf)
        assert bf >= prev_bf, (mode, p)
        prev_bf = bf
    # exact on the base cases the formulas special-case
    assert ell(1) == _brute_force_depth(1, mode) == 0
    first = 6 if mode == "distributed" else 3
    for p in range(2, first + 1):
        assert ell(p) == _brute_force_depth(p, mode) == 1


def test_ell_shared_base_cases():
    assert ell_shared(1) == 0
    assert ell_shared(2) == 1
    assert ell_shared(3) == 1
    # P = 8: P/2 = 4 = 4^1 → k=1, rem 0 → ℓ=2 (complete level)
    assert ell_shared(8) == 2
    # P = 32: P/2 = 16 = 4^2 → k=2, rem 0 → ℓ=3
    assert ell_shared(32) == 3
    # step-wise/non-injective by design (see distributed variant note)
    assert ell_shared(16) == 2  # P/2 = 8 = 2·4 → multiple of 4 → complete
    assert ell_shared(5) == 2


# --- tree construction -----------------------------------------------------


def _cover_matrix(tasks, n):
    """Count how many times each C entry in the lower triangle is *owned*.

    ATA tasks accumulate into low(C) of their block; ATB tasks into their
    full C block. Every lower-triangle entry must be covered ≥ 1; writes of
    distinct tasks may accumulate into the same block (the two ATA calls
    into C11), which is the additive-psum pattern, so we check coverage of
    the *output region union*, not exclusivity.
    """
    cover = np.zeros((n, n), dtype=int)
    for t in tasks:
        cover[t.cr0 : t.cr1, t.cc0 : t.cc1] += 1
    return cover


@pytest.mark.parametrize("mode,fanout_ata,fanout_atb", [
    ("shared", 3, 4),
    ("distributed", 6, 8),
])
def test_fanouts(mode, fanout_ata, fanout_atb):
    # expanding the root once yields exactly the documented fanout
    leaves = build_task_tree(64, 64, 2, mode=mode)
    assert len(leaves) == fanout_ata
    kinds = sorted(t.kind for t in leaves)
    if mode == "shared":
        assert kinds == ["ATA", "ATA", "ATB"]
    else:
        assert kinds == ["ATA"] * 4 + ["ATB"] * 2


@pytest.mark.parametrize("mode", ["shared", "distributed"])
@pytest.mark.parametrize("p", [1, 2, 4, 6, 8, 16, 37])
def test_tree_covers_lower_triangle(mode, p):
    n = 64
    leaves = build_task_tree(n, n, p, mode=mode)
    assert len(leaves) >= min(p, 3)
    cover = _cover_matrix(leaves, n)
    low = np.tril_indices(n)
    assert (cover[low] >= 1).all(), "every lower-triangle entry must be owned"


def test_shared_mode_tasks_write_disjoint_blocks():
    """ATA-S guarantee: no two leaf tasks of the *shared* tree write the
    same C entry, except the paired ATA accumulations are eliminated —
    in shared mode stripes are full-height so blocks are truly disjoint."""
    n = 64
    for p in [2, 4, 8, 16]:
        leaves = build_task_tree(n, n, p, mode="shared")
        regions = [(t.cr0, t.cr1, t.cc0, t.cc1) for t in leaves]
        for a in range(len(regions)):
            for b in range(a + 1, len(regions)):
                r1, r2 = regions[a], regions[b]
                overlap_rows = max(r1[0], r2[0]) < min(r1[1], r2[1])
                overlap_cols = max(r1[2], r2[2]) < min(r1[3], r2[3])
                assert not (overlap_rows and overlap_cols), (
                    f"tasks {a} and {b} overlap: {r1} vs {r2}"
                )


def test_distributed_mode_atb_weight_twice_ata():
    leaves = build_task_tree(128, 128, 2, mode="distributed")
    ata_w = [t.weight() for t in leaves if t.kind == "ATA"]
    atb_w = [t.weight() for t in leaves if t.kind == "ATB"]
    # same-size blocks: ATB ≈ 2× ATA (paper's α rationale)
    assert ata_w and atb_w
    assert abs(atb_w[0] / ata_w[0] - 2.0) < 0.1


# --- assignment / balance --------------------------------------------------


@pytest.mark.parametrize("p", [2, 4, 8, 16, 32])
def test_lpt_assignment_balance(p):
    leaves = build_task_tree(1024, 1024, 4 * p, mode="shared")
    buckets = assign_tasks(leaves, p)
    loads = [task_flops(b) for b in buckets]
    assert len(buckets) == p
    assert sum(len(b) for b in buckets) == len(leaves)
    # LPT bound: max load ≤ (4/3) · ideal when enough tasks exist
    ideal = sum(loads) / p
    assert max(loads) <= 1.5 * ideal


def test_modeled_speedup_monotone_and_stepwise():
    sp = [modeled_speedup(4096, p, mode="shared") for p in range(1, 33)]
    assert sp[0] == pytest.approx(1.0)
    # speedup grows overall
    assert sp[-1] > 6.0
    # and is monotone non-decreasing within tolerance (step-wise curve)
    for a, b in zip(sp, sp[1:]):
        assert b >= a - 1e-6
