"""Batched- and fused-leaf dispatch: bitwise parity with the unrolled
recursion, jaxpr-size regressions, and plan threading.

The acceptance contract of the batched-leaf and fused-leaf PRs:

* ``leaf_dispatch='batched'`` and ``'fused'`` are **bitwise-equal** to
  ``'unrolled'`` on the same plan, for ``strassen_tn``/``ata``/
  ``ata_batched``, across odd and rectangular shapes, dense and packed
  output, and alpha/c/beta accumulation (batched: both variants; fused:
  classical only — winograd raises);
* the batched dispatch emits **O(levels)** dots (one batched TN gemm + one
  batched syrk for the whole ATA tree), not O(7^L); the fused dispatch
  emits one dot per leaf but **zero materialized operand-add stacks** —
  both jaxpr regression tests;
* the planner carries the choice (``Plan.leaf_dispatch``): candidates
  enumerate all three (fused for classical Strassen only), JSON
  round-trips it, pre-leaf_dispatch cache entries deserialize to
  ``'unrolled'``, and the overhead pricing makes the dispatches
  distinguishable to the analytic model.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import ata, ata_batched, strassen_tn
from repro.core.strassen import tree_depth
from repro.tune import cost, defaults

jax.config.update("jax_enable_x64", True)


def rng(seed=0):
    return np.random.default_rng(seed)


def _bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bitwise parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["strassen", "winograd"])
@pytest.mark.parametrize(
    "m,n,k",
    [
        (64, 64, 64),
        (128, 96, 80),   # rectangular
        (67, 53, 41),    # odd everywhere
        (100, 200, 50),  # tall/wide mix
        (33, 1, 7),      # degenerate (L = 0: both dispatches ARE one dot)
    ],
)
def test_strassen_batched_bitwise_equals_unrolled(variant, m, n, k):
    r = rng(hash((m, n, k)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)))
    b = jnp.asarray(r.standard_normal((m, k)))
    kw = dict(n_base=8, variant=variant, acc_dtype=jnp.float64)
    _bitwise(
        strassen_tn(a, b, leaf_dispatch="unrolled", **kw),
        strassen_tn(a, b, leaf_dispatch="batched", **kw),
    )


def test_strassen_batched_alpha_beta_accumulate_bitwise():
    r = rng(1)
    a = jnp.asarray(r.standard_normal((32, 24)))
    b = jnp.asarray(r.standard_normal((32, 40)))
    c = jnp.asarray(r.standard_normal((24, 40)))
    kw = dict(alpha=2.5, c=c, beta=-0.5, n_base=8, acc_dtype=jnp.float64)
    got = strassen_tn(a, b, leaf_dispatch="batched", **kw)
    _bitwise(strassen_tn(a, b, leaf_dispatch="unrolled", **kw), got)
    np.testing.assert_allclose(got, 2.5 * (a.T @ b) - 0.5 * c, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("variant", ["strassen", "winograd"])
@pytest.mark.parametrize("m,n", [(64, 64), (67, 53), (200, 100), (257, 129)])
def test_ata_batched_leaf_bitwise_equals_unrolled(variant, m, n):
    r = rng(hash((m, n, variant)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)))
    kw = dict(n_base=8, variant=variant, acc_dtype=jnp.float64)
    dense_u = ata(a, leaf_dispatch="unrolled", **kw)
    dense_b = ata(a, leaf_dispatch="batched", **kw)
    _bitwise(dense_u, dense_b)
    np.testing.assert_allclose(dense_b, a.T @ a, rtol=1e-9, atol=1e-9)
    # packed: the packed *blocks* must agree bitwise, not just to_dense()
    pu = ata(a, leaf_dispatch="unrolled", out="packed", packed_block=32, **kw)
    pb = ata(a, leaf_dispatch="batched", out="packed", packed_block=32, **kw)
    _bitwise(pu.blocks, pb.blocks)
    _bitwise(pb.to_dense(), dense_b)


def test_ata_alpha_beta_accumulation_bitwise_both_outs():
    from repro.core import SymmetricMatrix

    r = rng(2)
    a = jnp.asarray(r.standard_normal((96, 80)))
    c_dense = jnp.asarray(r.standard_normal((80, 80)))
    kw = dict(alpha=0.25, n_base=16, acc_dtype=jnp.float64)
    _bitwise(
        ata(a, c=c_dense, beta=2.0, leaf_dispatch="unrolled", **kw),
        ata(a, c=c_dense, beta=2.0, leaf_dispatch="batched", **kw),
    )
    c_packed = SymmetricMatrix.from_dense(
        jnp.asarray(c_dense + c_dense.T), 32
    )
    pu = ata(a, c=c_packed, beta=2.0, out="packed", packed_block=32,
             leaf_dispatch="unrolled", **kw)
    pb = ata(a, c=c_packed, beta=2.0, out="packed", packed_block=32,
             leaf_dispatch="batched", **kw)
    _bitwise(pu.blocks, pb.blocks)


@pytest.mark.parametrize("out", ["dense", "packed"])
def test_ata_batched_op_bitwise_equals_unrolled(out):
    """The (B, m, n) gram entry point, both output modes."""
    r = rng(11)
    a = jnp.asarray(r.standard_normal((5, 48, 28)))
    kw = dict(n_base=8, acc_dtype=jnp.float64, out=out)
    if out == "packed":
        kw["packed_block"] = 16
    u = ata_batched(a, leaf_dispatch="unrolled", **kw)
    b = ata_batched(a, leaf_dispatch="batched", **kw)
    if out == "packed":
        _bitwise(u.blocks, b.blocks)
    else:
        _bitwise(u, b)
        np.testing.assert_allclose(
            b, jnp.einsum("bmi,bmj->bij", a, a), rtol=1e-9, atol=1e-9
        )


def test_batched_under_jit_and_grad():
    r = rng(3)
    a = jnp.asarray(r.standard_normal((64, 48)))
    f = jax.jit(
        lambda a: ata(a, n_base=16, leaf_dispatch="batched", acc_dtype=jnp.float64)
    )
    _bitwise(f(a), ata(a, n_base=16, leaf_dispatch="unrolled", acc_dtype=jnp.float64))
    g = jax.grad(
        lambda a: strassen_tn(
            a, a, n_base=16, leaf_dispatch="batched", acc_dtype=jnp.float64
        ).sum()
    )(a)
    g_ref = jax.grad(lambda a: (a.T @ a).sum())(a)
    np.testing.assert_allclose(g, g_ref, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# fused dispatch parity (XLA slot-gather path; the kernel launch path is
# covered by test_kernels.py's coefficient-table section)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,n,k",
    [
        (64, 64, 64),
        (128, 96, 80),   # rectangular
        (67, 53, 41),    # odd everywhere -> root pad, cropped leaves
        (100, 200, 50),  # tall/wide mix
        (33, 1, 7),      # degenerate (L = 0: every dispatch IS one dot)
    ],
)
def test_strassen_fused_bitwise_equals_unrolled(m, n, k):
    r = rng(hash(("fused", m, n, k)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)))
    b = jnp.asarray(r.standard_normal((m, k)))
    kw = dict(n_base=8, variant="strassen", acc_dtype=jnp.float64)
    _bitwise(
        strassen_tn(a, b, leaf_dispatch="unrolled", **kw),
        strassen_tn(a, b, leaf_dispatch="fused", **kw),
    )


def test_strassen_fused_alpha_beta_accumulate_bitwise():
    r = rng(21)
    a = jnp.asarray(r.standard_normal((32, 24)))
    b = jnp.asarray(r.standard_normal((32, 40)))
    c = jnp.asarray(r.standard_normal((24, 40)))
    kw = dict(alpha=2.5, c=c, beta=-0.5, n_base=8, variant="strassen",
              acc_dtype=jnp.float64)
    got = strassen_tn(a, b, leaf_dispatch="fused", **kw)
    _bitwise(strassen_tn(a, b, leaf_dispatch="unrolled", **kw), got)
    np.testing.assert_allclose(got, 2.5 * (a.T @ b) - 0.5 * c, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("m,n", [(64, 64), (67, 53), (200, 100), (257, 129)])
def test_ata_fused_leaf_bitwise_equals_unrolled(m, n):
    r = rng(hash(("fused", m, n)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)))
    kw = dict(n_base=8, variant="strassen", acc_dtype=jnp.float64)
    dense_u = ata(a, leaf_dispatch="unrolled", **kw)
    dense_f = ata(a, leaf_dispatch="fused", **kw)
    _bitwise(dense_u, dense_f)
    np.testing.assert_allclose(dense_f, a.T @ a, rtol=1e-9, atol=1e-9)
    pu = ata(a, leaf_dispatch="unrolled", out="packed", packed_block=32, **kw)
    pf = ata(a, leaf_dispatch="fused", out="packed", packed_block=32, **kw)
    _bitwise(pu.blocks, pf.blocks)
    _bitwise(pf.to_dense(), dense_f)


@pytest.mark.parametrize("B", [1, 5])
@pytest.mark.parametrize("out", ["dense", "packed"])
def test_ata_batched_op_fused_bitwise(B, out):
    """The (B, m, n) gram entry point — including the B=1 leading dim the
    fused level grids must carry through their batch axis."""
    r = rng(22 + B)
    a = jnp.asarray(r.standard_normal((B, 48, 28)))
    kw = dict(n_base=8, variant="strassen", acc_dtype=jnp.float64, out=out)
    if out == "packed":
        kw["packed_block"] = 16
    u = ata_batched(a, leaf_dispatch="unrolled", **kw)
    f = ata_batched(a, leaf_dispatch="fused", **kw)
    if out == "packed":
        _bitwise(u.blocks, f.blocks)
    else:
        _bitwise(u, f)
        np.testing.assert_allclose(
            f, jnp.einsum("bmi,bmj->bij", a, a), rtol=1e-9, atol=1e-9
        )


def test_fused_requires_classical_variant():
    """The slot tables encode the 7-term classical combos; winograd's
    chained within-level sums have no per-leaf ±1 table, so the fused
    dispatch refuses rather than silently switching algorithms."""
    a = jnp.zeros((32, 32))
    with pytest.raises(ValueError, match="fused"):
        strassen_tn(a, a, n_base=8, variant="winograd", leaf_dispatch="fused")
    with pytest.raises(ValueError, match="fused"):
        ata(a, n_base=8, variant="winograd", leaf_dispatch="fused")


def test_fused_under_jit_and_grad():
    r = rng(23)
    a = jnp.asarray(r.standard_normal((64, 48)))
    kw = dict(n_base=16, variant="strassen", acc_dtype=jnp.float64)
    f = jax.jit(lambda a: ata(a, leaf_dispatch="fused", **kw))
    _bitwise(f(a), ata(a, leaf_dispatch="unrolled", **kw))
    g = jax.grad(
        lambda a: strassen_tn(a, a, leaf_dispatch="fused", **kw).sum()
    )(a)
    g_ref = jax.grad(lambda a: (a.T @ a).sum())(a)
    np.testing.assert_allclose(g, g_ref, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# jaxpr-size regression: O(levels) dots, not O(7^L)
# ---------------------------------------------------------------------------


def _dot_count(fn, *args):
    from repro import check

    jaxpr = jax.make_jaxpr(fn)(*args)
    return sum(1 for s in check.walk_eqns(jaxpr.jaxpr)
               if s.eqn.primitive.name == "dot_general")


def test_batched_ata_emits_two_dots():
    """The whole ATA tree = ONE batched syrk + ONE batched TN gemm."""
    a = jnp.zeros((256, 256), jnp.float32)
    n_dots_b = _dot_count(lambda x: ata(x, n_base=32, leaf_dispatch="batched"), a)
    n_dots_u = _dot_count(lambda x: ata(x, n_base=32, leaf_dispatch="unrolled"), a)
    assert n_dots_b == 2, n_dots_b
    # the unrolled tree really is leaf-per-op: 4^3 = 64 syrk leaves plus
    # Σ_ℓ 2^{2ℓ-1}·7^{3-ℓ} = 186 Strassen leaves — and the dispatch_calls
    # counter the cost model prices is exactly that jaxpr dot count
    s, g = cost._ata_leaves(256, 256, 32)
    assert (s, g) == (64, 186)
    assert n_dots_u == s + g, (n_dots_u, s, g)


def test_batched_strassen_emits_one_dot_and_scales_by_levels():
    a = jnp.zeros((512, 512), jnp.float32)
    b = jnp.zeros((512, 512), jnp.float32)
    for n_base, leaves in [(256, 7), (128, 49), (64, 343)]:
        nb_dots = _dot_count(
            lambda x, y: strassen_tn(x, y, n_base=n_base, leaf_dispatch="batched"),
            a, b,
        )
        nu_dots = _dot_count(
            lambda x, y: strassen_tn(x, y, n_base=n_base, leaf_dispatch="unrolled"),
            a, b,
        )
        assert nb_dots == 1, (n_base, nb_dots)
        assert nu_dots == leaves, (n_base, nu_dots)


def test_batched_jaxpr_total_size_grows_linearly_not_geometrically():
    """Total eqn count of the batched dispatch is O(levels): deepening the
    recursion by a level adds a constant band of encode/decode ops, while
    the unrolled jaxpr multiplies by ~7."""
    a = jnp.zeros((512, 512), jnp.float32)
    b = jnp.zeros((512, 512), jnp.float32)

    def eqns(n_base, ld):
        jaxpr = jax.make_jaxpr(
            lambda x, y: strassen_tn(x, y, n_base=n_base, leaf_dispatch=ld)
        )(a, b)
        return len(jaxpr.jaxpr.eqns)

    b1, b2, b3 = eqns(256, "batched"), eqns(128, "batched"), eqns(64, "batched")
    u2, u3 = eqns(128, "unrolled"), eqns(64, "unrolled")
    assert b3 - b2 < 2 * (b2 - b1) + 40   # additive growth, small constant
    assert u3 > 5 * u2                    # geometric growth
    assert b3 < u3 / 10


def test_fused_jaxpr_one_dot_per_leaf_and_zero_operand_stacks():
    """The fused XLA path's acceptance property: every leaf is its own dot
    (the combines happen per-leaf at trace time, 7^L dots total) and NO
    operand-combination stack is ever materialized — the repro.check
    ``no-operand-stacks`` + ``dot-budget`` rules run against the real
    fused program. Rectangular dims keep the operand block shapes
    distinguishable from the product/decode shapes; as the positive
    control, the *batched* dispatch's jaxpr (which materializes both
    operand stacks by design) must FIRE the rule when presented under a
    fused-claiming plan."""
    from repro import check

    m, n, k, n_base = 96, 32, 16, 4   # L = 2 -> 49 leaves
    a = jnp.zeros((m, n), jnp.float32)
    b = jnp.zeros((m, k), jnp.float32)
    nb, kb = n // 4, k // 4

    def trace(ld):
        return jax.make_jaxpr(
            lambda x, y: strassen_tn(
                x, y, n_base=n_base, variant="strassen", leaf_dispatch=ld
            )
        )(a, b)

    def plan(ld):
        return dataclasses.replace(
            cost.default_plan("gemm_tn", m, n, k, backend="cpu"),
            algorithm="strassen", leaf_dispatch=ld, n_base=n_base,
            use_kernels=False,
        )

    fused = trace("fused")
    art = check.Artifact(label="gemm:fused", jaxpr=fused.jaxpr,
                         plan=plan("fused"))
    report = check.run(art, rules=["no-operand-stacks", "dot-budget"])
    assert not report.violations, report.summary()
    # 49 = one dot per leaf (the dot-budget closed form, asserted again
    # directly so a registry regression can't silently weaken this test)
    n_dots = sum(1 for s in check.walk_eqns(fused.jaxpr)
                 if s.eqn.primitive.name == "dot_general")
    assert n_dots == 49, n_dots
    # the product stack IS materialized, by design
    fused_shapes = [tuple(v.aval.shape) for s in check.walk_eqns(fused.jaxpr)
                    for v in s.eqn.outvars]
    assert (49, nb, kb) in fused_shapes
    # positive control: the batched dispatch materializes both operand
    # stacks — under a fused-claiming plan the rule must fire
    batched = trace("batched")
    art_b = check.Artifact(label="gemm:batched-as-fused", jaxpr=batched.jaxpr,
                           plan=plan("fused"))
    fired = check.run(art_b, rules=["no-operand-stacks"])
    assert fired.violations, "no-operand-stacks failed to fire on a stack"


# ---------------------------------------------------------------------------
# planner threading
# ---------------------------------------------------------------------------


@pytest.fixture
def _fresh_memo(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    tune.cache.clear_memo()
    yield
    tune.cache.clear_memo()


def test_candidates_enumerate_leaf_dispatch():
    cands = cost.candidates("gemm_tn", 4096, 4096, 4096, backend="cpu")
    lds = {(c.algorithm, c.leaf_dispatch) for c in cands}
    assert any(ld == "batched" for _, ld in lds)
    assert any(ld == "unrolled" for _, ld in lds)
    # fused is enumerated for the classical variant only: the slot tables
    # encode the 7-term classical combos (winograd's chained within-level
    # sums raise in core.strassen), and dense has nothing to batch or fuse
    assert ("strassen", "fused") in lds
    assert ("winograd", "fused") not in lds
    assert ("dense", "fused") not in lds
    assert ("dense", "batched") not in lds


def test_overhead_pricing_separates_the_dispatches():
    """With thousands of leaves, unrolled must be priced above batched on
    the machine models whose stack charge is the nominal write+read (that
    is the launch-overhead term the batched dispatch removes). The cpu
    model is the deliberate exception since the fused-leaf recalibration:
    its measured stack_word_cost (≈5.5, cache-thrash dominated) outweighs
    even 7^6 thunk launches at depth 6 — matching the measured cpu ranking
    where deep batched trails deep unrolled. Fused must undercut batched
    in its shallow regime (the fig-4 bench shapes live at 1–2 levels):
    same O(levels) launches, zero materialized stacks — while at depth 6
    its 3^L slot-gather amplification prices it out, as measured."""
    for backend in ("cpu", "tpu", "gpu"):
        pu, pb = (
            cost.predict_seconds(
                "gemm_tn", "strassen", 8192, 8192, 8192, 128,
                backend=backend, leaf_dispatch=ld,
            )
            for ld in ("unrolled", "batched")
        )
        calls = cost.dispatch_calls(
            "gemm_tn", "strassen", 8192, 8192, 8192, 128, "unrolled"
        )
        assert calls == 7 ** 6
        if backend == "cpu":
            assert pb > pu, backend  # recalibrated: stacks beat launches
        else:
            assert pu > pb, backend
    for backend in ("cpu", "tpu"):  # gpu's untuned model keeps them tied
        pb1, pf1 = (
            cost.predict_seconds(
                "gemm_tn", "strassen", 8192, 8192, 8192, 4096,
                backend=backend, leaf_dispatch=ld,
            )
            for ld in ("batched", "fused")
        )
        assert pf1 < pb1, backend


def test_dispatch_calls_counts():
    assert cost.dispatch_calls("gemm_tn", "dense", 1024, 1024, 1024, 512, "unrolled") == 1
    assert cost.dispatch_calls("gemm_tn", "strassen", 1024, 1024, 1024, 256, "unrolled") == 49
    # batched: 2 leaf calls + O(levels) encode/decode stack ops
    assert cost.dispatch_calls("gemm_tn", "strassen", 1024, 1024, 1024, 256, "batched") == 10
    s, g = cost._ata_leaves(1024, 1024, 256)
    assert cost.dispatch_calls("ata", "strassen", 1024, 1024, 1024, 256, "unrolled") == s + g
    # fused: one launch per LEVEL, never per leaf — one fused leaf launch
    # + one decode pass per level for Strassen; gathered diagonal syrk +
    # per-level fused dot + per-level decode for ATA
    assert cost.dispatch_calls("gemm_tn", "strassen", 1024, 1024, 1024, 256, "fused") == 3
    assert cost.dispatch_calls("gemm_tn", "strassen", 1024, 1024, 1024, 512, "fused") == 2
    assert cost.dispatch_calls("ata", "strassen", 1024, 1024, 1024, 256, "fused") == 6


def test_plan_json_roundtrip_and_legacy_entries(_fresh_memo):
    p = tune.plan(op="ata", m=777, n=333)
    d = json.loads(json.dumps(p.to_json()))
    assert "leaf_dispatch" in d
    assert cost.Plan.from_json(d) == p
    # a pre-leaf_dispatch cache entry must deserialize to 'unrolled' —
    # exactly the dispatch it was measured with
    legacy = dict(d)
    legacy.pop("leaf_dispatch")
    assert cost.Plan.from_json(legacy).leaf_dispatch == "unrolled"


def test_autotuner_distinguishes_leaf_dispatch():
    """_same_dispatch must treat the two dispatches as different (they time
    differently), so a batched candidate can displace the unrolled default."""
    from repro.tune.search import _same_dispatch

    base = cost.default_plan("ata", 512, 512)
    flipped = dataclasses.replace(base, leaf_dispatch="batched")
    assert not _same_dispatch(base, flipped)
    fused = dataclasses.replace(base, leaf_dispatch="fused")
    assert not _same_dispatch(base, fused)
    assert not _same_dispatch(flipped, fused)


def test_ata_honors_plan_leaf_dispatch_bitwise(_fresh_memo):
    """ata(plan=p) with p.leaf_dispatch='batched' must equal the explicit
    kwarg — and both must equal the unrolled dispatch bitwise."""
    r = rng(7)
    a = jnp.asarray(r.standard_normal((200, 160)), jnp.float32)
    p = dataclasses.replace(
        tune.plan(op="ata", m=200, n=160),
        algorithm="strassen", n_base=64, leaf_dispatch="batched",
    )
    via_plan = ata(a, plan=p)
    by_hand = ata(a, n_base=64, variant="strassen", leaf_dispatch="batched")
    _bitwise(via_plan, by_hand)
    _bitwise(via_plan, ata(a, n_base=64, variant="strassen", leaf_dispatch="unrolled"))
    p_fused = dataclasses.replace(p, leaf_dispatch="fused")
    _bitwise(ata(a, plan=p_fused), via_plan)


def test_root_pad_hoist_depth_matches_legacy_recursion():
    """tree_depth reproduces the legacy per-level pad-to-even depth
    (⌈⌈d/2⌉/2⌉ = ⌈d/4⌉) for ragged dims."""
    def legacy_depth(dims, n_base):
        L = 0
        while min(dims) > n_base:
            dims = [(d + (d & 1)) // 2 for d in dims]
            L += 1
        return L

    r = rng(13)
    for _ in range(200):
        dims = tuple(int(d) for d in r.integers(1, 3000, size=3))
        n_base = int(r.integers(1, 600))
        assert tree_depth(dims, n_base) == legacy_depth(list(dims), n_base), (
            dims, n_base,
        )


def test_leaf_dispatch_validation():
    a = jnp.zeros((16, 16))
    with pytest.raises(ValueError):
        strassen_tn(a, a, n_base=8, leaf_dispatch="nope")
    with pytest.raises(ValueError):
        ata(a, n_base=8, leaf_dispatch="nope")
