"""Batched-leaf dispatch: bitwise parity with the unrolled recursion,
jaxpr-size regression, and plan threading.

The acceptance contract of the batched-leaf PR:

* ``leaf_dispatch='batched'`` is **bitwise-equal** to ``'unrolled'`` on the
  same plan, for ``strassen_tn``/``ata``/``ata_batched``, across odd and
  rectangular shapes, both variants, dense and packed output, and
  alpha/c/beta accumulation;
* the batched dispatch emits **O(levels)** dots (one batched TN gemm + one
  batched syrk for the whole ATA tree), not O(7^L) — a jaxpr-size
  regression test;
* the planner carries the choice (``Plan.leaf_dispatch``): candidates
  enumerate it, JSON round-trips it, pre-leaf_dispatch cache entries
  deserialize to ``'unrolled'``, and the overhead pricing makes the two
  dispatches distinguishable to the analytic model.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import ata, ata_batched, strassen_tn
from repro.core.strassen import tree_depth
from repro.tune import cost, defaults

jax.config.update("jax_enable_x64", True)


def rng(seed=0):
    return np.random.default_rng(seed)


def _bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bitwise parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["strassen", "winograd"])
@pytest.mark.parametrize(
    "m,n,k",
    [
        (64, 64, 64),
        (128, 96, 80),   # rectangular
        (67, 53, 41),    # odd everywhere
        (100, 200, 50),  # tall/wide mix
        (33, 1, 7),      # degenerate (L = 0: both dispatches ARE one dot)
    ],
)
def test_strassen_batched_bitwise_equals_unrolled(variant, m, n, k):
    r = rng(hash((m, n, k)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)))
    b = jnp.asarray(r.standard_normal((m, k)))
    kw = dict(n_base=8, variant=variant, acc_dtype=jnp.float64)
    _bitwise(
        strassen_tn(a, b, leaf_dispatch="unrolled", **kw),
        strassen_tn(a, b, leaf_dispatch="batched", **kw),
    )


def test_strassen_batched_alpha_beta_accumulate_bitwise():
    r = rng(1)
    a = jnp.asarray(r.standard_normal((32, 24)))
    b = jnp.asarray(r.standard_normal((32, 40)))
    c = jnp.asarray(r.standard_normal((24, 40)))
    kw = dict(alpha=2.5, c=c, beta=-0.5, n_base=8, acc_dtype=jnp.float64)
    got = strassen_tn(a, b, leaf_dispatch="batched", **kw)
    _bitwise(strassen_tn(a, b, leaf_dispatch="unrolled", **kw), got)
    np.testing.assert_allclose(got, 2.5 * (a.T @ b) - 0.5 * c, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("variant", ["strassen", "winograd"])
@pytest.mark.parametrize("m,n", [(64, 64), (67, 53), (200, 100), (257, 129)])
def test_ata_batched_leaf_bitwise_equals_unrolled(variant, m, n):
    r = rng(hash((m, n, variant)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)))
    kw = dict(n_base=8, variant=variant, acc_dtype=jnp.float64)
    dense_u = ata(a, leaf_dispatch="unrolled", **kw)
    dense_b = ata(a, leaf_dispatch="batched", **kw)
    _bitwise(dense_u, dense_b)
    np.testing.assert_allclose(dense_b, a.T @ a, rtol=1e-9, atol=1e-9)
    # packed: the packed *blocks* must agree bitwise, not just to_dense()
    pu = ata(a, leaf_dispatch="unrolled", out="packed", packed_block=32, **kw)
    pb = ata(a, leaf_dispatch="batched", out="packed", packed_block=32, **kw)
    _bitwise(pu.blocks, pb.blocks)
    _bitwise(pb.to_dense(), dense_b)


def test_ata_alpha_beta_accumulation_bitwise_both_outs():
    from repro.core import SymmetricMatrix

    r = rng(2)
    a = jnp.asarray(r.standard_normal((96, 80)))
    c_dense = jnp.asarray(r.standard_normal((80, 80)))
    kw = dict(alpha=0.25, n_base=16, acc_dtype=jnp.float64)
    _bitwise(
        ata(a, c=c_dense, beta=2.0, leaf_dispatch="unrolled", **kw),
        ata(a, c=c_dense, beta=2.0, leaf_dispatch="batched", **kw),
    )
    c_packed = SymmetricMatrix.from_dense(
        jnp.asarray(c_dense + c_dense.T), 32
    )
    pu = ata(a, c=c_packed, beta=2.0, out="packed", packed_block=32,
             leaf_dispatch="unrolled", **kw)
    pb = ata(a, c=c_packed, beta=2.0, out="packed", packed_block=32,
             leaf_dispatch="batched", **kw)
    _bitwise(pu.blocks, pb.blocks)


@pytest.mark.parametrize("out", ["dense", "packed"])
def test_ata_batched_op_bitwise_equals_unrolled(out):
    """The (B, m, n) gram entry point, both output modes."""
    r = rng(11)
    a = jnp.asarray(r.standard_normal((5, 48, 28)))
    kw = dict(n_base=8, acc_dtype=jnp.float64, out=out)
    if out == "packed":
        kw["packed_block"] = 16
    u = ata_batched(a, leaf_dispatch="unrolled", **kw)
    b = ata_batched(a, leaf_dispatch="batched", **kw)
    if out == "packed":
        _bitwise(u.blocks, b.blocks)
    else:
        _bitwise(u, b)
        np.testing.assert_allclose(
            b, jnp.einsum("bmi,bmj->bij", a, a), rtol=1e-9, atol=1e-9
        )


def test_batched_under_jit_and_grad():
    r = rng(3)
    a = jnp.asarray(r.standard_normal((64, 48)))
    f = jax.jit(
        lambda a: ata(a, n_base=16, leaf_dispatch="batched", acc_dtype=jnp.float64)
    )
    _bitwise(f(a), ata(a, n_base=16, leaf_dispatch="unrolled", acc_dtype=jnp.float64))
    g = jax.grad(
        lambda a: strassen_tn(
            a, a, n_base=16, leaf_dispatch="batched", acc_dtype=jnp.float64
        ).sum()
    )(a)
    g_ref = jax.grad(lambda a: (a.T @ a).sum())(a)
    np.testing.assert_allclose(g, g_ref, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# jaxpr-size regression: O(levels) dots, not O(7^L)
# ---------------------------------------------------------------------------


def _dot_count(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    return sum(1 for e in jaxpr.jaxpr.eqns if e.primitive.name == "dot_general")


def test_batched_ata_emits_two_dots():
    """The whole ATA tree = ONE batched syrk + ONE batched TN gemm."""
    a = jnp.zeros((256, 256), jnp.float32)
    n_dots_b = _dot_count(lambda x: ata(x, n_base=32, leaf_dispatch="batched"), a)
    n_dots_u = _dot_count(lambda x: ata(x, n_base=32, leaf_dispatch="unrolled"), a)
    assert n_dots_b == 2, n_dots_b
    # the unrolled tree really is leaf-per-op: 4^3 = 64 syrk leaves plus
    # Σ_ℓ 2^{2ℓ-1}·7^{3-ℓ} = 186 Strassen leaves — and the dispatch_calls
    # counter the cost model prices is exactly that jaxpr dot count
    s, g = cost._ata_leaves(256, 256, 32)
    assert (s, g) == (64, 186)
    assert n_dots_u == s + g, (n_dots_u, s, g)


def test_batched_strassen_emits_one_dot_and_scales_by_levels():
    a = jnp.zeros((512, 512), jnp.float32)
    b = jnp.zeros((512, 512), jnp.float32)
    for n_base, leaves in [(256, 7), (128, 49), (64, 343)]:
        nb_dots = _dot_count(
            lambda x, y: strassen_tn(x, y, n_base=n_base, leaf_dispatch="batched"),
            a, b,
        )
        nu_dots = _dot_count(
            lambda x, y: strassen_tn(x, y, n_base=n_base, leaf_dispatch="unrolled"),
            a, b,
        )
        assert nb_dots == 1, (n_base, nb_dots)
        assert nu_dots == leaves, (n_base, nu_dots)


def test_batched_jaxpr_total_size_grows_linearly_not_geometrically():
    """Total eqn count of the batched dispatch is O(levels): deepening the
    recursion by a level adds a constant band of encode/decode ops, while
    the unrolled jaxpr multiplies by ~7."""
    a = jnp.zeros((512, 512), jnp.float32)
    b = jnp.zeros((512, 512), jnp.float32)

    def eqns(n_base, ld):
        jaxpr = jax.make_jaxpr(
            lambda x, y: strassen_tn(x, y, n_base=n_base, leaf_dispatch=ld)
        )(a, b)
        return len(jaxpr.jaxpr.eqns)

    b1, b2, b3 = eqns(256, "batched"), eqns(128, "batched"), eqns(64, "batched")
    u2, u3 = eqns(128, "unrolled"), eqns(64, "unrolled")
    assert b3 - b2 < 2 * (b2 - b1) + 40   # additive growth, small constant
    assert u3 > 5 * u2                    # geometric growth
    assert b3 < u3 / 10


# ---------------------------------------------------------------------------
# planner threading
# ---------------------------------------------------------------------------


@pytest.fixture
def _fresh_memo(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    tune.cache.clear_memo()
    yield
    tune.cache.clear_memo()


def test_candidates_enumerate_leaf_dispatch():
    cands = cost.candidates("gemm_tn", 4096, 4096, 4096, backend="cpu")
    lds = {(c.algorithm, c.leaf_dispatch) for c in cands}
    assert any(ld == "batched" for _, ld in lds)
    assert any(ld == "unrolled" for _, ld in lds)
    # dense has nothing to batch
    assert ("dense", "batched") not in lds


def test_overhead_pricing_separates_the_dispatches():
    """With thousands of leaves, unrolled must be priced above batched on
    every machine model (that is the term the batched dispatch removes)."""
    for backend in ("cpu", "tpu", "gpu"):
        pu = cost.predict_seconds(
            "gemm_tn", "strassen", 8192, 8192, 8192, 128,
            backend=backend, leaf_dispatch="unrolled",
        )
        pb = cost.predict_seconds(
            "gemm_tn", "strassen", 8192, 8192, 8192, 128,
            backend=backend, leaf_dispatch="batched",
        )
        calls = cost.dispatch_calls(
            "gemm_tn", "strassen", 8192, 8192, 8192, 128, "unrolled"
        )
        assert calls == 7 ** 6
        assert pu > pb, backend


def test_dispatch_calls_counts():
    assert cost.dispatch_calls("gemm_tn", "dense", 1024, 1024, 1024, 512, "unrolled") == 1
    assert cost.dispatch_calls("gemm_tn", "strassen", 1024, 1024, 1024, 256, "unrolled") == 49
    # batched: 2 leaf calls + O(levels) encode/decode stack ops
    assert cost.dispatch_calls("gemm_tn", "strassen", 1024, 1024, 1024, 256, "batched") == 10
    s, g = cost._ata_leaves(1024, 1024, 256)
    assert cost.dispatch_calls("ata", "strassen", 1024, 1024, 1024, 256, "unrolled") == s + g


def test_plan_json_roundtrip_and_legacy_entries(_fresh_memo):
    p = tune.plan(op="ata", m=777, n=333)
    d = json.loads(json.dumps(p.to_json()))
    assert "leaf_dispatch" in d
    assert cost.Plan.from_json(d) == p
    # a pre-leaf_dispatch cache entry must deserialize to 'unrolled' —
    # exactly the dispatch it was measured with
    legacy = dict(d)
    legacy.pop("leaf_dispatch")
    assert cost.Plan.from_json(legacy).leaf_dispatch == "unrolled"


def test_autotuner_distinguishes_leaf_dispatch():
    """_same_dispatch must treat the two dispatches as different (they time
    differently), so a batched candidate can displace the unrolled default."""
    from repro.tune.search import _same_dispatch

    base = cost.default_plan("ata", 512, 512)
    flipped = dataclasses.replace(base, leaf_dispatch="batched")
    assert not _same_dispatch(base, flipped)


def test_ata_honors_plan_leaf_dispatch_bitwise(_fresh_memo):
    """ata(plan=p) with p.leaf_dispatch='batched' must equal the explicit
    kwarg — and both must equal the unrolled dispatch bitwise."""
    r = rng(7)
    a = jnp.asarray(r.standard_normal((200, 160)), jnp.float32)
    p = dataclasses.replace(
        tune.plan(op="ata", m=200, n=160),
        algorithm="strassen", n_base=64, leaf_dispatch="batched",
    )
    via_plan = ata(a, plan=p)
    by_hand = ata(a, n_base=64, variant="strassen", leaf_dispatch="batched")
    _bitwise(via_plan, by_hand)
    _bitwise(via_plan, ata(a, n_base=64, variant="strassen", leaf_dispatch="unrolled"))


def test_root_pad_hoist_depth_matches_legacy_recursion():
    """tree_depth reproduces the legacy per-level pad-to-even depth
    (⌈⌈d/2⌉/2⌉ = ⌈d/4⌉) for ragged dims."""
    def legacy_depth(dims, n_base):
        L = 0
        while min(dims) > n_base:
            dims = [(d + (d & 1)) // 2 for d in dims]
            L += 1
        return L

    r = rng(13)
    for _ in range(200):
        dims = tuple(int(d) for d in r.integers(1, 3000, size=3))
        n_base = int(r.integers(1, 600))
        assert tree_depth(dims, n_base) == legacy_depth(list(dims), n_base), (
            dims, n_base,
        )


def test_leaf_dispatch_validation():
    a = jnp.zeros((16, 16))
    with pytest.raises(ValueError):
        strassen_tn(a, a, n_base=8, leaf_dispatch="nope")
    with pytest.raises(ValueError):
        ata(a, n_base=8, leaf_dispatch="nope")
