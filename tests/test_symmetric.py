"""Tests for the packed-symmetric storage (`repro.core.symmetric`) and the
packed-index math shared with the syrk kernel grid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.symmetric import SymmetricMatrix, default_block_size, tri_block_indices
from repro.kernels.syrk import _tri_coords


# ---------------------------------------------------------------------------
# _tri_coords: the packed-index → (i, j) inverse used by the kernel grid
# ---------------------------------------------------------------------------


def test_tri_coords_exhaustive_1e6():
    """Exhaustive inverse check for every packed index t < 10⁶."""
    t = jnp.arange(1_000_000, dtype=jnp.int32)
    i, j = _tri_coords(t)
    i, j = np.asarray(i), np.asarray(j)
    # exact inverse of t = i(i+1)/2 + j
    np.testing.assert_array_equal(i.astype(np.int64) * (i + 1) // 2 + j, np.asarray(t))
    assert (j >= 0).all() and (j <= i).all()


def test_tri_coords_fp_boundary_cases():
    """Triangular numbers and their neighbours are exactly where the f32
    sqrt can round the wrong way — the integer correction must absorb it."""
    rows = np.unique(
        np.concatenate(
            [
                np.arange(1, 2000, dtype=np.int64),
                np.asarray([2047, 2048, 2896, 4095, 4096], dtype=np.int64),
            ]
        )
    )
    cases = []
    for i in rows:
        tri = i * (i + 1) // 2
        cases += [tri - 1, tri, tri + 1]  # last of row i-1, first/second of row i
    t = jnp.asarray(np.asarray(sorted(set(c for c in cases if c >= 0))), jnp.int32)
    i, j = _tri_coords(t)
    i, j = np.asarray(i, np.int64), np.asarray(j, np.int64)
    np.testing.assert_array_equal(i * (i + 1) // 2 + j, np.asarray(t))
    assert (j >= 0).all() and (j <= i).all()


def test_tri_coords_matches_tril_indices_enumeration():
    """Kernel grid order and SymmetricMatrix storage order must agree."""
    nb = 53
    i_ref, j_ref = tri_block_indices(nb)
    t = jnp.arange(nb * (nb + 1) // 2, dtype=jnp.int32)
    i, j = _tri_coords(t)
    np.testing.assert_array_equal(np.asarray(i), i_ref)
    np.testing.assert_array_equal(np.asarray(j), j_ref)


# ---------------------------------------------------------------------------
# SymmetricMatrix: packed <-> dense round trips and arithmetic
# ---------------------------------------------------------------------------


def _random_sym(r, n):
    x = r.standard_normal((n, n)).astype(np.float32)
    low = np.tril(x)
    return jnp.asarray(low + np.tril(x, -1).T)


@pytest.mark.parametrize("n,bn", [(8, 8), (64, 16), (100, 32), (129, 64), (7, 128)])
def test_roundtrip_dense_packed_dense(n, bn):
    r = np.random.default_rng(n * 1000 + bn)
    dense = _random_sym(r, n)
    sm = SymmetricMatrix.from_dense(dense, bn)
    np.testing.assert_array_equal(np.asarray(sm.to_dense()), np.asarray(dense))
    # packed block count is triangular, never nb²
    assert sm.blocks.shape[-3] == sm.nb * (sm.nb + 1) // 2
    assert sm.shape == (n, n)


def test_block_size_clamp():
    # a 7×7 matrix must not be blown up to a 128×128 block
    assert default_block_size(7, 128) == 8
    assert default_block_size(1000, 128) == 128
    sm = SymmetricMatrix.zeros(7, 128)
    assert sm.bn == 8 and sm.blocks.shape == (1, 8, 8)


def test_packed_memory_ratio():
    """Resident bytes approach half of dense as blocks-per-side grows."""
    n, bn = 1024, 128
    sm = SymmetricMatrix.zeros(n, bn)
    dense_bytes = n * n * 4
    ratio = sm.nbytes / dense_bytes
    k = n // bn
    assert ratio == pytest.approx((k + 1) / (2 * k))
    assert ratio < 0.6


def test_add_scale_stay_packed_and_match_dense():
    r = np.random.default_rng(3)
    a, b = _random_sym(r, 96), _random_sym(r, 96)
    sa = SymmetricMatrix.from_dense(a, 32)
    sb = SymmetricMatrix.from_dense(b, 32)
    out = 0.25 * sa + sb.scale(2.0)
    assert isinstance(out, SymmetricMatrix)
    np.testing.assert_allclose(
        np.asarray(out.to_dense()), 0.25 * np.asarray(a) + 2.0 * np.asarray(b),
        rtol=1e-6, atol=1e-6,
    )


def test_add_incompatible_layouts_raise():
    a = SymmetricMatrix.zeros(64, 16)
    b = SymmetricMatrix.zeros(64, 32)
    with pytest.raises(ValueError):
        a.add(b)


def test_diagonal_and_trace():
    r = np.random.default_rng(4)
    dense = _random_sym(r, 70)
    sm = SymmetricMatrix.from_dense(dense, 32)
    np.testing.assert_allclose(np.asarray(sm.diagonal()), np.diag(np.asarray(dense)), rtol=1e-6)
    np.testing.assert_allclose(float(sm.trace()), float(jnp.trace(dense)), rtol=1e-5)


def test_pytree_jit_vmap_cond():
    """SymmetricMatrix must ride through jit, vmap, and lax.cond as a pytree."""
    r = np.random.default_rng(5)
    batch = jnp.asarray(
        np.stack([np.asarray(_random_sym(r, 40)) for _ in range(3)])
    )
    sm = jax.vmap(lambda d: SymmetricMatrix.from_dense(d, 16))(batch)
    assert sm.blocks.shape[0] == 3

    @jax.jit
    def decayed(s):
        return jax.lax.cond(True, lambda x: 0.5 * x, lambda x: x, s)

    out = decayed(sm)
    np.testing.assert_allclose(
        np.asarray(out.to_dense()), 0.5 * np.asarray(batch), rtol=1e-6, atol=1e-6
    )


def test_batched_roundtrip():
    r = np.random.default_rng(6)
    batch = np.stack([np.asarray(_random_sym(r, 33)) for _ in range(4)])
    sm = SymmetricMatrix.from_dense(jnp.asarray(batch), 16)
    assert sm.blocks.shape[:1] == (4,)
    np.testing.assert_array_equal(np.asarray(sm.to_dense()), batch)


# ---------------------------------------------------------------------------
# write-traffic model (analysis satellite)
# ---------------------------------------------------------------------------


def test_syrk_write_traffic_model():
    from repro.analysis.roofline import syrk_write_traffic

    n, bn = 1024, 128
    nb = n // bn
    t = nb * (nb + 1) // 2
    packed = syrk_write_traffic(n, bn, "packed")
    dual = syrk_write_traffic(n, bn, "dual")
    mirror = syrk_write_traffic(n, bn, "mirror")
    assert packed == t * bn * bn * 4
    assert dual == nb * nb * bn * bn * 4
    # the seed's mirror pass re-writes the full square on top of the kernel's
    # triangular writes — strictly the worst of the three
    assert mirror > dual > packed
    assert packed / dual == pytest.approx((nb + 1) / (2 * nb))


def test_from_tile_stack_presymmetrized_skips_diag_symmetrize():
    """``presymmetrized=True`` is the BFS/DFS schedule's contract: the
    producer already applied ``sym_tile`` to every diagonal tile, so the
    aligned path must trust the stack verbatim (on a sharded stack
    ``_symmetrize_diag`` is a whole cross-device gather). The misaligned
    path re-symmetrizes regardless — ``sym_tile`` is idempotent, so
    presymmetrized inputs stay bitwise-correct there too."""
    from repro.core.symmetric import sym_tile

    rng = np.random.default_rng(21)
    n, nb, w = 96, 3, 32
    t = nb * (nb + 1) // 2
    tiles = jnp.asarray(rng.standard_normal((t, w, w)), jnp.float32)

    # aligned (w == packed block): raw asymmetric diagonals are symmetrized
    # by default...
    sym = SymmetricMatrix.from_tile_stack(tiles, n, nb=nb, packed_block=w)
    # ...and trusted verbatim under the flag
    raw = SymmetricMatrix.from_tile_stack(tiles, n, nb=nb, packed_block=w,
                                          presymmetrized=True)
    assert (np.asarray(raw.blocks) == np.asarray(tiles)).all()
    assert not (np.asarray(sym.blocks) == np.asarray(tiles)).all()

    # a producer that actually pre-symmetrizes gets bitwise the same
    # storage either way
    diag_t = np.array([i * (i + 1) // 2 + i for i in range(nb)])
    pre = tiles.at[diag_t].set(sym_tile(tiles[diag_t]))
    a = SymmetricMatrix.from_tile_stack(pre, n, nb=nb, packed_block=w)
    b = SymmetricMatrix.from_tile_stack(pre, n, nb=nb, packed_block=w,
                                        presymmetrized=True)
    assert (np.asarray(a.blocks) == np.asarray(b.blocks)).all()

    # misaligned (stripe w=32 onto a 48-block grid): the flag is inert —
    # the repack mixes stripe tiles, so it must re-symmetrize either way
    c = SymmetricMatrix.from_tile_stack(pre, n, nb=nb, packed_block=48)
    d = SymmetricMatrix.from_tile_stack(pre, n, nb=nb, packed_block=48,
                                        presymmetrized=True)
    assert (np.asarray(c.blocks) == np.asarray(d.blocks)).all()
    assert (np.asarray(c.to_dense()) == np.asarray(c.to_dense()).T).all()
