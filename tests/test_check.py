"""repro.check engine tests: every rule fires on a violating program and
stays silent on a clean planned one.

Per-rule structure (the PR's acceptance criterion): a small synthetic
program that violates the contract — the rule must produce a Finding with
eqn provenance — plus a planned program traced through the same
``trace_plan`` path CI uses, on which the rule must stay quiet. The
report/allowlist machinery and the ``python -m repro.check`` CLI JSON
contract are covered at the end. The *integration* halves (rules run
against the real solve/distributed/kernel programs, positive controls on
the real batched dispatch) live with their subjects in test_solve /
test_distributed / test_leaf_dispatch / test_kernels / test_core_ata.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import check
from repro.check import rules as check_rules
from repro.tune import cost


def _art(fn, *args, label="synthetic", plan=None, hlo_text=None, **overrides):
    """Trace ``fn`` into a plan-less Artifact with override-pinned rules."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return check.Artifact(label=label, jaxpr=jaxpr.jaxpr, plan=plan,
                          hlo_text=hlo_text, overrides=overrides)


def _violations(art, rule_id):
    return check.run(art, rules=[rule_id]).violations


# ---------------------------------------------------------------------------
# registry basics
# ---------------------------------------------------------------------------


def test_registry_ships_the_eight_rules():
    assert check.rule_ids() == sorted([
        "no-dense-square", "no-operand-stacks", "dot-budget",
        "launch-budget", "no-full-transpose", "acc-dtype",
        "no-vmap-of-pallas", "collective-budget",
    ])
    for rid in check.rule_ids():
        r = check.REGISTRY[rid]
        assert r.doc, f"rule {rid} has no docstring"
        assert r.severity in ("error", "warning")


def test_unknown_rule_id_raises():
    art = _art(lambda x: x + 1, jnp.zeros((2, 2)))
    with pytest.raises(KeyError, match="no-such-rule"):
        check.run(art, rules=["no-such-rule"])


# ---------------------------------------------------------------------------
# no-dense-square
# ---------------------------------------------------------------------------


def test_no_dense_square_fires_on_materialized_square():
    a = jnp.zeros((16, 8), jnp.float32)
    art = _art(lambda x: x.T @ x, a, forbidden_squares={(8, 8)})
    found = _violations(art, "no-dense-square")
    assert found and found[0].shape == (8, 8)
    assert found[0].primitive == "dot_general"
    assert found[0].eqn_index is not None
    assert "eqn#" in found[0].provenance


def test_no_dense_square_descends_nested_jaxprs():
    """The square hides inside a jit body — provenance carries the path."""
    a = jnp.zeros((16, 8), jnp.float32)
    art = _art(lambda x: jax.jit(lambda y: y.T @ y)(x), a,
               forbidden_squares={(8, 8)})
    found = _violations(art, "no-dense-square")
    # the wrapper eqn's outvar matches too; the in-body finding carries
    # the enclosing path
    assert any(f.path == ("pjit",) for f in found), found


def test_no_dense_square_clean_on_planned_packed_grid():
    plan = dataclasses.replace(
        cost.default_plan("ata", 192, 128, backend="cpu"),
        algorithm="strassen", n_base=32, packed_block=32, out="packed",
        use_kernels=False)
    art = check.trace_plan(plan)
    assert not _violations(art, "no-dense-square")


# ---------------------------------------------------------------------------
# no-operand-stacks
# ---------------------------------------------------------------------------


def _fused_gemm_plan(m=96, n=32, k=16, n_base=4):
    return dataclasses.replace(
        cost.default_plan("gemm_tn", m, n, k, backend="cpu"),
        algorithm="strassen", leaf_dispatch="fused", n_base=n_base,
        use_kernels=False)


def test_no_operand_stacks_fires_on_seven_multiple_stack():
    # leaf operand shape at L=2 for (96, 32, 16)/4 is (24, 8); a 49-deep
    # stack of it is exactly the batched dispatch's signature traffic
    plan = _fused_gemm_plan()
    art = _art(lambda x: jnp.broadcast_to(x, (49, 24, 8)) * 2.0,
               jnp.zeros((24, 8), jnp.float32), plan=plan)
    found = _violations(art, "no-operand-stacks")
    assert found and found[0].shape == (49, 24, 8)


def test_no_operand_stacks_ignores_product_stacks_and_pow2_relayouts():
    plan = _fused_gemm_plan()
    # (49, 8, 4) is the product stack (materialized by design); (16, 24, 8)
    # is a 4^L block-major relayout — neither is a violation
    art = _art(
        lambda x, y: (jnp.broadcast_to(x, (49, 8, 4)),
                      jnp.broadcast_to(y, (16, 24, 8))),
        jnp.zeros((8, 4), jnp.float32), jnp.zeros((24, 8), jnp.float32),
        plan=plan)
    assert not _violations(art, "no-operand-stacks")


# ---------------------------------------------------------------------------
# dot-budget
# ---------------------------------------------------------------------------


def test_dot_budget_fires_on_count_mismatch():
    a = jnp.zeros((8, 8), jnp.float32)
    art = _art(lambda x: x @ x, a, expected_dots=2)
    found = _violations(art, "dot-budget")
    assert found and "predicts 2" in found[0].message


def test_dot_budget_clean_on_planned_unrolled_ata():
    plan = dataclasses.replace(
        cost.default_plan("ata", 192, 128, backend="cpu"),
        algorithm="strassen", leaf_dispatch="unrolled", n_base=32,
        use_kernels=False)
    art = check.trace_plan(plan)
    assert not _violations(art, "dot-budget")
    # and the closed form really is s + g
    s, g = cost._ata_leaves(192, 128, 32)
    got = sum(1 for st in art.sites()
              if st.eqn.primitive.name == "dot_general")
    assert got == s + g


# ---------------------------------------------------------------------------
# launch-budget
# ---------------------------------------------------------------------------


def _one_interpret_syrk(x):
    from repro.kernels import ops

    return ops.syrk(x, blocks=(64, 64), interpret=True)


def test_launch_budget_fires_on_count_and_ceiling():
    a = jnp.zeros((64, 64), jnp.float32)
    art = _art(_one_interpret_syrk, a, expected_launches=0,
               launch_ceiling=0)
    found = _violations(art, "launch-budget")
    # one launch vs expected 0, and 1 > ceiling 0: both findings
    assert len(found) == 2
    assert any("closed" in f.message for f in found)
    assert any("budget" in f.message for f in found)


def test_launch_budget_clean_on_planned_fused_kernels():
    plan = dataclasses.replace(
        cost.default_plan("ata", 192, 128, backend="cpu"),
        algorithm="strassen", leaf_dispatch="fused", n_base=32,
        packed_block=32, use_kernels=True)
    art = check.trace_plan(plan)
    assert not _violations(art, "launch-budget")


# ---------------------------------------------------------------------------
# no-full-transpose
# ---------------------------------------------------------------------------


def test_no_full_transpose_fires_above_tile_bound():
    a = jnp.zeros((8, 16), jnp.float32)
    art = _art(lambda x: x.T, a, max_transpose_dim=4)
    found = _violations(art, "no-full-transpose")
    assert found and found[0].shape == (16, 8)
    assert found[0].primitive == "transpose"


def test_no_full_transpose_mirror_budget_consumed_once():
    a = jnp.zeros((8, 8), jnp.float32)
    # two (8, 8) mirrors against a budget of one: the second must fire
    art = _art(lambda x: x.T + x.T * 2.0, a, max_transpose_dim=4,
               mirror_budget=1, mirror_shape=(8, 8))
    assert len(_violations(art, "no-full-transpose")) == 1


def test_no_full_transpose_allows_tile_granular():
    a = jnp.zeros((4, 4), jnp.float32)
    art = _art(lambda x: x.T, a, max_transpose_dim=4)
    assert not _violations(art, "no-full-transpose")


# ---------------------------------------------------------------------------
# acc-dtype
# ---------------------------------------------------------------------------


def test_acc_dtype_fires_on_bf16_accumulation():
    a = jnp.zeros((8, 8), jnp.bfloat16)
    art = _art(lambda x, y: x @ y, a, a)
    found = _violations(art, "acc-dtype")
    assert found and "bfloat16" in found[0].message


def test_acc_dtype_clean_with_pinned_preferred_type():
    a = jnp.zeros((8, 8), jnp.bfloat16)
    art = _art(
        lambda x, y: jnp.matmul(x, y, preferred_element_type=jnp.float32),
        a, a)
    assert not _violations(art, "acc-dtype")


def test_acc_dtype_clean_on_planned_bf16_grid():
    """The satellite fix: the planned bf16 paths (CG operator, Cholesky
    Schur einsums included) all pin f32 accumulation."""
    plan = dataclasses.replace(
        cost.default_plan("ata", 192, 128, backend="cpu"),
        algorithm="strassen", leaf_dispatch="unrolled", n_base=32,
        use_kernels=False, dtype="bfloat16")
    art = check.trace_plan(plan)
    assert not _violations(art, "acc-dtype")


# ---------------------------------------------------------------------------
# no-vmap-of-pallas
# ---------------------------------------------------------------------------


def test_no_vmap_of_pallas_fires_on_vmapped_kernel():
    a = jnp.zeros((2, 64, 64), jnp.float32)
    art = _art(jax.vmap(_one_interpret_syrk), a)
    found = _violations(art, "no-vmap-of-pallas")
    assert found and "vmapped_dims" in found[0].message


def test_no_vmap_of_pallas_clean_on_native_batch_grid():
    a = jnp.zeros((2, 64, 64), jnp.float32)
    art = _art(_one_interpret_syrk, a)   # 3-D input: native leading grid
    assert not _violations(art, "no-vmap-of-pallas")


# ---------------------------------------------------------------------------
# collective-budget
# ---------------------------------------------------------------------------

_AR_HLO = "  %ar = f32[128,128]{1,0} all-reduce(%x), replica_groups={}\n"


def test_collective_budget_fires_over_budget():
    art = _art(lambda x: x, jnp.zeros((2, 2)),
               hlo_text=_AR_HLO, collective_budget_bytes=1024)
    found = _violations(art, "collective-budget")
    assert found and "65536" in found[0].message   # 128·128·4


def test_collective_budget_respects_slack_and_budget():
    art = _art(lambda x: x, jnp.zeros((2, 2)),
               hlo_text=_AR_HLO, collective_budget_bytes=65536)
    assert not _violations(art, "collective-budget")
    art2 = _art(lambda x: x, jnp.zeros((2, 2)),
                hlo_text=_AR_HLO, collective_budget_bytes=32768,
                collective_slack=2.0)
    assert not _violations(art2, "collective-budget")


def test_collective_budget_skips_without_hlo():
    art = _art(lambda x: x, jnp.zeros((2, 2)),
               collective_budget_bytes=0)
    assert not _violations(art, "collective-budget")


# ---------------------------------------------------------------------------
# report / allowlist / obs wiring
# ---------------------------------------------------------------------------


def test_allowlist_suppresses_but_keeps_auditable():
    a = jnp.zeros((16, 8), jnp.float32)
    art = _art(lambda x: x.T @ x, a, label="known:debt",
               forbidden_squares={(8, 8)})
    allow = check.Allow(rule="no-dense-square", artifact="known:*",
                        reason="legacy retrieval path, tracked in §9")
    report = check.run(art, rules=["no-dense-square"], allowlist=[allow])
    assert report.exit_code == 0 and not report.violations
    assert len(report.allowlisted) == 1
    j = report.to_json()
    assert j["counts"] == {"artifacts": 1, "findings": 0,
                           "violations": 0, "allowlisted": 1}
    assert j["allowlist"][0]["reason"].startswith("legacy")


def test_allowlist_pattern_must_match_artifact():
    a = jnp.zeros((16, 8), jnp.float32)
    art = _art(lambda x: x.T @ x, a, label="other:site",
               forbidden_squares={(8, 8)})
    allow = check.Allow(rule="no-dense-square", artifact="known:*")
    report = check.run(art, rules=["no-dense-square"], allowlist=[allow])
    assert report.exit_code == 1 and report.violations


def test_report_json_schema_and_summary():
    a = jnp.zeros((16, 8), jnp.float32)
    art = _art(lambda x: x.T @ x, a, forbidden_squares={(8, 8)})
    report = check.run(art, rules=["no-dense-square"])
    j = report.to_json()
    assert j["schema"] == check.REPORT_SCHEMA == "repro.check/v1"
    f = j["findings"][0]
    assert f["rule"] == "no-dense-square" and f["shape"] == [8, 8]
    assert f["provenance"]
    assert "no-dense-square" in report.summary()


def test_run_increments_obs_counters():
    from repro.obs import metrics

    before = metrics.get("check.violations")
    a = jnp.zeros((16, 8), jnp.float32)
    art = _art(lambda x: x.T @ x, a, forbidden_squares={(8, 8)})
    check.run(art, rules=["no-dense-square"])
    assert metrics.get("check.violations") == before + 1
    assert metrics.get("check.findings.no-dense-square") >= 1
    assert metrics.get("check.artifacts") >= 1


# ---------------------------------------------------------------------------
# harness + CLI
# ---------------------------------------------------------------------------


def test_canonical_grid_covers_the_dispatch_matrix():
    plans = check.canonical_plans()
    assert len(plans) >= 20
    assert {p.op for p in plans} == {"ata", "gemm_tn", "solve"}
    assert {p.leaf_dispatch for p in plans if p.op == "ata"} >= {
        "unrolled", "batched", "fused"}
    assert any(p.use_kernels for p in plans)
    assert any(p.dtype == "bfloat16" for p in plans)
    assert {p.method for p in plans if p.op == "solve"} == {"factor", "cg"}


def test_bfsdfs_plans_are_planner_selected():
    """The distributed sweep's BFS/DFS artifacts trace the interleaving the
    planner picked — a BFS-containing comm_schedule on a pool-divisible
    triangle — for both output modes of the harness mesh."""
    plans = check.bfsdfs_plans(2, 4)
    assert {p.out for p in plans} == {"dense", "packed"}
    for p in plans:
        assert p.comm_schedule and "B" in p.comm_schedule
        assert p.devices == 2 and p.row_devices == 4
        t = p.nb * (p.nb + 1) // 2
        assert t % (p.devices * p.row_devices) == 0


def test_cli_quick_json_smoke(tmp_path):
    out = tmp_path / "CHECK_report.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.check", "--quick", "--json", str(out)],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    j = json.loads(out.read_text())
    assert j["schema"] == "repro.check/v1"
    assert j["counts"]["violations"] == 0
    assert j["counts"]["artifacts"] == 3
    assert "repro.check:" in proc.stdout


def test_cli_list_rules():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.check", "--list"],
        env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0
    for rid in check.rule_ids():
        assert rid in proc.stdout
