"""Tests for the serving layer (repro.serve).

Coverage per the PR's acceptance criteria:

* the **bitwise parity property**: every bucketed result equals the
  per-request ``solve.lstsq`` answer bit for bit — ragged m/r tails,
  pad-then-crop at exact bucket edges, vector RHS, mixed ridges in one
  flush, float32 and float64 request dtypes, and the whiten path against
  its unbatched pipeline;
* the bucket lattice: admission rules (exact n/dtype, banded m/r,
  ``exact_m`` for recursing grams), tightest-fit routing, numpy pad/crop;
* the queue: max-batch and max-wait flushing with a fake clock, FIFO
  order, and all three reject reasons with their retry-hint contract;
* the zero-retrace contract: floors armed by warm, growth raises (strict)
  or counts (non-strict);
* serve metrics: reservoir percentiles and the published obs gauges;
* the CLI smoke gate and the ``repro.check`` serve harness.
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.serve import metrics as serve_metrics
from repro.serve.bucketing import (
    BucketLattice,
    BucketSpec,
    crop_result,
    make_buckets,
    pad_operands,
)
from repro.serve.engine import Server, ServeConfig, smoke_config
from repro.serve.queue import FlushPolicy, MicroBatchQueue, Rejected, Request

# ---------------------------------------------------------------------------
# bucketing


def test_bucket_spec_validation():
    with pytest.raises(ValueError):
        BucketSpec(op="qr", m=8, n=8, r=1, batch=1)
    with pytest.raises(ValueError):
        BucketSpec(op="lstsq", m=4, n=8, r=1, batch=1)  # m < n
    with pytest.raises(ValueError):
        BucketSpec(op="lstsq", m=8, n=8, r=0, batch=1)


def test_bucket_admission_rules():
    s = BucketSpec(op="lstsq", m=48, n=32, r=4, batch=4)
    assert s.admits("lstsq", 48, 32, 4, "float32")
    assert s.admits("lstsq", 33, 32, 1, "float32")   # m, r band up
    assert not s.admits("lstsq", 49, 32, 4, "float32")   # m over capacity
    assert not s.admits("lstsq", 48, 33, 4, "float32")   # n is exact
    assert not s.admits("lstsq", 48, 32, 5, "float32")   # r over capacity
    assert not s.admits("whiten", 48, 32, 4, "float32")  # op is exact
    assert not s.admits("lstsq", 48, 32, 4, "float64")   # dtype is exact
    exact = BucketSpec(op="lstsq", m=48, n=32, r=4, batch=4, exact_m=True)
    assert exact.admits("lstsq", 48, 32, 2, "float32")
    assert not exact.admits("lstsq", 40, 32, 2, "float32")  # no m banding


def test_make_buckets_marks_recursing_grams_exact_m():
    specs = make_buckets(ops=("lstsq",), n_values=(32, 128), m_bands=(128,),
                         r_bands=(4,), batch=2, n_base=64)
    by_n = {s.n: s for s in specs}
    assert not by_n[32].exact_m       # single-leaf gram: m-padding is bitwise
    assert by_n[128].exact_m          # recursing gram: padding moves the split
    # m bands below n are skipped, and an all-skipped lattice is an error
    assert all(s.m >= s.n for s in
               make_buckets(n_values=(32,), m_bands=(16, 48), batch=1))
    with pytest.raises(ValueError):
        make_buckets(n_values=(64,), m_bands=(32,), batch=1)


def test_lattice_routes_to_tightest_bucket():
    lattice = BucketLattice(make_buckets(
        ops=("lstsq",), n_values=(32,), m_bands=(48, 96), r_bands=(4, 8),
        batch=4, n_base=64))
    assert lattice.bucket_for("lstsq", 40, 32, 3).key == \
        ("lstsq", 48, 32, 4, "float32")
    assert lattice.bucket_for("lstsq", 50, 32, 3).key == \
        ("lstsq", 96, 32, 4, "float32")
    assert lattice.bucket_for("lstsq", 40, 32, 5).key == \
        ("lstsq", 48, 32, 8, "float32")
    assert lattice.bucket_for("lstsq", 40, 64, 3) is None   # unknown n
    assert lattice.bucket_for("lstsq", 97, 32, 3) is None   # over every band
    with pytest.raises(ValueError):
        BucketLattice([BucketSpec(op="lstsq", m=8, n=8, r=1, batch=1)] * 2)


def test_pad_operands_is_numpy_zero_padding():
    spec = BucketSpec(op="lstsq", m=48, n=32, r=4, batch=4)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((40, 32)).astype(np.float32)
    b = rng.standard_normal((40, 3)).astype(np.float32)
    a_pad, b_pad = pad_operands(spec, a, b)
    assert isinstance(a_pad, np.ndarray) and isinstance(b_pad, np.ndarray)
    assert a_pad.shape == (48, 32) and b_pad.shape == (48, 4)
    np.testing.assert_array_equal(a_pad[:40], a)
    assert not a_pad[40:].any() and not b_pad[40:].any()
    assert not b_pad[:, 3:].any()
    np.testing.assert_array_equal(crop_result(spec, b_pad, 3), b_pad[:, :3])
    # whiten's rhs lives in feature space: rows pad to n, not m
    wspec = BucketSpec(op="whiten", m=48, n=32, r=4, batch=4)
    _, v_pad = pad_operands(wspec, a, rng.standard_normal((32, 2)))
    assert v_pad.shape == (32, 4)
    for bad_a, bad_b in [(rng.standard_normal((40, 33)), b),    # wrong n
                         (rng.standard_normal((49, 32)), b),    # m over
                         (a, rng.standard_normal((40, 5)))]:    # r over
        with pytest.raises(ValueError):
            pad_operands(spec, bad_a, bad_b)


def test_bucket_spec_json_roundtrip():
    s = BucketSpec(op="whiten", m=96, n=64, r=8, batch=2, dtype="float64",
                   exact_m=True)
    assert BucketSpec.from_json(json.loads(json.dumps(s.to_json()))) == s


# ---------------------------------------------------------------------------
# queue (fake clock; no jax anywhere)


def _queue(capacity=8, max_wait_s=0.01, batch=3):
    lattice = BucketLattice(make_buckets(
        ops=("lstsq",), n_values=(8,), m_bands=(8, 16), r_bands=(2,),
        batch=batch, n_base=64))
    return MicroBatchQueue(lattice, capacity=capacity,
                           policy=FlushPolicy(max_wait_s=max_wait_s))


def _req(m=8, n=8, r=2, **kw):
    return Request(op="lstsq", a=np.zeros((m, n), np.float32),
                   b=np.zeros((m, r), np.float32), **kw)


def test_queue_max_batch_flush_and_fifo():
    q = _queue(batch=3)
    tickets = [q.offer(_req(), now=0.0) for _ in range(3)]
    assert q.depth() == 3
    batches = q.due(0.0)
    assert len(batches) == 1 and q.depth() == 0
    assert [t.id for _, lane in batches for t in lane] == \
        [t.id for t in tickets]                      # FIFO within the lane


def test_queue_max_wait_flushes_ragged():
    q = _queue(max_wait_s=0.01, batch=3)
    q.offer(_req(), now=0.0)
    assert q.due(0.005) == []                        # young: not due yet
    batches = q.due(0.02)                            # aged past max_wait
    assert len(batches) == 1 and len(batches[0][1]) == 1
    q.offer(_req(), now=1.0)
    assert len(q.due(1.0, force=True)) == 1          # force drains young lanes


def test_queue_reject_reasons_and_retry_hints():
    q = _queue(capacity=2, max_wait_s=0.01)
    with pytest.raises(Rejected) as e:
        q.offer(_req(n=9), now=0.0)                  # no bucket for n=9
    assert e.value.reason == "no-bucket" and e.value.retry_after_s is None
    with pytest.raises(Rejected) as e:
        q.offer(_req(deadline_s=0.001), now=0.0)     # budget < max_wait
    assert e.value.reason == "deadline"
    q.offer(_req(), now=0.0)
    q.offer(_req(), now=0.0)
    with pytest.raises(Rejected) as e:
        q.offer(_req(), now=0.0)                     # bounded depth
    assert e.value.reason == "capacity"
    assert e.value.retry_after_s == pytest.approx(0.01)  # the flush bound


def test_queue_lane_depths_track_buckets():
    q = _queue(batch=3)
    q.offer(_req(m=8), now=0.0)
    q.offer(_req(m=16), now=0.0)
    depths = q.lane_depths()
    assert sum(depths.values()) == 2 and len(depths) == 2


# ---------------------------------------------------------------------------
# serve metrics


def test_percentile_interpolation_and_reservoir_bound():
    assert np.isnan(serve_metrics.percentile([], 50))
    vals = list(map(float, range(100)))
    assert serve_metrics.percentile(vals, 50) == pytest.approx(49.5)
    assert serve_metrics.percentile(vals, 99) == pytest.approx(98.01)
    serve_metrics.reset()
    for i in range(serve_metrics.RESERVOIR_SIZE + 100):
        serve_metrics.record_latency("boundcheck", float(i))
    got = serve_metrics.samples("boundcheck")
    assert len(got) == serve_metrics.RESERVOIR_SIZE
    assert got[0] == 100.0                           # oldest samples evicted
    serve_metrics.reset()


def test_publish_percentiles_lands_in_obs_snapshot():
    serve_metrics.reset()
    for v in (0.001, 0.002, 0.003):
        serve_metrics.record_latency("pubcheck", v)
    published = serve_metrics.publish_percentiles()
    assert published["serve.latency.pubcheck.p50"] == pytest.approx(0.002)
    snap = obs_metrics.validate_snapshot(obs_metrics.snapshot())
    assert "serve.latency.pubcheck.p95" in snap["gauges"]
    summary = serve_metrics.percentiles("pubcheck")
    assert summary["count"] == 3 and summary["mean"] == pytest.approx(0.002)
    serve_metrics.reset()


# ---------------------------------------------------------------------------
# engine: the bitwise parity property suite


@pytest.fixture(scope="module")
def warm_server():
    server = Server(smoke_config())
    server.warm()
    return server


def _lstsq_ref(server, ticket):
    """The parity reference: per-request solve.lstsq under the request twin
    of the bucket plan (the published contract of the serving layer)."""
    from repro.solve import lstsq as solve_lstsq

    req = ticket.request
    m = req.a.shape[0]
    r = 1 if req.b.ndim == 1 else req.b.shape[-1]
    twin = server.request_twin(ticket.bucket, m, r)
    b2 = req.b[:, None] if req.b.ndim == 1 else req.b
    ref = np.asarray(solve_lstsq(req.a, b2, ridge=req.ridge, plan=twin))
    return ref[:, 0] if req.b.ndim == 1 else ref


def _whiten_ref(server, ticket):
    """Unbatched whiten pipeline: z = L⁻¹·v from the packed factor of the
    (ridge-shifted) gram, under the request twin."""
    import jax.numpy as jnp

    from repro.core.ata import ata
    from repro.solve.cholesky import cholesky
    from repro.solve.triangular import solve_triangular

    req = ticket.request
    sp = server.bucket_plan(ticket.bucket)
    twin = server.request_twin(ticket.bucket, req.a.shape[0],
                               req.b.shape[-1])
    ata_plan = dataclasses.replace(twin, op="ata", k=twin.n, out="packed",
                                   method=None, predicted_s=None)
    gram = ata(jnp.asarray(req.a, jnp.float32), plan=ata_plan, out="packed",
               packed_block=sp.packed_block)
    gram = gram.add_scaled_identity(jnp.float32(req.ridge))
    f = cholesky(gram, plan=twin)
    return np.asarray(solve_triangular(
        f, jnp.asarray(req.b, jnp.float32), transpose=False, plan=twin))


def test_mixed_workload_parity_is_bitwise(warm_server):
    """The headline property: ragged m/r, vector rhs, mixed ridges, both
    ops — every served slice bitwise-equals its per-request reference."""
    from repro.serve.__main__ import _mixed_workload, _run_workload

    served, rejected = _run_workload(warm_server, _mixed_workload(24, 11))
    assert rejected == 0 and all(t.done() for t in served)
    assert warm_server.retraces() == 0
    for t in served:
        ref = (_lstsq_ref if t.request.op == "lstsq" else _whiten_ref)(
            warm_server, t)
        np.testing.assert_array_equal(ref, np.asarray(t.result()),
                                      err_msg=t.bucket.label())


def test_parity_at_exact_bucket_edges(warm_server):
    """Requests at exact capacity (no padding) and one row/col inside it
    (maximal pad-then-crop) meet the same bitwise contract."""
    rng = np.random.default_rng(5)
    for m, r in [(48, 4), (47, 3), (96, 8), (33, 1)]:
        a = rng.standard_normal((m, 32)).astype(np.float32)
        b = rng.standard_normal((m, r)).astype(np.float32)
        t = warm_server.submit(Request(op="lstsq", a=a, b=b, ridge=1e-4))
        warm_server.drain()
        ref = _lstsq_ref(warm_server, t)
        np.testing.assert_array_equal(ref, np.asarray(t.result()),
                                      err_msg=f"m={m} r={r}")
        assert t.result().shape == (32, r)


def test_vector_rhs_roundtrip(warm_server):
    rng = np.random.default_rng(6)
    a = rng.standard_normal((40, 32)).astype(np.float32)
    b = rng.standard_normal((40,)).astype(np.float32)
    t = warm_server.submit(Request(op="lstsq", a=a, b=b))
    warm_server.drain()
    assert t.result().shape == (32,)                 # 1-D in, 1-D out
    np.testing.assert_array_equal(_lstsq_ref(warm_server, t),
                                  np.asarray(t.result()))


def test_float64_requests_share_the_contract():
    """An f64 bucket serves f64 payloads; parity stays bitwise because
    both paths share lstsq's f32 compute cast."""
    cfg = ServeConfig(
        buckets=(BucketSpec(op="lstsq", m=48, n=32, r=2, batch=2,
                            dtype="float64"),),
        capacity=8, max_wait_s=0.005)
    server = Server(cfg)
    server.warm()
    rng = np.random.default_rng(7)
    a = rng.standard_normal((40, 32))
    b = rng.standard_normal((40, 2))
    t = server.submit(Request(op="lstsq", a=a, b=b, ridge=1e-3))
    server.drain()
    np.testing.assert_array_equal(_lstsq_ref(server, t),
                                  np.asarray(t.result()))
    assert server.retraces() == 0


def test_ragged_flush_replicates_a_real_request(warm_server):
    """A lone request in a width-4 bucket flushes with 3 replicated fill
    slots (counted, cropped, never returned) and still matches its ref."""
    before = obs_metrics.get("serve.padded_slots")
    rng = np.random.default_rng(8)
    a = rng.standard_normal((40, 32)).astype(np.float32)
    b = rng.standard_normal((40, 2)).astype(np.float32)
    t = warm_server.submit(Request(op="lstsq", a=a, b=b))
    warm_server.drain()
    assert obs_metrics.get("serve.padded_slots") - before == 3
    np.testing.assert_array_equal(_lstsq_ref(warm_server, t),
                                  np.asarray(t.result()))


def test_warm_arms_the_retrace_floor(warm_server):
    assert warm_server.warmed
    for spec in warm_server.config.buckets:
        assert warm_server._trace_floor[spec] == 1   # one trace per bucket
    stats = warm_server.stats()
    assert set(stats["warm_seconds"]) == {s.label() for s in
                                          warm_server.config.buckets}


def test_retrace_assertion_raises_strict_counts_lenient(warm_server):
    start = obs_metrics.get("serve.retraces")
    spec = warm_server.config.buckets[0]
    fn, _ = warm_server.bucket_callable(spec)
    real_floor = warm_server._trace_floor[spec]
    warm_server._trace_floor[spec] = 0               # simulate a hot retrace
    with pytest.raises(RuntimeError, match="zero-retrace"):
        warm_server._assert_no_retrace(spec, fn)     # counts AND raises
    assert warm_server._trace_floor[spec] == real_floor  # floor self-heals
    lenient = Server(dataclasses.replace(warm_server.config,
                                         strict_retrace=False))
    lenient._plans = warm_server._plans
    lenient._fns = warm_server._fns
    lenient._trace_floor[spec] = 0
    before = obs_metrics.get("serve.retraces")
    lenient._assert_no_retrace(spec, fn)             # counts, no raise
    assert obs_metrics.get("serve.retraces") == before + real_floor
    # the counter is process-global: undo both simulated retraces so later
    # tests (and fresh servers) still see a clean steady state
    obs_metrics.inc("serve.retraces", start - obs_metrics.get("serve.retraces"))


def test_server_propagates_admission_rejects(warm_server):
    with pytest.raises(Rejected):
        warm_server.submit(_req(m=8, n=8, r=2))      # n=8 not in the lattice


def test_deadline_missed_is_flagged(warm_server):
    rng = np.random.default_rng(9)
    a = rng.standard_normal((40, 32)).astype(np.float32)
    b = rng.standard_normal((40, 2)).astype(np.float32)
    dl = warm_server.config.max_wait_s               # admissible, but tight
    t = warm_server.submit(Request(op="lstsq", a=a, b=b, deadline_s=dl))
    time.sleep(2 * dl)                               # age past the budget
    warm_server.drain()
    assert t.done() and t.deadline_missed
    assert t.latency_s > dl


# ---------------------------------------------------------------------------
# CLI + check harness

_TINY = ServeConfig(
    buckets=(BucketSpec(op="lstsq", m=48, n=32, r=4, batch=2),),
    capacity=8, max_wait_s=0.005)


def test_check_harness_run_serve_is_clean():
    from repro.check import harness

    report = harness.run_serve(config=_TINY, steady_batches=1)
    assert report.exit_code == 0
    labels = [a["label"] for a in report.artifacts]
    assert any(l.startswith("serve:lstsq") for l in labels)
    assert "serve:steady-state" in labels


def test_cli_smoke_gate(tmp_path):
    from repro.serve.__main__ import main

    out = tmp_path / "serve_report.json"
    assert main(["--smoke", "--requests", "16", "--out", str(out)]) == 0
    report = json.loads(out.read_text())
    assert report["schema"] == "repro.serve/v1"
    assert report["served"] == 16 and not report["failures"]
    assert report["parity_checked"] > 0
    assert report["stats"]["counters"].get("serve.retraces", 0) == 0
