"""Multi-device tests for the SPMD gram schedules.

The main pytest process sees a single CPU device (by design — see the
dry-run rules), so multi-device checks run in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.distributed import (
    ata_tile_parallel,
    choose_tiling,
    gemm_tn_colshard,
    tile_parallel_device_flops,
)


def _run_in_subprocess(script: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run(
        [sys.executable, "-c", script],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout


# --- single-device smoke (debuggable in-process) ---------------------------


def test_tile_parallel_single_device():
    mesh = jax.make_mesh((1,), ("model",))
    r = np.random.default_rng(0)
    a = jnp.asarray(r.standard_normal((96, 80)), dtype=jnp.float32)
    c = ata_tile_parallel(a, mesh, task_axis="model", n_base=32)
    np.testing.assert_allclose(c, a.T @ a, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c).T)


def test_tile_parallel_packed_single_device():
    """out='packed' returns a SymmetricMatrix whose to_dense() is bitwise
    the dense schedule's output (dense IS packed.to_dense() at the root)."""
    from repro.core.symmetric import SymmetricMatrix

    mesh = jax.make_mesh((1,), ("model",))
    r = np.random.default_rng(0)
    a = jnp.asarray(r.standard_normal((96, 80)), dtype=jnp.float32)
    c = ata_tile_parallel(a, mesh, task_axis="model", n_base=32)
    s = ata_tile_parallel(a, mesh, task_axis="model", n_base=32, out="packed")
    assert isinstance(s, SymmetricMatrix)
    np.testing.assert_array_equal(np.asarray(s.to_dense()), np.asarray(c))
    # alpha applies to the packed output too (documented contract)
    s2 = ata_tile_parallel(
        a, mesh, task_axis="model", n_base=32, out="packed", alpha=0.5
    )
    np.testing.assert_array_equal(
        np.asarray(s2.blocks), np.asarray(0.5 * s.blocks)
    )


def test_tile_parallel_packed_no_dense_intermediate():
    """The packed path's jaxpr must not materialize any dense (n, n)
    square — the whole point of packed retrieval. Runs the repro.check
    ``no-dense-square`` rule (its walker descends shard_map/cond bodies)
    with the shape set pinned by override — the tile schedule has no Plan
    object here."""
    from repro import check

    mesh = jax.make_mesh((1,), ("model",))
    n = 256  # aligned: w == packed bn == 128 → pure-slice retrieval
    a_abs = jax.ShapeDtypeStruct((128, n), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda a: ata_tile_parallel(
            a, mesh, task_axis="model", n_base=64, nb=2, out="packed"
        )
    )(a_abs)
    art = check.Artifact(label="tile:packed", jaxpr=jaxpr.jaxpr,
                         overrides={"forbidden_squares": {(n, n)}})
    report = check.run(art, rules=["no-dense-square"])
    assert not report.violations, report.summary()


class _StubMesh:
    """mesh.shape stand-in: the divisibility validations read only the axis
    sizes, which lets the >1-device error paths run on a 1-device host."""

    def __init__(self, shape):
        self.shape = shape


def test_tile_parallel_row_axis_must_divide_m():
    """row_axis sharding of m is validated up front (not an opaque
    shard_map failure): the row_axis size must divide m."""
    mesh = _StubMesh({"data": 2, "model": 1})
    with pytest.raises(ValueError, match=r"row_axis 'data' size 2 must divide m=97"):
        ata_tile_parallel(
            jnp.zeros((97, 64), jnp.float32), mesh,
            task_axis="model", row_axis="data", n_base=32, nb=2,
        )


def test_colshard_divisibility_messages():
    """Regression: the k % p_task check used to raise the inverted message
    'k={k} must divide task axis {p}'; the requirement runs the other way —
    the task axis size must divide k. row_axis divisibility of m is now
    validated the same way instead of failing opaquely inside shard_map."""
    from repro.core.distributed import gemm_tn_colshard

    mesh = _StubMesh({"data": 2, "model": 3})
    a = jnp.zeros((64, 32), jnp.float32)
    with pytest.raises(
        ValueError, match=r"task axis 'model' size 3 must divide k=16"
    ):
        gemm_tn_colshard(a, jnp.zeros((64, 16), jnp.float32), mesh,
                         task_axis="model")
    with pytest.raises(
        ValueError, match=r"row_axis 'data' size 2 must divide the contraction dim m=63"
    ):
        gemm_tn_colshard(
            jnp.zeros((63, 32), jnp.float32),
            jnp.zeros((63, 9), jnp.float32),
            mesh, task_axis="model", row_axis="data",
        )


def test_choose_tiling_properties():
    for n in [256, 1000, 4096]:
        for p in [1, 2, 4, 8, 16]:
            nb, w = choose_tiling(n, p)
            t = nb * (nb + 1) // 2
            assert t >= p
            assert nb * w >= n
            assert w % 8 == 0


def test_choose_tiling_covers_triangle_exactly_once_and_balanced():
    """Property sweep over a broad (n, p) grid: the tile enumeration covers
    the padded lower-triangle block grid exactly once, and the contiguous
    per-device split stays α-balanced (α = 1/2 → makespan ≤ 1.5·ideal;
    the waste-minimizing search actually achieves ≤ ~1.003 on this grid,
    asserted at 1.25 to leave headroom, not to weaken the α claim)."""
    import numpy as np

    for n in [128, 200, 777, 1000, 2048, 4096, 8192]:
        for p in [1, 2, 3, 5, 7, 8, 12, 16, 24, 32, 48, 64]:
            nb, w = choose_tiling(n, p)
            t_total = nb * (nb + 1) // 2
            # exactly-once coverage of the lower block triangle
            cover = np.zeros((nb, nb), dtype=int)
            for t in range(t_total):
                i = int((np.sqrt(8 * t + 1) - 1) // 2)
                if i * (i + 1) // 2 > t:
                    i -= 1
                j = t - i * (i + 1) // 2
                assert j <= i
                cover[i, j] += 1
            low = np.tril_indices(nb)
            assert (cover[low] == 1).all()
            assert np.triu(cover, 1).sum() == 0
            # α-balance of the uniform-tile split (t_per·p within 1.5·T)
            t_per = -(-t_total // p)
            assert t_per * p <= 1.25 * t_total


def test_masked_dummy_tiles_flop_model_matches_lpt():
    """Regression for the dummy-tile recompute: per-device flops of the
    masked schedule must sum to exactly T tiles' worth (the clamped seed
    recomputed tile T−1 up to t_per−1 extra times per device) and the
    makespan must equal the LPT makespan of T uniform tile tasks — checked
    on (nb, p) combinations with T % p != 0."""
    from repro.core.reference import classical_gemm_flops, strassen_tn_flops

    m, n = 256, 192
    for p, nb in [(8, 4), (3, 4), (7, 5), (4, 5)]:
        w = -(-(-(-n // nb)) // 8) * 8
        t_total = nb * (nb + 1) // 2
        assert t_total % p != 0, (p, nb)
        for use_strassen, n_base in [(True, 32), (False, None)]:
            per_dev = tile_parallel_device_flops(
                m, n, p, nb=nb, n_base=n_base, use_strassen=use_strassen
            )
            tile = (
                strassen_tn_flops(m, w, w, 32)
                if use_strassen
                else classical_gemm_flops(m, w, w)
            )
            assert len(per_dev) == p
            # no dummy recompute: total is exactly T tiles
            assert sum(per_dev) == t_total * tile
            # LPT of T uniform tasks: makespan = ceil(T/p) tiles
            assert max(per_dev) == -(-t_total // p) * tile
            # the clamped seed schedule would have computed this instead:
            assert sum(per_dev) < p * -(-t_total // p) * tile


# --- 8-device subprocess checks ---------------------------------------------

TILE_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import ata_tile_parallel
assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((8,), ("model",))
r = np.random.default_rng(0)
a = jnp.asarray(r.standard_normal((256, 192)), dtype=jnp.float32)
c = jax.jit(lambda a: ata_tile_parallel(a, mesh, task_axis="model", n_base=32))(a)
np.testing.assert_allclose(np.asarray(c), np.asarray(a.T @ a), rtol=1e-4, atol=1e-4)
assert (np.asarray(c) == np.asarray(c).T).all()
print("OK")
"""

TILE_2D_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import ata_tile_parallel
mesh = jax.make_mesh((2, 4), ("data", "model"))
r = np.random.default_rng(1)
a = jnp.asarray(r.standard_normal((128, 160)), dtype=jnp.float32)
a = jax.device_put(a, NamedSharding(mesh, P("data", None)))
f = jax.jit(lambda a: ata_tile_parallel(
    a, mesh, task_axis="model", row_axis="data", n_base=32))
c = f(a)
np.testing.assert_allclose(np.asarray(c), np.asarray(a.T @ a), rtol=1e-4, atol=1e-4)
# collective check: the psum reduces the packed tile stack, not dense (n,n)
from repro.analysis.hlo import compiled_text
hlo = compiled_text(f, a)
assert "all-reduce" in hlo or "all-gather" in hlo
print("OK")
"""

ROWSHARD_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core.distributed import gram_rowshard
mesh = jax.make_mesh((8,), ("data",))
r = np.random.default_rng(2)
a = jnp.asarray(r.standard_normal((512, 96)), dtype=jnp.float32)
f = jax.jit(shard_map(
    lambda x: gram_rowshard(x, "data", n_base=32),
    mesh=mesh, in_specs=(P("data", None),), out_specs=P(None, None)))
c = f(a)
np.testing.assert_allclose(np.asarray(c), np.asarray(a.T @ a), rtol=1e-4, atol=1e-4)
print("OK")
"""

COLSHARD_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import gemm_tn_colshard
mesh = jax.make_mesh((2, 4), ("data", "model"))
r = np.random.default_rng(3)
a = jnp.asarray(r.standard_normal((256, 96)), dtype=jnp.float32)
b = jnp.asarray(r.standard_normal((256, 64)), dtype=jnp.float32)
# replicated inputs, task axis only
c = jax.jit(lambda a, b: gemm_tn_colshard(a, b, mesh, task_axis="model", n_base=32))(a, b)
np.testing.assert_allclose(np.asarray(c), np.asarray(a.T @ b), rtol=1e-4, atol=1e-4)
# row-sharded contraction + psum
a2 = jax.device_put(a, NamedSharding(mesh, P("data", None)))
b2 = jax.device_put(b, NamedSharding(mesh, P("data", "model")))
c2 = jax.jit(lambda a, b: gemm_tn_colshard(
    a, b, mesh, task_axis="model", row_axis="data", n_base=32))(a2, b2)
np.testing.assert_allclose(np.asarray(c2), np.asarray(a.T @ b), rtol=1e-4, atol=1e-4)
print("OK")
"""


TILE_RAGGED_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import ata_tile_parallel
mesh = jax.make_mesh((8,), ("model",))
r = np.random.default_rng(4)
a = jnp.asarray(r.standard_normal((256, 192)), dtype=jnp.float32)
# nb=4 -> T=10 tiles over 8 devices: t_per=2, 6 dummy slots (devices 5-7
# fully dummy) -- the cond-masked path, not the clamp-recompute path.
c = jax.jit(lambda a: ata_tile_parallel(
    a, mesh, task_axis="model", nb=4, n_base=32))(a)
np.testing.assert_allclose(np.asarray(c), np.asarray(a.T @ a), rtol=1e-4, atol=1e-4)
assert (np.asarray(c) == np.asarray(c).T).all()
print("OK")
"""


TILE_PACKED_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import ata_tile_parallel
from repro.core.symmetric import SymmetricMatrix
from repro.core.reference import syrk_ref
assert len(jax.devices()) == 8
mesh = jax.make_mesh((8,), ("model",))
r = np.random.default_rng(5)
a = jnp.asarray(r.standard_normal((256, 192)), dtype=jnp.float32)
# nb=4 -> T=10 over 8 devices: T % p != 0 (dummy cond slots) AND w=48 is
# misaligned with the packed bn=96 grid -> the repack path.
for nb in (None, 4):
    dense = jax.jit(lambda a, nb=nb: ata_tile_parallel(
        a, mesh, task_axis="model", n_base=32, nb=nb))(a)
    packed = jax.jit(lambda a, nb=nb: ata_tile_parallel(
        a, mesh, task_axis="model", n_base=32, nb=nb, out="packed"))(a)
    assert isinstance(packed, SymmetricMatrix), type(packed)
    # bitwise parity with the dense schedule on the same tiling
    assert (np.asarray(packed.to_dense()) == np.asarray(dense)).all(), nb
    # and correctness vs the sequential reference
    ref = np.asarray(syrk_ref(a))
    np.testing.assert_allclose(np.asarray(dense), ref, rtol=1e-4, atol=1e-4)
print("OK")
"""

TILE_2D_PACKED_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import ata_tile_parallel
mesh = jax.make_mesh((2, 4), ("data", "model"))
r = np.random.default_rng(6)
a = jnp.asarray(r.standard_normal((128, 160)), dtype=jnp.float32)
a = jax.device_put(a, NamedSharding(mesh, P("data", None)))
f_dense = jax.jit(lambda a: ata_tile_parallel(
    a, mesh, task_axis="model", row_axis="data", n_base=32))
f_packed = jax.jit(lambda a: ata_tile_parallel(
    a, mesh, task_axis="model", row_axis="data", n_base=32, out="packed"))
dense, packed = f_dense(a), f_packed(a)
assert (np.asarray(packed.to_dense()) == np.asarray(dense)).all()
np.testing.assert_allclose(np.asarray(dense), np.asarray(a.T @ a),
                           rtol=1e-4, atol=1e-4)
print("OK")
"""

ROWSHARD_PACKED_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core.distributed import gram_rowshard
from repro.analysis.hlo import collective_bytes, compiled_text
mesh = jax.make_mesh((8,), ("data",))
r = np.random.default_rng(7)
a = jnp.asarray(r.standard_normal((512, 96)), dtype=jnp.float32)
fd = jax.jit(shard_map(
    lambda x: gram_rowshard(x, "data", n_base=32),
    mesh=mesh, in_specs=(P("data", None),), out_specs=P(None, None)))
# packed_block=24 -> a 4x4 packed grid (T=10 of 16 blocks): the psum moves
# T*bn^2 = 0.625*n^2 words; n=96 with the default 128-block would be a
# single block (no saving to observe)
fp = jax.jit(shard_map(
    lambda x: gram_rowshard(x, "data", n_base=32, out="packed",
                            packed_block=24),
    mesh=mesh, in_specs=(P("data", None),), out_specs=P(None, None, None)))
dense, packed = fd(a), fp(a)
np.testing.assert_allclose(np.asarray(packed.to_dense()), np.asarray(dense),
                           rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(dense), np.asarray(a.T @ a),
                           rtol=1e-4, atol=1e-4)
# the psum payload is the packed stack: T/nb^2 = 10/16 of the dense bytes
bd = sum(collective_bytes(compiled_text(fd, a)).values())
bp = sum(collective_bytes(compiled_text(fp, a)).values())
assert 0 < bp < 0.7 * bd, (bp, bd)
print("OK")
"""

TILE_BF16_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import ata_tile_parallel
mesh = jax.make_mesh((8,), ("model",))
r = np.random.default_rng(8)
a = jnp.asarray(r.standard_normal((128, 192)), dtype=jnp.bfloat16)
# nb=4 -> dummy cond slots on trailing devices; with a bf16 accumulation
# dtype the seed's hardcoded f32 zero tile made the cond branches disagree
# on dtype and fail to trace (regression for the eval_shape-derived dummy).
c = jax.jit(lambda a: ata_tile_parallel(
    a, mesh, task_axis="model", n_base=32, nb=4,
    acc_dtype=jnp.bfloat16))(a)
assert c.dtype == jnp.bfloat16, c.dtype
ref = np.asarray(a, np.float32).T @ np.asarray(a, np.float32)
np.testing.assert_allclose(np.asarray(c, np.float32), ref,
                           rtol=0.1, atol=2.0)
print("OK")
"""

FUSED_DISPATCH_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core.distributed import ata_tile_parallel, gemm_tn_colshard, gram_rowshard
mesh = jax.make_mesh((8,), ("model",))
r = np.random.default_rng(10)
a = jnp.asarray(r.standard_normal((256, 192)), dtype=jnp.float32)
# the per-device tile bodies inherit the fused dispatch: bitwise parity with
# the unrolled schedule on the same tiling (leaf_dispatch never changes
# values, only how the leaves reach the hardware)
mk = lambda ld: jax.jit(lambda a: ata_tile_parallel(
    a, mesh, task_axis="model", n_base=32, variant="strassen",
    leaf_dispatch=ld))
cu, cf = mk("unrolled")(a), mk("fused")(a)
assert (np.asarray(cu) == np.asarray(cf)).all()
np.testing.assert_allclose(np.asarray(cf), np.asarray(a.T @ a),
                           rtol=1e-4, atol=1e-4)
# colshard stripes through the fused per-device body
b = jnp.asarray(r.standard_normal((256, 64)), dtype=jnp.float32)
mkg = lambda ld: jax.jit(lambda a, b: gemm_tn_colshard(
    a, b, mesh, task_axis="model", n_base=32, variant="strassen",
    leaf_dispatch=ld))
gu, gf = mkg("unrolled")(a, b), mkg("fused")(a, b)
assert (np.asarray(gu) == np.asarray(gf)).all()
# rowshard: fused local gram under the packed psum
mesh2 = jax.make_mesh((8,), ("data",))
a2 = jnp.asarray(r.standard_normal((512, 96)), dtype=jnp.float32)
mkr = lambda ld: jax.jit(shard_map(
    lambda x: gram_rowshard(x, "data", n_base=32, variant="strassen",
                            leaf_dispatch=ld),
    mesh=mesh2, in_specs=(P("data", None),), out_specs=P(None, None)))
ru, rf = mkr("unrolled")(a2), mkr("fused")(a2)
assert (np.asarray(ru) == np.asarray(rf)).all()
print("OK")
"""

BFSDFS_PARITY_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import ata_bfs_dfs, ata_tile_parallel
from repro.core.symmetric import SymmetricMatrix
mesh = jax.make_mesh((2, 4), ("data", "model"))
r = np.random.default_rng(11)
a = jnp.asarray(r.standard_normal((256, 192)), dtype=jnp.float32)
a = jax.device_put(a, NamedSharding(mesh, P("data", None)))
# pin ONE (nb, packed_block) grid on both schedules: every interleaving is
# value-identical (the tri-direct scatter only adds zeros), so parity with
# the psum schedule is bitwise, not allclose. nb=4 -> T=10 over pool=8:
# t_pad=16, every device owns a padded chunk — the dummy-slot path too.
kw = dict(mesh=mesh, task_axis="model", row_axis="data", n_base=32, nb=4,
          packed_block=48)
dense0 = jax.jit(lambda a: ata_tile_parallel(a, **kw))(a)
packed0 = jax.jit(lambda a: ata_tile_parallel(a, out="packed", **kw))(a)
np.testing.assert_allclose(np.asarray(dense0), np.asarray(a.T @ a),
                           rtol=1e-4, atol=1e-4)
for il in ("D", "B", "BD", "DB"):
    dense = jax.jit(lambda a, il=il: ata_bfs_dfs(a, interleaving=il, **kw))(a)
    assert (np.asarray(dense) == np.asarray(dense0)).all(), il
    packed = jax.jit(lambda a, il=il: ata_bfs_dfs(
        a, interleaving=il, out="packed", **kw))(a)
    assert isinstance(packed, SymmetricMatrix), type(packed)
    assert (np.asarray(packed.to_dense())
            == np.asarray(packed0.to_dense())).all(), il
print("OK")
"""

BFSDFS_LEAF_DISPATCH_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import ata_bfs_dfs
mesh = jax.make_mesh((8,), ("model",))
r = np.random.default_rng(12)
a = jnp.asarray(r.standard_normal((256, 384)), dtype=jnp.float32)
# nb=4 -> w=96 > n_base: the per-tile Strassen actually recurses, so the
# three leaf bodies compile genuinely different programs — which must still
# agree bitwise (leaf_dispatch never changes values) under the BFS scatter
mk = lambda ld: jax.jit(lambda a: ata_bfs_dfs(
    a, mesh, task_axis="model", interleaving="B", n_base=32, nb=4,
    packed_block=96, variant="strassen", leaf_dispatch=ld))
cu, cb, cf = mk("unrolled")(a), mk("batched")(a), mk("fused")(a)
assert (np.asarray(cu) == np.asarray(cb)).all()
assert (np.asarray(cu) == np.asarray(cf)).all()
np.testing.assert_allclose(np.asarray(cf), np.asarray(a.T @ a),
                           rtol=1e-4, atol=1e-4)
print("OK")
"""

BFSDFS_PURE_DFS_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.analysis.hlo import collective_bytes, compiled_text
from repro.core.distributed import ata_bfs_dfs, ata_tile_parallel
mesh = jax.make_mesh((8,), ("model",))
r = np.random.default_rng(13)
a = jnp.asarray(r.standard_normal((256, 192)), dtype=jnp.float32)
# pure 'D' degenerates to the existing schedule: same default tiling
# (choose_tiling, not bfs_tiling), same plain psum, bitwise outputs AND an
# identical collective footprint — no scatter, no staging buffer
for out in ("dense", "packed"):
    fd = jax.jit(lambda a, out=out: ata_bfs_dfs(
        a, mesh, task_axis="model", interleaving="D", n_base=32, out=out))
    ft = jax.jit(lambda a, out=out: ata_tile_parallel(
        a, mesh, task_axis="model", n_base=32, out=out))
    cd, ct = fd(a), ft(a)
    if out == "packed":
        cd, ct = cd.to_dense(), ct.to_dense()
    assert (np.asarray(cd) == np.asarray(ct)).all(), out
    bd = collective_bytes(compiled_text(fd, a))
    bt = collective_bytes(compiled_text(ft, a))
    assert bd == bt, (out, bd, bt)
print("OK")
"""

BFSDFS_6DEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import ata_bfs_dfs, ata_tile_parallel
from repro.tune.cost import bfs_tiling
assert len(jax.devices()) == 6, jax.devices()
mesh = jax.make_mesh((6,), ("model",))
r = np.random.default_rng(14)
a = jnp.asarray(r.standard_normal((192, 160)), dtype=jnp.float32)
# pool=6 (neither a power of two nor 8): bfs_tiling must still hand back a
# pool-divisible triangle so the scatter chunks exactly
nb, w = bfs_tiling(160, 6, devices=6, out="packed")
assert (nb * (nb + 1) // 2) % 6 == 0, (nb, w)
kw = dict(mesh=mesh, task_axis="model", n_base=32, nb=nb, packed_block=w)
dense0 = jax.jit(lambda a: ata_tile_parallel(a, **kw))(a)
np.testing.assert_allclose(np.asarray(dense0), np.asarray(a.T @ a),
                           rtol=1e-4, atol=1e-4)
for il in ("B", "BD"):
    c = jax.jit(lambda a, il=il: ata_bfs_dfs(a, interleaving=il, **kw))(a)
    assert (np.asarray(c) == np.asarray(dense0)).all(), il
    pk = jax.jit(lambda a, il=il: ata_bfs_dfs(
        a, interleaving=il, out="packed", **kw))(a)
    assert (np.asarray(pk.to_dense()) == np.asarray(dense0)).all(), il
# a user-pinned ragged grid (T=10, 10 % 6 != 0) still scatters correctly:
# t_pad rounds up and the sacrificial row swallows the dummy ids
c2 = jax.jit(lambda a: ata_bfs_dfs(
    a, mesh, task_axis="model", n_base=32, nb=4, interleaving="B"))(a)
ct2 = jax.jit(lambda a: ata_tile_parallel(
    a, mesh, task_axis="model", n_base=32, nb=4))(a)
assert (np.asarray(c2) == np.asarray(ct2)).all()
print("OK")
"""

BFSDFS_RANKING_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.analysis.hlo import collective_bytes, compiled_text
from repro.core.distributed import ata_bfs_dfs, ata_tile_parallel, choose_tiling
from repro.tune import cost
# the alpha-beta comm model's per-mesh ranking (BFS tri-direct scatter vs
# psum) must match the measured collective-bytes ranking at every task
# width P of the 8-device pool — the calibration configuration of the
# collectives_bfsdfs bench rows. Wall clock on fake CPU devices is
# emulation noise (obs.calibrate's drift table shows >2x single-device
# drift), but the compiled collective payload is exact, so bytes are the
# honest comm measurement here. Only the per-mesh B-vs-psum ordering is
# contractual: cross-P psum bytes are non-monotone (GSPMD folds parts of
# the retrieval at some widths), which is exactly why the planner prices
# schedules per mesh instead of reusing one measurement.
m, n = 512, 1024
mach = cost.machine_for("cpu")
r = np.random.default_rng(15)
a0 = jnp.asarray(r.standard_normal((m, n)), dtype=jnp.float32)
for pt in (2, 4, 8):
    d = 8 // pt
    mesh = Mesh(np.asarray(jax.devices()).reshape(d, pt), ("data", "model"))
    a = jax.device_put(a0, NamedSharding(mesh, P("data", None)))
    ra = "data" if d > 1 else None
    nb_b, w_b = cost.bfs_tiling(n, 8, devices=pt, out="packed")
    nb_d, w_d = choose_tiling(n, pt, out="packed")
    model = {
        "B": cost.comm_seconds(mach, "B", nb_b, w_b, pt, d, out="packed"),
        "psum": cost.comm_seconds(mach, None, nb_d, w_d, pt, d,
                                  out="packed"),
    }
    fb = jax.jit(lambda a, nb=nb_b, w=w_b, ra=ra: ata_bfs_dfs(
        a, mesh, task_axis="model", row_axis=ra, interleaving="B",
        n_base=64, nb=nb, packed_block=w, out="packed"))
    fp = jax.jit(lambda a, nb=nb_d, ra=ra: ata_tile_parallel(
        a, mesh, task_axis="model", row_axis=ra, n_base=64, nb=nb,
        out="packed"))
    meas = {
        "B": sum(collective_bytes(compiled_text(fb, a)).values()),
        "psum": sum(collective_bytes(compiled_text(fp, a)).values()),
    }
    assert sorted(model, key=model.get) == sorted(meas, key=meas.get), \
        (pt, model, meas)
    assert model["B"] < model["psum"], (pt, model)
    assert meas["B"] < meas["psum"], (pt, meas)
print("OK")
"""

POWERSGD_SHARDED_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.optim import powersgd
mesh = jax.make_mesh((8,), ("data",))
r = np.random.default_rng(9)
m, n, rank = 256, 96, 8
g = jnp.asarray(r.standard_normal((m, n)), dtype=jnp.float32)
state = powersgd.init_state(jax.random.key(0), (m, n), rank)
# reference: single-device compress
p_ref, q_ref, st_ref = powersgd.compress(g, state, n_base=32)
# sharded: row-sharded g/error, packed-psum gram, psum'd Q factor
def sharded(g, err, q):
    st = powersgd.PowerSGDState(q=q, error=err)
    p_l, q_new, st_new = powersgd.compress_sharded(g, st, "data", n_base=32)
    return p_l, q_new, st_new.error
f = jax.jit(shard_map(
    sharded, mesh=mesh,
    in_specs=(P("data", None), P("data", None), P(None, None)),
    out_specs=(P("data", None), P(None, None), P("data", None))))
p_sh, q_sh, err_sh = f(g, state.error, state.q)
np.testing.assert_allclose(np.asarray(p_sh), np.asarray(p_ref),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(q_sh), np.asarray(q_ref),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(err_sh), np.asarray(st_ref.error),
                           rtol=2e-4, atol=2e-4)
print("OK")
"""


@pytest.mark.parametrize(
    "script",
    [TILE_SCRIPT, TILE_2D_SCRIPT, ROWSHARD_SCRIPT, COLSHARD_SCRIPT,
     TILE_RAGGED_SCRIPT, TILE_PACKED_SCRIPT, TILE_2D_PACKED_SCRIPT,
     ROWSHARD_PACKED_SCRIPT, TILE_BF16_SCRIPT, FUSED_DISPATCH_SCRIPT,
     BFSDFS_PARITY_SCRIPT, BFSDFS_LEAF_DISPATCH_SCRIPT,
     BFSDFS_PURE_DFS_SCRIPT, BFSDFS_RANKING_SCRIPT,
     POWERSGD_SHARDED_SCRIPT],
    ids=["tile_8dev", "tile_2d", "rowshard", "colshard", "tile_ragged",
         "tile_packed", "tile_2d_packed", "rowshard_packed", "tile_bf16",
         "fused_dispatch", "bfsdfs_parity", "bfsdfs_leaf_dispatch",
         "bfsdfs_pure_dfs", "bfsdfs_ranking", "powersgd_sharded"],
)
def test_multidevice(script):
    _run_in_subprocess(script)


def test_bfsdfs_six_devices():
    """BFS/DFS on a 6-device pool — not a power of two, not the 8 the other
    scripts assume: bfs_tiling's pool-divisible triangle, subgroup splits
    over {1,2,3,6}-device groups, and the ragged user-pinned grid."""
    _run_in_subprocess(BFSDFS_6DEV_SCRIPT, devices=6)


SP_DECODE_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.configs.registry import get_smoke
from repro.models import layers as L

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_smoke("command-r-plus-104b")  # GQA groups > 1
p = L.init_attn(jax.random.key(0), cfg)
rng = np.random.default_rng(0)
b, s_cache = 4, 32
ck = jnp.asarray(rng.standard_normal((b, s_cache, cfg.num_kv_heads, cfg.head_dim)), jnp.float32)
cv = jnp.asarray(rng.standard_normal((b, s_cache, cfg.num_kv_heads, cfg.head_dim)), jnp.float32)
pos = jnp.asarray([5, 9, 13, 31], jnp.int32)
x = jnp.asarray(rng.standard_normal((b, 1, cfg.d_model)), jnp.float32)
for window in (None, 7):
    ref_out, ref_ck, ref_cv = L.attention_decode(p, x, cfg, ck, cv, pos, window=window)
    sp_out, sp_ck, sp_cv = L.attention_decode_sp(p, x, cfg, ck, cv, pos, mesh, window=window)
    np.testing.assert_allclose(np.asarray(sp_out), np.asarray(ref_out), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sp_ck), np.asarray(ref_ck), rtol=1e-5, atol=1e-5)
print("OK")
"""


def test_seq_parallel_flash_decode():
    """shard_map flash-decode (seq-sharded cache, local slot write, psum
    softmax combine) must match the reference decode attention."""
    _run_in_subprocess(SP_DECODE_SCRIPT)


CP_ATTENTION_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.configs.registry import get_smoke
from repro.models import layers as L
from repro.models.transformer import forward_train, init

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_smoke("hymba-1.5b")
p = L.init_attn(jax.random.key(0), cfg)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)), jnp.float32)
for window in (None, 8):
    want = L.attention_train(p, x, cfg, window=window)
    got = L.attention_train_cp(p, x, cfg, mesh, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # return_kv path (prefill)
    got2, (k, v) = L.attention_train_cp(p, x, cfg, mesh, window=window,
                                        return_kv=True)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

# end-to-end hybrid forward: mesh (CP+p_major) vs no-mesh reference
params = init(jax.random.key(1), cfg)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32)
ref, _ = forward_train(params, {"tokens": tokens}, cfg, None,
                       compute_dtype=jnp.float32)
got, _ = forward_train(params, {"tokens": tokens}, cfg, mesh,
                       compute_dtype=jnp.float32)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=5e-3, atol=5e-3)
print("OK")
"""


def test_context_parallel_attention():
    """CP attention (q-seq over model, shard_map) must match the reference,
    including the full hymba forward with p_major SSD sharding."""
    _run_in_subprocess(CP_ATTENTION_SCRIPT)
