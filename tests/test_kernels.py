"""Shape/dtype sweeps for the Pallas kernels vs the pure-jnp oracles.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
the same code path compiles through Mosaic on a real TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gemm_tn, syrk
from repro.kernels.ref import gemm_tn_ref, syrk_ref

SHAPES_GEMM = [
    (8, 128, 128),
    (64, 128, 256),
    (256, 384, 128),
    (128, 256, 256),
    (40, 100, 60),     # unaligned — exercises padding
    (513, 257, 129),   # odd, > one block
    (1024, 512, 256),  # multi-block reduction
]

SHAPES_SYRK = [
    (8, 128),
    (64, 256),
    (256, 384),
    (40, 100),
    (513, 257),
    (1024, 512),
    (300, 700),
]

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("m,n,k", SHAPES_GEMM)
def test_gemm_tn_kernel_matches_ref(m, n, k, dtype):
    r = np.random.default_rng(hash((m, n, k)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)), dtype=dtype)
    b = jnp.asarray(r.standard_normal((m, k)), dtype=dtype)
    got = gemm_tn(a, b, blocks=(256, 128, 128), interpret=True)
    want = gemm_tn_ref(a, b)
    assert got.shape == (n, k)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("m,n", SHAPES_SYRK)
def test_syrk_kernel_matches_ref(m, n, dtype):
    r = np.random.default_rng(hash((m, n)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)), dtype=dtype)
    got = syrk(a, blocks=(256, 128), interpret=True)
    want = syrk_ref(a)
    assert got.shape == (n, n)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    # bitwise symmetry contract
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got).T)


def test_gemm_tn_alpha():
    r = np.random.default_rng(0)
    a = jnp.asarray(r.standard_normal((64, 128)), dtype=jnp.float32)
    b = jnp.asarray(r.standard_normal((64, 128)), dtype=jnp.float32)
    got = gemm_tn(a, b, alpha=-2.0, blocks=(64, 128, 128), interpret=True)
    np.testing.assert_allclose(got, -2.0 * (a.T @ b), rtol=1e-5, atol=1e-5)


def test_syrk_alpha():
    r = np.random.default_rng(1)
    a = jnp.asarray(r.standard_normal((64, 128)), dtype=jnp.float32)
    got = syrk(a, alpha=0.5, blocks=(64, 128), interpret=True)
    np.testing.assert_allclose(got, 0.5 * (a.T @ a), rtol=1e-5, atol=1e-5)


def test_ata_with_pallas_base():
    """End-to-end: the ATA recursion bottoming out in the Pallas kernels."""
    from repro.core import ata

    r = np.random.default_rng(2)
    a = jnp.asarray(r.standard_normal((512, 384)), dtype=jnp.float32)
    got = ata(
        a,
        n_base=128,
        base_syrk=lambda x: syrk(x, blocks=(128, 128), interpret=True),
        base_dot=lambda x, y: gemm_tn(x, y, blocks=(128, 128, 128), interpret=True),
    )
    np.testing.assert_allclose(got, a.T @ a, rtol=2e-4, atol=2e-4)


def test_strassen_with_pallas_base():
    from repro.core import strassen_tn

    r = np.random.default_rng(3)
    a = jnp.asarray(r.standard_normal((512, 256)), dtype=jnp.float32)
    b = jnp.asarray(r.standard_normal((512, 320)), dtype=jnp.float32)
    got = strassen_tn(
        a,
        b,
        n_base=128,
        base_dot=lambda x, y: gemm_tn(x, y, blocks=(128, 128, 128), interpret=True),
    )
    np.testing.assert_allclose(got, a.T @ b, rtol=2e-4, atol=2e-4)


def test_syrk_triangular_grid_only_lower_blocks():
    """The packed-grid index math must enumerate each lower block exactly once."""
    from repro.kernels.syrk import _tri_coords

    nb = 37
    seen = set()
    for t in range(nb * (nb + 1) // 2):
        i, j = _tri_coords(jnp.int32(t))
        i, j = int(i), int(j)
        assert 0 <= j <= i < nb
        seen.add((i, j))
    assert len(seen) == nb * (nb + 1) // 2


# ---------------------------------------------------------------------------
# packed / dual-write / batched output modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n", [(64, 256), (40, 100), (513, 257), (300, 700)])
def test_syrk_packed_mode_matches_dense_bitwise(m, n):
    """Packed output must reconstruct the dense dual-write output exactly,
    while allocating only the nb(nb+1)/2 lower blocks."""
    from repro.core import SymmetricMatrix

    r = np.random.default_rng(hash((m, n, "p")) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)), dtype=jnp.float32)
    dense = syrk(a, blocks=(256, 128), interpret=True)
    packed = syrk(a, blocks=(256, 128), interpret=True, out="packed")
    assert isinstance(packed, SymmetricMatrix)
    nb = packed.nb
    assert packed.blocks.shape == (nb * (nb + 1) // 2, packed.bn, packed.bn)
    np.testing.assert_array_equal(np.asarray(packed.to_dense()), np.asarray(dense))


def test_syrk_dual_write_no_mirror_postpass():
    """The dense mode's symmetry comes from the in-kernel dual write — the
    wrapper must contain no full-square transpose/mirror post-pass. Only
    tile-granular (≤ block) transposes inside the kernel body are allowed."""

    def wrapper_transposes(jaxpr, acc):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "transpose":
                acc.append(eqn.outvars[0].aval.shape)
            # descend into jit wrappers but NOT into the kernel body itself
            if eqn.primitive.name != "pallas_call":
                for v in eqn.params.values():
                    if hasattr(v, "jaxpr"):
                        wrapper_transposes(v.jaxpr, acc)
        return acc

    a = jnp.zeros((256, 256), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda x: syrk(x, blocks=(128, 128), interpret=True))(a)
    found = wrapper_transposes(jaxpr.jaxpr, [])
    assert found == [], f"wrapper reintroduced a mirror post-pass: {found}"


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_syrk_batched_one_launch(dtype):
    """(B, m, n) input runs through a leading batch grid dimension."""
    r = np.random.default_rng(9)
    a = jnp.asarray(r.standard_normal((3, 70, 200)), dtype=dtype)
    got = syrk(a, blocks=(64, 128), interpret=True)
    want = jnp.einsum(
        "bmi,bmj->bij", a.astype(jnp.float32), a.astype(jnp.float32)
    )
    assert got.shape == (3, 200, 200)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    for b in range(3):
        np.testing.assert_array_equal(np.asarray(got[b]), np.asarray(got[b]).T)
    packed = syrk(a, blocks=(64, 128), interpret=True, out="packed")
    assert packed.blocks.shape[0] == 3
    np.testing.assert_array_equal(np.asarray(packed.to_dense()), np.asarray(got))


def test_syrk_packed_layout_compatible_with_other_producers():
    """Packed kernel output must share the common block-size clamp so it can
    be accumulated against ata-packed results and zeros() state (and a small
    matrix is never padded up to a huge single block)."""
    from repro.core import SymmetricMatrix, ata

    r = np.random.default_rng(11)
    a = jnp.asarray(r.standard_normal((32, 64)), jnp.float32)
    p_syrk = syrk(a, interpret=True, out="packed")
    assert p_syrk.bn == 64 and p_syrk.nbytes <= 64 * 64 * 4
    p_ata = ata(a, n_base=256, out="packed", packed_block=128)
    acc = SymmetricMatrix.zeros(64, 128) + p_syrk + p_ata.astype(jnp.float32)
    np.testing.assert_allclose(
        acc.to_dense(), 2.0 * (a.T @ a), rtol=1e-4, atol=1e-4
    )


def test_ata_packed_with_pallas_packed_base():
    """End-to-end packed path: recursion + packed-capable Pallas base."""
    from repro.core import ata

    r = np.random.default_rng(10)
    a = jnp.asarray(r.standard_normal((256, 192)), dtype=jnp.float32)
    got = ata(
        a,
        n_base=128,
        out="packed",
        base_syrk=lambda x: syrk(x, blocks=(128, 128), interpret=True),
        base_dot=lambda x, y: gemm_tn(x, y, blocks=(128, 128, 128), interpret=True),
    )
    np.testing.assert_allclose(got.to_dense(), a.T @ a, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_gemm_tn_batched_one_launch(dtype):
    """(B, m, n) × (B, m, k) runs through a leading batch grid dimension —
    the batched-grid contract the batched-leaf recursion relies on."""
    r = np.random.default_rng(12)
    a = jnp.asarray(r.standard_normal((5, 70, 200)), dtype=dtype)
    b = jnp.asarray(r.standard_normal((5, 70, 130)), dtype=dtype)
    got = gemm_tn(a, b, blocks=(64, 128, 128), interpret=True)
    want = jnp.einsum(
        "bmn,bmk->bnk", a.astype(jnp.float32), b.astype(jnp.float32)
    )
    assert got.shape == (5, 200, 130)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    # per-slice agreement with the unbatched kernel (one grid, same math)
    one = gemm_tn(a[2], b[2], blocks=(64, 128, 128), interpret=True)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(one))


def test_gemm_tn_batched_shape_errors():
    a = jnp.zeros((2, 16, 8))
    with pytest.raises(ValueError):
        gemm_tn(a, jnp.zeros((3, 16, 8)), interpret=True)   # batch mismatch
    with pytest.raises(ValueError):
        gemm_tn(a, jnp.zeros((16, 8)), interpret=True)      # rank mismatch


def test_strassen_batched_leaves_with_pallas_base():
    """leaf_dispatch='batched' hands the Pallas kernel the whole leaf stack
    as its one leading batch dim — values match the unrolled kernel path
    bitwise (identical kernel, identical per-leaf grids)."""
    from functools import partial

    from repro.core import strassen_tn

    r = np.random.default_rng(13)
    a = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    b = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    base = partial(gemm_tn, blocks=(64, 64, 64), interpret=True)
    u = strassen_tn(a, b, n_base=64, base_dot=base, leaf_dispatch="unrolled")
    got = strassen_tn(a, b, n_base=64, base_dot=base, leaf_dispatch="batched")
    np.testing.assert_array_equal(np.asarray(u), np.asarray(got))
    # f32 + one Strassen level: looser than the plain-kernel sweeps above
    np.testing.assert_allclose(got, gemm_tn_ref(a, b), rtol=1e-3, atol=1e-3)
