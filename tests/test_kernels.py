"""Shape/dtype sweeps for the Pallas kernels vs the pure-jnp oracles.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
the same code path compiles through Mosaic on a real TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gemm_tn, syrk
from repro.kernels.ref import gemm_tn_ref, syrk_ref

SHAPES_GEMM = [
    (8, 128, 128),
    (64, 128, 256),
    (256, 384, 128),
    (128, 256, 256),
    (40, 100, 60),     # unaligned — exercises padding
    (513, 257, 129),   # odd, > one block
    (1024, 512, 256),  # multi-block reduction
]

SHAPES_SYRK = [
    (8, 128),
    (64, 256),
    (256, 384),
    (40, 100),
    (513, 257),
    (1024, 512),
    (300, 700),
]

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("m,n,k", SHAPES_GEMM)
def test_gemm_tn_kernel_matches_ref(m, n, k, dtype):
    r = np.random.default_rng(hash((m, n, k)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)), dtype=dtype)
    b = jnp.asarray(r.standard_normal((m, k)), dtype=dtype)
    got = gemm_tn(a, b, blocks=(256, 128, 128), interpret=True)
    want = gemm_tn_ref(a, b)
    assert got.shape == (n, k)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("m,n", SHAPES_SYRK)
def test_syrk_kernel_matches_ref(m, n, dtype):
    r = np.random.default_rng(hash((m, n)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)), dtype=dtype)
    got = syrk(a, blocks=(256, 128), interpret=True)
    want = syrk_ref(a)
    assert got.shape == (n, n)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    # bitwise symmetry contract
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got).T)


def test_gemm_tn_alpha():
    r = np.random.default_rng(0)
    a = jnp.asarray(r.standard_normal((64, 128)), dtype=jnp.float32)
    b = jnp.asarray(r.standard_normal((64, 128)), dtype=jnp.float32)
    got = gemm_tn(a, b, alpha=-2.0, blocks=(64, 128, 128), interpret=True)
    np.testing.assert_allclose(got, -2.0 * (a.T @ b), rtol=1e-5, atol=1e-5)


def test_syrk_alpha():
    r = np.random.default_rng(1)
    a = jnp.asarray(r.standard_normal((64, 128)), dtype=jnp.float32)
    got = syrk(a, alpha=0.5, blocks=(64, 128), interpret=True)
    np.testing.assert_allclose(got, 0.5 * (a.T @ a), rtol=1e-5, atol=1e-5)


def test_ata_with_pallas_base():
    """End-to-end: the ATA recursion bottoming out in the Pallas kernels."""
    from repro.core import ata

    r = np.random.default_rng(2)
    a = jnp.asarray(r.standard_normal((512, 384)), dtype=jnp.float32)
    got = ata(
        a,
        n_base=128,
        base_syrk=lambda x: syrk(x, blocks=(128, 128), interpret=True),
        base_dot=lambda x, y: gemm_tn(x, y, blocks=(128, 128, 128), interpret=True),
    )
    np.testing.assert_allclose(got, a.T @ a, rtol=2e-4, atol=2e-4)


def test_strassen_with_pallas_base():
    from repro.core import strassen_tn

    r = np.random.default_rng(3)
    a = jnp.asarray(r.standard_normal((512, 256)), dtype=jnp.float32)
    b = jnp.asarray(r.standard_normal((512, 320)), dtype=jnp.float32)
    got = strassen_tn(
        a,
        b,
        n_base=128,
        base_dot=lambda x, y: gemm_tn(x, y, blocks=(128, 128, 128), interpret=True),
    )
    np.testing.assert_allclose(got, a.T @ b, rtol=2e-4, atol=2e-4)


def test_syrk_triangular_grid_only_lower_blocks():
    """The packed-grid index math must enumerate each lower block exactly once."""
    from repro.kernels.syrk import _tri_coords

    nb = 37
    seen = set()
    for t in range(nb * (nb + 1) // 2):
        i, j = _tri_coords(jnp.int32(t))
        i, j = int(i), int(j)
        assert 0 <= j <= i < nb
        seen.add((i, j))
    assert len(seen) == nb * (nb + 1) // 2


# ---------------------------------------------------------------------------
# packed / dual-write / batched output modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n", [(64, 256), (40, 100), (513, 257), (300, 700)])
def test_syrk_packed_mode_matches_dense_bitwise(m, n):
    """Packed output must reconstruct the dense dual-write output exactly,
    while allocating only the nb(nb+1)/2 lower blocks."""
    from repro.core import SymmetricMatrix

    r = np.random.default_rng(hash((m, n, "p")) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)), dtype=jnp.float32)
    dense = syrk(a, blocks=(256, 128), interpret=True)
    packed = syrk(a, blocks=(256, 128), interpret=True, out="packed")
    assert isinstance(packed, SymmetricMatrix)
    nb = packed.nb
    assert packed.blocks.shape == (nb * (nb + 1) // 2, packed.bn, packed.bn)
    np.testing.assert_array_equal(np.asarray(packed.to_dense()), np.asarray(dense))


def test_syrk_dual_write_no_mirror_postpass():
    """The dense mode's symmetry comes from the in-kernel dual write — the
    wrapper must contain no full-square transpose/mirror post-pass. Only
    tile-granular (≤ block) transposes inside the kernel body are allowed.
    The repro.check ``no-full-transpose`` walker is pallas-opaque by
    default (kernel-body mirrors ARE the base-case symmetry contract), so
    ``max_transpose_dim=0`` makes it flag ANY wrapper-level 2-D transpose."""
    from repro import check

    a = jnp.zeros((256, 256), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda x: syrk(x, blocks=(128, 128), interpret=True))(a)
    art = check.Artifact(
        label="kernels:syrk-dual-write", jaxpr=jaxpr.jaxpr,
        overrides={"max_transpose_dim": 0, "mirror_budget": 0})
    report = check.run(art, rules=["no-full-transpose"])
    assert not report.violations, (
        f"wrapper reintroduced a mirror post-pass: {report.summary()}")


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_syrk_batched_one_launch(dtype):
    """(B, m, n) input runs through a leading batch grid dimension."""
    r = np.random.default_rng(9)
    a = jnp.asarray(r.standard_normal((3, 70, 200)), dtype=dtype)
    got = syrk(a, blocks=(64, 128), interpret=True)
    want = jnp.einsum(
        "bmi,bmj->bij", a.astype(jnp.float32), a.astype(jnp.float32)
    )
    assert got.shape == (3, 200, 200)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    for b in range(3):
        np.testing.assert_array_equal(np.asarray(got[b]), np.asarray(got[b]).T)
    packed = syrk(a, blocks=(64, 128), interpret=True, out="packed")
    assert packed.blocks.shape[0] == 3
    np.testing.assert_array_equal(np.asarray(packed.to_dense()), np.asarray(got))


def test_syrk_packed_layout_compatible_with_other_producers():
    """Packed kernel output must share the common block-size clamp so it can
    be accumulated against ata-packed results and zeros() state (and a small
    matrix is never padded up to a huge single block)."""
    from repro.core import SymmetricMatrix, ata

    r = np.random.default_rng(11)
    a = jnp.asarray(r.standard_normal((32, 64)), jnp.float32)
    p_syrk = syrk(a, interpret=True, out="packed")
    assert p_syrk.bn == 64 and p_syrk.nbytes <= 64 * 64 * 4
    p_ata = ata(a, n_base=256, out="packed", packed_block=128)
    acc = SymmetricMatrix.zeros(64, 128) + p_syrk + p_ata.astype(jnp.float32)
    np.testing.assert_allclose(
        acc.to_dense(), 2.0 * (a.T @ a), rtol=1e-4, atol=1e-4
    )


def test_ata_packed_with_pallas_packed_base():
    """End-to-end packed path: recursion + packed-capable Pallas base."""
    from repro.core import ata

    r = np.random.default_rng(10)
    a = jnp.asarray(r.standard_normal((256, 192)), dtype=jnp.float32)
    got = ata(
        a,
        n_base=128,
        out="packed",
        base_syrk=lambda x: syrk(x, blocks=(128, 128), interpret=True),
        base_dot=lambda x, y: gemm_tn(x, y, blocks=(128, 128, 128), interpret=True),
    )
    np.testing.assert_allclose(got.to_dense(), a.T @ a, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_gemm_tn_batched_one_launch(dtype):
    """(B, m, n) × (B, m, k) runs through a leading batch grid dimension —
    the batched-grid contract the batched-leaf recursion relies on."""
    r = np.random.default_rng(12)
    a = jnp.asarray(r.standard_normal((5, 70, 200)), dtype=dtype)
    b = jnp.asarray(r.standard_normal((5, 70, 130)), dtype=dtype)
    got = gemm_tn(a, b, blocks=(64, 128, 128), interpret=True)
    want = jnp.einsum(
        "bmn,bmk->bnk", a.astype(jnp.float32), b.astype(jnp.float32)
    )
    assert got.shape == (5, 200, 130)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    # per-slice agreement with the unbatched kernel (one grid, same math)
    one = gemm_tn(a[2], b[2], blocks=(64, 128, 128), interpret=True)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(one))


def test_gemm_tn_batched_shape_errors():
    a = jnp.zeros((2, 16, 8))
    with pytest.raises(ValueError):
        gemm_tn(a, jnp.zeros((3, 16, 8)), interpret=True)   # batch mismatch
    with pytest.raises(ValueError):
        gemm_tn(a, jnp.zeros((16, 8)), interpret=True)      # rank mismatch


def test_strassen_batched_leaves_with_pallas_base():
    """leaf_dispatch='batched' hands the Pallas kernel the whole leaf stack
    as its one leading batch dim — values match the unrolled kernel path
    bitwise (identical kernel, identical per-leaf grids)."""
    from functools import partial

    from repro.core import strassen_tn

    r = np.random.default_rng(13)
    a = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    b = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    base = partial(gemm_tn, blocks=(64, 64, 64), interpret=True)
    u = strassen_tn(a, b, n_base=64, base_dot=base, leaf_dispatch="unrolled")
    got = strassen_tn(a, b, n_base=64, base_dot=base, leaf_dispatch="batched")
    np.testing.assert_array_equal(np.asarray(u), np.asarray(got))
    # f32 + one Strassen level: looser than the plain-kernel sweeps above
    np.testing.assert_allclose(got, gemm_tn_ref(a, b), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# fused-operand leaves (the coefficient-table contract)
# ---------------------------------------------------------------------------


def _slot_path(a, b, L, blocks):
    """The XLA half of the fused contract: per-leaf trace-time slot gather
    (`_combine_slots`' balanced ± tree) + the SAME unbatched blocked kernel
    per leaf. The fused launch must match it bitwise — identical chunk
    dots, identical add-tree association, signs applied in-kernel."""
    from repro.core.strassen import _block_getter, _combine_slots, _slot_tables

    (ar, ac, asg), (br, bc, bsg) = _slot_tables(L)
    ga, gb = _block_getter(a, L), _block_getter(b, L)
    return jnp.stack([
        gemm_tn(
            _combine_slots(ga, ar[t], ac[t], asg[t]),
            _combine_slots(gb, br[t], bc[t], bsg[t]),
            blocks=blocks, interpret=True,
        )
        for t in range(ar.shape[0])
    ])


@pytest.mark.parametrize(
    "m,n,k,L",
    [
        (256, 192, 128, 1),
        (67, 53, 41, 1),    # odd everywhere -> root pad, cropped leaves
        (96, 96, 96, 2),    # two levels: 49 leaves, one launch
    ],
)
def test_gemm_tn_fused_bitwise_vs_slot_gather(m, n, k, L):
    from repro.core.strassen import _pad_root, _slot_tables, _to_blocks
    from repro.kernels import ops

    r = np.random.default_rng(hash((m, n, k, L)) % 2**32)
    a = _pad_root(jnp.asarray(r.standard_normal((m, n)), jnp.float32), L)
    b = _pad_root(jnp.asarray(r.standard_normal((m, k)), jnp.float32), L)
    blocks = (64, 64, 64)
    want = _slot_path(a, b, L, blocks)
    got = ops.gemm_tn_fused(
        _to_blocks(a, L)[None], _to_blocks(b, L)[None], _slot_tables(L),
        blocks=blocks, interpret=True,
    )
    assert got.shape == want.shape and got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("B", [1, 3])
def test_gemm_tn_fused_batched_grid(B):
    """An operand batch dim rides the grid like everything else — including
    the B=1 degenerate leading dim the level-synchronous ATA tree emits."""
    from repro.core.strassen import _pad_root, _slot_tables, _to_blocks
    from repro.kernels import ops

    r = np.random.default_rng(20 + B)
    a = _pad_root(jnp.asarray(r.standard_normal((B, 128, 96)), jnp.float32), 1)
    b = _pad_root(jnp.asarray(r.standard_normal((B, 128, 64)), jnp.float32), 1)
    blocks = (64, 64, 64)
    want = _slot_path(a, b, 1, blocks)  # (7, B, n/2, k/2): leaf-major stack
    got = ops.gemm_tn_fused(
        _to_blocks(a, 1)[None], _to_blocks(b, 1)[None], _slot_tables(1),
        blocks=blocks, interpret=True,
    )
    assert got.shape == (7, B, 48, 32)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    # B=1 slices agree with the unbatched launch (same grids, same math)
    one = ops.gemm_tn_fused(
        _to_blocks(a[0], 1)[None], _to_blocks(b[0], 1)[None], _slot_tables(1),
        blocks=blocks, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(got[:, 0]), np.asarray(one))


def test_gemm_tn_fused_bf16_storage_f32_accumulate():
    """bf16 operand blocks, f32 accumulation. Bitwise parity with the
    trace-time gather is an f32/f64 property only: the slot path rounds its
    bf16 combine at the pallas-call input boundary, while the in-kernel
    combine feeds the dot inside one XLA computation, where float
    normalization may (and on CPU does) keep the bf16 adds at f32 precision
    — strictly *more* accurate, never bitwise. So bf16 asserts the combine
    is at least operand-precision against the f32 oracle, plus the flush
    cast contract."""
    from repro.core.strassen import _pad_root, _slot_tables, _to_blocks
    from repro.kernels import ops

    r = np.random.default_rng(30)
    a = _pad_root(jnp.asarray(r.standard_normal((128, 96)), jnp.bfloat16), 1)
    b = _pad_root(jnp.asarray(r.standard_normal((128, 64)), jnp.bfloat16), 1)
    blocks = (64, 64, 64)
    got = ops.gemm_tn_fused(
        _to_blocks(a, 1)[None], _to_blocks(b, 1)[None], _slot_tables(1),
        blocks=blocks, interpret=True,
    )
    assert got.dtype == jnp.float32
    want = _slot_path(
        a.astype(jnp.float32), b.astype(jnp.float32), 1, blocks
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-1)
    # and the slot path in bf16 lands within the same band of the oracle
    slot = _slot_path(a, b, 1, blocks)
    np.testing.assert_allclose(np.asarray(slot), np.asarray(want),
                               rtol=2e-2, atol=2e-1)
    lo = ops.gemm_tn_fused(
        _to_blocks(a, 1)[None], _to_blocks(b, 1)[None], _slot_tables(1),
        blocks=blocks, interpret=True, out_dtype=jnp.bfloat16,
    )
    assert lo.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(lo, np.float32), np.asarray(got), rtol=2e-2, atol=2e-1
    )


@pytest.mark.parametrize("L", [1, 2])
def test_syrk_gather_bitwise_vs_stacked_syrk(L):
    """The diagonal half of the contract: gathering leaf pairs through the
    index maps equals stacking them first and running the batched syrk —
    the stack is simply never built."""
    from repro.core.strassen import _to_blocks
    from repro.kernels import ops

    r = np.random.default_rng(40 + L)
    a = jnp.asarray(r.standard_normal((256, 256)), jnp.float32)
    ab = _to_blocks(a, L)
    R = 1 << L
    s = np.arange(R * R, dtype=np.int32)
    stacked = jnp.swapaxes(ab, 0, 1).reshape(R * R, *ab.shape[-2:])
    want = syrk(stacked, blocks=(128, 64), interpret=True)
    got = ops.syrk_gather(ab, s % R, s // R, blocks=(128, 64), interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_syrk_gather_batched_grid():
    from repro.core.strassen import _to_blocks
    from repro.kernels import ops

    r = np.random.default_rng(42)
    a = jnp.asarray(r.standard_normal((2, 128, 128)), jnp.float32)
    ab = _to_blocks(a, 1)
    s = np.arange(4, dtype=np.int32)
    stacked = jnp.swapaxes(ab, 0, 1).reshape(4, 2, *ab.shape[-2:])
    want = syrk(
        stacked.reshape(-1, *ab.shape[-2:]), blocks=(64, 64), interpret=True
    ).reshape(4, 2, ab.shape[-1], ab.shape[-1])
    got = ops.syrk_gather(ab, s % 2, s // 2, blocks=(64, 64), interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_fused_leaves_with_pallas_kernels_end_to_end():
    """A use_kernels fused plan runs the whole recursion through ONE fused
    launch per level — bitwise with the unrolled kernel dispatch on the
    same plan, for strassen_tn and both ata output modes (odd shapes)."""
    import dataclasses

    from repro.core import ata, strassen_tn
    from repro.tune import cost

    r = np.random.default_rng(50)

    def mk(op, m, n, k, ld, **kw):
        return dataclasses.replace(
            cost.default_plan(op, m, n, k), algorithm="strassen", n_base=64,
            use_kernels=True, leaf_dispatch=ld, **kw,
        )

    a = jnp.asarray(r.standard_normal((300, 260)), jnp.float32)
    du = ata(a, plan=mk("ata", 300, 260, None, "unrolled"))
    df = ata(a, plan=mk("ata", 300, 260, None, "fused"))
    np.testing.assert_array_equal(np.asarray(du), np.asarray(df))
    np.testing.assert_allclose(df, a.T @ a, rtol=2e-4, atol=2e-4)
    pf = ata(a, plan=mk("ata", 300, 260, None, "fused", out="packed"),
             out="packed")
    np.testing.assert_array_equal(np.asarray(pf.to_dense()), np.asarray(du))

    b = jnp.asarray(r.standard_normal((300, 200)), jnp.float32)
    gu = strassen_tn(a, b, plan=mk("gemm_tn", 300, 260, 200, "unrolled"))
    gf = strassen_tn(a, b, plan=mk("gemm_tn", 300, 260, 200, "fused"))
    np.testing.assert_array_equal(np.asarray(gu), np.asarray(gf))
    np.testing.assert_allclose(gf, a.T @ b, rtol=2e-4, atol=2e-4)
