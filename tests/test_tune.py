"""Tests for the repro.tune planning subsystem.

Covers the acceptance contract of the tune PR: plan determinism for a given
cache state, JSON cache round-tripping, out-invariant algorithm choice
(packed results stay bitwise equal to dense under default planning), the
measured autotuner always sweeping the hardcoded default, and the consumers
actually honoring a Plan.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import ata, strassen_tn
from repro.core.reference import ata_flops, strassen_tn_flops
from repro.tune import cost, defaults
from repro.tune.cache import load_cache, plan_key, save_cache


@pytest.fixture(autouse=True)
def _fresh_memo(tmp_path, monkeypatch):
    """Isolate every test from the user-level cache file and the memo."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "plans.json"))
    tune.cache.clear_memo()
    yield
    tune.cache.clear_memo()


# --- cost model -------------------------------------------------------------


def test_analytic_plan_basic_sanity():
    p = tune.plan(op="ata", m=2048, n=2048)
    assert p.op == "ata" and p.k == 2048
    assert p.algorithm in ("dense", "strassen", "winograd")
    assert p.n_base in defaults.N_BASE_CANDIDATES
    assert p.predicted_s > 0
    assert p.source == "analytic"
    # CPU container: no native Pallas
    assert p.backend != "tpu" or p.use_kernels


def test_cost_model_prefers_recursion_at_scale():
    """The paper's claim must survive the model: at large n the ATA
    recursion beats one classical dot on every backend model."""
    for backend in ("cpu", "tpu"):
        p = cost.analytic_plan("ata", 8192, 8192, backend=backend)
        assert p.algorithm != "dense", backend


def test_cost_model_degenerates_to_dense_dispatch_for_tiny_shapes():
    """Tiny problems must not pay recursion overhead: either an explicit
    dense plan or a cutoff at least the matrix size (same dispatch)."""
    p = cost.analytic_plan("ata", 64, 64, backend="cpu")
    assert p.algorithm == "dense" or p.n_base >= 64


def test_predicted_seconds_monotone_in_problem_size():
    small = cost.analytic_plan("ata", 512, 512).predicted_s
    big = cost.analytic_plan("ata", 4096, 4096).predicted_s
    assert big > small


def test_flop_split_matches_reference_totals():
    """mult + add == the exact reference counters, for both ops."""
    for algo in ("strassen", "winograd"):
        mult, adds = cost._flop_split("ata", algo, 1024, 768, 768, 128)
        total = ata_flops(1024, 768, 128, winograd=algo == "winograd")
        assert mult + adds == total
        mult, adds = cost._flop_split("gemm_tn", algo, 512, 384, 256, 64)
        if algo == "strassen":
            assert mult + adds == strassen_tn_flops(512, 384, 256, 64)


def test_out_invariant_algorithm_choice():
    """Packed and dense plans of one problem must dispatch identically, so
    packed output stays bitwise equal to dense regardless of cache state."""
    for m, n in [(300, 200), (1024, 1024), (4096, 512)]:
        pd = tune.plan(op="ata", m=m, n=n, out="dense")
        pp = tune.plan(op="ata", m=m, n=n, out="packed")
        assert (pd.algorithm, pd.n_base) == (pp.algorithm, pp.n_base)


# --- cache ------------------------------------------------------------------


def test_plan_deterministic_for_fixed_cache_state():
    p1 = tune.plan(op="ata", m=1024, n=512)
    tune.cache.clear_memo()  # force a re-resolution from the same state
    p2 = tune.plan(op="ata", m=1024, n=512)
    assert p1 == p2


def test_plan_json_roundtrip(tmp_path):
    p = tune.plan(op="ata", m=777, n=333, out="packed")
    d = json.loads(json.dumps(p.to_json()))
    assert cost.Plan.from_json(d) == p

    path = str(tmp_path / "c.json")
    key = plan_key("ata", 777, 333, 333, 0, "float32", "packed", p.backend)
    save_cache({key: dataclasses.replace(p, source="measured")}, path)
    loaded = load_cache(path)
    assert loaded[key] == dataclasses.replace(p, source="measured")


def test_measured_cache_entry_is_served(tmp_path):
    """A persisted measured plan must shadow the analytic model (that is
    the point of the cache) and survive the JSON round trip."""
    path = str(tmp_path / "c.json")
    analytic = tune.plan(op="ata", m=640, n=640, cache_file=path)
    fake = dataclasses.replace(
        analytic, n_base=128, source="measured", measured_s=1e-3
    )
    key = plan_key("ata", 640, 640, 640, 0, "float32", "dense", analytic.backend)
    save_cache({key: fake}, path)
    tune.cache.clear_memo()
    served = tune.plan(op="ata", m=640, n=640, cache_file=path)
    assert served.n_base == 128 and served.source == "cache"


def test_corrupt_cache_file_falls_back_to_analytic(tmp_path):
    path = str(tmp_path / "broken.json")
    with open(path, "w") as f:
        f.write("{not json")
    p = tune.plan(op="ata", m=512, n=256, cache_file=path)
    assert p.source == "analytic"


def test_corrupt_cache_entries_are_skipped_not_fatal(tmp_path):
    """Regression: a hand-edited or truncated *entry* (KeyError on a missing
    field, ValueError on a non-dict value, TypeError on schema drift) must
    be skipped by load_cache, not crash every planned dispatch."""
    path = str(tmp_path / "edited.json")
    good = dataclasses.replace(
        tune.plan(op="ata", m=640, n=320), source="measured"
    )
    key_good = plan_key("ata", 640, 320, 320, 0, "float32", "dense", good.backend)
    payload = {
        "schema": "v1",
        "plans": {
            key_good: good.to_json(),
            "k_truncated": {"op": "ata", "m": 1, "n": 1},       # KeyError
            "k_not_a_dict": "garbage string entry",             # ValueError
            "k_schema_drift": dict(good.to_json(), bogus=1),    # TypeError
        },
    }
    with open(path, "w") as f:
        json.dump(payload, f)
    loaded = load_cache(path)
    assert set(loaded) == {key_good}
    assert loaded[key_good] == good
    # and the front door serves the surviving measured entry
    tune.cache.clear_memo()
    served = tune.plan(op="ata", m=640, n=320, cache_file=path)
    assert served.source == "cache"


def test_old_schema_cache_files_still_load_and_serve(tmp_path):
    """Regression (schema bumps v1→v2 op='solve', v2→v3 fused leaves,
    v3→v4 comm_schedule): an old cache file — old schema tag,
    old-prefixed keys, Plan entries WITHOUT later fields — must keep
    loading and serving its measured plans (same tolerance contract as
    the corrupt-entry fix: never fatal)."""
    key_now = plan_key("ata", 640, 640, 640, 0, "float32", "dense", "cpu")
    assert key_now.startswith("v4|")
    for old in ("v1", "v2", "v3"):
        path = str(tmp_path / f"{old}.json")
        p = dataclasses.replace(
            tune.plan(op="ata", m=640, n=640), n_base=128,
            source="measured", measured_s=1e-3,
        )
        key_old = old + "|" + key_now.split("|", 1)[1]
        # pre-v4 keys had no row-devices segment either
        key_old = key_old.replace("|r=1", "")
        entry = p.to_json()
        del entry["comm_schedule"]  # the fields did not exist pre-v4
        del entry["row_devices"]
        if old == "v1":
            del entry["method"]  # the field did not exist pre-PR-5
        with open(path, "w") as f:
            json.dump({"schema": old, "plans": {key_old: entry}}, f)

        loaded = load_cache(path)
        # the old key migrates to the current prefix (r=1 inserted),
        # missing fields default
        assert set(loaded) == {key_now}
        if old == "v1":
            assert loaded[key_now].method is None
        assert loaded[key_now].comm_schedule is None
        assert loaded[key_now].n_base == 128

        tune.cache.clear_memo()
        served = tune.plan(op="ata", m=640, n=640, cache_file=path)
        assert served.source == "cache" and served.n_base == 128


def test_unknown_leaf_dispatch_in_cache_falls_back_to_unrolled(tmp_path):
    """Regression (fused-leaf PR hardening): a cache entry written by a
    *future* schema may carry a leaf_dispatch this revision has never heard
    of. Loading must sanitize it to 'unrolled' (always valid, bitwise-
    identical output), not raise at every planned dispatch — the same
    never-fatal contract as the corrupt-entry tolerance."""
    path = str(tmp_path / "future.json")
    p = dataclasses.replace(
        tune.plan(op="ata", m=640, n=640), n_base=256,
        leaf_dispatch="hypercube", source="measured", measured_s=1e-3,
    )
    key = plan_key("ata", 640, 640, 640, 0, "float32", "dense", p.backend)
    with open(path, "w") as f:
        json.dump({"schema": "v3", "plans": {key: p.to_json()}}, f)

    loaded = load_cache(path)
    assert loaded[key].leaf_dispatch == "unrolled"
    assert loaded[key].n_base == 256  # the rest of the entry survives

    # and the front door serves a plan the recursion actually accepts
    tune.cache.clear_memo()
    served = tune.plan(op="ata", m=640, n=640, cache_file=path)
    assert served.source == "cache" and served.leaf_dispatch == "unrolled"
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((96, 80)), jnp.float32)
    got = ata(a, plan=served)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a.T @ a), rtol=2e-4, atol=2e-4
    )


def test_unknown_comm_schedule_in_cache_sanitizes_to_psum(tmp_path):
    """Regression (BFS/DFS PR hardening): a cache entry written by a future
    schema may carry an interleaving string this revision's
    bfs_dfs_assignment has never heard of. Loading must sanitize it to
    None — the psum schedule, always valid and bitwise-identical — not
    raise at every planned dispatch."""
    path = str(tmp_path / "future.json")
    p = dataclasses.replace(
        tune.plan(op="ata", m=640, n=640), n_base=256,
        comm_schedule="BQX", source="measured", measured_s=1e-3,
    )
    key = plan_key("ata", 640, 640, 640, 0, "float32", "dense", p.backend)
    with open(path, "w") as f:
        json.dump({"schema": "v4", "plans": {key: p.to_json()}}, f)

    loaded = load_cache(path)
    assert loaded[key].comm_schedule is None
    assert loaded[key].n_base == 256  # the rest of the entry survives

    # a *valid* future-ish interleaving is preserved verbatim
    with open(path, "w") as f:
        json.dump({"schema": "v4", "plans": {
            key: dataclasses.replace(p, comm_schedule="BDB").to_json()}}, f)
    assert load_cache(path)[key].comm_schedule == "BDB"

    # and the front door serves the sanitized plan
    with open(path, "w") as f:
        json.dump({"schema": "v4", "plans": {key: p.to_json()}}, f)
    tune.cache.clear_memo()
    served = tune.plan(op="ata", m=640, n=640, cache_file=path)
    assert served.source == "cache" and served.comm_schedule is None


# --- BFS/DFS comm planning --------------------------------------------------


def test_bfs_tiling_pool_divisible_triangle():
    """The BFS grid's tile triangle must divide the merged device pool
    (tri-direct reduce-scatter chunks exactly; packed retrieval is an
    identity slice) while keeping the usual tiling invariants."""
    from repro.tune.cost import bfs_tiling

    for n in (160, 512, 777, 1024, 4096):
        for pool in (1, 2, 3, 4, 6, 8, 16):
            nb, w = bfs_tiling(n, pool)
            t = nb * (nb + 1) // 2
            if pool > 1:
                assert t % pool == 0, (n, pool, nb)
            assert nb * w >= n
            assert w % 8 == 0


def test_bfs_tiling_balances_bfs_assignment():
    """With ``devices`` given, the grid search penalizes triangles whose
    BFS subgroup split leaves a device group over-assigned (extra tiles
    beyond the ideal ceil(T/devices) makespan, weighted by tile area).
    The chosen grid's imbalance cost never exceeds the device-blind
    choice's, and strictly improves on it at the bench mesh — nb=15's 'B'
    split over-assigns by 6 tiles at 4 devices; the search moves to
    nb=16 (2 extra)."""
    from repro.tune.cost import _bfs_makespan, bfs_tiling

    def extra_cost(nb, w, devices):
        t = nb * (nb + 1) // 2
        return (_bfs_makespan(nb, devices, "B") - -(-t // devices)) * w * w

    nb_blind, w_blind = bfs_tiling(1024, 8)
    for devices in (2, 4, 8):
        nb, w = bfs_tiling(1024, 8, devices=devices)
        assert extra_cost(nb, w, devices) <= \
            extra_cost(nb_blind, w_blind, devices), (devices, nb)
    nb4, w4 = bfs_tiling(1024, 8, devices=4)
    assert extra_cost(nb4, w4, 4) < extra_cost(nb_blind, w_blind, 4)


def test_planner_selects_bfs_interleaving():
    """Acceptance: the *planner* — not a hardcoded string — picks the BFS
    schedule at every multi-device bench mesh (the comm model prices the
    tri-direct scatter under the psum schedule's all-reduce + diag-gather),
    and keeps the psum schedule on a single device."""
    from repro.tune import cost

    for devices, row_devices in ((2, 4), (4, 2), (8, 1), (2, 1), (4, 1)):
        for out in ("dense", "packed"):
            top = cost.candidates("ata", 1024, 1024, out=out,
                                  devices=devices, row_devices=row_devices)[0]
            assert top.comm_schedule and "B" in top.comm_schedule, \
                (devices, row_devices, out, top.comm_schedule)
    single = cost.candidates("ata", 1024, 1024, out="packed", devices=1)[0]
    assert single.comm_schedule is None


def test_comm_model_prices_bfs_under_psum_at_bench_meshes():
    """The alpha-beta totals behind the selection above: at the bench
    meshes the one-chunk tri-direct scatter undercuts the psum schedule's
    row all-reduce + root gather + diag-symmetrization gather."""
    from repro.core.distributed import choose_tiling
    from repro.tune.cost import bfs_tiling, comm_seconds, machine_for

    mach = machine_for("cpu")
    for devices, row_devices in ((2, 4), (4, 2), (8, 1)):
        pool = devices * row_devices
        nb_b, w_b = bfs_tiling(1024, pool, devices=devices)
        nb_d, w_d = choose_tiling(1024, devices, out="packed")
        b = comm_seconds(mach, "B", nb_b, w_b, devices, row_devices,
                         out="packed")
        d = comm_seconds(mach, None, nb_d, w_d, devices, row_devices,
                         out="packed")
        assert b < d, (devices, row_devices, b, d)


# --- autotune ---------------------------------------------------------------


@pytest.mark.slow
def test_autotune_persists_and_beats_or_matches_default(tmp_path):
    path = str(tmp_path / "tuned.json")
    p = tune.plan(op="ata", m=256, n=256, autotune=True, cache_file=path)
    assert p.source == "measured"
    assert p.measured_s is not None and p.measured_s > 0
    # persisted and re-served from the file
    tune.cache.clear_memo()
    again = tune.plan(op="ata", m=256, n=256, autotune=True, cache_file=path)
    assert again.source == "cache"
    assert (again.algorithm, again.n_base) == (p.algorithm, p.n_base)


def test_autotune_keeps_default_unless_candidate_beats_margin(monkeypatch):
    """The default plan is the reference of every interleaved comparison:
    a candidate that only wins within noise (≤ margin) must NOT displace
    it, and one that clearly wins must."""
    base = cost.default_plan("ata", 96, 96)

    def paired(ratio):
        # fake time_ratio: default takes `ratio`, candidate takes 1.0
        def fake(fa, fb, *a, **kw):
            return ratio, ratio, 1.0

        return fake

    monkeypatch.setattr(tune.search, "time_fn", lambda *a, **kw: 1.0)
    # candidate faster, but only by 10% — inside the noise margin: keep default
    monkeypatch.setattr(tune.search, "time_ratio", paired(1.10))
    kept = tune.search.autotune("ata", 96, 96, max_candidates=3)
    assert tune.search._same_dispatch(kept, base)
    assert kept.source == "measured"
    # candidate 2x faster — clearly outside noise: take it
    monkeypatch.setattr(tune.search, "time_ratio", paired(2.0))
    tuned = tune.search.autotune("ata", 96, 96, max_candidates=3)
    assert not tune.search._same_dispatch(tuned, base)
    assert tuned.baseline_s == 2.0 and tuned.measured_s == 1.0


def test_autotune_refreshes_default_dispatch_memo(tmp_path, monkeypatch):
    """After an in-process autotune, default (non-autotune) dispatches of
    the same key must see the measured plan — the cache state changed."""
    path = str(tmp_path / "c.json")
    monkeypatch.setattr(tune.search, "time_fn", lambda *a, **kw: 1.0)
    monkeypatch.setattr(tune.search, "time_ratio", lambda *a, **kw: (2.0, 2.0, 1.0))
    before = tune.plan(op="ata", m=160, n=160, cache_file=path)  # analytic memo
    tuned = tune.plan(op="ata", m=160, n=160, autotune=True, cache_file=path)
    after = tune.plan(op="ata", m=160, n=160, cache_file=path)
    assert before.source == "analytic"
    assert (after.algorithm, after.n_base) == (tuned.algorithm, tuned.n_base)


def test_autotune_distributed_stays_analytic(tmp_path):
    """devices > 1: the autotuner cannot time the distributed schedule, so
    the plan stays analytic (and nothing is persisted)."""
    path = str(tmp_path / "c.json")
    p = tune.plan(op="ata", m=512, n=512, devices=8, autotune=True, cache_file=path)
    assert p.source == "analytic"
    assert p.nb is not None and p.tile_w is not None
    assert tune.cache.load_cache(path) == {}


# --- distributed branch: retrieval bytes + packed-aligned tiling ------------


def test_distributed_tiling_dense_behavior_unchanged():
    """out='dense' must reproduce the historical search exactly (the
    alignment term is constant there) — guards plan stability."""
    for n in [256, 1000, 4096]:
        for p in [1, 2, 4, 8, 16]:
            assert cost.distributed_tiling(n, p) == cost.distributed_tiling(
                n, p, out="dense"
            )


def test_distributed_tiling_packed_snaps_when_balanced():
    """When the packed-grid-aligned stripe count is as balanced as the best
    candidate, packed mode must pick it (pure-slice retrieval)."""
    from repro.core.symmetric import default_block_size

    # n=1024, p=4: nb=8 (w == bn == 128) has waste 0 → aligned must win
    nb, w = cost.distributed_tiling(1024, 4, out="packed")
    assert w == default_block_size(1024, defaults.DEFAULT_PACKED_BLOCK)
    assert nb * w >= 1024 and w % 8 == 0
    # balance still dominates: a misaligned zero-waste tiling beats an
    # aligned one that idles devices (n=512, p=8: aligned T=10 < 2 tiles/dev)
    nb2, w2 = cost.distributed_tiling(512, 8, out="packed")
    t2 = nb2 * (nb2 + 1) // 2
    assert -(-t2 // 8) * 8 - t2 == 0  # zero waste kept


def test_distributed_tiling_packed_never_forfeits_strassen_depth():
    """Alignment must not shrink stripes below the leaf Strassen cutoff
    when a balanced wide tiling exists: at n=4096 the dense search keeps
    w > DEFAULT_N_BASE (one recursion level per tile) and packed mode must
    keep the same depth rather than snapping to 128-wide dots."""
    for p in (1, 4):
        nbd, wd = cost.distributed_tiling(4096, p, out="dense")
        nbp, wp = cost.distributed_tiling(4096, p, out="packed")
        assert wd > defaults.DEFAULT_N_BASE
        assert wp > defaults.DEFAULT_N_BASE, (p, nbp, wp)
        assert (nbp, wp) == (nbd, wd)


def test_distributed_retrieval_bytes_packed_halves_dense():
    for n, p in [(1024, 4), (2048, 8), (512, 8)]:
        for out in ("dense", "packed"):
            nb, w = cost.distributed_tiling(n, p, out=out)
            t = nb * (nb + 1) // 2
            rb = cost.retrieval_bytes(out, nb, w)
            if out == "packed":
                assert rb == t * w * w * 4
                assert rb < 0.75 * (nb * w) ** 2 * 4  # ≈ half the square
            else:
                assert rb == (nb * w) ** 2 * 4


def test_distributed_plan_prediction_reflects_out_mode():
    """The distributed plan's predicted seconds must price packed retrieval
    below dense replication (same algorithm either way: out-invariance)."""
    pd = tune.plan(op="ata", m=4096, n=2048, devices=8, out="dense")
    pp = tune.plan(op="ata", m=4096, n=2048, devices=8, out="packed")
    assert (pd.algorithm, pd.n_base) == (pp.algorithm, pp.n_base)
    assert pd.nb is not None and pp.nb is not None
    assert pp.predicted_s <= pd.predicted_s


# --- consumers honor the plan ----------------------------------------------


def test_ata_honors_plan_bitwise():
    """ata(plan=p) must equal ata with p's tunables spelled out by hand."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((200, 160)), jnp.float32)
    p = dataclasses.replace(
        tune.plan(op="ata", m=200, n=160), algorithm="winograd", n_base=64
    )
    via_plan = ata(a, plan=p)
    by_hand = ata(a, n_base=64, variant="winograd")
    np.testing.assert_array_equal(np.asarray(via_plan), np.asarray(by_hand))


def test_packed_default_plan_bitwise_equals_dense():
    """The acceptance bit: default-planned packed output mirrors to exactly
    the default-planned dense output."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((300, 200)), jnp.float32)
    dense = ata(a)
    packed = ata(a, out="packed")
    np.testing.assert_array_equal(np.asarray(packed.to_dense()), np.asarray(dense))


def test_strassen_tn_honors_plan():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((160, 120)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((160, 96)), jnp.float32)
    p = dataclasses.replace(
        tune.plan(op="gemm_tn", m=160, n=120, k=96), algorithm="strassen", n_base=32
    )
    np.testing.assert_array_equal(
        np.asarray(strassen_tn(a, b, plan=p)),
        np.asarray(strassen_tn(a, b, n_base=32, variant="strassen")),
    )


def test_plan_under_jit_and_vmap():
    """Planning happens at trace time: default dispatches must compose with
    jit and vmap (the planner sees the unbatched trace shape)."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((4, 96, 64)), jnp.float32)
    got = jax.jit(jax.vmap(lambda x: ata(x)))(a)
    want = jnp.einsum("bmi,bmj->bij", a, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_shampoo_unpinned_n_base_runs():
    """Shampoo with planner-dispatched grams still produces finite updates."""
    from repro.optim import constant
    from repro.optim.shampoo import shampoo

    rng = np.random.default_rng(4)
    params = {"w": jnp.asarray(rng.standard_normal((48, 32)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.standard_normal((48, 32)), jnp.float32) * 1e-2}
    opt = shampoo(constant(1e-3), block=16, update_every=1)
    state = opt.init(params)
    u, state = opt.update(grads, state, params)
    assert np.isfinite(np.asarray(u["w"])).all()


def test_distributed_tiling_is_choose_tiling():
    from repro.core.distributed import choose_tiling

    for n, p in [(256, 4), (1000, 8), (4096, 16)]:
        assert choose_tiling(n, p) == cost.distributed_tiling(n, p)


# --- warm / cache_prefetch (the serve layer's bulk pre-warm API) ------------


def test_warm_resolves_analytic_and_seeds_the_memo():
    from repro.tune.cache import cache_stats, warm

    before = cache_stats()
    specs = [dict(op="solve", m=48, n=32, k=4, out="packed"),
             dict(op="ata", m=256, n=128)]
    plans = warm(specs)
    after = cache_stats()
    assert after["warm_miss"] - before["warm_miss"] == 2  # empty cache file
    assert after["warm_hit"] == before["warm_hit"]
    assert [p.op for p in plans] == ["solve", "ata"]      # spec order kept
    # the point of warming: the per-dispatch plan() calls are memo hits
    served = tune.plan(op="solve", m=48, n=32, k=4, out="packed")
    assert served is plans[0]
    assert cache_stats()["memo_hit"] - after["memo_hit"] == 1


def test_warm_serves_persisted_plans_in_one_read(tmp_path):
    from repro.tune.cache import cache_stats, warm

    path = str(tmp_path / "c.json")
    analytic = tune.plan(op="solve", m=96, n=64, k=8, out="packed",
                         cache_file=path)
    key = plan_key("solve", 96, 64, 8, 0, "float32", "packed",
                   analytic.backend, 1, 1)
    save_cache({key: dataclasses.replace(analytic, source="measured")}, path)
    tune.cache.clear_memo()
    before = cache_stats()
    hit, miss = warm([dict(op="solve", m=96, n=64, k=8, out="packed"),
                      dict(op="solve", m=48, n=32, k=4, out="packed")],
                     cache_file=path)
    after = cache_stats()
    assert after["warm_hit"] - before["warm_hit"] == 1
    assert after["warm_miss"] - before["warm_miss"] == 1
    assert hit.source == "cache" and miss.source == "analytic"


def test_warm_never_clobbers_an_existing_memo_entry():
    from repro.tune.cache import cache_stats, warm

    first = tune.plan(op="solve", m=48, n=32, k=4, out="packed")
    before = cache_stats()
    (warmed,) = warm([dict(op="solve", m=48, n=32, k=4, out="packed")])
    assert warmed is first                 # the memoized plan wins
    assert cache_stats()["warm_memo"] - before["warm_memo"] == 1


def test_warm_validates_specs():
    from repro.tune.cache import warm

    with pytest.raises(ValueError, match="unknown op"):
        warm([dict(op="qr", m=8, n=8)])
    with pytest.raises(ValueError, match="unbatched"):
        warm([dict(op="solve", m=8, n=8, batch=4)])
    with pytest.raises(TypeError, match="unknown keys"):
        warm([dict(op="ata", m=8, n=8, block_size=32)])


def test_cache_prefetch_is_warm_and_lazily_exported():
    from repro.tune import cache

    assert cache.cache_prefetch is cache.warm
    assert tune.warm is cache.warm         # repro.tune lazy re-export
