"""Correctness + property tests for the core ATA / Strassen algorithms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is a dev-only dependency (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import ata, strassen_tn
from repro.core.reference import (
    ata_flops,
    classical_gemm_flops,
    classical_syrk_flops,
    gemm_tn_ref,
    strassen_tn_flops,
    strassen_tn_flops_winograd,
    syrk_ref,
)

jax.config.update("jax_enable_x64", True)


def rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# strassen_tn
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["strassen", "winograd"])
@pytest.mark.parametrize(
    "m,n,k",
    [
        (8, 8, 8),
        (16, 16, 16),
        (64, 64, 64),
        (128, 96, 80),   # rectangular
        (67, 53, 41),    # odd everywhere
        (1, 5, 3),       # degenerate contraction
        (33, 1, 7),      # degenerate output dims
        (100, 200, 50),  # tall/wide mix
    ],
)
def test_strassen_tn_matches_ref(variant, m, n, k):
    r = rng(hash((m, n, k)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)))
    b = jnp.asarray(r.standard_normal((m, k)))
    got = strassen_tn(a, b, n_base=8, variant=variant, acc_dtype=jnp.float64)
    want = gemm_tn_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_strassen_tn_alpha_beta_accumulate():
    r = rng(1)
    a = jnp.asarray(r.standard_normal((32, 24)))
    b = jnp.asarray(r.standard_normal((32, 40)))
    c = jnp.asarray(r.standard_normal((24, 40)))
    got = strassen_tn(a, b, alpha=2.5, c=c, beta=-0.5, n_base=8, acc_dtype=jnp.float64)
    want = 2.5 * (a.T @ b) - 0.5 * c
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_strassen_tn_shape_errors():
    a = jnp.zeros((4, 5))
    b = jnp.zeros((3, 7))
    with pytest.raises(ValueError):
        strassen_tn(a, b)
    with pytest.raises(ValueError):
        strassen_tn(jnp.zeros((4,)), jnp.zeros((4, 2)))
    with pytest.raises(ValueError):
        strassen_tn(a, jnp.zeros((4, 2)), variant="nope")


def test_strassen_tn_under_jit_and_grad():
    r = rng(2)
    a = jnp.asarray(r.standard_normal((32, 16)))
    b = jnp.asarray(r.standard_normal((32, 16)))

    f = jax.jit(lambda a, b: strassen_tn(a, b, n_base=8, acc_dtype=jnp.float64).sum())
    np.testing.assert_allclose(f(a, b), (a.T @ b).sum(), rtol=1e-9)

    g = jax.grad(lambda a: strassen_tn(a, b, n_base=8, acc_dtype=jnp.float64).sum())(a)
    g_ref = jax.grad(lambda a: (a.T @ b).sum())(a)
    np.testing.assert_allclose(g, g_ref, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# ata
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["strassen", "winograd"])
@pytest.mark.parametrize(
    "m,n",
    [
        (8, 8),
        (64, 64),
        (128, 96),
        (67, 53),
        (53, 67),
        (1, 9),
        (200, 100),
        (100, 200),
        (257, 129),
    ],
)
def test_ata_matches_ref(variant, m, n):
    r = rng(hash((m, n)) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)))
    got = ata(a, n_base=8, variant=variant, acc_dtype=jnp.float64)
    want = syrk_ref(a)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_ata_symmetry_exact():
    """C must be exactly symmetric (C12 is the mirror of C21, not recomputed)."""
    r = rng(3)
    a = jnp.asarray(r.standard_normal((96, 80)))
    c = ata(a, n_base=8, acc_dtype=jnp.float64)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c).T)


def test_ata_alpha_beta():
    r = rng(4)
    a = jnp.asarray(r.standard_normal((48, 32)))
    c0 = jnp.asarray(r.standard_normal((32, 32)))
    got = ata(a, alpha=0.25, c=c0, beta=2.0, n_base=8, acc_dtype=jnp.float64)
    np.testing.assert_allclose(got, 0.25 * (a.T @ a) + 2.0 * c0, rtol=1e-9, atol=1e-9)


def test_ata_vmap():
    """Blocked-Shampoo uses vmapped ATA over parameter blocks."""
    r = rng(5)
    a = jnp.asarray(r.standard_normal((4, 40, 24)))
    got = jax.vmap(lambda x: ata(x, n_base=8, acc_dtype=jnp.float64))(a)
    want = jnp.einsum("bmi,bmj->bij", a, a)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_ata_grad():
    r = rng(6)
    a = jnp.asarray(r.standard_normal((32, 16)))
    g = jax.grad(lambda a: ata(a, n_base=8, acc_dtype=jnp.float64).sum())(a)
    g_ref = jax.grad(lambda a: (a.T @ a).sum())(a)
    np.testing.assert_allclose(g, g_ref, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("m,n", [(64, 64), (67, 53), (200, 100), (257, 129)])
def test_ata_packed_bitwise_matches_dense(m, n):
    """ata(out='packed').to_dense() must equal dense ata *bitwise*."""
    from repro.core import SymmetricMatrix

    r = rng(hash((m, n, "packed")) % 2**32)
    a = jnp.asarray(r.standard_normal((m, n)))
    dense = ata(a, n_base=8, acc_dtype=jnp.float64)
    packed = ata(a, n_base=8, acc_dtype=jnp.float64, out="packed", packed_block=32)
    assert isinstance(packed, SymmetricMatrix)
    np.testing.assert_array_equal(np.asarray(packed.to_dense()), np.asarray(dense))
    # packed really is packed: T = nb(nb+1)/2 blocks, not nb²
    assert packed.blocks.shape[-3] == packed.nb * (packed.nb + 1) // 2


def test_ata_packed_no_intermediate_square_transposes():
    """No full-square (2-D, > n_base) transpose anywhere in the packed path;
    dense output takes exactly one — the root mirror. Both halves run the
    repro.check ``no-full-transpose`` rule; the dense half's root-mirror
    allowance is the ``mirror_budget`` override (and dropping the budget
    is the positive control: the rule must fire on that mirror)."""
    from repro import check

    n_base = 64
    n = 256
    a = jnp.zeros((n, n), jnp.float32)

    def trace(fn):
        return jax.make_jaxpr(fn)(a).jaxpr

    # leaf-tile mirrors (≤ n_base per dim) are the base-case symmetry
    # contract; anything larger would be a reintroduced square mirror.
    packed = check.Artifact(
        label="ata:packed", jaxpr=trace(lambda x: ata(x, n_base=n_base,
                                                      out="packed")),
        overrides={"max_transpose_dim": n_base, "mirror_budget": 0})
    assert not check.run(packed, rules=["no-full-transpose"]).violations

    dense_jaxpr = trace(lambda x: ata(x, n_base=n_base))
    dense = check.Artifact(
        label="ata:dense", jaxpr=dense_jaxpr,
        overrides={"max_transpose_dim": n_base, "mirror_budget": 1,
                   "mirror_shape": (n, n)})
    assert not check.run(dense, rules=["no-full-transpose"]).violations
    # positive control: with no mirror budget the root (n, n) mirror must
    # be flagged — exactly once
    no_budget = check.Artifact(
        label="ata:dense-no-budget", jaxpr=dense_jaxpr,
        overrides={"max_transpose_dim": n_base, "mirror_budget": 0})
    fired = check.run(no_budget, rules=["no-full-transpose"]).violations
    assert [f.shape for f in fired] == [(n, n)], fired


def test_ata_batched_matches_einsum():
    from repro.core import ata_batched

    r = rng(11)
    a = jnp.asarray(r.standard_normal((5, 48, 28)))
    got = ata_batched(a, n_base=8, acc_dtype=jnp.float64)
    want = jnp.einsum("bmi,bmj->bij", a, a)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)
    packed = ata_batched(a, n_base=8, acc_dtype=jnp.float64, out="packed", packed_block=16)
    np.testing.assert_array_equal(np.asarray(packed.to_dense()), np.asarray(got))


def test_ata_f32_tolerance_moderate_depth():
    """Production dtype path: f32 with a few recursion levels stays tight."""
    r = rng(7)
    a = jnp.asarray(r.standard_normal((2048, 1024)), dtype=jnp.float32)
    got = ata(a, n_base=256, acc_dtype=jnp.float32)
    want = (a.astype(jnp.float64).T @ a.astype(jnp.float64)).astype(jnp.float64)
    err = np.abs(np.asarray(got, dtype=np.float64) - np.asarray(want))
    scale = np.abs(np.asarray(want)) + 1.0
    # measured: ATA ≈ 9.3e-5 vs 6.4e-5 for a plain f32 matmul at this shape —
    # Strassen's amplification is ~1.5× here; gate at 5e-4 to stay robust.
    assert (err / scale).max() < 5e-4


# ---------------------------------------------------------------------------
# property tests (hypothesis) — arbitrary rectangular shapes
# (skipped when hypothesis is not installed; see requirements-dev.txt)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=80),
        n=st.integers(min_value=1, max_value=80),
        n_base=st.sampled_from([1, 2, 4, 8]),
        variant=st.sampled_from(["strassen", "winograd"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_property_ata_any_shape(m, n, n_base, variant, seed):
        r = rng(seed)
        a = jnp.asarray(r.standard_normal((m, n)))
        got = ata(a, n_base=n_base, variant=variant, acc_dtype=jnp.float64)
        np.testing.assert_allclose(got, a.T @ a, rtol=1e-8, atol=1e-8)
        # invariant: exact symmetry by construction
        np.testing.assert_array_equal(np.asarray(got), np.asarray(got).T)

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=64),
        n=st.integers(min_value=1, max_value=64),
        k=st.integers(min_value=1, max_value=64),
        n_base=st.sampled_from([1, 2, 4, 8]),
        variant=st.sampled_from(["strassen", "winograd"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_property_strassen_any_shape(m, n, k, n_base, variant, seed):
        r = rng(seed)
        a = jnp.asarray(r.standard_normal((m, n)))
        b = jnp.asarray(r.standard_normal((m, k)))
        got = strassen_tn(a, b, n_base=n_base, variant=variant, acc_dtype=jnp.float64)
        np.testing.assert_allclose(got, a.T @ b, rtol=1e-8, atol=1e-8)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=48),
        n=st.integers(min_value=1, max_value=48),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_property_ata_psd(m, n, seed):
        """AᵀA is positive semi-definite — eigvals of ATA's result are ≥ -eps."""
        r = rng(seed)
        a = jnp.asarray(r.standard_normal((m, n)))
        c = np.asarray(ata(a, n_base=4, acc_dtype=jnp.float64))
        w = np.linalg.eigvalsh(c)
        assert w.min() >= -1e-8 * max(1.0, abs(w).max())

else:

    @pytest.mark.skip(reason="hypothesis not installed (pip install -r requirements-dev.txt)")
    def test_property_suite_requires_hypothesis():
        pass


# ---------------------------------------------------------------------------
# flop counters — paper Section 3.2 claims
# ---------------------------------------------------------------------------


def test_flops_strassen_base_equals_classical():
    assert strassen_tn_flops(64, 64, 64, 64) == classical_gemm_flops(64, 64, 64)


def test_flops_ratio_ata_vs_strassen_approaches_two_thirds():
    """Paper Eq. (3): T(n) ≈ (2/3)·T_S(n) asymptotically."""
    prev = None
    for p in range(10, 15):
        n = 2**p
        ratio = ata_flops(n, n, 64) / strassen_tn_flops(n, n, n, 64)
        if prev is not None:
            assert abs(ratio - 2 / 3) < abs(prev - 2 / 3) + 1e-12  # monotone approach
        prev = ratio
    assert abs(prev - 2 / 3) < 0.02


def test_flops_ata_beats_classical_syrk_asymptotically():
    n = 2**14
    assert ata_flops(n, n, 512) < classical_syrk_flops(n, n)


def test_flops_strassen_beats_classical_gemm_asymptotically():
    n = 2**14
    assert strassen_tn_flops(n, n, n, 512) < classical_gemm_flops(n, n, n)
    # and the winograd variant is cheaper still (fewer additions)
    assert strassen_tn_flops_winograd(n, n, n, 512) < strassen_tn_flops(n, n, n, 512)


def test_flops_seven_multiplies_recurrence():
    """One Strassen level ≈ 7 × half-size classical + O(n²) adds."""
    n = 1024
    one_level = strassen_tn_flops(n, n, n, n // 2)
    half = classical_gemm_flops(n // 2, n // 2, n // 2)
    adds = one_level - 7 * half
    assert 0 < adds <= 18 * (n // 2) ** 2
