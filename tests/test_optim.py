"""Tests for AdamW, ATA-powered Shampoo, and PowerSGD compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, apply_updates, constant, shampoo, warmup_cosine
from repro.optim.adamw import clip_by_global_norm, global_norm
from repro.optim.powersgd import compress, decompress, init_state
from repro.optim.shampoo import inverse_pth_root


def _quadratic_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (16, 8), jnp.float32),
        "b": jax.random.normal(k2, (8,), jnp.float32),
    }


def _loss(params, x, y):
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - y) ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(constant(1e-2)),
    lambda: shampoo(constant(1e-2), block=8, update_every=2, n_base=4),
], ids=["adamw", "shampoo"])
def test_optimizer_decreases_loss(make_opt):
    key = jax.random.key(0)
    params = _quadratic_params(key)
    x = jax.random.normal(jax.random.key(1), (64, 16))
    w_true = jax.random.normal(jax.random.key(2), (16, 8))
    y = x @ w_true

    opt = make_opt()
    state = opt.init(params)
    loss0 = float(_loss(params, x, y))

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(_loss)(params, x, y)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state, loss

    for _ in range(60):
        params, state, loss = step(params, state)
    assert float(loss) < 0.5 * loss0
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-5)
    assert float(sched(jnp.asarray(55))) < 1.0


def test_global_norm_clip():
    tree = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((10,)) * 4.0}
    n = float(global_norm(tree))
    assert n == pytest.approx(np.sqrt(90 + 160))
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_inverse_pth_root_matches_eigh():
    r = np.random.default_rng(0)
    x = r.standard_normal((32, 32)).astype(np.float32)
    a = x @ x.T + 0.1 * np.eye(32, dtype=np.float32)
    got = np.asarray(inverse_pth_root(jnp.asarray(a), p=4, iters=40, ridge=0.0))
    w, v = np.linalg.eigh(a)
    want = (v * w ** -0.25) @ v.T
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_inverse_pth_root_p2():
    r = np.random.default_rng(1)
    x = r.standard_normal((16, 16)).astype(np.float32)
    a = x @ x.T + 0.5 * np.eye(16, dtype=np.float32)
    got = np.asarray(inverse_pth_root(jnp.asarray(a), p=2, iters=40, ridge=0.0))
    w, v = np.linalg.eigh(a)
    want = (v * w ** -0.5) @ v.T
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "dense"])
def test_shampoo_stats_are_ata_grams(packed):
    """The L/R statistics must equal decayed G·Gᵀ / GᵀG gram sums —
    in packed (SymmetricMatrix) form by default, dense on request."""
    from repro.core import SymmetricMatrix

    opt = shampoo(constant(1e-2), block=16, update_every=1, stat_decay=0.5,
                  n_base=4, packed_grams=packed, gram_block=8)
    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    g = jax.random.normal(jax.random.key(3), (16, 16), jnp.float32)
    state = opt.init(params)
    _, state = opt.update({"w": g}, state, params)
    l_stat = state["shampoo"]["w"]["l"]
    r_stat = state["shampoo"]["w"]["r"]
    if packed:
        assert isinstance(l_stat, SymmetricMatrix)
        # the memory claim: only T = k(k+1)/2 blocks are resident
        nb = l_stat.nb
        assert l_stat.blocks.shape[-3] == nb * (nb + 1) // 2
        l_stat, r_stat = l_stat.to_dense(), r_stat.to_dense()
    l = np.asarray(l_stat[0])
    r_ = np.asarray(r_stat[0])
    np.testing.assert_allclose(l, 0.5 * np.asarray(g @ g.T), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(r_, 0.5 * np.asarray(g.T @ g), rtol=1e-4, atol=1e-4)


def test_shampoo_packed_matches_dense_updates():
    """packed_grams must not change the math: step results allclose, and the
    resident gram-statistics memory must shrink."""
    params = {"w": jax.random.normal(jax.random.key(7), (64, 32), jnp.float32)}
    g = {"w": jax.random.normal(jax.random.key(8), (64, 32), jnp.float32)}
    outs, stats_bytes = {}, {}
    for packed in (True, False):
        opt = shampoo(constant(1e-2), block=32, update_every=2, n_base=8,
                      packed_grams=packed, gram_block=8)
        state = opt.init(params)
        u1, state = opt.update(g, state, params)
        u2, state = opt.update(g, state, params)   # step 2 refreshes roots
        outs[packed] = (u1["w"], u2["w"])
        s = state["shampoo"]["w"]
        stats_bytes[packed] = s["l"].nbytes + s["r"].nbytes
    np.testing.assert_allclose(outs[True][0], outs[False][0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs[True][1], outs[False][1], rtol=1e-4, atol=1e-5)
    assert stats_bytes[True] < stats_bytes[False]


def test_shampoo_skips_embeddings():
    opt = shampoo(constant(1e-2), block=8)
    params = {"embed": jnp.zeros((32, 8)), "layers": {"w": jnp.zeros((16, 8))}}
    state = opt.init(params)
    assert state["shampoo"]["embed"] == 0            # Adam fallback
    assert isinstance(state["shampoo"]["layers"]["w"], dict)


def test_shampoo_blocked_partitioning_roundtrip():
    from repro.optim.shampoo import _from_blocks, _plan, _to_blocks

    g = jax.random.normal(jax.random.key(4), (40, 24), jnp.float32)
    pt = _plan(g.shape, 16)
    blocks = _to_blocks(g, pt)
    assert blocks.shape == (pt.n1 * pt.n2, pt.b1, pt.b2)
    back = _from_blocks(blocks, pt, g.shape)
    np.testing.assert_allclose(back, g, rtol=1e-6)


# --- PowerSGD ---------------------------------------------------------------


def test_shampoo_packed_state_specs_shard_blocks_over_data():
    """Regression (ZeRO-1 dense-replication bug): the packed SymmetricMatrix
    stat stacks are 4-D (nb, T, bn, bn) and used to fall through
    state_specs' 3-D-only rule to fully-replicated — doubling per-device
    optimizer-state bytes back to dense scale. They must shard their
    leading block-ownership dim over 'data' exactly like dense stacks."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh
    from repro.configs.base import SHAPES, OptimizerConfig, RunConfig
    from repro.configs.registry import get_smoke
    from repro.models.transformer import init
    from repro.optim import build as build_opt
    from repro.train.train_step import state_specs

    cfg = get_smoke("qwen1.5-0.5b")
    mesh = make_mesh((1, 1), ("data", "model"))
    run = RunConfig(
        model=cfg, shape=SHAPES["train_4k"],
        optimizer=OptimizerConfig(name="shampoo", zero1=True),
    )
    opt = build_opt(run.optimizer, 100)
    params_abs = jax.eval_shape(
        lambda: init(jax.random.key(0), cfg, mesh=mesh)
    )
    opt_abs = jax.eval_shape(opt.init, params_abs)
    specs = state_specs(cfg, mesh, run, params_abs, opt_abs)
    sh_specs = jax.tree.leaves(
        specs["opt"]["shampoo"], is_leaf=lambda x: isinstance(x, P)
    )
    four_d = [s for s in sh_specs if isinstance(s, P) and len(s) == 4]
    assert four_d, "no packed (4-D) stat-stack specs found"
    assert all(s[0] == "data" and s[1:] == (None, None, None) for s in four_d)
    # dense 3-D stacks (pl/pr preconditioners) keep their block sharding too
    three_d = [s for s in sh_specs if isinstance(s, P) and len(s) == 3]
    assert three_d and all(s[0] == "data" for s in three_d)


def test_powersgd_rank_sufficient_exact():
    """If rank ≥ rank(G), compression is (nearly) lossless after one step."""
    r = np.random.default_rng(5)
    u = r.standard_normal((32, 4)).astype(np.float32)
    v = r.standard_normal((24, 4)).astype(np.float32)
    g = jnp.asarray(u @ v.T)
    state = init_state(jax.random.key(0), g.shape, rank=8)
    p, q, state = compress(g, state, n_base=8)
    g_hat = decompress(p, q)
    np.testing.assert_allclose(g_hat, g, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(state.error), 0.0, atol=1e-3)


def test_powersgd_error_feedback_accumulates():
    r = np.random.default_rng(6)
    g = jnp.asarray(r.standard_normal((32, 24)).astype(np.float32))
    state = init_state(jax.random.key(1), g.shape, rank=2)
    total_hat = jnp.zeros_like(g)
    rels = []
    for i in range(30):
        p, q, state = compress(g, state, n_base=8)
        total_hat = total_hat + decompress(p, q)
        avg = np.asarray(total_hat / (i + 1))
        rels.append(np.linalg.norm(avg - np.asarray(g)) / np.linalg.norm(np.asarray(g)))
    # over repeated rounds of the same gradient, error feedback makes the
    # *average* reconstruction approach g (rank 2 of 24 on a flat spectrum →
    # measured ≈0.56@10 / 0.23@30, monotone decreasing)
    assert rels[-1] < 0.3, rels[-1]
    assert rels[-1] < rels[9] < rels[4]


def test_powersgd_orthonormal_p():
    from repro.optim.powersgd import _orthonormalize

    r = np.random.default_rng(7)
    p = jnp.asarray(r.standard_normal((64, 6)).astype(np.float32))
    po = _orthonormalize(p)
    gram = np.asarray(po.T @ po)
    np.testing.assert_allclose(gram, np.eye(6), rtol=1e-3, atol=1e-3)
