"""Layer-level oracle tests: flash attention vs naive attention, RoPE, SSD."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.models import layers as L
from repro.models import ssm as SSM


def _naive_attention(params, x, cfg, window=None):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    s = x.shape[1]
    pos = jnp.arange(s)[None]
    q = L.rope(q, pos, cfg.rope_theta)
    k = L.rope(k, pos, cfg.rope_theta)
    groups = cfg.num_heads // cfg.num_kv_heads
    k = jnp.repeat(k, groups, axis=2)
    v = jnp.repeat(v, groups, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) * cfg.head_dim**-0.5
    i, j = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
    mask = i >= j
    if window is not None:
        mask &= (i - j) < window
    sc = jnp.where(mask[None, None], sc, -1e30)
    w = jax.nn.softmax(sc, -1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


@pytest.mark.parametrize("arch,window", [
    ("qwen1.5-0.5b", None),
    ("hymba-1.5b", 8),
    ("command-r-plus-104b", None),   # GQA groups > 1
])
def test_flash_attention_matches_naive(arch, window):
    cfg = get_smoke(arch)
    params = L.init_attn(jax.random.key(0), cfg)
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((2, 16, cfg.d_model)), jnp.float32
    )
    out = L.attention_train(params, x, cfg, window=window)
    want = _naive_attention(params, x, cfg, window=window)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_flash_attention_multi_block(monkeypatch):
    """Force tiny blocks so the running-softmax recurrence spans many chunks."""
    monkeypatch.setattr(L, "Q_BLOCK", 8)
    monkeypatch.setattr(L, "KV_BLOCK", 4)
    cfg = get_smoke("qwen1.5-0.5b")
    params = L.init_attn(jax.random.key(1), cfg)
    x = jnp.asarray(
        np.random.default_rng(1).standard_normal((2, 37, cfg.d_model)), jnp.float32
    )
    out = L.attention_train(params, x, cfg, window=None)
    want = _naive_attention(params, x, cfg, window=None)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
    # windowed across blocks too
    out_w = L.attention_train(params, x, cfg, window=5)
    want_w = _naive_attention(params, x, cfg, window=5)
    np.testing.assert_allclose(out_w, want_w, rtol=1e-5, atol=1e-5)


def test_rope_relative_property():
    """q(p1)·k(p2) must depend only on p1 − p2."""
    d = 64
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)

    def dot_at(p1, p2):
        qq = L.rope(q, jnp.asarray([[p1]]), 1e4)
        kk = L.rope(k, jnp.asarray([[p2]]), 1e4)
        return float(jnp.sum(qq * kk))

    assert dot_at(5, 3) == pytest.approx(dot_at(102, 100), rel=1e-4)
    assert dot_at(7, 0) == pytest.approx(dot_at(107, 100), rel=1e-4)


def test_rms_norm_scale_invariance():
    x = jnp.asarray(np.random.default_rng(3).standard_normal((2, 4, 32)), jnp.float32)
    w = jnp.zeros((32,))
    y1 = L.rms_norm(x, w)
    y2 = L.rms_norm(10.0 * x, w)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(y1**2, -1)), 1.0, rtol=1e-3
    )


def test_ssd_chunked_matches_sequential_decode():
    """The chunked SSD scan must agree with the stepwise recurrence."""
    cfg = get_smoke("mamba2-1.3b")
    p = SSM.init_ssm(jax.random.key(4), cfg)
    rng = np.random.default_rng(5)
    b, s = 2, 48  # not a multiple of chunk (32) — exercises padding
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)

    y_train = SSM.ssm_train(p, x, cfg)

    h, conv = SSM.init_ssm_state(cfg, b)
    ys = []
    for t in range(s):
        y, h, conv = SSM.ssm_decode(p, x[:, t : t + 1], cfg, h, conv)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_train, y_dec, rtol=2e-3, atol=2e-3)


def test_ssd_gradient_finite_long_decay():
    """Large dt·A decays must not produce NaN grads (mask-before-exp)."""
    cfg = get_smoke("mamba2-1.3b")
    p = SSM.init_ssm(jax.random.key(6), cfg)
    # scale dt projection up to force extreme decays
    p = {**p, "dt_proj": p["dt_proj"] * 50.0}
    x = jnp.asarray(
        np.random.default_rng(7).standard_normal((1, 64, cfg.d_model)), jnp.float32
    )
    g = jax.grad(lambda xx: SSM.ssm_train(p, xx, cfg).sum())(x)
    assert np.isfinite(np.asarray(g)).all()
