"""Tests for the packed solver layer (repro.solve) and its base kernels.

Coverage per the PR's acceptance criteria:

* packed Cholesky round-trip (``L·Lᵀ`` reconstructs the input) and parity
  with ``jnp.linalg.cholesky`` on ``to_dense()``, exhaustively over
  odd/rect/bn-misaligned shapes and batch dims;
* **bitwise** packed-vs-dense solve parity (same walk, same rounding);
* the Pallas ``potrf``/``trsm`` kernels against their jnp oracles,
  batched per the kernels' leading-grid-dim contract;
* blocked triangular substitution (multi-RHS, vector RHS, both passes);
* ``solve.lstsq`` against ``jnp.linalg.lstsq``, plus the jaxpr regression
  that the packed factor pipeline materializes **no dense (n, n)**;
* CG convergence on conditioned SPD fixtures;
* the planner's ``op='solve'`` entry (method choice, cache round-trip);
* Shampoo's ``precond_p=2`` packed path vs its dense twin (fp tolerance)
  and the p=4 path's exact indifference to this PR.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import solve, tune
from repro.core.ata import ata, ata_batched
from repro.core.reference import (
    blocked_potrf_flops,
    classical_gemm_flops,
    potrf_flops,
    trsm_flops,
)
from repro.core.symmetric import SymmetricMatrix
from repro.kernels import ops
from repro.kernels.potrf import potrf_pallas
from repro.kernels.trsm import trsm_pallas
from repro.solve.cholesky import CholeskyFactor

try:  # hypothesis is a dev-only dependency (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _spd(rng, n, cond=None):
    """Well-conditioned SPD fixture; ``cond`` forces the spectrum."""
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    if cond is None:
        eig = rng.uniform(1.0, 2.0, n)
    else:
        eig = np.logspace(0, -np.log10(cond), n)
    a = (q * eig) @ q.T
    return jnp.asarray((a + a.T) / 2, jnp.float32)


def _packed_gram(rng, m, n, bn, ridge=None):
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    g = ata(a, n_base=32, out="packed", packed_block=bn)
    return g.add_scaled_identity(float(n) if ridge is None else ridge)


# ---------------------------------------------------------------------------
# Pallas kernels vs oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 16, 64, 128])
def test_potrf_kernel_matches_jnp(n):
    rng = np.random.default_rng(n)
    a = _spd(rng, n) + float(n) * jnp.eye(n, dtype=jnp.float32)
    got = potrf_pallas(a, interpret=True)
    ref = jnp.linalg.cholesky(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # strict upper must be exactly zero (the factor-tile contract)
    assert not np.triu(np.asarray(got), 1).any()


def test_potrf_kernel_batched_is_one_stacked_call():
    rng = np.random.default_rng(0)
    a = jnp.stack([_spd(rng, 32) + 32.0 * jnp.eye(32, dtype=jnp.float32) for _ in range(5)])
    got = potrf_pallas(a, interpret=True)
    ref = jax.vmap(jnp.linalg.cholesky)(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("transpose", [True, False])
@pytest.mark.parametrize("m", [8, 24, 300])
def test_trsm_kernel_matches_triangular_solve(transpose, m):
    rng = np.random.default_rng(m)
    n = 16
    l = jnp.linalg.cholesky(_spd(rng, n) + float(n) * jnp.eye(n, dtype=jnp.float32))
    b = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    got = trsm_pallas(l, b, transpose=transpose, interpret=True)
    ref = jax.lax.linalg.triangular_solve(
        l, b, left_side=False, lower=True, transpose_a=transpose
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_trsm_kernel_batched_per_entry_factors():
    """Each stack entry solves against its OWN factor tile (the packed
    Cholesky panel contract: batch dims x panel rows flattened)."""
    rng = np.random.default_rng(1)
    n = 16
    ls = jnp.stack([jnp.linalg.cholesky(_spd(rng, n) + n * jnp.eye(n, dtype=jnp.float32))
                    for _ in range(4)])
    bs = jnp.asarray(rng.standard_normal((4, 24, n)), jnp.float32)
    got = trsm_pallas(ls, bs, transpose=True, interpret=True)
    ref = jax.lax.linalg.triangular_solve(
        ls, bs, left_side=False, lower=True, transpose_a=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# packed Cholesky: parity + round-trip, exhaustive shapes
# ---------------------------------------------------------------------------

# odd n, rect operands, bn-misaligned (n % bn != 0), single-block, and
# bn larger than n (clamped by default_block_size)
CHOL_SHAPES = [
    (64, 48, 16), (100, 37, 8), (129, 65, 16), (300, 200, 64),
    (128, 128, 128), (96, 41, 64), (513, 129, 32), (40, 24, 256),
]


@pytest.mark.parametrize("m,n,bn", CHOL_SHAPES)
def test_packed_cholesky_matches_dense_cholesky(m, n, bn):
    rng = np.random.default_rng(n * 7 + bn)
    g = _packed_gram(rng, m, n, bn)
    f = solve.cholesky(g)
    assert isinstance(f, CholeskyFactor)
    assert f.blocks.shape == g.blocks.shape  # same packed geometry
    ref = jnp.linalg.cholesky(g.to_dense())
    np.testing.assert_allclose(np.asarray(f.to_dense()), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n,bn", CHOL_SHAPES[:4])
def test_packed_cholesky_round_trip(m, n, bn):
    rng = np.random.default_rng(n + bn)
    g = _packed_gram(rng, m, n, bn)
    ld = solve.cholesky(g).to_dense()
    gd = g.to_dense()
    np.testing.assert_allclose(np.asarray(ld @ ld.T), np.asarray(gd),
                               rtol=1e-4, atol=1e-4 * float(jnp.abs(gd).max()))


@pytest.mark.parametrize("batch", [(3,), (2, 2)])
def test_packed_cholesky_batch_dims(batch):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((*batch, 64, 40)), jnp.float32)
    flat = a.reshape(-1, 64, 40)
    g = ata_batched(flat, n_base=16, out="packed", packed_block=16)
    g = SymmetricMatrix(g.blocks.reshape(*batch, *g.blocks.shape[-3:]),
                        g.n, g.bn).add_scaled_identity(40.0)
    f = solve.cholesky(g)
    assert f.blocks.shape[:-3] == batch
    ref = jnp.linalg.cholesky(g.to_dense())
    np.testing.assert_allclose(np.asarray(f.to_dense()), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_packed_cholesky_bitwise_equals_dense_input_path():
    """cholesky(SymmetricMatrix) and cholesky(dense array of the same
    values) run the identical walk — results must be BITWISE equal."""
    rng = np.random.default_rng(3)
    g = _packed_gram(rng, 120, 72, 16)
    f_packed = solve.cholesky(g)
    f_dense = solve.cholesky(g.to_dense(), packed_block=16)
    np.testing.assert_array_equal(np.asarray(f_packed.blocks),
                                  np.asarray(f_dense.blocks))


def test_packed_cholesky_kernel_base_matches_jnp_base():
    """The Pallas base engines (interpret mode here) drive the same walk to
    the same factor within fp tolerance."""
    rng = np.random.default_rng(4)
    g = _packed_gram(rng, 80, 48, 16)
    f_jnp = solve.cholesky(g)
    f_kern = solve.cholesky(
        g, base_potrf=ops.potrf,
        base_trsm=lambda l, p: ops.trsm(l, p, transpose=True),
    )
    np.testing.assert_allclose(np.asarray(f_kern.to_dense()),
                               np.asarray(f_jnp.to_dense()),
                               rtol=1e-4, atol=1e-4)


def test_cholesky_factor_identity_and_pytree():
    f = CholeskyFactor.identity(40, 16, batch=(2,))
    np.testing.assert_array_equal(
        np.asarray(f.to_dense()), np.stack([np.eye(40, dtype=np.float32)] * 2)
    )
    leaves, treedef = jax.tree_util.tree_flatten(f)
    assert len(leaves) == 1
    f2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (f2.n, f2.bn) == (f.n, f.bn)


# ---------------------------------------------------------------------------
# triangular substitution
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transpose", [False, True])
@pytest.mark.parametrize("r", [1, 5])
def test_solve_triangular_matches_reference(transpose, r):
    rng = np.random.default_rng(7)
    g = _packed_gram(rng, 100, 56, 16)
    f = solve.cholesky(g)
    b = jnp.asarray(rng.standard_normal((56, r)), jnp.float32)
    got = solve.solve_triangular(f, b, transpose=transpose)
    ref = jax.lax.linalg.triangular_solve(
        f.to_dense(), b, left_side=True, lower=True, transpose_a=transpose
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_solve_triangular_vector_rhs_round_trip():
    rng = np.random.default_rng(8)
    g = _packed_gram(rng, 90, 33, 8)
    f = solve.cholesky(g)
    b = jnp.asarray(rng.standard_normal(33), jnp.float32)
    x = solve.solve_cholesky(f, b)
    assert x.shape == (33,)
    np.testing.assert_allclose(np.asarray(g.to_dense() @ x), np.asarray(b),
                               rtol=5e-3, atol=5e-3)


def test_solve_cholesky_matches_linalg_solve_batched():
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.standard_normal((3, 80, 40)), jnp.float32)
    g = ata_batched(a, n_base=16, out="packed", packed_block=16)
    g = g.add_scaled_identity(40.0)
    f = solve.cholesky(g)
    b = jnp.asarray(rng.standard_normal((3, 40, 2)), jnp.float32)
    x = solve.solve_cholesky(f, b)
    ref = jnp.linalg.solve(g.to_dense(), b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# lstsq front door
# ---------------------------------------------------------------------------


def test_lstsq_matches_jnp_lstsq():
    rng = np.random.default_rng(10)
    m, n, r = 200, 60, 3
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((m, r)), jnp.float32)
    x = solve.lstsq(a, b, method="factor")
    ref = jnp.linalg.lstsq(a, b)[0]
    np.testing.assert_allclose(np.asarray(x), np.asarray(ref),
                               rtol=1e-2, atol=1e-3)


def test_lstsq_ridge_shrinks_solution():
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((120, 40)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((120,)), jnp.float32)
    x0 = solve.lstsq(a, b, method="factor", ridge=1e-6)
    x1 = solve.lstsq(a, b, method="factor", ridge=1e3)
    assert float(jnp.linalg.norm(x1)) < float(jnp.linalg.norm(x0))


def test_lstsq_factor_vs_cg_agree():
    rng = np.random.default_rng(12)
    m, n = 300, 40  # tall: benign normal-equations conditioning
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    xt = jnp.asarray(rng.standard_normal((n, 2)), jnp.float32)
    b = a @ xt
    xf = solve.lstsq(a, b, method="factor")
    xc = solve.lstsq(a, b, method="cg")
    np.testing.assert_allclose(np.asarray(xf), np.asarray(xt), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(xc), np.asarray(xt), rtol=1e-3,
                               atol=1e-3)


def test_lstsq_packed_jaxpr_has_no_dense_square():
    """The acceptance criterion: the whole planned factor pipeline —
    packed gram, packed Cholesky, substitutions — must not materialize any
    (n, n) or (n_pad, n_pad) dense square in its jaxpr (the repro.check
    ``no-dense-square`` rule, run here against the real solve program)."""
    from repro import check

    # n > packed_block so block tiles != the square; m chosen so no input
    # row-slab of the recursion is coincidentally (n, n) (m = 2n would be)
    m, n, r = 384, 256, 4
    # recursion-forcing plan (same style as the PR 3 packed-retrieval
    # test): a degenerate single-leaf gram would legitimately emit one
    # (n, n) base tile, which is not the mirror this test polices.
    plan = dataclasses.replace(
        tune.plan(op="solve", m=m, n=n, k=r, out="packed", backend="cpu"),
        method="factor", algorithm="strassen", n_base=64,
    )
    assert plan.packed_block < n
    a_abs = jax.ShapeDtypeStruct((m, n), jnp.float32)
    b_abs = jax.ShapeDtypeStruct((m, r), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda a, b: solve.lstsq(a, b, ridge=1e-4, plan=plan)
    )(a_abs, b_abs)
    art = check.Artifact(label="solve:factor:packed", jaxpr=jaxpr.jaxpr,
                         plan=plan)
    report = check.run(art, rules=["no-dense-square"])
    assert not report.violations, report.summary()


# ---------------------------------------------------------------------------
# CG
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cond", [10.0, 1e3])
def test_cg_converges_on_conditioned_spd(cond):
    rng = np.random.default_rng(int(cond))
    n = 48
    g = _spd(rng, n, cond=cond)
    xt = jnp.asarray(rng.standard_normal((n, 2)), jnp.float32)
    b = g @ xt
    x = solve.cg_gram(lambda p: g @ p, b, iters=n * 2, tol=1e-10)
    np.testing.assert_allclose(np.asarray(x), np.asarray(xt),
                               rtol=2e-2, atol=2e-2)


def test_cg_vector_rhs_and_early_stop_masking():
    rng = np.random.default_rng(13)
    n = 32
    g = _spd(rng, n, cond=5.0)
    xt = jnp.asarray(rng.standard_normal(n), jnp.float32)
    b = g @ xt
    x = solve.cg_gram(lambda p: g @ p, b, iters=4 * n, tol=1e-12)
    assert x.shape == (n,)
    np.testing.assert_allclose(np.asarray(x), np.asarray(xt), rtol=1e-3,
                               atol=1e-3)


def test_cg_lstsq_never_forms_gram():
    """CG's jaxpr must hold no (n, n) intermediate either — the gram is an
    operator, not a matrix. Plan-less program: the ``forbidden_squares``
    override pins the rule's shape set directly."""
    from repro import check

    m, n = 256, 64
    a_abs = jax.ShapeDtypeStruct((m, n), jnp.float32)
    b_abs = jax.ShapeDtypeStruct((m,), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda a, b: solve.cg_lstsq(a, b, iters=8)
    )(a_abs, b_abs)
    art = check.Artifact(label="solve:cg", jaxpr=jaxpr.jaxpr,
                         overrides={"forbidden_squares": {(n, n)}})
    report = check.run(art, rules=["no-dense-square"])
    assert not report.violations, report.summary()


# ---------------------------------------------------------------------------
# planner: op='solve'
# ---------------------------------------------------------------------------


def test_solve_candidates_both_methods_scored():
    cands = tune.candidates("solve", 2048, 512, 4, backend="cpu")
    methods = {c.method for c in cands}
    assert methods == {"factor", "cg"}
    assert all(c.op == "solve" and c.predicted_s is not None for c in cands)
    assert cands[0].predicted_s <= cands[1].predicted_s


def test_solve_planner_prefers_cg_for_tall_skinny_few_rhs():
    """CG's iters·4mnr undercuts the factor's mn² when n is large relative
    to the CG budget and r is small; the analytic argmin must flip."""
    few = tune.candidates("solve", 4096, 4096, 1, backend="cpu")[0]
    many = tune.candidates("solve", 4096, 256, 256, backend="cpu")[0]
    assert few.method == "cg"
    assert many.method == "factor"


def test_solve_plan_front_door_and_cache_roundtrip(tmp_path):
    path = str(tmp_path / "cache.json")
    p = tune.plan(op="solve", m=512, n=128, k=8, out="packed",
                  backend="cpu", cache_file=path)
    assert p.op == "solve" and p.method in ("factor", "cg")
    p2 = tune.cost.Plan.from_json(p.to_json())
    assert p2 == p


def test_solve_plan_unknown_op_still_rejected():
    with pytest.raises(ValueError):
        tune.plan(op="potrf", m=8, n=8)


def test_solve_plan_rejects_batch():
    """lstsq takes one 2-D design matrix; a batched solve plan would be
    unexecutable (and untimeable by the autotuner) — rejected up front."""
    with pytest.raises(ValueError, match="unbatched"):
        tune.plan(op="solve", m=128, n=64, k=2, batch=3, backend="cpu")
    with pytest.raises(ValueError, match="unbatched"):
        tune.candidates("solve", 128, 64, 2, batch=3, backend="cpu")


def test_lstsq_pinned_method_bypasses_planner(tmp_path, monkeypatch):
    """lstsq(method=...) with no plan must not consult the tune front door
    (the bitwise-reproducibility contract of manual pins)."""
    import repro.tune.cache as cache_mod

    def _boom(*a, **k):  # pragma: no cover - must not be reached
        raise AssertionError("planner consulted despite pinned method")

    monkeypatch.setattr(cache_mod, "plan", _boom)
    rng = np.random.default_rng(20)
    a = jnp.asarray(rng.standard_normal((96, 40)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((96,)), jnp.float32)
    for method in ("factor", "cg"):
        x = solve.lstsq(a, b, method=method, ridge=1e-4)
        assert x.shape == (40,)


def test_symmetric_block_views_match_dense():
    """The block views the factor walk reads (block / diag_blocks /
    col_panel) agree with the corresponding to_dense() slices."""
    rng = np.random.default_rng(21)
    n = 56
    g = _packed_gram(rng, 100, n, 16)
    d = np.asarray(g.to_dense())
    bn, nb = g.bn, g.nb
    for i in range(nb):
        for j in range(i + 1):
            h = min(bn, n - i * bn)
            w = min(bn, n - j * bn)
            blk = np.asarray(g.block(i, j))[:h, :w]
            ref = d[i * bn : i * bn + h, j * bn : j * bn + w]
            if i == j:
                # diagonal tiles: LOWER halves are the authoritative
                # content (intra-tile upper corners may be unwritten —
                # to_dense's mirror reconstructs them)
                blk, ref = np.tril(blk), np.tril(ref)
            np.testing.assert_array_equal(blk, ref)
    with pytest.raises(ValueError):
        g.block(0, 1)
    panel = np.asarray(g.col_panel(0))
    assert panel.shape == (nb - 1, bn, bn)
    np.testing.assert_array_equal(panel[0], np.asarray(g.block(1, 0)))
    assert g.diag_blocks().shape == (nb, bn, bn)


def test_flop_counters_consistency():
    # unblocked potrf: classical n^3/3 leading term, exact small cases
    assert potrf_flops(1) == 1
    assert potrf_flops(2) == 1 + (1 + 1 + 2)  # col0: sqrt+div+update, col1: sqrt
    n = 64
    assert abs(potrf_flops(n) - n**3 / 3) / n**3 < 0.05
    assert trsm_flops(n, 8) == n * n * 8
    # blocked counter degenerates to the unblocked one at bn >= n
    assert blocked_potrf_flops(n, n) == potrf_flops(n)
    # and is dominated by the same n^3/3 term for finer grids
    total = blocked_potrf_flops(256, 64)
    assert 0.3 < total / (256**3 / 3) < 1.6
    assert classical_gemm_flops(2, 3, 4) == 48


# ---------------------------------------------------------------------------
# Shampoo p=2: packed Cholesky preconditioning
# ---------------------------------------------------------------------------


def _run_shampoo(precond_p, packed, steps=4):
    from repro.optim.shampoo import shampoo

    params = {"w": jnp.asarray(
        np.random.default_rng(0).standard_normal((96, 48)), jnp.float32)}
    grads = {"w": jnp.asarray(
        np.random.default_rng(1).standard_normal((96, 48)), jnp.float32)}
    opt = shampoo(lambda s: 1e-2, block=32, update_every=2,
                  precond_p=precond_p, packed_grams=packed,
                  n_base=16, gram_block=16)
    state = opt.init(params)
    u = None
    for _ in range(steps):
        u, state = jax.jit(opt.update)(grads, state, params)
    return u["w"], state


def test_shampoo_p2_packed_matches_dense_within_fp():
    u_packed, st_packed = _run_shampoo(2, True)
    u_dense, _ = _run_shampoo(2, False)
    np.testing.assert_allclose(np.asarray(u_packed), np.asarray(u_dense),
                               rtol=2e-3, atol=2e-3)
    # the p=2 preconditioner state IS packed factors — never densified
    s = jax.tree_util.tree_leaves(
        st_packed["shampoo"]["w"]["pl"],
        is_leaf=lambda x: isinstance(x, CholeskyFactor),
    )[0]
    assert isinstance(s, CholeskyFactor)


def test_shampoo_p4_path_unchanged_bitwise():
    u_packed, _ = _run_shampoo(4, True)
    u_dense, _ = _run_shampoo(4, False)
    np.testing.assert_array_equal(np.asarray(u_packed), np.asarray(u_dense))


def test_shampoo_rejects_bad_precond_p():
    from repro.optim.shampoo import shampoo

    with pytest.raises(ValueError):
        shampoo(lambda s: 1e-2, precond_p=3)


# ---------------------------------------------------------------------------
# PowerSGD packed whitening
# ---------------------------------------------------------------------------


def test_powersgd_whiten_packed_matches_dense():
    from repro.optim.powersgd import _whiten

    rng = np.random.default_rng(14)
    p = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    g_dense = jax.lax.dot_general(
        p, p, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    g_packed = SymmetricMatrix.from_dense(g_dense, 8)
    w_dense = _whiten(p, g_dense)
    w_packed = _whiten(p, g_packed)
    np.testing.assert_allclose(np.asarray(w_packed), np.asarray(w_dense),
                               rtol=2e-4, atol=2e-4)
    # whitened columns are orthonormal up to the ridge
    wtw = np.asarray(w_packed.T @ w_packed)
    np.testing.assert_allclose(wtw, np.eye(8), atol=1e-2)


# ---------------------------------------------------------------------------
# optional hypothesis sweep (mirrors test_core_ata's pattern)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(16, 160),
        n=st.integers(9, 96),
        bn=st.sampled_from([8, 16, 32, 64]),
    )
    def test_property_packed_cholesky_round_trip(m, n, bn):
        rng = np.random.default_rng(m * 1000 + n * 10 + bn)
        g = _packed_gram(rng, max(m, n), n, bn)
        ld = solve.cholesky(g).to_dense()
        gd = g.to_dense()
        np.testing.assert_allclose(
            np.asarray(ld @ ld.T), np.asarray(gd),
            rtol=1e-3, atol=1e-3 * float(jnp.abs(gd).max()),
        )

else:  # pragma: no cover

    @pytest.mark.skip(
        reason="hypothesis not installed (pip install -r requirements-dev.txt)"
    )
    def test_property_packed_cholesky_round_trip():
        pass
