"""Quickstart: the paper's ATA algorithm as a composable JAX op.

Covers: plain ``alpha·AᵀA`` (vs the classical product), the rectangular
FastStrassen ``AᵀB``, flop accounting (the paper's 2/3-of-Strassen claim),
a normal-equations solve, and the Pallas kernel base case.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ata, strassen_tn
from repro.core.reference import (
    ata_flops,
    classical_syrk_flops,
    strassen_tn_flops,
)
from repro.kernels import gemm_tn, syrk


def main():
    rng = np.random.default_rng(0)

    # --- 1. AᵀA, any rectangular shape, jit/vmap/grad-compatible ----------
    a = jnp.asarray(rng.standard_normal((1537, 771)), jnp.float32)  # odd dims
    c = jax.jit(lambda a: ata(a, n_base=256))(a)
    err = float(jnp.abs(c - a.T @ a).max() / jnp.abs(c).max())
    print(f"ata(1537x771): rel err vs classical = {err:.2e}  "
          f"(bitwise symmetric: {bool((c == c.T).all())})")

    # --- 2. rectangular Strassen AᵀB --------------------------------------
    b = jnp.asarray(rng.standard_normal((1537, 500)), jnp.float32)
    cb = strassen_tn(a, b, n_base=256)
    print(f"strassen_tn(AᵀB): rel err = "
          f"{float(jnp.abs(cb - a.T @ b).max() / jnp.abs(cb).max()):.2e}")

    # --- 3. the paper's flop claim ----------------------------------------
    n = 1 << 14
    r_strassen = ata_flops(n, n, 512) / strassen_tn_flops(n, n, n, 512)
    r_classic = ata_flops(n, n, 512) / classical_syrk_flops(n, n)
    print(f"flops @ n=16384: ATA/Strassen = {r_strassen:.3f} (→ 2/3), "
          f"ATA/classical-syrk = {r_classic:.3f}")

    # --- 4. application: least squares via normal equations ----------------
    x_true = rng.standard_normal(771).astype(np.float32)
    y = a @ x_true + 0.01 * rng.standard_normal(1537).astype(np.float32)
    gram = ata(a, n_base=256) + 1e-4 * jnp.eye(771)
    x_hat = jnp.linalg.solve(gram, a.T @ y)
    print(f"normal equations: ||x̂ − x||/||x|| = "
          f"{float(jnp.linalg.norm(x_hat - x_true) / jnp.linalg.norm(x_true)):.3e}")

    # --- 5. Pallas kernels as the recursion base case ----------------------
    a_small = jnp.asarray(rng.standard_normal((512, 384)), jnp.float32)
    c_k = ata(
        a_small,
        n_base=128,
        base_syrk=lambda x: syrk(x, blocks=(128, 128)),
        base_dot=lambda x, y: gemm_tn(x, y, blocks=(128, 128, 128)),
    )
    print(f"ata with Pallas base (interpret on CPU): rel err = "
          f"{float(jnp.abs(c_k - a_small.T @ a_small).max()):.2e}")


if __name__ == "__main__":
    main()
