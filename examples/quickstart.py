"""Quickstart: the paper's ATA algorithm as a composable, *planned* JAX op.

Covers: the ``repro.tune.plan`` front door (plan → ata → packed result —
the documented entry point), plain ``alpha·AᵀA`` vs the classical product,
the rectangular FastStrassen ``AᵀB``, flop accounting (the paper's
2/3-of-Strassen claim), packed-native least squares (plan → ata →
``solve.lstsq`` — the gram is factored and solved without ever being
densified), the Pallas kernel base case, and the ``repro.obs``
observability switch (spans + metrics snapshot + calibration drift).

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, solve, tune
from repro.core import ata, strassen_tn
from repro.core.reference import (
    ata_flops,
    classical_syrk_flops,
    strassen_tn_flops,
)


def main():
    rng = np.random.default_rng(0)

    # --- 1. the front door: plan → ata → packed result ---------------------
    # Every dispatch tunable (algorithm variant, recursion cutoff, kernel
    # blocks, packed block size) is decided by the cost model — or by the
    # measured autotuner with plan(..., autotune=True) — never hardcoded.
    a = jnp.asarray(rng.standard_normal((1537, 771)), jnp.float32)  # odd dims
    p = tune.plan(op="ata", m=1537, n=771, out="packed")
    # cached measured plans carry measured_s but may lack a prediction
    cost_s = p.measured_s or p.predicted_s
    cost_str = f"{cost_s:.2e}s" if cost_s is not None else "n/a"
    print(f"plan: algorithm={p.algorithm} n_base={p.n_base} "
          f"packed_block={p.packed_block} backend={p.backend} "
          f"source={p.source} cost={cost_str}")

    packed = jax.jit(lambda a: ata(a, plan=p, out="packed"))(a)
    print(f"packed result: {packed.t_total} lower-tri blocks of "
          f"{packed.bn}x{packed.bn} ({packed.nbytes} bytes vs "
          f"{packed.dense_nbytes(packed.n)} dense)")

    # --- 2. dense output of the same plan is bitwise the packed mirror -----
    dense = jax.jit(lambda a: ata(a, plan=p))(a)
    err = float(jnp.abs(dense - a.T @ a).max() / jnp.abs(dense).max())
    print(f"ata(1537x771): rel err vs classical = {err:.2e}  "
          f"(bitwise symmetric: {bool((dense == dense.T).all())}, "
          f"packed==dense: {bool((packed.to_dense() == dense).all())})")

    # --- 3. rectangular Strassen AᵀB (self-planned: no plan pinned) --------
    b = jnp.asarray(rng.standard_normal((1537, 500)), jnp.float32)
    cb = strassen_tn(a, b)
    print(f"strassen_tn(AᵀB): rel err = "
          f"{float(jnp.abs(cb - a.T @ b).max() / jnp.abs(cb).max()):.2e}")

    # --- 4. the paper's flop claim at the planned cutoff --------------------
    n = 1 << 14
    big = tune.plan(op="ata", m=n, n=n)
    nb = big.n_base
    r_strassen = ata_flops(n, n, nb) / strassen_tn_flops(n, n, n, nb)
    r_classic = ata_flops(n, n, nb) / classical_syrk_flops(n, n)
    print(f"flops @ n=16384 (planned n_base={nb}): ATA/Strassen = "
          f"{r_strassen:.3f} (→ 2/3), ATA/classical-syrk = {r_classic:.3f}")

    # --- 5. application: packed-native least squares (repro.solve) ---------
    # The ten-line front door: the planner prices factor-vs-CG for this
    # shape/RHS count, the gram comes out of the planned ata packed, the
    # Cholesky factors it in place, and two packed substitutions finish —
    # no dense (771, 771) matrix exists anywhere in the pipeline.
    x_true = rng.standard_normal(771).astype(np.float32)
    y = a @ x_true + 0.01 * rng.standard_normal(1537).astype(np.float32)
    sp = tune.plan(op="solve", m=1537, n=771, k=1, out="packed")
    x_hat = solve.lstsq(a, y, ridge=1e-4, plan=sp)
    print(f"solve.lstsq (method={sp.method}, algorithm={sp.algorithm}): "
          f"||x̂ − x||/||x|| = "
          f"{float(jnp.linalg.norm(x_hat - x_true) / jnp.linalg.norm(x_true)):.3e}")

    # --- 6. Pallas kernels as the recursion base case -----------------------
    # On TPU the planner sets use_kernels=True by itself; forcing it here
    # shows the same plan driving the Pallas base engines (interpret mode
    # on CPU, so keep the operand small).
    a_small = jnp.asarray(rng.standard_normal((512, 384)), jnp.float32)
    pk = dataclasses.replace(
        tune.plan(op="ata", m=512, n=384), use_kernels=True
    )
    c_k = ata(a_small, plan=pk)  # base_syrk/base_dot built from the plan
    print(f"ata with Pallas base (interpret on CPU): max err = "
          f"{float(jnp.abs(c_k - a_small.T @ a_small).max()):.2e}")

    # --- 7. observability: obs.enable() → ata → metrics snapshot ------------
    # Counters (dispatch/leaf/cache accounting) are always on; enable() adds
    # spans (named_scope regions per recursion level, zero jaxpr ops) and
    # per-dispatch calibration of the cost model's predicted_s against wall
    # clock. Disabled, every instrumented path is bitwise-identical.
    obs.enable()
    # a recursing batched plan so the per-level spans have levels to name
    pr = dataclasses.replace(p, n_base=128, leaf_dispatch="batched",
                             source="analytic")
    _ = ata(a, plan=pr, out="packed")  # eager: times itself vs predicted_s
    snap = obs.metrics.snapshot()  # JSON-ready, schema "repro.obs/v1"
    obs.metrics.validate_snapshot(snap)
    print(f"obs: dispatch.ata.* counters = "
          f"{ {k: v for k, v in snap['counters'].items() if k.startswith('dispatch.ata')} }, "
          f"spans = {sorted(snap['spans'])}, "
          f"calibration rows = {len(snap['calibration'])}")
    print(obs.report())  # predicted-vs-measured drift table (DESIGN.md §8)
    obs.disable()

    # --- 8. static contract checks: check.trace_plan → check.run ------------
    # The structural invariants behind all of the above (no dense (n, n)
    # square on packed paths, no materialized Aᵀ, dot/launch counts equal
    # to the cost model's closed forms, f32 accumulation) are machine-
    # checked: trace the exact planned callable and run the rule registry
    # (DESIGN.md §9; CI gates on `python -m repro.check`).
    from repro import check

    art = check.trace_plan(p)   # the step-1 packed plan
    report = check.run(art)
    print(f"repro.check: {len(list(check.rule_ids()))} rules over "
          f"'{art.label}' → {len(report.violations)} violations")
    assert not report.violations, report.summary()

    # --- 9. gram-as-a-service: the serving layer (repro.serve) -------------
    # The ten-line serving story (DESIGN.md §10): warm once (plans + XLA,
    # off the request path), then heterogeneous lstsq requests micro-batch
    # by plan key into single launches — bitwise-equal to per-request
    # solve.lstsq, zero steady-state retraces, p95 from the obs snapshot.
    from repro.serve import Request, Server, metrics as serve_metrics, smoke_config

    server = Server(smoke_config())
    server.warm()
    tickets = [server.submit(Request(
        op="lstsq", a=rng.standard_normal((40 + i % 8, 32)).astype(np.float32),
        b=rng.standard_normal((40 + i % 8, 1 + i % 4)).astype(np.float32),
        ridge=1e-4)) for i in range(100)]
    server.drain()
    serve_metrics.publish_percentiles()
    snap = obs.metrics.snapshot()
    print(f"serve: {sum(t.done() for t in tickets)}/100 served, "
          f"retraces={server.retraces()}, request p95 = "
          f"{snap['gauges']['serve.latency.request.p95']*1e3:.2f}ms")


if __name__ == "__main__":
    main()
