"""The paper's algorithm inside the optimizer: ATA-powered Shampoo.

Trains a small MLP classifier twice — AdamW vs Shampoo (whose L/R
preconditioner statistics are the paper's ``AᵀA`` products computed by
``repro.core.ata``) — and prints the loss curves, plus a distributed gram
demo with the ATA-S/ATA-D tile schedule on a host-platform mesh.

    PYTHONPATH=src python examples/gram_shampoo.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import ata_tile_parallel
from repro.optim import adamw, apply_updates, constant, shampoo


def train(opt_name: str, steps: int = 150):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((512, 64)), jnp.float32)
    w_true = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    y = jnp.tanh(x @ w_true) @ jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)

    params = {
        "w1": jnp.asarray(rng.standard_normal((64, 32)) * 0.1, jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((32, 8)) * 0.1, jnp.float32),
    }
    # no n_base pin: the gram dispatches are planned per block shape
    opt = (adamw(constant(3e-3)) if opt_name == "adamw"
           else shampoo(constant(3e-3), block=32, update_every=5))
    state = opt.init(params)

    def loss_fn(p):
        return jnp.mean((jnp.tanh(x @ p["w1"]) @ p["w2"] - y) ** 2)

    @jax.jit
    def step(p, s):
        l, g = jax.value_and_grad(loss_fn)(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, l

    curve = []
    for i in range(steps):
        params, state, l = step(params, state)
        if i % 30 == 0 or i == steps - 1:
            curve.append((i, float(l)))
    return curve


def main():
    for name in ["adamw", "shampoo"]:
        curve = train(name)
        pts = "  ".join(f"{i}:{l:.4f}" for i, l in curve)
        print(f"{name:8s} loss: {pts}")

    # distributed gram on this host's device pool (1 device here; run with
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 for real sharding)
    from repro.compat import make_mesh

    mesh = make_mesh((len(jax.devices()),), ("model",))
    a = jnp.asarray(np.random.default_rng(1).standard_normal((1024, 512)), jnp.float32)
    c = ata_tile_parallel(a, mesh, task_axis="model")
    print(f"distributed gram (P={len(jax.devices())}): rel err = "
          f"{float(jnp.abs(c - a.T @ a).max() / jnp.abs(c).max()):.2e}")
    # packed retrieval (paper Prop. 4.2): the result never leaves low(C)
    # form — ~half the payload of the dense replicated square
    s = ata_tile_parallel(a, mesh, task_axis="model", out="packed")
    ratio = s.nbytes / s.dense_nbytes(s.n)
    err = float(jnp.abs(s.to_dense() - a.T @ a).max() / jnp.abs(c).max())
    print(f"packed retrieval: {type(s).__name__} blocks={s.blocks.shape} "
          f"({ratio:.2f}x dense bytes), rel err = {err:.2e}")


if __name__ == "__main__":
    main()
