"""Serving example: batched prefill + decode with slot-based batching.

Runs the serving driver on a reduced config (CPU-sized); on TPU the same
code paths serve the full configs with the production mesh.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen1.5-0.5b]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    serve_driver.main([
        "--arch", args.arch, "--smoke",
        "--requests", str(args.requests),
        "--batch", "4", "--prompt-len", "32", "--gen-len", "16",
    ])


if __name__ == "__main__":
    main()
