"""End-to-end training example: a ~100M-param LM for a few hundred steps.

Uses the full production stack (sharded train step, checkpointing,
preemption guard, deterministic pipeline) via the ``repro.launch.train``
driver. The model is a scaled qwen-family config (~100M params); loss on
the synthetic Zipf-Markov stream drops well below log(V) within a few
hundred steps, demonstrating real learning end to end.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.launch import train as train_driver

# ~100M params: 12L d=512 8H ffn=2048 vocab=32k
LM_100M = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=32_000,
    qkv_bias=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "shampoo"])
    ap.add_argument("--out", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # register the example config so the driver can resolve it
    registry.ARCHS["lm-100m"] = LM_100M
    registry.SMOKES["lm-100m"] = LM_100M
    print(f"lm-100m parameters: {LM_100M.num_params()/1e6:.1f}M")

    final_loss = train_driver.main([
        "--arch", "lm-100m",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--optimizer", args.optimizer,
        "--out", args.out,
        "--log-every", "20",
        "--save-every", "100",
    ])
    import math
    print(f"final loss {final_loss:.3f} vs uniform log(V) = "
          f"{math.log(LM_100M.vocab_size):.3f}")


if __name__ == "__main__":
    main()
