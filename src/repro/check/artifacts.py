"""Traced artifacts and the one canonical jaxpr traversal.

An :class:`Artifact` is what the rules see: a jaxpr (plus, optionally, the
compiled HLO text for collective accounting) together with the governing
:class:`repro.tune.cost.Plan` — the closed-form predictions the budget
rules compare the program against. ``overrides`` lets a call site without
a full plan (or with a stricter local contract than the plan implies) pin
individual rule parameters; see :mod:`repro.check.rules` for the keys each
rule reads.

:func:`walk_eqns` is the single recursive traversal that replaces the five
hand-rolled walkers the test suite used to carry: it descends into every
nested jaxpr reachable through equation params (``pjit``, ``shard_map``,
``scan``/``while``/``cond`` bodies, custom-call wrappers …) and — by
default — treats ``pallas_call`` bodies as opaque. In-kernel equations are
tile-granular by the kernels' block contract; the structural invariants the
rules police (dense squares, operand stacks, full transposes, dispatch
counts) are wrapper-level properties, so counting inside kernel bodies
would double-book every leaf. Pass ``into_pallas=True`` to audit kernel
bodies too.

:func:`trace_plan` is the harness entry: it traces the exact callable the
autotuner times (``tune.apply.build_callable``) on abstract operands of the
plan's shape, so the program the checker sees IS the program the plan
dispatches — no parallel re-implementation of the dispatch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

__all__ = ["Artifact", "EqnSite", "walk_eqns", "abstract_args",
           "plan_label", "trace_plan"]


@dataclasses.dataclass(frozen=True)
class EqnSite:
    """One equation plus its provenance in the traversal."""

    path: Tuple[str, ...]   # enclosing primitive names, outermost first
    index: int              # eqn index within its own jaxpr
    eqn: Any                # jax.core.JaxprEqn


@dataclasses.dataclass
class Artifact:
    """One traced program under one plan — the unit the rules analyze."""

    label: str
    jaxpr: Any                          # jax.core.Jaxpr (ClosedJaxpr.jaxpr)
    plan: Optional[Any] = None          # repro.tune.cost.Plan
    hlo_text: Optional[str] = None      # compiled per-device HLO, if lowered
    overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def sites(self, *, into_pallas: bool = False) -> Iterator[EqnSite]:
        return walk_eqns(self.jaxpr, into_pallas=into_pallas)


def _subjaxprs(value) -> Iterator[Any]:
    """Jaxprs reachable from one equation param value.

    Accepts a ClosedJaxpr (→ its ``.jaxpr``), a raw Jaxpr, or a list/tuple
    of either (``cond`` branches); anything else yields nothing.
    """
    for x in (value if isinstance(value, (list, tuple)) else (value,)):
        j = getattr(x, "jaxpr", x)      # ClosedJaxpr → Jaxpr; Jaxpr → itself
        if hasattr(j, "eqns") and hasattr(j, "outvars"):
            yield j


def walk_eqns(jaxpr, *, into_pallas: bool = False,
              _path: Tuple[str, ...] = ()) -> Iterator[EqnSite]:
    """Yield every equation of ``jaxpr`` and its nested jaxprs, depth-first.

    ``pallas_call`` bodies are opaque unless ``into_pallas=True`` (see
    module docstring). The yielded :class:`EqnSite` carries the enclosing
    primitive path and the eqn's index in its own jaxpr — the provenance
    findings report.
    """
    for i, eqn in enumerate(jaxpr.eqns):
        yield EqnSite(_path, i, eqn)
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            continue
        sub_path = _path + (eqn.primitive.name,)
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from walk_eqns(sub, into_pallas=into_pallas,
                                     _path=sub_path)


def abstract_args(plan) -> tuple:
    """Abstract operands matching ``tune.apply.build_callable(plan)``'s
    signature — the same shapes the autotuner would time."""
    import jax
    import jax.numpy as jnp

    dt = jnp.dtype(plan.dtype)
    lead = (plan.batch,) if plan.batch else ()
    a = jax.ShapeDtypeStruct(lead + (plan.m, plan.n), dt)
    if plan.op in ("gemm_tn", "solve"):
        b = jax.ShapeDtypeStruct(lead + (plan.m, plan.k), dt)
        return (a, b)
    return (a,)


def plan_label(plan) -> str:
    """Stable artifact label for a plan — the allowlist's match key."""
    parts = [
        plan.op, plan.algorithm, plan.leaf_dispatch,
        "kern" if plan.use_kernels else "xla", plan.out,
        f"{plan.m}x{plan.n}x{plan.k}", plan.dtype,
    ]
    if plan.method:
        parts.append(plan.method)
    if plan.devices > 1:
        parts.append(f"dist{plan.devices}")
    return ":".join(parts)


def trace_plan(plan, *, lower: bool = False,
               label: Optional[str] = None) -> Artifact:
    """Trace ``build_callable(plan)`` into an :class:`Artifact`.

    ``lower=True`` additionally compiles and attaches the per-device HLO
    text (one lowering, shared with the collective accounting — see
    :func:`repro.analysis.hlo.compiled_text`).
    """
    import jax

    from repro.tune import apply

    fn = apply.build_callable(plan)
    args = abstract_args(plan)
    closed = jax.make_jaxpr(fn)(*args)
    hlo = None
    if lower:
        from repro.analysis.hlo import compiled_text

        hlo = compiled_text(fn, *args)
    return Artifact(label=label or plan_label(plan), jaxpr=closed.jaxpr,
                    plan=plan, hlo_text=hlo)
