"""Structured findings, the allowlist, and the check report.

A :class:`Finding` is one rule violation pinned to a traced artifact: rule
id, severity, a human message, and the offending equation's provenance
(primitive name, enclosing-jaxpr path, equation index, output shape).
Findings are plain frozen data — the analyzer never raises on a violation;
it *reports*, and the CLI turns unallowlisted errors into a nonzero exit.

The allowlist is the mechanism for *intentional* violations: an
:class:`Allow` entry names a rule id and an ``fnmatch`` pattern over
artifact labels, plus a mandatory reason (the policy mirror of the
``# repro.check: allow(<rule-id>)`` comments at the source sites — see
DESIGN.md §9). Allowlisted findings stay in the report (auditable) but do
not fail the run.
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Iterable, List, Optional, Sequence, Tuple

__all__ = ["Finding", "Allow", "Report", "REPORT_SCHEMA", "SEVERITIES"]

REPORT_SCHEMA = "repro.check/v1"
SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation on one traced artifact."""

    rule: str
    message: str
    artifact: str = ""
    severity: str = "error"
    # eqn provenance: the primitive that produced the offending value, the
    # enclosing-jaxpr primitive path (e.g. ('pjit', 'scan')) and the eqn's
    # index within its own jaxpr — enough to find it in a printed jaxpr.
    primitive: Optional[str] = None
    path: Tuple[str, ...] = ()
    eqn_index: Optional[int] = None
    shape: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {SEVERITIES}"
            )

    @property
    def provenance(self) -> str:
        """``pjit/scan eqn#12 (transpose)`` — where in the jaxpr."""
        where = "/".join(self.path) or "<top>"
        eqn = f" eqn#{self.eqn_index}" if self.eqn_index is not None else ""
        prim = f" ({self.primitive})" if self.primitive else ""
        return f"{where}{eqn}{prim}"

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["path"] = list(self.path)
        d["shape"] = list(self.shape) if self.shape is not None else None
        d["provenance"] = self.provenance
        return d


@dataclasses.dataclass(frozen=True)
class Allow:
    """One allowlist entry: rule id + artifact-label pattern + reason."""

    rule: str
    artifact: str = "*"          # fnmatch pattern over artifact labels
    reason: str = ""

    def matches(self, f: Finding) -> bool:
        return self.rule == f.rule and fnmatch.fnmatch(f.artifact, self.artifact)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Report:
    """Accumulates findings across artifacts, partitioned by the allowlist.

    ``violations`` (error-severity, not allowlisted) drive the CLI exit
    code; everything — including allowlisted findings — lands in the JSON
    report for audit.
    """

    def __init__(self, allowlist: Sequence[Allow] = ()):
        self.allowlist: List[Allow] = list(allowlist)
        self.findings: List[Finding] = []
        self.allowlisted: List[Finding] = []
        self.artifacts: List[dict] = []   # {label, rules, findings} per artifact

    def add(self, findings: Iterable[Finding]) -> List[Finding]:
        """Partition ``findings`` by the allowlist; returns the kept ones."""
        kept = []
        for f in findings:
            if any(a.matches(f) for a in self.allowlist):
                self.allowlisted.append(f)
            else:
                self.findings.append(f)
                kept.append(f)
        return kept

    def record_artifact(self, label: str, rules: Sequence[str],
                        n_findings: int) -> None:
        self.artifacts.append(
            {"label": label, "rules": list(rules), "findings": n_findings}
        )

    @property
    def violations(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def exit_code(self) -> int:
        return 1 if self.violations else 0

    def merge(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        self.allowlisted.extend(other.allowlisted)
        self.artifacts.extend(other.artifacts)
        return self

    def to_json(self) -> dict:
        try:
            import jax

            meta = {"backend": jax.default_backend(),
                    "jax_version": jax.__version__}
        except Exception:                          # pragma: no cover
            meta = {"backend": "unknown", "jax_version": "unknown"}
        return {
            "schema": REPORT_SCHEMA,
            "meta": meta,
            "artifacts": self.artifacts,
            "findings": [f.to_json() for f in self.findings],
            "allowlisted": [f.to_json() for f in self.allowlisted],
            "allowlist": [a.to_json() for a in self.allowlist],
            "counts": {
                "artifacts": len(self.artifacts),
                "findings": len(self.findings),
                "violations": len(self.violations),
                "allowlisted": len(self.allowlisted),
            },
        }

    def summary(self) -> str:
        lines = [
            f"repro.check: {len(self.artifacts)} artifacts, "
            f"{len(self.findings)} findings "
            f"({len(self.violations)} violations, "
            f"{len(self.allowlisted)} allowlisted)"
        ]
        for f in self.findings:
            lines.append(
                f"  [{f.severity}] {f.rule} @ {f.artifact}: {f.message}"
                f"  [{f.provenance}]"
            )
        return "\n".join(lines)
