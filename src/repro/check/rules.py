"""The rule registry: the repo's structural invariants as machine checks.

Every rule is a function ``(Artifact) -> list[Finding]`` registered under a
stable id. Rules are *self-gating*: each decides from the artifact's plan
(or ``overrides``) whether it applies, and returns ``[]`` when it doesn't —
so the runner can always throw the whole registry at every artifact.

The shipped rules, and the contracts they encode (DESIGN.md §9 carries the
full taxonomy; the source contracts live in ``kernels/__init__.py`` and the
module docstrings of ``core.ata`` / ``core.strassen`` / ``solve``):

================== ========================================================
``no-dense-square``   packed paths never materialize an ``(n, n)`` /
                      ``(n_pad, n_pad)`` square (paper Prop. 4.2's low(C)).
``no-operand-stacks`` fused dispatch never materializes a ``7``-multiple
                      leaf *operand* stack (the batched dispatch's
                      signature traffic) — combines live in the prologue.
``dot-budget``        ``dot_general`` count equals the closed-form leaf
                      count the cost model prices (``tune.cost``).
``launch-budget``     ``pallas_call`` count equals the kernel-path closed
                      form and never exceeds ``cost.dispatch_calls``.
``no-full-transpose`` the TN contract: no 2-D transpose above tile
                      granularity, except the single dense-ATA root mirror.
``acc-dtype``         every dot accumulates at ≥ the plan's accumulator
                      width (f32) — sub-f32 accumulation never sneaks in
                      via dtype promotion.
``no-vmap-of-pallas`` kernels batch through their native leading grid
                      dimension, never through vmap.
``collective-budget`` reduction-collective bytes (all-reduce +
                      reduce-scatter, per device) stay within the
                      ``cost.retrieval_bytes`` payload the planner prices;
                      BFS-containing plans get the tighter one-chunk
                      reduce-scatter budget (``ceil(T/P)·w²``).
================== ========================================================

Override keys (``Artifact.overrides``) let plan-less call sites pin rule
parameters; each rule documents the keys it reads. Intentional violations
are suppressed through the report-level allowlist
(:class:`repro.check.findings.Allow`), never by weakening a rule.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.check.artifacts import Artifact
from repro.check.findings import Allow, Finding, Report

__all__ = ["Rule", "REGISTRY", "rule", "run", "run_many", "rule_ids"]


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    severity: str
    doc: str
    fn: Callable[[Artifact], List[Finding]]


REGISTRY: Dict[str, Rule] = {}


def rule(rule_id: str, severity: str = "error"):
    """Register a rule function under ``rule_id``."""

    def deco(fn):
        REGISTRY[rule_id] = Rule(rule_id, severity,
                                 (fn.__doc__ or "").strip(), fn)
        return fn

    return deco


def rule_ids() -> List[str]:
    return sorted(REGISTRY)


# ---------------------------------------------------------------------------
# shared plan geometry
# ---------------------------------------------------------------------------


def _finding(art: Artifact, rule_id: str, message: str, site=None,
             shape=None) -> Finding:
    return Finding(
        rule=rule_id, message=message, artifact=art.label,
        severity=REGISTRY[rule_id].severity if rule_id in REGISTRY else "error",
        primitive=site.eqn.primitive.name if site else None,
        path=site.path if site else (),
        eqn_index=site.index if site else None,
        shape=tuple(shape) if shape is not None else None,
    )


def _depth(plan) -> int:
    """Recursion depth of the plan's product tree (0 for algorithm='dense'
    and for trees the cutoff covers entirely)."""
    from repro.core.strassen import tree_depth

    if plan.algorithm == "dense":
        return 0
    dims = (plan.m, plan.n, plan.k) if plan.op == "gemm_tn" else (plan.m, plan.n)
    return tree_depth(dims, plan.n_base)


def _packed_bn(plan) -> int:
    """Effective packed block (the grid clamp every producer shares)."""
    from repro.core.symmetric import default_block_size

    return default_block_size(plan.n, plan.packed_block)


def _ceil_half(d: int, times: int) -> int:
    for _ in range(times):
        d = (d + (d & 1)) // 2
    return d


def _itemsize(dtype_str: str) -> int:
    import jax.numpy as jnp

    return jnp.dtype(dtype_str).itemsize


# ---------------------------------------------------------------------------
# the rules
# ---------------------------------------------------------------------------


@rule("no-dense-square")
def no_dense_square(art: Artifact) -> List[Finding]:
    """Packed paths must never materialize a dense ``(n, n)`` or padded
    ``(n_pad, n_pad)`` square — the whole point of packed retrieval.

    Applies to plans with ``out='packed'`` (op='solve' included: the gram,
    factor, and substitutions are all packed-native). A degenerate
    single-block grid (``bn ≥ n``) legitimately holds the square as its one
    block, so the rule requires a real block grid. On the kernel path,
    ``pallas_call`` outputs padded up to the plan's block shapes can
    coincide with ``(n, n)`` when a block dim reaches ``n`` — padding
    granularity, not a gram square — so kernel launches whose output fits
    inside one block are exempt. Overrides: ``forbidden_squares`` —
    explicit set of (r, c) trailing shapes.
    """
    plan = art.plan
    forbidden = art.overrides.get("forbidden_squares")
    block_pad = 0
    if forbidden is None:
        if plan is None or plan.out != "packed":
            return []
        n = plan.n
        bn = _packed_bn(plan)
        if bn >= n:
            return []        # single-block grid: the square IS the block
        if plan.op != "solve" and _depth(plan) == 0:
            return []        # single-leaf gram: one (n, n) base tile is legal
        n_pad = -(-n // bn) * bn
        forbidden = {(n, n), (n_pad, n_pad)}
        if plan.use_kernels:
            block_pad = max(plan.syrk_blocks + plan.gemm_blocks)
    forbidden = {tuple(s) for s in forbidden}
    out = []
    for site in art.sites():
        if (site.eqn.primitive.name == "pallas_call" and block_pad
                and all(max(tuple(v.aval.shape)[-2:] or (0,)) <= block_pad
                        for v in site.eqn.outvars)):
            continue         # block-padded leaf tiles, bounded by the spec
        for v in site.eqn.outvars:
            shape = tuple(getattr(v.aval, "shape", ()))
            if shape[-2:] in forbidden:
                out.append(_finding(
                    art, "no-dense-square",
                    f"dense square {shape} materialized on a packed path",
                    site, shape))
    return out


@rule("no-operand-stacks")
def no_operand_stacks(art: Artifact) -> List[Finding]:
    """Fused dispatch must not materialize leaf *operand* stacks.

    The fused-leaf contract (``kernels/__init__.py``): operand ± combines
    happen in the kernel prologue (or per-leaf at trace time on the XLA
    path) — never as a cross-leaf ``(…·7^i, m_L, n_L)`` stack in HBM. The
    discriminator is exact: Strassen operand stacks carry a leading-dim
    product divisible by 7, while the legal block-major relayouts are
    power-of-two-leading and the ATA diagonal stack is ``4^L``-leading.
    Product/decode stacks (trailing ``(n_L, k_L)``) are excluded — those
    the fused dispatch *does* materialize, by design.

    Applies to ``leaf_dispatch='fused'`` product plans with depth ≥ 1.
    """
    plan = art.plan
    if (plan is None or plan.leaf_dispatch != "fused"
            or plan.op not in ("ata", "gemm_tn")):
        return []
    lv = _depth(plan)
    if lv == 0:
        return []
    m_l, n_l = _ceil_half(plan.m, lv), _ceil_half(plan.n, lv)
    if plan.op == "gemm_tn":
        k_l = _ceil_half(plan.k, lv)
        forbidden = {(m_l, n_l), (m_l, k_l)} - {(n_l, k_l)}
    else:
        # both operands of the off-diagonal leaves are A-blocks; the
        # product tile is (n_l, n_l), excluded when indistinguishable
        forbidden = {(m_l, n_l)} - {(n_l, n_l)}
    if not forbidden:
        return []            # square leaves: operand ≡ product shape
    out = []
    for site in art.sites():
        for v in site.eqn.outvars:
            shape = tuple(getattr(v.aval, "shape", ()))
            if len(shape) < 3 or shape[-2:] not in forbidden:
                continue
            lead = math.prod(shape[:-2])
            if lead > 1 and lead % 7 == 0:
                out.append(_finding(
                    art, "no-operand-stacks",
                    f"materialized operand stack {shape} under fused "
                    f"dispatch (leading {lead} ≡ 0 mod 7)",
                    site, shape))
    return out


def _expected_dots(plan) -> Optional[int]:
    """Closed-form ``dot_general`` count of the XLA dispatch, or None when
    the rule has no exact form (see ``dot-budget`` docstring)."""
    from repro.tune import cost

    if plan.op in ("ata", "gemm_tn"):
        lv = _depth(plan)
        if lv == 0:
            return 1                       # one classical dot, any dispatch
        if plan.op == "ata":
            s, g = cost._ata_leaves(plan.m, plan.n, plan.n_base)
            return {"unrolled": s + g, "batched": 2,
                    "fused": g + 1}[plan.leaf_dispatch]
        leaves = cost._strassen_leaves(plan.m, plan.n, plan.k, plan.n_base)
        return {"unrolled": leaves, "batched": 1,
                "fused": leaves}[plan.leaf_dispatch]
    if plan.op == "solve":
        if plan.method == "cg":
            # Aᵀb + one loop body (A·p plus the planned TN leaves); the
            # body is traced once regardless of the iteration budget
            n_base = (max(plan.n_base, plan.m, plan.n)
                      if plan.algorithm == "dense" else plan.n_base)
            leaves = cost._strassen_leaves(plan.m, plan.n, plan.k, n_base)
            return 1 + 2 * leaves
        # factor: gram + Aᵀb + the blocked factor/substitution einsums
        # (per block column: one Schur update against the finished panel
        # row, one cross-panel update, and one update per substitution
        # pass — all lowering to dot_general)
        gram_plan = dataclasses.replace(plan, op="ata", k=plan.n)
        gram = _expected_dots(gram_plan)
        nbk = -(-plan.n // _packed_bn(plan))
        return gram + 1 + (nbk - 1) + max(nbk - 2, 0) + 2 * (nbk - 1)
    return None


@rule("dot-budget")
def dot_budget(art: Artifact) -> List[Finding]:
    """The jaxpr's ``dot_general`` count must equal the closed-form leaf
    count the cost model prices.

    This is the cost model cross-checked against the program it prices:
    unrolled = one dot per leaf (``cost._ata_leaves`` /
    ``cost._strassen_leaves`` — exactly ``cost.dispatch_calls``), batched =
    O(1) batched dots, fused = per-leaf trace-time gathers feeding one dot
    per off-diagonal leaf plus the gathered diagonal syrk. Solve plans get
    the gram's form plus the factor/substitution einsum band (method=
    'factor') or the CG operator pair (method='cg'). Applies to XLA-path
    plans (``use_kernels=False``, unbatched); the kernel path is budgeted
    by ``launch-budget``. Override: ``expected_dots``.
    """
    plan = art.plan
    expected = art.overrides.get("expected_dots")
    if expected is None:
        if plan is None or plan.use_kernels or plan.batch:
            return []
        expected = _expected_dots(plan)
        if expected is None:
            return []
    got = sum(1 for s in art.sites()
              if s.eqn.primitive.name == "dot_general")
    if got == expected:
        return []
    return [_finding(
        art, "dot-budget",
        f"jaxpr dispatches {got} dot_general eqns; the closed form "
        f"predicts {expected}")]


@rule("launch-budget")
def launch_budget(art: Artifact) -> List[Finding]:
    """Kernel-path plans: the ``pallas_call`` count must equal the closed
    form (unrolled = one launch per leaf; batched = one per engine; fused =
    one per level plus the gathered diagonal) and never exceed the
    ``cost.dispatch_calls`` budget the planner prices. Applies to product
    plans with ``use_kernels=True``. Override: ``expected_launches``.
    """
    from repro.tune import cost

    plan = art.plan
    expected = art.overrides.get("expected_launches")
    budget = art.overrides.get("launch_ceiling")
    if expected is None:
        if (plan is None or not plan.use_kernels or plan.batch
                or plan.op not in ("ata", "gemm_tn")):
            return []
        lv = _depth(plan)
        if lv == 0:
            expected = 1
        elif plan.op == "ata":
            s, g = cost._ata_leaves(plan.m, plan.n, plan.n_base)
            expected = {"unrolled": s + g, "batched": 2,
                        "fused": lv + 1}[plan.leaf_dispatch]
        else:
            leaves = cost._strassen_leaves(plan.m, plan.n, plan.k,
                                           plan.n_base)
            expected = {"unrolled": leaves, "batched": 1,
                        "fused": 1}[plan.leaf_dispatch]
        budget = cost.dispatch_calls(
            plan.op, plan.algorithm, plan.m, plan.n, plan.k, plan.n_base,
            plan.leaf_dispatch)
    got = sum(1 for s in art.sites()
              if s.eqn.primitive.name == "pallas_call")
    out = []
    if got != expected:
        out.append(_finding(
            art, "launch-budget",
            f"jaxpr dispatches {got} pallas_call launches; the closed "
            f"form predicts {expected}"))
    if budget is not None and got > budget:
        out.append(_finding(
            art, "launch-budget",
            f"{got} pallas_call launches exceed the priced "
            f"dispatch_calls budget {budget}"))
    return out


@rule("no-full-transpose")
def no_full_transpose(art: Artifact) -> List[Finding]:
    """The TN contract: no 2-D transpose above tile granularity.

    ``Aᵀ`` is never materialized (paper §3) — the only transposes a planned
    program may contain are tile mirrors bounded by the recursion cutoff /
    packed block, plus, for dense-output ATA with a real recursion, exactly
    ONE root ``(n, n)`` mirror (the documented ``sym_tile`` finalize).
    Kernel bodies are opaque (their in-kernel tile mirrors are the base-case
    symmetry contract). Overrides: ``max_transpose_dim`` (tile bound;
    plans default to ``max(n_base, packed block)``), ``mirror_budget``.
    """
    plan = art.plan
    max_dim = art.overrides.get("max_transpose_dim")
    budget = art.overrides.get("mirror_budget")
    mirror_shape = None
    if plan is not None:
        if max_dim is None:
            max_dim = max(plan.n_base, _packed_bn(plan))
        if budget is None:
            # the root mirror exists at every depth: a single-leaf gram's
            # base syrk tril+mirror IS the (n, n) mirror
            budget = 1 if (plan.op == "ata" and plan.out == "dense") else 0
        mirror_shape = (plan.n, plan.n)
    if max_dim is None:
        return []
    budget = budget or 0
    mirror_shape = art.overrides.get("mirror_shape", mirror_shape)
    out, mirrors = [], 0
    for site in art.sites():
        if site.eqn.primitive.name != "transpose":
            continue
        shape = tuple(site.eqn.outvars[0].aval.shape)
        if len(shape) != 2 or max(shape) <= max_dim:
            continue
        if shape == mirror_shape and mirrors < budget:
            mirrors += 1
            continue
        out.append(_finding(
            art, "no-full-transpose",
            f"2-D transpose of {shape} exceeds the {max_dim}-tile bound "
            f"(materialized operand mirror)",
            site, shape))
    return out


@rule("acc-dtype")
def acc_dtype(art: Artifact) -> List[Finding]:
    """Every ``dot_general`` must accumulate at the plan accumulator width.

    jnp-level dots always carry a ``preferred_element_type`` (filled with
    the *promoted input dtype* when the caller doesn't pass one), so
    presence is meaningless — the rule checks the effective accumulation
    dtype: ``preferred_element_type`` if set, else the output dtype, must
    be at least as wide as the accumulator (f32, or the operand dtype when
    that is wider). A bf16 operand reaching a dot without an explicit
    ``preferred_element_type=f32`` shows up here as bf16 accumulation.
    Override: ``min_acc_itemsize``.
    """
    import jax.numpy as jnp

    plan = art.plan
    required = art.overrides.get("min_acc_itemsize")
    if required is None:
        required = max(4, _itemsize(plan.dtype)) if plan is not None else 4
    out = []
    for site in art.sites():
        if site.eqn.primitive.name != "dot_general":
            continue
        pref = site.eqn.params.get("preferred_element_type")
        eff = jnp.dtype(pref) if pref is not None else jnp.dtype(
            site.eqn.outvars[0].aval.dtype)
        if not jnp.issubdtype(eff, jnp.floating):
            continue
        if eff.itemsize < required:
            out.append(_finding(
                art, "acc-dtype",
                f"dot accumulates at {eff.name} "
                f"({eff.itemsize} B < required {required} B) — missing "
                f"preferred_element_type on the call site",
                site, tuple(site.eqn.outvars[0].aval.shape)))
    return out


@rule("no-vmap-of-pallas")
def no_vmap_of_pallas(art: Artifact) -> List[Finding]:
    """Kernel batching goes through the native leading grid dimension —
    one launch for the whole batch — never through ``vmap`` of a kernel
    (the batched-grid contract of ``kernels/__init__.py``). A vmapped
    ``pallas_call`` is visible in the jaxpr as a nonempty
    ``grid_mapping.vmapped_dims``. Applies to every artifact.
    """
    out = []
    for site in art.sites():
        if site.eqn.primitive.name != "pallas_call":
            continue
        gm = site.eqn.params.get("grid_mapping")
        dims = tuple(getattr(gm, "vmapped_dims", ()))
        if dims:
            out.append(_finding(
                art, "no-vmap-of-pallas",
                f"pallas_call batched via vmap (vmapped_dims={dims}); "
                f"use the kernel's native leading batch grid",
                site))
    return out


@rule("collective-budget")
def collective_budget(art: Artifact) -> List[Finding]:
    """Distributed plans: per-device reduction-collective bytes must stay
    within the retrieval payload the planner prices.

    The tile schedule psums the ``(T, w, w)`` stack and the rowshard path
    all-reduces the replicated result — in both cases the reduction-class
    payload (all-reduce + reduce-scatter) is bounded by
    ``cost.retrieval_bytes(out, nb, w)`` (measured exact for rowshard,
    ≲0.8× for the tile schedule; operand movement rides collective-permute
    / all-gather and is priced separately). A BFS-containing
    ``comm_schedule`` gets the far tighter scatter budget: the tri-direct
    reduce-scatter's whole reduction payload is ONE ``T_pad/P``-tile chunk
    per device (``ceil(T/P)·w²`` — the CAPS bandwidth saving the schedule
    exists for), so a BFS artifact whose reduction bytes regress to the
    psum schedule's full-stack payload fails the rule even though it would
    pass the psum budget. Needs compiled HLO text and a plan with a
    multi-device pool and a resolved ``nb``/``tile_w``. Overrides:
    ``collective_budget_bytes``, ``collective_slack`` (default 1.0).
    """
    from repro.analysis.hlo import collective_bytes
    from repro.tune import cost

    plan = art.plan
    if art.hlo_text is None:
        return []
    budget = art.overrides.get("collective_budget_bytes")
    if budget is None:
        if plan is None or plan.nb is None or plan.tile_w is None:
            return []
        pool = plan.devices * max(getattr(plan, "row_devices", 1), 1)
        if pool <= 1:
            return []
        cs = getattr(plan, "comm_schedule", None)
        if cs and "B" in cs:
            t_total = plan.nb * (plan.nb + 1) // 2
            budget = (
                -(-t_total // pool) * plan.tile_w * plan.tile_w
                * _itemsize(plan.dtype)
            )
        else:
            budget = cost.retrieval_bytes(
                plan.out, plan.nb, plan.tile_w, _itemsize(plan.dtype))
    slack = art.overrides.get("collective_slack", 1.0)
    by_kind = collective_bytes(art.hlo_text)
    reduction = by_kind["all-reduce"] + by_kind["reduce-scatter"]
    if reduction <= slack * budget:
        return []
    return [_finding(
        art, "collective-budget",
        f"reduction collectives move {reduction} B/device "
        f"(all-reduce {by_kind['all-reduce']}, reduce-scatter "
        f"{by_kind['reduce-scatter']}) > priced retrieval payload "
        f"{budget} B × slack {slack}")]


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------


def run(artifact: Artifact, rules: Optional[Sequence[str]] = None,
        allowlist: Sequence[Allow] = (),
        report: Optional[Report] = None) -> Report:
    """Run ``rules`` (default: the whole registry) over one artifact.

    Findings are partitioned by ``allowlist`` into the returned
    :class:`Report`; violation counters land in the ``repro.obs`` registry
    (``check.*`` — see DESIGN.md §8's naming table).
    """
    from repro.obs import metrics

    if report is None:
        report = Report(allowlist)
    ids = list(rules) if rules is not None else rule_ids()
    unknown = [i for i in ids if i not in REGISTRY]
    if unknown:
        raise KeyError(f"unknown rule ids {unknown}; have {rule_ids()}")
    n = 0
    for rid in ids:
        found = REGISTRY[rid].fn(artifact)
        kept = report.add(found)
        n += len(found)
        metrics.inc("check.rules_run")
        for f in kept:
            metrics.inc(f"check.findings.{f.rule}")
            if f.severity == "error":
                metrics.inc("check.violations")
    metrics.inc("check.artifacts")
    report.record_artifact(artifact.label, ids, n)
    return report


def run_many(artifacts: Sequence[Artifact],
             rules: Optional[Sequence[str]] = None,
             allowlist: Sequence[Allow] = ()) -> Report:
    report = Report(allowlist)
    for art in artifacts:
        run(art, rules=rules, allowlist=allowlist, report=report)
    return report
