"""CLI: ``python -m repro.check`` — the CI contract gate.

Traces the canonical plan grid (or, with ``--distributed``, the SPMD
schedules on the active mesh), runs the rule registry over every artifact,
prints a summary, optionally writes the JSON report, and exits nonzero on
any unallowlisted error-severity finding.
"""

from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="Static contract checks over traced plan artifacts.")
    ap.add_argument("--json", metavar="PATH",
                    help="write the repro.check/v1 JSON report here")
    ap.add_argument("--quick", action="store_true",
                    help="three-artifact smoke subset instead of the grid")
    ap.add_argument("--distributed", action="store_true",
                    help="check the SPMD schedules (needs >1 device)")
    ap.add_argument("--serve", action="store_true",
                    help="check the serve bucket callables + zero-retrace")
    ap.add_argument("--lower", action="store_true",
                    help="also compile each grid artifact (attaches HLO)")
    ap.add_argument("--rules", metavar="IDS",
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--list", action="store_true", dest="list_rules",
                    help="list registered rules and exit")
    args = ap.parse_args(argv)

    from repro.check import harness, rules

    if args.list_rules:
        for rid in rules.rule_ids():
            r = rules.REGISTRY[rid]
            first = r.doc.splitlines()[0] if r.doc else ""
            print(f"{rid:20s} [{r.severity}] {first}")
        return 0

    ids = args.rules.split(",") if args.rules else None
    if args.distributed:
        report = harness.run_distributed(verbose=True)
    elif args.serve:
        report = harness.run_serve(verbose=True)
    else:
        report = harness.run_grid(rules=ids, lower=args.lower,
                                  quick=args.quick, verbose=True)
    print(report.summary())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.to_json(), f, indent=1, sort_keys=True)
        print(f"report written to {args.json}")
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
