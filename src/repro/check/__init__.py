"""``repro.check`` — static analysis of traced programs against the repo's
structural contracts.

The paper's advantage (14/3·n^log2(7) flops, transpose-free TN leaves,
packed-symmetric output, O(levels) dispatch) only survives in a traced
program if structural invariants hold. This package turns those invariants
— previously scattered across hand-rolled test walkers — into a
rule-registry static analyzer over traced artifacts:

* :mod:`repro.check.findings` — :class:`Finding` / :class:`Allow` /
  :class:`Report`: structured violations with eqn provenance, the
  allowlist, and the JSON report (schema ``repro.check/v1``).
* :mod:`repro.check.artifacts` — :class:`Artifact`, the canonical
  :func:`walk_eqns` traversal, and :func:`trace_plan` (traces the exact
  callable the autotuner times).
* :mod:`repro.check.rules` — the registry and the eight shipped rules.
* :mod:`repro.check.harness` — the canonical plan grid and the
  distributed (multi-device) sweep.

CLI: ``python -m repro.check [--json CHECK_report.json]`` — nonzero exit
on violations; ``--distributed`` for the SPMD schedules. DESIGN.md §9 has
the rule taxonomy and the policy for allowlisting intentional violations.
"""

from repro.check.artifacts import Artifact, abstract_args, plan_label, trace_plan, walk_eqns
from repro.check.findings import Allow, Finding, Report, REPORT_SCHEMA
from repro.check.harness import (
    DEFAULT_ALLOWLIST,
    bfsdfs_plans,
    canonical_plans,
    distributed_plans,
    run_distributed,
    run_grid,
)
from repro.check.rules import REGISTRY, rule, rule_ids, run, run_many

__all__ = [
    "Artifact", "Allow", "Finding", "Report", "REPORT_SCHEMA",
    "REGISTRY", "DEFAULT_ALLOWLIST",
    "abstract_args", "plan_label", "trace_plan", "walk_eqns",
    "rule", "rule_ids", "run", "run_many",
    "canonical_plans", "run_grid", "distributed_plans", "bfsdfs_plans",
    "run_distributed",
]
