"""Tracing harnesses: the canonical plan grid and the distributed sweep.

:func:`canonical_plans` enumerates the (op × algorithm × leaf_dispatch ×
out × engine × dtype) grid the CI gate traces on every push — all three
leaf dispatches, XLA and kernel (interpret) engines, dense and packed
outputs, both solver methods, plus a bf16 row per product op so the
``acc-dtype`` rule has sub-f32 operands to police. Shapes are rectangular
(``m ≠ n ≠ k``) on purpose: several rules' shape discriminators (operand
stacks vs product stacks, dense squares vs row slabs) need the dims
distinguishable, and ``n_base=32`` forces a depth-2 ATA tree / depth-1
Strassen tree so every budget has a real recursion to count.

:func:`distributed_plans` / :func:`run_distributed` are the multi-device
half — the tile-parallel and rowshard schedules traced through
``shard_map`` on the active mesh, plus the BFS/DFS schedule
(:func:`bfsdfs_plans` — planner-selected interleaving on a 2-axis
(row, task) submesh) — compiled once (the ``analysis.hlo.compiled_text``
path shared with the collective accounting), and checked against the
packed/fused structural rules plus ``collective-budget`` (which holds
BFS artifacts to the tighter one-chunk reduce-scatter budget). CI runs
it inside the distributed-smoke job's 8-fake-CPU-device subprocess.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence

from repro.check.artifacts import Artifact, plan_label, trace_plan
from repro.check.findings import Allow, Report
from repro.check import rules as _rules

__all__ = [
    "CANONICAL_SHAPE", "DEFAULT_ALLOWLIST",
    "canonical_plans", "run_grid", "distributed_plans", "bfsdfs_plans",
    "run_distributed", "run_serve",
]

# (m, n, k): rectangular; n_base forces L=2 on the ATA tree, L=1 on the
# gemm tree; packed_block gives a real 4-stripe packed grid at n=128.
CANONICAL_SHAPE = dict(m=192, n=128, k=64, n_base=32, packed_block=32)

# Intentional violations, suppressed by policy rather than by weakening a
# rule (DESIGN.md §9). Currently empty: every canonical artifact is clean.
DEFAULT_ALLOWLIST: List[Allow] = []


def canonical_plans() -> List:
    """The canonical plan grid (see module docstring)."""
    from repro.tune import cost

    m, n, k = (CANONICAL_SHAPE[d] for d in ("m", "n", "k"))
    nb, pb = CANONICAL_SHAPE["n_base"], CANONICAL_SHAPE["packed_block"]

    def mk(base, **kw):
        kw.setdefault("n_base", nb)
        kw.setdefault("packed_block", pb)
        return dataclasses.replace(base, **kw)

    ata = cost.default_plan("ata", m, n)
    gemm = cost.default_plan("gemm_tn", m, n, k)
    solve = cost.default_plan("solve", m, n, k, out="packed")

    plans = []
    # the product grid: all three leaf dispatches × both engines × both outs
    for uk in (False, True):
        for ld in ("unrolled", "batched", "fused"):
            for out in ("dense", "packed"):
                plans.append(mk(ata, algorithm="strassen", leaf_dispatch=ld,
                                use_kernels=uk, out=out))
            plans.append(mk(gemm, algorithm="strassen", leaf_dispatch=ld,
                            use_kernels=uk))
    # algorithm row: the single classical dot and the winograd variant
    plans.append(mk(ata, algorithm="dense", leaf_dispatch="unrolled",
                    use_kernels=False))
    plans.append(mk(gemm, algorithm="dense", leaf_dispatch="unrolled",
                    use_kernels=False))
    plans.append(mk(ata, algorithm="winograd", leaf_dispatch="unrolled",
                    use_kernels=False, out="packed"))
    # bf16 row: sub-f32 operands — the acc-dtype rule's real quarry
    plans.append(mk(ata, algorithm="strassen", leaf_dispatch="unrolled",
                    use_kernels=False, dtype="bfloat16"))
    plans.append(mk(gemm, algorithm="strassen", leaf_dispatch="unrolled",
                    use_kernels=False, dtype="bfloat16"))
    # the solve path: both methods, packed-native
    plans.append(mk(solve, algorithm="strassen", method="factor"))
    plans.append(mk(solve, algorithm="strassen", method="cg"))
    return plans


def _quick_plans() -> List:
    """A three-artifact subset for smoke tests (one per op)."""
    plans = canonical_plans()
    picks = {}
    for p in plans:
        key = p.op
        if key not in picks and not p.use_kernels:
            picks[key] = p
    return list(picks.values())


def run_grid(plans: Optional[Sequence] = None, *,
             rules: Optional[Sequence[str]] = None,
             allowlist: Optional[Sequence[Allow]] = None,
             lower: bool = False, quick: bool = False,
             verbose: bool = False) -> Report:
    """Trace every plan and run the registry over each artifact."""
    if plans is None:
        plans = _quick_plans() if quick else canonical_plans()
    report = Report(DEFAULT_ALLOWLIST if allowlist is None else allowlist)
    for plan in plans:
        if verbose:
            print(f"  tracing {plan_label(plan)}", flush=True)
        art = trace_plan(plan, lower=lower)
        _rules.run(art, rules=rules, allowlist=report.allowlist,
                   report=report)
    return report


# ---------------------------------------------------------------------------
# distributed sweep (requires a multi-device backend, e.g. the CI job's
# XLA_FLAGS=--xla_force_host_platform_device_count=8 subprocess)
# ---------------------------------------------------------------------------

# m sized so the per-device rowshard slab (m/8 rows) still recurses past
# the cutoff — a sub-cutoff slab is a legitimate single-leaf gram whose
# (n, n) base tile the no-dense-square rule rightly exempts, and a harness
# should exercise the non-degenerate contract.
_DIST_SHAPE = dict(m=1024, n=512, n_base=64)
_DIST_RULES = ("no-dense-square", "no-vmap-of-pallas", "acc-dtype",
               "collective-budget")


def distributed_plans(devices: int) -> List:
    """Tile-parallel and rowshard plans (dense + packed) for ``devices``."""
    from repro.tune import cost

    m, n, nb_cut = _DIST_SHAPE["m"], _DIST_SHAPE["n"], _DIST_SHAPE["n_base"]
    plans = []
    for out in ("dense", "packed"):
        # default_plan's distributed branch resolves (nb, tile_w) through
        # the same tiling search ata_tile_parallel uses internally
        plans.append(dataclasses.replace(
            cost.default_plan("ata", m, n, out=out, devices=devices),
            algorithm="strassen", n_base=nb_cut))
    return plans


def bfsdfs_plans(devices: int, row_devices: int) -> List:
    """BFS/DFS plans (dense + packed) for a (row, task) 2-axis mesh.

    The interleaving is *planner-selected* — the top BFS-containing
    candidate of ``cost.candidates`` for the harness shape and mesh — so
    the artifact compiles exactly the schedule the front door would
    dispatch, and the collective-budget rule gates its one-chunk
    reduce-scatter payload.
    """
    from repro.tune import cost

    m, n, nb_cut = _DIST_SHAPE["m"], _DIST_SHAPE["n"], _DIST_SHAPE["n_base"]
    plans = []
    for out in ("dense", "packed"):
        cands = cost.candidates("ata", m, n, out=out, devices=devices,
                                row_devices=row_devices)
        top_b = next(
            (p for p in cands if p.comm_schedule and "B" in p.comm_schedule),
            None)
        if top_b is not None:
            plans.append(dataclasses.replace(top_b, n_base=nb_cut))
    return plans


def _trace_distributed(plan, mesh, schedule: str, *, m_global=None) -> Artifact:
    """Trace + compile one distributed schedule into an Artifact.

    ``plan`` is the plan the *rules* see (for rowshard: per-device row
    count); ``m_global`` is the traced input's row count when it differs.
    """
    import jax

    from repro.analysis.hlo import compiled_text

    a_abs = jax.ShapeDtypeStruct((m_global or plan.m, plan.n), "float32")
    if schedule == "tile":
        from repro.core.distributed import ata_tile_parallel

        fn = jax.jit(functools.partial(
            ata_tile_parallel, mesh=mesh, task_axis="model",
            n_base=plan.n_base, nb=plan.nb, out=plan.out))
    elif schedule == "bfsdfs":
        from repro.core.distributed import ata_bfs_dfs

        fn = jax.jit(functools.partial(
            ata_bfs_dfs, mesh=mesh, task_axis="model",
            row_axis=("data" if "data" in mesh.shape else None),
            interleaving=plan.comm_schedule, n_base=plan.n_base,
            nb=plan.nb, packed_block=plan.packed_block, out=plan.out))
    else:
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        from repro.core.distributed import gram_rowshard

        run = functools.partial(
            gram_rowshard, axis="model", n_base=plan.n_base, out=plan.out,
            packed_block=plan.packed_block)
        fn = jax.jit(shard_map(run, mesh=mesh, in_specs=P("model", None),
                               out_specs=P()))
    closed = jax.make_jaxpr(fn)(a_abs)
    hlo = compiled_text(fn, a_abs)
    return Artifact(label=f"{schedule}:{plan_label(plan)}",
                    jaxpr=closed.jaxpr, plan=plan, hlo_text=hlo)


def _serve_expected_dots(spec, sp) -> int:
    """Closed-form ``dot_general`` count of one serve bucket callable.

    The batched pipeline's dot count is batch-invariant (the batch dim
    rides every dot; the per-slice substitution solves are
    ``triangular_solve``, not dots), so lstsq buckets reuse the solve
    closed form of :func:`rules._expected_dots` verbatim. Whiten buckets
    drop the ``Aᵀb`` dot and the backward-substitution einsum band:
    gram + factor Schur band + ONE substitution pass.
    """
    if spec.op == "lstsq":
        return _rules._expected_dots(sp)
    gram_plan = dataclasses.replace(sp, op="ata", k=sp.n)
    gram = _rules._expected_dots(gram_plan)
    nbk = -(-sp.n // _rules._packed_bn(sp))
    return gram + (nbk - 1) + max(nbk - 2, 0) + (nbk - 1)


# the serve-path rule set: the packed/structural contracts the bucket
# callables must honor (dot-budget rides the explicit override above;
# launch-budget self-gates on the XLA-path smoke buckets but stays in the
# list so kernel-path bucket configs are covered the day they exist)
_SERVE_RULES = ("no-dense-square", "acc-dtype", "no-vmap-of-pallas",
                "dot-budget", "launch-budget")


def run_serve(*, config=None, steady_batches: int = 2,
              verbose: bool = False) -> Report:
    """Check the serve layer: trace every bucket callable of the (smoke)
    lattice against the packed/structural rules, then run a warmed
    steady-state loop and assert it performs **zero retraces**.

    The traced program IS the program a flush dispatches
    (``Server.bucket_callable`` — no parallel re-implementation), traced
    on the bucket's static abstract operands. The artifact carries the
    *batched* plan (the program's real identity) plus the
    ``expected_dots`` override computed from the unbatched solve closed
    form (see :func:`_serve_expected_dots`).

    The retrace half is dynamic by nature: a warmed :class:`Server`
    serves ``steady_batches`` full flushes per bucket; any growth of a
    jit cache past the warm floor lands as a ``serve-no-retrace``
    finding (plus the engine's own ``serve.retraces`` counter).
    """
    import numpy as np

    from repro.check.findings import Finding
    from repro.serve.engine import Server, serve_abstract_args, smoke_config
    from repro.serve.queue import Request

    if config is None:
        config = smoke_config()
    server = Server(config)
    report = Report(DEFAULT_ALLOWLIST)

    import jax

    for spec in config.buckets:
        if verbose:
            print(f"  tracing serve:{spec.label()}", flush=True)
        fn, sp = server.bucket_callable(spec)
        closed = jax.make_jaxpr(fn)(*serve_abstract_args(spec))
        batched = dataclasses.replace(sp, batch=spec.batch)
        art = Artifact(
            label=f"serve:{spec.label()}", jaxpr=closed.jaxpr, plan=batched,
            overrides={"expected_dots": _serve_expected_dots(spec, sp)})
        _rules.run(art, rules=_SERVE_RULES, allowlist=report.allowlist,
                   report=report)

    # steady-state: warm, then flush full batches and hold the jit caches
    # to the warm floor (the engine raises on strict_retrace — the harness
    # wants a Finding instead, so it serves in counter mode)
    if verbose:
        print("  warming serve steady-state loop", flush=True)
    server = Server(dataclasses.replace(config, strict_retrace=False))
    server.warm()
    rng = np.random.default_rng(0)
    for spec in config.buckets:
        for _ in range(steady_batches):
            for _i in range(spec.batch):
                a = rng.standard_normal((spec.m, spec.n)).astype(spec.dtype)
                rows = spec.m if spec.op == "lstsq" else spec.n
                b = rng.standard_normal((rows, spec.r)).astype(spec.dtype)
                server.submit(Request(op=spec.op, a=a, b=b))
    server.drain()
    findings = []
    if server.retraces():
        findings.append(Finding(
            rule="serve-no-retrace",
            message=f"steady-state loop retraced {server.retraces()} times "
                    "after the warm pass (compile-cache floor exceeded)",
            artifact="serve:steady-state"))
    report.add(findings)
    report.record_artifact("serve:steady-state", ["serve-no-retrace"],
                           len(findings))
    return report


def run_distributed(*, mesh=None,
                    allowlist: Optional[Sequence[Allow]] = None,
                    verbose: bool = False) -> Report:
    """Check the SPMD schedules on the active (or given) mesh."""
    import jax

    if mesh is None:
        p = jax.device_count()
        if p < 2:
            raise RuntimeError(
                "run_distributed needs >1 device; run under "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8")
        mesh = jax.make_mesh((p,), ("model",))
    p = mesh.shape["model"]
    report = Report(DEFAULT_ALLOWLIST if allowlist is None else allowlist)
    for plan in distributed_plans(p):
        for schedule in ("tile", "rowshard"):
            if schedule == "rowshard":
                if plan.m % p:
                    continue
                # rowshard has no stripe tiling of its own: its reduction
                # payload is the replicated result — the packed block grid.
                # The artifact is the *per-device* program, so the plan
                # carries the local row count (depth gates resolve against
                # the slab each device actually recurses on).
                from repro.core.symmetric import default_block_size

                bn = default_block_size(plan.n, plan.packed_block)
                plan_r = dataclasses.replace(
                    plan, m=plan.m // p, nb=-(-plan.n // bn), tile_w=bn)
            else:
                plan_r = plan
            if verbose:
                print(f"  tracing {schedule}:{plan_label(plan_r)}",
                      flush=True)
            art = _trace_distributed(plan_r, mesh, schedule,
                                     m_global=plan.m)
            _rules.run(art, rules=_DIST_RULES, allowlist=report.allowlist,
                       report=report)
    # BFS/DFS artifacts on a 2-axis (row, task) submesh of the same
    # devices: the planner-selected interleaving, gated by the tighter
    # one-chunk scatter budget of the collective-budget rule. The row
    # axis is 4 so the per-device slab (m/4 = 256 rows) stays
    # distinguishable from the (n, n) square the no-dense-square rule
    # hunts (a 2-way split of the 1024×512 harness shape would make the
    # operand slab square).
    if p >= 4 and p % 4 == 0:
        import numpy as np
        from jax.sharding import Mesh

        mesh2 = Mesh(
            np.asarray(mesh.devices).reshape(4, p // 4), ("data", "model"))
        for plan in bfsdfs_plans(p // 4, 4):
            if verbose:
                print(f"  tracing bfsdfs:{plan_label(plan)}", flush=True)
            art = _trace_distributed(plan, mesh2, "bfsdfs",
                                     m_global=_DIST_SHAPE["m"])
            _rules.run(art, rules=_DIST_RULES, allowlist=report.allowlist,
                       report=report)
    return report
