"""Cost-model calibration: predicted-vs-measured seconds per plan.

``tune.cost.predict_seconds`` is the planner's whole claim to authority —
every analytic dispatch is an argmin over its predictions — yet until this
module nothing ever held those predictions against a wall clock outside
the autotuner's private comparisons. Two producers feed the table:

* **eager dispatch sites** (``core.ata``, ``core.strassen``,
  ``solve.lstsq``): with obs enabled and concrete (non-traced) operands,
  each planned front-door call times itself end-to-end
  (``block_until_ready``) and records ``(plan, measured)`` against the
  plan's own ``predicted_s``;
* **the autotuner** (``tune.search.autotune``): every timed candidate
  already carries an analytic prediction — each trial's
  min-of-interleaved floor is recorded against it.

``report()`` renders the drift table per Machine profile (backend):
``ratio = measured / predicted`` per plan key, plus the per-profile
geometric-mean drift — the number to re-fit ``tune.cost.MACHINES``
against (the PR-4/PR-6 recalibrations did exactly this by hand).
"""

from __future__ import annotations

import math
import threading
from typing import List, Optional

__all__ = [
    "record",
    "record_pair",
    "rows",
    "drift_table",
    "report",
    "reset",
    "plan_label",
    "MAX_ROWS",
]

_LOCK = threading.Lock()
_ROWS: List[dict] = []

# calibration rows are append-per-dispatch; cap them like span events so a
# long-running process with obs left on cannot grow host memory unboundedly
MAX_ROWS = 10_000


def plan_label(plan) -> str:
    """Compact human-stable identity of one dispatch configuration — the
    calibration key. Deliberately *not* the cache key: no jax version, no
    dtype-tail noise; rows from different processes of one machine profile
    aggregate. Distributed plans append the mesh pool and the interleaving
    (the comm_schedule axis changes the compiled program — a BFS plan and
    a psum plan at one shape must not aggregate into one drift row)."""
    shape = f"{plan.m}x{plan.n}" + (f"x{plan.k}" if plan.k != plan.n else "")
    tail = f"|{plan.method}" if plan.method else f"|{plan.leaf_dispatch}"
    devices = getattr(plan, "devices", 1)
    row_devices = getattr(plan, "row_devices", 1)
    if devices * row_devices > 1:
        cs = getattr(plan, "comm_schedule", None)
        tail += f"|P={devices}x{row_devices}|cs={cs or 'psum'}"
    return (
        f"{plan.op}|{shape}|b={plan.batch}|{plan.algorithm}"
        f"|nb={plan.n_base}{tail}"
    )


def record_pair(
    key: str,
    op: str,
    backend: str,
    predicted_s: float,
    measured_s: float,
    source: str = "dispatch",
) -> None:
    """Append one raw calibration row (already-resolved fields)."""
    row = {
        "key": key,
        "op": op,
        "backend": backend,
        "predicted_s": float(predicted_s),
        "measured_s": float(measured_s),
        "source": source,
    }
    with _LOCK:
        if len(_ROWS) < MAX_ROWS:
            _ROWS.append(row)


def record(plan, measured_s: float, source: str = "dispatch") -> None:
    """Record one ``(plan, measured)`` pair against the plan's own
    ``predicted_s``. Silently skipped when the plan carries no prediction
    (hand-built plans; the op-retargeted inner plans of ``solve.lstsq``)
    or the measurement is non-positive."""
    pred = getattr(plan, "predicted_s", None)
    if plan is None or pred is None or pred <= 0 or measured_s <= 0:
        return
    record_pair(
        plan_label(plan), plan.op, plan.backend, pred, measured_s, source
    )


def rows() -> List[dict]:
    with _LOCK:
        return [dict(r) for r in _ROWS]


def reset() -> None:
    with _LOCK:
        _ROWS.clear()


def drift_table(backend: Optional[str] = None) -> List[dict]:
    """Aggregate rows per (backend, key): min/median-free — the mean of
    per-row ratios plus the best (minimum) measured seconds, which is the
    noise-floor convention of ``tune.search.time_ratio``. Sorted by
    descending |log ratio| (worst drift first)."""
    by_key: dict = {}
    for r in rows():
        if backend is not None and r["backend"] != backend:
            continue
        g = by_key.setdefault(
            (r["backend"], r["key"]),
            {
                "backend": r["backend"], "key": r["key"], "op": r["op"],
                "n": 0, "predicted_s": r["predicted_s"],
                "measured_s": math.inf, "_log_ratio_sum": 0.0,
            },
        )
        g["n"] += 1
        g["measured_s"] = min(g["measured_s"], r["measured_s"])
        g["_log_ratio_sum"] += math.log(r["measured_s"] / r["predicted_s"])
    out = []
    for g in by_key.values():
        g["ratio"] = math.exp(g.pop("_log_ratio_sum") / g["n"])
        out.append(g)
    out.sort(key=lambda g: -abs(math.log(g["ratio"])))
    return out


def report() -> str:
    """The drift table rendered per machine profile, with a per-profile
    geometric-mean ratio — >1 means the model is optimistic (measured
    slower than predicted), <1 pessimistic."""
    table = drift_table()
    if not table:
        return "calibration: no predicted-vs-measured pairs recorded"
    lines = []
    for backend in sorted({g["backend"] for g in table}):
        rows_b = [g for g in table if g["backend"] == backend]
        gmean = math.exp(
            sum(math.log(g["ratio"]) for g in rows_b) / len(rows_b)
        )
        lines.append(
            f"calibration [{backend}] — {len(rows_b)} plan keys, "
            f"geomean measured/predicted = {gmean:.2f}"
        )
        width = max(len(g["key"]) for g in rows_b)
        lines.append(
            f"  {'plan':<{width}}  {'pred_s':>10}  {'meas_s':>10}  "
            f"{'ratio':>7}  {'n':>3}"
        )
        for g in rows_b:
            lines.append(
                f"  {g['key']:<{width}}  {g['predicted_s']:>10.3e}  "
                f"{g['measured_s']:>10.3e}  {g['ratio']:>7.2f}  {g['n']:>3}"
            )
    return "\n".join(lines)
