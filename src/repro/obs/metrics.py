"""Process-local counters / gauges / histograms with JSON snapshot export.

The metrics registry is **always on** — counters are plain integers behind
one lock, incremented at Python dispatch/trace time (never inside the
compiled program), so they cost nanoseconds and can't perturb a jaxpr.
What ``obs.enable()`` gates is the *tracing* half (spans) and the
*calibration* timing, both of which do real work.

Semantics on traced code paths: a counter incremented inside a function
under ``jax.jit`` counts **traces**, not executions — e.g.
``kernels.launch.syrk`` is the number of syrk launches *in the traced
program*, which is exactly the per-dispatch leaf accounting the cost
model's ``dispatch_calls`` predicts.

Naming convention (dotted, lowercase):

    tune.cache.*       plan-cache hits/misses/migrations/sanitizations
    tune.autotune.*    trials, wins, win-margin histogram
    dispatch.<op>.*    planned dispatches per leaf-dispatch / method
    <op>.leaves.*      leaf counts per dispatch
    kernels.launch.*   Pallas wrapper launches (traced)
    solve.*            solver front-door counters
    collective_bytes.* per-kind HLO collective payload (via record_collective_bytes)
    check.*            repro.check analyzer accounting: rules_run /
                       artifacts / findings.<rule-id> / violations

Snapshot schema (``SNAPSHOT_SCHEMA``): see :func:`snapshot` /
:func:`validate_snapshot` — the contract the CI obs-smoke step asserts.
"""

from __future__ import annotations

import json
import threading
from typing import Dict, Optional

__all__ = [
    "inc",
    "set_gauge",
    "observe",
    "get",
    "counters",
    "gauges",
    "histograms",
    "snapshot",
    "validate_snapshot",
    "export_json",
    "record_collective_bytes",
    "reset",
    "SNAPSHOT_SCHEMA",
]

SNAPSHOT_SCHEMA = "repro.obs/v1"

_LOCK = threading.Lock()
_COUNTERS: Dict[str, int] = {}
_GAUGES: Dict[str, float] = {}
_HISTS: Dict[str, dict] = {}   # name -> {count, sum, min, max}


def inc(name: str, value: int = 1) -> None:
    """Add ``value`` to counter ``name`` (created at 0)."""
    with _LOCK:
        _COUNTERS[name] = _COUNTERS.get(name, 0) + int(value)


def set_gauge(name: str, value: float) -> None:
    """Set gauge ``name`` to the latest value."""
    with _LOCK:
        _GAUGES[name] = float(value)


def observe(name: str, value: float) -> None:
    """Record one sample into histogram ``name`` (count/sum/min/max —
    enough for means and ranges without bucket-boundary bikeshedding)."""
    v = float(value)
    with _LOCK:
        h = _HISTS.get(name)
        if h is None:
            _HISTS[name] = {"count": 1, "sum": v, "min": v, "max": v}
        else:
            h["count"] += 1
            h["sum"] += v
            h["min"] = min(h["min"], v)
            h["max"] = max(h["max"], v)


def get(name: str, default: int = 0) -> int:
    """Current value of counter ``name``."""
    with _LOCK:
        return _COUNTERS.get(name, default)


def counters(prefix: str = "") -> Dict[str, int]:
    with _LOCK:
        return {k: v for k, v in _COUNTERS.items() if k.startswith(prefix)}


def gauges(prefix: str = "") -> Dict[str, float]:
    with _LOCK:
        return {k: v for k, v in _GAUGES.items() if k.startswith(prefix)}


def histograms(prefix: str = "") -> Dict[str, dict]:
    with _LOCK:
        return {k: dict(v) for k, v in _HISTS.items() if k.startswith(prefix)}


def reset() -> None:
    """Clear every registered metric (tests; between benchmark modules).
    Spans and calibration rows have their own ``reset`` in their modules."""
    with _LOCK:
        _COUNTERS.clear()
        _GAUGES.clear()
        _HISTS.clear()


def record_collective_bytes(hlo_text: str, prefix: str = "collective_bytes") -> dict:
    """Fold one compiled module's per-device collective payload into the
    registry: counter ``<prefix>.<kind>`` += bytes for every collective
    kind found by :func:`repro.analysis.hlo.collective_bytes`. Returns the
    per-kind dict (nonzero kinds only) for the caller's own reporting."""
    from repro.analysis.hlo import collective_bytes

    by_kind = {k: v for k, v in collective_bytes(hlo_text).items() if v}
    for kind, b in by_kind.items():
        inc(f"{prefix}.{kind}", b)
    return by_kind


def _meta() -> dict:
    """Runtime identity stamped on snapshots — jax imported lazily so the
    registry itself stays importable anywhere."""
    try:
        import jax

        return {"backend": jax.default_backend(), "jax_version": jax.__version__}
    except Exception:
        return {"backend": "unknown", "jax_version": "unknown"}


def snapshot() -> dict:
    """One JSON-serializable view of everything observed this process:
    metrics, span counts (``repro.obs.trace``), and the calibration rows
    (``repro.obs.calibrate``)."""
    from repro.obs import calibrate, trace

    return {
        "schema": SNAPSHOT_SCHEMA,
        "meta": _meta(),
        "counters": counters(),
        "gauges": gauges(),
        "histograms": histograms(),
        "spans": trace.span_counts(),
        "calibration": calibrate.rows(),
    }


def validate_snapshot(d: dict) -> dict:
    """Schema check for :func:`snapshot` output (the CI obs-smoke contract).
    Raises ``ValueError`` on any violation; returns ``d`` unchanged."""
    if not isinstance(d, dict):
        raise ValueError(f"snapshot must be a dict, got {type(d).__name__}")
    if d.get("schema") != SNAPSHOT_SCHEMA:
        raise ValueError(
            f"snapshot schema {d.get('schema')!r} != {SNAPSHOT_SCHEMA!r}"
        )
    for section, typ in (
        ("meta", dict), ("counters", dict), ("gauges", dict),
        ("histograms", dict), ("spans", dict), ("calibration", list),
    ):
        if not isinstance(d.get(section), typ):
            raise ValueError(f"snapshot[{section!r}] must be {typ.__name__}")
    for k, v in d["counters"].items():
        if not isinstance(k, str) or not isinstance(v, int):
            raise ValueError(f"counter {k!r}: {v!r} is not a str->int entry")
    for k, v in d["histograms"].items():
        missing = {"count", "sum", "min", "max"} - set(v)
        if missing:
            raise ValueError(f"histogram {k!r} missing fields {sorted(missing)}")
    for row in d["calibration"]:
        missing = {"key", "op", "backend", "predicted_s", "measured_s"} - set(row)
        if missing:
            raise ValueError(f"calibration row missing fields {sorted(missing)}")
    return d


def export_json(path: str, extra: Optional[dict] = None) -> str:
    """Write the validated snapshot (plus optional extra top-level keys)
    to ``path``; returns the path."""
    snap = validate_snapshot(snapshot())
    if extra:
        snap = {**snap, **extra}
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
    return path
