"""``repro.obs`` — zero-dependency observability for the ATA stack.

Three small modules, one switch:

* :mod:`repro.obs.trace` — nestable **spans** naming recursion levels,
  batched/fused leaf launches, kernel wrappers, the solve front door and
  the SPMD schedule bodies. Disabled (the default) they are strict no-ops
  — instrumented paths stay bitwise- and jaxpr-identical (tested); enabled
  they record events and wrap regions in ``jax.named_scope`` +
  ``jax.profiler.TraceAnnotation`` so profiler timelines carry the same
  names.
* :mod:`repro.obs.metrics` — always-on process-local counters / gauges /
  histograms (plan-cache hits/misses/migrations, autotune trials and win
  margins, leaf counts per dispatch, kernel launches, collective bytes,
  solve iterations) with a validated JSON snapshot
  (``metrics.export_json`` → ``BENCH_obs.json``).
* :mod:`repro.obs.calibrate` — every planned *eager* dispatch records
  ``(plan, predicted_seconds, measured_seconds)``; ``calibrate.report()``
  renders the predicted-vs-measured drift table per Machine profile,
  closing the loop on ``tune.cost.predict_seconds``.

Quickstart (DESIGN.md §8):

    from repro import obs
    obs.enable()
    c = ata(a, out="packed")            # spans + dispatch counters
    x = solve.lstsq(a, b)               # + one calibration row
    snap = obs.metrics.snapshot()       # JSON-ready; obs.report() for text

Smoke entry point: ``python -m repro.obs`` runs one planned
``plan → ata → solve.lstsq`` with tracing on, validates the snapshot, and
writes ``BENCH_obs.json`` — the CI obs-smoke step.
"""

from __future__ import annotations

import time

from repro.obs import calibrate, metrics, trace
from repro.obs.trace import disable, enable, enabled, span

__all__ = [
    "trace",
    "metrics",
    "calibrate",
    "enable",
    "disable",
    "enabled",
    "span",
    "report",
    "dispatch_start",
    "dispatch_finish",
]


def report() -> str:
    """The calibration drift table (text) — see ``calibrate.report``."""
    return calibrate.report()


# ---------------------------------------------------------------------------
# dispatch-site calibration helpers (used by core.ata / core.strassen /
# solve.lstsq — the three planned front doors)
# ---------------------------------------------------------------------------


def dispatch_start(plan, operand):
    """Start a calibration measurement for one planned dispatch, or return
    ``None`` when there is nothing meaningful to measure:

    * obs disabled (the common case — this is the one-branch fast path);
    * no plan / no ``predicted_s`` on it (hand-pinned dispatches);
    * ``operand`` is a tracer — inside ``jit``/``shard_map`` the wrapped
      region runs at *trace* time, where wall clock means compile time.
    """
    if not trace.enabled():
        return None
    if plan is None or getattr(plan, "predicted_s", None) is None:
        return None
    import jax

    if isinstance(operand, jax.core.Tracer):
        return None
    return time.perf_counter()


def dispatch_finish(plan, t0, result):
    """Close a measurement opened by :func:`dispatch_start`: block on the
    result (pytree-aware), record the pair, hand the result back."""
    if t0 is None:
        return result
    import jax

    result = jax.block_until_ready(result)
    calibrate.record(plan, time.perf_counter() - t0)
    return result
