"""``python -m repro.obs`` — the obs smoke run (the CI obs-smoke step).

One planned ``plan → ata → solve.lstsq`` pipeline with tracing on, then:

* assert the metrics snapshot is non-empty and schema-valid
  (``metrics.validate_snapshot``);
* assert spans exist for every recursion level of a forced-recursing
  dispatch and for the kernel wrappers it launched;
* assert the calibration table holds ≥ 1 predicted-vs-measured row per
  dispatched op;
* write the snapshot to ``BENCH_obs.json`` (``--out PATH`` overrides) and
  print the calibration drift report.

Exit code 0 only if every assertion holds — CI uploads the JSON artifact.
"""

from __future__ import annotations

import sys

from repro import obs


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    out_path = "BENCH_obs.json"
    if "--out" in argv:
        i = argv.index("--out")
        out_path = argv[i + 1]

    obs.enable()

    import dataclasses

    import jax
    import numpy as np

    from repro import tune
    from repro.core.ata import ata
    from repro.solve.lstsq import lstsq

    m, n, r = 192, 96, 4
    rng = np.random.default_rng(0)
    a = jax.numpy.asarray(rng.standard_normal((m, n)), jax.numpy.float32)
    b = jax.numpy.asarray(rng.standard_normal((m, r)), jax.numpy.float32)

    # 1. the planner front door (plan-cache counters)
    plan = tune.plan(op="ata", m=m, n=n, dtype="float32", out="packed")

    # 2. planned ata — plus one *forced-recursing* plan so the smoke run
    # demonstrably yields spans for real recursion levels even where the
    # planner's argmin for this small shape is the single dense dot.
    gram = ata(a, out="packed")
    rec_plan = dataclasses.replace(
        plan, algorithm="strassen", n_base=32, leaf_dispatch="batched",
        source="analytic",
    )
    gram_rec = ata(a, plan=rec_plan, out="packed")
    np.testing.assert_allclose(
        np.asarray(gram.to_dense()), np.asarray(gram_rec.to_dense()),
        rtol=2e-4, atol=2e-4,
    )

    # 3. planned solve front door
    x = lstsq(a, b, ridge=1e-3)
    assert x.shape == (n, r), x.shape

    snap = obs.metrics.validate_snapshot(obs.metrics.snapshot())

    counters = snap["counters"]
    assert counters, "metrics snapshot has no counters"
    assert any(k.startswith("tune.cache.") for k in counters), (
        "no plan-cache counters in snapshot: " + ", ".join(sorted(counters))
    )
    assert any(k.startswith("dispatch.") for k in counters), (
        "no dispatch counters in snapshot: " + ", ".join(sorted(counters))
    )

    spans = snap["spans"]
    levels = {k for k in spans if ".encode.L" in k or ".rec." in k}
    assert levels, "no recursion-level spans recorded: " + ", ".join(sorted(spans))
    assert any(k.startswith("solve.") for k in spans), sorted(spans)

    cal_ops = {row["op"] for row in snap["calibration"]}
    assert {"ata", "solve"} <= cal_ops, (
        f"calibration rows cover {sorted(cal_ops)}, want ata + solve"
    )

    obs.metrics.export_json(out_path)
    print(obs.report())
    print(
        f"obs smoke OK: {len(counters)} counters, {len(spans)} span names, "
        f"{len(snap['calibration'])} calibration rows -> {out_path}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
