"""Nestable span API — the tracing half of ``repro.obs``.

A *span* names one region of the dispatch pipeline: a recursion level, a
batched/fused leaf launch, a kernel wrapper, the solve front door, an SPMD
schedule body. Spans are threaded through the stack unconditionally, but

* **disabled (the default)** — :func:`span` returns one shared no-op
  context manager. No jax import, no allocation beyond the call itself, no
  effect on the traced program: instrumented paths stay bitwise- and
  jaxpr-identical to their uninstrumented form (regression-tested in
  ``tests/test_obs.py``).
* **enabled** (:func:`enable` / ``REPRO_OBS=1``) — each span records an
  event into a bounded in-process buffer (name, depth, attrs) and wraps
  the region in ``jax.named_scope`` (so op names in lowered HLO carry the
  span path — metadata only, never an op) plus
  ``jax.profiler.TraceAnnotation`` (so host trace timelines from
  ``jax.profiler.trace`` show the same region names).

Spans deliberately do **not** time traced code: inside ``jit`` they open
and close at trace time, where wall clock means compile time. Wall-clock
measurement lives at the eager dispatch sites (``repro.obs.calibrate``)
and in the profiler traces the annotations label.
"""

from __future__ import annotations

import os
import threading
from collections import Counter

__all__ = [
    "enable",
    "disable",
    "enabled",
    "span",
    "span_counts",
    "span_events",
    "reset",
    "MAX_EVENTS",
]

_ENABLED = os.environ.get("REPRO_OBS", "") == "1"
_LOCK = threading.Lock()
_COUNTS: Counter = Counter()          # span name -> times entered
_EVENTS: list = []                    # ordered (name, depth, attrs), bounded
_DEPTH = threading.local()

# events beyond this are counted but not stored — an unrolled 7^L recursion
# must never grow host memory unboundedly just because tracing is on.
MAX_EVENTS = 10_000


def enabled() -> bool:
    return _ENABLED


def enable() -> None:
    """Turn span recording on (and named_scope/TraceAnnotation wrapping)."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def reset() -> None:
    """Drop recorded spans (tests; between benchmark modules)."""
    with _LOCK:
        _COUNTS.clear()
        _EVENTS.clear()


def span_counts() -> dict:
    """{span name: times entered} since the last :func:`reset`."""
    with _LOCK:
        return dict(_COUNTS)


def span_events() -> list:
    """Ordered recorded events ``(name, depth, attrs)`` (bounded by
    ``MAX_EVENTS``; counts in :func:`span_counts` are always complete)."""
    with _LOCK:
        return list(_EVENTS)


class _NullSpan:
    """The shared disabled-mode span: enters and exits with no effect."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("name", "attrs", "_scope", "_annotation")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        depth = getattr(_DEPTH, "v", 0)
        _DEPTH.v = depth + 1
        with _LOCK:
            _COUNTS[self.name] += 1
            if len(_EVENTS) < MAX_EVENTS:
                _EVENTS.append((self.name, depth, self.attrs))
        import jax

        self._scope = jax.named_scope(self.name)
        self._scope.__enter__()
        try:
            self._annotation = jax.profiler.TraceAnnotation(self.name)
            self._annotation.__enter__()
        except Exception:
            # host profiler unavailable (stripped containers): the span
            # still records + names scopes; annotation becomes a no-op.
            self._annotation = None
        return self

    def __exit__(self, *exc):
        if self._annotation is not None:
            self._annotation.__exit__(*exc)
        self._scope.__exit__(*exc)
        _DEPTH.v = getattr(_DEPTH, "v", 1) - 1
        return False


def span(name: str, **attrs):
    """Context manager naming one region of the dispatch pipeline.

    ``name`` is a dotted path (``"ata.encode.L2"``, ``"kernels.syrk"``);
    keyword attrs ride along into the event buffer (small static values
    only — shapes, leaf counts, dispatch kinds; never arrays).
    """
    if not _ENABLED:
        return _NULL
    return _Span(name, attrs)
