"""JAX version-compatibility shims.

The repo targets the jax>=0.5 public APIs (``jax.shard_map``,
``jax.sharding.AxisType``, ``pltpu.CompilerParams``); this module maps them
onto their older homes so the library also runs on jax 0.4.3x (the container
baseline). Keep every version branch in this one file.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "AxisType", "make_mesh", "tpu_compiler_params"]

try:  # jax>=0.5
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (jax>=0.5) / ``TPUCompilerParams`` (older)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    """``jax.shard_map`` with the old experimental fallback.

    ``check_vma`` (new name) maps to ``check_rep`` (old name); ``None`` means
    the backend default.
    """
    if hasattr(jax, "shard_map"):
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    if check_vma is not None:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
