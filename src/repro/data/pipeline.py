"""Deterministic, resumable synthetic-token data pipeline.

Production properties we reproduce without external data:

* **Step-indexed determinism** — batch ``i`` is a pure function of
  ``(seed, i)`` (counter-based PRNG), so a restart at step ``i`` regenerates
  exactly the stream a crashed run would have seen: the checkpoint only needs
  the integer step, never pipeline buffers.
* **Per-host sharding** — each host materializes only its slice of the
  global batch (``host_slice``), matching multi-controller JAX.
* **Prefetch** — a background thread keeps ``prefetch`` batches ready.

The token distribution is a Zipfian mixture with a Markov flavor so the
cross-entropy of a real model decreases measurably during the example
training runs (pure uniform tokens would pin loss at log V).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["SyntheticLM", "make_batch"]


def _batch_rng(seed: int, step: int) -> np.random.Generator:
    # counter-based: independent stream per (seed, step)
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def make_batch(
    cfg: ModelConfig,
    shape: ShapeConfig,
    seed: int,
    step: int,
    host_index: int = 0,
    host_count: int = 1,
    seq_len: Optional[int] = None,
    batch: Optional[int] = None,
) -> Dict[str, np.ndarray]:
    """Materialize this host's slice of global batch ``step``."""
    b = batch if batch is not None else shape.global_batch
    s = seq_len if seq_len is not None else shape.seq_len
    if b % host_count:
        raise ValueError(f"global batch {b} not divisible by {host_count} hosts")
    b_local = b // host_count
    rng = _batch_rng(seed, step)
    # skip ahead deterministically to this host's slice
    v = cfg.vocab_size

    def sample_tokens(r, shape_):
        # Zipf-ish: x ~ floor(v * u^3) puts mass on small ids
        u = r.random(shape_)
        base = np.minimum((v * u**3).astype(np.int64), v - 1)
        # Markov flavor: with p=0.3, repeat the previous token + 1 (mod v)
        rep = r.random(shape_) < 0.3
        shifted = np.roll(base, 1, axis=-1)
        out = np.where(rep, (shifted + 1) % v, base)
        return out.astype(np.int32)

    # one independent generator per host slice keeps slices uncorrelated
    host_rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, host_index])
    )
    if cfg.num_codebooks > 1:
        toks = sample_tokens(host_rng, (b_local, s, cfg.num_codebooks))
    else:
        toks = sample_tokens(host_rng, (b_local, s))
    out: Dict[str, np.ndarray] = {"tokens": toks}
    if cfg.modality == "vision_text":
        n_img = min(cfg.num_patches, max(s - 8, 0))
        out["tokens"] = toks[:, : s - n_img] if toks.ndim == 2 else toks
        out["image_embeds"] = host_rng.standard_normal(
            (b_local, n_img, cfg.d_model)
        ).astype(np.float32) * 0.02
        out["labels"] = out["tokens"]
    else:
        out["labels"] = toks
    return out


class SyntheticLM:
    """Prefetching iterator over deterministic synthetic batches."""

    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        seed: int = 0,
        start_step: int = 0,
        host_index: int = 0,
        host_count: int = 1,
        prefetch: int = 2,
        seq_len: Optional[int] = None,
        batch: Optional[int] = None,
    ):
        self.cfg, self.shape = cfg, shape
        self.seed = seed
        self.step = start_step
        self.host_index, self.host_count = host_index, host_count
        self._seq_len, self._batch = seq_len, batch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = make_batch(
                self.cfg, self.shape, self.seed, step,
                self.host_index, self.host_count,
                seq_len=self._seq_len, batch=self._batch,
            )
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def state(self) -> dict:
        """Resumable state — just the next step index."""
        return {"seed": self.seed, "next_step": self.step}

    def close(self):
        self._stop.set()
