"""The jitted train step: loss, grads, clipping, optimizer, microbatching.

Sharding strategy (see DESIGN.md §6): params/optimizer states get
PartitionSpecs from ``parallel.sharding``; ZeRO-1 additionally shards
optimizer moments over the ``data`` axis. The step is a pure function so
``jax.jit(..., donate_argnums=0)`` reuses the state buffers in place.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.models.transformer import forward_train
from repro.optim import apply_updates, build as build_optimizer
from repro.optim.adamw import clip_by_global_norm

__all__ = [
    "cross_entropy",
    "make_loss_fn",
    "make_train_step",
    "state_specs",
    "TrainState",
]

TrainState = Dict[str, Any]  # {"params": ..., "opt": ..., "step": int32}


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_real: int) -> jax.Array:
    """Mean token NLL; logits may carry padded vocab columns (masked out).

    Written to stay **vocab-shard friendly**: no ``take_along_axis`` gather
    over the vocab axis (which forces GSPMD to all-gather the full-vocab
    logits — tens of GB per device at 150k+ vocabs). The label logit is
    picked with a fused iota-compare masked reduction and the normalizer is
    a plain reduction, both of which partition cleanly over a
    ``model``-sharded vocab dim.
    """
    v_pad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    iota = jnp.arange(v_pad)
    if v_pad > vocab_real:
        logits = jnp.where(iota >= vocab_real, -1e30, logits)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    label_hit = iota == labels[..., None].astype(jnp.int32)
    label_logit = jnp.sum(jnp.where(label_hit, shifted, 0.0), axis=-1)
    return (lse - label_logit).mean()


def make_loss_fn(cfg: ModelConfig, mesh: Optional[Mesh], run: RunConfig):
    compute_dtype = jnp.dtype(run.compute_dtype)

    def loss_fn(params, batch):
        logits, aux = forward_train(
            params, batch, cfg, mesh, remat=run.remat, compute_dtype=compute_dtype
        )
        loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_coef * aux
        return loss, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    mesh: Optional[Mesh],
    run: RunConfig,
    total_steps: int = 10_000,
    max_grad_norm: float = 1.0,
):
    """Returns (train_step, optimizer). train_step(state, batch) -> (state,
    metrics); microbatches split the batch's leading dim and accumulate
    grads in f32 under ``lax.scan`` (comm overlap: XLA schedules each
    microbatch's reduce against the next one's compute)."""
    opt = build_optimizer(run.optimizer, total_steps)
    loss_fn = make_loss_fn(cfg, mesh, run)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    n_micro = max(run.microbatch, 1)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        params = state["params"]

        if n_micro == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            def slice_micro(x, i):
                b = x.shape[0] // n_micro
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)

            def micro_body(acc, i):
                mb = jax.tree.map(lambda x: slice_micro(x, i), batch)
                (_, m), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / n_micro, acc, g
                )
                return acc, m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, ms = jax.lax.scan(
                micro_body, zeros, jnp.arange(n_micro, dtype=jnp.int32)
            )
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = opt.update(grads, state["opt"], params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return {"params": params, "opt": opt_state, "step": state["step"] + 1}, metrics

    return train_step, opt


# ---------------------------------------------------------------------------
# sharding specs for the full train state
# ---------------------------------------------------------------------------


def _zero1(spec: P, shape, mesh: Mesh) -> P:
    """Add 'data' sharding to the first unsharded, divisible dim (ZeRO-1)."""
    if "data" not in mesh.shape:
        return spec
    d = mesh.shape["data"]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (ax, dim) in enumerate(zip(parts, shape)):
        if ax is None and dim % d == 0 and dim >= d:
            parts[i] = "data"
            return P(*parts)
    return spec


def state_specs(
    cfg: ModelConfig,
    mesh: Mesh,
    run: RunConfig,
    params_abs,
    opt_state_abs,
) -> TrainState:
    """PartitionSpec tree for {"params", "opt", "step"}.

    Optimizer moments mirror the param specs; with ZeRO-1 they additionally
    shard over 'data'. Shampoo stat stacks (nb, b, b) shard their block dim
    over 'data' (block ownership — each data shard owns a subset of blocks,
    the optimizer-level analogue of the paper's disjoint tasks).
    """
    from repro.parallel.sharding import param_specs

    p_specs = param_specs(mesh, cfg)

    def like_param(spec_tree, abs_tree, zero1: bool):
        def one(spec, ab):
            if zero1:
                return _zero1(spec, ab.shape, mesh)
            return spec

        return jax.tree.map(
            one, spec_tree, abs_tree, is_leaf=lambda x: isinstance(x, P)
        )

    zero1 = run.optimizer.zero1
    opt_specs: Any
    if run.optimizer.name == "adamw":
        opt_specs = {
            "m": like_param(p_specs, params_abs, zero1),
            "v": like_param(p_specs, params_abs, zero1),
            "step": P(),
        }
    else:  # shampoo: map specs onto its state tree
        def shampoo_leaf_spec(ab):
            # stat/preconditioner stacks lead with the parameter-block batch
            # dim: (nb, b, b) dense, (nb, T, bn, bn) packed SymmetricMatrix
            # blocks. Both shard block ownership over 'data'. The packed
            # (4-D) case used to fall through to fully-replicated — the
            # dense-replication bug that made ZeRO-1 shampoo state 2× its
            # packed size again on every device.
            if ab.ndim in (3, 4):
                shard = (
                    zero1 and "data" in mesh.shape
                    and ab.shape[0] % mesh.shape["data"] == 0
                )
                parts = ["data" if shard else None] + [None] * (ab.ndim - 1)
                return P(*parts)
            return P(*([None] * ab.ndim))

        opt_specs = {
            "m": like_param(p_specs, params_abs["params"] if isinstance(params_abs, dict) and "params" in params_abs else params_abs, zero1),
            "v": like_param(p_specs, params_abs["params"] if isinstance(params_abs, dict) and "params" in params_abs else params_abs, zero1),
            "shampoo": jax.tree.map(shampoo_leaf_spec, opt_state_abs["shampoo"]),
            "step": P(),
        }
    return {"params": p_specs, "opt": opt_specs, "step": P()}
