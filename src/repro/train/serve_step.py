"""Serving steps: prefill (cache construction + first logits) and decode
(one token per sequence against the KV/SSM cache), plus sampling."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models.transformer import forward_decode, forward_train

__all__ = ["make_prefill_step", "make_decode_step", "sample_logits"]


def sample_logits(
    logits: jax.Array, key, temperature: float = 1.0, vocab_real: Optional[int] = None
) -> jax.Array:
    """Temperature sampling over the last position. logits: (B, 1, [K,] V)."""
    lg = logits.astype(jnp.float32)
    if vocab_real is not None and lg.shape[-1] > vocab_real:
        mask = jnp.arange(lg.shape[-1]) >= vocab_real
        lg = jnp.where(mask, -1e30, lg)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, lg / temperature, axis=-1).astype(jnp.int32)


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                      compute_dtype=jnp.bfloat16, cache_len: Optional[int] = None):
    """prefill(params, batch) -> (last_logits, cache). The cache is laid out
    for the decode step (absolute slots; ring buffers for SWA layers)."""

    def prefill(params, batch):
        logits, _aux, cache = forward_train(
            params, batch, cfg, mesh,
            compute_dtype=compute_dtype, return_cache=True, cache_len=cache_len,
        )
        return logits[:, -1:], cache

    return prefill


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                     compute_dtype=jnp.bfloat16):
    """decode(params, tokens, cache, pos) -> (logits, new_cache)."""

    def decode(params, tokens, cache, pos):
        return forward_decode(
            params, tokens, cache, pos, cfg, mesh, compute_dtype=compute_dtype
        )

    return decode
