"""Core library: the paper's contribution as composable JAX modules.

Public API:
  * :func:`repro.core.ata` — Strassen-based ``alpha·AᵀA`` (paper Algorithm 1).
  * :func:`repro.core.strassen_tn` — rectangular TN Strassen (FastStrassen).
  * :mod:`repro.core.reference` — naive oracles + exact flop counters.
  * :mod:`repro.core.task_tree` — ATA-S/ATA-D task scheduler (paper §4.1).
  * :mod:`repro.core.distributed` — shard_map gram schedules (paper §4.2/4.3).
"""

from repro.core.ata import ata
from repro.core.strassen import DEFAULT_N_BASE, strassen_tn
from repro.core import reference

__all__ = ["ata", "strassen_tn", "reference", "DEFAULT_N_BASE"]
