"""Core library: the paper's contribution as composable JAX modules.

Public API:
  * :func:`repro.core.ata` — Strassen-based ``alpha·AᵀA`` (paper Algorithm 1),
    dense or packed-symmetric output.
  * :func:`repro.core.ata_batched` — the same recursion with a leading batch
    dim (one trace / one kernel launch per base tile; Shampoo's gram path).
  * :class:`repro.core.SymmetricMatrix` — packed lower-triangular block
    storage for symmetric results (``repro.core.symmetric``).
  * :func:`repro.core.strassen_tn` — rectangular TN Strassen (FastStrassen).
  * :mod:`repro.core.reference` — naive oracles + exact flop counters.
  * :mod:`repro.core.task_tree` — ATA-S/ATA-D task scheduler (paper §4.1).
  * :mod:`repro.core.distributed` — shard_map gram schedules (paper §4.2/4.3).
"""

from repro.core.ata import ata, ata_batched
from repro.core.strassen import DEFAULT_N_BASE, strassen_tn
from repro.core.symmetric import SymmetricMatrix
from repro.core import reference

__all__ = [
    "ata",
    "ata_batched",
    "strassen_tn",
    "SymmetricMatrix",
    "reference",
    "DEFAULT_N_BASE",
]
