"""The paper's task-tree scheduler (Section 4.1) — faithful reproduction.

Implements:

* the parallel-level formulas ℓ(P) for ATA-D (Eq. 5) and ATA-S (Eq. 6);
* the task tree 𝒯: a BFS expansion of the recursion tree of ATA-naive
  (recursive-GEMM instead of Strassen), interrupted once 𝒯 has ≥ P leaves;
* leaf tasks carrying ``computation_type ∈ {ATA, ATB}`` and the row/column
  offsets+sizes of the A/B/C sub-matrices (paper §4.1.1, items 1-3);
* the α = 1/2 load-balancing rule (an AᵀB task costs ≈ 2× an AᵀA task of the
  same size, paper §4.1.2) and an LPT assignment of leaves to P processes.

Two expansion modes mirror the paper:

* ``mode='distributed'`` (ATA-D): an ATA node fans out into **6** children
  (4 recursive ATA + 2 recursive-GEMM), an ATB node into **8** (the 2×2×2
  recursive-GEMM splits) — Algorithm 1 + Algorithm 2.
* ``mode='shared'`` (ATA-S): vertical/horizontal striping (Fig. 2, Eq. 7)
  so every task writes a **disjoint** block of C: an ATA node fans out into
  **3** children (ATA on the left column stripe → C11, ATA on the right
  stripe → C22, one full-height ATB stripe product → C21) and an ATB node
  into **4** (one per C quadrant, full contraction height).

The SPMD executor (`repro.core.distributed`) uses a shape-uniform
block-cyclic realization of the same disjoint-task principle (see its
docstring); this module is the faithful model — used for tests, the
analytic speedup benchmarks (paper Fig. 5/6), and for choosing stripe
widths.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import List, Literal, Optional, Tuple

__all__ = [
    "Task",
    "ell_distributed",
    "ell_shared",
    "build_task_tree",
    "assign_tasks",
    "task_flops",
    "modeled_speedup",
]

ALPHA = 0.5  # paper's load-balancing parameter (§4.1.2)


def ell_distributed(p: int) -> int:
    """Eq. (5): number of parallel levels in the ATA-D task tree."""
    if p < 1:
        raise ValueError("P must be >= 1")
    if p == 1:
        return 0
    if p <= 6:
        return 1
    q = p / 4.0
    k = max(0, math.floor(math.log(q, 8)))  # max k with q / 8^k >= 1
    rem = q % (8 ** max(k, 1))
    return 1 + k + (1 if rem > 0 else 0)


def ell_shared(p: int) -> int:
    """Eq. (6): number of parallel levels in the ATA-S task tree."""
    if p < 1:
        raise ValueError("P must be >= 1")
    if p == 1:
        return 0
    if p <= 3:
        return 1
    q = p / 2.0
    k = max(0, math.floor(math.log(q, 4)))  # max k with q / 4^k >= 1
    rem = q % (4 ** max(k, 1))
    return 1 + k + (1 if rem > 0 else 0)


@dataclasses.dataclass(frozen=True)
class Task:
    """A node of the task tree 𝒯 (leaf tasks = actual multiplications).

    Offsets/sizes address sub-matrices of the *original* A (and of C):
    ``ATA``: C[c_off : c_off+c_rows, c_off : c_off+c_cols] += A_aᵀ·A_a
    ``ATB``: C[...] += A_aᵀ·A_b  where A_a/A_b are column×row windows of A.
    """

    kind: Literal["ATA", "ATB"]
    # A operand window: rows [ar0, ar1), cols [ac0, ac1)
    ar0: int
    ar1: int
    ac0: int
    ac1: int
    # B operand window (ATB only; for ATA it mirrors the A window)
    br0: int = -1
    br1: int = -1
    bc0: int = -1
    bc1: int = -1
    # C output window: rows [cr0, cr1), cols [cc0, cc1)
    cr0: int = 0
    cr1: int = 0
    cc0: int = 0
    cc1: int = 0
    parent: int = -1  # index of the parent node (result-retrieval edge, ATA-D)
    depth: int = 0

    def weight(self) -> float:
        """Relative cost model used for α-balancing: ATB ≈ 2× ATA (§4.1.2)."""
        m = self.ar1 - self.ar0
        n = self.ac1 - self.ac0
        if self.kind == "ATA":
            return m * n * (n + 1) / 2.0
        k = self.bc1 - self.bc0
        return float(m * n * k)


def _children_distributed(t: Task, idx: int) -> List[Task]:
    """ATA → 6 children (Alg. 1); ATB → 8 children (Alg. 2)."""
    m1 = (t.ar0 + t.ar1) // 2
    n1 = (t.ac0 + t.ac1) // 2
    d = t.depth + 1
    if t.kind == "ATA":
        c1 = (t.ac1 - t.ac0) // 2  # cols in the C11 block
        out = [
            # four recursive ATA calls (lines 7-10)
            Task("ATA", t.ar0, m1, t.ac0, n1, cr0=t.cr0, cr1=t.cr0 + c1,
                 cc0=t.cc0, cc1=t.cc0 + c1, parent=idx, depth=d),
            Task("ATA", m1, t.ar1, t.ac0, n1, cr0=t.cr0, cr1=t.cr0 + c1,
                 cc0=t.cc0, cc1=t.cc0 + c1, parent=idx, depth=d),
            Task("ATA", t.ar0, m1, n1, t.ac1, cr0=t.cr0 + c1, cr1=t.cr1,
                 cc0=t.cc0 + c1, cc1=t.cc1, parent=idx, depth=d),
            Task("ATA", m1, t.ar1, n1, t.ac1, cr0=t.cr0 + c1, cr1=t.cr1,
                 cc0=t.cc0 + c1, cc1=t.cc1, parent=idx, depth=d),
            # two AᵀB calls for C21 (lines 11-12): A12ᵀA11 and A22ᵀA21
            Task("ATB", t.ar0, m1, n1, t.ac1, br0=t.ar0, br1=m1, bc0=t.ac0,
                 bc1=n1, cr0=t.cr0 + c1, cr1=t.cr1, cc0=t.cc0, cc1=t.cc0 + c1,
                 parent=idx, depth=d),
            Task("ATB", m1, t.ar1, n1, t.ac1, br0=m1, br1=t.ar1, bc0=t.ac0,
                 bc1=n1, cr0=t.cr0 + c1, cr1=t.cr1, cc0=t.cc0, cc1=t.cc0 + c1,
                 parent=idx, depth=d),
        ]
        return out
    # ATB → RecursiveGEMM's 2×2×2 split (Algorithm 2)
    out = []
    bn1 = (t.bc0 + t.bc1) // 2
    cr_mid = (t.cr0 + t.cr1) // 2
    cc_mid = (t.cc0 + t.cc1) // 2
    for i in range(2):  # C row-block = A column half
        a_c = (t.ac0, n1) if i == 0 else (n1, t.ac1)
        c_r = (t.cr0, cr_mid) if i == 0 else (cr_mid, t.cr1)
        for j in range(2):  # C col-block = B column half
            b_c = (t.bc0, bn1) if j == 0 else (bn1, t.bc1)
            c_c = (t.cc0, cc_mid) if j == 0 else (cc_mid, t.cc1)
            for kk in range(2):  # contraction half
                a_r = (t.ar0, m1) if kk == 0 else (m1, t.ar1)
                out.append(
                    Task("ATB", a_r[0], a_r[1], a_c[0], a_c[1],
                         br0=a_r[0], br1=a_r[1], bc0=b_c[0], bc1=b_c[1],
                         cr0=c_r[0], cr1=c_r[1], cc0=c_c[0], cc1=c_c[1],
                         parent=idx, depth=d)
                )
    return out


def _children_shared(t: Task, idx: int) -> List[Task]:
    """ATA → 3 children, ATB → 4 children (Fig. 2 striping, disjoint C)."""
    n1 = (t.ac0 + t.ac1) // 2
    d = t.depth + 1
    if t.kind == "ATA":
        c1 = (t.ac1 - t.ac0) // 2
        return [
            # full-height column stripes: disjoint C blocks, no k-split
            Task("ATA", t.ar0, t.ar1, t.ac0, n1, cr0=t.cr0, cr1=t.cr0 + c1,
                 cc0=t.cc0, cc1=t.cc0 + c1, parent=idx, depth=d),
            Task("ATA", t.ar0, t.ar1, n1, t.ac1, cr0=t.cr0 + c1, cr1=t.cr1,
                 cc0=t.cc0 + c1, cc1=t.cc1, parent=idx, depth=d),
            Task("ATB", t.ar0, t.ar1, n1, t.ac1, br0=t.ar0, br1=t.ar1,
                 bc0=t.ac0, bc1=n1, cr0=t.cr0 + c1, cr1=t.cr1, cc0=t.cc0,
                 cc1=t.cc0 + c1, parent=idx, depth=d),
        ]
    bn1 = (t.bc0 + t.bc1) // 2
    cr_mid = (t.cr0 + t.cr1) // 2
    cc_mid = (t.cc0 + t.cc1) // 2
    out = []
    for i in range(2):
        a_c = (t.ac0, n1) if i == 0 else (n1, t.ac1)
        c_r = (t.cr0, cr_mid) if i == 0 else (cr_mid, t.cr1)
        for j in range(2):
            b_c = (t.bc0, bn1) if j == 0 else (bn1, t.bc1)
            c_c = (t.cc0, cc_mid) if j == 0 else (cc_mid, t.cc1)
            out.append(
                Task("ATB", t.ar0, t.ar1, a_c[0], a_c[1], br0=t.ar0,
                     br1=t.ar1, bc0=b_c[0], bc1=b_c[1], cr0=c_r[0],
                     cr1=c_r[1], cc0=c_c[0], cc1=c_c[1], parent=idx, depth=d)
            )
    return out


def build_task_tree(
    m: int,
    n: int,
    p: int,
    mode: Literal["shared", "distributed"] = "shared",
    min_dim: int = 1,
) -> List[Task]:
    """BFS-expand the ATA-naive recursion tree until ≥ P leaves (paper §4.1.1).

    Returns the leaf tasks in BFS order. Expansion stops early on tasks whose
    dimensions would drop below ``min_dim``.
    """
    if p < 1:
        raise ValueError("P must be >= 1")
    children = _children_shared if mode == "shared" else _children_distributed
    root = Task("ATA", 0, m, 0, n, cr0=0, cr1=n, cc0=0, cc1=n, parent=-1)
    leaves = deque([(root, 0)])
    node_count = 1
    while len(leaves) < p:
        # expand the oldest (shallowest) expandable leaf — BFS order
        for _ in range(len(leaves)):
            t, idx = leaves[0]
            dims = (t.ar1 - t.ar0, t.ac1 - t.ac0)
            if min(dims) >= 2 * min_dim:
                leaves.popleft()
                for ch in children(t, idx):
                    node_count += 1
                    leaves.append((ch, node_count))
                break
            leaves.rotate(-1)
        else:
            break  # nothing expandable
        continue
    return [t for t, _ in leaves]


def assign_tasks(tasks: List[Task], p: int) -> List[List[Task]]:
    """LPT (longest-processing-time) assignment of leaf tasks to P processes.

    Realizes the α = 1/2 balance: ATB leaves weigh ≈2× same-size ATA leaves
    via :meth:`Task.weight`.
    """
    buckets: List[List[Task]] = [[] for _ in range(p)]
    loads = [0.0] * p
    for t in sorted(tasks, key=lambda t: -t.weight()):
        i = loads.index(min(loads))
        buckets[i].append(t)
        loads[i] += t.weight()
    return buckets


def task_flops(tasks: List[Task]) -> float:
    return sum(t.weight() for t in tasks)


def modeled_speedup(n: int, p: int, mode: str = "shared") -> float:
    """Analytic speedup model: serial weight / critical-path weight.

    Mirrors paper Eq. (8): T(n,P) = O(P) + O(n^{log₂7} / 4^{ℓ(P)}); we use
    the actual LPT-balanced makespan of the task tree, which reproduces the
    step-wise curves of Fig. 5/6.
    """
    tasks = build_task_tree(n, n, p, mode=mode)
    buckets = assign_tasks(tasks, p)
    serial = task_flops(tasks)
    makespan = max(task_flops(b) for b in buckets)
    return serial / max(makespan, 1.0)
