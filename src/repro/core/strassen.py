"""Generalized rectangular Strassen for the TN product ``C = alpha·AᵀB``.

This is the paper's FastStrassen (Algorithm 1, lines 14-18) adapted to JAX/TPU:

* **Trace-time recursion** — the recursion runs in Python over static shapes
  during ``jax.jit`` tracing and unrolls into an XLA graph. XLA's buffer
  assignment plays the role of the paper's pre-allocated ``M, P, Q`` scratch
  (Section 3.3): no per-level allocation happens at run time.

* **TN form is preserved all the way down.** The paper notes that row-major
  ``AᵀA`` is cache-hostile because access is column-wise; on TPU the fix is to
  never materialize ``Aᵀ``. With ``X = Aᵀ`` split into quadrants,
  ``X11 = A11ᵀ, X12 = A21ᵀ, X21 = A12ᵀ, X22 = A22ᵀ``, every one of Strassen's
  seven products is again a TN product of *combinations of A blocks in their
  original orientation* against combinations of B blocks. The base case hands
  a TN ``dot_general`` (contracting dims ``((0,),(0,))``) to the MXU, which
  consumes the transpose inside its dataflow for free.

* **Odd sizes** — handled by zero-padding odd dims up to even at each level
  and cropping the result (the paper's "virtual padding" of the ``axpy`` sums;
  under XLA a 1-row ``lax.pad`` fuses, so the malloc/copy overhead the paper
  engineers around does not exist here).

* **Variants** — ``'strassen'`` (paper-faithful: 7 mults, 18 adds) and
  ``'winograd'`` (beyond-paper: 7 mults, 15 adds; lowers the memory roofline
  term).

* **Base case** — recursion cuts off when any dimension ≤ ``n_base`` and hands
  the tile to ``base_dot`` (default: MXU-dense ``dot_general``; the Pallas
  ``gemm_tn`` kernel via ``repro.kernels.ops`` on TPU). On TPU the cutoff is
  the analogue of the paper's "fits in cache": below it, Strassen's extra VPU
  additions cost more than the MXU saves.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.tune.defaults import DEFAULT_N_BASE  # re-export (tunables live there)

__all__ = ["strassen_tn", "DEFAULT_N_BASE", "resolve_tunables"]


def resolve_tunables(
    plan,
    n_base,
    variant,
    packed_block,
    *,
    op: str,
    m: int,
    n: int,
    k: Optional[int] = None,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
):
    """Fill unset tunables (shared by `strassen_tn`, `ata`, `distributed`).

    Three regimes, in order:

    * a ``plan`` was handed in → unset args come from it;
    * no algorithm tunable (``n_base``/``variant``) was pinned → consult the
      ``repro.tune.plan`` front door (analytic model / plan cache) — every
      default dispatch is planned (``packed_block`` is a storage-layout
      parameter, not an algorithm choice: pinning it alone — as packed
      producers must, for cross-producer layout compatibility — does not
      bypass the planner);
    * the caller pinned an algorithm tunable manually → fill the rest with
      the static paper-faithful defaults (``repro.tune.defaults``),
      **without** consulting the planner, so explicit calls stay bitwise
      reproducible regardless of cache state.

    Returns ``(plan_or_None, n_base, variant, packed_block)``; a plan with
    ``algorithm='dense'`` comes back with ``n_base`` covering the whole
    operand, which is how "classical one-dot dispatch" is expressed to the
    recursion.
    """
    from repro.tune import defaults as _defaults

    if plan is None and n_base is None and variant is None:
        from repro.tune import plan as _plan_fn

        plan = _plan_fn(op=op, m=m, n=n, k=k, batch=batch, dtype=dtype, out=out)
    if plan is not None:
        n_base = plan.n_base if n_base is None else n_base
        variant = plan.variant if variant is None else variant
        packed_block = plan.packed_block if packed_block is None else packed_block
        if plan.algorithm == "dense":
            n_base = max(n_base, m, n, k or n)
    else:
        n_base = _defaults.DEFAULT_N_BASE if n_base is None else n_base
        variant = _defaults.DEFAULT_VARIANT if variant is None else variant
        packed_block = (
            _defaults.DEFAULT_PACKED_BLOCK if packed_block is None else packed_block
        )
    return plan, n_base, variant, packed_block


def _plan_base_fns(plan, base_syrk, base_dot):
    """Pallas base kernels per the plan (when the caller supplied none)."""
    if plan is not None and plan.use_kernels and base_syrk is None and base_dot is None:
        from repro.tune.apply import base_fns

        return base_fns(plan)
    return base_syrk, base_dot


def _dot_tn(a, b, acc_dtype):
    """Base-case ``AᵀB`` without materializing ``Aᵀ`` (TN dot_general).

    Operates on the last two dims; any leading dims are batch dims (used by
    the batched gram path in ``repro.core.ata.ata_batched``).
    """
    nb = a.ndim - 2
    batch = tuple(range(nb))
    return jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((nb,), (nb,)), (batch, batch)),
        preferred_element_type=acc_dtype,
    )


def _pad_even(x):
    """Zero-pad the last two dims of ``x`` up to even (virtual padding)."""
    m, n = x.shape[-2:]
    pm, pn = m & 1, n & 1
    if pm or pn:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)])
    return x


def _quadrants(x):
    m, n = x.shape[-2:]
    m2, n2 = m // 2, n // 2
    return (
        x[..., :m2, :n2],
        x[..., :m2, n2:],
        x[..., m2:, :n2],
        x[..., m2:, n2:],
    )


def _rec_strassen(a, b, n_base, base_dot, acc_dtype):
    """Classical Strassen recursion on the TN product (7 mults, 18 adds)."""
    m, n = a.shape[-2:]
    k = b.shape[-1]
    if min(m, n, k) <= n_base:
        return base_dot(a, b)

    a = _pad_even(a)
    b = _pad_even(b)
    a11, a12, a21, a22 = _quadrants(a)
    b11, b12, b21, b22 = _quadrants(b)

    rec = functools.partial(
        _rec_strassen, n_base=n_base, base_dot=base_dot, acc_dtype=acc_dtype
    )
    # With X = Aᵀ: X11=A11ᵀ X12=A21ᵀ X21=A12ᵀ X22=A22ᵀ. Classical formulas:
    m1 = rec(a11 + a22, b11 + b22)  # (X11+X22)(Y11+Y22)
    m2 = rec(a12 + a22, b11)        # (X21+X22)Y11
    m3 = rec(a11, b12 - b22)        # X11(Y12-Y22)
    m4 = rec(a22, b21 - b11)        # X22(Y21-Y11)
    m5 = rec(a11 + a21, b22)        # (X11+X12)Y22
    m6 = rec(a12 - a11, b11 + b12)  # (X21-X11)(Y11+Y12)
    m7 = rec(a21 - a22, b21 + b22)  # (X12-X22)(Y21+Y22)

    c11 = m1 + m4 - m5 + m7
    c12 = m3 + m5
    c21 = m2 + m4
    c22 = m1 - m2 + m3 + m6

    c = jnp.block([[c11, c12], [c21, c22]])
    return c[..., :n, :k]


def _rec_winograd(a, b, n_base, base_dot, acc_dtype):
    """Strassen-Winograd recursion (7 mults, 15 adds) — beyond-paper variant."""
    m, n = a.shape[-2:]
    k = b.shape[-1]
    if min(m, n, k) <= n_base:
        return base_dot(a, b)

    a = _pad_even(a)
    b = _pad_even(b)
    a11, a12, a21, a22 = _quadrants(a)
    b11, b12, b21, b22 = _quadrants(b)

    rec = functools.partial(
        _rec_winograd, n_base=n_base, base_dot=base_dot, acc_dtype=acc_dtype
    )
    # X blocks in A-space: X11=A11 X12=A21 X21=A12 X22=A22 (all transposed
    # implicitly by the TN product). Winograd schedule:
    s1 = a12 + a22          # X21 + X22
    s2 = s1 - a11           # S1 - X11
    s3 = a11 - a12          # X11 - X21
    s4 = a21 - s2           # X12 - S2
    t1 = b12 - b11          # Y12 - Y11
    t2 = b22 - t1           # Y22 - T1
    t3 = b22 - b12          # Y22 - Y12
    t4 = t2 - b21           # T2 - Y21

    p1 = rec(a11, b11)      # X11 Y11
    p2 = rec(a21, b21)      # X12 Y21
    p3 = rec(s4, b22)       # S4 Y22
    p4 = rec(a22, t4)       # X22 T4
    p5 = rec(s1, t1)        # S1 T1
    p6 = rec(s2, t2)        # S2 T2
    p7 = rec(s3, t3)        # S3 T3

    u2 = p1 + p6
    u3 = u2 + p7
    u4 = u2 + p5

    c11 = p1 + p2
    c12 = u4 + p3
    c21 = u3 - p4
    c22 = u3 + p5

    c = jnp.block([[c11, c12], [c21, c22]])
    return c[..., :n, :k]


def strassen_tn(
    a: jax.Array,
    b: jax.Array,
    *,
    alpha: float = 1.0,
    c: Optional[jax.Array] = None,
    beta: float = 1.0,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    base_dot: Optional[Callable] = None,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """``C = alpha·AᵀB (+ beta·C)`` via rectangular TN Strassen.

    Args:
      a: ``(m, n)`` left operand (used transposed, never materialized as Aᵀ).
        Leading batch dims are allowed if ``b`` carries matching ones (the
        recursion and base dot then run batched — one trace, no vmap).
      b: ``(m, k)`` right operand.
      alpha, c, beta: optional scaling/accumulation, BLAS-style.
      plan: a frozen :class:`repro.tune.Plan` carrying every tunable. With
        no plan and no pinned tunables, the dispatch is planned through
        ``repro.tune.plan`` (analytic cost model / plan cache).
      n_base: recursion cutoff — any dim ≤ n_base goes to the base matmul.
        Pinning this (or ``variant``) manually bypasses the planner.
      variant: ``'strassen'`` (paper-faithful) or ``'winograd'`` (15 adds).
      base_dot: base-case TN matmul ``f(a, b) -> aᵀb``. Defaults to a TN
        ``dot_general`` (MXU-native; the plan may swap in the Pallas
        ``gemm_tn`` kernel). Pass ``repro.kernels.ops.gemm_tn`` explicitly
        to force the kernel.
      acc_dtype: accumulation dtype for the base matmul
        (``preferred_element_type``).

    Returns:
      ``(n, k)`` product in ``acc_dtype`` (or the base_dot's output dtype).
    """
    if a.ndim < 2 or b.ndim < 2 or a.ndim != b.ndim:
        raise ValueError(f"strassen_tn expects 2-D+ operands, got {a.shape}, {b.shape}")
    if a.shape[-2] != b.shape[-2] or a.shape[:-2] != b.shape[:-2]:
        raise ValueError(
            f"contracting/batch dims mismatch: A is {a.shape}, B is {b.shape} "
            "(TN product contracts dim -2 of both; leading dims are batch)"
        )
    plan, n_base, variant, _ = resolve_tunables(
        plan, n_base, variant, None,
        op="gemm_tn", m=a.shape[-2], n=a.shape[-1], k=b.shape[-1],
        batch=math.prod(a.shape[:-2]) if a.ndim > 2 else 0,
        dtype=str(a.dtype),
    )
    if variant not in ("strassen", "winograd"):
        raise ValueError(f"unknown variant {variant!r}")
    if base_dot is None:
        _, base_dot = _plan_base_fns(plan, None, base_dot)
    if base_dot is None:
        base_dot = functools.partial(_dot_tn, acc_dtype=acc_dtype)

    rec = _rec_strassen if variant == "strassen" else _rec_winograd
    out = rec(a, b, n_base=n_base, base_dot=base_dot, acc_dtype=acc_dtype)
    if alpha != 1.0:
        out = alpha * out
    if c is not None:
        out = out + (beta * c if beta != 1.0 else c)
    return out
