"""Generalized rectangular Strassen for the TN product ``C = alpha·AᵀB``.

This is the paper's FastStrassen (Algorithm 1, lines 14-18) adapted to JAX/TPU:

* **Trace-time recursion** — the recursion runs in Python over static shapes
  during ``jax.jit`` tracing and unrolls into an XLA graph. XLA's buffer
  assignment plays the role of the paper's pre-allocated ``M, P, Q`` scratch
  (Section 3.3): no per-level allocation happens at run time.

* **TN form is preserved all the way down.** The paper notes that row-major
  ``AᵀA`` is cache-hostile because access is column-wise; on TPU the fix is to
  never materialize ``Aᵀ``. With ``X = Aᵀ`` split into quadrants,
  ``X11 = A11ᵀ, X12 = A21ᵀ, X21 = A12ᵀ, X22 = A22ᵀ``, every one of Strassen's
  seven products is again a TN product of *combinations of A blocks in their
  original orientation* against combinations of B blocks. The base case hands
  a TN ``dot_general`` (contracting dims ``((0,),(0,))``) to the MXU, which
  consumes the transpose inside its dataflow for free.

* **Odd sizes** — handled by **one root pad**: the dispatch computes the
  recursion depth ``L`` up front, zero-pads each dim once to a multiple of
  ``2^L`` (the paper's "virtual padding" hoisted out of the levels — a single
  ``lax.pad`` instead of one per level), and crops once at the root. Interior
  levels then always split exactly in half.

* **Variants** — ``'strassen'`` (paper-faithful: 7 mults, 18 adds) and
  ``'winograd'`` (beyond-paper: 7 mults, 15 adds; lowers the memory roofline
  term).

* **Leaf dispatch** — three formulations of the same arithmetic
  (``leaf_dispatch`` on the plan, DESIGN.md §2):

  - ``'unrolled'`` (legacy): the recursion emits one ``base_dot`` per leaf —
    ``7^L`` separate dots in the jaxpr.
  - ``'batched'``: an iterative, level-synchronous schedule. Each level
    *encodes* Strassen's ±1 operand combinations into a stacked tensor with a
    leading leaf-batch axis (pure adds/subs on ``(7^ℓ, m/2^ℓ, n/2^ℓ)``
    stacks), **all** ``7^L`` leaf products run as *one* batched TN dot, and
    the result is *decoded* level-by-level (the c11..c22 recombinations on
    stacks, quadrant concatenation). O(L) ops in the jaxpr instead of
    O(7^L); bitwise-equal to the unrolled form (tested).
  - ``'fused'``: no materialized operand combinations at all. Each leaf
    operand is described by a per-leaf ±1 *slot table* over the root
    leaf-block grid (built at trace time); the combinations are either
    folded into the Pallas leaf kernel's prologue (coefficient tables ride
    in as scalar-prefetch operands) or built as trace-time slice gathers on
    the XLA path. One leaf launch, shared decode, bitwise-equal to the
    other two (tested); classical variant only.

* **Base case** — recursion cuts off when any dimension ≤ ``n_base`` and hands
  the tile to ``base_dot`` (default: MXU-dense ``dot_general``; the Pallas
  ``gemm_tn`` kernel via ``repro.kernels.ops`` on TPU). On TPU the cutoff is
  the analogue of the paper's "fits in cache": below it, Strassen's extra VPU
  additions cost more than the MXU saves.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.tune.defaults import DEFAULT_N_BASE  # re-export (tunables live there)

__all__ = ["strassen_tn", "DEFAULT_N_BASE", "resolve_tunables"]


def resolve_tunables(
    plan,
    n_base,
    variant,
    packed_block,
    *,
    op: str,
    m: int,
    n: int,
    k: Optional[int] = None,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
    leaf_dispatch: Optional[str] = None,
):
    """Fill unset tunables (shared by `strassen_tn`, `ata`, `distributed`).

    Three regimes, in order:

    * a ``plan`` was handed in → unset args come from it;
    * no algorithm tunable (``n_base``/``variant``) was pinned → consult the
      ``repro.tune.plan`` front door (analytic model / plan cache) — every
      default dispatch is planned (``packed_block`` and ``leaf_dispatch``
      are layout/scheduling parameters, not algorithm choices: pinning one
      of them alone does not bypass the planner — ``leaf_dispatch`` never
      changes *values*, only how the leaves reach the hardware);
    * the caller pinned an algorithm tunable manually → fill the rest with
      the static paper-faithful defaults (``repro.tune.defaults``),
      **without** consulting the planner, so explicit calls stay bitwise
      reproducible regardless of cache state.

    Returns ``(plan_or_None, n_base, variant, packed_block, leaf_dispatch)``;
    a plan with ``algorithm='dense'`` comes back with ``n_base`` covering the
    whole operand, which is how "classical one-dot dispatch" is expressed to
    the recursion.
    """
    from repro.tune import defaults as _defaults

    if plan is None and n_base is None and variant is None:
        from repro.tune import plan as _plan_fn

        plan = _plan_fn(op=op, m=m, n=n, k=k, batch=batch, dtype=dtype, out=out)
    if plan is not None:
        n_base = plan.n_base if n_base is None else n_base
        variant = plan.variant if variant is None else variant
        packed_block = plan.packed_block if packed_block is None else packed_block
        if leaf_dispatch is None:
            # getattr: plans deserialized from pre-leaf_dispatch caches
            leaf_dispatch = getattr(plan, "leaf_dispatch", None)
        if plan.algorithm == "dense":
            n_base = max(n_base, m, n, k or n)
    else:
        n_base = _defaults.DEFAULT_N_BASE if n_base is None else n_base
        variant = _defaults.DEFAULT_VARIANT if variant is None else variant
        packed_block = (
            _defaults.DEFAULT_PACKED_BLOCK if packed_block is None else packed_block
        )
    if leaf_dispatch is None:
        leaf_dispatch = _defaults.DEFAULT_LEAF_DISPATCH
    if leaf_dispatch not in ("unrolled", "batched", "fused"):
        raise ValueError(
            f"unknown leaf_dispatch {leaf_dispatch!r}; "
            "use 'unrolled', 'batched' or 'fused'"
        )
    return plan, n_base, variant, packed_block, leaf_dispatch


def _plan_base_fns(plan, base_syrk, base_dot):
    """Pallas base kernels per the plan (when the caller supplied none)."""
    if plan is not None and plan.use_kernels and base_syrk is None and base_dot is None:
        from repro.tune.apply import base_fns

        return base_fns(plan)
    return base_syrk, base_dot


def _plan_fused_fns(plan):
    """(fused_syrk, fused_dot) Pallas fused leaf launches per the plan —
    ``(None, None)`` keeps the XLA trace-time gather path."""
    if plan is not None and plan.use_kernels:
        from repro.tune.apply import fused_fns

        return fused_fns(plan)
    return None, None


def _dot_tn(a, b, acc_dtype):
    """Base-case ``AᵀB`` without materializing ``Aᵀ`` (TN dot_general).

    Operates on the last two dims; any leading dims are batch dims (used by
    the batched gram path in ``repro.core.ata.ata_batched`` and by the
    batched leaf dispatch, whose leading dim is the leaf stack).
    """
    nb = a.ndim - 2
    batch = tuple(range(nb))
    return jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((nb,), (nb,)), (batch, batch)),
        preferred_element_type=acc_dtype,
    )


# ---------------------------------------------------------------------------
# root padding (the per-level _pad_even of the seed, hoisted to dispatch)
# ---------------------------------------------------------------------------


def tree_depth(dims, n_base: int) -> int:
    """Levels the recursion performs: smallest ``L`` with
    ``min(⌈d/2^L⌉) ≤ n_base`` — identical to the legacy per-level
    pad-to-even recursion depth (⌈⌈d/2⌉/2⌉ = ⌈d/4⌉)."""
    L = 0
    while min(-(-d // (1 << L)) for d in dims) > n_base:
        L += 1
    return L


def _pad_root(x, L: int):
    """Zero-pad the last two dims of ``x`` up to multiples of ``2^L`` —
    the one root pad; every interior level then splits exactly in half."""
    step = 1 << L
    m, n = x.shape[-2:]
    pm = (-m) % step
    pn = (-n) % step
    if pm or pn:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)])
    return x


def _quadrants(x):
    m, n = x.shape[-2:]
    m2, n2 = m // 2, n // 2
    return (
        x[..., :m2, :n2],
        x[..., :m2, n2:],
        x[..., m2:, :n2],
        x[..., m2:, n2:],
    )


# ---------------------------------------------------------------------------
# unrolled leaf dispatch (legacy): one base_dot per leaf
# ---------------------------------------------------------------------------


def _rec_strassen(a, b, n_base, base_dot, acc_dtype):
    """Classical Strassen recursion on the TN product (7 mults, 18 adds).

    Operands arrive root-padded (dims divisible by 2 at every level above
    the cutoff), so no per-level padding or cropping happens here.
    """
    m, n = a.shape[-2:]
    k = b.shape[-1]
    if min(m, n, k) <= n_base:
        return base_dot(a, b)

    a11, a12, a21, a22 = _quadrants(a)
    b11, b12, b21, b22 = _quadrants(b)

    rec = functools.partial(
        _rec_strassen, n_base=n_base, base_dot=base_dot, acc_dtype=acc_dtype
    )
    # With X = Aᵀ: X11=A11ᵀ X12=A21ᵀ X21=A12ᵀ X22=A22ᵀ. Classical formulas:
    m1 = rec(a11 + a22, b11 + b22)  # (X11+X22)(Y11+Y22)
    m2 = rec(a12 + a22, b11)        # (X21+X22)Y11
    m3 = rec(a11, b12 - b22)        # X11(Y12-Y22)
    m4 = rec(a22, b21 - b11)        # X22(Y21-Y11)
    m5 = rec(a11 + a21, b22)        # (X11+X12)Y22
    m6 = rec(a12 - a11, b11 + b12)  # (X21-X11)(Y11+Y12)
    m7 = rec(a21 - a22, b21 + b22)  # (X12-X22)(Y21+Y22)

    # Balanced association (not the textbook left-to-right chain): the fused
    # leaf dispatch evaluates its per-leaf slot tables as perfect binary add
    # trees, and keeping every dispatch on the same association keeps the
    # three of them bitwise-equal.
    c11 = (m1 + m4) + (m7 - m5)
    c12 = m3 + m5
    c21 = m2 + m4
    c22 = (m1 - m2) + (m3 + m6)

    return jnp.block([[c11, c12], [c21, c22]])


def _rec_winograd(a, b, n_base, base_dot, acc_dtype):
    """Strassen-Winograd recursion (7 mults, 15 adds) — beyond-paper variant."""
    m, n = a.shape[-2:]
    k = b.shape[-1]
    if min(m, n, k) <= n_base:
        return base_dot(a, b)

    a11, a12, a21, a22 = _quadrants(a)
    b11, b12, b21, b22 = _quadrants(b)

    rec = functools.partial(
        _rec_winograd, n_base=n_base, base_dot=base_dot, acc_dtype=acc_dtype
    )
    # X blocks in A-space: X11=A11 X12=A21 X21=A12 X22=A22 (all transposed
    # implicitly by the TN product). Winograd schedule:
    s1 = a12 + a22          # X21 + X22
    s2 = s1 - a11           # S1 - X11
    s3 = a11 - a12          # X11 - X21
    s4 = a21 - s2           # X12 - S2
    t1 = b12 - b11          # Y12 - Y11
    t2 = b22 - t1           # Y22 - T1
    t3 = b22 - b12          # Y22 - Y12
    t4 = t2 - b21           # T2 - Y21

    p1 = rec(a11, b11)      # X11 Y11
    p2 = rec(a21, b21)      # X12 Y21
    p3 = rec(s4, b22)       # S4 Y22
    p4 = rec(a22, t4)       # X22 T4
    p5 = rec(s1, t1)        # S1 T1
    p6 = rec(s2, t2)        # S2 T2
    p7 = rec(s3, t3)        # S3 T3

    u2 = p1 + p6
    u3 = u2 + p7
    u4 = u2 + p5

    c11 = p1 + p2
    c12 = u4 + p3
    c21 = u3 - p4
    c22 = u3 + p5

    return jnp.block([[c11, c12], [c21, c22]])


# ---------------------------------------------------------------------------
# batched leaf dispatch: level-synchronous encode → one dot → decode
#
# Stack layout (block-major): (S, R, C, *batch, mb, nb) — the leaf-batch
# axis is ALWAYS axis 0, followed by the entry's leaf-block grid (R row
# blocks × C column blocks of leaf-sized (mb, nb) tiles), then any operand
# batch dims. The operands are transposed into this layout ONCE at the root
# (`_to_blocks`), so every level's quadrant split is a *leading-axis* slice
# of whole leaf blocks — large contiguous chunks, not the row-fragment
# strides that a (..., m, n) quadrant slice produces — and the final leaf
# stack is the base dot's batch layout with no further copy. One encode
# level multiplies S by 7 (child s·7+t is product t of parent s) and halves
# R, C; one decode level does the reverse; `_unblock` undoes the root
# blocking after the last decode.
#
# The same elementwise adds/subs as the unrolled recursion run on the
# stacks, in the same order, on the same values — layout is the only thing
# that differs — so the two dispatches are bitwise-equal (tested).
# ---------------------------------------------------------------------------


def _to_blocks(x, L):
    """(*batch, M, N) → block-major (2^L, 2^L, *batch, M/2^L, N/2^L)."""
    R = 1 << L
    *batch, M, N = x.shape
    nbd = len(batch)
    x = x.reshape(*batch, R, M // R, R, N // R)
    x = jnp.moveaxis(x, nbd, 0)       # row-block axis first
    x = jnp.moveaxis(x, nbd + 2, 1)   # column-block axis second
    return x


def _unblock(x):
    """(S, R, C, *batch, h, w) → (S, *batch, R·h, C·w) — the inverse root
    transpose, applied once after the last decode level."""
    S, R, C = x.shape[:3]
    batch = x.shape[3:-2]
    h, w = x.shape[-2:]
    nbd = len(batch)
    perm = (0,) + tuple(range(3, 3 + nbd)) + (1, 3 + nbd, 2, 4 + nbd)
    return x.transpose(perm).reshape(S, *batch, R * h, C * w)


def _quadrants_b(x):
    """Quadrants of a block-major stack — slices of the block-grid axes."""
    m2, n2 = x.shape[1] // 2, x.shape[2] // 2
    return (
        x[:, :m2, :n2],
        x[:, :m2, n2:],
        x[:, m2:, :n2],
        x[:, m2:, n2:],
    )


def _stack7(parts):
    """Stack 7 per-parent combinations into the leaf-batch axis: (S, ...)
    → (7S, ...) with child index ``s·7 + t``."""
    e = jnp.stack(parts, axis=1)
    return e.reshape(e.shape[0] * 7, *e.shape[2:])


def _encode_strassen(A, B):
    """One encode level: 7 operand combinations per parent, halved grids."""
    a11, a12, a21, a22 = _quadrants_b(A)
    b11, b12, b21, b22 = _quadrants_b(B)
    ea = _stack7([a11 + a22, a12 + a22, a11, a22, a11 + a21, a12 - a11, a21 - a22])
    eb = _stack7([b11 + b22, b11, b12 - b22, b21 - b11, b22, b11 + b12, b21 + b22])
    return ea, eb


def _encode_winograd(A, B):
    a11, a12, a21, a22 = _quadrants_b(A)
    b11, b12, b21, b22 = _quadrants_b(B)
    s1 = a12 + a22
    s2 = s1 - a11
    s3 = a11 - a12
    s4 = a21 - s2
    t1 = b12 - b11
    t2 = b22 - t1
    t3 = b22 - b12
    t4 = t2 - b21
    ea = _stack7([a11, a21, s4, a22, s1, s2, s3])
    eb = _stack7([b11, b21, b22, t4, t1, t2, t3])
    return ea, eb


def _cat_quads(c11, c12, c21, c22):
    top = jnp.concatenate([c11, c12], axis=2)
    bot = jnp.concatenate([c21, c22], axis=2)
    return jnp.concatenate([top, bot], axis=1)


def _decode_strassen(P):
    """One decode level: (7S, R, C, ...) products → (S, 2R, 2C, ...)."""
    P = P.reshape(P.shape[0] // 7, 7, *P.shape[1:])
    m1, m2, m3, m4, m5, m6, m7 = (P[:, t] for t in range(7))
    # same balanced association as `_rec_strassen` (bitwise equality)
    c11 = (m1 + m4) + (m7 - m5)
    c12 = m3 + m5
    c21 = m2 + m4
    c22 = (m1 - m2) + (m3 + m6)
    return _cat_quads(c11, c12, c21, c22)


def _decode_winograd(P):
    P = P.reshape(P.shape[0] // 7, 7, *P.shape[1:])
    p1, p2, p3, p4, p5, p6, p7 = (P[:, t] for t in range(7))
    u2 = p1 + p6
    u3 = u2 + p7
    u4 = u2 + p5
    c11 = p1 + p2
    c12 = u4 + p3
    c21 = u3 - p4
    c22 = u3 + p5
    return _cat_quads(c11, c12, c21, c22)


def _encode_fns(variant):
    if variant == "strassen":
        return _encode_strassen, _decode_strassen
    return _encode_winograd, _decode_winograd


def _leaf_dot(base_dot, A, B):
    """Dispatch a whole leaf stack as ONE batched TN product.

    ``(S, *batch, m, n) × (S, *batch, m, k)`` is flattened to a single
    leading dim for the base dot — the Pallas kernels take exactly one batch
    grid dimension (`repro.kernels` batched-grid contract) and the jnp base
    handles any leading dims — then unflattened.
    """
    S = A.shape[0]
    batch = A.shape[1:-2]
    out = base_dot(
        A.reshape(-1, *A.shape[-2:]), B.reshape(-1, *B.shape[-2:])
    )
    return out.reshape(S, *batch, *out.shape[-2:])


def _strassen_batched(a, b, L, base_dot, variant):
    """Iterative, level-synchronous Strassen: one root blocking transpose,
    encode L levels, one batched leaf dot, decode L levels, unblock.
    Operands arrive root-padded (2^L-divisible)."""
    if L == 0:
        return base_dot(a, b)
    enc, dec = _encode_fns(variant)
    A, B = _to_blocks(a, L)[None], _to_blocks(b, L)[None]
    for lev in range(1, L + 1):
        with obs.span(f"strassen.encode.L{lev}"):
            A, B = enc(A, B)
    # stacks are now (7^L, 1, 1, *batch, mb, nb): the block grid collapsed
    # into the leaf batch — squeeze it into the base dot's layout for free.
    with obs.span("strassen.leaf_dot", leaves=A.shape[0]):
        P = _leaf_dot(base_dot, A[:, 0, 0], B[:, 0, 0])
    P = P[:, None, None]
    for lev in range(L, 0, -1):
        with obs.span(f"strassen.decode.L{lev}"):
            P = dec(P)
    return _unblock(P)[0]


# ---------------------------------------------------------------------------
# fused leaf dispatch: per-leaf ±1 coefficient tables, zero operand stacks
#
# The batched dispatch materializes every encode level as a (7^ℓ, …) stack
# that the next level re-reads — the 2.0-words/add traffic the cost model
# charges it for. The fused dispatch never materializes an operand
# combination: each of the 7^L leaf operands is described by a *slot table*
# of 2^L (row, col, sign) entries over the root leaf-block grid
# (`_to_blocks` coordinates), built at trace time by mirroring
# `_encode_strassen` symbolically:
#
#   * two-term combination  x + σ·y  → concat slots(x) ++ σ·slots(y)
#   * single-term copy      x        → concat slots(x) ++ zero slots
#
# so slot k of a leaf operand is the coefficient of root block
# (rows[k], cols[k]) and the *position* of k encodes where that block sits
# in the unrolled recursion's add tree: evaluating the slots as a perfect
# binary tree (level-1 adds innermost, level-L outermost; zero slots drop
# out symbolically at trace time) reproduces the unrolled operand
# combinations bitwise — x−y ≡ x+(−y) and −(x+y) ≡ (−x)+(−y) are IEEE-754
# identities, and the quadrant slicing commutes with the elementwise adds.
#
# The tables are tiny (7^L · 2^L · 3 ints per operand side) and static, so
# they ride into the Pallas kernels as scalar-prefetch operands (the
# coefficient-table contract in `repro.kernels`); the XLA fallback gathers
# the blocks as plain slices of the original operand — no block-major
# transpose is ever materialized on that path. Only the classical variant
# has the one-add-per-level structure the slot encoding needs: Winograd's
# chained within-level combinations (s2 = s1 − a11, …) would square the
# table width per level, so `leaf_dispatch='fused'` requires
# `variant='strassen'`.
# ---------------------------------------------------------------------------

_FUSED_A_COMBOS = ((0, 3, 1), (1, 3, 1), (0, None, 0), (3, None, 0),
                   (0, 2, 1), (1, 0, -1), (2, 3, -1))
_FUSED_B_COMBOS = ((0, 3, 1), (0, None, 0), (1, 3, -1), (2, 0, -1),
                   (3, None, 0), (0, 1, 1), (2, 3, 1))


@functools.lru_cache(maxsize=None)
def _slot_tables(L: int):
    """Per-leaf ±1 coefficient tables of the fused dispatch.

    Returns ``((a_rows, a_cols, a_sgn), (b_rows, b_cols, b_sgn))`` — six
    ``(7**L, 2**L)`` int32 arrays. Row ``s`` describes leaf product ``s``
    (same leaf ordering as ``_stack7``: level-1 digit is the most
    significant base-7 digit); sign 0 marks a dead slot.
    """

    def build(combos):
        R = 1 << L
        r, c = np.indices((R, R))
        # (S, rows, cols, slots, {row, col, sign}) — starts as the identity
        slots = np.stack([r, c, np.ones((R, R), np.int64)], axis=-1)
        slots = slots[None, :, :, None, :]
        for _ in range(L):
            S, Rg, Cg, W, _ = slots.shape
            h, w = Rg // 2, Cg // 2
            quad = (slots[:, :h, :w], slots[:, :h, w:],
                    slots[:, h:, :w], slots[:, h:, w:])
            parts = []
            for p, q, sg in combos:
                first = quad[p]
                if q is None:
                    second = np.zeros_like(first)
                else:
                    second = quad[q].copy()
                    second[..., 2] *= sg
                parts.append(np.concatenate([first, second], axis=3))
            slots = np.stack(parts, axis=1).reshape(S * 7, h, w, 2 * W, 3)
        slots = slots[:, 0, 0]
        return (slots[..., 0].astype(np.int32),
                slots[..., 1].astype(np.int32),
                slots[..., 2].astype(np.int32))

    return build(_FUSED_A_COMBOS), build(_FUSED_B_COMBOS)


def _combine_slots(get_block, rows, cols, sgn):
    """One leaf operand from its slot table: the perfect binary add tree of
    the unrolled recursion. ``get_block(r, c)`` fetches root leaf block
    (r, c); dead (sign-0) slots drop out at trace time, so the jaxpr holds
    exactly the adds the unrolled recursion performs on this operand."""

    def ev(lo, hi):
        if hi - lo == 1:
            s = int(sgn[lo])
            if s == 0:
                return None
            blk = get_block(int(rows[lo]), int(cols[lo]))
            return -blk if s < 0 else blk
        mid = (lo + hi) // 2
        left, right = ev(lo, mid), ev(mid, hi)
        if left is None:
            return right
        if right is None:
            return left
        return left + right

    return ev(0, len(sgn))


def _block_getter(x, L):
    """Leaf-block fetcher in `_to_blocks` coordinates, as direct slices of
    the unblocked operand — the XLA fused path never materializes the
    block-major transpose."""
    mb, nb = x.shape[-2] >> L, x.shape[-1] >> L

    def get(r, c):
        return x[..., r * mb:(r + 1) * mb, c * nb:(c + 1) * nb]

    return get


def _strassen_fused(a, b, L, base_dot, fused_dot=None):
    """Fused-operand Strassen: slot-table gather+combine per leaf, one leaf
    launch, shared balanced decode. Operands arrive root-padded.

    With ``fused_dot`` (the Pallas fused kernel, `kernels.ops.gemm_tn_fused`)
    the gather+combine runs in the kernel prologue against the block-major
    layout; otherwise the combinations are built as trace-time slice
    gathers and the leaf stack feeds one batched ``base_dot``.
    """
    if L == 0:
        return base_dot(a, b)
    (ar, ac, asg), (br, bc, bsg) = _slot_tables(L)
    with obs.span("strassen.fused_leaves", leaves=7 ** L,
                  kernel=fused_dot is not None):
        if fused_dot is not None:
            # the Pallas fused launch: gather+combine happens in the kernel
            # prologue against the block-major layout (one leading group here)
            P = fused_dot(_to_blocks(a, L)[None], _to_blocks(b, L)[None],
                          _slot_tables(L))
        else:
            # XLA fallback: per-leaf combine + per-leaf dot. Stacking the
            # combined operands for one batched dot would just rebuild the
            # operand stack the fused dispatch exists to avoid (and XLA:CPU
            # runs a leading batch dim slower than the same dots unbatched);
            # only the product stack — the decode input — is materialized.
            ga, gb = _block_getter(a, L), _block_getter(b, L)
            P = jnp.stack([
                base_dot(_combine_slots(ga, ar[s], ac[s], asg[s]),
                         _combine_slots(gb, br[s], bc[s], bsg[s]))
                for s in range(7 ** L)
            ])
    P = P[:, None, None]
    for lev in range(L, 0, -1):
        with obs.span(f"strassen.decode.L{lev}"):
            P = _decode_strassen(P)
    return _unblock(P)[0]


def strassen_tn(
    a: jax.Array,
    b: jax.Array,
    *,
    alpha: float = 1.0,
    c: Optional[jax.Array] = None,
    beta: float = 1.0,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    leaf_dispatch: Optional[str] = None,
    base_dot: Optional[Callable] = None,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """``C = alpha·AᵀB (+ beta·C)`` via rectangular TN Strassen.

    Args:
      a: ``(m, n)`` left operand (used transposed, never materialized as Aᵀ).
        Leading batch dims are allowed if ``b`` carries matching ones (the
        recursion and base dot then run batched — one trace, no vmap).
      b: ``(m, k)`` right operand.
      alpha, c, beta: optional scaling/accumulation, BLAS-style.
      plan: a frozen :class:`repro.tune.Plan` carrying every tunable. With
        no plan and no pinned tunables, the dispatch is planned through
        ``repro.tune.plan`` (analytic cost model / plan cache).
      n_base: recursion cutoff — any dim ≤ n_base goes to the base matmul.
        Pinning this (or ``variant``) manually bypasses the planner.
      variant: ``'strassen'`` (paper-faithful) or ``'winograd'`` (15 adds).
      leaf_dispatch: ``'unrolled'`` (one dot per leaf, legacy),
        ``'batched'`` (level-synchronous: every leaf of the tree in one
        batched TN dot — bitwise-equal output, O(levels) jaxpr), or
        ``'fused'`` (per-leaf ±1 coefficient tables folded into the leaf
        launch — zero materialized operand-add stacks; classical variant
        only). Defaults to the plan's choice; does not bypass the planner
        when pinned alone (it never changes values).
      base_dot: base-case TN matmul ``f(a, b) -> aᵀb``. Defaults to a TN
        ``dot_general`` (MXU-native; the plan may swap in the Pallas
        ``gemm_tn`` kernel). Pass ``repro.kernels.ops.gemm_tn`` explicitly
        to force the kernel. Must accept one leading batch dim (it receives
        the whole leaf stack when ``leaf_dispatch='batched'``).
      acc_dtype: accumulation dtype for the base matmul
        (``preferred_element_type``).

    Returns:
      ``(n, k)`` product in ``acc_dtype`` (or the base_dot's output dtype).
    """
    if a.ndim < 2 or b.ndim < 2 or a.ndim != b.ndim:
        raise ValueError(f"strassen_tn expects 2-D+ operands, got {a.shape}, {b.shape}")
    if a.shape[-2] != b.shape[-2] or a.shape[:-2] != b.shape[:-2]:
        raise ValueError(
            f"contracting/batch dims mismatch: A is {a.shape}, B is {b.shape} "
            "(TN product contracts dim -2 of both; leading dims are batch)"
        )
    plan, n_base, variant, _, leaf_dispatch = resolve_tunables(
        plan, n_base, variant, None,
        op="gemm_tn", m=a.shape[-2], n=a.shape[-1], k=b.shape[-1],
        batch=math.prod(a.shape[:-2]) if a.ndim > 2 else 0,
        dtype=str(a.dtype), leaf_dispatch=leaf_dispatch,
    )
    if variant not in ("strassen", "winograd"):
        raise ValueError(f"unknown variant {variant!r}")
    if leaf_dispatch == "fused" and variant != "strassen":
        raise ValueError(
            "leaf_dispatch='fused' supports variant='strassen' only: "
            "Winograd's chained within-level combinations do not fit the "
            "per-leaf ±1 slot tables (see DESIGN.md §2)"
        )
    fused_dot = None
    if base_dot is None:
        _, base_dot = _plan_base_fns(plan, None, base_dot)
        if leaf_dispatch == "fused":
            _, fused_dot = _plan_fused_fns(plan)
    if base_dot is None:
        base_dot = functools.partial(_dot_tn, acc_dtype=acc_dtype)

    m, n = a.shape[-2:]
    k = b.shape[-1]
    L = tree_depth((m, n, k), n_base)
    obs.metrics.inc(f"dispatch.gemm_tn.{leaf_dispatch}")
    obs.metrics.inc("gemm_tn.leaves", 7 ** L)
    t0 = obs.dispatch_start(plan, a)
    with obs.span(
        "strassen_tn", m=m, n=n, k=k, levels=L, leaf_dispatch=leaf_dispatch
    ):
        if L:
            # satellite of the batched-leaf PR: ONE root pad to 2^L multiples
            # (and one crop below) replaces the per-level _pad_even of the seed.
            a = _pad_root(a, L)
            b = _pad_root(b, L)
        if leaf_dispatch == "batched":
            out = _strassen_batched(a, b, L, base_dot, variant)
        elif leaf_dispatch == "fused":
            out = _strassen_fused(a, b, L, base_dot, fused_dot)
        else:
            rec = _rec_strassen if variant == "strassen" else _rec_winograd
            out = rec(a, b, n_base=n_base, base_dot=base_dot, acc_dtype=acc_dtype)
        out = out[..., :n, :k]
        if alpha != 1.0:
            out = alpha * out
        if c is not None:
            out = out + (beta * c if beta != 1.0 else c)
        return obs.dispatch_finish(plan, t0, out)
