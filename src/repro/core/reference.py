"""Naive reference implementations and exact flop counters for the ATA paper.

These are the *oracles* against which the Strassen-based implementations
(`repro.core.strassen`, `repro.core.ata`) and the Pallas kernels
(`repro.kernels`) are validated, plus analytic flop counters that mirror the
paper's cost model (Section 3.2):

  * classical ``AᵀA`` (syrk):  ``m·n·(n+1)`` flops (n(n+1)/2 output entries,
    2m flops each) — the paper's ``n²(n+1)`` for square matrices.
  * classical ``AᵀB`` (gemm): ``2·m·n·k`` flops.
  * Strassen ``AᵀB``:          recursive counter matching our cutoff.
  * ATA ``AᵀA``:               recursive counter; paper Eq. (3):
                               ``T(n) = 4T(n/2) + 2T_S(n/2) + 3(n/2)² ≈ (2/3)T_S``.
  * Cholesky ``A = L·Lᵀ``:     ``potrf_flops`` (unblocked, symmetric-aware)
                               and ``blocked_potrf_flops`` — the exact walk
                               of ``repro.solve.cholesky`` over the packed
                               block grid (diag potrf + panel trsm + Schur
                               updates, padded tail blocks counted as the
                               graph executes them).
  * triangular solve:          ``trsm_flops`` — one triangular solve against
                               an ``n × n`` factor with ``r`` right-hand
                               sides (``n²·r`` flops; both the factorization
                               panels and the solve phase are this shape).

The counters walk the *same* recursion (same floor/ceil splits, same cutoff)
as the implementations, so they are exact for any rectangular shape, not just
powers of two.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

__all__ = [
    "syrk_ref",
    "gemm_tn_ref",
    "classical_syrk_flops",
    "classical_gemm_flops",
    "strassen_tn_flops",
    "ata_flops",
    "potrf_flops",
    "trsm_flops",
    "blocked_potrf_flops",
    "cg_iteration_flops",
]


def syrk_ref(a, alpha=1.0, c=None, beta=1.0):
    """Classical ``C = alpha·AᵀA (+ beta·C)`` oracle (full symmetric output)."""
    out = alpha * (a.T @ a)
    if c is not None:
        out = out + beta * c
    return out


def gemm_tn_ref(a, b, alpha=1.0, c=None, beta=1.0):
    """Classical ``C = alpha·AᵀB (+ beta·C)`` oracle."""
    out = alpha * (a.T @ b)
    if c is not None:
        out = out + beta * c
    return out


def classical_syrk_flops(m: int, n: int) -> int:
    """Flops of classical syrk exploiting symmetry: n(n+1)/2 dots of length m."""
    return m * n * (n + 1)


def classical_gemm_flops(m: int, n: int, k: int) -> int:
    """Flops of classical ``AᵀB`` with A:(m,n), B:(m,k)."""
    return 2 * m * n * k


@functools.lru_cache(maxsize=None)
def strassen_tn_flops(m: int, n: int, k: int, n_base: int) -> int:
    """Exact flop count of our rectangular TN Strassen (classical variant).

    Mirrors ``repro.core.strassen.strassen_tn``: cutoff when any dim <= n_base,
    odd dims padded up to even before splitting (the padded row/col costs are
    counted, exactly as the compiled graph executes them).
    """
    if min(m, n, k) <= n_base:
        return classical_gemm_flops(m, n, k)
    # pad to even (virtual padding — the implementation pads then splits)
    mp, np_, kp = m + (m & 1), n + (n & 1), k + (k & 1)
    m2, n2, k2 = mp // 2, np_ // 2, kp // 2
    mults = 7 * strassen_tn_flops(m2, n2, k2, n_base)
    # classical Strassen: 10 operand-side additions (on (m2,n2)/(m2,k2) blocks)
    # + 8 additions to combine the 7 products into 4 C blocks (on (n2,k2)).
    adds = 5 * m2 * n2 + 5 * m2 * k2 + 8 * n2 * k2
    return mults + adds


@functools.lru_cache(maxsize=None)
def strassen_tn_flops_winograd(m: int, n: int, k: int, n_base: int) -> int:
    """Flop count for the Winograd variant (7 mults, 15 adds)."""
    if min(m, n, k) <= n_base:
        return classical_gemm_flops(m, n, k)
    mp, np_, kp = m + (m & 1), n + (n & 1), k + (k & 1)
    m2, n2, k2 = mp // 2, np_ // 2, kp // 2
    mults = 7 * strassen_tn_flops_winograd(m2, n2, k2, n_base)
    # Winograd: 4 A-side pre-additions, 4 B-side pre-additions, 7 combine adds.
    adds = 4 * m2 * n2 + 4 * m2 * k2 + 7 * n2 * k2
    return mults + adds


def potrf_flops(n: int) -> int:
    """Exact flops of the unblocked right-looking Cholesky of an ``n × n``
    SPD matrix, symmetric-aware (only the lower triangle is updated).

    Per column ``j`` (0-based): one sqrt, ``n−1−j`` divisions, and the
    rank-1 Schur update of the trailing lower triangle —
    ``(n−1−j)(n−j)/2`` entries at 2 flops (multiply + subtract) each.
    Total ``n³/3 + O(n²)`` — the classical LAPACK ``potrf`` count.
    """
    total = 0
    for j in range(n):
        t = n - 1 - j
        total += 1 + t + t * (t + 1)
    return total


def trsm_flops(n: int, r: int) -> int:
    """Exact flops of one triangular solve ``X·Lᵀ = B`` (equivalently
    ``L·Y = C``) against an ``n × n`` triangular factor with ``r``
    right-hand sides: column ``j`` costs ``r·(2j + 1)`` flops (a length-j
    accumulated dot per rhs plus the diagonal division) — total ``n²·r``.
    """
    return n * n * r


def blocked_potrf_flops(n: int, bn: int) -> int:
    """Exact flops of the packed blocked Cholesky (``repro.solve.cholesky``).

    Walks the identical ``nb = ⌈n/bn⌉`` block-column loop the implementation
    traces — padded tail blocks are full ``bn`` blocks there (the pad region
    factors as identity), so they are counted at full size here, exactly as
    the compiled graph executes them. Per block column ``j``: the diagonal
    Schur updates (``j`` NT block products, counted full — the implementation
    computes full ``bn×bn`` tiles), one ``potrf(bn)``, the panel Schur
    updates (``(nb−1−j)·j`` block products) and ``nb−1−j`` panel
    ``trsm(bn, bn)``.
    """
    nb = -(-n // bn)
    gemm = classical_gemm_flops(bn, bn, bn)  # one bn×bn NT block product
    total = 0
    for j in range(nb):
        rows = nb - 1 - j
        total += j * gemm                      # diagonal Schur update
        total += potrf_flops(bn)               # diagonal factorization
        total += rows * j * gemm               # panel Schur updates
        total += rows * trsm_flops(bn, bn)     # panel solves
    return total


def cg_iteration_flops(m: int, n: int, r: int) -> int:
    """Exact flops of one CG iteration on the gram *operator*
    ``x ↦ Aᵀ(A·x) + λx`` with ``r`` simultaneous right-hand sides:
    the two planned TN products (``2mnr`` each — ``A·p`` then ``Aᵀ(Ap)``)
    plus the ridge axpy and the 5 length-``n·r`` vector updates/dots of the
    textbook iteration.
    """
    return 2 * classical_gemm_flops(m, n, r) + 12 * n * r


@functools.lru_cache(maxsize=None)
def ata_flops(m: int, n: int, n_base: int, winograd: bool = False) -> int:
    """Exact flop count of ATA (Algorithm 1) with our cutoff.

    4 recursive ATA calls + 2 Strassen TN calls + 2 block additions
    (C11 and C22 accumulations, n/2 × n/2 each) + the C21 accumulation.
    Asymptotically (2/3)·T_S(n) — verified by tests.
    """
    if min(m, n) <= n_base:
        return classical_syrk_flops(m, n)
    mp, np_ = m + (m & 1), n + (n & 1)
    m2, n2 = mp // 2, np_ // 2
    s = strassen_tn_flops_winograd if winograd else strassen_tn_flops
    rec = 4 * ata_flops(m2, n2, n_base, winograd)
    strassen = 2 * s(m2, n2, n2, n_base)
    # additions: low(C11) and low(C22) accumulations exploit symmetry
    # (n2(n2+1)/2 each) plus the full C21 accumulation (n2²).
    adds = 2 * (n2 * (n2 + 1) // 2) + n2 * n2
    return rec + strassen + adds
