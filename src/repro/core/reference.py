"""Naive reference implementations and exact flop counters for the ATA paper.

These are the *oracles* against which the Strassen-based implementations
(`repro.core.strassen`, `repro.core.ata`) and the Pallas kernels
(`repro.kernels`) are validated, plus analytic flop counters that mirror the
paper's cost model (Section 3.2):

  * classical ``AᵀA`` (syrk):  ``m·n·(n+1)`` flops (n(n+1)/2 output entries,
    2m flops each) — the paper's ``n²(n+1)`` for square matrices.
  * classical ``AᵀB`` (gemm): ``2·m·n·k`` flops.
  * Strassen ``AᵀB``:          recursive counter matching our cutoff.
  * ATA ``AᵀA``:               recursive counter; paper Eq. (3):
                               ``T(n) = 4T(n/2) + 2T_S(n/2) + 3(n/2)² ≈ (2/3)T_S``.

The counters walk the *same* recursion (same floor/ceil splits, same cutoff)
as the implementations, so they are exact for any rectangular shape, not just
powers of two.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

__all__ = [
    "syrk_ref",
    "gemm_tn_ref",
    "classical_syrk_flops",
    "classical_gemm_flops",
    "strassen_tn_flops",
    "ata_flops",
]


def syrk_ref(a, alpha=1.0, c=None, beta=1.0):
    """Classical ``C = alpha·AᵀA (+ beta·C)`` oracle (full symmetric output)."""
    out = alpha * (a.T @ a)
    if c is not None:
        out = out + beta * c
    return out


def gemm_tn_ref(a, b, alpha=1.0, c=None, beta=1.0):
    """Classical ``C = alpha·AᵀB (+ beta·C)`` oracle."""
    out = alpha * (a.T @ b)
    if c is not None:
        out = out + beta * c
    return out


def classical_syrk_flops(m: int, n: int) -> int:
    """Flops of classical syrk exploiting symmetry: n(n+1)/2 dots of length m."""
    return m * n * (n + 1)


def classical_gemm_flops(m: int, n: int, k: int) -> int:
    """Flops of classical ``AᵀB`` with A:(m,n), B:(m,k)."""
    return 2 * m * n * k


@functools.lru_cache(maxsize=None)
def strassen_tn_flops(m: int, n: int, k: int, n_base: int) -> int:
    """Exact flop count of our rectangular TN Strassen (classical variant).

    Mirrors ``repro.core.strassen.strassen_tn``: cutoff when any dim <= n_base,
    odd dims padded up to even before splitting (the padded row/col costs are
    counted, exactly as the compiled graph executes them).
    """
    if min(m, n, k) <= n_base:
        return classical_gemm_flops(m, n, k)
    # pad to even (virtual padding — the implementation pads then splits)
    mp, np_, kp = m + (m & 1), n + (n & 1), k + (k & 1)
    m2, n2, k2 = mp // 2, np_ // 2, kp // 2
    mults = 7 * strassen_tn_flops(m2, n2, k2, n_base)
    # classical Strassen: 10 operand-side additions (on (m2,n2)/(m2,k2) blocks)
    # + 8 additions to combine the 7 products into 4 C blocks (on (n2,k2)).
    adds = 5 * m2 * n2 + 5 * m2 * k2 + 8 * n2 * k2
    return mults + adds


@functools.lru_cache(maxsize=None)
def strassen_tn_flops_winograd(m: int, n: int, k: int, n_base: int) -> int:
    """Flop count for the Winograd variant (7 mults, 15 adds)."""
    if min(m, n, k) <= n_base:
        return classical_gemm_flops(m, n, k)
    mp, np_, kp = m + (m & 1), n + (n & 1), k + (k & 1)
    m2, n2, k2 = mp // 2, np_ // 2, kp // 2
    mults = 7 * strassen_tn_flops_winograd(m2, n2, k2, n_base)
    # Winograd: 4 A-side pre-additions, 4 B-side pre-additions, 7 combine adds.
    adds = 4 * m2 * n2 + 4 * m2 * k2 + 7 * n2 * k2
    return mults + adds


@functools.lru_cache(maxsize=None)
def ata_flops(m: int, n: int, n_base: int, winograd: bool = False) -> int:
    """Exact flop count of ATA (Algorithm 1) with our cutoff.

    4 recursive ATA calls + 2 Strassen TN calls + 2 block additions
    (C11 and C22 accumulations, n/2 × n/2 each) + the C21 accumulation.
    Asymptotically (2/3)·T_S(n) — verified by tests.
    """
    if min(m, n) <= n_base:
        return classical_syrk_flops(m, n)
    mp, np_ = m + (m & 1), n + (n & 1)
    m2, n2 = mp // 2, np_ // 2
    s = strassen_tn_flops_winograd if winograd else strassen_tn_flops
    rec = 4 * ata_flops(m2, n2, n_base, winograd)
    strassen = 2 * s(m2, n2, n2, n_base)
    # additions: low(C11) and low(C22) accumulations exploit symmetry
    # (n2(n2+1)/2 each) plus the full C21 accumulation (n2²).
    adds = 2 * (n2 * (n2 + 1) // 2) + n2 * n2
    return rec + strassen + adds
