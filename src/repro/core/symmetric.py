"""Packed lower-triangular block storage for symmetric matrices.

The paper's product ``C = AᵀA`` is symmetric, and the algorithm only ever
*computes* ``low(C)`` — the ``nb(nb+1)/2`` lower-triangular blocks of the
``nb × nb`` block grid. The seed implementation discarded that saving at the
storage level by mirroring into a full square at every consumer boundary.
:class:`SymmetricMatrix` keeps the packed form end-to-end:

    blocks : (..., T, bn, bn)   with T = nb·(nb+1)/2, nb = ⌈n/bn⌉

where block ``t`` is the ``(i, j)`` tile of the block grid under the
row-major lower-triangular enumeration ``t = i(i+1)/2 + j`` (j ≤ i) — the
same enumeration the Pallas ``syrk`` kernel grid uses, so kernel output in
packed mode *is* this storage with zero reshuffling.

Contract per block:

  * off-diagonal blocks (i > j) hold the full ``bn × bn`` tile of ``C``;
  * diagonal blocks (i == j) hold a full tile that is **bitwise symmetric**
    (producers symmetrize the diagonal tile once, at tile granularity —
    an O(n·bn) cost, not the O(n²) full-matrix mirror this class exists to
    eliminate).

``to_dense`` therefore reconstructs the exact dense matrix with a single
mirror at the conversion boundary; arithmetic (``add``/``scale``) and the
decayed accumulations in the Shampoo optimizer stay packed, halving the
resident memory of symmetric state (ratio ``(k+1)/2k`` for ``k = n/bn``
blocks per side).

Registered as a JAX pytree: composes with ``jit``, ``vmap`` (leading batch
dims on ``blocks``), ``lax.cond`` carries, and optimizer state trees.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SymmetricMatrix",
    "tri_block_indices",
    "diag_block_indices",
    "col_panel_indices",
    "default_block_size",
    "sym_tile",
    "write_packed_region",
]


def sym_tile(x):
    """Bitwise-symmetrize the trailing two dims: keep ``low(x)``, mirror up.

    This single expression *is* the cross-producer diagonal-tile contract —
    the jnp base case, the Pallas kernel, and ``to_dense`` all symmetrize
    through it so diagonal tiles from any producer agree bitwise.
    """
    return jnp.tril(x) + jnp.swapaxes(jnp.tril(x, -1), -1, -2)


def default_block_size(n: int, bn: int) -> int:
    """Clamp a requested packed block size to the logical matrix size.

    Two adjustments to the request: (1) the block never exceeds the next
    multiple of 8 ≥ n, so a tiny matrix is not padded up to one huge block;
    (2) the size is *balanced* over the implied block count
    (``ceil8(⌈n/nb⌉)`` for ``nb = ⌈n/bn⌉``), so e.g. n=200 with a 128
    request stores 2 balanced 104-blocks per side instead of padding the
    matrix out to 256. Every producer of packed storage must use this same
    clamp so that packed operands with equal ``(n, bn)`` requests are
    structurally identical and can be added without re-blocking.
    """
    bn = min(bn, max(8, -(-n // 8) * 8))
    nb = -(-n // bn)
    return max(8, -(-(-(-n // nb)) // 8) * 8)


def write_packed_region(buf, arr, r0, c0, bn):
    """Scatter a dense region at global offset ``(r0, c0)`` into packed
    ``(..., T, bn, bn)`` block storage, splitting it along the bn grid.

    Pieces falling in strictly-upper blocks (bi < bj) are skipped — they can
    only come from the intra-tile upper halves of *symmetric* regions that
    straddle a block boundary (diagonal base tiles of the ATA recursion,
    diagonal stripe tiles of the distributed schedule), whose content the
    mirror in ``to_dense`` reconstructs. All offsets are static: each piece
    is one static-slice ``dynamic_update_slice``.
    """
    h, w = arr.shape[-2:]
    r = r0
    while r < r0 + h:
        bi = r // bn
        r_end = min((bi + 1) * bn, r0 + h)
        c = c0
        while c < c0 + w:
            bj = c // bn
            c_end = min((bj + 1) * bn, c0 + w)
            if bi >= bj:
                t = bi * (bi + 1) // 2 + bj
                buf = buf.at[
                    ..., t, r - bi * bn : r_end - bi * bn, c - bj * bn : c_end - bj * bn
                ].set(arr[..., r - r0 : r_end - r0, c - c0 : c_end - c0])
            c = c_end
        r = r_end
    return buf


def diag_block_indices(nb: int):
    """Packed indices of the ``nb`` diagonal blocks: ``t = i(i+1)/2 + i``."""
    return np.array([i * (i + 1) // 2 + i for i in range(nb)], np.int32)


def col_panel_indices(nb: int, j: int):
    """Packed indices of block column ``j`` *below* the diagonal —
    ``t = i(i+1)/2 + j`` for ``i = j+1 … nb−1``, the panel the blocked
    Cholesky walk (`repro.solve.cholesky`) factors against diagonal ``j``.
    """
    return np.array(
        [i * (i + 1) // 2 + j for i in range(j + 1, nb)], np.int32
    )


def tri_block_indices(nb: int):
    """``tril_indices``-style enumeration of the packed block grid.

    Returns int32 arrays ``(i, j)`` of length ``T = nb(nb+1)/2`` with
    ``t = i(i+1)/2 + j`` and ``j ≤ i`` — row-major over the lower triangle,
    matching both ``np.tril_indices`` and the syrk kernel's ``_tri_coords``
    inverse.
    """
    i, j = np.tril_indices(nb)
    return i.astype(np.int32), j.astype(np.int32)


@jax.tree_util.register_pytree_node_class
class SymmetricMatrix:
    """Symmetric ``n × n`` matrix stored as packed lower-triangular blocks."""

    __slots__ = ("blocks", "n", "bn")

    def __init__(self, blocks, n: int, bn: int):
        # NOTE: deliberately no shape validation — tree transforms (vmap,
        # eval_shape, tree_map with sentinels) rebuild instances with
        # placeholder leaves.
        self.blocks = blocks
        self.n = int(n)
        self.bn = int(bn)

    # -- static geometry ----------------------------------------------------

    @property
    def nb(self) -> int:
        return -(-self.n // self.bn)

    @property
    def t_total(self) -> int:
        return self.nb * (self.nb + 1) // 2

    @property
    def shape(self):
        """Logical dense shape (leading batch dims + (n, n))."""
        return tuple(self.blocks.shape[:-3]) + (self.n, self.n)

    @property
    def dtype(self):
        return self.blocks.dtype

    @property
    def nbytes(self) -> int:
        """Resident bytes of the packed storage (the memory claim)."""
        return int(self.blocks.size) * self.blocks.dtype.itemsize

    @staticmethod
    def dense_nbytes(n: int, batch=(), itemsize: int = 4) -> int:
        """Bytes the equivalent dense storage would occupy (for reporting)."""
        return int(math.prod(batch)) * n * n * itemsize

    # -- pytree protocol ----------------------------------------------------

    def tree_flatten(self):
        return (self.blocks,), (self.n, self.bn)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    # -- constructors -------------------------------------------------------

    @classmethod
    def zeros(cls, n: int, bn: int, batch=(), dtype=jnp.float32):
        bn = default_block_size(n, bn)
        nb = -(-n // bn)
        t = nb * (nb + 1) // 2
        return cls(jnp.zeros((*batch, t, bn, bn), dtype), n, bn)

    @classmethod
    def from_dense_lower(cls, lower, bn: int):
        """Pack a dense array whose meaningful content is the lower triangle.

        ``lower`` is ``(..., n, n)`` where strictly-upper *block* positions
        are ignored (typically zero) and diagonal tiles may carry their full
        symmetric content. The pack is a pure gather — no transpose of the
        square is ever taken.
        """
        *batch, n, n2 = lower.shape
        if n != n2:
            raise ValueError(f"expected square input, got {lower.shape}")
        bn = default_block_size(n, bn)
        nb = -(-n // bn)
        pad = nb * bn - n
        if pad:
            cfg = [(0, 0)] * len(batch) + [(0, pad), (0, pad)]
            lower = jnp.pad(lower, cfg)
        i_idx, j_idx = tri_block_indices(nb)

        def pack2d(x):
            x4 = x.reshape(nb, bn, nb, bn)
            # advanced indices on axes 0 and 2 (separated by a slice) put the
            # broadcast dim first: (T, bn, bn) — a gather, not a transpose.
            return x4[i_idx, :, j_idx, :]

        fn = pack2d
        for _ in batch:
            fn = jax.vmap(fn)
        return cls(fn(lower), n, bn)

    @classmethod
    def from_tile_stack(cls, tiles, n: int, *, nb: int, packed_block=None,
                        presymmetrized: bool = False):
        """Assemble from a tri-enumerated ``(..., S, w, w)`` lower-triangle
        tile stack — the SPMD schedules' psum'd payload (paper Prop. 4.2).

        ``presymmetrized=True`` asserts the producer already applied
        :func:`sym_tile` to every diagonal tile (e.g. the BFS/DFS schedule
        symmetrizes locally after its reduce-scatter, where slot→tile
        membership is static), so the aligned path can skip
        ``_symmetrize_diag`` — on a sharded stack that gather is a whole
        cross-device collective. Only the aligned path honours the flag:
        the repack path's packed-grid diagonal blocks mix pieces of several
        stripe tiles and must be re-symmetrized regardless (``sym_tile`` is
        idempotent, so presymmetrized inputs stay bitwise-correct there).

        ``tiles`` covers an ``nb``-stripe grid of width ``w =
        tiles.shape[-1]`` under the same row-major enumeration this storage
        uses (``t = i(i+1)/2 + j``, ``j ≤ i``); ``S ≥ nb(nb+1)/2`` — trailing
        entries (SPMD dummy slots of ``ata_tile_parallel``) are ignored, as
        are stripes that lie entirely in the padding beyond ``n``.

        Two paths:

        * **aligned** (``w`` equals the packed grid's block size): the
          enumeration is prefix-closed, so the packed blocks *are* the first
          ``T`` stack entries — a pure slice, no dense buffer, no copy of
          the off-diagonal payload;
        * **misaligned**: each stripe tile is re-tiled onto the packed grid
          with static-offset writes (:func:`write_packed_region`) — still no
          dense ``(n, n)`` intermediate anywhere.

        Diagonal blocks are symmetrized to the storage contract either way
        (diagonal *stripe* tiles arrive as raw ``AᵢᵀAᵢ`` dots, which are only
        approximately symmetric under XLA accumulation order).
        """
        w = tiles.shape[-1]
        t_src = nb * (nb + 1) // 2
        if tiles.shape[-2] != w:
            raise ValueError(f"expected square tiles, got {tiles.shape[-2:]}")
        if tiles.shape[-3] < t_src:
            raise ValueError(
                f"stack holds {tiles.shape[-3]} tiles < T={t_src} for nb={nb}"
            )
        if nb * w < n:
            raise ValueError(f"nb={nb} stripes of width {w} do not cover n={n}")
        if packed_block is None:
            from repro.tune.defaults import DEFAULT_PACKED_BLOCK

            packed_block = DEFAULT_PACKED_BLOCK
        bn = default_block_size(n, packed_block)
        nb_pack = -(-n // bn)
        t_pack = nb_pack * (nb_pack + 1) // 2
        if w == bn:
            # prefix-closed enumeration: stack[:T_pack] IS the packed storage
            packed = cls(tiles[..., :t_pack, :, :], n, bn)
            return packed if presymmetrized else packed._symmetrize_diag()
        # repack: re-tile every stripe tile onto the bn grid
        n_pad = nb_pack * bn
        batch = tiles.shape[:-3]
        buf = jnp.zeros((*batch, t_pack, bn, bn), tiles.dtype)
        i_idx, j_idx = tri_block_indices(nb)
        for t in range(t_src):
            i, j = int(i_idx[t]), int(j_idx[t])
            r0, c0 = i * w, j * w
            if r0 >= n_pad or c0 >= n_pad:
                continue  # stripe entirely in the padding beyond n
            tile = tiles[..., t, :, :]
            if i == j:
                # symmetrize before the scatter so pieces skipped in
                # strictly-upper packed blocks are mirror-reconstructible
                tile = sym_tile(tile)
            h, wd = min(w, n_pad - r0), min(w, n_pad - c0)
            buf = write_packed_region(buf, tile[..., :h, :wd], r0, c0, bn)
        return cls(buf, n, bn)._symmetrize_diag()

    @classmethod
    def from_dense(cls, dense, bn: int):
        """Pack a full symmetric dense matrix (upper triangle discarded)."""
        return cls.from_dense_lower(jnp.tril(dense), bn)._symmetrize_diag()

    def _symmetrize_diag(self):
        """Restore the full-symmetric-diagonal-tile contract after a tril."""
        nb, bn = self.nb, self.bn
        diag_t = np.array([i * (i + 1) // 2 + i for i in range(nb)], np.int32)
        diag = self.blocks[..., diag_t, :, :]
        return SymmetricMatrix(
            self.blocks.at[..., diag_t, :, :].set(sym_tile(diag)), self.n, self.bn
        )

    # -- conversions --------------------------------------------------------

    def to_dense(self):
        """Dense ``(..., n, n)`` reconstruction, bitwise symmetric.

        The single mirror of the whole lower triangle happens *here*, at the
        conversion boundary — never inside producers.
        """
        nb, bn, n = self.nb, self.bn, self.n
        i_idx, j_idx = tri_block_indices(nb)

        def unpack2d(blocks):
            z = jnp.zeros((nb, bn, nb, bn), blocks.dtype)
            z = z.at[i_idx, :, j_idx, :].set(blocks)
            return sym_tile(z.reshape(nb * bn, nb * bn)[:n, :n])

        fn = unpack2d
        for _ in self.blocks.shape[:-3]:
            fn = jax.vmap(fn)
        return fn(self.blocks)

    # -- block views (the packed factor walk of repro.solve reads these) ----

    @staticmethod
    def block_index(i: int, j: int) -> int:
        """Packed index of block ``(i, j)`` — row-major lower enumeration."""
        if j > i:
            raise ValueError(f"block ({i}, {j}) lies in the upper triangle")
        return i * (i + 1) // 2 + j

    def block(self, i: int, j: int):
        """The ``(..., bn, bn)`` tile of block-grid position ``(i, j)``,
        ``j ≤ i`` — a pure static slice of the packed storage."""
        return self.blocks[..., self.block_index(i, j), :, :]

    def diag_blocks(self):
        """All diagonal tiles as one ``(..., nb, bn, bn)`` stack."""
        return self.blocks[..., diag_block_indices(self.nb), :, :]

    def col_panel(self, j: int):
        """Block column ``j`` below the diagonal: ``(..., nb−1−j, bn, bn)``
        (empty stack for the last column). This is the panel the blocked
        Cholesky factors with one batched trsm launch."""
        idx = col_panel_indices(self.nb, j)
        return self.blocks[..., idx, :, :]

    def add_scaled_identity(self, s) -> "SymmetricMatrix":
        """``self + s·I`` on the *logical* diagonal (pad entries beyond
        ``n`` untouched), packed-native: only the ``nb`` diagonal tiles are
        updated, via a static numpy mask — no dense ``(n, n)`` anywhere."""
        nb, bn, n = self.nb, self.bn, self.n
        diag_t = diag_block_indices(nb)
        mask = np.zeros((nb, bn, bn), np.float32)
        for i in range(nb):
            d = min(bn, n - i * bn)
            mask[i, range(d), range(d)] = 1.0
        tiles = self.diag_blocks() + s * jnp.asarray(mask, self.blocks.dtype)
        return SymmetricMatrix(
            self.blocks.at[..., diag_t, :, :].set(tiles), self.n, self.bn
        )

    def diagonal(self):
        """The main diagonal of the logical matrix, ``(..., n)``."""
        nb, bn, n = self.nb, self.bn, self.n
        diag_t = diag_block_indices(nb)
        tiles = self.blocks[..., diag_t, :, :]          # (..., nb, bn, bn)
        d = jnp.diagonal(tiles, axis1=-2, axis2=-1)      # (..., nb, bn)
        return d.reshape(*self.blocks.shape[:-3], nb * bn)[..., :n]

    def trace(self):
        return jnp.sum(self.diagonal(), axis=-1)

    # -- arithmetic (packed-linear ops stay packed) -------------------------

    def _check_compatible(self, other: "SymmetricMatrix"):
        if (self.n, self.bn) != (other.n, other.bn):
            raise ValueError(
                f"incompatible packed layouts: (n={self.n}, bn={self.bn}) vs "
                f"(n={other.n}, bn={other.bn})"
            )

    def add(self, other: "SymmetricMatrix") -> "SymmetricMatrix":
        self._check_compatible(other)
        return SymmetricMatrix(self.blocks + other.blocks, self.n, self.bn)

    def scale(self, s) -> "SymmetricMatrix":
        return SymmetricMatrix(self.blocks * s, self.n, self.bn)

    def astype(self, dtype) -> "SymmetricMatrix":
        return SymmetricMatrix(self.blocks.astype(dtype), self.n, self.bn)

    def __add__(self, other):
        if isinstance(other, SymmetricMatrix):
            return self.add(other)
        return NotImplemented

    def __mul__(self, s):
        if isinstance(s, SymmetricMatrix):
            return NotImplemented
        return self.scale(s)

    __rmul__ = __mul__

    def __repr__(self):
        return (
            f"SymmetricMatrix(n={self.n}, bn={self.bn}, "
            f"blocks={getattr(self.blocks, 'shape', None)}, "
            f"dtype={getattr(self.blocks, 'dtype', None)})"
        )
