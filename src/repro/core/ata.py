"""ATA — cache-oblivious Strassen-based ``C = alpha·AᵀA`` (paper Algorithm 1).

The recursion (Eq. 1-2 of the paper), for ``A ∈ R^{m×n}`` split into 2×2
quadrants with floor/ceil halving:

    C11 = A11ᵀA11 + A21ᵀA21      (two recursive ATA calls)
    C22 = A12ᵀA12 + A22ᵀA22      (two recursive ATA calls)
    C21 = A12ᵀA11 + A22ᵀA21      (two rectangular Strassen TN calls)
    C12 = C21ᵀ                   (never computed — symmetry)

Cost: ``T(n) = 4T(n/2) + 2T_S(n/2) + 3(n/2)² ≈ (2/3)·T_S(n)`` — two thirds of
Strassen applied naively, i.e. (14/3)·n^{log₂7} (paper Section 3.2).

TPU adaptation notes (see DESIGN.md §2):

* the recursion unrolls at trace time (static shapes) — cache-obliviousness
  survives as nested recursive blocking that XLA/Mosaic tiles onto
  HBM→VMEM→VREG;
* the symmetric saving at the *base-case* level lives in the Pallas ``syrk``
  kernel, which computes only lower-triangular output blocks and mirrors;
* ``C12 = C21ᵀ`` is materialized once per level by ``jnp.block`` — the flop
  saving is kept, and the transpose is a copy XLA folds into the layout of the
  consuming op (the paper likewise materializes the full square C at the
  root).

``ata`` is a pure JAX function: it composes with ``jit``, ``vmap`` (used by
the blocked-Shampoo optimizer over parameter blocks), ``grad``, and
``shard_map`` (used by ``repro.core.distributed``).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.strassen import DEFAULT_N_BASE, _dot_tn, _rec_strassen, _rec_winograd

__all__ = ["ata", "DEFAULT_N_BASE"]


def _syrk_base(a, acc_dtype):
    """Default base case: ``AᵀA`` via one TN dot, lower triangle mirrored.

    The Pallas kernel (``repro.kernels.ops.syrk``) replaces this on TPU and
    computes only the lower-triangular blocks; at the pure-jnp level the MXU
    executes the full tile matmul, and we mirror ``low(C)`` so the public
    invariant *C is exactly symmetric* holds bitwise (XLA's accumulation
    order can differ per output position, so the raw matmul is only
    approximately symmetric).
    """
    c = _dot_tn(a, a, acc_dtype)
    low = jnp.tril(c)
    return low + jnp.tril(c, -1).T


def _rec_ata(a, n_base, base_syrk, strassen_rec, base_dot, acc_dtype):
    m, n = a.shape
    if min(m, n) <= n_base:
        return base_syrk(a)

    # floor/ceil split, paper Eq. (1): m1 = ⌊m/2⌋, n1 = ⌊n/2⌋.
    m1, n1 = m // 2, n // 2
    a11 = a[:m1, :n1]
    a12 = a[:m1, n1:]
    a21 = a[m1:, :n1]
    a22 = a[m1:, n1:]

    rec = functools.partial(
        _rec_ata,
        n_base=n_base,
        base_syrk=base_syrk,
        strassen_rec=strassen_rec,
        base_dot=base_dot,
        acc_dtype=acc_dtype,
    )
    st = functools.partial(
        strassen_rec, n_base=n_base, base_dot=base_dot, acc_dtype=acc_dtype
    )

    c11 = rec(a11) + rec(a21)          # (n1, n1)
    c22 = rec(a12) + rec(a22)          # (n2, n2)
    c21 = st(a12, a11) + st(a22, a21)  # (n2, n1)

    return jnp.block([[c11, c21.T], [c21, c22]])


def ata(
    a: jax.Array,
    *,
    alpha: float = 1.0,
    c: Optional[jax.Array] = None,
    beta: float = 1.0,
    n_base: int = DEFAULT_N_BASE,
    variant: str = "strassen",
    base_syrk: Optional[Callable] = None,
    base_dot: Optional[Callable] = None,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """``C = alpha·AᵀA (+ beta·C)`` via the paper's ATA algorithm.

    Args:
      a: ``(m, n)`` input, any rectangular shape (odd sizes handled by the
        floor/ceil split here and virtual padding inside Strassen).
      alpha, c, beta: BLAS-style scaling/accumulation.
      n_base: recursion cutoff; tiles with any dim ≤ n_base go to the base
        syrk/gemm. The TPU analogue of the paper's "fits in cache".
      variant: Strassen variant for the C21 off-diagonal products —
        ``'strassen'`` (paper-faithful) or ``'winograd'`` (beyond-paper,
        15 adds).
      base_syrk: base-case ``f(a) -> aᵀa`` (full symmetric tile). Defaults to
        a TN dot_general; pass ``repro.kernels.ops.syrk`` for the Pallas
        kernel.
      base_dot: base-case ``f(a, b) -> aᵀb`` for the Strassen leaves.
      acc_dtype: accumulation dtype.

    Returns:
      ``(n, n)`` full symmetric product.
    """
    if a.ndim != 2:
        raise ValueError(f"ata expects a 2-D operand, got shape {a.shape}")
    if variant not in ("strassen", "winograd"):
        raise ValueError(f"unknown variant {variant!r}")
    if base_syrk is None:
        base_syrk = functools.partial(_syrk_base, acc_dtype=acc_dtype)
    if base_dot is None:
        base_dot = functools.partial(_dot_tn, acc_dtype=acc_dtype)

    strassen_rec = _rec_strassen if variant == "strassen" else _rec_winograd
    out = _rec_ata(
        a,
        n_base=n_base,
        base_syrk=base_syrk,
        strassen_rec=strassen_rec,
        base_dot=base_dot,
        acc_dtype=acc_dtype,
    )
    if alpha != 1.0:
        out = alpha * out
    if c is not None:
        out = out + (beta * c if beta != 1.0 else c)
    return out
