"""ATA — cache-oblivious Strassen-based ``C = alpha·AᵀA`` (paper Algorithm 1).

The recursion (Eq. 1-2 of the paper), for ``A ∈ R^{m×n}`` split into 2×2
quadrants:

    C11 = A11ᵀA11 + A21ᵀA21      (two recursive ATA calls)
    C22 = A12ᵀA12 + A22ᵀA22      (two recursive ATA calls)
    C21 = A12ᵀA11 + A22ᵀA21      (two rectangular Strassen TN calls)
    C12 = C21ᵀ                   (never computed — symmetry)

Cost: ``T(n) = 4T(n/2) + 2T_S(n/2) + 3(n/2)² ≈ (2/3)·T_S(n)`` — two thirds of
Strassen applied naively, i.e. (14/3)·n^{log₂7} (paper Section 3.2).

TPU adaptation notes (see DESIGN.md §2):

* the recursion unrolls at trace time (static shapes) — cache-obliviousness
  survives as nested recursive blocking that XLA/Mosaic tiles onto
  HBM→VMEM→VREG;
* odd shapes are handled by **one root pad** (to a shape divisible by
  ``2^L`` for the recursion depth ``L``) and a crop-aware root assembly —
  no per-level padding, every interior split is an exact half;
* the symmetric saving at the *base-case* level lives in the Pallas ``syrk``
  kernel, which computes only lower-triangular output blocks;
* **the symmetric saving at the storage level lives here**: the recursion is
  organized as a *slab sum* — each node computes ``Σ_k A_kᵀA_k`` over a list
  of row-slabs for one contiguous column range — and returns a
  ``(c11, c21, c22)`` triangular node structure instead of a dense square.
  No ``jnp.block`` and no ``C21ᵀ`` is materialized at any intermediate level;
  the lower triangle is assembled exactly once at the root (each block
  written once via static-offset updates), and the mirror to a full square
  happens once for dense output — or never, when the caller asks for packed
  output via ``ata(a, out="packed")``, which returns a
  :class:`repro.core.symmetric.SymmetricMatrix`;
* **leaf dispatch** is pluggable (``Plan.leaf_dispatch``): the legacy
  ``'unrolled'`` recursion emits ``4^L`` base syrks and ``O(7^L)`` Strassen
  leaf dots as separate ops; ``'batched'`` runs the same tree
  level-synchronously — all diagonal leaves as ONE batched syrk and every
  Strassen leaf of every off-diagonal block as ONE batched TN dot — and
  decodes back into the identical ``_TriNode`` assembly, bitwise-equal to
  the unrolled form (tested; see DESIGN.md §2); ``'fused'`` keeps the
  level-synchronous tree but never materializes an operand combination:
  each leaf operand is a per-leaf ±1 slot table over the root leaf-block
  grid, evaluated in the Pallas kernel prologues (coefficient tables as
  scalar-prefetch operands) or as trace-time slice gathers on the XLA
  path — same decode, same ``_TriNode`` assembly, bitwise-equal (tested).

``ata`` is a pure JAX function: it composes with ``jit``, ``vmap``, ``grad``,
and ``shard_map`` (used by ``repro.core.distributed``). ``ata_batched`` runs
the same recursion with an explicit leading batch dimension — one trace, one
kernel launch per base tile over the whole batch — which is what the
blocked-Shampoo optimizer uses for its per-block gram statistics.

Dispatch tunables (cutoff, variant, kernel blocks, packed block, leaf
dispatch) resolve through the ``repro.tune`` planning layer: pass a frozen
``plan=``, pin values manually, or pass nothing and let the front door
decide (see DESIGN.md §7).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.strassen import (
    DEFAULT_N_BASE,
    _combine_slots,
    _dot_tn,
    _encode_fns,
    _leaf_dot,
    _pad_root,
    _plan_base_fns,
    _plan_fused_fns,
    _rec_strassen,
    _rec_winograd,
    _slot_tables,
    _to_blocks,
    _unblock,
    resolve_tunables,
    tree_depth,
)
from repro.core.symmetric import (
    SymmetricMatrix,
    default_block_size,
    sym_tile,
    write_packed_region,
)
from repro.tune.defaults import DEFAULT_PACKED_BLOCK  # re-export

__all__ = ["ata", "ata_batched", "DEFAULT_N_BASE", "DEFAULT_PACKED_BLOCK"]


def _syrk_base(a, acc_dtype):
    """Default base case: ``AᵀA`` via one TN dot, lower triangle mirrored.

    The Pallas kernel (``repro.kernels.ops.syrk``) replaces this on TPU and
    computes only the lower-triangular blocks; at the pure-jnp level the MXU
    executes the full tile matmul, and we mirror ``low(C)`` so the tile-level
    invariant *the base tile is exactly symmetric* holds bitwise (XLA's
    accumulation order can differ per output position, so the raw matmul is
    only approximately symmetric). This transpose is a ≤ n_base tile op — the
    full-square mirror of the seed implementation is gone.
    """
    return sym_tile(_dot_tn(a, a, acc_dtype))


class _TriNode(NamedTuple):
    """One recursion level of the symmetric product: C = [[c11, ·], [c21, c22]].

    ``c11``/``c22`` are ``_TriNode`` or dense symmetric base tiles; ``c21`` is
    the dense rectangular off-diagonal block. The never-computed upper block
    has no representation — that is the point.
    """

    c11: object
    c21: jax.Array
    c22: object


def _rec_ata(slabs, n_base, base_syrk, strassen_rec, base_dot, acc_dtype):
    """Compute ``Σ_k slab_kᵀ·slab_k`` for one column range, as a _TriNode tree.

    ``slabs`` is a list of ``(..., m_k, n)`` row-slabs sharing the column
    range (the paper's ``C11 = A11ᵀA11 + A21ᵀA21`` generalized: every level
    of row-halving doubles the slab list instead of materializing partial
    dense sums). Keeping the sum *inside* the recursion means both addends of
    every accumulation share one node structure by construction — the result
    tree is a function of the column range only. Inputs arrive root-padded,
    so every split below is an exact half.
    """
    n = slabs[0].shape[-1]
    m_max = max(s.shape[-2] for s in slabs)
    if n <= n_base or m_max <= n_base:
        with obs.span("ata.rec.base", n=n, slabs=len(slabs)):
            out = base_syrk(slabs[0])
            for s in slabs[1:]:
                out = out + base_syrk(s)
            return out

    halves = []
    for s in slabs:
        m1 = s.shape[-2] // 2
        if m1:
            halves.append(s[..., :m1, :])
        halves.append(s[..., m1:, :])
    n1 = n // 2
    left = [h[..., :n1] for h in halves]
    right = [h[..., n1:] for h in halves]

    rec = functools.partial(
        _rec_ata,
        n_base=n_base,
        base_syrk=base_syrk,
        strassen_rec=strassen_rec,
        base_dot=base_dot,
        acc_dtype=acc_dtype,
    )
    st = functools.partial(
        strassen_rec, n_base=n_base, base_dot=base_dot, acc_dtype=acc_dtype
    )

    with obs.span(f"ata.rec.n{n}", slabs=len(slabs)):
        c11 = rec(left)
        c22 = rec(right)
        c21 = st(right[0], left[0])
        for r, l in zip(right[1:], left[1:]):
            c21 = c21 + st(r, l)
        return _TriNode(c11, c21, c22)


# ---------------------------------------------------------------------------
# level-synchronous batched-leaf formulation of the same tree
# ---------------------------------------------------------------------------


def _accum_axis1(x):
    """Left-to-right accumulation over axis 1 — the exact add order of the
    unrolled slab loop (``out = t0; out = out + t1; …``), on a stack."""
    acc = x[:, 0]
    for r in range(1, x.shape[1]):
        acc = acc + x[:, r]
    return acc


def _combine_level(a, L, lev, mL, nL):
    """Fused leaf operands of ATA level ``lev`` as trace-time slice gathers.

    One (A, B) operand pair per (slab parent ``p``, Strassen leaf ``t``),
    ordered parent-major exactly like the encode stacks (``p·7^{L-ℓ} + t``).
    Every slot block is a direct slice of the root-padded input — no
    block-major transpose and no operand stack is ever materialized.
    """
    R, Rl, H = 1 << L, 1 << lev, 1 << (lev - 1)
    q = R // Rl
    (ar, ac, asg), (br, bc, bsg) = _slot_tables(L - lev)
    T = 7 ** (L - lev)

    def getter(p, side):
        h, rb = divmod(p, Rl)

        def get(r, c):
            i = rb * q + r
            j = (2 * h + side) * q + c
            return a[..., i * mL:(i + 1) * mL, j * nL:(j + 1) * nL]

        return get

    la, lb = [], []
    for p in range(H * Rl):
        ga, gb = getter(p, 1), getter(p, 0)   # A = right slabs, B = left
        for t in range(T):
            la.append(_combine_slots(ga, ar[t], ac[t], asg[t]))
            lb.append(_combine_slots(gb, br[t], bc[t], bsg[t]))
    return la, lb


def _ata_level_sync(a, L, *, variant, base_syrk, base_dot,
                    fused=False, fused_syrk=None, fused_dot=None):
    """The whole ATA tree with batched leaves: encode every off-diagonal
    Strassen product into per-level stacks, run ALL ``Σ_ℓ 2^{2ℓ-1}·7^{L-ℓ}``
    Strassen leaves as one batched TN dot and ALL ``4^L`` diagonal leaves as
    one batched syrk, then decode back into the identical ``_TriNode`` tree.

    ``a`` arrives root-padded: ``(*batch, M, N)`` with both dims divisible
    by ``2^L``; it is transposed ONCE into the leaf-block-major layout of
    ``core.strassen`` (``(R, C, *batch, mL, nL)``), from which every group's
    operands are leading-axis block slices. An ATA-level-ℓ group is ordered
    ``s = i·2^ℓ + r`` (``i`` = parent column range, ``r`` = row slab), so
    the per-``i`` slab accumulation of the unrolled recursion is a
    left-to-right fold over a reshaped axis.

    ``fused=True`` replaces the encode stacks with per-leaf ±1 slot tables
    (`core.strassen._slot_tables`): either evaluated in the Pallas fused
    kernels' prologues (``fused_dot``/``fused_syrk``, one launch per level)
    or as trace-time slice gathers on the XLA path — zero materialized
    operand-add stacks either way. The decode side and the ``_TriNode``
    assembly are shared verbatim with the batched path, so all three leaf
    dispatches stay bitwise-equal (classical variant; tested).
    """
    if L == 0:
        return base_syrk(a)
    batch = a.shape[:-2]
    _, dec = _encode_fns(variant)
    R = 1 << L
    ab = _to_blocks(a, L)           # (R, R, *batch, mL, nL)
    mL, nL = ab.shape[-2:]

    # encode: one Strassen operand stack per ATA level ℓ (the C21 blocks of
    # the 2^{ℓ-1} nodes split at level ℓ-1, × 2^ℓ row slabs each), pushed
    # down the remaining L-ℓ Strassen levels, then concatenated into ONE
    # leaf stack across all levels (every leaf has the same (mL, nL) shape).
    parts_a, parts_b, sizes = [], [], []
    P_levels = [] if fused else None
    for lev in range(1, L + 1):
      with obs.span(f"ata.encode.L{lev}", fused=fused):
        Rl, H = 1 << lev, 1 << (lev - 1)
        q = R // Rl
        if fused and fused_dot is None:
            # XLA fallback: per-leaf combine + per-leaf dot (see
            # `core.strassen._strassen_fused`) — only the product stack,
            # the decode input, is materialized.
            la, lb = _combine_level(a, L, lev, mL, nL)
            P_levels.append(jnp.stack(
                [base_dot(x, y) for x, y in zip(la, lb)]
            ))
            sizes.append(len(la))
            continue
        # block rows grouped into the 2^ℓ slabs, block columns into
        # (parent i, left/right, q): operand (i, r) is a pure block slice
        g = ab.reshape(Rl, q, H, 2, q, *batch, mL, nL)
        right = jnp.moveaxis(g[:, :, :, 1], 2, 0)   # (H, Rl, q, q, ...)
        left = jnp.moveaxis(g[:, :, :, 0], 2, 0)
        A = right.reshape(H * Rl, q, q, *batch, mL, nL)
        B = left.reshape(H * Rl, q, q, *batch, mL, nL)
        if fused:
            # one fused Pallas launch per level: the ±1 combinations run in
            # the kernel prologue against these block grids
            with obs.span(f"ata.fused_dot.L{lev}", leaves=A.shape[0] * 7 ** (L - lev)):
                P_levels.append(fused_dot(A, B, _slot_tables(L - lev)))
            sizes.append(A.shape[0] * 7 ** (L - lev))
            continue
        enc, _ = _encode_fns(variant)
        for _ in range(L - lev):
            A, B = enc(A, B)
        parts_a.append(A[:, 0, 0])  # grids collapsed to (1, 1): squeeze
        parts_b.append(B[:, 0, 0])
        sizes.append(A.shape[0])
    if P_levels is None:
        with obs.span("ata.leaf_dot", leaves=sum(sizes)):
            P = _leaf_dot(
                base_dot,
                jnp.concatenate(parts_a, axis=0),
                jnp.concatenate(parts_b, axis=0),
            )
        P_levels = []
        off = 0
        for size in sizes:
            P_levels.append(P[off : off + size])
            off += size

    # all diagonal leaves as one batched syrk, ordered (column block i, slab r)
    with obs.span("ata.syrk_batch", leaves=R * R, fused=fused):
        if fused and fused_syrk is not None:
            # gather prologue: the kernel pulls each slab straight out of the
            # block-major layout by its (row, col) index table — no copy of D
            import numpy as np

            s = np.arange(R * R, dtype=np.int32)
            Dp = fused_syrk(ab, s % R, s // R)
        else:
            D = jnp.swapaxes(ab, 0, 1).reshape(R * R, *batch, mL, nL)
            Dp = base_syrk(D.reshape(-1, mL, nL))
    Dp = Dp.reshape(R, R, *batch, *Dp.shape[-2:])
    diag = _accum_axis1(Dp)  # (2^L, *batch, nL, nL)

    # decode: per level, pop its slice of the leaf stack, fold the Strassen
    # levels back up, fold the slab sum in block form, then unblock
    c21 = {}
    for lev, p in zip(range(1, L + 1), P_levels):
      with obs.span(f"ata.decode.L{lev}"):
        p = p[:, None, None]
        for _ in range(L - lev):
            p = dec(p)
        Rl, Hl = 1 << lev, 1 << (lev - 1)
        q = R // Rl
        p = _accum_axis1(p.reshape(Hl, Rl, q, q, *p.shape[3:]))
        c21[lev] = _unblock(p)      # (H, *batch, N/2^ℓ, N/2^ℓ)

    def build(lev, idx):
        if lev == L:
            return diag[idx]
        return _TriNode(
            build(lev + 1, 2 * idx), c21[lev + 1][idx], build(lev + 1, 2 * idx + 1)
        )

    return build(0, 0)


# ---------------------------------------------------------------------------
# root assembly (crop-aware: the node tree covers the padded N ≥ n)
# ---------------------------------------------------------------------------


def _first_leaf(node):
    while isinstance(node, _TriNode):
        node = node.c11
    return node


def _assemble_lower(node, buf, off, lim):
    """Write the lower-triangular content of ``node`` into ``buf`` at diagonal
    offset ``off``, clipped to ``lim`` (the true n — blocks can overhang into
    the root pad). Each surviving piece is written exactly once
    (static-offset updates); no concatenation, no transposes."""
    if not isinstance(node, _TriNode):
        h = min(node.shape[-1], lim - off)
        if h <= 0:
            return buf
        return buf.at[..., off : off + h, off : off + h].set(node[..., :h, :h])
    n1 = node.c21.shape[-1]
    m2 = node.c21.shape[-2]
    buf = _assemble_lower(node.c11, buf, off, lim)
    r0 = off + n1
    h, w = min(m2, lim - r0), min(n1, lim - off)
    if h > 0 and w > 0:
        buf = buf.at[..., r0 : r0 + h, off : off + w].set(node.c21[..., :h, :w])
    return _assemble_lower(node.c22, buf, off + n1, lim)


def _lower_dense(node, n):
    """Assemble the root lower triangle (strictly-upper block region zero,
    diagonal base tiles full-symmetric)."""
    leaf = _first_leaf(node)
    batch = leaf.shape[:-2]
    buf = jnp.zeros((*batch, n, n), leaf.dtype)
    return _assemble_lower(node, buf, 0, n)


def _finalize_dense(node, n):
    if not isinstance(node, _TriNode):
        return node  # single base tile: already full and bitwise symmetric
    # the one and only full-square mirror — at the root, for dense consumers.
    return sym_tile(_lower_dense(node, n))


def _assemble_packed(node, buf, off, bn, lim):
    # write_packed_region (core.symmetric): each block lands in packed
    # storage via static-offset updates, strictly-upper pieces skipped;
    # blocks overhanging ``lim`` (the packed grid extent) are clipped.
    if not isinstance(node, _TriNode):
        h = min(node.shape[-1], lim - off)
        if h <= 0:
            return buf
        return write_packed_region(buf, node[..., :h, :h], off, off, bn)
    n1 = node.c21.shape[-1]
    m2 = node.c21.shape[-2]
    buf = _assemble_packed(node.c11, buf, off, bn, lim)
    r0 = off + n1
    h, w = min(m2, lim - r0), min(n1, lim - off)
    if h > 0 and w > 0:
        buf = write_packed_region(buf, node.c21[..., :h, :w], r0, off, bn)
    return _assemble_packed(node.c22, buf, off + n1, bn, lim)


def _finalize_packed(node, n, packed_block):
    """Pack the node tree directly — the dense square is never materialized
    (each result block is written once, straight into packed storage)."""
    bn = default_block_size(n, packed_block)
    nb = -(-n // bn)
    leaf = _first_leaf(node)
    batch = leaf.shape[:-2]
    buf = jnp.zeros((*batch, nb * (nb + 1) // 2, bn, bn), leaf.dtype)
    return SymmetricMatrix(_assemble_packed(node, buf, 0, bn, nb * bn), n, bn)


def _ata_impl(
    a,
    *,
    alpha,
    c,
    beta,
    plan,
    n_base,
    variant,
    leaf_dispatch,
    base_syrk,
    base_dot,
    acc_dtype,
    out,
    packed_block,
):
    if out not in ("dense", "packed"):
        raise ValueError(f"unknown output mode {out!r}; use 'dense' or 'packed'")
    plan, n_base, variant, packed_block, leaf_dispatch = resolve_tunables(
        plan, n_base, variant, packed_block,
        op="ata", m=a.shape[-2], n=a.shape[-1],
        batch=a.shape[0] if a.ndim > 2 else 0,
        dtype=str(a.dtype), out=out, leaf_dispatch=leaf_dispatch,
    )
    if variant not in ("strassen", "winograd"):
        raise ValueError(f"unknown variant {variant!r}")
    if leaf_dispatch == "fused" and variant != "strassen":
        raise ValueError(
            "leaf_dispatch='fused' supports variant='strassen' only: "
            "Winograd's chained within-level combinations do not fit the "
            "per-leaf ±1 slot tables (see DESIGN.md §2)"
        )
    fused_syrk = fused_dot_kernel = None
    if leaf_dispatch == "fused" and base_syrk is None and base_dot is None:
        fused_syrk, fused_dot_kernel = _plan_fused_fns(plan)
    base_syrk, base_dot = _plan_base_fns(plan, base_syrk, base_dot)
    if base_syrk is None:
        base_syrk = functools.partial(_syrk_base, acc_dtype=acc_dtype)
    if base_dot is None:
        base_dot = functools.partial(_dot_tn, acc_dtype=acc_dtype)

    n = a.shape[-1]
    L = tree_depth(a.shape[-2:], n_base)
    obs.metrics.inc(f"dispatch.ata.{leaf_dispatch}")
    # leaf accounting, identical across the three dispatches (the tree is a
    # function of L only): 4^L diagonal syrk leaves, Σ_ℓ 2^{2ℓ-1}·7^{L-ℓ}
    # off-diagonal Strassen leaves — what cost.dispatch_calls predicts.
    obs.metrics.inc("ata.leaves.syrk", 4 ** L)
    obs.metrics.inc(
        "ata.leaves.strassen",
        sum(2 ** (2 * lev - 1) * 7 ** (L - lev) for lev in range(1, L + 1)),
    )
    t0 = obs.dispatch_start(plan, a)
    with obs.span(
        "ata", m=a.shape[-2], n=n, levels=L, leaf_dispatch=leaf_dispatch
    ):
        ap = _pad_root(a, L) if L else a
        if leaf_dispatch in ("batched", "fused"):
            node = _ata_level_sync(
                ap, L, variant=variant, base_syrk=base_syrk, base_dot=base_dot,
                fused=leaf_dispatch == "fused",
                fused_syrk=fused_syrk, fused_dot=fused_dot_kernel,
            )
        else:
            strassen_rec = _rec_strassen if variant == "strassen" else _rec_winograd
            node = _rec_ata(
                [ap],
                n_base=n_base,
                base_syrk=base_syrk,
                strassen_rec=strassen_rec,
                base_dot=base_dot,
                acc_dtype=acc_dtype,
            )

        if out == "packed":
            result = _finalize_packed(node, n, packed_block)
            if alpha != 1.0:
                result = result.scale(alpha)
            if c is not None:
                if not isinstance(c, SymmetricMatrix):
                    raise TypeError(
                        "ata(..., out='packed') accumulates only into a "
                        f"SymmetricMatrix c, got {type(c).__name__}"
                    )
                result = result.add(c.scale(beta) if beta != 1.0 else c)
            return obs.dispatch_finish(plan, t0, result)

        result = _finalize_dense(node, n)
        if alpha != 1.0:
            result = alpha * result
        if c is not None:
            if isinstance(c, SymmetricMatrix):
                c = c.to_dense()
            result = result + (beta * c if beta != 1.0 else c)
        return obs.dispatch_finish(plan, t0, result)


def ata(
    a: jax.Array,
    *,
    alpha: float = 1.0,
    c: Optional[Union[jax.Array, SymmetricMatrix]] = None,
    beta: float = 1.0,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    leaf_dispatch: Optional[str] = None,
    base_syrk: Optional[Callable] = None,
    base_dot: Optional[Callable] = None,
    acc_dtype=jnp.float32,
    out: str = "dense",
    packed_block: Optional[int] = None,
) -> Union[jax.Array, SymmetricMatrix]:
    """``C = alpha·AᵀA (+ beta·C)`` via the paper's ATA algorithm.

    Args:
      a: ``(m, n)`` input, any rectangular shape (odd sizes handled by one
        root pad to a ``2^L``-divisible shape and a crop-aware assembly).
      alpha, c, beta: BLAS-style scaling/accumulation. With ``out='packed'``,
        ``c`` must itself be a ``SymmetricMatrix`` of matching layout.
      plan: a frozen :class:`repro.tune.Plan` carrying every tunable
        (cutoff, variant, kernel blocks, packed block, leaf dispatch). With
        no plan and no pinned tunables the dispatch is planned through
        ``repro.tune.plan`` — the analytic cost model, or a measured plan
        from the cache. Note the output *type* always follows ``out``,
        never the plan.
      n_base: recursion cutoff; tiles with any dim ≤ n_base go to the base
        syrk/gemm. The TPU analogue of the paper's "fits in cache".
        Pinning this (or ``variant``) manually bypasses the planner and
        fills the rest from ``repro.tune.defaults``.
      variant: Strassen variant for the C21 off-diagonal products —
        ``'strassen'`` (paper-faithful) or ``'winograd'`` (beyond-paper,
        15 adds).
      leaf_dispatch: ``'unrolled'`` (one op per leaf), ``'batched'``
        (level-synchronous: ONE batched syrk for all diagonal leaves + ONE
        batched TN dot for every Strassen leaf — bitwise-equal result,
        O(levels) jaxpr), or ``'fused'`` (the level-synchronous tree with
        per-leaf ±1 coefficient tables instead of encode stacks — zero
        materialized operand combinations, bitwise-equal result; classical
        variant only). Defaults to the plan's choice; pinning it alone
        does not bypass the planner (it never changes values).
      base_syrk: base-case ``f(a) -> aᵀa`` (full, bitwise-symmetric tile).
        Defaults to a TN dot_general (or the plan's Pallas kernel); pass
        ``repro.kernels.ops.syrk`` to force the kernel. Must accept one
        leading batch dim (it receives the whole diagonal-leaf stack when
        ``leaf_dispatch='batched'``).
      base_dot: base-case ``f(a, b) -> aᵀb`` for the Strassen leaves (same
        leading-batch contract).
      acc_dtype: accumulation dtype.
      out: ``'dense'`` → ``(n, n)`` full symmetric array (one mirror, at the
        root). ``'packed'`` → :class:`SymmetricMatrix` holding only the
        ``nb(nb+1)/2`` lower-triangular blocks — no mirror anywhere.
      packed_block: block size of the packed output grid (clamped to the
        matrix size; see ``symmetric.default_block_size``).

    Returns:
      ``(n, n)`` full symmetric product, or its packed form.
    """
    if a.ndim != 2:
        raise ValueError(f"ata expects a 2-D operand, got shape {a.shape}")
    return _ata_impl(
        a,
        alpha=alpha,
        c=c,
        beta=beta,
        plan=plan,
        n_base=n_base,
        variant=variant,
        leaf_dispatch=leaf_dispatch,
        base_syrk=base_syrk,
        base_dot=base_dot,
        acc_dtype=acc_dtype,
        out=out,
        packed_block=packed_block,
    )


def ata_batched(
    a: jax.Array,
    *,
    alpha: float = 1.0,
    c: Optional[Union[jax.Array, SymmetricMatrix]] = None,
    beta: float = 1.0,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    leaf_dispatch: Optional[str] = None,
    base_syrk: Optional[Callable] = None,
    base_dot: Optional[Callable] = None,
    acc_dtype=jnp.float32,
    out: str = "dense",
    packed_block: Optional[int] = None,
) -> Union[jax.Array, SymmetricMatrix]:
    """Batched ``C_b = alpha·A_bᵀA_b`` for ``a: (B, m, n)`` — one trace.

    Unlike ``vmap(ata)``, the batch dimension is threaded through the
    recursion itself: every base case is a single *batched* syrk over all B
    tiles (one kernel launch with a leading batch grid dimension when the
    Pallas kernel is the base), and every Strassen leaf is a batched TN dot.
    With ``leaf_dispatch='batched'`` the leaf stack and the operand batch
    are flattened into that one leading kernel dim, so the whole gram batch
    still costs two launches total. ``out='packed'`` returns a
    ``SymmetricMatrix`` whose blocks carry the leading batch dim:
    ``(B, T, bn, bn)``. This is the gram-statistics entry point for the
    blocked-Shampoo optimizer.
    """
    if a.ndim != 3:
        raise ValueError(f"ata_batched expects a (B, m, n) operand, got {a.shape}")
    return _ata_impl(
        a,
        alpha=alpha,
        c=c,
        beta=beta,
        plan=plan,
        n_base=n_base,
        variant=variant,
        leaf_dispatch=leaf_dispatch,
        base_syrk=base_syrk,
        base_dot=base_dot,
        acc_dtype=acc_dtype,
        out=out,
        packed_block=packed_block,
    )
