"""ATA — cache-oblivious Strassen-based ``C = alpha·AᵀA`` (paper Algorithm 1).

The recursion (Eq. 1-2 of the paper), for ``A ∈ R^{m×n}`` split into 2×2
quadrants with floor/ceil halving:

    C11 = A11ᵀA11 + A21ᵀA21      (two recursive ATA calls)
    C22 = A12ᵀA12 + A22ᵀA22      (two recursive ATA calls)
    C21 = A12ᵀA11 + A22ᵀA21      (two rectangular Strassen TN calls)
    C12 = C21ᵀ                   (never computed — symmetry)

Cost: ``T(n) = 4T(n/2) + 2T_S(n/2) + 3(n/2)² ≈ (2/3)·T_S(n)`` — two thirds of
Strassen applied naively, i.e. (14/3)·n^{log₂7} (paper Section 3.2).

TPU adaptation notes (see DESIGN.md §2):

* the recursion unrolls at trace time (static shapes) — cache-obliviousness
  survives as nested recursive blocking that XLA/Mosaic tiles onto
  HBM→VMEM→VREG;
* the symmetric saving at the *base-case* level lives in the Pallas ``syrk``
  kernel, which computes only lower-triangular output blocks;
* **the symmetric saving at the storage level lives here**: the recursion is
  organized as a *slab sum* — each node computes ``Σ_k A_kᵀA_k`` over a list
  of row-slabs for one contiguous column range — and returns a
  ``(c11, c21, c22)`` triangular node structure instead of a dense square.
  No ``jnp.block`` and no ``C21ᵀ`` is materialized at any intermediate level;
  the lower triangle is assembled exactly once at the root (each block
  written once via static-offset updates), and the mirror to a full square
  happens once for dense output — or never, when the caller asks for packed
  output via ``ata(a, out="packed")``, which returns a
  :class:`repro.core.symmetric.SymmetricMatrix`.

``ata`` is a pure JAX function: it composes with ``jit``, ``vmap``, ``grad``,
and ``shard_map`` (used by ``repro.core.distributed``). ``ata_batched`` runs
the same recursion with an explicit leading batch dimension — one trace, one
kernel launch per base tile over the whole batch — which is what the
blocked-Shampoo optimizer uses for its per-block gram statistics.

Dispatch tunables (cutoff, variant, kernel blocks, packed block) resolve
through the ``repro.tune`` planning layer: pass a frozen ``plan=``, pin
values manually, or pass nothing and let the front door decide
(see DESIGN.md §7).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.strassen import (
    DEFAULT_N_BASE,
    _dot_tn,
    _plan_base_fns,
    _rec_strassen,
    _rec_winograd,
    resolve_tunables,
)
from repro.core.symmetric import (
    SymmetricMatrix,
    default_block_size,
    sym_tile,
    write_packed_region,
)
from repro.tune.defaults import DEFAULT_PACKED_BLOCK  # re-export

__all__ = ["ata", "ata_batched", "DEFAULT_N_BASE", "DEFAULT_PACKED_BLOCK"]


def _syrk_base(a, acc_dtype):
    """Default base case: ``AᵀA`` via one TN dot, lower triangle mirrored.

    The Pallas kernel (``repro.kernels.ops.syrk``) replaces this on TPU and
    computes only the lower-triangular blocks; at the pure-jnp level the MXU
    executes the full tile matmul, and we mirror ``low(C)`` so the tile-level
    invariant *the base tile is exactly symmetric* holds bitwise (XLA's
    accumulation order can differ per output position, so the raw matmul is
    only approximately symmetric). This transpose is a ≤ n_base tile op — the
    full-square mirror of the seed implementation is gone.
    """
    return sym_tile(_dot_tn(a, a, acc_dtype))


class _TriNode(NamedTuple):
    """One recursion level of the symmetric product: C = [[c11, ·], [c21, c22]].

    ``c11``/``c22`` are ``_TriNode`` or dense symmetric base tiles; ``c21`` is
    the dense rectangular off-diagonal block. The never-computed upper block
    has no representation — that is the point.
    """

    c11: object
    c21: jax.Array
    c22: object


def _rec_ata(slabs, n_base, base_syrk, strassen_rec, base_dot, acc_dtype):
    """Compute ``Σ_k slab_kᵀ·slab_k`` for one column range, as a _TriNode tree.

    ``slabs`` is a list of ``(..., m_k, n)`` row-slabs sharing the column
    range (the paper's ``C11 = A11ᵀA11 + A21ᵀA21`` generalized: every level
    of row-halving doubles the slab list instead of materializing partial
    dense sums). Keeping the sum *inside* the recursion means both addends of
    every accumulation share one node structure by construction — the result
    tree is a function of the column range only.
    """
    n = slabs[0].shape[-1]
    m_max = max(s.shape[-2] for s in slabs)
    if n <= n_base or m_max <= n_base:
        out = base_syrk(slabs[0])
        for s in slabs[1:]:
            out = out + base_syrk(s)
        return out

    # floor/ceil split, paper Eq. (1): rows of every slab, then columns.
    halves = []
    for s in slabs:
        m1 = s.shape[-2] // 2
        if m1:
            halves.append(s[..., :m1, :])
        halves.append(s[..., m1:, :])
    n1 = n // 2
    left = [h[..., :n1] for h in halves]
    right = [h[..., n1:] for h in halves]

    rec = functools.partial(
        _rec_ata,
        n_base=n_base,
        base_syrk=base_syrk,
        strassen_rec=strassen_rec,
        base_dot=base_dot,
        acc_dtype=acc_dtype,
    )
    st = functools.partial(
        strassen_rec, n_base=n_base, base_dot=base_dot, acc_dtype=acc_dtype
    )

    c11 = rec(left)
    c22 = rec(right)
    c21 = st(right[0], left[0])
    for r, l in zip(right[1:], left[1:]):
        c21 = c21 + st(r, l)
    return _TriNode(c11, c21, c22)


def _first_leaf(node):
    while isinstance(node, _TriNode):
        node = node.c11
    return node


def _assemble_lower(node, buf, off):
    """Write the lower-triangular content of ``node`` into ``buf`` at diagonal
    offset ``off``. Each block is written exactly once (static-offset
    ``dynamic_update_slice``); no concatenation, no transposes."""
    if not isinstance(node, _TriNode):
        k = node.shape[-1]
        return buf.at[..., off : off + k, off : off + k].set(node)
    n1 = node.c21.shape[-1]
    m2 = node.c21.shape[-2]
    buf = _assemble_lower(node.c11, buf, off)
    buf = buf.at[..., off + n1 : off + n1 + m2, off : off + n1].set(node.c21)
    return _assemble_lower(node.c22, buf, off + n1)


def _lower_dense(node, n):
    """Assemble the root lower triangle (strictly-upper block region zero,
    diagonal base tiles full-symmetric)."""
    leaf = _first_leaf(node)
    batch = leaf.shape[:-2]
    buf = jnp.zeros((*batch, n, n), leaf.dtype)
    return _assemble_lower(node, buf, 0)


def _finalize_dense(node, n):
    if not isinstance(node, _TriNode):
        return node  # single base tile: already full and bitwise symmetric
    # the one and only full-square mirror — at the root, for dense consumers.
    return sym_tile(_lower_dense(node, n))


def _assemble_packed(node, buf, off, bn):
    # write_packed_region (core.symmetric): each block lands in packed
    # storage via static-offset updates, strictly-upper pieces skipped.
    if not isinstance(node, _TriNode):
        return write_packed_region(buf, node, off, off, bn)
    n1 = node.c21.shape[-1]
    buf = _assemble_packed(node.c11, buf, off, bn)
    buf = write_packed_region(buf, node.c21, off + n1, off, bn)
    return _assemble_packed(node.c22, buf, off + n1, bn)


def _finalize_packed(node, n, packed_block):
    """Pack the node tree directly — the dense square is never materialized
    (each result block is written once, straight into packed storage)."""
    bn = default_block_size(n, packed_block)
    nb = -(-n // bn)
    leaf = _first_leaf(node)
    batch = leaf.shape[:-2]
    buf = jnp.zeros((*batch, nb * (nb + 1) // 2, bn, bn), leaf.dtype)
    return SymmetricMatrix(_assemble_packed(node, buf, 0, bn), n, bn)


def _ata_impl(
    a,
    *,
    alpha,
    c,
    beta,
    plan,
    n_base,
    variant,
    base_syrk,
    base_dot,
    acc_dtype,
    out,
    packed_block,
):
    if out not in ("dense", "packed"):
        raise ValueError(f"unknown output mode {out!r}; use 'dense' or 'packed'")
    plan, n_base, variant, packed_block = resolve_tunables(
        plan, n_base, variant, packed_block,
        op="ata", m=a.shape[-2], n=a.shape[-1],
        batch=a.shape[0] if a.ndim > 2 else 0,
        dtype=str(a.dtype), out=out,
    )
    if variant not in ("strassen", "winograd"):
        raise ValueError(f"unknown variant {variant!r}")
    base_syrk, base_dot = _plan_base_fns(plan, base_syrk, base_dot)
    if base_syrk is None:
        base_syrk = functools.partial(_syrk_base, acc_dtype=acc_dtype)
    if base_dot is None:
        base_dot = functools.partial(_dot_tn, acc_dtype=acc_dtype)

    n = a.shape[-1]
    strassen_rec = _rec_strassen if variant == "strassen" else _rec_winograd
    node = _rec_ata(
        [a],
        n_base=n_base,
        base_syrk=base_syrk,
        strassen_rec=strassen_rec,
        base_dot=base_dot,
        acc_dtype=acc_dtype,
    )

    if out == "packed":
        result = _finalize_packed(node, n, packed_block)
        if alpha != 1.0:
            result = result.scale(alpha)
        if c is not None:
            if not isinstance(c, SymmetricMatrix):
                raise TypeError(
                    "ata(..., out='packed') accumulates only into a "
                    f"SymmetricMatrix c, got {type(c).__name__}"
                )
            result = result.add(c.scale(beta) if beta != 1.0 else c)
        return result

    result = _finalize_dense(node, n)
    if alpha != 1.0:
        result = alpha * result
    if c is not None:
        if isinstance(c, SymmetricMatrix):
            c = c.to_dense()
        result = result + (beta * c if beta != 1.0 else c)
    return result


def ata(
    a: jax.Array,
    *,
    alpha: float = 1.0,
    c: Optional[Union[jax.Array, SymmetricMatrix]] = None,
    beta: float = 1.0,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    base_syrk: Optional[Callable] = None,
    base_dot: Optional[Callable] = None,
    acc_dtype=jnp.float32,
    out: str = "dense",
    packed_block: Optional[int] = None,
) -> Union[jax.Array, SymmetricMatrix]:
    """``C = alpha·AᵀA (+ beta·C)`` via the paper's ATA algorithm.

    Args:
      a: ``(m, n)`` input, any rectangular shape (odd sizes handled by the
        floor/ceil split here and virtual padding inside Strassen).
      alpha, c, beta: BLAS-style scaling/accumulation. With ``out='packed'``,
        ``c`` must itself be a ``SymmetricMatrix`` of matching layout.
      plan: a frozen :class:`repro.tune.Plan` carrying every tunable
        (cutoff, variant, kernel blocks, packed block). With no plan and no
        pinned tunables the dispatch is planned through ``repro.tune.plan``
        — the analytic cost model, or a measured plan from the cache.
        Note the output *type* always follows ``out``, never the plan.
      n_base: recursion cutoff; tiles with any dim ≤ n_base go to the base
        syrk/gemm. The TPU analogue of the paper's "fits in cache".
        Pinning this (or ``variant``/``packed_block``) manually bypasses
        the planner and fills the rest from ``repro.tune.defaults``.
      variant: Strassen variant for the C21 off-diagonal products —
        ``'strassen'`` (paper-faithful) or ``'winograd'`` (beyond-paper,
        15 adds).
      base_syrk: base-case ``f(a) -> aᵀa`` (full, bitwise-symmetric tile).
        Defaults to a TN dot_general (or the plan's Pallas kernel); pass
        ``repro.kernels.ops.syrk`` to force the kernel.
      base_dot: base-case ``f(a, b) -> aᵀb`` for the Strassen leaves.
      acc_dtype: accumulation dtype.
      out: ``'dense'`` → ``(n, n)`` full symmetric array (one mirror, at the
        root). ``'packed'`` → :class:`SymmetricMatrix` holding only the
        ``nb(nb+1)/2`` lower-triangular blocks — no mirror anywhere.
      packed_block: block size of the packed output grid (clamped to the
        matrix size; see ``symmetric.default_block_size``).

    Returns:
      ``(n, n)`` full symmetric product, or its packed form.
    """
    if a.ndim != 2:
        raise ValueError(f"ata expects a 2-D operand, got shape {a.shape}")
    return _ata_impl(
        a,
        alpha=alpha,
        c=c,
        beta=beta,
        plan=plan,
        n_base=n_base,
        variant=variant,
        base_syrk=base_syrk,
        base_dot=base_dot,
        acc_dtype=acc_dtype,
        out=out,
        packed_block=packed_block,
    )


def ata_batched(
    a: jax.Array,
    *,
    alpha: float = 1.0,
    c: Optional[Union[jax.Array, SymmetricMatrix]] = None,
    beta: float = 1.0,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    base_syrk: Optional[Callable] = None,
    base_dot: Optional[Callable] = None,
    acc_dtype=jnp.float32,
    out: str = "dense",
    packed_block: Optional[int] = None,
) -> Union[jax.Array, SymmetricMatrix]:
    """Batched ``C_b = alpha·A_bᵀA_b`` for ``a: (B, m, n)`` — one trace.

    Unlike ``vmap(ata)``, the batch dimension is threaded through the
    recursion itself: every base case is a single *batched* syrk over all B
    tiles (one kernel launch with a leading batch grid dimension when the
    Pallas kernel is the base), and every Strassen leaf is a batched TN dot.
    ``out='packed'`` returns a ``SymmetricMatrix`` whose blocks carry the
    leading batch dim: ``(B, T, bn, bn)``. This is the gram-statistics
    entry point for the blocked-Shampoo optimizer.
    """
    if a.ndim != 3:
        raise ValueError(f"ata_batched expects a (B, m, n) operand, got {a.shape}")
    return _ata_impl(
        a,
        alpha=alpha,
        c=c,
        beta=beta,
        plan=plan,
        n_base=n_base,
        variant=variant,
        base_syrk=base_syrk,
        base_dot=base_dot,
        acc_dtype=acc_dtype,
        out=out,
        packed_block=packed_block,
    )
