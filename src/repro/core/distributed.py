"""SPMD schedules for the distributed ``AᵀA`` product (paper §4.2 / §4.3).

The paper's parallel insight: schedule the symmetric product as **disjoint,
α-balanced tasks** over the lower triangle of C (threads/ranks never collide
on writes), and **retrieve only packed lower-triangular payloads**. Its
transport — MPI scatter/gather trees from a root rank — would serialize on
one chip on a TPU pod, so the schedules here map the same insight onto
jax-native SPMD (see DESIGN.md §2):

* :func:`gram_rowshard` — A row-sharded (the ``C = Σ_p A_pᵀA_p`` view, i.e.
  the C11 recursion collapsed onto the mesh): local ATA + one ``psum``.
  This is the pure-DP gram used by the Shampoo optimizer for row-sharded
  gradients. With ``out='packed'`` the psum payload is the packed
  ``SymmetricMatrix`` block stack — ``T·bn² ≈ n²/2`` words per reduce
  instead of the dense ``n²`` (the collective-bytes halving the packed
  optimizer statistics ride on).

* :func:`ata_tile_parallel` — the ATA-S/ATA-D analogue. C's lower triangle
  is tiled into ``nb(nb+1)/2`` uniform ``w×w`` tiles, assigned contiguously
  to the devices of ``task_axis`` (uniform shapes keep the program SPMD);
  each device computes its tiles with the sequential ATA/Strassen machinery
  at the leaf level (paper §4.1.3: "Strassen can still be used at
  leaf-level computation") — including the level-synchronous
  ``leaf_dispatch='batched'`` formulation when the plan picks it, so each
  device's tile products cost O(levels) dispatched ops, not O(7^L)
  (DESIGN.md §4), and the fused-operand ``'fused'`` dispatch, whose ±1
  leaf combinations never materialize an operand stack in any per-device
  body (DESIGN.md §2). Partial sums over a ``row_axis`` (if A is also
  row-sharded — the ATA-D two-level layout) are combined with a single
  ``psum`` **of the packed tile stack** — ``T·w² ≈ n²/2`` words instead of
  the dense ``n²``, reproducing the paper's packed-low(C) retrieval saving
  (Prop. 4.2) as a collective-bytes saving. Retrieval keeps that form:
  ``out='packed'`` assembles a :class:`~repro.core.symmetric
  .SymmetricMatrix` straight from the tile stack (a pure slice when the
  stripe width matches the packed block grid — no dense buffer anywhere),
  and the dense mode is just its ``to_dense()`` at the root — the mirrored
  replicated square the seed materialized unconditionally is now opt-in.

* :func:`gemm_tn_colshard` — the distributed FastStrassen companion:
  ``C = AᵀB`` with B column-sharded; each device owns a disjoint column
  stripe of C (no collision, no reduction).

Correspondence with ``repro.core.task_tree``: the task tree is the faithful
scheduler model (heterogeneous leaf shapes — fine for MPI ranks, hostile to
SPMD). The block-cyclic tiling here is the shape-uniform realization of the
same disjoint-task principle; `tests/test_distributed.py` checks that both
cover the lower triangle exactly once and that flop balance matches the
LPT model within the tile-granularity bound.
"""

from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.compat import shard_map
from repro.core.ata import ata
from repro.core.strassen import strassen_tn
from repro.core.symmetric import SymmetricMatrix, sym_tile

__all__ = [
    "gram_rowshard",
    "ata_tile_parallel",
    "ata_bfs_dfs",
    "bfs_dfs_assignment",
    "gemm_tn_colshard",
    "choose_tiling",
    "tile_parallel_device_flops",
]


# ---------------------------------------------------------------------------
# rowshard: C = Σ_p A_pᵀ A_p
# ---------------------------------------------------------------------------


def gram_rowshard(
    a_local: jax.Array,
    axis: str,
    *,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    leaf_dispatch: Optional[str] = None,
    use_ata: Optional[bool] = None,
    out: str = "dense",
    packed_block: Optional[int] = None,
) -> Union[jax.Array, SymmetricMatrix]:
    """Per-device gram + all-reduce. Call **inside** shard_map/pjit-manual.

    ``a_local`` is this device's row block; the result is the full replicated
    ``AᵀA``. The local product uses the sequential ATA algorithm, so the
    paper's 2/3-Strassen flop saving applies on every chip. Tunables resolve
    through the planner (`repro.tune.plan` on the local shape) unless pinned
    — including ``leaf_dispatch``: the per-device body reuses the batched
    or fused leaf formulation when the plan (or the caller) asks for it, so
    the SPMD schedule inherits the O(levels)-jaxpr win per shard (and, for
    ``'fused'``, the zero-operand-stack leaf combine). ``use_ata=False``
    — or a plan whose algorithm is ``'dense'`` — falls back to the
    classical one-dot gram.

    ``out='packed'`` keeps the paper's low(C) form **across the psum**: the
    local gram comes out of ``ata(..., out='packed')`` mirror-free and the
    all-reduce moves the packed ``(T, bn, bn)`` block stack — ``≈ n²/2``
    words instead of the dense ``n²`` — returning a replicated
    :class:`SymmetricMatrix`. (``SymmetricMatrix`` is a pytree, so the
    caller's ``shard_map`` needs a 3-axis out_spec, e.g. ``P(None, None,
    None)``.)
    """
    if out not in ("dense", "packed"):
        raise ValueError(f"unknown output mode {out!r}; use 'dense' or 'packed'")
    if use_ata is None:
        use_ata = plan is None or plan.algorithm != "dense"
    obs.metrics.inc("dispatch.gram_rowshard")
    with obs.span("distributed.gram_rowshard", out=out, use_ata=use_ata):
        if use_ata:
            local = ata(
                a_local, plan=plan, n_base=n_base, variant=variant,
                leaf_dispatch=leaf_dispatch, out=out, packed_block=packed_block,
            )
        else:
            local = jax.lax.dot_general(
                a_local, a_local, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            if out == "packed":
                if packed_block is None:
                    from repro.tune.defaults import DEFAULT_PACKED_BLOCK

                    packed_block = (
                        plan.packed_block if plan is not None else DEFAULT_PACKED_BLOCK
                    )
                local = SymmetricMatrix.from_dense(local, packed_block)
        # psum maps over the SymmetricMatrix pytree leaf — the packed stack
        # is the collective payload, never a mirrored square.
        with obs.span("distributed.psum", axis=axis, out=out):
            return jax.lax.psum(local, axis)


# ---------------------------------------------------------------------------
# tile-parallel: block-cyclic lower-triangle tiles over a mesh axis
# ---------------------------------------------------------------------------


def choose_tiling(
    n: int,
    p: int,
    target_tiles_per_dev: Optional[int] = None,
    *,
    out: str = "dense",
    packed_block: Optional[int] = None,
) -> tuple[int, int]:
    """Pick (nb, w): nb stripe count, w stripe width (multiple of 8).

    Delegates to the planner's distributed branch
    (`repro.tune.cost.distributed_tiling`) — kept as the public name the
    SPMD schedules and tests use. ``out='packed'`` lets the search snap the
    stripe width to the packed block grid (pure-slice retrieval).
    """
    from repro.tune.cost import distributed_tiling

    return distributed_tiling(
        n, p, target_tiles_per_dev, out=out, packed_block=packed_block
    )


def _tri_coords_traced(t):
    tf = t.astype(jnp.float32)
    i = jnp.floor((jnp.sqrt(8.0 * tf + 1.0) - 1.0) / 2.0).astype(jnp.int32)
    i = jnp.where((i + 1) * (i + 2) // 2 <= t, i + 1, i)
    i = jnp.where(i * (i + 1) // 2 > t, i - 1, i)
    j = t - i * (i + 1) // 2
    return i, j


def ata_tile_parallel(
    a: jax.Array,
    mesh: Mesh,
    *,
    task_axis: str = "model",
    row_axis: Optional[str] = None,
    alpha: float = 1.0,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    leaf_dispatch: Optional[str] = None,
    use_strassen: bool = True,
    nb: Optional[int] = None,
    out: str = "dense",
    packed_block: Optional[int] = None,
    acc_dtype=jnp.float32,
) -> Union[jax.Array, SymmetricMatrix]:
    """Distributed ``C = alpha·AᵀA`` with disjoint lower-triangle tile tasks.

    Args:
      a: global ``(m, n)``. Sharded ``P(row_axis, None)`` if ``row_axis``
        is given (the row_axis size must divide m), replicated otherwise.
      mesh: the device mesh.
      task_axis: mesh axis that owns disjoint C tiles (the "thread pool" of
        ATA-S / the worker ranks of ATA-D).
      row_axis: optional mesh axis across which the contraction dimension is
        sharded (ATA-D's two-level layout). Partial tiles are psum'ed as a
        packed stack (≈ n²/2 words — the paper's low(C) retrieval saving).
      alpha: scalar applied to the result — in **both** output modes
        (``out='packed'`` scales the packed blocks; the equivalence
        ``alpha·packed == pack(alpha·dense)`` holds bitwise).
      plan: :class:`repro.tune.Plan` (its ``nb``/``tile_w`` distributed
        branch supplies the stripe tiling; ``n_base``/``variant``/
        ``leaf_dispatch`` feed the leaf-level Strassen of every per-device
        tile body — a batched plan runs each device's tile products through
        the level-synchronous one-dot-per-tile dispatch, a fused plan
        through the coefficient-table combine with no operand stacks). Default: the
        planner front door with ``devices=p_task`` and the requested
        ``out`` — packed plans snap ``tile_w`` to the packed block grid so
        retrieval is a pure slice.
      leaf_dispatch: explicit override of the plan's leaf dispatch for the
        per-device Strassen bodies (``'unrolled'``/``'batched'``/``'fused'``
        — values are bitwise-identical in every case; ``'fused'`` requires
        the classical variant, so pin ``variant='strassen'`` alongside it
        if the resolved plan picked winograd).
      nb: stripe count override (default: the plan / :func:`choose_tiling`).
      out: ``'dense'`` → replicated ``(n, n)`` array, assembled as
        ``packed.to_dense()`` at the root (one mirror, at the conversion
        boundary). ``'packed'`` → :class:`SymmetricMatrix` built directly
        from the psum'd tile stack: no dense ``(n, n)`` buffer, no mirror,
        no per-tile update loop anywhere in the graph.
      packed_block: packed output grid block size (default: the plan's, or
        ``tune.defaults.DEFAULT_PACKED_BLOCK``); clamped per
        ``symmetric.default_block_size`` for cross-producer compatibility.
      acc_dtype: accumulation dtype of the leaf products (the dummy-slot
        zero tiles follow it — derived via ``eval_shape``, never hardcoded).

    Returns:
      Full symmetric ``(n, n)`` C replicated over the mesh, or its packed
      ``SymmetricMatrix`` form.
    """
    if out not in ("dense", "packed"):
        raise ValueError(f"unknown output mode {out!r}; use 'dense' or 'packed'")
    m, n = a.shape
    p_task = mesh.shape[task_axis]
    if row_axis is not None:
        p_row = mesh.shape[row_axis]
        if m % p_row:
            raise ValueError(
                f"row_axis {row_axis!r} size {p_row} must divide m={m} "
                f"(A is row-sharded P({row_axis!r}, None))"
            )
    if plan is None and n_base is None and variant is None and nb is None:
        from repro.tune import plan as _plan_fn

        plan = _plan_fn(
            op="ata", m=m, n=n, dtype=str(a.dtype), devices=p_task, out=out
        )
    w = None
    if plan is not None:
        n_base = plan.n_base if n_base is None else n_base
        variant = plan.variant if variant is None else variant
        if leaf_dispatch is None:
            leaf_dispatch = getattr(plan, "leaf_dispatch", None)
        if packed_block is None:
            packed_block = plan.packed_block
        if plan.algorithm == "dense":
            use_strassen = False
        # adopt the plan's stripe tiling only if it was built for THIS
        # problem — a plan for another width would tile (and silently
        # truncate) the wrong column range.
        if nb is None and plan.devices == p_task and plan.n == n and plan.nb:
            nb, w = plan.nb, plan.tile_w
    if nb is None:
        nb, w = choose_tiling(n, p_task, out=out, packed_block=packed_block)
    elif w is None:
        w = -(-n // nb)
        w = -(-w // 8) * 8
    n_pad = nb * w
    t_total = nb * (nb + 1) // 2
    t_per = -(-t_total // p_task)

    if n_pad > n:
        a = jnp.pad(a, ((0, 0), (0, n_pad - n)))

    def compute_tile(a_local, t):
        i, j = _tri_coords_traced(t)
        ai = jax.lax.dynamic_slice_in_dim(a_local, i * w, w, axis=1)
        aj = jax.lax.dynamic_slice_in_dim(a_local, j * w, w, axis=1)
        if use_strassen:
            return strassen_tn(
                ai, aj, n_base=n_base, variant=variant,
                leaf_dispatch=leaf_dispatch, acc_dtype=acc_dtype,
            )
        return jax.lax.dot_general(
            ai, aj, (((0,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        )

    # shape/dtype of one computed tile, without tracing a real one: the
    # dummy-slot zero tile must agree with it exactly, or the two lax.cond
    # branches fail to trace (e.g. a bf16 accumulation dtype against the
    # previously hardcoded f32 dummy).
    m_local = m // mesh.shape[row_axis] if row_axis is not None else m
    tile_abs = jax.eval_shape(
        compute_tile,
        jax.ShapeDtypeStruct((m_local, n_pad), a.dtype),
        jax.ShapeDtypeStruct((), jnp.int32),
    )

    obs.metrics.inc("dispatch.ata_tile_parallel")
    obs.metrics.inc("ata_tile_parallel.tiles", t_total)

    def local_fn(a_local):
        p = jax.lax.axis_index(task_axis)

        def tile_slot(q):
            """Slot q of this device: tile p·t_per+q, or a zero dummy.

            When T % p ≠ 0 the trailing devices own dummy slots. The seed
            clamped them to tile T−1 and recomputed it up to t_per−1 extra
            times per device; dummies are now **masked to a zero tile**
            behind ``lax.cond`` — real control flow, so the dot never runs —
            which restores the exact LPT flop model
            (:func:`tile_parallel_device_flops`, regression-tested).
            Slots that are valid on *every* device skip the cond statically.
            """
            g = p * t_per + q
            if (p_task - 1) * t_per + q < t_total:
                return compute_tile(a_local, g)
            return jax.lax.cond(
                g < t_total,
                lambda: compute_tile(a_local, jnp.minimum(g, t_total - 1)),
                lambda: jnp.zeros(tile_abs.shape, tile_abs.dtype),
            )

        # python-unrolled tile loop (t_per is small): keeps every tile's
        # matmuls visible to XLA's cost model (lax.map would count the body
        # once) and lets XLA schedule tiles independently.
        with obs.span("distributed.tile_body", t_per=t_per, w=w):
            tiles = jnp.stack([tile_slot(q) for q in range(t_per)])
        if row_axis is not None:
            # packed retrieval: reduce the tile stack, not a dense (n, n)
            with obs.span("distributed.psum", axis=row_axis, out="packed"):
                tiles = jax.lax.psum(tiles, row_axis)
        return tiles

    in_spec = P(row_axis, None) if row_axis else P(None, None)
    tiles = shard_map(
        local_fn, mesh=mesh, in_specs=(in_spec,), out_specs=P(task_axis, None, None)
    )(a)
    # tiles: global (p_task * t_per, w, w), tri-enumerated — exactly the
    # packed retrieval payload. Assemble the SymmetricMatrix straight from
    # it (pure slice when w matches the packed grid; static re-tile
    # otherwise); dense output is its one root-level mirror. The seed's
    # per-tile dynamic_update_slice loop into a replicated (n_pad, n_pad)
    # square is gone from both modes.
    sym = SymmetricMatrix.from_tile_stack(tiles, n, nb=nb, packed_block=packed_block)
    if alpha != 1.0:
        sym = sym.scale(alpha)
    if out == "packed":
        return sym
    return sym.to_dense()


# ---------------------------------------------------------------------------
# CAPS-style BFS/DFS schedule (paper §5 / Prop. 4.2 × CAPS, arxiv 1202.3173)
# ---------------------------------------------------------------------------


def _region_tiles(region) -> list:
    """Stripe-index (i, j) tiles of one schedule region (lower triangle)."""
    if region[0] == "tri":
        _, lo, hi = region
        return [(i, j) for i in range(lo, hi) for j in range(lo, i + 1)]
    _, rlo, rhi, clo, chi = region
    return [(i, j) for i in range(rlo, rhi) for j in range(clo, chi)]


def _region_children(region):
    """One recursion level of the ATA tree in tile space, or None at a leaf.

    A diagonal (triangular) region splits as the paper's ATA recursion:
    ``C11`` (triangle, ceil-half), ``C21`` (the off-diagonal rectangle — the
    two Strassen products of the 4+3 diag/off-diag split), ``C22``
    (triangle). A rectangular region splits 2×2 (its products are plain
    Strassen gemms whose 7-way tree lives *inside* each tile's
    ``strassen_tn`` leaf, below tile granularity).
    """
    if region[0] == "tri":
        _, lo, hi = region
        if hi - lo < 2:
            return None
        mid = lo + (hi - lo + 1) // 2
        return [("tri", lo, mid), ("rect", mid, hi, lo, mid),
                ("tri", mid, hi)]
    _, rlo, rhi, clo, chi = region
    if rhi - rlo < 2 and chi - clo < 2:
        return None
    rows = [(rlo, rhi)] if rhi - rlo < 2 else [
        (rlo, rlo + (rhi - rlo + 1) // 2), (rlo + (rhi - rlo + 1) // 2, rhi)]
    cols = [(clo, chi)] if chi - clo < 2 else [
        (clo, clo + (chi - clo + 1) // 2), (clo + (chi - clo + 1) // 2, chi)]
    return [("rect", a, b, c, d) for a, b in rows for c, d in cols]


def bfs_dfs_assignment(nb: int, pool: int, interleaving: str,
                       *, emit_spans: bool = False):
    """Static BFS/DFS tile ownership over a ``pool``-device task axis.

    The **interleaving-string contract**: ``interleaving`` is a string over
    ``{'B', 'D'}``; character ℓ tags recursion level ℓ of the ATA tree
    *in tile space* (level 0 = the root split of the ``nb``-stripe lower
    triangle). A ``'B'`` (breadth-first, CAPS-style) level splits every
    active device group into disjoint subgroups, one per child subproblem
    (diag/off-diag: two triangles + the C21 rectangle; rectangles split
    2×2), with devices allotted proportionally to child tile counts
    (largest remainder, every nonempty child ≥ 1 device while they last;
    with fewer devices than children, children are LPT-packed onto the
    devices). A ``'D'`` (depth-first) level keeps each group intact — its
    devices sweep that level's subproblems cooperatively. Groups of one
    device, and regions at tile granularity, pass through unchanged, so
    any device count (7-divisible or not) and any string length are valid.
    After the last character each group's tiles are assigned contiguously
    (tri-order) to its devices — a pure-``'D'`` string therefore
    reproduces :func:`ata_tile_parallel`'s contiguous split exactly.

    Returns ``(owned, levels)``: ``owned[dev]`` is the sorted list of
    global tri-order tile ids device ``dev`` computes; ``levels`` is one
    ``{'tag', 'groups'}`` dict per interleaving character (telemetry —
    with ``emit_spans`` each level's split is wrapped in a
    ``distributed.bfs`` / ``distributed.dfs`` obs span).
    """
    if not interleaving or any(c not in "BD" for c in interleaving):
        raise ValueError(
            f"interleaving must be a non-empty string over {{'B','D'}}; "
            f"got {interleaving!r}")
    groups = [([("tri", 0, nb)], list(range(pool)))]
    levels = []

    def split_level(lv: int) -> None:
        nonlocal groups
        new_groups = []
        for regions, devs in groups:
            if len(devs) < 2:
                new_groups.append((regions, devs))
                continue
            kids = []
            for r in regions:
                ch = _region_children(r)
                kids.extend(ch if ch else [r])
            kids = [(k, len(_region_tiles(k))) for k in kids]
            kids = [(k, c) for k, c in kids if c]
            if len(kids) < 2:
                new_groups.append(([k for k, _ in kids], devs))
                continue
            g = len(devs)
            if g >= len(kids):
                total = sum(c for _, c in kids)
                quota = [c * g / total for _, c in kids]
                alloc = [max(1, int(q)) for q in quota]
                while sum(alloc) > g:
                    over = [i for i in range(len(alloc)) if alloc[i] > 1]
                    i = max(over, key=lambda i: alloc[i] - quota[i])
                    alloc[i] -= 1
                while sum(alloc) < g:
                    i = min(range(len(alloc)),
                            key=lambda i: (alloc[i] - quota[i], -quota[i]))
                    alloc[i] += 1
                pos = 0
                for (k, _), a in zip(kids, alloc):
                    new_groups.append(([k], devs[pos:pos + a]))
                    pos += a
            else:
                buckets = [[[], 0] for _ in range(g)]
                for k, c in sorted(kids, key=lambda kc: -kc[1]):
                    b = min(buckets, key=lambda b: b[1])
                    b[0].append(k)
                    b[1] += c
                new_groups.extend(
                    (regs, [dev]) for (regs, _), dev in zip(buckets, devs))
        groups = new_groups

    for lv, ch in enumerate(interleaving):
        if ch == "B":
            if emit_spans:
                with obs.span("distributed.bfs", level=lv):
                    split_level(lv)
            else:
                split_level(lv)
        elif emit_spans:
            with obs.span("distributed.dfs", level=lv, groups=len(groups)):
                pass
        levels.append(dict(tag=ch, groups=len(groups)))

    owned = [[] for _ in range(pool)]
    for regions, devs in groups:
        ts = sorted(i * (i + 1) // 2 + j
                    for r in regions for i, j in _region_tiles(r))
        per = -(-len(ts) // len(devs))
        for idx, dev in enumerate(devs):
            owned[dev] = ts[idx * per:(idx + 1) * per]
    return owned, levels


def ata_bfs_dfs(
    a: jax.Array,
    mesh: Mesh,
    *,
    task_axis: str = "model",
    row_axis: Optional[str] = None,
    interleaving: Optional[str] = None,
    alpha: float = 1.0,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    leaf_dispatch: Optional[str] = None,
    use_strassen: bool = True,
    nb: Optional[int] = None,
    out: str = "dense",
    packed_block: Optional[int] = None,
    acc_dtype=jnp.float32,
) -> Union[jax.Array, SymmetricMatrix]:
    """Distributed ``C = alpha·AᵀA`` under a CAPS-style BFS/DFS schedule.

    The ATA analogue of CAPS (Ballard–Demmel–Holtz–Schwartz, arxiv
    1202.3173): each recursion level of the lower-triangle tile tree is
    tagged BFS (``'B'``) or DFS (``'D'``) by ``interleaving`` (contract:
    see :func:`bfs_dfs_assignment` — e.g. ``"BD"``). BFS levels confine
    each child subproblem (4 sub-ATAs + the C21 Strassen rectangle — the
    diag/off-diag 4+3 split) to a disjoint device subgroup of the task
    axis, so subgroup collectives run on sub-axes of the mesh and never
    cross subgroups; DFS levels keep all of a group's devices cooperating
    on one subproblem, exactly like :func:`ata_tile_parallel`'s contiguous
    sweep. Leaf tiles dispatch through the planned sequential machinery
    (``strassen_tn`` with the plan's unrolled/batched/fused leaf body),
    and retrieval is the packed ``SymmetricMatrix`` stack at the root.

    Communication: any BFS level switches the root exchange to the
    **tri-direct reduce-scatter** — every device stages its partial tiles
    at their global tri positions in a ``T``-padded buffer and one
    ``psum_scatter`` over the merged ``(task, row)`` axes simultaneously
    (a) sums the row-wise partials and (b) deals each device a contiguous
    tri-order chunk of the reduced stack, so the packed retrieval is a
    pure slice and diagonal symmetrization happens locally on the chunk
    (``from_tile_stack(presymmetrized=True)`` skips its cross-shard diag
    gather). The collective payload is one chunk of ``T_pad/(p·d)`` tiles
    per device — versus the psum schedule's full ``t_per``-tile
    all-reduce *plus* an ``nb``-tile diag-gather — at the price of the
    ``T``-tile staging buffer: the classic CAPS memory-for-bandwidth
    trade (BFS = more memory, fewer words; DFS = lean memory, more
    words). A pure-``'D'`` interleaving degenerates to the existing
    schedule — same contiguous assignment, same plain ``psum``, same
    out_specs, bitwise-identical program. Every interleaving is
    value-identical: tile products and their reduction order never depend
    on the tags (the scatter only adds zeros, which is bitwise-neutral),
    so results match :func:`ata_tile_parallel` bitwise in both output
    modes.

    ``interleaving=None`` resolves through the planner
    (``plan.comm_schedule`` — picked per shape/mesh/memory by the α-β
    communication model of ``tune.cost``), falling back to pure DFS.
    Other arguments match :func:`ata_tile_parallel`.
    """
    if out not in ("dense", "packed"):
        raise ValueError(f"unknown output mode {out!r}; use 'dense' or 'packed'")
    m, n = a.shape
    p_task = mesh.shape[task_axis]
    d_row = mesh.shape[row_axis] if row_axis is not None else 1
    if row_axis is not None and m % d_row:
        raise ValueError(
            f"row_axis {row_axis!r} size {d_row} must divide m={m} "
            f"(A is row-sharded P({row_axis!r}, None))"
        )
    if plan is None and n_base is None and variant is None and nb is None \
            and interleaving is None:
        from repro.tune import plan as _plan_fn

        plan = _plan_fn(
            op="ata", m=m, n=n, dtype=str(a.dtype), devices=p_task, out=out,
            row_devices=d_row,
        )
    w = None
    if plan is not None:
        n_base = plan.n_base if n_base is None else n_base
        variant = plan.variant if variant is None else variant
        if leaf_dispatch is None:
            leaf_dispatch = getattr(plan, "leaf_dispatch", None)
        if packed_block is None:
            packed_block = plan.packed_block
        if interleaving is None:
            interleaving = getattr(plan, "comm_schedule", None)
        if plan.algorithm == "dense":
            use_strassen = False
        if nb is None and plan.devices == p_task and plan.n == n and plan.nb \
                and getattr(plan, "row_devices", 1) == d_row:
            nb, w = plan.nb, plan.tile_w
    if interleaving is None:
        interleaving = "D"
    if nb is None:
        if "B" in interleaving and p_task * d_row > 1:
            # BFS tiling: T must divide the merged device pool so the
            # tri-direct reduce-scatter chunks exactly and the packed
            # retrieval is an identity slice (see tune.cost.bfs_tiling)
            from repro.tune.cost import bfs_tiling

            nb, w = bfs_tiling(n, p_task * d_row, devices=p_task, out=out,
                               packed_block=packed_block)
            if packed_block is None:
                packed_block = w
        else:
            nb, w = choose_tiling(n, p_task, out=out,
                                  packed_block=packed_block)
    elif w is None:
        w = -(-n // nb)
        w = -(-w // 8) * 8
    n_pad = nb * w
    t_total = nb * (nb + 1) // 2

    owned, levels = bfs_dfs_assignment(nb, p_task, interleaving,
                                       emit_spans=True)
    pool = p_task * d_row
    scatter = "B" in interleaving and pool > 1
    s_eff = max(len(o) for o in owned)
    # tri-direct staging: pad T to a multiple of the device pool so one
    # reduce-scatter over the merged (task, row) axes lands every device a
    # contiguous tri-order chunk of the fully reduced stack
    t_pad = -(-t_total // pool) * pool
    chunk = t_pad // pool
    # the static slot table the per-device body indexes with its own
    # axis_index: slot_table[dev][q] = global tri-order tile id, -1 = dummy
    import numpy as _np

    slot_table = _np.full((p_task, s_eff), -1, dtype=_np.int32)
    for dev, ts in enumerate(owned):
        slot_table[dev, : len(ts)] = ts
    all_valid = (slot_table >= 0).all(axis=0)  # per-slot: cond-free?
    diag_mask = _np.zeros(t_pad, dtype=bool)
    for i in range(nb):
        diag_mask[i * (i + 1) // 2 + i] = True

    if n_pad > n:
        a = jnp.pad(a, ((0, 0), (0, n_pad - n)))

    def compute_tile(a_local, t):
        i, j = _tri_coords_traced(t)
        ai = jax.lax.dynamic_slice_in_dim(a_local, i * w, w, axis=1)
        aj = jax.lax.dynamic_slice_in_dim(a_local, j * w, w, axis=1)
        if use_strassen:
            return strassen_tn(
                ai, aj, n_base=n_base, variant=variant,
                leaf_dispatch=leaf_dispatch, acc_dtype=acc_dtype,
            )
        return jax.lax.dot_general(
            ai, aj, (((0,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        )

    m_local = m // d_row
    tile_abs = jax.eval_shape(
        compute_tile,
        jax.ShapeDtypeStruct((m_local, n_pad), a.dtype),
        jax.ShapeDtypeStruct((), jnp.int32),
    )

    obs.metrics.inc("dispatch.ata_bfs_dfs")
    obs.metrics.inc("ata_bfs_dfs.tiles", t_total)
    obs.metrics.inc("ata_bfs_dfs.bfs_levels", interleaving.count("B"))
    obs.metrics.inc("ata_bfs_dfs.dfs_levels", interleaving.count("D"))

    table = jnp.asarray(slot_table)
    diag_tbl = jnp.asarray(diag_mask)
    from repro.launch.mesh import merged_axis

    merged = merged_axis(task_axis, row_axis)

    def local_fn(a_local):
        pidx = jax.lax.axis_index(task_axis)
        row = jax.lax.dynamic_slice_in_dim(table, pidx, 1, axis=0)[0]

        def tile_slot(q):
            g = row[q]
            if all_valid[q]:
                return compute_tile(a_local, g)
            return jax.lax.cond(
                g >= 0,
                lambda: compute_tile(a_local, jnp.maximum(g, 0)),
                lambda: jnp.zeros(tile_abs.shape, tile_abs.dtype),
            )

        with obs.span("distributed.tile_body", t_per=s_eff, w=w):
            tiles = jnp.stack([tile_slot(q) for q in range(s_eff)])
        if scatter:
            # BFS redistribution, tri-direct: stage the partial tiles at
            # their global tri positions in a T-padded buffer (one extra
            # sacrificial row swallows the dummy slots), then ONE
            # reduce-scatter over the merged (task, row) axes both sums
            # the row-wise partials and deals every device its contiguous
            # tri-order chunk of the reduced stack — reduction and
            # retrieval re-layout in a single chunk-sized collective.
            ids = jnp.where(row >= 0, row, t_pad)
            buf = jnp.zeros((t_pad + 1, *tiles.shape[1:]), tiles.dtype)
            buf = buf.at[ids].set(tiles)[:t_pad]
            with obs.span("distributed.psum_scatter", axis=str(merged),
                          out="packed"):
                tiles = jax.lax.psum_scatter(
                    buf, merged, scatter_dimension=0, tiled=True)
            # local diagonal symmetrization: the chunk's global tile ids
            # are axis_index-affine, so diag membership is a tiny static
            # table lookup — from_tile_stack can then skip its cross-shard
            # _symmetrize_diag gather (presymmetrized=True).
            k = jax.lax.axis_index(task_axis)
            if row_axis is not None:
                k = k * d_row + jax.lax.axis_index(row_axis)
            dm = jnp.take(diag_tbl, k * chunk + jnp.arange(chunk))
            tiles = jnp.where(dm[:, None, None], sym_tile(tiles), tiles)
        elif row_axis is not None:
            with obs.span("distributed.psum", axis=row_axis,
                          out="packed"):
                tiles = jax.lax.psum(tiles, row_axis)
        return tiles

    in_spec = P(row_axis, None) if row_axis else P(None, None)
    out_spec = (P(merged, None, None) if scatter
                else P(task_axis, None, None))
    tiles = shard_map(
        local_fn, mesh=mesh, in_specs=(in_spec,), out_specs=out_spec
    )(a)
    # either way the global stack is the tri-order prefix: scatter path by
    # construction (chunk k holds tiles [k·chunk, (k+1)·chunk)), psum path
    # because contiguous per-task assignment puts task t's tiles at
    # [t·s_eff, …) with dummies trailing — retrieval is a pure slice.
    sym = SymmetricMatrix.from_tile_stack(tiles, n, nb=nb,
                                          packed_block=packed_block,
                                          presymmetrized=scatter)
    if alpha != 1.0:
        sym = sym.scale(alpha)
    if out == "packed":
        return sym
    return sym.to_dense()


def tile_parallel_device_flops(
    m: int,
    n: int,
    p: int,
    *,
    nb: Optional[int] = None,
    n_base: Optional[int] = None,
    use_strassen: Optional[bool] = None,
    dtype: str = "float32",
    out: str = "dense",
    packed_block: Optional[int] = None,
) -> list:
    """Exact per-device flops of :func:`ata_tile_parallel`'s masked schedule.

    Device ``d`` computes its valid contiguous slots only — dummy slots are
    cond-masked zero tiles, not recomputed clamps — so the per-device counts
    are ``t_per`` (or fewer) uniform-tile flop counts and the total over
    devices is exactly ``T`` tiles' worth: the LPT model of ``T`` equal
    tasks. Mirrors the tile compute path via the reference counters —
    including the tunable resolution: unpinned ``n_base``/``use_strassen``
    resolve through the same planner front door the execution path
    consults, so the model counts what the default dispatch actually runs
    (pass the operand's ``dtype`` — the plan, and hence the recursion, is
    keyed on it — and the dispatch's ``out``/``packed_block``: the packed
    mode's tiling can snap to the packed block grid, changing the stripe
    width the flop model must mirror).
    """
    from repro.core.reference import classical_gemm_flops, strassen_tn_flops

    if n_base is None or use_strassen is None:
        from repro.tune import plan as _plan_fn

        pl = _plan_fn(op="ata", m=m, n=n, dtype=dtype, devices=p, out=out)
        n_base = pl.n_base if n_base is None else n_base
        use_strassen = (
            (pl.algorithm != "dense") if use_strassen is None else use_strassen
        )
    if nb is None:
        nb, w = choose_tiling(n, p, out=out, packed_block=packed_block)
    else:
        w = -(-n // nb)
        w = -(-w // 8) * 8
    t_total = nb * (nb + 1) // 2
    t_per = -(-t_total // p)
    tile = (
        strassen_tn_flops(m, w, w, n_base)
        if use_strassen
        else classical_gemm_flops(m, w, w)
    )
    return [
        tile * max(0, min(t_per, t_total - d * t_per)) for d in range(p)
    ]


# ---------------------------------------------------------------------------
# colshard gemm: C = AᵀB with B column-sharded (disjoint C column stripes)
# ---------------------------------------------------------------------------


def gemm_tn_colshard(
    a: jax.Array,
    b: jax.Array,
    mesh: Mesh,
    *,
    task_axis: str = "model",
    row_axis: Optional[str] = None,
    plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    leaf_dispatch: Optional[str] = None,
    use_strassen: bool = True,
) -> jax.Array:
    """Distributed ``C = AᵀB``: each device owns C's column stripe for its
    B shard — the FastStrassen leaves of the task tree, collision-free.
    Leaf tunables (including ``leaf_dispatch`` — the per-device stripe
    product reuses the batched or fused leaf formulation when the plan
    picks it) resolve through the planner unless pinned."""
    m, n = a.shape
    mb, k = b.shape
    if m != mb:
        raise ValueError(f"contraction mismatch {a.shape} vs {b.shape}")
    p_task = mesh.shape[task_axis]
    if k % p_task:
        # the requirement runs device→columns: every device of the task
        # axis owns one equal column stripe of C.
        raise ValueError(
            f"task axis {task_axis!r} size {p_task} must divide k={k} "
            f"(B is column-sharded P(..., {task_axis!r}))"
        )
    if row_axis is not None:
        p_row = mesh.shape[row_axis]
        if m % p_row:
            # validated here, with the same orientation, instead of letting
            # shard_map fail opaquely on an indivisible in_spec.
            raise ValueError(
                f"row_axis {row_axis!r} size {p_row} must divide the "
                f"contraction dim m={m} (A and B are row-sharded "
                f"P({row_axis!r}, ...))"
            )
    if plan is not None:
        n_base = plan.n_base if n_base is None else n_base
        variant = plan.variant if variant is None else variant
        if leaf_dispatch is None:
            leaf_dispatch = getattr(plan, "leaf_dispatch", None)
        if plan.algorithm == "dense":
            use_strassen = False
    # unpinned n_base/variant fall through to strassen_tn, which self-plans
    # on the per-device leaf shape (m, n, k/p) — every dispatch is planned.

    obs.metrics.inc("dispatch.gemm_tn_colshard")

    def local_fn(a_local, b_local):
        with obs.span("distributed.colshard_body", use_strassen=use_strassen):
            if use_strassen:
                c_local = strassen_tn(
                    a_local, b_local, n_base=n_base, variant=variant,
                    leaf_dispatch=leaf_dispatch,
                )
            else:
                c_local = jax.lax.dot_general(
                    a_local, b_local, (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
        if row_axis is not None:
            with obs.span("distributed.psum", axis=row_axis, out="dense"):
                c_local = jax.lax.psum(c_local, row_axis)
        return c_local

    row_spec = row_axis if row_axis else None
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(row_spec, None), P(row_spec, task_axis)),
        out_specs=P(None, task_axis),
    )(a, b)
