"""Checkpointing: atomic, sharded, async-capable, keep-N, resumable.

Layout (one directory per step)::

    <dir>/step_000123/
        meta.json                  # step, tree structure, shapes, dtypes
        shard_00000.npz            # flat leaves (host's addressable shards)
        _COMMITTED                 # written last — presence marks validity

Production properties:

* **Atomicity** — writers stage into ``step_N.tmp`` and ``os.replace`` into
  place after fsync; the ``_COMMITTED`` marker is written last, so a crash
  mid-save never yields a checkpoint that ``latest_step`` would resume from.
* **Async save** — ``save(..., blocking=False)`` snapshots to host RAM
  (device_get) synchronously — a consistent cut — then writes in a
  background thread so the train loop keeps stepping (the next save joins
  the previous writer first).
* **Keep-N GC** — older committed checkpoints beyond ``keep`` are deleted
  after a successful commit.
* **Resume** — ``restore(step=None)`` loads the newest committed step.
  ``restore_sharded`` re-places leaves with a target sharding (elastic
  re-mesh: the on-disk format is mesh-agnostic full arrays per leaf).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None

    # -- paths --------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.dir, name)
                if os.path.exists(os.path.join(path, "_COMMITTED")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = True, extra: dict = None):
        """Checkpoint ``tree`` at ``step``. Non-blocking saves snapshot to
        host first (consistent), then write in the background."""
        self.wait()  # at most one in-flight writer
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        meta = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
            if hasattr(treedef, "serialize_using_proto")
            else None,
            "num_leaves": len(host_leaves),
            "extra": extra or {},
        }

        def _write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(
                os.path.join(tmp, "shard_00000.npz"),
                **{f"leaf_{i}": x for i, x in enumerate(host_leaves)},
            )
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._writer = threading.Thread(target=_write, daemon=True)
            self._writer.start()

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def restore(self, like: Any, step: Optional[int] = None):
        """Restore into the structure of ``like`` (shapes must match)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self._step_dir(step)
        data = np.load(os.path.join(path, "shard_00000.npz"))
        leaves, treedef = _flatten(like)
        restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
        for got, want in zip(restored, leaves):
            if tuple(got.shape) != tuple(np.shape(want)):
                raise ValueError(
                    f"checkpoint leaf shape {got.shape} != expected {np.shape(want)}"
                )
        out = jax.tree_util.tree_unflatten(treedef, restored)
        return out, step

    def restore_sharded(self, like: Any, shardings, step: Optional[int] = None):
        """Restore and place with target shardings (elastic re-mesh path)."""
        tree, step = self.restore(like, step)
        placed = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
        return placed, step

    def extra(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self._step_dir(step), "meta.json")) as f:
            return json.load(f).get("extra", {})
