"""``repro.serve`` — Gram-as-a-service: the batched solve server.

The fifth architectural layer (algorithms → planner → kernels → solvers →
**server**): a request front door that turns the repo's planned packed
normal-equations stack into a serving path. Heterogeneous ``lstsq`` /
``whiten`` requests are bucketed by **plan key** (exact feature dimension
``n``, banded row count ``m`` and RHS count ``r``, dtype — see
:mod:`repro.serve.bucketing`), micro-batched per bucket, and each flush
runs as ONE jitted batched launch whose per-request results are
bitwise-equal to per-request ``solve.lstsq`` under the same plan (the
parity contract of :mod:`repro.serve.bucketing`).

The serving economics the layer exists for: a cold request pays
trace + plan + XLA compile (hundreds of milliseconds); after
:meth:`Server.warm` every configured bucket's plan is resolved (one
plan-cache file read via ``tune.cache.warm``) and its callable compiled,
so a request pays a dictionary lookup plus one batched solve — and the
steady-state loop performs **zero retraces**, asserted per dispatch
against the jit compile-cache size, not hoped (``serve.retraces`` stays
0 or the engine raises).

Modules:

* :mod:`repro.serve.bucketing` — the bucket lattice, pad/crop rules, and
  the bitwise-parity contract.
* :mod:`repro.serve.queue` — bounded admission queue: deadline-aware
  admission, explicit reject-with-retry-after backpressure, and the
  max-wait/max-batch flush policy.
* :mod:`repro.serve.engine` — ``Server``: pre-warm pass, the batched
  bucket callables, the steady-state dispatch loop.
* :mod:`repro.serve.metrics` — ``serve.*`` counters/gauges into
  ``repro.obs`` plus p50/p95/p99 latency reservoirs.

Quickstart (DESIGN.md §10; ``python -m repro.serve --smoke`` is the CI
smoke):

    from repro import serve
    srv = serve.Server(serve.smoke_config())
    srv.warm()                                  # plans + XLA, off the request path
    t = srv.submit(serve.Request(op="lstsq", a=a, b=b))
    srv.drain()
    x = t.result()                              # == solve.lstsq(a, b, plan) bitwise
    print(serve.metrics.latency_summary())      # p50/p95/p99 per bucket
"""

from __future__ import annotations

from repro.serve import bucketing, metrics
from repro.serve.bucketing import BucketLattice, BucketSpec
from repro.serve.engine import Server, ServeConfig, smoke_config
from repro.serve.queue import FlushPolicy, Rejected, Request, Ticket

__all__ = [
    "bucketing",
    "metrics",
    "BucketLattice",
    "BucketSpec",
    "Server",
    "ServeConfig",
    "smoke_config",
    "FlushPolicy",
    "Rejected",
    "Request",
    "Ticket",
]
