"""The bucket lattice: plan-keyed request routing with pad/crop rules.

A bucket is one *compiled program identity*: every request routed to the
same :class:`BucketSpec` shares one solve plan, one jitted batched
callable, and one static operand shape — so a flush is ONE launch and a
request in steady state never retraces anything. The lattice is the map
from a heterogeneous request ``(op, m, n, r, dtype)`` to that identity.

Which axes band and which stay exact is not a free design choice — it is
dictated by the **bitwise-parity contract**: a bucketed result must equal
the per-request ``solve.lstsq`` answer bit for bit, or micro-batching
changes numerics under load (the one failure mode a serving layer must
never have). The rules, each established empirically against the packed
pipeline (see ``tests/test_serve.py``):

* ``n`` (features) is an **exact key, never padded**. ``n`` determines the
  packed block grid and the blocked Cholesky walk; padding it across a
  block boundary reorders the factorization's reductions (~1e-7 drift).
  A request whose ``n`` is not in the lattice is rejected, not resized.
* ``m`` (rows) **bands up with zero-row padding** — appended zero rows
  extend the gram's reduction without re-associating it, so the gram (and
  everything downstream) is bitwise unchanged. This holds for buckets
  whose gram is a single leaf (``n ≤ plan.n_base`` — the serving regime);
  a *recursing* gram splits ``m`` into slabs, padding moves the split, so
  recursing buckets carry ``exact_m=True`` and admit only ``m == spec.m``.
* ``r`` (right-hand sides) **bands up with zero-column padding** — each
  RHS column flows through the substitutions independently, so appended
  zero columns solve to zero columns and the crop is exact.
* ``dtype`` is an exact key (it is part of the plan key for the same
  reason it is part of the tune cache key).

The parity reference for a request ``(m, n, r)`` served by bucket ``spec``
is ``solve.lstsq(a, b, plan=request_twin(spec_plan, m, r))`` — the bucket's
solve plan re-shaped to the request (same ``n_base``/``packed_block``/
method, request ``m``/``k``). The engine's other half of the contract
(rank-2 per-slice diagonal substitution solves, always-added traced ridge,
replicate-a-real-request batch fill) lives in :mod:`repro.serve.engine`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

__all__ = [
    "OPS",
    "BucketSpec",
    "BucketLattice",
    "make_buckets",
    "pad_operands",
    "crop_result",
]

# request operations the server understands:
#   lstsq  — min ‖A·x − b‖² + ridge‖x‖²: a (m, n), b (m, r) → x (n, r)
#   whiten — L⁻¹·v with AᵀA = L·Lᵀ:      a (m, n), v (n, r) → z (n, r)
OPS = ("lstsq", "whiten")


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """One bucket: a compiled-program identity in the lattice.

    ``m``/``r`` are *capacities* (requests pad up to them); ``n`` is exact.
    ``batch`` is the static flush width B of the compiled callable.
    ``exact_m`` marks buckets whose gram recurses (``n > n_base``), where
    zero-row m-padding would move the recursion's row split and break the
    bitwise contract — those admit only ``m == spec.m``.
    """

    op: str
    m: int
    n: int
    r: int
    batch: int
    dtype: str = "float32"
    exact_m: bool = False

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown serve op {self.op!r}; use one of {OPS}")
        if self.m < self.n:
            raise ValueError(
                f"bucket m={self.m} < n={self.n}: the normal equations "
                "need a tall (or square) design matrix")
        if min(self.m, self.n, self.r, self.batch) < 1:
            raise ValueError(f"bucket dims must be positive, got {self}")

    @property
    def key(self) -> Tuple:
        """The routing identity (one compiled program per key)."""
        return (self.op, self.m, self.n, self.r, self.dtype)

    def label(self) -> str:
        """Stable metric/artifact label: ``lstsq:m96:n64:r8:float32:b4``."""
        tag = f"{self.op}:m{self.m}:n{self.n}:r{self.r}:{self.dtype}:b{self.batch}"
        return tag + (":exact_m" if self.exact_m else "")

    def admits(self, op: str, m: int, n: int, r: int, dtype: str) -> bool:
        """Can a ``(op, m, n, r, dtype)`` request be served by this bucket?"""
        if op != self.op or n != self.n or dtype != self.dtype:
            return False
        if self.exact_m:
            if m != self.m:
                return False
        elif m > self.m:
            return False
        return r <= self.r

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "BucketSpec":
        return cls(**d)


def make_buckets(
    *,
    ops: Sequence[str] = ("lstsq",),
    n_values: Sequence[int] = (64,),
    m_bands: Sequence[int] = (128,),
    r_bands: Sequence[int] = (8,),
    batch: int = 4,
    dtype: str = "float32",
    n_base: Optional[int] = None,
) -> Tuple[BucketSpec, ...]:
    """The cross-product lattice: one bucket per (op × n × m-band × r-band).

    ``n_base`` (default: the planner's ``DEFAULT_N_BASE``) decides which
    buckets recurse and therefore carry ``exact_m`` (see module docstring).
    """
    if n_base is None:
        from repro.tune.defaults import DEFAULT_N_BASE

        n_base = DEFAULT_N_BASE
    specs = []
    for op in ops:
        for n in n_values:
            for m in sorted(m_bands):
                if m < n:
                    continue
                for r in sorted(r_bands):
                    specs.append(BucketSpec(
                        op=op, m=m, n=n, r=r, batch=batch, dtype=dtype,
                        exact_m=n > n_base))
    if not specs:
        raise ValueError("empty bucket lattice (every m band below n?)")
    return tuple(specs)


class BucketLattice:
    """Routes requests to the smallest admitting bucket.

    "Smallest" means least padding: among admitting buckets the one with
    minimal ``(m, r)`` lexicographically — bands are nested by
    construction, so this is the tightest capacity fit.
    """

    def __init__(self, specs: Sequence[BucketSpec]):
        seen = set()
        for s in specs:
            if s.key in seen:
                raise ValueError(f"duplicate bucket key {s.key}")
            seen.add(s.key)
        self.specs: Tuple[BucketSpec, ...] = tuple(
            sorted(specs, key=lambda s: (s.op, s.n, s.dtype, s.m, s.r)))

    def __len__(self) -> int:
        return len(self.specs)

    def bucket_for(self, op: str, m: int, n: int, r: int,
                   dtype: str = "float32") -> Optional[BucketSpec]:
        """The tightest admitting bucket, or None (→ admission reject)."""
        for s in self.specs:          # sorted ascending (m, r) per group
            if s.admits(op, m, n, r, dtype):
                return s
        return None


def pad_operands(spec: BucketSpec, a, b):
    """Pad one request's operands to the bucket's static shape.

    ``a``: (m, n) → (spec.m, n) with zero rows (bitwise-transparent to the
    gram — the parity contract's m rule). ``b``: lstsq (m, r) →
    (spec.m, spec.r) with zero rows (they meet A's zero rows in Aᵀb) and
    zero columns; whiten (n, r) → (n, spec.r) with zero columns only (v
    lives in feature space — it has no row padding to do).

    Assembly is **numpy on purpose**: jnp padding/stacking would compile
    one XLA micro-op per distinct request shape on the hot path — the
    only compiled program a flush may touch is the bucket callable.
    """
    import numpy as np

    a = np.asarray(a)
    b = np.asarray(b)
    m, n = a.shape
    r = b.shape[-1]
    if n != spec.n or m > spec.m or r > spec.r:
        raise ValueError(
            f"request ({m}, {n}, r={r}) does not fit bucket {spec.label()}")
    a_pad = np.zeros((spec.m, spec.n), a.dtype)
    a_pad[:m] = a
    want_rows = spec.m if spec.op == "lstsq" else spec.n
    b_pad = np.zeros((want_rows, spec.r), b.dtype)
    b_pad[:b.shape[0], :r] = b
    return a_pad, b_pad


def crop_result(spec: BucketSpec, x, r: int):
    """Crop one bucketed result slice back to the request's RHS count.

    ``x``: (n, spec.r) → (n, r). The crop is exact by the parity
    contract: padded RHS columns are zero end-to-end, and ``n`` was never
    padded in the first place.
    """
    del spec
    return x[:, :r]
