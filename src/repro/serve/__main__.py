"""CLI: ``python -m repro.serve`` — warm, smoke, and workload replay.

    python -m repro.serve --warm             # pre-warm the smoke lattice
    python -m repro.serve --smoke            # the CI serve-smoke contract
    python -m repro.serve --replay spec.json # run a recorded workload

``--smoke`` is the CI gate: it warms the shared smoke lattice
(``engine.smoke_config``), replays a deterministic mixed workload
(``--requests``, default 100) of ragged lstsq/whiten shapes through the
queue, then **fails loudly** (nonzero exit) unless every contract holds:

* every admitted request completed (drain leaves nothing behind),
* zero steady-state retraces (``serve.retraces == 0``),
* a per-request bitwise parity spot-check against ``solve.lstsq`` under
  the request twin of the bucket plan,
* the obs snapshot validates and carries ``serve.*`` counters and the
  published percentile gauges.

A replay spec is JSON: ``{"seed": 0, "requests": [{"op", "m", "n", "r",
"ridge"?, "deadline_s"?}, ...], "buckets": [BucketSpec.to_json(), ...]?}``
— request *data* is generated from the seed (the spec records shapes and
knobs, not payloads). Omitted ``buckets`` means the smoke lattice.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _mixed_workload(n_requests: int, seed: int):
    """The deterministic smoke workload: ragged shapes spanning every
    smoke bucket, vector and matrix RHS, mixed ridges."""
    shapes = [
        # (op, m, n, r, ridge)  — r=0 means a 1-D (vector) rhs
        ("lstsq", 40, 32, 3, 0.0),
        ("lstsq", 48, 32, 4, 1e-3),
        ("lstsq", 90, 64, 8, 0.0),
        ("lstsq", 96, 64, 5, 1e-2),
        ("whiten", 48, 32, 4, 0.0),
        ("lstsq", 33, 32, 0, 0.0),
        ("lstsq", 64, 64, 2, 1e-3),
        ("whiten", 41, 32, 2, 1e-3),
    ]
    rng = np.random.default_rng(seed)
    for i in range(n_requests):
        op, m, n, r, ridge = shapes[i % len(shapes)]
        yield _make_request(rng, op, m, n, r, ridge)


def _make_request(rng, op, m, n, r, ridge, deadline_s=None, dtype="float32"):
    from repro.serve.queue import Request

    a = rng.standard_normal((m, n)).astype(dtype)
    rows = m if op == "lstsq" else n
    b = (rng.standard_normal((rows,)).astype(dtype) if r == 0
         else rng.standard_normal((rows, r)).astype(dtype))
    return Request(op=op, a=a, b=b, ridge=ridge, deadline_s=deadline_s)


def _parity_spot_check(server, served, sample_every=7):
    """Bitwise-compare a sample of served lstsq tickets against the
    per-request reference. Returns (checked, failures)."""
    from repro.solve import lstsq as solve_lstsq

    checked, failures = 0, []
    for i, ticket in enumerate(served):
        if ticket.request.op != "lstsq" or i % sample_every:
            continue
        req = ticket.request
        m = req.a.shape[0]
        r = 1 if req.b.ndim == 1 else req.b.shape[-1]
        twin = server.request_twin(ticket.bucket, m, r)
        ref = solve_lstsq(req.a, req.b, ridge=req.ridge, plan=twin)
        got = ticket.result()
        checked += 1
        if not (np.asarray(ref) == np.asarray(got)).all():
            failures.append(
                f"ticket {ticket.id} ({ticket.bucket.label()}, m={m}, r={r})"
                f" max|Δ|={np.abs(np.asarray(ref) - np.asarray(got)).max():.3e}")
    return checked, failures


def _run_workload(server, requests):
    """Submit every request; returns (served tickets, rejected count)."""
    from repro.serve.queue import Rejected

    served, rejected = [], 0
    for req in requests:
        try:
            served.append(server.submit(req))
        except Rejected:
            rejected += 1
    server.drain()
    return served, rejected


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Gram-as-a-service: plan-keyed micro-batching solve server.")
    ap.add_argument("--warm", action="store_true",
                    help="pre-warm the lattice (plans + XLA) and report")
    ap.add_argument("--smoke", action="store_true",
                    help="the CI contract: warm + mixed workload + checks")
    ap.add_argument("--replay", metavar="SPEC.json",
                    help="run a recorded workload spec")
    ap.add_argument("--requests", type=int, default=100,
                    help="smoke workload size (default 100)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", metavar="PATH",
                    help="write the serve report JSON here")
    args = ap.parse_args(argv)
    if not (args.warm or args.smoke or args.replay):
        ap.error("pick one of --warm / --smoke / --replay")

    from repro.obs import metrics as obs_metrics
    from repro.serve import metrics as serve_metrics
    from repro.serve.bucketing import BucketLattice, BucketSpec
    from repro.serve.engine import Server, smoke_config

    cfg = smoke_config()
    replay_spec = None
    if args.replay:
        with open(args.replay) as f:
            replay_spec = json.load(f)
        if replay_spec.get("buckets"):
            import dataclasses

            buckets = tuple(BucketSpec.from_json(d)
                            for d in replay_spec["buckets"])
            BucketLattice(buckets)  # validate before serving
            cfg = dataclasses.replace(cfg, buckets=buckets)

    server = Server(cfg)
    print(f"warming {len(cfg.buckets)} buckets ...", flush=True)
    warm_report = server.warm(verbose=True)
    print(f"warm total: {sum(warm_report.values()):.2f}s", flush=True)

    failures = []
    served = []
    rejected = 0
    parity_checked = 0
    if args.smoke:
        served, rejected = _run_workload(
            server, _mixed_workload(args.requests, args.seed))
        parity_checked, parity_failures = _parity_spot_check(server, served)
        failures += parity_failures
        if parity_checked == 0:
            failures.append("parity spot-check covered zero requests")
    elif args.replay:
        rng = np.random.default_rng(replay_spec.get("seed", args.seed))
        reqs = [
            _make_request(rng, d["op"], d["m"], d["n"], d.get("r", 1),
                          d.get("ridge", 0.0), d.get("deadline_s"),
                          d.get("dtype", "float32"))
            for d in replay_spec["requests"]
        ]
        served, rejected = _run_workload(server, reqs)

    if args.smoke or args.replay:
        not_done = [t.id for t in served if not t.done()]
        if not_done:
            failures.append(f"{len(not_done)} tickets never served: {not_done[:5]}")
        if server.retraces():
            failures.append(f"steady state retraced {server.retraces()} times")
        gauges = serve_metrics.publish_percentiles()
        try:
            snap = obs_metrics.validate_snapshot(obs_metrics.snapshot())
            if not any(k.startswith("serve.") for k in snap["counters"]):
                failures.append("obs snapshot carries no serve.* counters")
            if not any(k.startswith("serve.latency.") for k in snap["gauges"]):
                failures.append("obs snapshot carries no serve latency gauges")
        except ValueError as e:
            failures.append(f"obs snapshot invalid: {e}")
        summary = serve_metrics.percentiles("request") or {}
        print(f"served {len(served)} requests ({rejected} rejected), "
              f"{server.retraces()} retraces, parity {parity_checked} checked")
        if summary:
            print("request latency: "
                  + ", ".join(f"{k}={summary[k]*1e3:.2f}ms"
                              for k in ("p50", "p95", "p99")))
        del gauges

    if args.out:
        report = {
            "schema": "repro.serve/v1",
            "mode": ("smoke" if args.smoke else
                     "replay" if args.replay else "warm"),
            "warm_seconds": warm_report,
            "served": len(served),
            "rejected": rejected,
            "parity_checked": parity_checked,
            "failures": failures,
            "stats": server.stats(),
        }
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True, default=float)
        print(f"report written to {args.out}")

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print("serve smoke OK" if args.smoke else "ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
