"""Bounded admission queue with deadline-aware admission and flush policy.

Admission is where a serving layer earns its latency SLO: a request that
cannot be served in time must be **rejected at the door with a concrete
retry hint**, never silently queued into a blown deadline. Three reject
reasons, all explicit (:class:`Rejected` carries ``reason`` and
``retry_after_s``):

* ``no-bucket`` — the request's ``(op, m, n, r, dtype)`` maps to no
  configured bucket. Retrying is pointless (``retry_after_s=None``); the
  lattice is the server's published contract.
* ``capacity`` — the bounded queue is full. This is backpressure, not
  failure: ``retry_after_s`` is the flush policy's ``max_wait_s`` (by then
  at least one waiting batch must have flushed and freed depth).
* ``deadline`` — the request's budget is smaller than the worst-case wait
  it could see (``max_wait_s``, the policy's flush guarantee), so it could
  miss before ever launching. Rejecting up front costs one dictionary
  lookup; accepting would cost a full solve that nobody can use.

Flushing (:meth:`MicroBatchQueue.due`) follows the classic micro-batching
pair: a bucket flushes when it reaches its static batch width B
(**max-batch**: a full launch, zero padding waste) or when its oldest
request has waited ``max_wait_s`` (**max-wait**: bounded queueing latency,
the tail flushes ragged and the engine pads the empty slots). The queue
never launches anything itself — it only decides *what is due*; the
engine owns dispatch so the queue stays trivially testable with a fake
clock (every entry point takes ``now``).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Tuple

from repro.obs import metrics as _obs
from repro.serve.bucketing import BucketLattice, BucketSpec

__all__ = ["Request", "Ticket", "Rejected", "FlushPolicy", "MicroBatchQueue"]


@dataclasses.dataclass
class Request:
    """One inbound problem: ``lstsq`` (a (m,n), b (m,r)) or ``whiten``
    (a (m,n), v=b (n,r)). ``deadline_s`` is a relative latency budget in
    seconds from submission (None = no SLO). ``ridge`` is per-request —
    the engine traces it as a batched scalar, so mixing ridges inside one
    flush is free."""

    op: str
    a: Any
    b: Any
    ridge: float = 0.0
    deadline_s: Optional[float] = None

    def shape_key(self) -> Tuple[str, int, int, int, str]:
        m, n = self.a.shape
        r = 1 if self.b.ndim == 1 else self.b.shape[-1]
        return (self.op, m, n, r, str(self.a.dtype))


class Rejected(Exception):
    """Admission refusal. ``retry_after_s`` is the backpressure contract:
    a float means "resubmit after this many seconds"; None means the
    request can never be admitted as posed (no-bucket)."""

    def __init__(self, reason: str, retry_after_s: Optional[float] = None):
        self.reason = reason
        self.retry_after_s = retry_after_s
        hint = (f"; retry after {retry_after_s:.3f}s"
                if retry_after_s is not None else "")
        super().__init__(f"request rejected ({reason}){hint}")


_ticket_ids = itertools.count()


class Ticket:
    """The caller's handle for one admitted request."""

    def __init__(self, request: Request, bucket: BucketSpec, enqueued_at: float):
        self.id = next(_ticket_ids)
        self.request = request
        self.bucket = bucket
        self.enqueued_at = enqueued_at
        self.latency_s: Optional[float] = None   # submit → result, filled
        self.deadline_missed = False             # by the engine at completion
        self._result: Any = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def set_result(self, value: Any) -> None:
        self._result = value
        self._done = True

    def result(self) -> Any:
        if not self._done:
            raise RuntimeError(
                f"ticket {self.id} not served yet — pump() or drain() first")
        return self._result


@dataclasses.dataclass(frozen=True)
class FlushPolicy:
    """max-wait/max-batch: flush a bucket at its static batch width, or
    when its oldest request has waited ``max_wait_s`` — whichever first.
    ``max_wait_s`` is therefore both the queueing-latency bound and the
    capacity-reject retry hint."""

    max_wait_s: float = 0.010

    def __post_init__(self):
        if self.max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {self.max_wait_s}")


class MicroBatchQueue:
    """Per-bucket FIFO lanes behind one bounded total depth."""

    def __init__(self, lattice: BucketLattice, *, capacity: int = 256,
                 policy: Optional[FlushPolicy] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.lattice = lattice
        self.capacity = capacity
        self.policy = policy or FlushPolicy()
        self._lanes: Dict[BucketSpec, List[Ticket]] = {}
        self._depth = 0

    # -- admission -----------------------------------------------------------

    def offer(self, request: Request, now: float) -> Ticket:
        """Admit or raise :class:`Rejected` (see module docstring)."""
        spec = self.lattice.bucket_for(*request.shape_key())
        if spec is None:
            _obs.inc("serve.requests.rejected.no-bucket")
            raise Rejected("no-bucket", retry_after_s=None)
        if (request.deadline_s is not None
                and request.deadline_s < self.policy.max_wait_s):
            _obs.inc("serve.requests.rejected.deadline")
            raise Rejected("deadline", retry_after_s=None)
        if self._depth >= self.capacity:
            _obs.inc("serve.requests.rejected.capacity")
            raise Rejected("capacity", retry_after_s=self.policy.max_wait_s)
        ticket = Ticket(request, spec, enqueued_at=now)
        self._lanes.setdefault(spec, []).append(ticket)
        self._depth += 1
        _obs.inc("serve.requests.accepted")
        _obs.set_gauge("serve.queue.depth", self._depth)
        return ticket

    # -- flush selection -----------------------------------------------------

    def due(self, now: float, *, force: bool = False
            ) -> List[Tuple[BucketSpec, List[Ticket]]]:
        """Pop every flushable batch: full lanes always; aged (or, with
        ``force``, all nonempty) lanes ragged. Each batch is at most the
        bucket's static width B, FIFO within its lane."""
        batches = []
        for spec in list(self._lanes):
            lane = self._lanes[spec]
            while lane:
                full = len(lane) >= spec.batch
                aged = now - lane[0].enqueued_at >= self.policy.max_wait_s
                if not (full or aged or force):
                    break
                take = lane[:spec.batch]
                del lane[:spec.batch]
                self._depth -= len(take)
                batches.append((spec, take))
            if not lane:
                del self._lanes[spec]
        if batches:
            _obs.set_gauge("serve.queue.depth", self._depth)
        return batches

    # -- introspection -------------------------------------------------------

    def depth(self) -> int:
        return self._depth

    def lane_depths(self) -> Dict[str, int]:
        return {spec.label(): len(lane) for spec, lane in self._lanes.items()}
