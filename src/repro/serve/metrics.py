"""``serve.*`` telemetry: counters/gauges into ``repro.obs`` plus latency
percentiles.

Everything countable rides the always-on ``repro.obs.metrics`` registry
under the ``serve.`` prefix (so the CI obs snapshot carries the serving
story with zero extra plumbing):

    serve.requests.accepted / .rejected.<reason> / .completed
    serve.flushes / serve.flushes.ragged
    serve.padded_slots          replicated fill slots across all flushes
    serve.padded_rows           zero rows added by m-banding
    serve.padded_cols           zero cols added by r-banding
    serve.retraces              steady-state retrace count (MUST stay 0)
    serve.deadline_missed       completed after their deadline
    serve.queue.depth           gauge: pending requests right now
    serve.latency.request       histogram: submit→result seconds
    serve.latency.dispatch      histogram: flush launch seconds

The obs registry's histograms carry count/sum/min/max only — enough for
means, useless for SLOs — so this module adds the missing half: a bounded
reservoir per series (last ``RESERVOIR_SIZE`` samples) from which
:func:`percentile` computes p50/p95/p99 by linear interpolation.
:func:`publish_percentiles` folds them into the obs registry as gauges
(``serve.latency.request.p95`` …), which is how they reach the snapshot
the CLI/bench validate.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional

from repro.obs import metrics as _obs

__all__ = [
    "RESERVOIR_SIZE",
    "record_latency",
    "percentile",
    "percentiles",
    "latency_summary",
    "publish_percentiles",
    "samples",
    "reset",
]

# per-series sample bound: at serving rates the tail of the last 4096
# requests is the SLO window that matters; memory stays O(pages), and the
# reservoir can never grow with uptime.
RESERVOIR_SIZE = 4096

_LOCK = threading.Lock()
_RES: Dict[str, deque] = {}

# the percentile set every summary/gauge publication reports
_PCTS = (50.0, 95.0, 99.0)


def record_latency(series: str, seconds: float) -> None:
    """One latency sample: obs histogram + the local percentile reservoir.

    ``series`` is the suffix under ``serve.latency.`` — e.g. ``request``,
    ``dispatch``, or a per-bucket ``request.lstsq:m96:n64:r8:float32:b4``.
    """
    name = f"serve.latency.{series}"
    _obs.observe(name, seconds)
    with _LOCK:
        res = _RES.get(name)
        if res is None:
            res = _RES[name] = deque(maxlen=RESERVOIR_SIZE)
        res.append(float(seconds))


def samples(series: str) -> List[float]:
    with _LOCK:
        return list(_RES.get(f"serve.latency.{series}", ()))


def percentile(values: List[float], p: float) -> float:
    """Linear-interpolation percentile of ``values`` (p in [0, 100])."""
    if not values:
        return float("nan")
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    rank = (p / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def percentiles(series: str) -> Optional[Dict[str, float]]:
    """{'p50': …, 'p95': …, 'p99': …, 'count': N, 'mean': …} or None."""
    vals = samples(series)
    if not vals:
        return None
    out = {f"p{int(p)}": percentile(vals, p) for p in _PCTS}
    out["count"] = len(vals)
    out["mean"] = sum(vals) / len(vals)
    return out


def latency_summary() -> Dict[str, Dict[str, float]]:
    """Every tracked series → its percentile summary."""
    with _LOCK:
        names = list(_RES)
    prefix = "serve.latency."
    return {
        name[len(prefix):]: p
        for name in names
        if (p := percentiles(name[len(prefix):])) is not None
    }


def publish_percentiles() -> Dict[str, float]:
    """Fold current percentiles into the obs registry as gauges
    (``serve.latency.<series>.p95`` …) so they land in the snapshot the
    CLI and bench validate; returns the published {gauge: value} map."""
    published = {}
    for series, summary in latency_summary().items():
        for key in ("p50", "p95", "p99"):
            gauge = f"serve.latency.{series}.{key}"
            _obs.set_gauge(gauge, summary[key])
            published[gauge] = summary[key]
    return published


def reset() -> None:
    """Clear the local reservoirs (tests). The obs registry has its own
    ``reset`` — serving counters live there, not here."""
    with _LOCK:
        _RES.clear()
