"""The serve engine: pre-warmed, retrace-free batched bucket dispatch.

One :class:`Server` owns the lattice, the queue, and one jitted **batched
bucket callable** per :class:`BucketSpec`. The callable is the packed
normal-equations pipeline of ``solve.lstsq`` lifted to a leading batch
dim, composed so that each request slice is **bitwise-equal** to the
per-request ``solve.lstsq`` answer under the request-shaped twin of the
bucket plan (``tests/test_serve.py`` holds the property suite):

    a32  = a.astype(f32)                       # lstsq's own cast
    gram = ata_batched(a32, plan=⟨bucket ata plan, batch=B⟩, out='packed')
    gram = gram.add_scaled_identity(ridge[:, None, None, None])
    rhs  = AᵀB via one batched dot_general (f32 accumulation)
    L    = cholesky(gram, plan=sp)             # packed blocked walk
    x    = solve_cholesky(L, rhs, base_trsm=per_slice_trsm)

Two deliberate choices carry the bitwise contract:

* :func:`per_slice_trsm` — the substitution's diagonal-tile solves loop
  over the batch with **rank-2** ``triangular_solve`` calls. XLA's rank-3
  (batched) triangular-solve lowering differs from rank-2 in the last
  bits; every other stage of the pipeline is batch-invariant, so this one
  substitution detail is the whole gap between "close" and "bitwise".
  (The Cholesky walk itself needs no such treatment: its base calls are
  always rank-3 via ``_flat_call``, identically in both paths.)
* ridge is a **traced** per-slice vector, always added. Mixing ridges in
  one flush costs nothing, ridge changes never retrace, and adding 0.0
  on the gram diagonal is bitwise-transparent (verified — gram diagonals
  are sums of squares, never −0.0).

Ragged tails fill their empty slots by **replicating the first real
request** — zero-filled slots would feed a singular gram to the factor.
Fill slots are compiled work, counted (``serve.padded_slots``) and
cropped, never returned.

**The zero-retrace contract is asserted, not hoped**: after
:meth:`Server.warm` the engine snapshots each callable's jit cache size
(1), and every dispatch re-reads it. Growth means a request managed to
retrace on the hot path — the engine increments ``serve.retraces`` and
(by default) raises. Static bucket shapes + traced ridge make this
impossible by construction; the assertion keeps it impossible under
refactoring.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs import metrics as _obs
from repro.serve import metrics as serve_metrics
from repro.serve.bucketing import (
    BucketLattice,
    BucketSpec,
    crop_result,
    make_buckets,
    pad_operands,
)
from repro.serve.queue import FlushPolicy, MicroBatchQueue, Request, Ticket

__all__ = ["ServeConfig", "Server", "smoke_config", "per_slice_trsm",
           "serve_abstract_args"]


def per_slice_trsm(l, c, *, transpose: bool):
    """Diagonal-tile substitution solves, one rank-2 call per batch slice.

    The parity-critical base engine (see module docstring): rank-3
    ``triangular_solve`` lowers differently from rank-2 in the last bits,
    so the batched pipeline loops the batch here — B is the (small) flush
    width, so the unrolled loop is B extra tiny solves per block, not a
    scaling concern.
    """
    import jax
    import jax.numpy as jnp

    def solve2(l2, c2):
        return jax.lax.linalg.triangular_solve(
            l2, c2, left_side=True, lower=True, transpose_a=transpose)

    if l.ndim == 2:
        return solve2(l, c)
    if l.ndim != 3:
        raise ValueError(f"per_slice_trsm expects (B, bn, bn), got {l.shape}")
    return jnp.stack([solve2(l[i], c[i]) for i in range(l.shape[0])], 0)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The server's published contract: which buckets exist and how the
    queue behaves. ``packed_block``/``n_base`` override the planner's
    choice uniformly (the check harness uses this to force a real block
    grid); ``strict_retrace=False`` downgrades the zero-retrace assertion
    to a counter (never in production — tests only)."""

    buckets: Tuple[BucketSpec, ...]
    capacity: int = 256
    max_wait_s: float = 0.010
    cache_file: Optional[str] = None
    packed_block: Optional[int] = None
    n_base: Optional[int] = None
    strict_retrace: bool = True


def smoke_config(**overrides) -> ServeConfig:
    """The CI-scale config: a small mixed lattice every tool shares —
    the CLI ``--smoke``, ``bench_serve``, and the check harness all serve
    exactly these buckets, so "the smoke grid" means one thing."""
    buckets = (
        make_buckets(ops=("lstsq",), n_values=(32, 64), m_bands=(48, 96),
                     r_bands=(4, 8), batch=4)
        + make_buckets(ops=("whiten",), n_values=(32,), m_bands=(48,),
                       r_bands=(4,), batch=4)
    )
    kw = dict(buckets=buckets, capacity=64, max_wait_s=0.005)
    kw.update(overrides)
    return ServeConfig(**kw)


def serve_abstract_args(spec: BucketSpec) -> tuple:
    """Abstract (a, b, ridge) matching the bucket callable's signature —
    what the check harness traces and the engine warms on."""
    import jax

    b_rows = spec.m if spec.op == "lstsq" else spec.n
    return (
        jax.ShapeDtypeStruct((spec.batch, spec.m, spec.n), spec.dtype),
        jax.ShapeDtypeStruct((spec.batch, b_rows, spec.r), spec.dtype),
        jax.ShapeDtypeStruct((spec.batch,), "float32"),
    )


class Server:
    """Gram-as-a-service: submit → bucket → micro-batch → one launch."""

    def __init__(self, config: ServeConfig, *,
                 clock: Callable[[], float] = time.perf_counter):
        self.config = config
        self.clock = clock
        self.lattice = BucketLattice(config.buckets)
        self.queue = MicroBatchQueue(
            self.lattice, capacity=config.capacity,
            policy=FlushPolicy(max_wait_s=config.max_wait_s))
        self._plans: Dict[BucketSpec, object] = {}
        self._fns: Dict[BucketSpec, Callable] = {}
        # jit-cache size after warm (or first cold dispatch); any growth
        # past this is a hot-path retrace — the asserted contract
        self._trace_floor: Dict[BucketSpec, int] = {}
        self._warm_s: Dict[BucketSpec, float] = {}
        self.warmed = False

    # -- plan + callable construction ---------------------------------------

    def bucket_plan(self, spec: BucketSpec):
        """The bucket's (unbatched) solve plan — planner-resolved, pinned
        to the factor method (the batched pipeline IS the factor path; a
        cg plan would break the parity contract's reference)."""
        plan = self._plans.get(spec)
        if plan is None:
            from repro import tune

            sp = tune.plan(op="solve", m=spec.m, n=spec.n, k=spec.r,
                           dtype=spec.dtype, out="packed",
                           cache_file=self.config.cache_file)
            repl = {"method": "factor", "predicted_s": None}
            if self.config.packed_block is not None:
                repl["packed_block"] = self.config.packed_block
            if self.config.n_base is not None:
                repl["n_base"] = self.config.n_base
            plan = dataclasses.replace(sp, **repl)
            self._plans[spec] = plan
        return plan

    def request_twin(self, spec: BucketSpec, m: int, r: int):
        """The parity reference's plan: the bucket plan re-shaped to one
        request — what per-request ``solve.lstsq`` must be called with to
        reproduce a bucketed slice bit for bit."""
        return dataclasses.replace(self.bucket_plan(spec), m=m, k=r)

    def bucket_callable(self, spec: BucketSpec) -> Tuple[Callable, object]:
        """(jitted batched callable, unbatched solve plan) for one bucket."""
        fn = self._fns.get(spec)
        sp = self.bucket_plan(spec)
        if fn is None:
            fn = _build_bucket_fn(spec, sp)
            self._fns[spec] = fn
        return fn, sp

    # -- pre-warm ------------------------------------------------------------

    def warm(self, *, verbose: bool = False) -> Dict[str, float]:
        """Populate the plan cache AND compile every bucket, off the
        request path: one bulk plan-cache read (``tune.cache.warm``), then
        one dummy execution per bucket to drive XLA compilation. Returns
        {bucket label: warm seconds}; afterwards the zero-retrace floor is
        armed for every bucket."""
        import numpy as np

        from repro.tune import cache as tune_cache

        # ONE cache-file read resolves every bucket's plan key into the
        # planner memo; the per-bucket plan() calls below are memo hits.
        tune_cache.warm(
            [dict(op="solve", m=s.m, n=s.n, k=s.r, dtype=s.dtype,
                  out="packed") for s in self.config.buckets],
            cache_file=self.config.cache_file)

        report = {}
        for spec in self.config.buckets:
            fn, _sp = self.bucket_callable(spec)
            # a well-conditioned dummy: eye(m, n) has full column rank, so
            # the factor path compiles against a non-singular gram. Numpy
            # operands ON PURPOSE — dispatch feeds numpy-assembled batches,
            # and jit caches committed (device) and uncommitted (numpy)
            # inputs as distinct entries; warming with jnp arrays would
            # leave the first real request to "retrace" the numpy entry.
            a = np.broadcast_to(
                np.eye(spec.m, spec.n, dtype=spec.dtype),
                (spec.batch, spec.m, spec.n))
            b_rows = spec.m if spec.op == "lstsq" else spec.n
            b = np.zeros((spec.batch, b_rows, spec.r), spec.dtype)
            ridge = np.zeros((spec.batch,), np.float32)
            t0 = self.clock()
            fn(a, b, ridge).block_until_ready()
            dt = self.clock() - t0
            self._trace_floor[spec] = _jit_cache_size(fn)
            self._warm_s[spec] = dt
            _obs.observe("serve.warm.seconds", dt)
            report[spec.label()] = dt
            if verbose:
                print(f"  warmed {spec.label()} in {dt:.3f}s", flush=True)
        self.warmed = True
        return report

    # -- request path --------------------------------------------------------

    def submit(self, request: Request) -> Ticket:
        """Admit one request (may raise :class:`Rejected`) and dispatch any
        bucket its arrival filled."""
        now = self.clock()
        ticket = self.queue.offer(request, now)
        self.pump()
        return ticket

    def pump(self, *, force: bool = False) -> int:
        """Dispatch every due batch; returns the number of flushes."""
        batches = self.queue.due(self.clock(), force=force)
        for spec, tickets in batches:
            self._dispatch(spec, tickets)
        return len(batches)

    def drain(self) -> None:
        """Force-flush until the queue is empty (every ticket resolved)."""
        while self.queue.depth():
            self.pump(force=True)

    # -- the flush -----------------------------------------------------------

    def _dispatch(self, spec: BucketSpec, tickets: List[Ticket]) -> None:
        # batch assembly is NUMPY end to end (see pad_operands): every jnp
        # micro-op here — pad, stack, slice — would XLA-compile once per
        # distinct request-shape signature, and those ~100ms compiles were
        # the entire workload tail. The only compiled program a flush runs
        # is the bucket callable; zero-padding in numpy is the same bits.
        import numpy as np

        fn, _sp = self.bucket_callable(spec)
        a_slices, b_slices, ridges, vectors = [], [], [], []
        pad_rows = pad_cols = 0
        for t in tickets:
            req = t.request
            b_np = np.asarray(req.b)
            vec = b_np.ndim == 1
            vectors.append(vec)
            b2 = b_np[:, None] if vec else b_np
            m, r = req.a.shape[0], b2.shape[-1]
            a_pad, b_pad = pad_operands(spec, req.a, b2)
            a_slices.append(a_pad)
            b_slices.append(b_pad)
            ridges.append(float(req.ridge))
            pad_rows += spec.m - m
            pad_cols += spec.r - r
        fill = spec.batch - len(tickets)
        if fill:
            # replicate a REAL request into the empty slots: a zero design
            # matrix would hand the factor a singular gram. Fill slices are
            # compiled work, never returned.
            a_slices += [a_slices[0]] * fill
            b_slices += [b_slices[0]] * fill
            ridges += [ridges[0]] * fill
            _obs.inc("serve.padded_slots", fill)
            _obs.inc("serve.flushes.ragged")
        _obs.inc("serve.flushes")
        _obs.inc("serve.padded_rows", pad_rows)
        _obs.inc("serve.padded_cols", pad_cols)

        a_stk = np.stack(a_slices, 0)
        b_stk = np.stack(b_slices, 0)
        ridge = np.asarray(ridges, np.float32)

        t0 = self.clock()
        out = fn(a_stk, b_stk, ridge)
        out.block_until_ready()
        serve_metrics.record_latency("dispatch", self.clock() - t0)

        self._assert_no_retrace(spec, fn)

        # one device→host transfer; per-ticket crops are then numpy views
        out_np = np.asarray(out)
        done_at = self.clock()
        for i, t in enumerate(tickets):
            r = 1 if vectors[i] else t.request.b.shape[-1]
            x = crop_result(spec, out_np[i], r)
            t.set_result(x[:, 0] if vectors[i] else x)
            t.latency_s = done_at - t.enqueued_at
            serve_metrics.record_latency("request", t.latency_s)
            serve_metrics.record_latency(f"request.{spec.label()}",
                                         t.latency_s)
            dl = t.request.deadline_s
            if dl is not None and t.latency_s > dl:
                t.deadline_missed = True
                _obs.inc("serve.deadline_missed")
            _obs.inc("serve.requests.completed")

    def _assert_no_retrace(self, spec: BucketSpec, fn) -> None:
        size = _jit_cache_size(fn)
        floor = self._trace_floor.get(spec)
        if floor is None:
            # cold dispatch (no warm pass): the first flush compiles by
            # design; it sets the floor the steady state is held to.
            self._trace_floor[spec] = size
            return
        if size > floor:
            grew = size - floor
            self._trace_floor[spec] = size
            _obs.inc("serve.retraces", grew)
            if self.config.strict_retrace:
                raise RuntimeError(
                    f"bucket {spec.label()} retraced on the request path "
                    f"(jit cache {floor} -> {size}); the zero-retrace "
                    "contract is broken")

    # -- introspection -------------------------------------------------------

    def retraces(self) -> int:
        return _obs.get("serve.retraces")

    def stats(self) -> dict:
        """One JSON-serializable serving snapshot."""
        return {
            "buckets": [s.label() for s in self.config.buckets],
            "warmed": self.warmed,
            "warm_seconds": {s.label(): t for s, t in self._warm_s.items()},
            "queue_depth": self.queue.depth(),
            "lane_depths": self.queue.lane_depths(),
            "counters": _obs.counters("serve."),
            "latency": serve_metrics.latency_summary(),
        }


def _jit_cache_size(fn) -> int:
    return int(fn._cache_size())


def _build_bucket_fn(spec: BucketSpec, sp):
    """The jitted batched pipeline for one bucket (module docstring)."""
    import jax
    import jax.numpy as jnp

    from repro.core.ata import ata_batched
    from repro.solve.cholesky import cholesky
    from repro.solve.triangular import solve_cholesky, solve_triangular

    # the gram plan of the batched pipeline — exactly lstsq's derivation
    # (op='ata', k=n, packed, method/predicted cleared) plus the batch dim
    ata_plan = dataclasses.replace(
        sp, op="ata", k=sp.n, out="packed", method=None, predicted_s=None,
        batch=spec.batch)

    def run(a, b, ridge):
        a32 = a.astype(jnp.float32)
        b32 = b.astype(jnp.float32)
        gram = ata_batched(a32, plan=ata_plan, out="packed",
                           packed_block=sp.packed_block)
        gram = gram.add_scaled_identity(ridge.reshape(-1, 1, 1, 1))
        f = cholesky(gram, plan=sp)
        if spec.op == "lstsq":
            # AᵀB batched, f32 accumulation — the batched twin of lstsq's
            # _dot_tn (Aᵀ never formed)
            rhs = jax.lax.dot_general(
                a32, b32, (((1,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)
            return solve_cholesky(f, rhs, plan=sp, base_trsm=per_slice_trsm)
        # whiten: z = L⁻¹·v — forward substitution only
        return solve_triangular(f, b32, transpose=False, plan=sp,
                                base_trsm=per_slice_trsm)

    return jax.jit(run)
