"""Fault tolerance: preemption-safe training, heartbeats, straggler notes.

What runs here (single-host container, multi-host by design):

* :class:`PreemptionGuard` — installs SIGTERM/SIGINT handlers that flip a
  flag; the train loop checks it each step and triggers an emergency
  checkpoint + clean exit (maps to TPU preemption notices / maintenance
  events in production).
* :class:`Heartbeat` — a background thread that stamps a file every few
  seconds; an external supervisor (or the launcher's watchdog) restarts the
  job when the stamp goes stale. On multi-host JAX, the stamp includes the
  process index so a coordinator can identify the dead host.
* :func:`run_with_restarts` — in-process supervisor used by tests and the
  example driver: runs a step loop, catches crashes, restores from the last
  committed checkpoint, and resumes. Combined with the step-indexed data
  pipeline this gives *bitwise identical* resume (verified in tests).

Straggler mitigation (design, documented for the 1000+-node target):
SPMD lockstep means a slow chip stalls the psum ring; mitigations wired
into this framework:
  1. the launcher's watchdog marks hosts whose heartbeat lags > T and
     triggers an elastic re-mesh (drop the slice, `runtime/elastic.py`
     reshards the last checkpoint onto the surviving topology);
  2. checkpoint cadence bounds lost work to `save_every` steps;
  3. data is step-indexed, so no pipeline state needs recovery, and
     "skip-ahead" after re-mesh is a counter bump.
"""

from __future__ import annotations

import os
import signal
import threading
import time
from typing import Callable, Optional

__all__ = ["PreemptionGuard", "Heartbeat", "run_with_restarts"]


class PreemptionGuard:
    """Flip-on-signal flag checked by the train loop."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = threading.Event()
        self._prev = {}
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self._requested.set()

    @property
    def preempted(self) -> bool:
        return self._requested.is_set()

    def request(self):  # testable without raising signals
        self._requested.set()

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


class Heartbeat:
    def __init__(self, path: str, interval: float = 5.0, process_index: int = 0):
        self.path = path
        self.interval = interval
        self.process_index = process_index
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def _run(self):
        while not self._stop.is_set():
            with open(self.path, "w") as f:
                f.write(f"{self.process_index} {time.time()}")
            self._stop.wait(self.interval)

    def stop(self):
        self._stop.set()

    @staticmethod
    def is_stale(path: str, timeout: float) -> bool:
        try:
            with open(path) as f:
                _, ts = f.read().split()
            return (time.time() - float(ts)) > timeout
        except (OSError, ValueError):
            return True


def run_with_restarts(
    make_state: Callable[[], tuple],
    step_fn: Callable,
    ckpt,
    total_steps: int,
    save_every: int = 10,
    max_restarts: int = 3,
    inject_crash_at: Optional[int] = None,
):
    """In-process restart supervisor (test/example harness).

    ``make_state() -> (state, start_step)`` builds fresh state and restores
    from ``ckpt`` when a committed checkpoint exists. ``step_fn(state, step)
    -> state`` runs one step and may raise. Crashes trigger restore+resume.
    """
    restarts = 0
    crashed_once = False
    while True:
        state, start = make_state()
        try:
            for step in range(start, total_steps):
                if inject_crash_at is not None and step == inject_crash_at and not crashed_once:
                    crashed_once = True
                    raise RuntimeError(f"injected failure at step {step}")
                state = step_fn(state, step)
                if (step + 1) % save_every == 0 or step + 1 == total_steps:
                    ckpt.save(step + 1, state, blocking=True)
            return state, restarts
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
