"""Elastic scaling: re-mesh a run onto a different device topology.

The checkpoint format stores full (unsharded) arrays per leaf, so elastic
re-scale is a *placement* problem, not a data transformation:

    1. survivors agree on the new mesh shape (drop a pod / halve the data
       axis / grow after repair);
    2. sharding rules are re-derived for the new mesh (they are functions
       of the mesh, see ``parallel/sharding.py``);
    3. ``CheckpointManager.restore_sharded`` re-places every leaf with the
       new NamedShardings.

Global batch is kept constant across re-meshes by adjusting the
gradient-accumulation microbatch count (``microbatches_for``), so training
curves are unaffected by topology changes — the production-standard
"constant-batch elasticity".
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

__all__ = ["remesh_plan", "microbatches_for", "reshard_tree"]


def remesh_plan(
    n_devices: int, prefer_model: int = 16
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Choose a (data, model) mesh for an arbitrary surviving device count.

    Keeps the model axis at the largest power-of-two divisor ≤ prefer_model
    (TP degree should shrink last — it is baked into layout choices)."""
    model = 1
    while model * 2 <= prefer_model and n_devices % (model * 2) == 0:
        model *= 2
    data = n_devices // model
    return (data, model), ("data", "model")


def microbatches_for(global_batch: int, per_device_batch: int, n_data: int) -> int:
    """Microbatch count that keeps global batch constant on a new topology."""
    per_step = per_device_batch * n_data
    if global_batch % per_step:
        raise ValueError(
            f"global batch {global_batch} not divisible by {per_step} "
            f"(= {per_device_batch} × {n_data} data shards)"
        )
    return global_batch // per_step


def reshard_tree(tree, mesh: Mesh, spec_tree):
    """Place a host tree onto a mesh with a PartitionSpec tree."""
    from repro.parallel.sharding import named

    shardings = named(mesh, spec_tree)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
