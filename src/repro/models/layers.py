"""Shared transformer layers: norms, RoPE, GQA attention (flash-style
chunked for long sequences), gated MLPs.

Everything is plain functional JAX over param dicts, designed to be
scanned over stacked layer params and partitioned by GSPMD from the rules
in ``repro.parallel.sharding``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map

__all__ = [
    "rms_norm",
    "rope",
    "attention_train",
    "attention_decode",
    "mlp_gated",
    "init_attn",
    "init_mlp",
]

# flash-attention block sizes (pure-JAX chunked attention; on a real TPU a
# splash/pallas kernel would slot in here — the math is identical)
Q_BLOCK = 2048
KV_BLOCK = 1024


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dtype)


def _rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple:
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embeddings. x: (..., S, H, D); positions: (..., S)."""
    half = x.shape[-1] // 2
    cos, sin = _rope_angles(positions, x.shape[-1], theta)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attn(key, cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h, hd), jnp.float32) * scale,
        "wk": jax.random.normal(ks[1], (d, kv, hd), jnp.float32) * scale,
        "wv": jax.random.normal(ks[2], (d, kv, hd), jnp.float32) * scale,
        "wo": jax.random.normal(ks[3], (h, hd, d), jnp.float32) * (h * hd) ** -0.5,
        "norm": jnp.zeros((d,), jnp.float32),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
    return p


def _project_qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _flash_body(q_blk, k, v, q_pos, kv_pos, window, scale, groups, unroll=False):
    """Attend one query block against all KV blocks with running softmax.

    q_blk: (B, Qb, H, D); k/v: (B, S, KV, D). Returns (B, Qb, H, D).
    Chunked over KV with f32 running (max, denom, acc) — the flash
    recurrence — so the (S × S) score matrix is never materialized.
    """
    b, qb, h, hd = q_blk.shape
    s = k.shape[1]
    n_kv = -(-s // KV_BLOCK)
    s_pad = n_kv * KV_BLOCK
    if s_pad > s:
        k = jnp.pad(k, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        # padded slots get position +inf so the causal test (q_pos >= kv_pos)
        # masks them for every real query
        kv_pos = jnp.pad(kv_pos, (0, s_pad - s), constant_values=10**9)
    k = k.reshape(b, n_kv, KV_BLOCK, k.shape[2], hd)
    v = v.reshape(b, n_kv, KV_BLOCK, v.shape[2], hd)
    kv_pos = kv_pos.reshape(n_kv, KV_BLOCK)

    def step(carry, inp):
        m_i, l_i, acc = carry
        k_c, v_c, pos_c = inp  # (B, C, KV, D), (C,)
        k_c = jnp.repeat(k_c, groups, axis=2)  # GQA: expand kv heads
        v_c = jnp.repeat(v_c, groups, axis=2)
        scores = jnp.einsum("bqhd,bchd->bhqc", q_blk, k_c).astype(jnp.float32)
        scores = scores * scale
        causal = q_pos[:, None] >= pos_c[None, :]          # (Qb, C)
        if window is not None:
            causal &= (q_pos[:, None] - pos_c[None, :]) < window
        scores = jnp.where(causal[None, None], scores, -1e30)
        m_new = jnp.maximum(m_i, scores.max(axis=-1))       # (B,H,Qb)
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p.astype(v_c.dtype), v_c
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    # remat the KV step: flash backward recomputes the (B,H,Qb,C) score/
    # probability blocks rather than saving S²-worth of them — this IS the
    # flash-attention memory property on the backward pass.
    step = jax.checkpoint(step)

    m0 = jnp.full((b, h, qb), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, qb), jnp.float32)
    acc0 = jnp.zeros((b, h, qb, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, acc0),
        (k.swapaxes(0, 1), v.swapaxes(0, 1), kv_pos),
        unroll=unroll,
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.swapaxes(1, 2).astype(q_blk.dtype)  # (B, Qb, H, D)


def attention_train(
    p: dict,
    x: jax.Array,
    cfg,
    *,
    window: Optional[int] = None,
    return_kv: bool = False,
    unroll: bool = False,
):
    """Causal (optionally sliding-window) self-attention, flash-chunked.

    x: (B, S, D) → (B, S, D). Never materializes S×S scores; used for both
    train and prefill. With ``return_kv`` also returns the roped (k, v)
    (B, S, KV, D) for prefill cache construction.
    """
    b, s, d = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions[None, :])
    groups = cfg.num_heads // cfg.num_kv_heads
    scale = cfg.head_dim ** -0.5

    n_q = -(-s // Q_BLOCK)
    s_pad = n_q * Q_BLOCK
    if s_pad > s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    qb = q.reshape(b, n_q, s_pad // n_q, cfg.num_heads, cfg.head_dim)
    q_pos = jnp.arange(s_pad).reshape(n_q, -1)

    def q_step(_, inp):
        q_c, pos_c = inp
        out = _flash_body(q_c, k, v, pos_c, positions, window, scale, groups,
                          unroll=unroll)
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qb.swapaxes(0, 1), q_pos),
                           unroll=unroll)
    out = outs.swapaxes(0, 1).reshape(b, s_pad, cfg.num_heads, cfg.head_dim)
    out = out[:, :s]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(
    p: dict,
    x: jax.Array,
    cfg,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step with a (ring-buffered when windowed) KV cache.

    x: (B, 1, D); cache_k/v: (B, S_cache, KV, D) — stores *roped* keys at
    absolute slot ``pos % S_cache``; pos: (B,) absolute positions.
    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    b, _, d = x.shape
    s_cache = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)

    slot = (pos % s_cache).astype(jnp.int32)
    cache_k = cache_k.at[jnp.arange(b), slot].set(k[:, 0])
    cache_v = cache_v.at[jnp.arange(b), slot].set(v[:, 0])

    groups = cfg.num_heads // cfg.num_kv_heads
    # grouped-query einsum — no materialized repeat of the KV cache
    b_, s_, _, hd_ = q.shape
    qg = q.reshape(b_, s_, cfg.num_kv_heads, groups, hd_)
    scores = jnp.einsum("bskgd,bckd->bkgsc", qg, cache_k).astype(jnp.float32)
    scores = scores.reshape(b_, cfg.num_heads, s_, -1)
    scores = scores * (cfg.head_dim ** -0.5)

    # validity: slot c holds absolute position; with a ring buffer the
    # absolute position of slot c is recoverable from (pos, window).
    slots = jnp.arange(s_cache)[None, :]                    # (1, S_cache)
    if window is None:
        # absolute-indexed full cache: slot index == position
        valid = slots <= pos[:, None]
    elif isinstance(window, int) and window == s_cache:
        # ring buffer (cache size == window): every slot written within the
        # last s_cache steps is valid once wrapped; before that, slots ≤ pos.
        valid = slots <= pos[:, None]
        wrapped = pos[:, None] >= s_cache
        valid = jnp.where(wrapped, jnp.ones_like(valid, dtype=bool), valid)
    else:
        # absolute-indexed full cache with a (possibly traced) window:
        # slot == position, mask by causal validity AND distance < window.
        valid = (slots <= pos[:, None]) & ((pos[:, None] - slots) < window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(cache_v.dtype)
    wg = w.reshape(b_, cfg.num_kv_heads, groups, s_, -1)
    out = jnp.einsum("bkgsc,bckd->bskgd", wg, cache_v)
    out = out.reshape(b_, s_, cfg.num_heads, hd_)
    return (
        jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)),
        cache_k,
        cache_v,
    )


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wg": jax.random.normal(ks[0], (d_model, d_ff), jnp.float32) * d_model**-0.5,
        "wu": jax.random.normal(ks[1], (d_model, d_ff), jnp.float32) * d_model**-0.5,
        "wd": jax.random.normal(ks[2], (d_ff, d_model), jnp.float32) * d_ff**-0.5,
        "norm": jnp.zeros((d_model,), jnp.float32),
    }


def mlp_gated(p: dict, x: jax.Array, activation: str = "swiglu") -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype))
    act = jax.nn.silu if activation == "swiglu" else functools.partial(
        jax.nn.gelu, approximate=True
    )
    h = act(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(x.dtype))


# ---------------------------------------------------------------------------
# sequence-parallel decode attention (shard_map flash-decode) — §Perf lever
# ---------------------------------------------------------------------------


def attention_decode_sp(
    p: dict,
    x: jax.Array,
    cfg,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    mesh,
    *,
    window=None,
    seq_axis: str = "model",
    batch_axes=("data",),
):
    """Decode attention with the KV cache **sequence-sharded over the model
    axis**, computed under shard_map.

    Replaces the GSPMD-auto path for decode, which (a) triggers
    "involuntary full rematerialization" on the cache scatter (the written
    slot lives on one seq shard) and (b) all-gathers cache slices for the
    attention einsum. Here:

      * the new (roped) K/V are written **locally** by the one shard that
        owns slot ``pos % S`` (predicated set — no collective);
      * each shard attends over its local slice and the partial softmax
        stats are combined with tiny ``pmax``/``psum`` collectives
        ((B,H,1)+(B,H,D) floats instead of MB-scale gathers) — the
        flash-decode combine.

    Returns (out (B,1,D), new_cache_k, new_cache_v) like attention_decode.
    """
    b = x.shape[0]
    s_cache = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)

    n_seq = mesh.shape[seq_axis]
    chunk = s_cache // n_seq
    groups = cfg.num_heads // cfg.num_kv_heads
    scale = cfg.head_dim ** -0.5
    b_ax = tuple(a for a in batch_axes if a in mesh.shape and b % mesh.shape[a] == 0) or None

    from jax.sharding import PartitionSpec as P

    def local(q_l, k_new, v_new, ck_l, cv_l, pos_l, window_l):
        j = jax.lax.axis_index(seq_axis)
        bl = q_l.shape[0]
        slot = (pos_l % s_cache).astype(jnp.int32)
        slot_loc = slot - j * chunk
        mine = (slot_loc >= 0) & (slot_loc < chunk)
        idx = jnp.clip(slot_loc, 0, chunk - 1)
        rows = jnp.arange(bl)
        old_k = ck_l[rows, idx]
        old_v = cv_l[rows, idx]
        ck_l = ck_l.at[rows, idx].set(
            jnp.where(mine[:, None, None], k_new[:, 0], old_k))
        cv_l = cv_l.at[rows, idx].set(
            jnp.where(mine[:, None, None], v_new[:, 0], old_v))

        # local attention over this shard's slice (absolute slot indices);
        # grouped-query einsum — no materialized repeat of the KV slice
        slots_abs = j * chunk + jnp.arange(chunk)                # (chunk,)
        b2, s2, _, hd2 = q_l.shape
        qg = q_l.reshape(b2, s2, cfg.num_kv_heads, groups, hd2)
        scores = jnp.einsum("bskgd,bckd->bkgsc", qg, ck_l).astype(jnp.float32)
        scores = scores.reshape(b2, cfg.num_heads, s2, -1)
        scores = scores * scale
        valid = slots_abs[None, :] <= pos_l[:, None]
        if window_l is not None:
            valid &= (pos_l[:, None] - slots_abs[None, :]) < window_l
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)

        m_l = scores.max(-1)                                      # (B,H,1)
        m_g = jax.lax.pmax(m_l, seq_axis)
        p_l = jnp.exp(scores - m_g[..., None])
        l_g = jax.lax.psum(p_l.sum(-1), seq_axis)                 # (B,H,1)
        pg = p_l.astype(cv_l.dtype).reshape(b2, cfg.num_kv_heads, groups, s2, -1)
        acc = jnp.einsum("bkgsc,bckd->bskgd", pg, cv_l)
        acc = acc.reshape(b2, s2, cfg.num_heads, hd2)
        acc = jax.lax.psum(acc.astype(jnp.float32), seq_axis)
        out = (acc / jnp.maximum(l_g, 1e-30).swapaxes(1, 2)[..., None]).astype(q_l.dtype)
        return out, ck_l, cv_l

    win_arg = None if window is None else jnp.asarray(window, jnp.int32)
    in_specs = (
        P(b_ax, None, None, None),   # q
        P(b_ax, None, None, None),   # k_new
        P(b_ax, None, None, None),   # v_new
        P(b_ax, seq_axis, None, None),
        P(b_ax, seq_axis, None, None),
        P(b_ax),
    ) + ((P(),) if win_arg is not None else ())
    out_specs = (
        P(b_ax, None, None, None),
        P(b_ax, seq_axis, None, None),
        P(b_ax, seq_axis, None, None),
    )
    args = (q, k, v, cache_k, cache_v, pos)
    if win_arg is not None:
        fn = lambda q_l, kn, vn, ck, cv, pl, wl: local(q_l, kn, vn, ck, cv, pl, wl)
        args = args + (win_arg,)
    else:
        fn = lambda q_l, kn, vn, ck, cv, pl: local(q_l, kn, vn, ck, cv, pl, None)
    out, ck, cv = shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )(*args)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, ck, cv


# ---------------------------------------------------------------------------
# context-parallel attention (shard_map, q-sequence over 'model') — for archs
# whose head counts do not divide the model axis (hymba: 25 q / 5 kv heads):
# without this, GSPMD replicates the whole S²·H attention compute on every
# model shard. Here each shard computes its own query-sequence slice
# (compute ÷ mesh), K/V are computed locally from the replicated input
# (cheap: kv_heads is small), and the output is all-gathered once.
# ---------------------------------------------------------------------------


def attention_train_cp(
    p: dict,
    x: jax.Array,
    cfg,
    mesh,
    *,
    window=None,
    return_kv: bool = False,
    unroll: bool = False,
    seq_axis: str = "model",
):
    b, s, d = x.shape
    n_seq = mesh.shape[seq_axis]
    if s % n_seq:
        return attention_train(p, x, cfg, window=window, return_kv=return_kv,
                               unroll=unroll)
    s_loc = s // n_seq
    groups = cfg.num_heads // cfg.num_kv_heads
    scale = cfg.head_dim ** -0.5

    from jax.sharding import PartitionSpec as P

    def local(x_full, wq, wk, wv, wo, bq, bk, bv):
        j = jax.lax.axis_index(seq_axis)
        x_l = jax.lax.dynamic_slice_in_dim(x_full, j * s_loc, s_loc, axis=1)
        q = jnp.einsum("bsd,dhk->bshk", x_l, wq.astype(x_l.dtype))
        k = jnp.einsum("bsd,dhk->bshk", x_full, wk.astype(x_l.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x_full, wv.astype(x_l.dtype))
        if bq is not None:
            q = q + bq.astype(x_l.dtype)
            k = k + bk.astype(x_l.dtype)
            v = v + bv.astype(x_l.dtype)
        q_pos = j * s_loc + jnp.arange(s_loc)
        kv_pos = jnp.arange(s)
        q = rope(q, q_pos[None, :], cfg.rope_theta)
        k = rope(k, kv_pos[None, :], cfg.rope_theta)
        out_l = _flash_body(q, k, v, q_pos, kv_pos, window, scale, groups,
                            unroll=unroll)              # (B, S_loc, H, hd)
        out_l = jnp.einsum("bshk,hkd->bsd", out_l, wo.astype(x_l.dtype))
        out = jax.lax.all_gather(out_l, seq_axis, axis=1, tiled=True)
        if return_kv:
            return out, k, v
        return out

    bq = p.get("bq")
    bk = p.get("bk")
    bv = p.get("bv")
    # bias args may be None → pass zeros-shaped placeholders instead of
    # branching specs (keeps a single shard_map signature)
    if bq is None:
        bq = jnp.zeros((cfg.num_heads, cfg.head_dim), x.dtype)
        bk = jnp.zeros((cfg.num_kv_heads, cfg.head_dim), x.dtype)
        bv = jnp.zeros((cfg.num_kv_heads, cfg.head_dim), x.dtype)

    # batch stays sharded over the DP axes; everything else is replicated
    # over 'model' going in, and the q-slice varies by model shard inside.
    b_ax = tuple(a for a in ("pod", "data")
                 if a in mesh.shape and b % mesh.shape[a] == 0) or None
    rep4 = P(b_ax, None, None, None)
    out = shard_map(
        lambda xf, wq, wk, wv, wo, bq_, bk_, bv_: local(xf, wq, wk, wv, wo,
                                                        bq_, bk_, bv_),
        mesh=mesh,
        in_specs=(P(b_ax, None, None), P(None, None, None), P(None, None, None),
                  P(None, None, None), P(None, None, None), P(None, None),
                  P(None, None), P(None, None)),
        out_specs=(P(b_ax, None, None), rep4, rep4) if return_kv
        else P(b_ax, None, None),
        check_vma=False,
    )(x, p["wq"], p["wk"], p["wv"], p["wo"], bq, bk, bv)
    if return_kv:
        out, k, v = out
        return out, (k, v)
    return out
