"""Model assembly for all assigned architectures.

One decoder-LM skeleton covers the pool:

* ``dense``  — GQA attention + gated MLP (qwen1.5-*, gemma, command-r-plus,
  musicgen backbone, llava backbone).
* ``moe``    — GQA attention + routed experts (+ fused shared experts).
* ``ssm``    — pure Mamba-2 SSD stack (no attention, no MLP).
* ``hybrid`` — hymba: parallel attention+SSM heads per layer + MLP, with
  per-layer sliding-window/global attention (unscanned layer loop so each
  layer can carry a differently-sized cache).

Modalities: ``audio`` (musicgen) feeds summed codebook embeddings (or
precomputed frame embeddings from the stub frontend) and predicts all
codebooks with a factored head; ``vision_text`` (llava) prepends stub patch
embeddings to the token sequence.

Entry points:
  * :func:`init` — real parameter init (works under ``jax.eval_shape`` for
    the allocation-free dry-run).
  * :func:`forward_train` — logits for training/prefill (optionally
    returning a decode cache).
  * :func:`forward_decode` — single-token step with KV/SSM caches.
  * :func:`init_cache` — decode-cache pytree for a given shape.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

__all__ = ["init", "forward_train", "forward_decode", "init_cache", "padded_vocab"]


def padded_vocab(cfg: ModelConfig, mesh: Optional[Mesh]) -> int:
    from repro.parallel.sharding import pad_vocab

    return pad_vocab(cfg.vocab_size, mesh) if mesh is not None else cfg.vocab_size


def _head_width(cfg: ModelConfig) -> int:
    mult = max(cfg.num_codebooks, 1)
    return mult  # lm head emits mult × vocab logits


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, mesh: Optional[Mesh]) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {}
    if cfg.family != "ssm":
        p["attn"] = L.init_attn(ks[0], cfg)
    if cfg.ssm is not None:
        p["ssm"] = SSM.init_ssm(ks[1], cfg)
    if cfg.moe is not None:
        p["moe"] = MOE.init_moe(ks[2], cfg, mesh)
        if cfg.moe.num_shared:
            shared = L.init_mlp(ks[3], cfg.d_model, cfg.moe.num_shared * cfg.moe.d_ff_expert)
            del shared["norm"]
            p["shared_mlp"] = shared
    elif cfg.d_ff:
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    return p


def init(key, cfg: ModelConfig, mesh: Optional[Mesh] = None) -> dict:
    v = padded_vocab(cfg, mesh)
    ke, kh, kl = jax.random.split(key, 3)
    params: dict = {
        "embed": jax.random.normal(ke, (v, cfg.d_model), jnp.float32)
        * cfg.d_model**-0.5,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(kh, (cfg.d_model, _head_width(cfg) * v), jnp.float32)
            * cfg.d_model**-0.5
        )
    layer_keys = jax.random.split(kl, cfg.num_layers)
    if cfg.scan_layers:
        params["layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, mesh)
        )(layer_keys)
    else:
        params["layers"] = [
            _init_layer(layer_keys[i], cfg, mesh) for i in range(cfg.num_layers)
        ]
    return params


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _embed_tokens(params, tokens, cfg, dtype):
    emb = params["embed"].astype(dtype)
    if cfg.num_codebooks > 1:
        # musicgen: (B, S, K) codebook ids → summed embeddings
        return emb[tokens].sum(axis=2)
    return emb[tokens]


def _lm_logits(params, x, cfg, v):
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T  # (d, V)
    else:
        w = params["lm_head"].astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.num_codebooks > 1:
        b, s, _ = logits.shape
        logits = logits.reshape(b, s, cfg.num_codebooks, v)
    return logits


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------


def _window_for(cfg: ModelConfig, layer_idx: int) -> Optional[int]:
    if cfg.sliding_window is None:
        return None
    if layer_idx in cfg.global_attn_layers:
        return None
    return cfg.sliding_window


def _window_array(cfg: ModelConfig, max_seq: int) -> jax.Array:
    """Per-layer attention window as data (scanned hybrid stacks): global
    layers get window = max_seq+1 (≥ any distance ⇒ full causal attention),
    SWA layers get the sliding window. Masked-flash flops are identical
    either way, so this keeps the layer stack scan-uniform."""
    w = []
    for i in range(cfg.num_layers):
        wi = _window_for(cfg, i)
        w.append(max_seq + 1 if wi is None else wi)
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _pad_kv_to(k, cache_len):
    """(B, S, KV, D) → (B, cache_len, KV, D) absolute-slot layout."""
    s = k.shape[1]
    if s < cache_len:
        return jnp.pad(k, ((0, 0), (0, cache_len - s), (0, 0), (0, 0)))
    return k[:, :cache_len]


def _ring_kv(k, window):
    """(B, S, KV, D) → (B, window, KV, D) ring layout: slot = pos % window."""
    s = k.shape[1]
    if s <= window:
        return jnp.pad(k, ((0, 0), (0, window - s), (0, 0), (0, 0)))
    return jnp.roll(k[:, -window:], s % window, axis=1)


def forward_train(
    params: dict,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    mesh: Optional[Mesh] = None,
    *,
    remat: str = "none",
    compute_dtype=jnp.bfloat16,
    return_cache: bool = False,
    cache_len: Optional[int] = None,
    unroll_scans: bool = False,
):
    """Training/prefill forward. batch: {'tokens': (B,S[,K])} or
    {'embeds': ..., 'image_embeds': ...}. Returns (logits, aux_loss) or
    (logits, aux_loss, cache) when ``return_cache`` (prefill)."""
    v = params["embed"].shape[0]
    if "embeds" in batch:  # audio stub frontend: precomputed frame embeddings
        x = batch["embeds"].astype(compute_dtype)
    else:
        x = _embed_tokens(params, batch["tokens"], cfg, compute_dtype)
    if cfg.modality == "vision_text" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(compute_dtype)
        x = jnp.concatenate([img, x], axis=1)
    seq = x.shape[1]
    cache_len = cache_len or seq

    def dense_body(x, p_layer):
        h = L.rms_norm(x, p_layer["attn"]["norm"], cfg.norm_eps)
        if return_cache:
            y, (kk, vv) = L.attention_train(
                p_layer["attn"], h, cfg, window=cfg.sliding_window,
                return_kv=True, unroll=unroll_scans,
            )
            c_len = min(cfg.sliding_window or cache_len, cache_len)
            if cfg.sliding_window is not None and seq > c_len:
                c = {"k": _ring_kv(kk, c_len), "v": _ring_kv(vv, c_len)}
            else:
                c = {"k": _pad_kv_to(kk, c_len), "v": _pad_kv_to(vv, c_len)}
        else:
            y = L.attention_train(p_layer["attn"], h, cfg,
                                  window=cfg.sliding_window, unroll=unroll_scans)
            c = None
        x = x + y
        aux = jnp.zeros((), jnp.float32)
        if cfg.moe is not None:
            xn = L.rms_norm(x, p_layer["moe"]["norm"], cfg.norm_eps)
            y, aux = MOE.moe_layer(p_layer["moe"], xn, cfg, mesh)
            if cfg.moe.num_shared:
                y = y + L.mlp_gated(p_layer["shared_mlp"], xn, cfg.mlp_activation)
            x = x + y
        elif cfg.d_ff:
            xn = L.rms_norm(x, p_layer["mlp"]["norm"], cfg.norm_eps)
            x = x + L.mlp_gated(p_layer["mlp"], xn, cfg.mlp_activation)
        return x, aux, c

    def ssm_body(x, p_layer):
        xn = L.rms_norm(x, p_layer["ssm"]["norm"], cfg.norm_eps)
        if return_cache:
            y, (h_f, conv) = SSM.ssm_train(
                p_layer["ssm"], xn, cfg, return_state=True, unroll=unroll_scans
            )
            c = {"h": h_f, "conv": conv}
        else:
            y = SSM.ssm_train(p_layer["ssm"], xn, cfg, unroll=unroll_scans)
            c = None
        return x + y, jnp.zeros((), jnp.float32), c

    use_cp = (
        cfg.cp_attention and mesh is not None and "model" in mesh.shape
        and mesh.shape["model"] > 1
    )

    def attn_fwd(p_attn, xn, window, return_kv):
        if use_cp:
            return L.attention_train_cp(
                p_attn, xn, cfg, mesh, window=window, return_kv=return_kv,
                unroll=unroll_scans,
            )
        return L.attention_train(
            p_attn, xn, cfg, window=window, return_kv=return_kv,
            unroll=unroll_scans,
        )

    def hybrid_body(x, p_layer, window):
        xn = L.rms_norm(x, p_layer["attn"]["norm"], cfg.norm_eps)
        if return_cache:
            attn_y, (kk, vv) = attn_fwd(p_layer["attn"], xn, window, True)
            c = {"k": _pad_kv_to(kk, cache_len), "v": _pad_kv_to(vv, cache_len)}
            ssm_y, (h_f, conv) = SSM.ssm_train(
                p_layer["ssm"], xn, cfg, return_state=True,
                unroll=unroll_scans, mesh=mesh,
            )
            c.update({"h": h_f, "conv": conv})
        else:
            attn_y = attn_fwd(p_layer["attn"], xn, window, False)
            ssm_y = SSM.ssm_train(p_layer["ssm"], xn, cfg, unroll=unroll_scans,
                                  mesh=mesh)
            c = None
        x = x + 0.5 * (attn_y + ssm_y)
        xn = L.rms_norm(x, p_layer["mlp"]["norm"], cfg.norm_eps)
        x = x + L.mlp_gated(p_layer["mlp"], xn, cfg.mlp_activation)
        return x, jnp.zeros((), jnp.float32), c

    body = ssm_body if cfg.family == "ssm" else dense_body

    aux_total = jnp.zeros((), jnp.float32)
    caches = None
    if cfg.scan_layers:
        if cfg.family == "hybrid":
            windows = _window_array(cfg, seq)
            fn3 = hybrid_body
            if remat != "none":
                policy = (
                    jax.checkpoint_policies.nothing_saveable
                    if remat == "full"
                    else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                )
                fn3 = jax.checkpoint(hybrid_body, policy=policy)

            def scan_body(carry, inp):
                p_layer, window = inp
                x, aux = carry
                x, a, c = fn3(x, p_layer, window)
                return (x, aux + a), c

            (x, aux_total), caches = jax.lax.scan(
                scan_body, (x, aux_total), (params["layers"], windows)
            )
        else:
            fn = body
            if remat != "none":
                policy = (
                    jax.checkpoint_policies.nothing_saveable
                    if remat == "full"
                    else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                )
                fn = jax.checkpoint(body, policy=policy)

            def scan_body(carry, p_layer):
                x, aux = carry
                x, a, c = fn(x, p_layer)
                return (x, aux + a), c

            (x, aux_total), caches = jax.lax.scan(scan_body, (x, aux_total), params["layers"])
    elif cfg.family != "hybrid":  # unscanned uniform stack (analysis variants)
        caches = []
        for p_layer in params["layers"]:
            x, a, c = body(x, p_layer)
            aux_total = aux_total + a
            caches.append(c)
    else:  # hybrid (unscanned): per-layer windows and cache shapes
        caches = []
        for i, p_layer in enumerate(params["layers"]):
            w = _window_for(cfg, i)

            def hyb(p_layer, x, w=w):
                xn = L.rms_norm(x, p_layer["attn"]["norm"], cfg.norm_eps)
                if return_cache:
                    attn_y, (kk, vv) = attn_fwd(p_layer["attn"], xn, w, True)
                    if w is not None and min(w, cache_len) < seq:
                        c = {"k": _ring_kv(kk, min(w, cache_len)),
                             "v": _ring_kv(vv, min(w, cache_len))}
                    else:
                        c_len = min(w, cache_len) if w is not None else cache_len
                        c = {"k": _pad_kv_to(kk, c_len), "v": _pad_kv_to(vv, c_len)}
                    ssm_y, (h_f, conv) = SSM.ssm_train(
                        p_layer["ssm"], xn, cfg, return_state=True,
                        unroll=unroll_scans, mesh=mesh,
                    )
                    c.update({"h": h_f, "conv": conv})
                else:
                    attn_y = attn_fwd(p_layer["attn"], xn, w, False)
                    ssm_y = SSM.ssm_train(p_layer["ssm"], xn, cfg,
                                          unroll=unroll_scans, mesh=mesh)
                    c = None
                x = x + 0.5 * (attn_y + ssm_y)
                xn = L.rms_norm(x, p_layer["mlp"]["norm"], cfg.norm_eps)
                return x + L.mlp_gated(p_layer["mlp"], xn, cfg.mlp_activation), c

            if remat != "none" and not return_cache:
                hyb = jax.checkpoint(hyb, static_argnums=())
            x, c = hyb(p_layer, x)
            caches.append(c)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.modality == "vision_text" and "image_embeds" in batch:
        x = x[:, batch["image_embeds"].shape[1]:]  # logits over text positions
    logits = _lm_logits(params, x, cfg, v)
    if return_cache:
        return logits, aux_total, {"layers": caches}
    return logits, aux_total


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    mesh: Optional[Mesh] = None,
    dtype=jnp.bfloat16,
) -> dict:
    """Decode-cache pytree.

    Attention layers: (L, B, S_c, KV, HD) ×2 with S_c = min(max_seq, window).
    SSM layers: SSD state (L, B, H, P, N) f32 + conv state.
    Hybrid (unscanned): per-layer dicts so SWA layers carry ring buffers of
    window size while global layers carry full-length caches.
    """
    kv, hd = cfg.num_kv_heads, cfg.head_dim

    def attn_cache(window):
        s_c = max_seq if window is None else min(window, max_seq)
        return {
            "k": jnp.zeros((batch, s_c, kv, hd), dtype),
            "v": jnp.zeros((batch, s_c, kv, hd), dtype),
        }

    def ssm_cache():
        h, conv = SSM.init_ssm_state(cfg, batch)
        return {"h": h, "conv": conv}

    if cfg.family == "ssm":
        per = [ssm_cache() for _ in range(cfg.num_layers)]
        return {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *per)}
    if cfg.family == "hybrid":
        if cfg.scan_layers:
            # scan-uniform: every layer carries a full-length absolute-slot
            # cache; SWA layers mask by distance (window-as-data), so the
            # ring layout is unnecessary.
            per = []
            for _ in range(cfg.num_layers):
                c = attn_cache(None)
                c.update(ssm_cache())
                per.append(c)
            return {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *per)}
        out = []
        for i in range(cfg.num_layers):
            c = attn_cache(_window_for(cfg, i))
            c.update(ssm_cache())
            out.append(c)
        return {"layers": out}
    per = [attn_cache(cfg.sliding_window) for _ in range(cfg.num_layers)]
    return {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *per)}


def forward_decode(
    params: dict,
    tokens: jax.Array,
    cache: dict,
    pos: jax.Array,
    cfg: ModelConfig,
    mesh: Optional[Mesh] = None,
    *,
    compute_dtype=jnp.bfloat16,
    unroll_layers: bool = False,
    sp_decode: bool = False,
) -> Tuple[jax.Array, dict]:
    """One decode step. tokens: (B, 1[, K]); pos: (B,) absolute positions.
    Returns (logits (B, 1, [K,] V), new_cache). ``unroll_layers`` unrolls the
    layer scan (used by the roofline analysis so XLA's cost model counts
    every layer). ``sp_decode`` switches attention to the shard_map
    sequence-parallel flash-decode (requires a mesh with a 'model' axis and
    a model-divisible cache length) — see layers.attention_decode_sp."""

    def attn_step(p_attn, xn, ck, cv, window):
        if sp_decode and mesh is not None and "model" in mesh.shape:
            return L.attention_decode_sp(
                p_attn, xn, cfg, ck, cv, pos, mesh, window=window
            )
        return L.attention_decode(p_attn, xn, cfg, ck, cv, pos, window=window)
    v = params["embed"].shape[0]
    x = _embed_tokens(params, tokens, cfg, compute_dtype)

    if cfg.family == "ssm" and cfg.scan_layers:

        def body(carry, inp):
            x = carry
            p_layer, c_layer = inp
            xn = L.rms_norm(x, p_layer["ssm"]["norm"], cfg.norm_eps)
            y, h, conv = SSM.ssm_decode(p_layer["ssm"], xn, cfg, c_layer["h"], c_layer["conv"])
            return x + y, {"h": h, "conv": conv}

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]),
                                     unroll=unroll_layers)
        new_cache = {"layers": new_layers}

    elif cfg.scan_layers and cfg.family == "hybrid":
        max_seq = cache["layers"]["k"].shape[2]
        windows = _window_array(cfg, max_seq)

        def body(carry, inp):
            x = carry
            p_layer, c_layer, window = inp
            xn = L.rms_norm(x, p_layer["attn"]["norm"], cfg.norm_eps)
            attn_y, ck, cv = attn_step(
                p_layer["attn"], xn, c_layer["k"], c_layer["v"], window
            )
            ssm_y, h, conv = SSM.ssm_decode(
                p_layer["ssm"], xn, cfg, c_layer["h"], c_layer["conv"]
            )
            x = x + 0.5 * (attn_y + ssm_y)
            xn = L.rms_norm(x, p_layer["mlp"]["norm"], cfg.norm_eps)
            x = x + L.mlp_gated(p_layer["mlp"], xn, cfg.mlp_activation)
            return x, {"k": ck, "v": cv, "h": h, "conv": conv}

        x, new_layers = jax.lax.scan(
            body, x, (params["layers"], cache["layers"], windows),
            unroll=unroll_layers,
        )
        new_cache = {"layers": new_layers}

    elif cfg.scan_layers and cfg.family in ("dense", "moe"):

        def body(carry, inp):
            x = carry
            p_layer, c_layer = inp
            xn = L.rms_norm(x, p_layer["attn"]["norm"], cfg.norm_eps)
            y, ck, cv = attn_step(
                p_layer["attn"], xn, c_layer["k"], c_layer["v"],
                cfg.sliding_window,
            )
            x = x + y
            if cfg.moe is not None:
                xn = L.rms_norm(x, p_layer["moe"]["norm"], cfg.norm_eps)
                y, _ = MOE.moe_layer(p_layer["moe"], xn, cfg, mesh)
                if cfg.moe.num_shared:
                    y = y + L.mlp_gated(
                        {**p_layer["shared_mlp"], "norm": None}, xn, cfg.mlp_activation
                    )
                x = x + y
            elif cfg.d_ff:
                xn = L.rms_norm(x, p_layer["mlp"]["norm"], cfg.norm_eps)
                x = x + L.mlp_gated(p_layer["mlp"], xn, cfg.mlp_activation)
            return x, {"k": ck, "v": cv}

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]),
                                     unroll=unroll_layers)
        new_cache = {"layers": new_layers}

    else:  # unscanned python layer loop (hybrid, or analysis variants)
        new_layers = []
        for i, (p_layer, c_layer) in enumerate(zip(params["layers"], cache["layers"])):
            w = _window_for(cfg, i)
            if cfg.family == "hybrid":
                xn = L.rms_norm(x, p_layer["attn"]["norm"], cfg.norm_eps)
                attn_y, ck, cv = L.attention_decode(
                    p_layer["attn"], xn, cfg, c_layer["k"], c_layer["v"], pos, window=w
                )
                ssm_y, h, conv = SSM.ssm_decode(
                    p_layer["ssm"], xn, cfg, c_layer["h"], c_layer["conv"]
                )
                x = x + 0.5 * (attn_y + ssm_y)
                xn = L.rms_norm(x, p_layer["mlp"]["norm"], cfg.norm_eps)
                x = x + L.mlp_gated(p_layer["mlp"], xn, cfg.mlp_activation)
                new_layers.append({"k": ck, "v": cv, "h": h, "conv": conv})
            elif cfg.family == "ssm":
                xn = L.rms_norm(x, p_layer["ssm"]["norm"], cfg.norm_eps)
                y, h, conv = SSM.ssm_decode(
                    p_layer["ssm"], xn, cfg, c_layer["h"], c_layer["conv"]
                )
                x = x + y
                new_layers.append({"h": h, "conv": conv})
            else:
                xn = L.rms_norm(x, p_layer["attn"]["norm"], cfg.norm_eps)
                y, ck, cv = L.attention_decode(
                    p_layer["attn"], xn, cfg, c_layer["k"], c_layer["v"], pos,
                    window=cfg.sliding_window,
                )
                x = x + y
                if cfg.moe is not None:
                    xn = L.rms_norm(x, p_layer["moe"]["norm"], cfg.norm_eps)
                    y, _ = MOE.moe_layer(p_layer["moe"], xn, cfg, mesh)
                    if cfg.moe.num_shared:
                        y = y + L.mlp_gated(p_layer["shared_mlp"], xn, cfg.mlp_activation)
                    x = x + y
                elif cfg.d_ff:
                    xn = L.rms_norm(x, p_layer["mlp"]["norm"], cfg.norm_eps)
                    x = x + L.mlp_gated(p_layer["mlp"], xn, cfg.mlp_activation)
                new_layers.append({"k": ck, "v": cv})
        new_cache = {"layers": new_layers}

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_logits(params, x, cfg, v)
    return logits, new_cache
