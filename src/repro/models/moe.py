"""Mixture-of-Experts layer with expert parallelism (EP).

Design (see DESIGN.md §6): token-choice top-k routing with capacity, computed
under ``shard_map`` with **experts sharded over the `model` axis and tokens
replicated across it** (tokens are naturally replicated over `model` in our
layouts — batch lives on the DP axes). Each model shard:

  1. computes the (replicated) router probabilities for all local tokens;
  2. for each of its *local* experts, capacity-selects the top-C tokens by
     routing weight (an expert-choice-among-routed capacity rule — tokens
     beyond capacity are dropped, as in GShard/Switch);
  3. runs the expert FFNs as one batched einsum over (E_local, C, d);
  4. scatter-adds the weighted expert outputs back to the token buffer.

The only collective is one ``psum`` over `model` of the (B, S, d) output —
the same volume as a row-parallel MLP all-reduce; no all-to-all is needed
because tokens are model-replicated. Dummy padded experts (qwen2-moe:
60 → 64) are masked in the router so they attract no tokens.

Shared experts (deepseek/qwen2-moe) are a fused dense gated MLP handled
outside this module (TP via GSPMD).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

__all__ = ["init_moe", "moe_layer", "moe_capacity"]


def init_moe(key, cfg, mesh: Optional[Mesh] = None) -> dict:
    from repro.parallel.sharding import pad_experts

    d = cfg.d_model
    f = cfg.moe.d_ff_expert
    e_pad = pad_experts(cfg.moe.num_experts, mesh) if mesh is not None else cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    return {
        "router": jax.random.normal(ks[0], (d, e_pad), jnp.float32) * scale,
        "wg": jax.random.normal(ks[1], (e_pad, d, f), jnp.float32) * scale,
        "wu": jax.random.normal(ks[2], (e_pad, d, f), jnp.float32) * scale,
        "wd": jax.random.normal(ks[3], (e_pad, f, d), jnp.float32) * f**-0.5,
        "norm": jnp.zeros((d,), jnp.float32),
    }


def moe_capacity(tokens: int, num_experts: int, top_k: int, cf: float) -> int:
    """Per-expert capacity C, padded to a multiple of 8 (sublane)."""
    c = int(tokens * top_k / num_experts * cf) + 1
    return -(-c // 8) * 8


def _moe_local(x, router, wg, wu, wd, *, cfg, e_pad: int, model_axis: Optional[str]):
    """Per-shard MoE compute. x: (B_loc, S, d) (model-replicated)."""
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    n_shards = 1
    shard_idx = 0
    if model_axis is not None:
        n_shards = jax.lax.axis_size(model_axis)
        shard_idx = jax.lax.axis_index(model_axis)
    e_local = e_pad // n_shards

    # --- routing (replicated over model) ---
    logits = (xf.astype(jnp.float32) @ router.astype(jnp.float32))  # (T, E_pad)
    # mask padded dummy experts
    if e_pad > moe.num_experts:
        pad_mask = jnp.arange(e_pad) >= moe.num_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, moe.top_k)                   # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # dense routing-weight matrix restricted to top-k: (T, E_pad)
    w_full = jnp.zeros((t, e_pad), jnp.float32)
    w_full = w_full.at[jnp.arange(t)[:, None], top_i].set(top_p)

    # aux load-balance loss (computed on true experts only)
    frac_tokens = (w_full[:, : moe.num_experts] > 0).mean(0)
    frac_probs = probs[:, : moe.num_experts].mean(0)
    aux = moe.num_experts * jnp.sum(frac_tokens * frac_probs)

    # --- local expert slice ---
    # wg/wu/wd arrive pre-sliced by shard_map: (E_local, d, f) etc.
    w_local = jax.lax.dynamic_slice(
        w_full, (0, shard_idx * e_local), (t, e_local)
    )  # (T, E_local)

    cap = moe_capacity(t, e_pad, moe.top_k, moe.capacity_factor)
    cap = min(cap, t)
    # capacity-select: per local expert, top-C tokens by routing weight
    sel_w, sel_t = jax.lax.top_k(w_local.T, cap)                     # (E_local, C)
    xg = xf[sel_t]                                                   # (E_local, C, d)
    active = (sel_w > 0.0).astype(xf.dtype)[..., None]

    g = jnp.einsum("ecd,edf->ecf", xg, wg.astype(xf.dtype))
    u = jnp.einsum("ecd,edf->ecf", xg, wu.astype(xf.dtype))
    h = jax.nn.silu(g) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, wd.astype(xf.dtype))
    out_e = out_e * active * sel_w[..., None].astype(xf.dtype)

    # scatter-add back to tokens
    yf = jnp.zeros((t, d), xf.dtype)
    yf = yf.at[sel_t.reshape(-1)].add(out_e.reshape(-1, d))
    if model_axis is not None:
        yf = jax.lax.psum(yf, model_axis)
        aux = aux  # identical on all shards (routing is replicated)
    return yf.reshape(b, s, d), aux


def moe_layer(
    p: dict,
    x: jax.Array,
    cfg,
    mesh: Optional[Mesh] = None,
) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN. x: (B, S, d) → (y, aux_loss).

    With a mesh: shard_map over the full mesh — tokens split over DP axes,
    experts over 'model'. Without a mesh (single-device smoke): direct call.
    """
    e_pad = p["router"].shape[-1]
    if mesh is None or "model" not in mesh.shape or mesh.shape["model"] == 1:
        y, aux = _moe_local(
            x, p["router"], p["wg"], p["wu"], p["wd"],
            cfg=cfg, e_pad=e_pad, model_axis=None,
        )
        return y, aux

    from repro.parallel.sharding import data_axes

    dp = data_axes(mesh)
    if cfg.moe.sharding == "ep" and e_pad % mesh.shape["model"] == 0:
        expert_spec = P("model", None, None)
        model_axis = "model"
    else:
        # TP fallback inside experts (ff dim) — experts replicated
        expert_spec = P(None, None, "model")
        model_axis = None

    def fn(x_l, router, wg, wu, wd):
        y, aux = _moe_local(
            x_l, router, wg, wu, wd, cfg=cfg, e_pad=e_pad,
            model_axis=model_axis,
        )
        if model_axis is None:
            # TP mode: partial outputs over the ff shards
            y = jax.lax.psum(y, "model")
        # aux: average over every mesh axis (replicated axes unaffected)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return y, aux

    b_axis = dp if x.shape[0] % _size(mesh, dp) == 0 else None
    s_axis = dp if b_axis is None and x.shape[1] % _size(mesh, dp) == 0 else None
    in_specs = (
        P(b_axis, s_axis, None),
        P(None, None),
        expert_spec,
        expert_spec,
        P("model", None, None) if model_axis else P(None, "model", None),
    )
    out_specs = (P(b_axis, s_axis, None), P())
    # check_vma=False: routing is replicated over 'model' while expert
    # weights vary over it; the psum-of-contributions pattern mixes
    # model-invariant and model-varying values, which the strict VMA
    # checker rejects even though the collective semantics are exactly
    # what we want (classic shard_map behavior).
    y, aux = shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return y, aux


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
