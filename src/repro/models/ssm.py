"""Mamba-2 (SSD — state-space duality) blocks, pure JAX.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk recurrent state pass via ``lax.scan``); decode uses the O(1)
recurrent update. The layer is attention-free: its state is
``(B, H, head_dim, d_state)`` — this is what makes the ``long_500k`` cell
servable for the SSM/hybrid archs.

Shapes follow the Mamba-2 paper: ``d_inner = expand·d_model``,
``H = d_inner / head_dim`` SSD heads, scalar-per-head ``A``; B and C are
shared across heads (single group), conv over the ``[x, B, C]`` channels.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_ssm", "ssm_train", "ssm_decode", "init_ssm_state"]


def init_ssm(key, cfg) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    di = s.d_inner(d)
    nh = s.num_heads(d)
    ns = s.d_state
    ks = jax.random.split(key, 6)
    scale = d ** -0.5
    return {
        "x_proj": jax.random.normal(ks[0], (d, di), jnp.float32) * scale,
        "z_proj": jax.random.normal(ks[1], (d, di), jnp.float32) * scale,
        "bc_proj": jax.random.normal(ks[2], (d, 2 * ns), jnp.float32) * scale,
        "dt_proj": jax.random.normal(ks[3], (d, nh), jnp.float32) * scale,
        "conv": jax.random.normal(ks[4], (di + 2 * ns, s.d_conv), jnp.float32)
        * (s.d_conv ** -0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gnorm": jnp.zeros((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d), jnp.float32) * di**-0.5,
        "norm": jnp.zeros((d,), jnp.float32),
    }


def init_ssm_state(cfg, batch: int, dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """(ssd_state, conv_state) for decode."""
    d = cfg.d_model
    s = cfg.ssm
    di = s.d_inner(d)
    nh = s.num_heads(d)
    h = jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype)
    conv = jnp.zeros((batch, s.d_conv - 1, di + 2 * s.d_state), dtype)
    return h, conv


def _depthwise_causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (C, K) causal depthwise conv."""
    k = w.shape[-1]
    x_pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        x_pad,
        w.T[:, None, :],  # (K, 1, C) -> spec below
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out


def _ssd_chunked(x, dt, a, b, c, chunk: int, unroll: bool = False):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (softplus'd); a: (H,) (negative);
    b, c: (B, S, N). Returns y: (B, S, H, P).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    # reshape to chunks: (B, NC, Q, ...); ALL per-chunk tensors (notably the
    # (B,Q,K,H) decay matrix) are built inside the scan body so peak memory
    # is one chunk's working set, not NC× of it.
    q = chunk
    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = b.reshape(bsz, nc, q, n)
    cc = c.reshape(bsz, nc, q, n)
    mask = jnp.tril(jnp.ones((q, q), bool))

    def step(h_carry, inp):
        x_c, dt_c, b_c, c_c = inp  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        da = dt_c * a[None, None, :]            # (B,Q,H) negative decay
        cum = jnp.cumsum(da, axis=1)
        total = cum[:, -1]                      # (B,H)

        # intra-chunk: L[q,k] = exp(cum[q] - cum[k]) for q >= k.
        # Mask *before* exp: rel > 0 in the (discarded) upper triangle would
        # overflow to inf, and grad-of-where would turn 0·inf into NaN.
        rel = cum[:, :, None, :] - cum[:, None, :, :]       # (B,Q,K,H)
        rel = jnp.where(mask[None, :, :, None], rel, -1e9)
        l_mat = jnp.exp(rel)
        scores = jnp.einsum("bqn,bkn->bqk", c_c, b_c)       # head-shared
        xdt = x_c * dt_c[..., None]                         # (B,K,H,P)
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, l_mat, xdt)

        # carried-state contribution + state update
        decay_in = jnp.exp(cum)                             # (B,Q,H)
        y_prev = jnp.einsum("bqn,bhpn->bqhp", c_c, h_carry) * decay_in[..., None]
        decay_rest = jnp.exp(total[:, None, :] - cum)       # (B,Q,H)
        s_chunk = jnp.einsum("bkn,bkh,bkhp->bhpn", b_c, dt_c * decay_rest, x_c)
        h_new = h_carry * jnp.exp(total)[..., None, None] + s_chunk
        return h_new, y_intra + y_prev

    # remat the chunk body: its backward recomputes the (B,Q,K,H) decay and
    # score matrices instead of saving them for every chunk (which would be
    # ~nc × 268 MB per layer at 4k/chunk-256 — the flash-style trade).
    step = jax.checkpoint(step)

    h0 = jnp.zeros((bsz, h, p, n), x.dtype)
    h_final, y = jax.lax.scan(
        step,
        h0,
        (
            xc.swapaxes(0, 1),
            dtc.swapaxes(0, 1),
            bc.swapaxes(0, 1),
            cc.swapaxes(0, 1),
        ),
        unroll=unroll,
    )
    y = y.swapaxes(0, 1).reshape(bsz, nc * q, h, p)[:, :s]
    return y, h_final


def ssm_train(p: dict, x_in: jax.Array, cfg, return_state: bool = False,
              unroll: bool = False, mesh=None):
    """Full-sequence SSD block. x_in: (B, S, D) → (B, S, D).

    With ``return_state`` also returns (h_final, conv_state) so prefill can
    hand off to the recurrent decode path. (Sequence padding inside the
    chunked scan is state-neutral: padded steps have dt = 0 → decay 1,
    increment 0.)"""
    s_cfg = cfg.ssm
    d = cfg.d_model
    di = s_cfg.d_inner(d)
    nh = s_cfg.num_heads(d)
    ns = s_cfg.d_state
    dtype = x_in.dtype

    x = jnp.einsum("bsd,de->bse", x_in, p["x_proj"].astype(dtype))
    z = jnp.einsum("bsd,de->bse", x_in, p["z_proj"].astype(dtype))
    bc = jnp.einsum("bsd,de->bse", x_in, p["bc_proj"].astype(dtype))
    dt = jnp.einsum("bsd,dh->bsh", x_in, p["dt_proj"].astype(dtype))

    xbc_raw = jnp.concatenate([x, bc], axis=-1)
    xbc = jax.nn.silu(_depthwise_causal_conv(xbc_raw, p["conv"].astype(dtype)))
    x, b, c = jnp.split(xbc, [di, di + ns], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    if s_cfg.p_major:
        # (B,S,P,H) → (B,S,H,P): the model-sharded d_inner axis lands on P
        # (head_dim), which divides the mesh even for odd head counts.
        xh = x.reshape(*x.shape[:2], s_cfg.head_dim, nh).swapaxes(-1, -2)
    else:
        xh = x.reshape(*x.shape[:2], nh, s_cfg.head_dim)
    if mesh is not None and "model" in mesh.shape and mesh.shape["model"] > 1:
        # pin the head grid's shardable axis so GSPMD keeps the SSD chunk
        # einsums distributed instead of replicating them over 'model'
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.sharding import data_axes

        import math

        dp = data_axes(mesh)
        dp_size = math.prod([mesh.shape[a] for a in dp]) if dp else 1
        b_ax = dp if x.shape[0] % dp_size == 0 else None
        axis_h = "model" if nh % mesh.shape["model"] == 0 else None
        axis_p = "model" if (axis_h is None and
                             s_cfg.head_dim % mesh.shape["model"] == 0) else None
        xh = jax.lax.with_sharding_constraint(
            xh, NamedSharding(mesh, P(b_ax, None, axis_h, axis_p))
        )

    y, h_final = _ssd_chunked(
        xh.astype(jnp.float32), dt, a, b.astype(jnp.float32),
        c.astype(jnp.float32), s_cfg.chunk, unroll=unroll,
    )
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    if s_cfg.p_major:
        y = y.swapaxes(-1, -2)
    y = y.reshape(*x.shape[:2], di).astype(dtype)

    # gated RMSNorm (Mamba-2's norm-before-out with z gate)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["gnorm"].astype(jnp.float32))).astype(dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype))
    if return_state:
        k = s_cfg.d_conv - 1
        tail = xbc_raw[:, -k:].astype(jnp.float32)
        if tail.shape[1] < k:  # sequences shorter than the conv receptive field
            tail = jnp.pad(tail, ((0, 0), (k - tail.shape[1], 0), (0, 0)))
        return out, (h_final.astype(jnp.float32), tail)
    return out


def ssm_decode(
    p: dict,
    x_in: jax.Array,
    cfg,
    h: jax.Array,
    conv_state: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token recurrent step.

    x_in: (B, 1, D); h: (B, H, P, N); conv_state: (B, K-1, C).
    Returns (y (B,1,D), new_h, new_conv_state).
    """
    s_cfg = cfg.ssm
    d = cfg.d_model
    di = s_cfg.d_inner(d)
    nh = s_cfg.num_heads(d)
    ns = s_cfg.d_state
    dtype = x_in.dtype

    x = jnp.einsum("bsd,de->bse", x_in, p["x_proj"].astype(dtype))
    z = jnp.einsum("bsd,de->bse", x_in, p["z_proj"].astype(dtype))
    bc = jnp.einsum("bsd,de->bse", x_in, p["bc_proj"].astype(dtype))
    dt = jnp.einsum("bsd,dh->bsh", x_in, p["dt_proj"].astype(dtype))

    xbc = jnp.concatenate([x, bc], axis=-1)[:, 0]        # (B, C)
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B, K, C)
    new_conv_state = window[:, 1:]
    w = p["conv"].astype(dtype)                          # (C, K)
    xbc = jax.nn.silu(jnp.einsum("bkc,ck->bc", window, w))
    x, b, c = jnp.split(xbc, [di, di + ns], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32))   # (B, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a[None])                           # (B, H)
    if s_cfg.p_major:
        xh = x.reshape(-1, s_cfg.head_dim, nh).swapaxes(-1, -2).astype(jnp.float32)
    else:
        xh = x.reshape(-1, nh, s_cfg.head_dim).astype(jnp.float32)

    # h ← h·exp(dt·A) + dt · B ⊗ x
    inc = jnp.einsum("bh,bn,bhp->bhpn", dt, b.astype(jnp.float32), xh)
    h = h * da[..., None, None] + inc
    y = jnp.einsum("bn,bhpn->bhp", c.astype(jnp.float32), h)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    if s_cfg.p_major:
        y = y.swapaxes(-1, -2)
    y = y.reshape(-1, 1, di).astype(dtype)

    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["gnorm"].astype(jnp.float32))).astype(dtype)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype)), h, new_conv_state
