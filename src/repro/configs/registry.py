"""Architecture registry: ``--arch <id>`` → (CONFIG, SMOKE).

Each assigned architecture lives in its own ``configs/<arch>.py`` module
exporting ``CONFIG`` (exact published config) and ``SMOKE`` (reduced
same-family config for CPU tests); this module aggregates them and provides
``input_specs`` — the allocation-free ShapeDtypeStruct stand-ins for the
multi-pod dry-run.
"""

from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES

__all__ = [
    "ARCHS",
    "SMOKES",
    "ARCH_MODULES",
    "get_config",
    "get_smoke",
    "input_specs",
    "cell_supported",
]

ARCH_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "musicgen-medium": "musicgen_medium",
    "mamba2-1.3b": "mamba2_13b",
    "qwen1.5-4b": "qwen15_4b",
    "qwen1.5-0.5b": "qwen15_05b",
    "command-r-plus-104b": "command_r_plus_104b",
    "gemma-7b": "gemma_7b",
    "hymba-1.5b": "hymba_15b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}

ARCHS: Dict[str, ModelConfig] = {}
SMOKES: Dict[str, ModelConfig] = {}
for _name, _mod in ARCH_MODULES.items():
    _m = importlib.import_module(f"repro.configs.{_mod}")
    ARCHS[_name] = _m.CONFIG
    SMOKES[_name] = _m.SMOKE


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_smoke(arch: str) -> ModelConfig:
    if arch not in SMOKES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(SMOKES)}")
    return SMOKES[arch]


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch × shape) runnable? long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k skipped: pure full-attention arch (see DESIGN.md "
            "§Arch-applicability) — a 524288-token context requires "
            "sub-quadratic attention (SSM / hybrid-SWA archs only)"
        )
    return True, ""


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, mode: Optional[str] = None
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    * train/prefill: token batch (+ labels for train, + stub modality
      inputs: precomputed patch/frame embeddings).
    * decode: one new token per sequence + absolute positions (the KV/SSM
      cache is built separately by ``init_cache`` under ``jax.eval_shape``).
    """
    mode = mode or shape.kind
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(shape_):
        return jax.ShapeDtypeStruct(shape_, i32)

    if mode == "decode":
        t = (b, 1, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, 1)
        return {"tokens": tok(t), "pos": tok((b,))}

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.modality == "vision_text":
        n_img = cfg.num_patches
        specs["tokens"] = tok((b, s - n_img))
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, n_img, cfg.d_model), jnp.bfloat16
        )
        if mode == "train":
            specs["labels"] = tok((b, s - n_img))
    elif cfg.num_codebooks > 1:
        specs["tokens"] = tok((b, s, cfg.num_codebooks))
        if mode == "train":
            specs["labels"] = tok((b, s, cfg.num_codebooks))
    else:
        specs["tokens"] = tok((b, s))
        if mode == "train":
            specs["labels"] = tok((b, s))
    return specs
