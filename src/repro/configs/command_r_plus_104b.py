"""command-r-plus-104b — 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000, no-bias [hf:CohereForAI/c4ai-command-r-plus]. The pool's
worst-case memory cell."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense", num_layers=64, d_model=12288,
    num_heads=96, num_kv_heads=8, head_dim=128, d_ff=33792, vocab_size=256000,
)

SMOKE = ModelConfig(
    name="command-r-plus-104b-smoke", family="dense", num_layers=2,
    d_model=128, num_heads=8, num_kv_heads=2, head_dim=16, d_ff=256,
    vocab_size=512,
)
