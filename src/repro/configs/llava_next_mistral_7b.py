"""llava-next-mistral-7b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision frontend is a STUB: input_specs() provides 2880 precomputed
patch embeddings (4 anyres tiles + base image, 576 patches each at 336px/
CLIP-L-14) prepended to the token sequence; loss is computed on text
positions only."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="dense", modality="vision_text",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, num_patches=2880,
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b-smoke", family="dense",
    modality="vision_text", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, num_patches=16,
)
