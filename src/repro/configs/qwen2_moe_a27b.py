"""qwen2-moe-a2.7b — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared [hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts do not divide the 16-wide model axis: EP pads to 64 with
router-masked dummies (see parallel/sharding.pad_experts)."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, num_shared=4, top_k=4, d_ff_expert=1408),
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=96, vocab_size=256,
    qkv_bias=True,
    moe=MoEConfig(num_experts=6, num_shared=1, top_k=2, d_ff_expert=96),
)
