"""musicgen-medium — 48L d_model=1536 24H d_ff=6144 vocab=2048,
decoder-only over EnCodec tokens (4 codebooks) [arXiv:2306.05284; hf].

The EnCodec frontend is a STUB: input_specs() provides codebook token ids
(or precomputed frame embeddings); the backbone sums codebook embeddings
and predicts all 4 codebooks with a factored LM head."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="dense", modality="audio", num_layers=48,
    d_model=1536, num_heads=24, num_kv_heads=24, head_dim=64, d_ff=6144,
    vocab_size=2048, num_codebooks=4, mlp_activation="geglu",
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke", family="dense", modality="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=64, num_codebooks=4, mlp_activation="geglu",
)
