"""Config system: dataclass configs for models, shapes, meshes, runs.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``CONFIG: ModelConfig`` (the exact published config) and ``SMOKE: ModelConfig``
(a reduced same-family config for CPU smoke tests). ``registry.py`` resolves
``--arch <id>``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = [
    "MoEConfig",
    "SSMConfig",
    "ModelConfig",
    "ShapeConfig",
    "OptimizerConfig",
    "RunConfig",
    "SHAPES",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int          # routed experts
    num_shared: int           # always-on shared experts
    top_k: int
    d_ff_expert: int          # per-expert FFN width
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # experts padded up to a multiple of the model axis for EP when needed
    # (qwen2-moe: 60 → 64; dummies are router-masked) — see parallel/sharding.
    sharding: str = "ep"      # 'ep' (expert dim) or 'tp' (ff dim inside expert)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256          # SSD chunk length (training/prefill)
    # P-major head layout: reshape d_inner as (head_dim, n_heads) so a
    # model-axis shard covers whole rows of the head grid even when the
    # SSD head count (e.g. hymba's 50) does not divide the axis.
    p_major: bool = False

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # 'dense' | 'moe' | 'ssm' | 'hybrid'
    modality: str = "text"    # 'text' | 'audio' | 'vision_text'
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    qkv_bias: bool = False
    mlp_activation: str = "swiglu"   # 'swiglu' | 'geglu'
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # sliding-window attention: None = full causal. Per-layer override via
    # global_attn_layers (hymba keeps a few global layers).
    sliding_window: Optional[int] = None
    global_attn_layers: Tuple[int, ...] = ()
    attention_free: bool = False     # mamba2
    scan_layers: bool = True         # lax.scan over stacked layer params
    # context-parallel attention: shard the query sequence over 'model'
    # inside shard_map when head counts do not divide the model axis
    # (hymba: 25 q heads / 5 kv heads) — compute scales 1/16 instead of
    # being model-replicated, at the cost of one output all-gather.
    cp_attention: bool = False
    # audio frontend (musicgen): number of EnCodec codebooks
    num_codebooks: int = 0
    # vision frontend (llava): patches provided by the stub frontend
    num_patches: int = 0
    dtype: str = "bfloat16"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM / hybrid-with-SWA)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.sliding_window is not None:
            return True
        return False

    def num_params(self) -> int:
        """Analytic parameter count (used for 6·N·D model-flops)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        for layer in range(self.num_layers):
            n += self._layer_params(layer)
        n += d                                        # final norm
        return n

    def _layer_params(self, layer_idx: int) -> int:
        d = self.d_model
        n = 0
        if self.family != "ssm":  # attention block
            h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
            n += d * h * hd + 2 * d * kv * hd + h * hd * d
            if self.qkv_bias:
                n += h * hd + 2 * kv * hd
            n += d  # attn norm
        if self.family in ("ssm", "hybrid") and self.ssm is not None:
            di = self.ssm.d_inner(d)
            nh = self.ssm.num_heads(d)
            ns = self.ssm.d_state
            n += d * di * 2            # x, z projections
            n += d * (2 * ns + nh)     # B, C, dt projections
            n += di * self.ssm.d_conv  # depthwise conv
            n += nh * 2 + di           # A, D, gated-norm weight
            n += di * d                # out projection
            n += d                     # ssm norm
        if self.moe is not None:
            e = self.moe.num_experts + self.moe.num_shared
            n += e * 3 * d * self.moe.d_ff_expert   # gate/up/down per expert
            n += d * self.moe.num_experts           # router
            n += d                                   # mlp norm
        elif self.d_ff:
            n += 3 * d * self.d_ff                   # swiglu/geglu
            n += d
        return n

    def active_params(self) -> int:
        """Active parameters per token (MoE: only top-k + shared experts)."""
        if self.moe is None:
            return self.num_params()
        full = self.num_params()
        e_total = self.moe.num_experts + self.moe.num_shared
        e_active = self.moe.top_k + self.moe.num_shared
        expert_params = self.num_layers * e_total * 3 * self.d_model * self.moe.d_ff_expert
        active_expert = self.num_layers * e_active * 3 * self.d_model * self.moe.d_ff_expert
        return full - expert_params + active_expert


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


# The assigned input-shape set (identical across the LM pool).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"       # 'adamw' | 'shampoo'
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    # Shampoo (ATA-powered)
    shampoo_block: int = 1024
    shampoo_update_every: int = 10
    shampoo_grafting: str = "adam"
    # ATA recursion cutoff for the gram statistics. None (default) defers
    # to the repro.tune planner per gram shape; >= shampoo_block disables
    # Strassen entirely (classical-gram baseline)
    shampoo_n_base: Optional[int] = None
    # ZeRO-1 optimizer-state sharding over the data axis
    zero1: bool = True
    # PowerSGD gradient compression (rank 0 = off)
    powersgd_rank: int = 0


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = OptimizerConfig()
    remat: str = "dots"       # 'none' | 'dots' | 'full'
    microbatch: int = 1       # gradient-accumulation microbatches
    seed: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
