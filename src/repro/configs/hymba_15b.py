"""hymba-1.5b — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16, parallel attention+mamba heads [arXiv:2411.13676; hf].

SWA window 2048 with 3 global-attention layers (first/middle/last, as in
the paper). The layer stack is scanned with the per-layer window passed as
*data* (global layers get window = seq+1), which keeps the stack
scan-uniform — masked-flash flops are window-invariant, so this changes no
costs while keeping GSPMD compile tractable. Decode carries full-length
absolute-slot caches for every layer (memory is dominated by the 3 global
layers anyway once sharded). SSM state is what makes long_500k servable.
Meta-tokens are omitted (noted in DESIGN.md)."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", num_layers=32, d_model=1600,
    num_heads=25, num_kv_heads=5, head_dim=64, d_ff=5504, vocab_size=32001,
    sliding_window=2048, global_attn_layers=(0, 15, 31), scan_layers=True,
    cp_attention=True,  # 25 q / 5 kv heads don't divide the model axis
    ssm=SSMConfig(d_state=16, head_dim=64, p_major=True),
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    sliding_window=32, global_attn_layers=(0,), scan_layers=True,
    ssm=SSMConfig(d_state=8, head_dim=16, chunk=32, p_major=True),
)
