"""mamba2-1.3b — 48L d_model=2048 attention-free, ssm_state=128 (SSD)
[arXiv:2405.21060]. vocab=50280 (padded to model-axis multiple for sharding).
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm", num_layers=48, d_model=2048,
    num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=50280,
    attention_free=True, ssm=SSMConfig(d_state=128),
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke", family="ssm", num_layers=2, d_model=64,
    num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=256,
    attention_free=True,
    ssm=SSMConfig(d_state=16, head_dim=16, chunk=32),
)
