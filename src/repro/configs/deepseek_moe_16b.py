"""deepseek-moe-16b — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared (fine-grained experts)
[arXiv:2401.06066; hf]."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=102400,
    moe=MoEConfig(num_experts=64, num_shared=2, top_k=6, d_ff_expert=1408),
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=96, vocab_size=256,
    moe=MoEConfig(num_experts=8, num_shared=2, top_k=2, d_ff_expert=96),
)
