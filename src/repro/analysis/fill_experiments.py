"""Inject dry-run + roofline tables into EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.analysis.fill_experiments \
        --dryrun results/dryrun --experiments EXPERIMENTS.md
"""

from __future__ import annotations

import argparse
import json
import re

from repro.analysis.roofline import compose_cell, load_cells, render_markdown


def dryrun_table(recs) -> str:
    hdr = ("| arch | shape | mesh | status | peak GiB/dev | compile s | "
           "collective schedule (per-dev MB: AR/AG/RS/A2A/CP) |\n"
           "|---|---|---|---|---|---|---|\n")
    lines = []
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"])):
        if r.get("variant_tag") or r.get("mode") == "gram":
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"skipped (documented) | - | - | - |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **ERROR** | - | - | "
                f"{r.get('error','')[:80]} |"
            )
            continue
        a = r["artifacts"]["main"]
        mem = a["memory"].get("peak_bytes_est", 0) / 2**30
        c = a["collectives"]
        coll = "/".join(
            f"{c.get(k, 0)/2**20:.0f}"
            for k in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute")
        )
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{mem:.2f} | {a['compile_s']:.0f} | {coll} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--experiments", default="EXPERIMENTS.md")
    args = ap.parse_args()

    recs = load_cells(args.dryrun)
    recs_main = [r for r in recs if not r.get("variant_tag")]
    dr_table = dryrun_table(recs_main)
    rows = [compose_cell(r) for r in recs_main]
    rf_table = render_markdown([r for r in rows if r])

    text = open(args.experiments).read()
    text = re.sub(
        r"<!-- DRYRUN_TABLE -->.*?(?=\n## |\Z)",
        "<!-- DRYRUN_TABLE -->\n" + dr_table + "\n",
        text, flags=re.S,
    )
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |\Z)",
        "<!-- ROOFLINE_TABLE -->\n" + rf_table + "\n",
        text, flags=re.S,
    )
    open(args.experiments, "w").write(text)
    ok = sum(1 for r in recs_main if r["status"] == "ok")
    skip = sum(1 for r in recs_main if r["status"] == "skipped")
    err = sum(1 for r in recs_main if r["status"] == "error")
    print(f"EXPERIMENTS.md updated: {ok} ok, {skip} skipped, {err} errors")


if __name__ == "__main__":
    main()
