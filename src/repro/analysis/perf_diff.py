"""Diff two dry-run artifacts and emit a §Perf log entry.

    PYTHONPATH=src python -m repro.analysis.perf_diff \
        results/dryrun/cmd__decode_32k__single.json \
        results/dryrun/cmd__decode_32k__single__bf16.json \
        --hypothesis "serving params in bf16 halves the memory term"
"""

from __future__ import annotations

import argparse
import json

from repro.analysis.roofline import compose_cell


def summarize(rec):
    row = compose_cell(rec)
    mem = rec["artifacts"]["main"]["memory"]
    return {
        "compute_s": row["compute_s"],
        "memory_s": row["memory_s"],
        "collective_s": row["collective_s"],
        "dominant": row["dominant"],
        "roofline_fraction": row["roofline_fraction"],
        "useful_flop_ratio": row["useful_flop_ratio"],
        "peak_gib": mem.get("peak_bytes_est", 0) / 2**30,
        "coll_bytes": row["collective_bytes_per_dev"],
    }


def fmt_delta(a, b):
    if a == 0:
        return "n/a"
    return f"{(b - a) / a * 100:+.1f}%"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("before")
    ap.add_argument("after")
    ap.add_argument("--hypothesis", default="")
    args = ap.parse_args()
    b = summarize(json.load(open(args.before)))
    a = summarize(json.load(open(args.after)))
    print(f"**Hypothesis**: {args.hypothesis}")
    print(f"| term | before | after | Δ |")
    print(f"|---|---|---|---|")
    for k in ("compute_s", "memory_s", "collective_s"):
        print(f"| {k} | {b[k]:.4f} | {a[k]:.4f} | {fmt_delta(b[k], a[k])} |")
    print(f"| peak GiB/dev | {b['peak_gib']:.2f} | {a['peak_gib']:.2f} | "
          f"{fmt_delta(b['peak_gib'], a['peak_gib'])} |")
    print(f"| roofline frac | {b['roofline_fraction']:.4f} | "
          f"{a['roofline_fraction']:.4f} | "
          f"{fmt_delta(b['roofline_fraction'], a['roofline_fraction'])} |")
    print(f"| dominant | {b['dominant']} | {a['dominant']} | |")


if __name__ == "__main__":
    main()
