"""Perf diffing: dry-run artifacts, and fresh-vs-committed BENCH rows.

Dry-run mode (CLI):

    PYTHONPATH=src python -m repro.analysis.perf_diff \
        results/dryrun/cmd__decode_32k__single.json \
        results/dryrun/cmd__decode_32k__single__bf16.json \
        --hypothesis "serving params in bf16 halves the memory term"

Bench mode (:func:`bench_diff` / :func:`print_bench_diff`): compare the
rows a benchmark module just produced against the committed
``BENCH_*.json`` baseline — wired into ``benchmarks/run.py`` (and hence
the CI bench job), **report-only**: a regression prints a table line, it
never fails the run. Rows are matched by ``name``; the baseline's backend
metadata is shown when it differs, because a seconds delta across
different machines is noise, not signal.
"""

from __future__ import annotations

import argparse
import json

from repro.analysis.roofline import compose_cell

_META_KEYS = ("backend", "device_kind", "jax_version", "interpret")


def bench_diff(baseline_rows, fresh_rows):
    """Match BENCH rows by name; return diff records (fresh order).

    Each record: ``{name, base_s, new_s, delta_pct, meta_changed}`` —
    ``base_s``/``delta_pct`` are ``None`` for rows with no baseline (new
    benchmarks), ``meta_changed`` lists the backend-metadata keys on which
    the two rows disagree (absent key ≠ mismatch: pre-metadata baselines
    stay comparable).
    """
    base = {
        r["name"]: r
        for r in baseline_rows
        if isinstance(r, dict) and "name" in r and "seconds" in r
    }
    out = []
    for r in fresh_rows:
        if not isinstance(r, dict) or "name" not in r or "seconds" not in r:
            continue
        b = base.get(r["name"])
        rec = {
            "name": r["name"],
            "base_s": b["seconds"] if b else None,
            "new_s": r["seconds"],
            "delta_pct": None,
            "meta_changed": [],
        }
        if b and b["seconds"]:
            rec["delta_pct"] = (r["seconds"] - b["seconds"]) / b["seconds"] * 100.0
            rec["meta_changed"] = [
                k for k in _META_KEYS
                if k in b and k in r and b[k] != r[k]
            ]
        out.append(rec)
    return out


def print_bench_diff(key, records, print_fn=print):
    """Render :func:`bench_diff` records as a report-only table."""
    if not records:
        return
    print_fn(f"# perf diff vs committed BENCH_{key}.json (report-only)")
    print_fn("# name | baseline_us | fresh_us | delta | note")
    for r in records:
        if r["base_s"] is None:
            print_fn(f"# {r['name']} | - | {r['new_s']*1e6:.1f} | NEW | ")
            continue
        note = ",".join(r["meta_changed"])
        if note:
            note = f"metadata changed: {note}"
        # delta is None for a zero-seconds baseline (marker rows)
        delta = "n/a" if r["delta_pct"] is None else f"{r['delta_pct']:+.1f}%"
        print_fn(
            f"# {r['name']} | {r['base_s']*1e6:.1f} | {r['new_s']*1e6:.1f} "
            f"| {delta} | {note}"
        )


def summarize(rec):
    row = compose_cell(rec)
    mem = rec["artifacts"]["main"]["memory"]
    return {
        "compute_s": row["compute_s"],
        "memory_s": row["memory_s"],
        "collective_s": row["collective_s"],
        "dominant": row["dominant"],
        "roofline_fraction": row["roofline_fraction"],
        "useful_flop_ratio": row["useful_flop_ratio"],
        "peak_gib": mem.get("peak_bytes_est", 0) / 2**30,
        "coll_bytes": row["collective_bytes_per_dev"],
    }


def fmt_delta(a, b):
    if a == 0:
        return "n/a"
    return f"{(b - a) / a * 100:+.1f}%"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("before")
    ap.add_argument("after")
    ap.add_argument("--hypothesis", default="")
    args = ap.parse_args()
    b = summarize(json.load(open(args.before)))
    a = summarize(json.load(open(args.after)))
    print(f"**Hypothesis**: {args.hypothesis}")
    print(f"| term | before | after | Δ |")
    print(f"|---|---|---|---|")
    for k in ("compute_s", "memory_s", "collective_s"):
        print(f"| {k} | {b[k]:.4f} | {a[k]:.4f} | {fmt_delta(b[k], a[k])} |")
    print(f"| peak GiB/dev | {b['peak_gib']:.2f} | {a['peak_gib']:.2f} | "
          f"{fmt_delta(b['peak_gib'], a['peak_gib'])} |")
    print(f"| roofline frac | {b['roofline_fraction']:.4f} | "
          f"{a['roofline_fraction']:.4f} | "
          f"{fmt_delta(b['roofline_fraction'], a['roofline_fraction'])} |")
    print(f"| dominant | {b['dominant']} | {a['dominant']} | |")


if __name__ == "__main__":
    main()
