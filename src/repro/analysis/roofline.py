"""Roofline analysis from dry-run artifacts (TPU v5e model).

Terms per (arch × shape × mesh) cell, all **per device** (the compiled SPMD
module is the per-device program; a balanced program makes per-device ≡
global/chips):

    compute_s    = HLO_flops / PEAK_FLOPS          (197 TFLOP/s bf16)
    memory_s     = HLO_bytes_accessed / HBM_BW     (819 GB/s)
    collective_s = Σ_kind factor·bytes / LINK_BW   (~50 GB/s/link ICI;
                   all-reduce counts 2× — ring reduce-scatter+all-gather)

Because XLA's cost model counts loop bodies once, train/prefill cells are
composed from the dry-run's reduced-depth *analysis variants* (layers
unrolled) via the affine model ``C(L) = C_fix + L·C_layer``:

    uniform stacks:  C_layer = C(2) − C(1);  C_fix = C(1) − C_layer
    hybrid (hymba):  three variants solve (C_fix, C_global, C_swa)

Decode cells compile with the layer loop unrolled, so their ``main``
artifact is exact directly.

MODEL_FLOPS uses the 6·N·T convention (2·N·T for forward-only prefill and
2·N·B for decode), with N = active params (MoE); the ratio
MODEL_FLOPS/HLO_flops exposes remat/attention/routing overheads.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

from repro.analysis.hlo import COLLECTIVE_KINDS, collective_seconds

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link
CHIPS = {"single": 256, "multi": 512}

__all__ = [
    "compose_cell",
    "load_cells",
    "render_markdown",
    "syrk_write_traffic",
    "syrk_write_seconds",
    "potrf_write_traffic",
    "trsm_write_traffic",
    "normal_eq_write_traffic",
    "normal_eq_write_seconds",
    "PEAK_FLOPS",
    "HBM_BW",
    "LINK_BW",
]


# ---------------------------------------------------------------------------
# symmetric-output write-traffic model (packed-storage PR)
# ---------------------------------------------------------------------------


def syrk_write_traffic(n: int, bn: int, mode: str, itemsize: int = 4) -> int:
    """HBM bytes *written* to produce an ``n × n`` symmetric product.

    ``nb = ⌈n/bn⌉`` output tiles per side; ``T = nb(nb+1)/2`` lower tiles.

      * ``'packed'``  — kernel stores only the T packed tiles:   ``T·bn²``.
      * ``'dual'``    — in-kernel dual-write dense output, every
        block stored exactly once:                               ``nb²·bn²``.
        (The diagonal tile's symmetrized re-store targets the same output
        block index, so it stays in VMEM and reaches HBM once.)
      * ``'mirror'``  — the seed pipeline: kernel stores T tiles into an
        nb²-tile buffer, then a tril+mirror post-pass re-writes the whole
        square:                                             ``T·bn² + n²``.

    The packed/dual ratio ``(nb+1)/2nb → 1/2`` is the storage half of the
    paper's symmetry claim; 'mirror' shows what discarding it costs.
    """
    nb = -(-n // bn)
    t = nb * (nb + 1) // 2
    tile = bn * bn * itemsize
    if mode == "packed":
        return t * tile
    if mode == "dual":
        return nb * nb * tile
    if mode == "mirror":
        return t * tile + n * n * itemsize
    raise ValueError(f"unknown syrk output mode {mode!r}")


def syrk_write_seconds(n: int, bn: int, mode: str, itemsize: int = 4) -> float:
    """Write-traffic seconds on the HBM roofline (v5e model)."""
    return syrk_write_traffic(n, bn, mode, itemsize) / HBM_BW


def potrf_write_traffic(n: int, bn: int, mode: str = "packed",
                        itemsize: int = 4) -> int:
    """HBM bytes written by the blocked Cholesky of an ``n × n`` gram.

    The factor walk overwrites exactly the block grid it reads:

      * ``'packed'`` — the packed factor stores the ``T = nb(nb+1)/2``
        lower tiles and nothing else:                       ``T·bn²``.
      * ``'dense'``  — a dense factorization writes the full square
        (LAPACK-style, upper zeroed):                       ``(nb·bn)²``.

    Same ``(nb+1)/2nb → 1/2`` ratio as the gram itself — the storage half
    of the paper's symmetry claim carries through the factorization.
    """
    nb = -(-n // bn)
    tile = bn * bn * itemsize
    if mode == "packed":
        return nb * (nb + 1) // 2 * tile
    if mode == "dense":
        return nb * nb * tile
    raise ValueError(f"unknown potrf output mode {mode!r}")


def trsm_write_traffic(n: int, r: int, itemsize: int = 4) -> int:
    """HBM bytes written by one triangular substitution pass: the solution
    panel, ``n·r`` words (the factor is read, not written)."""
    return n * r * itemsize


def normal_eq_write_traffic(n: int, bn: int, r: int, *, mode: str = "packed",
                            itemsize: int = 4) -> int:
    """Write bytes of the post-gram normal-equations tail: factor the
    ``n × n`` gram in place (``potrf_write_traffic``) and run the two
    substitution passes (``2·n·r``). Add ``syrk_write_traffic`` for the
    gram itself to price the full ``ata → factor → solve`` pipeline —
    the dryrun gram sweep and ``tune.cost``'s op='solve' entry both do.
    """
    return (
        potrf_write_traffic(n, bn, mode, itemsize)
        + 2 * trsm_write_traffic(n, r, itemsize)
    )


def normal_eq_write_seconds(n: int, bn: int, r: int, *, mode: str = "packed",
                            itemsize: int = 4) -> float:
    """Full-pipeline (gram + factor + substitutions) write seconds on the
    HBM roofline: ``syrk_write_traffic`` of the matching gram mode plus
    :func:`normal_eq_write_traffic`."""
    gram_mode = "packed" if mode == "packed" else "dual"
    total = (
        syrk_write_traffic(n, bn, gram_mode, itemsize)
        + normal_eq_write_traffic(n, bn, r, mode=mode, itemsize=itemsize)
    )
    return total / HBM_BW


def _cost_vec(artifact: dict) -> dict:
    v = {
        "flops": artifact["cost"].get("flops", 0.0),
        "bytes": artifact["cost"].get("bytes_accessed", 0.0),
    }
    for k in COLLECTIVE_KINDS:
        v[f"coll_{k}"] = float(artifact["collectives"].get(k, 0))
    return v


def _affine(v1: dict, v2: dict, n_layers: int) -> dict:
    out = {}
    for k in v1:
        layer = max(v2[k] - v1[k], 0.0)
        fix = max(v1[k] - layer, 0.0)
        out[k] = fix + n_layers * layer
    return out


def _hybrid(vg1: dict, vgs2: dict, vss2: dict, n_g: int, n_s: int) -> dict:
    out = {}
    for k in vg1:
        f_s = max(vgs2[k] - vg1[k], 0.0)
        f_fix = max(vss2[k] - 2 * f_s, 0.0)
        f_g = max(vg1[k] - f_fix, 0.0)
        out[k] = f_fix + n_g * f_g + n_s * f_s
    return out


def model_flops_per_device(rec: dict) -> float:
    n = rec["active_params"]
    chips = CHIPS[rec["mesh"]]
    from repro.configs.base import SHAPES

    shape = SHAPES[rec["shape"]]
    b, s = shape.global_batch, shape.seq_len
    if rec["mode"] == "train":
        total = 6.0 * n * b * s
    elif rec["mode"] == "prefill":
        total = 2.0 * n * b * s
    else:  # decode: one token per sequence
        total = 2.0 * n * b
    return total / chips


def compose_cell(rec: dict) -> Optional[dict]:
    """Roofline terms for one dry-run record (None if skipped/errored)."""
    if rec.get("status") != "ok":
        return None
    if rec.get("mode") == "gram":
        return None  # gram cells are reported separately (§Perf)
    arts = rec["artifacts"]
    if rec["mode"] == "decode":
        vec = _cost_vec(arts.get("analysis_unrolled", arts["main"]))
    elif "analysis_g1" in arts:  # hybrid
        n_g = len(rec.get("global_attn_layers", []))
        n_s = rec["num_layers"] - n_g
        vec = _hybrid(
            _cost_vec(arts["analysis_g1"]),
            _cost_vec(arts["analysis_gs2"]),
            _cost_vec(arts["analysis_ss2"]),
            n_g, n_s,
        )
    elif "analysis_l1" in arts:
        vec = _affine(
            _cost_vec(arts["analysis_l1"]),
            _cost_vec(arts["analysis_l2"]),
            rec["num_layers"],
        )
    else:  # no analysis variants: raw (loop-once — undercounts; flagged)
        vec = _cost_vec(arts["main"])

    coll_bytes = {k: vec[f"coll_{k}"] for k in COLLECTIVE_KINDS}
    compute_s = vec["flops"] / PEAK_FLOPS
    memory_s = vec["bytes"] / HBM_BW
    coll_s = collective_seconds(coll_bytes, LINK_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    bound = max(terms.values())
    out = {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "mode": rec["mode"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "hlo_flops_per_dev": vec["flops"],
        "hlo_bytes_per_dev": vec["bytes"],
        "collective_bytes_per_dev": coll_bytes,
        "model_flops_per_dev": mf,
        "useful_flop_ratio": round(mf / vec["flops"], 4) if vec["flops"] else 0.0,
        # roofline fraction: how close the dominant term is to the ideal
        # compute-only time (1.0 = perfectly compute-bound at model flops)
        "roofline_fraction": round((mf / PEAK_FLOPS) / bound, 4) if bound else 0.0,
        "peak_bytes_per_dev": rec["artifacts"]["main"]["memory"].get("peak_bytes_est"),
    }
    return out


def load_cells(dryrun_dir: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


_SUGGEST = {
    "compute": "raise MXU utilization: larger per-device microbatch or fewer "
               "remat recomputes (policy 'dots' where memory allows)",
    "memory": "cut HBM traffic: fuse/bf16-ify f32 intermediates, fewer remat "
              "round-trips, larger fused blocks",
    "collective": "cut collective volume: reduce-scatter instead of "
                  "all-reduce for grads (ZeRO), bf16 psums before f32 "
                  "upcasts, overlap via async collectives",
}


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | model/HLO flops | roofline frac | peak GiB/dev | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r is None:
            continue
        peak = r.get("peak_bytes_per_dev")
        peak_s = f"{peak/2**30:.2f}" if peak else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_flop_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} | {peak_s} | "
            f"{_SUGGEST[r['dominant']]} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()
    recs = load_cells(args.dryrun)
    rows = [compose_cell(r) for r in recs]
    rows = [r for r in rows if r]
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    md = render_markdown(rows)
    with open(os.path.join(args.out, "roofline.md"), "w") as f:
        f.write(md)
    print(md)


if __name__ == "__main__":
    main()
