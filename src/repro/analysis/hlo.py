"""HLO-text analysis: collective-byte accounting for the roofline model.

``collective_bytes(hlo)`` parses compiled (post-SPMD) HLO and sums the
result-buffer bytes of every collective op, keyed by op kind. Bytes are
**per device** (the compiled module is the per-device SPMD program), which
matches the per-device flop/byte numbers from ``compiled.cost_analysis()``.

Handles plain and async (``-start``/``-done``) forms — only starts are
counted — and tuple-shaped results. A plain variadic collective's tuple
elements are all payload and sum; an async ``-start`` whose result is a
tuple follows HLO's ``(operand, result[, contexts…])`` convention, so only
element 1 — the actual transferred buffer — is counted (summing would
double-count the payload via its operand alias).
"""

from __future__ import annotations

import re
from typing import Dict

__all__ = [
    "collective_bytes",
    "collective_seconds",
    "compiled_text",
    "DTYPE_BYTES",
    "COLLECTIVE_KINDS",
]

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# a shape like  bf16[128,1024]{1,0}  or  f32[] ; layout braces optional
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# an HLO instruction: `%name = <result-type> <opcode>(...)`
_INSTR_RE = re.compile(
    r"=\s+(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\b"
)


def compiled_text(fn, *abstract_args) -> str:
    """Lower + compile ``fn`` once and return the per-device HLO text —
    the input :func:`collective_bytes` parses.

    The single lowering path shared by every collective-accounting
    consumer (``repro.check``'s collective-budget rule,
    ``obs.metrics.record_collective_bytes`` call sites, the distributed
    benchmarks): callers that only need byte counts never hold the
    compiled executable, and nothing lowers the same function twice.
    ``fn`` may already be jitted (it is reused as-is) or a plain callable.
    """
    import jax

    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    return jitted.lower(*abstract_args).compile().as_text()


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue  # e.g. token[] / opaque
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _tuple_elems(type_str: str):
    """Top-level elements of a parenthesized tuple type, or ``None`` for a
    non-tuple result. Splits on commas outside ``[]``/``{}`` (shape dims and
    layouts carry commas of their own)."""
    if not (type_str.startswith("(") and type_str.endswith(")")):
        return None
    elems, depth, cur = [], 0, []
    for ch in type_str[1:-1]:
        if ch in "[{":
            depth += 1
        elif ch in "]}":
            depth -= 1
        elif ch == "," and depth == 0:
            elems.append("".join(cur))
            cur = []
            continue
        cur.append(ch)
    elems.append("".join(cur))
    return elems


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device result bytes of every collective, keyed by kind."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for m in _INSTR_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue  # counted at -start
        result = m.group("result")
        if m.group("suffix") == "-start":
            # async tuple result is (operand, result[, contexts…]): count
            # the transferred buffer only, not its aliased operand
            elems = _tuple_elems(result)
            if elems is not None and len(elems) >= 2:
                out[m.group("op")] += _shape_bytes(elems[1])
                continue
        out[m.group("op")] += _shape_bytes(result)
    return out


def collective_seconds(
    bytes_by_kind: Dict[str, int],
    link_bw: float = 50e9,
    scale: float = 1.0,
) -> float:
    """Time model: all-reduce moves ≈2× its buffer over the bottleneck link
    (ring reduce-scatter + all-gather); the others ≈1×. ``scale`` multiplies
    byte counts (used by the layer-differencing composition)."""
    factors = {
        "all-reduce": 2.0,
        "all-gather": 1.0,
        "reduce-scatter": 1.0,
        "all-to-all": 1.0,
        "collective-permute": 1.0,
    }
    t = 0.0
    for kind, b in bytes_by_kind.items():
        t += factors.get(kind, 1.0) * b * scale / link_bw
    return t
