"""Pallas TPU kernel for the triangular panel solve (trsm).

The second base-case engine of the packed solver layer
(``repro.solve``): given the lower-triangular diagonal factor tile ``L``
of one block column, solve

    X · Lᵀ = B      (``transpose=True``  — the factorization panel op:
                     ``L[i,j] = S[i,j]·L[j,j]⁻ᵀ`` of the blocked Cholesky)
    X · L  = B      (``transpose=False`` — the backward-substitution form:
                     ``Lᵀx = y  ⇔  xᵀ·L = yᵀ``)

for a row panel ``B``. Each row of ``X`` is independent, so the kernel
grid blocks the panel rows ("parallel") while the column recurrence runs
as ``n`` ``fori_loop`` steps of masked VPU updates inside the tile:

    X[:,j] = (B[:,j] − Σ_k X[:,k]·op(L)[k,j]) / L[j,j]

with ``j`` ascending for ``X·Lᵀ = B`` and descending for ``X·L = B``
(the factor row/column and the pivot are masked reductions — no dynamic
slicing, so one body serves Mosaic and interpret mode alike).

Batched: a leading stack dimension on BOTH operands (each panel entry has
its *own* factor tile, e.g. all block rows of all batch entries of a
Shampoo stat stack) becomes the leading grid dimension — one launch per
stack, per the package-wide batched-grid contract in ``repro.kernels``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params

__all__ = ["trsm_pallas"]


def _trsm_kernel(l_ref, b_ref, x_ref, *, nn: int, transpose: bool):
    l = l_ref[...].reshape(l_ref.shape[-2:]).astype(jnp.float32)
    b = b_ref[...].reshape(b_ref.shape[-2:]).astype(jnp.float32)
    mm = b.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (nn, nn), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (nn, nn), 1)
    k1d = row[:, 0]                                            # (nn,)
    bcol_ids = jax.lax.broadcasted_iota(jnp.int32, (mm, nn), 1)

    def body(step, x):
        j = step if transpose else nn - 1 - step
        d = jnp.sum(jnp.where((row == j) & (col == j), l, 0.0))
        if transpose:
            # op(L)[k, j] = L[j, k], known entries k < j
            lvec = jnp.sum(jnp.where(row == j, l, 0.0), axis=0)
            lvec = jnp.where(k1d < j, lvec, 0.0)
        else:
            # op(L)[k, j] = L[k, j], known entries k > j
            lvec = jnp.sum(jnp.where(col == j, l, 0.0), axis=1)
            lvec = jnp.where(k1d > j, lvec, 0.0)
        acc = jnp.sum(x * lvec[None, :], axis=1)               # X·op(L)[:,j]
        bj = jnp.sum(jnp.where(bcol_ids == j, b, 0.0), axis=1)
        return jnp.where(bcol_ids == j, ((bj - acc) / d)[:, None], x)

    x = jax.lax.fori_loop(0, nn, body, jnp.zeros((mm, nn), jnp.float32))
    x_ref[...] = x.astype(x_ref.dtype).reshape(x_ref.shape)


def _pad_rows(x, mult):
    m = x.shape[-2]
    pm = (-m) % mult
    if pm:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, 0)])
    return x


@functools.partial(
    jax.jit, static_argnames=("transpose", "block_rows", "interpret", "out_dtype")
)
def trsm_pallas(
    l: jax.Array,
    b: jax.Array,
    *,
    transpose: bool = True,
    block_rows: int = 256,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Solve ``X·Lᵀ = B`` (``transpose=True``) or ``X·L = B`` against the
    lower-triangular ``l: (n, n)`` or stacked ``(B, n, n)``, panel
    ``b: (m, n)`` or ``(B, m, n)``.

    The panel rows are blocked over a parallel grid dimension (rows are
    independent); a leading batch dim becomes the leading grid dimension —
    one launch for the whole stack (the ``repro.kernels`` contract).
    """
    if l.ndim not in (2, 3) or l.shape[-1] != l.shape[-2]:
        raise ValueError(f"trsm expects (n, n) or (B, n, n) factor, got {l.shape}")
    if b.ndim != l.ndim or b.shape[-1] != l.shape[-1] or b.shape[:-2] != l.shape[:-2]:
        raise ValueError(f"bad trsm shapes: {l.shape} x {b.shape}")
    batched = b.ndim == 3
    m, nn = b.shape[-2:]
    bm = min(block_rows, max(8, -(-m // 8) * 8))
    b_pad = _pad_rows(b, bm)
    mp = b_pad.shape[-2]

    lead = (1,) if batched else ()
    batch_dims = b.shape[:-2]
    grid = batch_dims + (mp // bm,)
    _pre = lambda idx: idx[:-1]  # () unbatched, (b,) batched

    out = pl.pallas_call(
        functools.partial(_trsm_kernel, nn=nn, transpose=transpose),
        grid=grid,
        in_specs=[
            pl.BlockSpec(lead + (nn, nn), lambda *idx: _pre(idx) + (0, 0)),
            pl.BlockSpec(lead + (bm, nn), lambda *idx: _pre(idx) + (idx[-1], 0)),
        ],
        out_specs=pl.BlockSpec(
            lead + (bm, nn), lambda *idx: _pre(idx) + (idx[-1], 0)
        ),
        out_shape=jax.ShapeDtypeStruct(batch_dims + (mp, nn), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",) * len(grid),
        ),
        interpret=interpret,
        name="trsm_t" if transpose else "trsm_n",
    )(l, b_pad)
    return out[..., :m, :]
