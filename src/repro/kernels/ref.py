"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references across a
shape × dtype sweep (see ``tests/test_kernels.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["syrk_ref", "gemm_tn_ref"]


def gemm_tn_ref(a: jax.Array, b: jax.Array, alpha: float = 1.0) -> jax.Array:
    """``C = alpha·AᵀB`` with f32 accumulation, f32 output."""
    out = jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    return (alpha * out).astype(jnp.float32)


def syrk_ref(a: jax.Array, alpha: float = 1.0) -> jax.Array:
    """``C = alpha·AᵀA`` full symmetric, f32 accumulation/output.

    Mirrors the kernel's exact-symmetry contract: the lower triangle is
    computed and reflected, so ``C == Cᵀ`` bitwise.
    """
    c = gemm_tn_ref(a, a, alpha)
    low = jnp.tril(c)
    return low + jnp.tril(c, -1).T
