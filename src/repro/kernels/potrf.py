"""Pallas TPU kernel for the diagonal-block Cholesky ``A = L·Lᵀ`` (potrf).

This is the base-case engine of the packed blocked Cholesky
(``repro.solve.cholesky``): every diagonal block of the packed factor walk
is one ``bn × bn`` SPD tile, and under the batched-dispatch contract of the
stack (see the ``repro.kernels`` package docstring) a *stack* of diagonal
tiles — the per-level Shampoo stat batch — factors as **one** kernel
launch with the stack as the leading ("parallel") grid dimension.

In-kernel algorithm: the unblocked right-looking recurrence

    for j in 0..n-1:
        L[j,j]    = sqrt(A[j,j])
        L[j+1:,j] = A[j+1:,j] / L[j,j]
        A[j+1:,j+1:] -= L[j+1:,j]·L[j+1:,j]ᵀ

as ``n`` ``fori_loop`` steps of masked VPU column/rank-1 updates on the
VMEM-resident tile (column extraction and the diagonal pivot are masked
reductions — no dynamic slicing, so the same body compiles on Mosaic and
runs in interpret mode). The strictly-upper half of the output is zeroed:
the public contract is a *lower-triangular* factor tile, ready for packed
factor storage.

The sequential column loop is the nature of the factorization — ``potrf``
is O(n³/3) work on an O(n²) tile and sits on the recursion's critical path
only ``nb`` times per factorization (vs O(nb²) trsm/gemm panel work), so a
VPU-resident unblocked sweep is the right shape for ``bn ≤ 512`` tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params

__all__ = ["potrf_pallas"]


def _potrf_kernel(a_ref, l_ref, *, nn: int):
    a = a_ref[...].reshape(a_ref.shape[-2:]).astype(jnp.float32)
    row = jax.lax.broadcasted_iota(jnp.int32, (nn, nn), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (nn, nn), 1)

    def body(j, a):
        # masked pivot/column extraction (no dynamic slicing on the tile)
        d = jnp.sqrt(jnp.sum(jnp.where((row == j) & (col == j), a, 0.0)))
        colj = jnp.sum(jnp.where(col == j, a, 0.0), axis=1)     # A[:, j]
        below = jnp.where(row[:, 0] > j, colj / d, 0.0)          # L[j+1:, j]
        newcol = below + jnp.where(row[:, 0] == j, d, 0.0)
        a = jnp.where(col == j, newcol[:, None], a)
        # rank-1 Schur update — `below` is zero at rows ≤ j, so the outer
        # product touches exactly the trailing submatrix
        return a - below[:, None] * below[None, :]

    a = jax.lax.fori_loop(0, nn, body, a)
    a = jnp.where(row >= col, a, 0.0)  # lower-triangular factor contract
    l_ref[...] = a.astype(l_ref.dtype).reshape(l_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def potrf_pallas(
    a: jax.Array,
    *,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Lower Cholesky factor of SPD tile(s) ``a: (n, n)`` or ``(B, n, n)``.

    A leading batch dim becomes the leading grid dimension — one launch for
    the whole stack (the ``repro.kernels`` batched-grid contract). The
    strict upper triangle of each output tile is zero.
    """
    if a.ndim not in (2, 3) or a.shape[-1] != a.shape[-2]:
        raise ValueError(f"potrf expects (n, n) or (B, n, n) SPD input, got {a.shape}")
    batched = a.ndim == 3
    nn = a.shape[-1]
    lead = (1,) if batched else ()
    batch_dims = a.shape[:-2]
    grid = batch_dims + (1,)
    _pre = lambda idx: idx[:-1]  # () unbatched, (b,) batched

    return pl.pallas_call(
        functools.partial(_potrf_kernel, nn=nn),
        grid=grid,
        in_specs=[pl.BlockSpec(lead + (nn, nn), lambda *idx: _pre(idx) + (0, 0))],
        out_specs=pl.BlockSpec(lead + (nn, nn), lambda *idx: _pre(idx) + (0, 0)),
        out_shape=jax.ShapeDtypeStruct(batch_dims + (nn, nn), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",) * len(batch_dims) + ("arbitrary",),
        ),
        interpret=interpret,
        name="potrf",
    )(a)
