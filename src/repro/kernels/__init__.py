"""Pallas TPU kernels for the paper's compute hot-spots.

* ``syrk``   — lower-triangular ``alpha·AᵀA`` (ATA base case; the paper's
  symmetric saving at the tile level).
* ``gemm_tn``— TN matmul ``alpha·AᵀB`` (FastStrassen base case; Aᵀ never
  materialized).

``ops`` holds the jit'd public wrappers (interpret-mode on CPU); ``ref``
holds the pure-jnp oracles used by the kernel test sweeps.
"""

from repro.kernels import ops, ref
from repro.kernels.ops import gemm_tn, syrk

__all__ = ["ops", "ref", "gemm_tn", "syrk"]
