"""Pallas TPU kernels for the paper's compute hot-spots.

* ``syrk``   — lower-triangular ``alpha·AᵀA`` (ATA base case; the paper's
  symmetric saving at the tile level).
* ``gemm_tn``— TN matmul ``alpha·AᵀB`` (FastStrassen base case; Aᵀ never
  materialized).
* ``potrf``  — diagonal-block Cholesky (packed-solver base case: one SPD
  ``bn×bn`` tile → its lower factor).
* ``trsm``   — triangular panel solve ``X·Lᵀ = B`` / ``X·L = B`` (the
  blocked-Cholesky panel op and the substitution engine of
  ``repro.solve``).

Three package-wide contracts, stated here once and honored by ALL FOUR
kernels (``repro.kernels.{syrk, gemm_tn, potrf, trsm}``) and their public
wrappers (``repro.kernels.ops``):

* **Interpret mode** (``ops.interpret_default()``): ``interpret=None`` at a
  wrapper resolves to ``jax.default_backend() != "tpu"`` — compiled Mosaic
  on a real TPU, Pallas interpret mode (the kernel body executed in Python
  by XLA, for correctness work) everywhere else. It is a *backend* property,
  not a debug flag: pass ``interpret=`` explicitly only to force one mode.

* **Batched grid** (leading dim = leaf batch): an optional leading operand
  dimension becomes the leading (``"parallel"``) grid dimension — the whole
  batch is ONE kernel launch, never a vmap-of-pallas (machine-checked: the
  ``no-vmap-of-pallas`` rule of ``repro.check`` flags any traced
  ``pallas_call`` with nonempty ``grid_mapping.vmapped_dims``; launch
  counts are policed by ``launch-budget``). The batched-leaf
  recursion (``Plan.leaf_dispatch='batched'``) relies on this: it flattens
  its leaf stack (and any operand batch) into exactly that one leading dim,
  so all ``7^L`` Strassen leaves / all ``4^L`` diagonal leaves land in a
  single launch. The packed Cholesky walk (``repro.solve.cholesky``) leans
  on the same contract: each block column factors its whole panel stack —
  batch dims × panel rows — as ONE ``trsm`` launch, and a batched stat
  stack's diagonal tiles as ONE ``potrf`` launch.

* **Coefficient tables** (fused-operand leaves): the fused leaf launches
  (``ops.gemm_tn_fused``, ``ops.syrk_gather`` — the
  ``Plan.leaf_dispatch='fused'`` engines) take their operands in the
  block-major leaf-grid layout of ``core.strassen._to_blocks`` plus
  per-leaf int32 ``(rows, cols, sign)`` slot tables
  (``core.strassen._slot_tables``), passed as scalar-prefetch operands.
  The kernel PROLOGUE gathers each slot block through the tables in its
  index maps and combines them as the recursion's balanced ± add tree
  before the MXU dot; the epilogue writes one product per leaf into the
  level's decode stack. No operand-combination stack is ever materialized
  in HBM — the combine traffic the batched dispatch pays simply does not
  exist (machine-checked: the ``no-operand-stacks`` rule of ``repro.check``
  flags any 7-multiple leaf-operand stack in a fused-dispatch jaxpr). The blocked dot inside (chunk shapes, contraction order, f32
  VMEM accumulation, flush cast) is identical to the unbatched kernels',
  which is what keeps all three leaf dispatches bitwise-equal for f32/f64
  operands (sub-f32 operands forfeit bitwise: the in-kernel combine feeds
  the dot inside one XLA computation, where float normalization may keep
  bf16 adds at f32 precision — more accurate than the trace-time gather,
  which rounds at the pallas input boundary); sign-0
  (dead) slots contribute an exact ±0 instead of being dropped, so the
  fused launch is value-equal to the trace-time gather (it may flip the
  sign of a zero — invisible to ``==``). Same kernel body for Mosaic and
  interpret mode, like everything else here.

``ops`` holds the jit'd public wrappers; ``ref`` holds the pure-jnp oracles
used by the kernel test sweeps.
"""

from repro.kernels import ops, ref
from repro.kernels.ops import gemm_tn, potrf, syrk, trsm

__all__ = ["ops", "ref", "gemm_tn", "syrk", "potrf", "trsm"]
