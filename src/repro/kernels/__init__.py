"""Pallas TPU kernels for the paper's compute hot-spots.

* ``syrk``   — lower-triangular ``alpha·AᵀA`` (ATA base case; the paper's
  symmetric saving at the tile level).
* ``gemm_tn``— TN matmul ``alpha·AᵀB`` (FastStrassen base case; Aᵀ never
  materialized).

Two package-wide contracts, stated here once and honored by BOTH kernels
(``repro.kernels.syrk``, ``repro.kernels.gemm_tn``) and their public
wrappers (``repro.kernels.ops``):

* **Interpret mode** (``ops.interpret_default()``): ``interpret=None`` at a
  wrapper resolves to ``jax.default_backend() != "tpu"`` — compiled Mosaic
  on a real TPU, Pallas interpret mode (the kernel body executed in Python
  by XLA, for correctness work) everywhere else. It is a *backend* property,
  not a debug flag: pass ``interpret=`` explicitly only to force one mode.

* **Batched grid** (leading dim = leaf batch): an optional leading operand
  dimension becomes the leading (``"parallel"``) grid dimension — the whole
  batch is ONE kernel launch, never a vmap-of-pallas. The batched-leaf
  recursion (``Plan.leaf_dispatch='batched'``) relies on this: it flattens
  its leaf stack (and any operand batch) into exactly that one leading dim,
  so all ``7^L`` Strassen leaves / all ``4^L`` diagonal leaves land in a
  single launch.

``ops`` holds the jit'd public wrappers; ``ref`` holds the pure-jnp oracles
used by the kernel test sweeps.
"""

from repro.kernels import ops, ref
from repro.kernels.ops import gemm_tn, syrk

__all__ = ["ops", "ref", "gemm_tn", "syrk"]
