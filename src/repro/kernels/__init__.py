"""Pallas TPU kernels for the paper's compute hot-spots.

* ``syrk``   — lower-triangular ``alpha·AᵀA`` (ATA base case; the paper's
  symmetric saving at the tile level).
* ``gemm_tn``— TN matmul ``alpha·AᵀB`` (FastStrassen base case; Aᵀ never
  materialized).
* ``potrf``  — diagonal-block Cholesky (packed-solver base case: one SPD
  ``bn×bn`` tile → its lower factor).
* ``trsm``   — triangular panel solve ``X·Lᵀ = B`` / ``X·L = B`` (the
  blocked-Cholesky panel op and the substitution engine of
  ``repro.solve``).

Two package-wide contracts, stated here once and honored by ALL FOUR
kernels (``repro.kernels.{syrk, gemm_tn, potrf, trsm}``) and their public
wrappers (``repro.kernels.ops``):

* **Interpret mode** (``ops.interpret_default()``): ``interpret=None`` at a
  wrapper resolves to ``jax.default_backend() != "tpu"`` — compiled Mosaic
  on a real TPU, Pallas interpret mode (the kernel body executed in Python
  by XLA, for correctness work) everywhere else. It is a *backend* property,
  not a debug flag: pass ``interpret=`` explicitly only to force one mode.

* **Batched grid** (leading dim = leaf batch): an optional leading operand
  dimension becomes the leading (``"parallel"``) grid dimension — the whole
  batch is ONE kernel launch, never a vmap-of-pallas. The batched-leaf
  recursion (``Plan.leaf_dispatch='batched'``) relies on this: it flattens
  its leaf stack (and any operand batch) into exactly that one leading dim,
  so all ``7^L`` Strassen leaves / all ``4^L`` diagonal leaves land in a
  single launch. The packed Cholesky walk (``repro.solve.cholesky``) leans
  on the same contract: each block column factors its whole panel stack —
  batch dims × panel rows — as ONE ``trsm`` launch, and a batched stat
  stack's diagonal tiles as ONE ``potrf`` launch.

``ops`` holds the jit'd public wrappers; ``ref`` holds the pure-jnp oracles
used by the kernel test sweeps.
"""

from repro.kernels import ops, ref
from repro.kernels.ops import gemm_tn, potrf, syrk, trsm

__all__ = ["ops", "ref", "gemm_tn", "syrk", "potrf", "trsm"]
