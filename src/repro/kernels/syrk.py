"""Pallas TPU kernel for the symmetric product ``C = alpha·AᵀA`` (syrk).

This is the base-case engine of ATA on TPU and carries the paper's key
block-level saving: **only lower-triangular output blocks are computed**
(the strictly-upper blocks are never visited by the grid), halving both MXU
work and HBM write traffic versus a general TN matmul — the TPU analogue of
the paper computing only ``low(C)`` at every level.

Grid design: a **packed triangular grid** ``(T, m/bm)`` where
``T = nb·(nb+1)/2`` enumerates the lower-triangular block pairs. Pallas TPU
grids are rectangular, so the block coordinates are recovered inside the
index maps from the triangular index ``t``:

    i = ⌊(√(8t+1) − 1)/2⌋,   j = t − i(i+1)/2      (j ≤ i)

(computed in f32 — exact for every t < 2²³, far beyond any realistic block
count — with an integer correction step to be safe at the boundaries).
The contraction over ``m`` runs in the minor-most grid dimension with an f32
VMEM scratch accumulator, exactly like ``gemm_tn``.

The wrapper zeroes the never-written upper blocks (``jnp.tril``) and mirrors
the strict lower triangle, so the public output is *bitwise symmetric*.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["syrk_pallas", "DEFAULT_BLOCKS"]

# (bm, bn): contraction block, output block (output tiles are bn × bn).
DEFAULT_BLOCKS = (512, 256)


def _tri_coords(t):
    """Map packed triangular index t -> (i, j) with j <= i, traceably."""
    tf = t.astype(jnp.float32)
    i = jnp.floor((jnp.sqrt(8.0 * tf + 1.0) - 1.0) / 2.0).astype(jnp.int32)
    # integer boundary corrections (defensive against fp rounding)
    i = jnp.where((i + 1) * (i + 2) // 2 <= t, i + 1, i)
    i = jnp.where(i * (i + 1) // 2 > t, i - 1, i)
    j = t - i * (i + 1) // 2
    return i, j


def _syrk_kernel(ai_ref, aj_ref, c_ref, acc_ref, *, alpha: float):
    """One (t, l) grid step: acc += A[l, i(t)]ᵀ · A[l, j(t)]."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        ai_ref[...],
        aj_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _flush():
        c_ref[...] = (alpha * acc_ref[...]).astype(c_ref.dtype)


def _pad_to(x, mult0, mult1):
    m, n = x.shape
    pm = (-m) % mult0
    pn = (-n) % mult1
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(
    jax.jit, static_argnames=("alpha", "blocks", "interpret", "out_dtype")
)
def syrk_pallas(
    a: jax.Array,
    *,
    alpha: float = 1.0,
    blocks: tuple = DEFAULT_BLOCKS,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``C = alpha·AᵀA`` with A:(m,n) → C:(n,n), bitwise symmetric.

    Only the ``nb(nb+1)/2`` lower-triangular output blocks are computed;
    the strict upper triangle is a mirror.
    """
    if a.ndim != 2:
        raise ValueError(f"syrk expects 2-D input, got {a.shape}")
    m, n = a.shape
    bm, bn = blocks
    bm = min(bm, max(8, -(-m // 8) * 8))
    bn = min(bn, max(128, -(-n // 128) * 128))

    a = _pad_to(a, bm, bn)
    mp, np_ = a.shape
    nb = np_ // bn
    t_total = nb * (nb + 1) // 2

    # row-block i(t) and col-block j(t) recovered from the packed index.
    def _ai_index(t, l):
        i, _ = _tri_coords(t)
        return (l, i)

    def _aj_index(t, l):
        _, j = _tri_coords(t)
        return (l, j)

    def _c_index(t, l):
        i, j = _tri_coords(t)
        return (i, j)

    raw = pl.pallas_call(
        functools.partial(_syrk_kernel, alpha=alpha),
        grid=(t_total, mp // bm),
        in_specs=[
            pl.BlockSpec((bm, bn), _ai_index),
            pl.BlockSpec((bm, bn), _aj_index),
        ],
        out_specs=pl.BlockSpec((bn, bn), _c_index),
        out_shape=jax.ShapeDtypeStruct((np_, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="syrk_lower",
    )(a, a)

    raw = raw[:n, :n]
    low = jnp.tril(raw)  # upper blocks were never written — discard garbage
    return low + jnp.tril(raw, -1).T
