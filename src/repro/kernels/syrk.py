"""Pallas TPU kernel for the symmetric product ``C = alpha·AᵀA`` (syrk).

This is the base-case engine of ATA on TPU and carries the paper's key
block-level saving: **only lower-triangular output blocks are computed**
(the strictly-upper blocks are never visited by the grid), halving both MXU
work and HBM write traffic versus a general TN matmul — the TPU analogue of
the paper computing only ``low(C)`` at every level.

Grid design: a **packed triangular grid** ``([B,] T, m/bm)`` where
``T = nb·(nb+1)/2`` enumerates the lower-triangular block pairs. The
optional leading batch dimension follows the package-wide batched-grid
contract (see the ``repro.kernels`` docstring: leading dim = leaf batch,
one launch per stack, never vmap-of-pallas — the batched-leaf recursion
lands all its diagonal leaves here in one call). Pallas TPU grids are
rectangular, so the block coordinates are recovered inside the index maps
from the triangular index ``t``:

    i = ⌊(√(8t+1) − 1)/2⌋,   j = t − i(i+1)/2      (j ≤ i)

(computed in f32 — exact for every t < 2²³, far beyond any realistic block
count — with an integer correction step to be safe at the boundaries).
The contraction over ``m`` runs in the minor-most grid dimension with an f32
VMEM scratch accumulator, exactly like ``gemm_tn``.

Output modes — both mirror-free (the seed's ``tril + mirror`` post-pass over
n² elements is gone):

* ``out='packed'``: the kernel writes the ``T`` lower-triangular blocks
  straight into packed ``(T, bn, bn)`` storage — ``nb(nb+1)/2`` output
  blocks allocated instead of ``nb²`` — returned as a
  :class:`repro.core.symmetric.SymmetricMatrix`. Diagonal tiles are
  symmetrized *in-kernel* at tile granularity (an O(n·bn) cost).

* ``out='dense'``: in-kernel **dual-write**. The contraction grid dimension
  carries one extra trailing step per block pair: after the lower block
  ``C[i,j]`` is flushed, the extra step retargets the output index map at
  ``C[j,i]`` and stores the transposed tile from the still-resident VMEM
  accumulator (diagonal pairs re-store the symmetrized tile instead). Every
  one of the nb² blocks is written exactly once; the public output is
  bitwise symmetric with no elementwise post-pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.symmetric import SymmetricMatrix, default_block_size, sym_tile

# (bm, bn): contraction block, output block (output tiles are bn × bn).
# The constant lives with every other tunable in repro.tune.defaults; the
# autotuner sweeps alternatives per shape (repro.tune.plan → syrk_blocks).
from repro.tune.defaults import SYRK_BLOCKS as DEFAULT_BLOCKS

__all__ = ["syrk_pallas", "syrk_gather_pallas", "DEFAULT_BLOCKS"]


def _tri_coords(t):
    """Map packed triangular index t -> (i, j) with j <= i, traceably."""
    tf = t.astype(jnp.float32)
    i = jnp.floor((jnp.sqrt(8.0 * tf + 1.0) - 1.0) / 2.0).astype(jnp.int32)
    # integer boundary corrections (defensive against fp rounding)
    i = jnp.where((i + 1) * (i + 2) // 2 <= t, i + 1, i)
    i = jnp.where(i * (i + 1) // 2 > t, i - 1, i)
    j = t - i * (i + 1) // 2
    return i, j


def _syrk_kernel(
    ai_ref, aj_ref, c_ref, acc_ref, *, alpha: float, t_axis: int, n_l: int, packed: bool
):
    """One grid step: acc += A[l, i(t)]ᵀ · A[l, j(t)], plus the mode's writes.

    In dense (dual-write) mode the contraction axis has ``n_l + 1`` steps;
    the trailing step stores the mirrored tile while the accumulator is still
    resident in VMEM.
    """
    l_axis = t_axis + 1
    l = pl.program_id(l_axis)
    t = pl.program_id(t_axis)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(l < n_l)
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            ai_ref[...].reshape(ai_ref.shape[-2:]),
            aj_ref[...].reshape(aj_ref.shape[-2:]),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    if packed:

        @pl.when(l == n_l - 1)
        def _flush_packed():
            out = (alpha * acc_ref[...]).astype(c_ref.dtype)
            i, j = _tri_coords(t)
            c_ref[...] = jnp.where(i == j, sym_tile(out), out).reshape(c_ref.shape)

    else:

        @pl.when(l == n_l - 1)
        def _flush_lower():
            out = (alpha * acc_ref[...]).astype(c_ref.dtype)
            c_ref[...] = out.reshape(c_ref.shape)

        @pl.when(l == n_l)
        def _flush_mirror():
            out = (alpha * acc_ref[...]).astype(c_ref.dtype)
            i, j = _tri_coords(t)
            # off-diagonal: the (j, i) block is the transposed tile; diagonal
            # pairs re-store the symmetrized tile into the same (i, i) slot.
            c_ref[...] = jnp.where(i == j, sym_tile(out), out.T).reshape(c_ref.shape)


def _pad_to(x, mult0, mult1):
    m, n = x.shape[-2:]
    pm = (-m) % mult0
    pn = (-n) % mult1
    if pm or pn:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)])
    return x


@functools.partial(
    jax.jit, static_argnames=("alpha", "blocks", "interpret", "out_dtype", "out")
)
def syrk_pallas(
    a: jax.Array,
    *,
    alpha: float = 1.0,
    blocks: tuple = DEFAULT_BLOCKS,
    interpret: bool = False,
    out_dtype=jnp.float32,
    out: str = "dense",
):
    """``C = alpha·AᵀA`` with A:(m,n) or (B,m,n).

    ``out='dense'`` → ``(..., n, n)``, bitwise symmetric, written once per
    block by the in-kernel dual-write (no mirror post-pass).
    ``out='packed'`` → :class:`SymmetricMatrix` holding the ``nb(nb+1)/2``
    lower-triangular blocks the grid computes — nothing else is allocated.
    """
    if a.ndim not in (2, 3):
        raise ValueError(f"syrk expects (m, n) or (B, m, n) input, got {a.shape}")
    if out not in ("dense", "packed"):
        raise ValueError(f"unknown output mode {out!r}; use 'dense' or 'packed'")
    batched = a.ndim == 3
    m, n = a.shape[-2:]
    bm, bn = blocks
    bm = min(bm, max(8, -(-m // 8) * 8))
    if out == "packed":
        # packed storage shares one block-size clamp across ALL producers
        # (symmetric.default_block_size) regardless of backend, so layouts
        # are always add-compatible and a small matrix is never padded up to
        # a huge single block. The clamp yields lane-unaligned blocks for
        # ragged n (e.g. 104 for n=200); Mosaic surfaces its own error for
        # sizes it cannot tile — on TPU, keep n and the requested block at
        # multiples of 128 (production gram shapes already are).
        bn = default_block_size(n, bn)
    else:
        bn = min(bn, max(128, -(-n // 128) * 128))

    a = _pad_to(a, bm, bn)
    mp, np_ = a.shape[-2:]
    nb = np_ // bn
    t_total = nb * (nb + 1) // 2
    n_l = mp // bm
    t_axis = 1 if batched else 0

    kernel = functools.partial(
        _syrk_kernel,
        alpha=alpha,
        t_axis=t_axis,
        n_l=n_l,
        packed=(out == "packed"),
    )
    # dense mode appends the dual-write step to the contraction axis.
    l_steps = n_l if out == "packed" else n_l + 1
    l_clamp = lambda l: jnp.minimum(l, n_l - 1)

    # one spec construction for both layouts: the batched case prepends the
    # batch coordinate to the grid, every block shape, and every index map.
    lead = (1,) if batched else ()
    batch_dims = a.shape[:-2]
    grid = batch_dims + (t_total, l_steps)
    _pre = lambda idx: idx[:-2]  # () unbatched, (b,) batched

    def _a_index(which):
        return lambda *idx: _pre(idx) + (
            l_clamp(idx[-1]), _tri_coords(idx[-2])[which]
        )

    in_specs = [
        pl.BlockSpec(lead + (bm, bn), _a_index(0)),
        pl.BlockSpec(lead + (bm, bn), _a_index(1)),
    ]
    if out == "packed":
        out_specs = pl.BlockSpec(
            lead + (1, bn, bn), lambda *idx: _pre(idx) + (idx[-2], 0, 0)
        )
        out_shape = jax.ShapeDtypeStruct(batch_dims + (t_total, bn, bn), out_dtype)
    else:

        def _c_index(*idx):
            i, j = _tri_coords(idx[-2])
            lower = idx[-1] < n_l
            return _pre(idx) + (jnp.where(lower, i, j), jnp.where(lower, j, i))

        out_specs = pl.BlockSpec(lead + (bn, bn), _c_index)
        out_shape = jax.ShapeDtypeStruct(batch_dims + (np_, np_), out_dtype)
    dim_sem = ("parallel",) * (len(grid) - 1) + ("arbitrary",)

    raw = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(dimension_semantics=dim_sem),
        interpret=interpret,
        name="syrk_packed" if out == "packed" else "syrk_dual",
    )(a, a)

    if out == "packed":
        return SymmetricMatrix(raw, n=n, bn=bn)
    return raw[..., :n, :n]


# ---------------------------------------------------------------------------
# gathered diagonal-leaf launch (leaf_dispatch='fused')
#
# Per the repro.kernels coefficient-table contract: the ATA recursion's
# fused dispatch hands this kernel the block-major leaf grid of
# `core.strassen._to_blocks` plus prefetched (row, col) index tables, and
# the PROLOGUE's index maps pull each diagonal slab straight out of the
# grid — the `(4^L, …)` gathered stack of the batched dispatch is never
# materialized. The grid, kernel body (`_syrk_kernel`, dense dual-write)
# and block clamps are identical to `syrk_pallas` on the equivalent
# stacked input, which keeps the fused diagonal bitwise-equal to the
# batched one. Diagonal coefficients are trivially +1, so the tables here
# are pure gather indices — the ± structure lives in the gemm twin
# (`gemm_tn_fused_pallas`).
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("alpha", "blocks", "interpret", "out_dtype")
)
def syrk_gather_pallas(
    a_blocks: jax.Array,
    rows: jax.Array,
    cols: jax.Array,
    *,
    alpha: float = 1.0,
    blocks: tuple = DEFAULT_BLOCKS,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """``C[s] = alpha·ÂᵀÂ`` with ``Â = a_blocks[rows[s], cols[s]]``.

    ``a_blocks``: ``(R, C, [B,] mL, nL)`` block-major leaf grid;
    ``rows``/``cols``: ``(S,)`` int32 gather tables. Returns the dense
    ``(S, [B,] nL, nL)`` stack — one launch for every diagonal leaf, the
    gather running in the kernel's index maps.
    """
    if a_blocks.ndim not in (4, 5):
        raise ValueError(f"bad gathered block grid: {a_blocks.shape}")
    batched = a_blocks.ndim == 5
    s_count = rows.shape[0]
    m, n = a_blocks.shape[-2:]
    bm, bn = blocks
    # the same clamp rule as `syrk_pallas` dense mode on one (mL, nL) leaf
    bm = min(bm, max(8, -(-m // 8) * 8))
    bn = min(bn, max(128, -(-n // 128) * 128))

    a_blocks = _pad_to(a_blocks, bm, bn)
    mp, np_ = a_blocks.shape[-2:]
    nb = np_ // bn
    t_total = nb * (nb + 1) // 2
    n_l = mp // bm
    t_axis = 2 if batched else 1

    def kernel(rows_ref, cols_ref, *refs):
        del rows_ref, cols_ref  # consumed by the index maps
        _syrk_kernel(*refs, alpha=alpha, t_axis=t_axis, n_l=n_l, packed=False)

    l_clamp = lambda l: jnp.minimum(l, n_l - 1)

    lead = (1,) if batched else ()
    batch_dims = a_blocks.shape[2:-2]
    grid = (s_count,) + batch_dims + (t_total, n_l + 1)
    _pre = lambda idx: idx[1:-2]  # () unbatched, (b,) batched

    def _a_index(which):
        def index(*args):
            idx, rows_ref, cols_ref = args[:-2], args[-2], args[-1]
            return (rows_ref[idx[0]], cols_ref[idx[0]]) + _pre(idx) + (
                l_clamp(idx[-1]), _tri_coords(idx[-2])[which]
            )

        return index

    def _c_index(*args):
        idx = args[:-2]
        i, j = _tri_coords(idx[-2])
        lower = idx[-1] < n_l
        return (idx[0],) + _pre(idx) + (
            jnp.where(lower, i, j), jnp.where(lower, j, i)
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1) + lead + (bm, bn), _a_index(0)),
            pl.BlockSpec((1, 1) + lead + (bm, bn), _a_index(1)),
        ],
        out_specs=pl.BlockSpec((1,) + lead + (bn, bn), _c_index),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
    )
    raw = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (s_count,) + batch_dims + (np_, np_), out_dtype
        ),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",) * (len(grid) - 1) + ("arbitrary",),
        ),
        interpret=interpret,
        name="syrk_gather",
    )(jnp.asarray(rows), jnp.asarray(cols), a_blocks, a_blocks)
    return raw[..., :n, :n]
