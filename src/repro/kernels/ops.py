"""Public jit'd wrappers for the Pallas kernels.

Both package-wide contracts — interpret-mode resolution and the batched
grid (leading dim = leaf batch, one launch per stack) — are stated once in
the ``repro.kernels`` package docstring; the wrappers here implement them.
``repro.core.ata``/``strassen_tn`` accept these as ``base_syrk``/``base_dot``
so the whole recursion bottoms out in the kernels — including the
level-synchronous ``leaf_dispatch='batched'`` recursion, which hands each
wrapper its entire leaf stack as the one leading batch dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.gemm_tn import DEFAULT_BLOCKS as GEMM_BLOCKS
from repro.kernels.gemm_tn import gemm_tn_fused_pallas, gemm_tn_pallas
from repro.kernels.potrf import potrf_pallas
from repro.kernels.syrk import DEFAULT_BLOCKS as SYRK_BLOCKS
from repro.kernels.syrk import syrk_gather_pallas, syrk_pallas
from repro.kernels.trsm import trsm_pallas

__all__ = [
    "syrk", "gemm_tn", "gemm_tn_fused", "syrk_gather", "potrf", "trsm",
    "interpret_default",
]


def interpret_default() -> bool:
    """Pallas interpret mode unless running on a real TPU.

    The canonical resolution of ``interpret=None`` for every wrapper in
    this module (see the ``repro.kernels`` package docstring): compiled
    Mosaic on TPU, interpret mode on any other backend.
    """
    return jax.default_backend() != "tpu"


def syrk(
    a,
    *,
    alpha: float = 1.0,
    blocks=None,
    plan=None,
    interpret=None,
    out_dtype=jnp.float32,
    out: str = "dense",
):
    """``alpha·AᵀA`` via the Pallas lower-triangular syrk kernel.

    Accepts ``(m, n)`` or batched ``(B, m, n)`` input — the batch runs as
    the leading grid dimension, one launch for the whole stack (the
    ``repro.kernels`` batched-grid contract). ``out='packed'`` returns the
    mirror-free :class:`repro.core.symmetric.SymmetricMatrix` form;
    ``out='dense'`` uses the in-kernel dual-write (no mirror post-pass).
    Block shapes come from ``blocks``, else the ``plan`` (a
    :class:`repro.tune.Plan`), else the tuned defaults. ``interpret=None``
    resolves via :func:`interpret_default`.
    """
    if interpret is None:
        interpret = interpret_default()
    if blocks is None and plan is not None:
        blocks = plan.syrk_blocks
    obs.metrics.inc("kernels.launch.syrk")
    with obs.span("kernels.syrk", interpret=interpret):
        return syrk_pallas(
            a,
            alpha=alpha,
            blocks=tuple(blocks or SYRK_BLOCKS),
            interpret=interpret,
            out_dtype=out_dtype,
            out=out,
        )


def gemm_tn(
    a,
    b,
    *,
    alpha: float = 1.0,
    blocks=None,
    plan=None,
    interpret=None,
    out_dtype=jnp.float32,
):
    """``alpha·AᵀB`` via the Pallas TN matmul kernel.

    Accepts ``(m, n) × (m, k)`` or batched ``(B, m, n) × (B, m, k)`` — the
    batch is the leading grid dimension, one launch for the whole stack
    (the ``repro.kernels`` batched-grid contract; this is where the
    batched-leaf recursion lands its ``7^L`` Strassen leaves). Blocks from
    the argument, else the ``plan``, else the tuned defaults;
    ``interpret=None`` resolves via :func:`interpret_default`.
    """
    if interpret is None:
        interpret = interpret_default()
    if blocks is None and plan is not None:
        blocks = plan.gemm_blocks
    obs.metrics.inc("kernels.launch.gemm_tn")
    with obs.span("kernels.gemm_tn", interpret=interpret):
        return gemm_tn_pallas(
            a,
            b,
            alpha=alpha,
            blocks=tuple(blocks or GEMM_BLOCKS),
            interpret=interpret,
            out_dtype=out_dtype,
        )


def gemm_tn_fused(
    a_blocks,
    b_blocks,
    tables,
    *,
    alpha: float = 1.0,
    blocks=None,
    plan=None,
    interpret=None,
    out_dtype=jnp.float32,
):
    """All ``G·T`` fused-operand Strassen leaf products in ONE launch.

    The ``leaf_dispatch='fused'`` leaf engine (the ``repro.kernels``
    coefficient-table contract): ``a_blocks``/``b_blocks`` are block-major
    leaf grids (`core.strassen._to_blocks`), ``tables`` the per-leaf
    ``(rows, cols, sign)`` slot tables (`core.strassen._slot_tables`); the
    ±1 combinations run in the kernel prologue against the prefetched
    tables — zero materialized operand stacks. Blocks from the argument,
    else the ``plan``, else the tuned defaults; ``interpret=None``
    resolves via :func:`interpret_default`.
    """
    if interpret is None:
        interpret = interpret_default()
    if blocks is None and plan is not None:
        blocks = plan.gemm_blocks
    obs.metrics.inc("kernels.launch.gemm_tn_fused")
    with obs.span("kernels.gemm_tn_fused", interpret=interpret):
        return gemm_tn_fused_pallas(
            a_blocks,
            b_blocks,
            tables,
            alpha=alpha,
            blocks=tuple(blocks or GEMM_BLOCKS),
            interpret=interpret,
            out_dtype=out_dtype,
        )


def syrk_gather(
    a_blocks,
    rows,
    cols,
    *,
    alpha: float = 1.0,
    blocks=None,
    plan=None,
    interpret=None,
    out_dtype=jnp.float32,
):
    """All gathered diagonal leaves ``a_blocks[rows[s], cols[s]]ᵀ·(…)`` in
    ONE launch (dense output stack).

    The diagonal half of the fused dispatch's coefficient-table contract:
    the gather indices feed the kernel's index maps, so the ``(4^L, …)``
    diagonal slab stack of the batched dispatch is never materialized.
    Blocks from the argument, else the ``plan``, else the tuned defaults;
    ``interpret=None`` resolves via :func:`interpret_default`.
    """
    if interpret is None:
        interpret = interpret_default()
    if blocks is None and plan is not None:
        blocks = plan.syrk_blocks
    obs.metrics.inc("kernels.launch.syrk_gather")
    with obs.span("kernels.syrk_gather", interpret=interpret):
        return syrk_gather_pallas(
            a_blocks,
            rows,
            cols,
            alpha=alpha,
            blocks=tuple(blocks or SYRK_BLOCKS),
            interpret=interpret,
            out_dtype=out_dtype,
        )


def potrf(a, *, interpret=None, out_dtype=jnp.float32):
    """Lower Cholesky factor of SPD tile(s) via the Pallas potrf kernel.

    Accepts ``(n, n)`` or a stacked ``(B, n, n)`` — the stack runs as the
    leading grid dimension, one launch for the whole batch (the
    ``repro.kernels`` batched-grid contract: a batched Shampoo stat stack
    factors its diagonal blocks in ONE launch per block column).
    ``interpret=None`` resolves via :func:`interpret_default`.
    """
    if interpret is None:
        interpret = interpret_default()
    obs.metrics.inc("kernels.launch.potrf")
    with obs.span("kernels.potrf", interpret=interpret):
        return potrf_pallas(a, interpret=interpret, out_dtype=out_dtype)


def trsm(l, b, *, transpose=True, interpret=None, out_dtype=jnp.float32):
    """Triangular panel solve ``X·Lᵀ = B`` (or ``X·L = B``) via the Pallas
    trsm kernel — the blocked-Cholesky panel op and the building block of
    the packed forward/backward substitution (``repro.solve.triangular``).

    Accepts ``(n, n) × (m, n)`` or stacked ``(B, n, n) × (B, m, n)`` — the
    stack is the leading grid dimension, one launch per panel stack.
    ``interpret=None`` resolves via :func:`interpret_default`.
    """
    if interpret is None:
        interpret = interpret_default()
    obs.metrics.inc("kernels.launch.trsm")
    with obs.span("kernels.trsm", interpret=interpret):
        return trsm_pallas(l, b, transpose=transpose, interpret=interpret,
                           out_dtype=out_dtype)
