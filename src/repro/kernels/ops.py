"""Public jit'd wrappers for the Pallas kernels.

On TPU the kernels compile through Mosaic; on CPU (this container) they run
in ``interpret=True`` mode, which executes the kernel body in Python for
correctness validation. ``repro.core.ata``/``strassen_tn`` accept these as
``base_syrk``/``base_dot`` so the whole recursion bottoms out in the kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gemm_tn import DEFAULT_BLOCKS as GEMM_BLOCKS
from repro.kernels.gemm_tn import gemm_tn_pallas
from repro.kernels.syrk import DEFAULT_BLOCKS as SYRK_BLOCKS
from repro.kernels.syrk import syrk_pallas

__all__ = ["syrk", "gemm_tn", "interpret_default"]


def interpret_default() -> bool:
    """Pallas interpret mode unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def syrk(
    a,
    *,
    alpha: float = 1.0,
    blocks=None,
    plan=None,
    interpret=None,
    out_dtype=jnp.float32,
    out: str = "dense",
):
    """``alpha·AᵀA`` via the Pallas lower-triangular syrk kernel.

    Accepts ``(m, n)`` or batched ``(B, m, n)`` input (the batch runs as a
    leading grid dimension — one launch). ``out='packed'`` returns the
    mirror-free :class:`repro.core.symmetric.SymmetricMatrix` form;
    ``out='dense'`` uses the in-kernel dual-write (no mirror post-pass).
    Block shapes come from ``blocks``, else the ``plan`` (a
    :class:`repro.tune.Plan`), else the tuned defaults.
    """
    if interpret is None:
        interpret = interpret_default()
    if blocks is None and plan is not None:
        blocks = plan.syrk_blocks
    return syrk_pallas(
        a,
        alpha=alpha,
        blocks=tuple(blocks or SYRK_BLOCKS),
        interpret=interpret,
        out_dtype=out_dtype,
        out=out,
    )


def gemm_tn(
    a,
    b,
    *,
    alpha: float = 1.0,
    blocks=None,
    plan=None,
    interpret=None,
    out_dtype=jnp.float32,
):
    """``alpha·AᵀB`` via the Pallas TN matmul kernel (blocks from the
    argument, else the ``plan``, else the tuned defaults)."""
    if interpret is None:
        interpret = interpret_default()
    if blocks is None and plan is not None:
        blocks = plan.gemm_blocks
    return gemm_tn_pallas(
        a,
        b,
        alpha=alpha,
        blocks=tuple(blocks or GEMM_BLOCKS),
        interpret=interpret,
        out_dtype=out_dtype,
    )
