"""Pallas TPU kernel for the TN matmul ``C = alpha·AᵀB``.

This is the base-case engine of FastStrassen on TPU. Design points:

* **TN-native**: the kernel contracts dim 0 of both operands with a single
  MXU ``dot_general`` per tile — ``Aᵀ`` is never materialized, addressing the
  paper's observation that ``AᵀA``-style access is cache-hostile (Section 3):
  on TPU the "transpose" happens inside the MXU dataflow.

* **Blocking**: grid ``([B,] n/bn, k/bk, m/bm)`` with the contraction
  dimension minor-most so Mosaic revisits the same output tile across the
  reduction ("arbitrary" semantics); the f32 accumulator lives in a VMEM
  scratch tile and is only written back to HBM once per output tile.

* **Batch**: an optional leading batch grid dimension per the package-wide
  batched-grid contract (see ``repro.kernels`` — leading dim = leaf batch):
  ``(B, m, n) × (B, m, k)`` runs as ONE kernel launch, which is how the
  level-synchronous ``leaf_dispatch='batched'`` recursion lands its whole
  Strassen leaf stack here.

* **VMEM budget**: per grid step the working set is
  ``bm·bn + bm·bk`` input elements + ``bn·bk`` f32 accumulator. The default
  ``(bm, bn, bk) = (512, 256, 256)`` with bf16 inputs is
  512·256·2·2 + 256·256·4 ≈ 0.8 MB — comfortably inside the ~16 MB VMEM and
  every matmul dim a multiple of the 128-lane MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

# (bm, bn, bk): contraction block, output-row block, output-col block.
# The constant lives with every other tunable in repro.tune.defaults; the
# autotuner sweeps alternatives per shape (repro.tune.plan → gemm_blocks).
from repro.tune.defaults import GEMM_BLOCKS as DEFAULT_BLOCKS

__all__ = ["gemm_tn_pallas", "DEFAULT_BLOCKS"]


def _gemm_tn_kernel(a_ref, b_ref, c_ref, acc_ref, *, alpha: float, l_axis: int):
    """One ([b,] i, j, l) grid step: acc += A[l,i]ᵀ · B[l,j]."""

    @pl.when(pl.program_id(l_axis) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].reshape(a_ref.shape[-2:]),
        b_ref[...].reshape(b_ref.shape[-2:]),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(l_axis) == pl.num_programs(l_axis) - 1)
    def _flush():
        c_ref[...] = (alpha * acc_ref[...]).astype(c_ref.dtype).reshape(c_ref.shape)


def _pad_to(x, mult0, mult1):
    m, n = x.shape[-2:]
    pm = (-m) % mult0
    pn = (-n) % mult1
    if pm or pn:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)])
    return x


@functools.partial(
    jax.jit, static_argnames=("alpha", "blocks", "interpret", "out_dtype")
)
def gemm_tn_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    alpha: float = 1.0,
    blocks: tuple = DEFAULT_BLOCKS,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``C = alpha·AᵀB`` with A:(m,n) or (B,m,n), B:(m,k) or (B,m,k).

    Inputs are zero-padded up to block multiples (zero rows of the
    contraction dim contribute nothing; padded output rows/cols are cropped).
    A leading batch dim becomes the leading grid dimension — one launch for
    the whole batch (the ``repro.kernels`` batched-grid contract).
    """
    if a.ndim not in (2, 3) or a.ndim != b.ndim:
        raise ValueError(f"bad TN shapes: {a.shape} x {b.shape}")
    if a.shape[-2] != b.shape[-2] or a.shape[:-2] != b.shape[:-2]:
        raise ValueError(f"bad TN shapes: {a.shape} x {b.shape}")
    batched = a.ndim == 3
    m, n = a.shape[-2:]
    k = b.shape[-1]
    bm, bn, bk = blocks
    # clamp blocks to (padded) problem size to avoid huge pads on small inputs
    bm = min(bm, max(8, -(-m // 8) * 8))
    bn = min(bn, max(128, -(-n // 128) * 128))
    bk = min(bk, max(128, -(-k // 128) * 128))

    a = _pad_to(a, bm, bn)
    b = _pad_to(b, bm, bk)
    mp, np_ = a.shape[-2:]
    kp = b.shape[-1]

    # one spec construction for both layouts: the batched case prepends the
    # batch coordinate to the grid, every block shape, and every index map
    # (same scheme as the syrk kernel).
    lead = (1,) if batched else ()
    batch_dims = a.shape[:-2]
    grid = batch_dims + (np_ // bn, kp // bk, mp // bm)
    l_axis = len(grid) - 1
    _pre = lambda idx: idx[:-3]  # () unbatched, (b,) batched

    out = pl.pallas_call(
        functools.partial(_gemm_tn_kernel, alpha=alpha, l_axis=l_axis),
        grid=grid,
        in_specs=[
            pl.BlockSpec(lead + (bm, bn), lambda *idx: _pre(idx) + (idx[-1], idx[-3])),
            pl.BlockSpec(lead + (bm, bk), lambda *idx: _pre(idx) + (idx[-1], idx[-2])),
        ],
        out_specs=pl.BlockSpec(
            lead + (bn, bk), lambda *idx: _pre(idx) + (idx[-3], idx[-2])
        ),
        out_shape=jax.ShapeDtypeStruct(batch_dims + (np_, kp), out_dtype),
        scratch_shapes=[pltpu.VMEM((bn, bk), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",) * l_axis + ("arbitrary",),
        ),
        interpret=interpret,
        name="gemm_tn",
    )(a, b)
    return out[..., :n, :k]
