"""Pallas TPU kernel for the TN matmul ``C = alpha·AᵀB``.

This is the base-case engine of FastStrassen on TPU. Design points:

* **TN-native**: the kernel contracts dim 0 of both operands with a single
  MXU ``dot_general`` per tile — ``Aᵀ`` is never materialized, addressing the
  paper's observation that ``AᵀA``-style access is cache-hostile (Section 3):
  on TPU the "transpose" happens inside the MXU dataflow.

* **Blocking**: grid ``([B,] n/bn, k/bk, m/bm)`` with the contraction
  dimension minor-most so Mosaic revisits the same output tile across the
  reduction ("arbitrary" semantics); the f32 accumulator lives in a VMEM
  scratch tile and is only written back to HBM once per output tile.

* **Batch**: an optional leading batch grid dimension per the package-wide
  batched-grid contract (see ``repro.kernels`` — leading dim = leaf batch):
  ``(B, m, n) × (B, m, k)`` runs as ONE kernel launch, which is how the
  level-synchronous ``leaf_dispatch='batched'`` recursion lands its whole
  Strassen leaf stack here.

* **VMEM budget**: per grid step the working set is
  ``bm·bn + bm·bk`` input elements + ``bn·bk`` f32 accumulator. The default
  ``(bm, bn, bk) = (512, 256, 256)`` with bf16 inputs is
  512·256·2·2 + 256·256·4 ≈ 0.8 MB — comfortably inside the ~16 MB VMEM and
  every matmul dim a multiple of the 128-lane MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

# (bm, bn, bk): contraction block, output-row block, output-col block.
# The constant lives with every other tunable in repro.tune.defaults; the
# autotuner sweeps alternatives per shape (repro.tune.plan → gemm_blocks).
from repro.tune.defaults import GEMM_BLOCKS as DEFAULT_BLOCKS

__all__ = ["gemm_tn_pallas", "gemm_tn_fused_pallas", "DEFAULT_BLOCKS"]


def _gemm_tn_kernel(a_ref, b_ref, c_ref, acc_ref, *, alpha: float, l_axis: int):
    """One ([b,] i, j, l) grid step: acc += A[l,i]ᵀ · B[l,j]."""

    @pl.when(pl.program_id(l_axis) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].reshape(a_ref.shape[-2:]),
        b_ref[...].reshape(b_ref.shape[-2:]),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(l_axis) == pl.num_programs(l_axis) - 1)
    def _flush():
        c_ref[...] = (alpha * acc_ref[...]).astype(c_ref.dtype).reshape(c_ref.shape)


def _pad_to(x, mult0, mult1):
    m, n = x.shape[-2:]
    pm = (-m) % mult0
    pn = (-n) % mult1
    if pm or pn:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)])
    return x


@functools.partial(
    jax.jit, static_argnames=("alpha", "blocks", "interpret", "out_dtype")
)
def gemm_tn_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    alpha: float = 1.0,
    blocks: tuple = DEFAULT_BLOCKS,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``C = alpha·AᵀB`` with A:(m,n) or (B,m,n), B:(m,k) or (B,m,k).

    Inputs are zero-padded up to block multiples (zero rows of the
    contraction dim contribute nothing; padded output rows/cols are cropped).
    A leading batch dim becomes the leading grid dimension — one launch for
    the whole batch (the ``repro.kernels`` batched-grid contract).
    """
    if a.ndim not in (2, 3) or a.ndim != b.ndim:
        raise ValueError(f"bad TN shapes: {a.shape} x {b.shape}")
    if a.shape[-2] != b.shape[-2] or a.shape[:-2] != b.shape[:-2]:
        raise ValueError(f"bad TN shapes: {a.shape} x {b.shape}")
    batched = a.ndim == 3
    m, n = a.shape[-2:]
    k = b.shape[-1]
    bm, bn, bk = blocks
    # clamp blocks to (padded) problem size to avoid huge pads on small inputs
    bm = min(bm, max(8, -(-m // 8) * 8))
    bn = min(bn, max(128, -(-n // 128) * 128))
    bk = min(bk, max(128, -(-k // 128) * 128))

    a = _pad_to(a, bm, bn)
    b = _pad_to(b, bm, bk)
    mp, np_ = a.shape[-2:]
    kp = b.shape[-1]

    # one spec construction for both layouts: the batched case prepends the
    # batch coordinate to the grid, every block shape, and every index map
    # (same scheme as the syrk kernel).
    lead = (1,) if batched else ()
    batch_dims = a.shape[:-2]
    grid = batch_dims + (np_ // bn, kp // bk, mp // bm)
    l_axis = len(grid) - 1
    _pre = lambda idx: idx[:-3]  # () unbatched, (b,) batched

    out = pl.pallas_call(
        functools.partial(_gemm_tn_kernel, alpha=alpha, l_axis=l_axis),
        grid=grid,
        in_specs=[
            pl.BlockSpec(lead + (bm, bn), lambda *idx: _pre(idx) + (idx[-1], idx[-3])),
            pl.BlockSpec(lead + (bm, bk), lambda *idx: _pre(idx) + (idx[-1], idx[-2])),
        ],
        out_specs=pl.BlockSpec(
            lead + (bn, bk), lambda *idx: _pre(idx) + (idx[-3], idx[-2])
        ),
        out_shape=jax.ShapeDtypeStruct(batch_dims + (np_, kp), out_dtype),
        scratch_shapes=[pltpu.VMEM((bn, bk), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",) * l_axis + ("arbitrary",),
        ),
        interpret=interpret,
        name="gemm_tn",
    )(a, b)
    return out[..., :n, :k]


# ---------------------------------------------------------------------------
# fused-operand leaf launch (leaf_dispatch='fused')
#
# Per the repro.kernels coefficient-table contract: the operands arrive in
# the block-major leaf-grid layout of `core.strassen._to_blocks` and the
# per-leaf ±1 combinations run in the PROLOGUE of this kernel, against the
# prefetched slot tables — no operand-combination stack is ever written to
# HBM. Each slot is one input ref (the same operand array passed W times
# with a per-slot index map off the prefetched (row, col) tables); the body
# combines them as the same balanced add tree as the trace-time paths
# (sign-0 slots contribute an exact ±0 instead of being dropped — value-
# equal), then runs the identical blocked TN dot as `_gemm_tn_kernel`:
# same (bm, bn)×(bm, bk) chunk shapes, same minor-most contraction order,
# same f32 VMEM accumulation — which is what keeps the fused launch
# bitwise-equal to the unrolled per-leaf kernel calls.
# ---------------------------------------------------------------------------


def _gemm_tn_fused_kernel(
    ar, ac, asg, br, bc, bsg, *refs, w: int, alpha: float, t_axis: int, l_axis: int
):
    """One ([g, t, b,] i, j, l) grid step of the fused leaf launch:
    acc += combine(A slots)ᵀ · combine(B slots)."""
    del ar, ac, br, bc  # consumed by the index maps
    a_refs, b_refs = refs[:w], refs[w : 2 * w]
    c_ref, acc_ref = refs[2 * w], refs[2 * w + 1]
    t = pl.program_id(t_axis)

    @pl.when(pl.program_id(l_axis) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def combine(slot_refs, sgn, lo, hi):
        # the balanced slot tree of `core.strassen._combine_slots`, with
        # runtime ±1/0 signs (a sign multiply is exact; adding the ±0 of a
        # dead slot is exact for every non-zero partial sum)
        if hi - lo == 1:
            x = slot_refs[lo][...].reshape(slot_refs[lo].shape[-2:])
            return sgn[t, lo].astype(x.dtype) * x
        mid = (lo + hi) // 2
        return combine(slot_refs, sgn, lo, mid) + combine(slot_refs, sgn, mid, hi)

    acc_ref[...] += jax.lax.dot_general(
        combine(a_refs, asg, 0, w),
        combine(b_refs, bsg, 0, w),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(l_axis) == pl.num_programs(l_axis) - 1)
    def _flush():
        c_ref[...] = (alpha * acc_ref[...]).astype(c_ref.dtype).reshape(c_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("alpha", "blocks", "interpret", "out_dtype")
)
def gemm_tn_fused_pallas(
    a_blocks: jax.Array,
    b_blocks: jax.Array,
    tables,
    *,
    alpha: float = 1.0,
    blocks: tuple = DEFAULT_BLOCKS,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Fused-operand Strassen leaf launch ``P[g·T+t] = alpha·Â(g,t)ᵀB̂(g,t)``.

    ``a_blocks``: ``(G, R, C, [B,] mb, n)`` block-major leaf grids
    (`core.strassen._to_blocks` layout, ``G`` independent groups);
    ``b_blocks`` the same with trailing ``(mb, k)``. ``tables`` =
    ``((a_rows, a_cols, a_sgn), (b_rows, b_cols, b_sgn))``, six ``(T, W)``
    int32 arrays (`core.strassen._slot_tables`): leaf operand ``Â(g, t)``
    is the signed sum of blocks ``a_blocks[g, a_rows[t, w], a_cols[t, w]]``
    over the ``W`` slots. One launch computes all ``G·T`` leaf products —
    the ± combinations run in the kernel prologue, nothing is materialized.
    """
    if a_blocks.ndim not in (5, 6) or a_blocks.ndim != b_blocks.ndim:
        raise ValueError(
            f"bad fused block grids: {a_blocks.shape} x {b_blocks.shape}"
        )
    if (
        a_blocks.shape[:3] != b_blocks.shape[:3]
        or a_blocks.shape[:-2] != b_blocks.shape[:-2]
        or a_blocks.shape[-2] != b_blocks.shape[-2]
    ):
        raise ValueError(
            f"bad fused block grids: {a_blocks.shape} x {b_blocks.shape}"
        )
    (a_rows, a_cols, a_sgn), (b_rows, b_cols, b_sgn) = tables
    t_count, w = a_rows.shape
    batched = a_blocks.ndim == 6
    g_count = a_blocks.shape[0]
    m, n = a_blocks.shape[-2:]
    k = b_blocks.shape[-1]
    bm, bn, bk = blocks
    # the same clamp rule as `gemm_tn_pallas` on one leaf's (m, n, k) —
    # identical chunking is what makes fused bitwise-equal to unrolled
    bm = min(bm, max(8, -(-m // 8) * 8))
    bn = min(bn, max(128, -(-n // 128) * 128))
    bk = min(bk, max(128, -(-k // 128) * 128))

    a_blocks = _pad_to(a_blocks, bm, bn)
    b_blocks = _pad_to(b_blocks, bm, bk)
    mp, np_ = a_blocks.shape[-2:]
    kp = b_blocks.shape[-1]

    lead = (1,) if batched else ()
    batch_dims = a_blocks.shape[3:-2]
    grid = (g_count, t_count) + batch_dims + (np_ // bn, kp // bk, mp // bm)
    t_axis, l_axis = 1, len(grid) - 1
    _pre = lambda idx: idx[2:-3]  # () unbatched, (b,) batched

    def _a_index(slot):
        def index(*args):
            idx, (rows, cols) = args[: len(grid)], args[len(grid) : len(grid) + 2]
            return (idx[0], rows[idx[1], slot], cols[idx[1], slot]) + _pre(
                idx
            ) + (idx[-1], idx[-3])

        return index

    def _b_index(slot):
        def index(*args):
            idx, rows, cols = args[: len(grid)], args[len(grid) + 3], args[len(grid) + 4]
            return (idx[0], rows[idx[1], slot], cols[idx[1], slot]) + _pre(
                idx
            ) + (idx[-1], idx[-2])

        return index

    def _c_index(*args):
        idx = args[: len(grid)]
        return (idx[0] * t_count + idx[1],) + _pre(idx) + (idx[-3], idx[-2])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1) + lead + (bm, bn), _a_index(s)) for s in range(w)
        ]
        + [
            pl.BlockSpec((1, 1, 1) + lead + (bm, bk), _b_index(s)) for s in range(w)
        ],
        out_specs=pl.BlockSpec((1,) + lead + (bn, bk), _c_index),
        scratch_shapes=[pltpu.VMEM((bn, bk), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(
            _gemm_tn_fused_kernel, w=w, alpha=alpha, t_axis=t_axis, l_axis=l_axis
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (g_count * t_count,) + batch_dims + (np_, kp), out_dtype
        ),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",) * l_axis + ("arbitrary",),
        ),
        interpret=interpret,
        name="gemm_tn_fused",
    )(
        jnp.asarray(a_rows), jnp.asarray(a_cols), jnp.asarray(a_sgn),
        jnp.asarray(b_rows), jnp.asarray(b_cols), jnp.asarray(b_sgn),
        *([a_blocks] * w), *([b_blocks] * w),
    )
    return out[..., :n, :k]
