"""End-to-end training driver.

Runs real training (CPU-sized smoke configs or any registry arch) with the
full production stack: sharded train step, checkpoint/restore, preemption
guard, deterministic data pipeline, metrics logging.

Examples::

    # ~100M-param LM for a few hundred steps on CPU (examples/train_lm.py)
    python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 300 --batch 8 --seq 256 --out /tmp/run1

    # resume after a crash/preemption: same command — restores automatically
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.configs.registry import get_config, get_smoke
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.models.transformer import init
from repro.runtime.fault_tolerance import Heartbeat, PreemptionGuard
from repro.train.train_step import make_train_step


def build_argparser():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--optimizer", choices=["adamw", "shampoo"], default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="/tmp/repro_train")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--microbatch", type=int, default=1)
    return ap


def main(argv=None):
    args = build_argparser().parse_args(argv)
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model")) if d * m > 1 else None

    run = RunConfig(
        model=cfg, shape=shape,
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr,
                                  warmup_steps=min(50, args.steps // 10 + 1)),
        remat=args.remat, microbatch=args.microbatch, seed=args.seed,
    )
    train_step, opt = make_train_step(cfg, mesh, run, total_steps=args.steps)
    jitted = jax.jit(train_step, donate_argnums=(0,))

    ckpt = CheckpointManager(os.path.join(args.out, "ckpt"), keep=2)
    guard = PreemptionGuard()
    hb = Heartbeat(os.path.join(args.out, "heartbeat"), interval=5.0).start()

    # --- build or restore state -------------------------------------------
    params = init(jax.random.key(args.seed), cfg, mesh)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state, start_step = ckpt.restore(state)
        print(f"resumed from checkpoint step {start_step}")

    data = SyntheticLM(cfg, shape, seed=args.seed, start_step=start_step)
    log_path = os.path.join(args.out, "metrics.jsonl")
    os.makedirs(args.out, exist_ok=True)

    t0 = time.time()
    losses = []
    with open(log_path, "a") as logf:
        for step in range(start_step, args.steps):
            batch = next(data)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = jitted(state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                rec = {
                    "step": step + 1,
                    "loss": round(float(np.mean(losses[-args.log_every:])), 4),
                    "grad_norm": round(float(metrics["grad_norm"]), 4),
                    "wall_s": round(time.time() - t0, 1),
                }
                logf.write(json.dumps(rec) + "\n")
                logf.flush()
                print(rec, flush=True)
            if (step + 1) % args.save_every == 0 or guard.preempted:
                ckpt.save(step + 1, state, blocking=False,
                          extra={"data_step": step + 1})
                if guard.preempted:
                    print("preemption requested — checkpointed, exiting")
                    break
    ckpt.wait()
    data.close()
    hb.stop()
    print(f"final loss (mean of last 10): {np.mean(losses[-10:]):.4f}")
    return float(np.mean(losses[-10:])) if losses else float("nan")


if __name__ == "__main__":
    main()
