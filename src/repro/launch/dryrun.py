import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (tests may shrink the fake-device pool — must happen before jax init)
if os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_FORCE_DEVICES']}"
    )

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:

  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod);
  2. builds allocation-free abstractions: params via ``jax.eval_shape`` over
     ``models.init``, optimizer state via ``eval_shape(opt.init)``, inputs
     via ``configs.registry.input_specs``, decode caches via
     ``eval_shape(init_cache)``;
  3. jits the step (train_step / prefill / decode) with explicit
     in/out_shardings from ``parallel.sharding`` and runs
     ``.lower(...).compile()``;
  4. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` flops/bytes, and the per-device collective bytes
     parsed from the compiled HLO;
  5. additionally compiles 1-layer/2-layer *analysis variants* (inner scans
     unrolled) whose affine composition recovers exact per-step flops —
     XLA's cost model counts loop bodies once, so the full scanned graph
     alone would undercount by ~L× (see analysis/roofline.py).

Usage::

    python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_bytes
from repro.configs.base import SHAPES, OptimizerConfig, RunConfig
from repro.configs.registry import ARCHS, cell_supported, get_config, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import forward_decode, init, init_cache
from repro.parallel.sharding import (
    batch_input_specs,
    cache_specs,
    named,
    param_specs,
)
from repro.train.serve_step import make_prefill_step
from repro.train.train_step import make_train_step, state_specs


def _mem_stats(compiled):
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        "peak_bytes_est": int(
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        ),
    }


def _cost_stats(compiled):
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x: one dict per device
        ca = ca[0] if ca else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


import contextlib


@contextlib.contextmanager
def _big_flash_blocks(enable: bool, block: int = 8192):
    """Analysis-lowering context: enlarge flash q/kv blocks so unrolled body
    count stays small. Total masked-flash flops and streamed bytes are
    invariant to the block size (every q×kv pair is computed either way), so
    the cost model is unaffected — only graph size shrinks."""
    import repro.models.layers as L

    if not enable:
        yield
        return
    old = (L.Q_BLOCK, L.KV_BLOCK)
    L.Q_BLOCK = L.KV_BLOCK = block
    try:
        yield
    finally:
        L.Q_BLOCK, L.KV_BLOCK = old


def _artifact(jitted, *abstract_args, big_blocks: bool = False):
    with _big_flash_blocks(big_blocks):
        t0 = time.time()
        lowered = jitted.lower(*abstract_args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    return {
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": _mem_stats(compiled),
        "cost": _cost_stats(compiled),
        "collectives": collective_bytes(compiled.as_text()),
    }


def _abstract_params(cfg, mesh, dtype=None):
    abs_ = jax.eval_shape(functools.partial(init, cfg=cfg, mesh=mesh), jax.random.key(0))
    if dtype is not None:
        abs_ = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a,
            abs_,
        )
    return abs_


def _abstract_batch(cfg, shape, mode):
    return dict(input_specs(cfg, shape, mode))


def _train_artifacts(cfg, shape, mesh, run, analysis=True):
    """Main scanned artifact + L∈{1,2} analysis variants."""
    out = {}

    def one(cfg_v, label, unroll_scans):
        run_v = run
        step_fn, opt = make_train_step(cfg_v, mesh, run_v)
        if unroll_scans:
            # rebuild loss with unrolled inner scans for exact flop counting
            from repro.models.transformer import forward_train
            from repro.train.train_step import cross_entropy
            from repro.optim import apply_updates, build as build_opt
            from repro.optim.adamw import clip_by_global_norm

            opt = build_opt(run_v.optimizer, 10_000)

            def loss_fn(params, batch):
                logits, aux = forward_train(
                    params, batch, cfg_v, mesh, remat=run_v.remat,
                    compute_dtype=jnp.dtype(run_v.compute_dtype),
                    unroll_scans=True,
                )
                loss = cross_entropy(logits, batch["labels"], cfg_v.vocab_size)
                if cfg_v.moe is not None:
                    loss = loss + cfg_v.moe.router_aux_coef * aux
                return loss, {"loss": loss, "aux": aux}

            grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

            def step_fn(state, batch):
                (_, metrics), grads = grad_fn(state["params"], batch)
                grads, gnorm = clip_by_global_norm(grads, 1.0)
                updates, opt_state = opt.update(grads, state["opt"], state["params"])
                params = apply_updates(state["params"], updates)
                return (
                    {"params": params, "opt": opt_state, "step": state["step"] + 1},
                    dict(metrics, grad_norm=gnorm),
                )

        params_abs = _abstract_params(cfg_v, mesh)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        state_abs = {"params": params_abs, "opt": opt_abs,
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
        batch_abs = _abstract_batch(cfg_v, shape, "train")
        st_specs = state_specs(cfg_v, mesh, run_v, params_abs, opt_abs)
        state_sh = named(mesh, st_specs)
        batch_sh = named(mesh, batch_input_specs(mesh, batch_abs))
        jitted = jax.jit(
            step_fn,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        out[label] = _artifact(jitted, state_abs, batch_abs,
                               big_blocks=unroll_scans)

    one(cfg, "main", unroll_scans=False)
    if analysis:
        for variants in _layer_variants(cfg):
            one(variants["cfg"], variants["label"], unroll_scans=True)
    return out


def _prefill_artifacts(cfg, shape, mesh, run, analysis=True, serve_dtype=None):
    out = {}

    def one(cfg_v, label, unroll_scans):
        from repro.models.transformer import forward_train

        def prefill(params, batch):
            logits, _aux, cache = forward_train(
                params, batch, cfg_v, mesh,
                compute_dtype=jnp.bfloat16, return_cache=True,
                unroll_scans=unroll_scans,
            )
            return logits[:, -1:], cache

        params_abs = _abstract_params(cfg_v, mesh, serve_dtype)
        batch_abs = _abstract_batch(cfg_v, shape, "prefill")
        cache_abs = jax.eval_shape(
            lambda p, b: prefill(p, b)[1], params_abs, batch_abs
        )
        p_sh = named(mesh, param_specs(mesh, cfg_v))
        b_sh = named(mesh, batch_input_specs(mesh, batch_abs))
        c_sh = named(mesh, cache_specs(mesh, cfg_v, cache_abs))
        jitted = jax.jit(
            prefill, in_shardings=(p_sh, b_sh), out_shardings=(None, c_sh)
        )
        out[label] = _artifact(jitted, params_abs, batch_abs,
                               big_blocks=unroll_scans)

    one(cfg, "main", unroll_scans=False)
    if analysis:
        for variants in _layer_variants(cfg):
            one(variants["cfg"], variants["label"], unroll_scans=True)
    return out


def _decode_artifacts(cfg, shape, mesh, run, serve_dtype=None, sp_decode=False):
    """Decode: two compiles — the *scanned* graph gives production memory
    (unrolling materializes per-layer param-slice temps that a scanned
    executable never holds), the *unrolled* graph gives exact per-step
    flop/byte/collective counts (XLA's cost model counts loop bodies once).
    The roofline composer reads memory from `main`, costs from
    `analysis_unrolled` when present."""
    b, s = shape.global_batch, shape.seq_len

    def make(unroll):
        def decode(params, tokens, cache, pos):
            return forward_decode(
                params, tokens, cache, pos, cfg, mesh,
                compute_dtype=jnp.bfloat16, unroll_layers=unroll,
                sp_decode=sp_decode,
            )
        return decode

    params_abs = _abstract_params(cfg, mesh, serve_dtype)
    cache_abs = jax.eval_shape(
        lambda: init_cache(cfg, b, s, mesh, dtype=jnp.bfloat16)
    )
    inp = input_specs(cfg, shape, "decode")
    tok_abs, pos_abs = inp["tokens"], inp["pos"]
    p_sh = named(mesh, param_specs(mesh, cfg))
    c_sh = named(mesh, cache_specs(mesh, cfg, cache_abs))
    io_sh = named(mesh, batch_input_specs(mesh, {"tokens": tok_abs, "pos": pos_abs}))
    out = {}
    for label, unroll in (("main", False), ("analysis_unrolled", True)):
        jitted = jax.jit(
            make(unroll),
            in_shardings=(p_sh, io_sh["tokens"], c_sh, io_sh["pos"]),
            out_shardings=(None, c_sh),
            donate_argnums=(2,),
        )
        out[label] = _artifact(jitted, params_abs, tok_abs, cache_abs, pos_abs)
    return out


def _gram_artifacts(mesh, *, m=65536, n=16384, n_base=None):
    """The paper's own workload on the production mesh: distributed
    C = AᵀA via the ATA-S/ATA-D tile schedule (core.distributed), lowered
    and compiled in three flavors:

      * ``naive``     — classical gram (no Strassen) — the pdsyrk baseline;
      * ``strassen``  — paper-faithful ATA leaves (7-mult recursion);
      * ``winograd``  — beyond-paper 15-add variant;
      * ``strassen_packed`` — packed low(C) retrieval: the result stays a
        ``SymmetricMatrix`` tile stack end-to-end (Prop. 4.2's saving as
        collective/output bytes — compare its ``collectives`` and
        ``output_bytes`` against ``strassen``'s dense replication).

    HLO flops show the 2/3-of-Strassen saving directly; collectives show
    the packed-tile retrieval volume (≈ n²/2 words). The planned cutoff
    and stripe count come from the repro.tune planner; the §Perf knob
    variants sweep the planner's neighboring candidates (one cutoff step
    down, two extra stripes) instead of hardcoded values.

    The gram record also carries an analytic ``normal_eq_model`` block
    (see ``run_cell``): the full normal-equations pipeline — gram +
    packed Cholesky factor + two substitutions — priced on the v5e write
    roofline (``analysis.roofline.normal_eq_write_seconds``), packed vs
    dense, so the sweep prices time-to-*solution*, not just the multiply.
    """
    from repro import tune
    from repro.core.distributed import ata_tile_parallel

    plan = tune.plan(op="ata", m=m, n=n, devices=mesh.shape["model"])
    base = plan.n_base if n_base is None else n_base
    alt = max((c for c in tune.defaults.N_BASE_CANDIDATES if c < base),
              default=base)
    wide = (plan.nb or tune.cost.distributed_tiling(n, mesh.shape["model"])[0]) + 2

    out = {}
    a_abs = jax.ShapeDtypeStruct((m, n), jnp.float32)
    row_axis = "data"
    in_sh = NamedSharding(mesh, P(row_axis, None))
    for label, kwargs in (
        ("naive", dict(use_strassen=False)),
        ("strassen", dict(use_strassen=True, variant="strassen")),
        ("winograd", dict(use_strassen=True, variant="winograd")),
        # packed retrieval (the distributed out='packed' mode)
        ("strassen_packed", dict(use_strassen=True, variant="strassen",
                                 out="packed")),
        # §Perf knobs: recursion cutoff (depth ↔ MXU-friendly leaf size)
        # and tile count (Strassen depth ↔ balance)
        (f"strassen_nb{alt}", dict(use_strassen=True, variant="strassen",
                                   n_base=alt)),
        (f"strassen_wide{wide}", dict(use_strassen=True, variant="strassen",
                                      nb=wide)),
    ):
        kw = dict(kwargs)
        nb_val = kw.pop("nb", None)
        fn = functools.partial(
            ata_tile_parallel, mesh=mesh, task_axis="model",
            row_axis=row_axis, n_base=kw.pop("n_base", base),
            nb=nb_val, **kw,
        )
        jitted = jax.jit(fn, in_shardings=(in_sh,))
        out[label] = _artifact(jitted, a_abs)
    return out


def _layer_variants(cfg):
    """Reduced-depth configs for the affine flop composition.

    scan_layers=False: XLA's cost model counts loop bodies once, so the
    analysis variants unroll the layer loop entirely. Hybrid layers are
    cost-uniform under masked flash (the window only changes the mask), so
    the same L∈{1,2} differencing applies with global_attn_layers=(0,).
    """
    extra = {"global_attn_layers": (0,)} if cfg.family == "hybrid" else {}
    return [
        {"label": "analysis_l1",
         "cfg": dataclasses.replace(cfg, num_layers=1, scan_layers=False, **extra)},
        {"label": "analysis_l2",
         "cfg": dataclasses.replace(cfg, num_layers=2, scan_layers=False, **extra)},
    ]


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             optimizer: str = "adamw", analysis: bool = True,
             remat: str = "full", microbatch: int = 1,
             zero1: bool = True, variant_tag: str = "",
             serve_dtype: str = "", sp_decode: bool = False,
             shampoo_n_base=None) -> dict:
    if arch == "gram":
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        rec = {"arch": "gram", "shape": shape_name, "mesh": mesh_kind,
               "mode": "gram", "optimizer": "-", "num_layers": 0,
               "global_attn_layers": [], "params": 0, "active_params": 0,
               "variant_tag": variant_tag}
        try:
            m, n = (int(x) for x in shape_name.split("x"))
            rec["artifacts"] = _gram_artifacts(mesh, m=m, n=n)
            # analytic full-pipeline pricing (paper's "time to solution"):
            # the gram sweep's write roofline extended by the potrf/trsm
            # traffic of the packed normal-equations tail, per RHS count.
            from repro.analysis import roofline as _rl
            from repro.core.symmetric import default_block_size as _dbs
            from repro.tune.defaults import DEFAULT_PACKED_BLOCK as _PB

            bn = _dbs(n, _PB)
            rec["normal_eq_model"] = {
                "packed_block": bn,
                "rhs": {
                    str(r): {
                        "packed_write_s": _rl.normal_eq_write_seconds(
                            n, bn, r, mode="packed"
                        ),
                        "dense_write_s": _rl.normal_eq_write_seconds(
                            n, bn, r, mode="dense"
                        ),
                        "factor_tail_bytes": _rl.normal_eq_write_traffic(
                            n, bn, r
                        ),
                    }
                    for r in (1, 16, 128)
                },
            }
            rec["status"] = "ok"
        except Exception as e:
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-4000:])
        return rec
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mode": shape.kind, "optimizer": optimizer,
        "remat": remat, "microbatch": microbatch, "zero1": zero1,
        "variant_tag": variant_tag,
        "num_layers": cfg.num_layers,
        "global_attn_layers": list(cfg.global_attn_layers),
        "params": cfg.num_params(), "active_params": cfg.active_params(),
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    # remat='full': measured on qwen1.5-0.5b×train_4k — none=127GiB,
    # dots=22.7GiB, full=13.5GiB/device at +1.7% recompute flops. Only
    # 'full' fits v5e's 16GiB at these global batches; per-cell relaxation
    # is a §Perf lever.
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    run = RunConfig(
        model=cfg, shape=shape,
        optimizer=OptimizerConfig(name=optimizer, zero1=zero1,
                                  shampoo_n_base=shampoo_n_base),
        remat=remat, microbatch=microbatch,
    )
    try:
        sdt = jnp.dtype(serve_dtype) if serve_dtype else None
        if shape.kind == "train":
            rec["artifacts"] = _train_artifacts(cfg, shape, mesh, run, analysis)
        elif shape.kind == "prefill":
            rec["artifacts"] = _prefill_artifacts(cfg, shape, mesh, run, analysis,
                                                  serve_dtype=sdt)
        else:
            rec["artifacts"] = _decode_artifacts(cfg, shape, mesh, run,
                                                 serve_dtype=sdt,
                                                 sp_decode=sp_decode)
        rec["status"] = "ok"
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS) + ["gram"], default=None)
    ap.add_argument("--shape", default=None,
                    help="shape name, or MxN for --arch gram")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--optimizer", choices=["adamw", "shampoo"], default="adamw")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--no-analysis", action="store_true",
                    help="skip the 1/2-layer analysis variants")
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--tag", default="", help="variant tag in the output name")
    # default None: the repro.tune planner picks the gram cutoff per shape
    ap.add_argument("--shampoo-n-base", type=int, default=None)
    ap.add_argument("--sp-decode", action="store_true",
                    help="use the shard_map sequence-parallel flash-decode")
    ap.add_argument("--serve-dtype", default="",
                    help="cast float params to this dtype for serve cells "
                         "(e.g. bfloat16); default keeps init dtype (f32)")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose output JSON already exists and is ok")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in sorted(ARCHS):
            for shape in SHAPES:
                for mesh in ("single", "multi"):
                    cells.append((arch, shape, mesh))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape, args.mesh)]

    n_ok = n_skip = n_err = 0
    for arch, shape, mesh in cells:
        tag = f"__{args.tag}" if args.tag else ""
        fname = f"{arch}__{shape}__{mesh}{tag}.json".replace("/", "_")
        fpath = os.path.join(args.out, fname)
        if args.resume and os.path.exists(fpath):
            try:
                prev = json.load(open(fpath))
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[ resumed] {arch} × {shape} × {mesh}", flush=True)
                    n_ok += prev["status"] == "ok"
                    n_skip += prev["status"] == "skipped"
                    continue
            except Exception:
                pass
        t0 = time.time()
        rec = run_cell(arch, shape, mesh, optimizer=args.optimizer,
                       analysis=not args.no_analysis, remat=args.remat,
                       microbatch=args.microbatch, zero1=not args.no_zero1,
                       variant_tag=args.tag, serve_dtype=args.serve_dtype,
                       sp_decode=args.sp_decode,
                       shampoo_n_base=args.shampoo_n_base)
        rec["wall_s"] = round(time.time() - t0, 1)
        with open(fpath, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        n_ok += status == "ok"
        n_skip += status == "skipped"
        n_err += status == "error"
        extra = ""
        if status == "ok":
            main_art = rec["artifacts"].get("main") or next(iter(rec["artifacts"].values()))
            mem = main_art.get("memory", {})
            extra = f" peak/dev={mem.get('peak_bytes_est', 0)/2**30:.2f}GiB"
        if status == "error":
            extra = " " + rec["error"][:120]
        print(f"[{status:>7}] {arch} × {shape} × {mesh} ({rec['wall_s']}s){extra}",
              flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
