"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and tests keep their single default device.
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.compat import make_mesh as _compat_make_mesh

__all__ = ["make_production_mesh", "make_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)          # 256 chips / pod
MULTI_POD = (2, 16, 16)        # 2 pods = 512 chips


def make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with explicit Auto axis types (GSPMD propagation)
    where the installed jax supports them."""
    return _compat_make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
