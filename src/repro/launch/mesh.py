"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and tests keep their single default device.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

from jax.sharding import Mesh

from repro.compat import make_mesh as _compat_make_mesh

__all__ = [
    "make_production_mesh",
    "make_mesh",
    "merged_axis",
    "split_axis",
    "SINGLE_POD",
    "MULTI_POD",
]

SINGLE_POD = (16, 16)          # 256 chips / pod
MULTI_POD = (2, 16, 16)        # 2 pods = 512 chips


def make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with explicit Auto axis types (GSPMD propagation)
    where the installed jax supports them."""
    return _compat_make_mesh(shape, axes)


def merged_axis(
    task_axis: str, row_axis: Optional[str] = None
) -> Union[str, Tuple[str, str]]:
    """The device pool the BFS reduce-scatter runs over.

    ``ata_bfs_dfs`` stages every device's partial tiles at their global
    tri positions and issues ONE ``psum_scatter`` over the task and row
    axes *merged into a single logical axis* — the tuple form jax
    collectives accept. Chunk order is task-major (the tuple's first
    axis is the slowest-varying), which is exactly the order
    ``bfs_dfs_assignment`` deals contiguous tri chunks in, so the
    scattered result is already in packed tri order.
    """
    return (task_axis, row_axis) if row_axis is not None else task_axis


def split_axis(
    mesh: Mesh, axis: str, sizes: Sequence[int], names: Sequence[str]
) -> Mesh:
    """Refactor one mesh axis into named subgroup axes, same device order.

    BFS levels assign Strassen/tri subproblems to *subgroups* of the task
    axis. The tri-direct schedule addresses subgroups logically (slot
    tables over ``axis_index``), but callers that want explicit subgroup
    collectives — or meshes shaped for a fixed interleaving — can reshape
    the task axis into ``names`` of ``sizes`` (row-major over the original
    axis, so ``(grp, sub)`` subgroup ``g`` holds the devices that owned the
    contiguous index range ``[g·sub_size, (g+1)·sub_size)``).
    """
    import math

    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh {mesh.axis_names}")
    if len(sizes) != len(names):
        raise ValueError("sizes and names must pair up")
    if math.prod(sizes) != mesh.shape[axis]:
        raise ValueError(
            f"prod(sizes)={math.prod(sizes)} != mesh.shape[{axis!r}]"
            f"={mesh.shape[axis]}"
        )
    new_shape, new_names = [], []
    for name in mesh.axis_names:
        if name == axis:
            new_shape.extend(sizes)
            new_names.extend(names)
        else:
            new_shape.append(mesh.shape[name])
            new_names.append(name)
    return Mesh(
        mesh.devices.reshape(tuple(new_shape)), tuple(new_names)
    )


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
