"""Serving driver: batched prefill + decode with slot-based continuous
batching.

The server keeps a fixed pool of ``--batch`` sequence slots. Requests are
prefilled (batched) into their slot's cache region; every decode step
advances all active slots by one token; finished slots (EOS or length
budget) are refilled from the queue. On CPU this runs the smoke configs —
on TPU the same code paths run the full ones (mesh via --mesh).

    python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 16 --batch 4 --prompt-len 32 --gen-len 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke
from repro.launch.mesh import make_mesh
from repro.models.transformer import init, init_cache
from repro.train.serve_step import make_decode_step, make_prefill_step, sample_logits


def _pad_slots(real: np.ndarray, b: int) -> np.ndarray:
    """Zero-pad a ragged tail batch of ``n < b`` real prompts up to the
    static slot count. Keeps the prefill/decode shapes static (no retrace
    on the tail) without drawing RNG for padding slots — the tail batch
    used to prefill ``b`` fresh prompts and advance the generator for
    slots nobody requested."""
    n = real.shape[0]
    if n == b:
        return real
    pad = np.zeros((b - n, *real.shape[1:]), dtype=real.dtype)
    return np.concatenate([real, pad], axis=0)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4, help="decode slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model")) if d * m > 1 else None

    b, p_len, g_len = args.batch, args.prompt_len, args.gen_len
    max_seq = p_len + g_len
    params = init(jax.random.key(args.seed), cfg, mesh)

    prefill = jax.jit(make_prefill_step(cfg, mesh, cache_len=max_seq))
    decode = jax.jit(make_decode_step(cfg, mesh))

    rng = np.random.default_rng(args.seed)
    key = jax.random.key(args.seed + 1)

    def new_prompts(n):
        if cfg.num_codebooks > 1:
            return rng.integers(0, cfg.vocab_size, (n, p_len, cfg.num_codebooks))
        return rng.integers(0, cfg.vocab_size, (n, p_len))

    served = 0
    t0 = time.time()
    tokens_out = 0
    while served < args.requests:
        n = min(b, args.requests - served)
        # generate only the n real prompts; zero-fill the padding slots
        # (static batch shape, but the RNG stream no longer advances for
        # slots nobody requested — the tail is reproducible vs a run whose
        # request count is a multiple of the slot count)
        prompts = _pad_slots(new_prompts(n), b)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, cache = prefill(params, batch)
        key, k1 = jax.random.split(key)
        tok = sample_logits(logits, k1, args.temperature, cfg.vocab_size)
        pos = jnp.full((b,), p_len, jnp.int32)
        for _ in range(g_len - 1):
            lg, cache = decode(params, tok, cache, pos)
            key, k1 = jax.random.split(key)
            tok = sample_logits(lg, k1, args.temperature, cfg.vocab_size)
            pos = pos + 1
            tokens_out += n
        served += n
        print(f"served {served}/{args.requests} requests "
              f"({tokens_out} tokens, {time.time()-t0:.1f}s)", flush=True)

    dt = time.time() - t0
    print(f"throughput: {tokens_out/dt:.1f} tok/s "
          f"({args.requests} requests in {dt:.1f}s)")


if __name__ == "__main__":
    main()
