"""``repro.tune`` — cost-model + measured-autotuner planning layer.

Every ATA dispatch in the repo resolves its tunables (algorithm variant,
recursion cutoff ``n_base``, Pallas block shapes, packed-block size,
distributed stripe tiling) through this subsystem instead of scattered
literals:

    from repro import tune
    p = tune.plan(op="ata", m=4096, n=1024)           # analytic (cache miss)
    p = tune.plan(op="ata", m=4096, n=1024, autotune=True)  # measured
    c = ata(a, plan=p)                                 # or just ata(a)

Modules: ``defaults`` (the single home of the tunable constants),
``cost`` (analytic roofline model + the frozen ``Plan``), ``search``
(measured autotuning + the shared timing discipline), ``cache``
(JSON-persistent plan store + the ``plan()`` front door), ``apply``
(plan → callable threading). See DESIGN.md §7.

This ``__init__`` is **lazy** (PEP 562): low layers (`core`, `kernels`)
import ``repro.tune.defaults`` at module scope, which must not drag in
``cost``/``cache`` (they import `core` back — the planner sits *above* the
algorithms it plans).
"""

from repro.tune import defaults  # dependency-free; safe to load eagerly

__all__ = [
    "plan",
    "warm",
    "Plan",
    "autotune",
    "analytic_plan",
    "default_plan",
    "candidates",
    "defaults",
    "cost",
    "search",
    "cache",
    "apply",
]

_LAZY = {
    "plan": ("repro.tune.cache", "plan"),
    "warm": ("repro.tune.cache", "warm"),
    "Plan": ("repro.tune.cost", "Plan"),
    "autotune": ("repro.tune.search", "autotune"),
    "analytic_plan": ("repro.tune.cost", "analytic_plan"),
    "default_plan": ("repro.tune.cost", "default_plan"),
    "candidates": ("repro.tune.cost", "candidates"),
    "cost": ("repro.tune.cost", None),
    "search": ("repro.tune.search", None),
    "cache": ("repro.tune.cache", None),
    "apply": ("repro.tune.apply", None),
}


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro.tune' has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(mod_name)
    value = mod if attr is None else getattr(mod, attr)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
