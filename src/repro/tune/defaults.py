"""The single home of every ATA-stack tunable constant.

Before the tune subsystem these literals were scattered across the repo
(`DEFAULT_N_BASE` in `core/strassen`, `DEFAULT_BLOCKS` in each Pallas
kernel, `shampoo_n_base` in `configs/base`, ad-hoc `N_BASE = 256` in every
benchmark). They now live here, in one dependency-free module, and reach
the call sites through :func:`repro.tune.plan` — an explicit kwarg at a
call site is a *manual override*, not a tuning decision.

This module must stay import-light (no jax, no repro imports): it is the
one `repro.tune` module that low layers (`core`, `kernels`) may import
without creating a cycle, via the lazy `repro.tune.__init__`.
"""

from __future__ import annotations

__all__ = [
    "DEFAULT_N_BASE",
    "DEFAULT_PACKED_BLOCK",
    "SYRK_BLOCKS",
    "GEMM_BLOCKS",
    "DEFAULT_VARIANT",
    "DEFAULT_LEAF_DISPATCH",
    "LEAF_DISPATCH_CANDIDATES",
    "DEFAULT_SOLVE_METHOD",
    "CG_MAX_ITERS",
    "CG_TOL",
    "TARGET_TILES_PER_DEVICE",
    "MAX_COMM_SCHEDULE_LEVELS",
    "N_BASE_CANDIDATES",
    "SYRK_BLOCK_CANDIDATES",
    "GEMM_BLOCK_CANDIDATES",
]

# Recursion cutoff of the Strassen/ATA trace-time recursion. 512 keeps every
# base-case matmul dimension a multiple of the 128-wide MXU while allowing
# 3-5 Strassen levels on the gram shapes of the framework (d_model/d_ff up
# to 33792).
DEFAULT_N_BASE = 512

# Block size of the packed (SymmetricMatrix) output grid.
DEFAULT_PACKED_BLOCK = 128

# Pallas syrk kernel blocks (bm, bn): contraction block, output block.
SYRK_BLOCKS = (512, 256)

# Pallas gemm_tn kernel blocks (bm, bn, bk): contraction, C-row, C-col.
GEMM_BLOCKS = (512, 256, 256)

# Strassen variant for the off-diagonal products when nothing chose one:
# 'strassen' is the paper-faithful schedule (7 mults / 18 adds).
DEFAULT_VARIANT = "strassen"

# How the recursion's leaf products reach the hardware when nothing chose:
# 'unrolled' emits one dot/syrk per leaf (the historical trace-time form);
# 'batched' runs the whole tree level-synchronously — every leaf in one
# batched call; 'fused' gathers-and-combines the ±1 operand combinations
# inside the leaf kernel's prologue from per-leaf slot tables — zero
# materialized add stacks, one launch per level (classical variant only).
# All three are bitwise-equal; the planner prices launch overhead against
# combine traffic and picks per shape.
DEFAULT_LEAF_DISPATCH = "unrolled"

# Leaf-dispatch axis the planner enumerates ('fused' is dropped for the
# winograd variant and for dense/degenerate candidates by `cost.candidates`).
LEAF_DISPATCH_CANDIDATES = ("unrolled", "batched", "fused")

# Normal-equations solver (repro.solve) when nothing chose a method:
# 'factor' = planned packed gram → packed Cholesky → two substitutions;
# 'cg' = matrix-free CG on the gram operator. The planner's op='solve'
# entry prices both and picks per shape/RHS count; this is the manual-pin
# fallback only.
DEFAULT_SOLVE_METHOD = "factor"

# CG budget: iteration cap (also capped by n — exact termination in exact
# arithmetic) and relative residual tolerance. The cost model prices CG
# with this same cap, so prediction and dispatch agree.
CG_MAX_ITERS = 64
CG_TOL = 1e-6

# Distributed tile schedule: how many lower-triangle tiles the tiling
# search aims to give each device of the task axis (balance ↔ tile width).
TARGET_TILES_PER_DEVICE = 2

# BFS/DFS interleaving search depth: the planner enumerates every string
# over {'B','D'} up to this many recursion levels (≤ the tile-tree depth)
# plus None (the plain-psum schedule). 3 levels = 15 candidates — the α-β
# model separates them well before the strings stop mattering (below tile
# granularity the tags are no-ops).
MAX_COMM_SCHEDULE_LEVELS = 3

# Candidate grids swept by the analytic model and the measured autotuner.
N_BASE_CANDIDATES = (128, 256, 512, 1024)
SYRK_BLOCK_CANDIDATES = ((256, 128), (512, 128), (512, 256), (1024, 256))
GEMM_BLOCK_CANDIDATES = (
    (256, 128, 128),
    (512, 256, 256),
    (512, 512, 256),
    (1024, 256, 256),
)
