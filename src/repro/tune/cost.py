"""Analytic cost model: predict the best dispatch plan for an ATA product.

The model joins the two quantitative assets the repo already owns:

* the **exact flop counters** of `repro.core.reference` (they walk the same
  floor/ceil recursion as the implementations, so counts are exact for any
  rectangular shape and cutoff), split here into MXU multiply flops and VPU
  addition flops, and
* the **write-traffic model** of `repro.analysis.roofline`
  (`syrk_write_traffic`: packed vs dual-write vs mirrored output bytes).

Per candidate the prediction is a two-term roofline

    compute_s = mult_flops / (peak · mxu_eff(d_base))
    memory_s  = (add_bytes + stream_bytes + output_bytes) / hbm_bw
    predicted = max(compute_s, memory_s)

where ``mxu_eff(d) = d / (d + d_half)`` models the efficiency loss of small
base matmuls (``d_half`` = tile size at which the matmul engine reaches half
its peak). This term is what creates the Strassen crossover the paper
engineers around: each extra recursion level multiplies mult flops by 7/8
but halves the base dimension, so the analytic argmin lands at a finite
``n_base`` instead of "recurse forever".

The memory terms: ``stream_bytes`` is the blocked-matmul operand traffic
``(mult/2)·(1/bn + 1/bk)`` of the *kernel output tile* (the plan's Pallas
blocks on TPU, XLA's ~256 tiling elsewhere) — the same for the one big
dense dot and for the recursion's base tiles, which is what makes the
comparison honest; ``combine_bytes`` charges the operand-combination
traffic — each VPU addition flop ``add_word_cost`` words for unrolled
(≈1 on TPU where XLA fuses operand combinations into the consuming dot's
reads; higher on CPU), ``stack_word_cost`` words for batched's
materialized stacks, and the 3^L slot-gather amplification for fused —
the Strassen memory overhead the paper's Section 3.3 engineers around.
It is an *additive* term, not part of the compute/memory max: the combine
passes serialize with the leaf matmuls on every measured backend.

A third, previously-unpriced term joins the roofline in this revision:
**per-call launch/graph overhead** (``dispatch_calls × launch_overhead_s``).
The unrolled recursion hands the runtime one op per leaf — ``7^L`` dots —
and on small leaves that dispatch tax, not flops, is what loses to a single
plain dot (BENCH_strassen's 0.19–0.61 speedups). The level-synchronous
``leaf_dispatch='batched'`` formulation collapses it to O(levels) calls at
the price of materialized (un-fused) operand-combination stacks;
``leaf_dispatch='fused'`` collapses both at once — one launch per level
and zero materialized stacks, paying only the slot-gather read
amplification (3^L) and the coefficient tables. The model prices all
three so the argmin can pick per shape.

Candidate axes (``candidates``): algorithm (dense-dot vs strassen vs
winograd vs the ATA recursion), output mode (dense vs packed), recursion
cutoff ``n_base``, leaf dispatch (unrolled vs batched vs fused —
value-identical, speed-different; fused is classical-variant-only), and
the Pallas kernel block shapes. The algorithm /
``n_base`` choice is deliberately **out-invariant** (scored with the dense
output term) so that ``out='packed'`` and ``out='dense'`` plans of one
problem always run the identical recursion — packed results stay bitwise
equal to dense ones regardless of cache state (``leaf_dispatch`` cannot
break this: both dispatches are bitwise-equal by construction, tested).

``distributed_tiling`` is the planner's distributed branch: the lower
triangle tiling search that used to live in ``core.distributed
.choose_tiling`` (which now delegates here).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

from repro.core.reference import (
    blocked_potrf_flops,
    cg_iteration_flops,
    classical_gemm_flops,
    classical_syrk_flops,
    ata_flops,
    strassen_tn_flops,
    strassen_tn_flops_winograd,
    trsm_flops,
)
from repro.tune import defaults

__all__ = [
    "Plan",
    "Machine",
    "MACHINES",
    "machine_for",
    "predict_seconds",
    "retrieval_bytes",
    "dispatch_calls",
    "solve_dispatch_calls",
    "candidates",
    "analytic_plan",
    "default_plan",
    "distributed_tiling",
]

_ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}


# ---------------------------------------------------------------------------
# the frozen dispatch plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    """One fully-resolved ATA/gemm dispatch: problem key + every tunable.

    Frozen and JSON-serializable (``to_json``/``from_json``) — this is the
    value the plan cache stores and the consumers (`core.ata`,
    `core.strassen`, `core.distributed`, `kernels.ops`) read instead of
    loose ints. ``algorithm`` semantics: for ``op='ata'``, 'strassen' /
    'winograd' select the C21 variant of the ATA recursion and 'dense' means
    one classical TN dot; for ``op='gemm_tn'``, they select the FastStrassen
    variant.
    """

    op: str                      # 'ata' | 'gemm_tn' | 'solve'
    m: int
    n: int
    k: int                       # == n for op='ata'; rhs count for op='solve'
    batch: int                   # leading batch size (0 = unbatched)
    dtype: str
    backend: str                 # jax.default_backend() at planning time
    out: str                     # 'dense' | 'packed'
    algorithm: str               # 'dense' | 'strassen' | 'winograd'
    n_base: int
    packed_block: int
    use_kernels: bool            # Pallas base kernels (TPU) vs dot_general
    syrk_blocks: Tuple[int, int]
    gemm_blocks: Tuple[int, int, int]
    # how the recursion's leaves reach the hardware: 'unrolled' = one
    # dot/syrk op per leaf (7^L dots in the jaxpr), 'batched' = the
    # level-synchronous formulation (all leaves in one batched call,
    # bitwise-equal values). Pre-leaf_dispatch cache entries deserialize to
    # 'unrolled' — exactly what they were measured with.
    leaf_dispatch: str = "unrolled"
    # op='solve' only: 'factor' (packed gram → packed Cholesky → two
    # substitutions) or 'cg' (matrix-free CG on the gram operator). None
    # for the product ops — and for pre-solve cache entries, which is why
    # the default keeps them deserializable unchanged.
    method: Optional[str] = None
    devices: int = 1             # distributed branch: task-axis size
    nb: Optional[int] = None     # distributed stripe count (devices > 1)
    tile_w: Optional[int] = None  # distributed stripe width (devices > 1)
    source: str = "analytic"     # 'analytic' | 'measured' | 'cache' | 'default'
    predicted_s: Optional[float] = None
    measured_s: Optional[float] = None
    # seconds of the hardcoded-default dispatch, measured interleaved with
    # this plan by the autotuner (time_pair) — baseline_s/measured_s is the
    # drift-resistant speedup-vs-default the tuning run actually observed.
    baseline_s: Optional[float] = None

    @property
    def variant(self) -> str:
        """Strassen variant usable by the recursion ('dense' plans included:
        the recursion never splits because n_base covers the whole tile)."""
        return "winograd" if self.algorithm == "winograd" else "strassen"

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["syrk_blocks"] = list(self.syrk_blocks)
        d["gemm_blocks"] = list(self.gemm_blocks)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Plan":
        d = dict(d)
        d["syrk_blocks"] = tuple(d["syrk_blocks"])
        d["gemm_blocks"] = tuple(d["gemm_blocks"])
        return cls(**d)


# ---------------------------------------------------------------------------
# machine models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Machine:
    """Roofline parameters of one backend."""

    name: str
    peak_flops: float      # matmul peak, flops/s
    hbm_bw: float          # bytes/s
    d_half: int            # matmul dim at which efficiency reaches 1/2
    kernels: bool          # Pallas kernels compile natively (not interpret)
    add_word_cost: float   # extra HBM words charged per VPU addition flop
    # words charged per addition flop of the *batched* dispatch, whose
    # operand combinations materialize as (7^ℓ,…) stacks the leaf dot then
    # re-reads. Nominally write+read = 2.0; the cpu model carries a larger
    # measured value (see MACHINES) because the block-major relayout and
    # stack concats thrash caches far beyond their linear byte count.
    stack_word_cost: float = 2.0
    xla_tile: int = 256    # nominal output tile of the non-Pallas matmul
    # per dispatched op: runtime launch/dispatch + amortized graph/compile
    # overhead. This is the term the batched leaf dispatch exists to kill:
    # unrolled recursion pays it 7^L times, batched O(L) times.
    launch_overhead_s: float = 5e-6

    def mxu_eff(self, d: int) -> float:
        d = max(int(d), 1)
        return d / (d + self.d_half)


def _tpu_machine() -> Machine:
    # join with the dry-run roofline model so both analyses share one v5e
    # parameterization (PEAK_FLOPS / HBM_BW are defined there).
    from repro.analysis import roofline

    return Machine(
        "tpu", roofline.PEAK_FLOPS, roofline.HBM_BW, 128, True, 1.0,
        launch_overhead_s=1.5e-6,
    )


MACHINES = {
    "tpu": _tpu_machine,
    # Container-class CPU, recalibrated against the min-of-interleaved
    # floors of the batched-leaf PR's measurement sweep (the old 1e11-peak/
    # d_half=48 numbers predated the per-call overhead term and let deep
    # tiny-leaf recursions look free): XLA's dense dot sustains ~205 GFLOP/s
    # at 1024³ on this container (peak 2.2e11), while 256-leaf recursions
    # run at <0.4 of that (d_half 512 — CPU matmul efficiency falls off far
    # harder than the MXU's), and each dispatched op costs ~50 µs of thunk
    # overhead. ``stack_word_cost`` is re-fit against the fused-leaf PR's
    # min-of-interleaved sweep at 2048³/n_base=1024: the batched dispatch
    # trails the unrolled one by ~0.022 s there, which against its ~1.9e7
    # addition flops prices each materialized-stack add at ≈5.5 words —
    # the nominal 2.0 hid behind the compute roofline and ranked batched
    # above unrolled, inverting the measured order. Under this model the
    # argmin at the bench shapes matches the measured per-shape ranking:
    # dense < unrolled(L=1) < fused(L=1) < batched(L=1) < deep recursions.
    "cpu": lambda: Machine("cpu", 2.2e11, 2.0e10, 512, False, 1.5,
                           stack_word_cost=5.5, launch_overhead_s=5e-5),
    # A100-class default for completeness (untuned; autotune refines).
    "gpu": lambda: Machine("gpu", 1.56e14, 1.6e12, 128, False, 1.0,
                           launch_overhead_s=8e-6),
}


def machine_for(backend: str) -> Machine:
    return MACHINES.get(backend, MACHINES["cpu"])()


# ---------------------------------------------------------------------------
# mult/add flop split (exact, mirrors repro.core.reference recursions)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _strassen_mult_flops(m: int, n: int, k: int, n_base: int) -> int:
    """MXU flops of the TN Strassen recursion (base matmuls only)."""
    if min(m, n, k) <= n_base:
        return classical_gemm_flops(m, n, k)
    mp, np_, kp = m + (m & 1), n + (n & 1), k + (k & 1)
    return 7 * _strassen_mult_flops(mp // 2, np_ // 2, kp // 2, n_base)


@functools.lru_cache(maxsize=None)
def _ata_mult_flops(m: int, n: int, n_base: int) -> int:
    """MXU flops of the ATA recursion (classical-syrk base tiles + Strassen
    leaves; the C11/C22/C21 accumulations are VPU adds, not counted here)."""
    if min(m, n) <= n_base:
        return classical_syrk_flops(m, n)
    mp, np_ = m + (m & 1), n + (n & 1)
    m2, n2 = mp // 2, np_ // 2
    return 4 * _ata_mult_flops(m2, n2, n_base) + 2 * _strassen_mult_flops(
        m2, n2, n2, n_base
    )


@functools.lru_cache(maxsize=None)
def _strassen_leaves(m: int, n: int, k: int, n_base: int) -> int:
    """Leaf (base-matmul) count of the TN Strassen recursion."""
    if min(m, n, k) <= n_base:
        return 1
    mp, np_, kp = m + (m & 1), n + (n & 1), k + (k & 1)
    return 7 * _strassen_leaves(mp // 2, np_ // 2, kp // 2, n_base)


@functools.lru_cache(maxsize=None)
def _ata_leaves(m: int, n: int, n_base: int) -> Tuple[int, int]:
    """(syrk_leaves, gemm_leaves) of the ATA tree (4 sub-ATAs + 2 Strassen
    off-diagonal products per level, mirroring `_ata_mult_flops`)."""
    if min(m, n) <= n_base:
        return 1, 0
    mp, np_ = m + (m & 1), n + (n & 1)
    m2, n2 = mp // 2, np_ // 2
    s, g = _ata_leaves(m2, n2, n_base)
    return 4 * s, 4 * g + 2 * _strassen_leaves(m2, n2, n2, n_base)


def _levels(op, m, n, k, n_base) -> int:
    # the recursion's own depth rule — pricing must count the exact tree
    # the dispatch executes (core.strassen only reaches back into tune
    # lazily, so this import is cycle-free, like core.reference above)
    from repro.core.strassen import tree_depth

    return tree_depth((m, n, k) if op == "gemm_tn" else (m, n), n_base)


def dispatch_calls(op, algorithm, m, n, k, n_base, leaf_dispatch) -> int:
    """Ops the dispatch hands the runtime — the per-call-overhead multiplier.

    ``'unrolled'`` pays one dispatched dot/syrk per leaf (``7^L`` for
    Strassen, ``4^L`` syrks + the off-diagonal leaf dots for ATA);
    ``'batched'`` pays the two batched leaf calls plus O(levels)
    encode/decode stack ops. ``'fused'`` is cheapest of all: the slot
    gather lives inside the kernel prologue, so Strassen is one fused
    leaf launch plus one decode pass per level, and ATA is one gathered
    diagonal syrk plus one fused off-diagonal launch and one decode pass
    per level — one launch per *level*, never per leaf. 'dense' is the
    single classical dot.
    """
    if algorithm == "dense":
        return 1
    if leaf_dispatch == "fused":
        lv = _levels(op, m, n, k, n_base)
        if op == "ata":
            return 2 + 2 * lv
        return 1 + lv
    if leaf_dispatch == "batched":
        return 2 + 4 * _levels(op, m, n, k, n_base)
    if op == "ata":
        s, g = _ata_leaves(m, n, n_base)
        return s + g
    return _strassen_leaves(m, n, k, n_base)


def solve_dispatch_calls(n: int, packed_block: int) -> int:
    """Ops the packed factor-and-substitute pipeline hands the runtime
    beyond the gram product itself: per block column one potrf, one batched
    panel trsm and up to two Schur-update einsums; per substitution pass
    one diagonal solve and one update einsum per block row, twice.
    """
    nb = -(-n // packed_block)
    factor = nb + (nb - 1) + 2 * max(nb - 1, 0)   # potrf + trsm + updates
    substitute = 2 * 2 * nb                        # two passes, solve+update
    return factor + substitute


def _solve_predict(
    method: str,
    algorithm: str,
    m: int,
    n: int,
    r: int,
    n_base: int,
    *,
    dtype: str,
    packed_block: int,
    machine: "Machine",
    blocks,
    leaf_dispatch: str = "unrolled",
) -> float:
    """Roofline prediction for one op='solve' candidate.

    ``method='factor'``: the planned packed gram (priced by the product
    model below) plus the factorization/substitution tail — potrf/trsm
    flops from the exact `core.reference` counters, and the **packed**
    write traffic of the factor (the `analysis.roofline` solve model: the
    factor overwrites T·bn² packed words, never an n² square).
    ``method='cg'``: `CG_MAX_ITERS`-capped iterations, each streaming the
    operand twice through the two planned TN products.
    """
    from repro.analysis.roofline import normal_eq_write_traffic

    itemsize = _ITEMSIZE.get(dtype, 4)
    if method == "cg":
        iters = min(n, defaults.CG_MAX_ITERS)
        flops = iters * cg_iteration_flops(m, n, r)
        d = min(m, n)
        compute_s = flops / (machine.peak_flops * machine.mxu_eff(d))
        # each iteration streams A twice (A·p, then Aᵀ(A·p)) + the vectors
        mem = iters * (2 * m * n + 6 * n * r) * itemsize
        overhead = iters * 8 * machine.launch_overhead_s
        return max(compute_s, mem / machine.hbm_bw) + overhead

    gram_s = predict_seconds(
        "ata", algorithm, m, n, n, n_base,
        dtype=dtype, out="packed", packed_block=packed_block,
        machine=machine, blocks=blocks, leaf_dispatch=leaf_dispatch,
    )
    flops = blocked_potrf_flops(n, packed_block) + 2 * trsm_flops(n, r)
    compute_s = flops / (machine.peak_flops * machine.mxu_eff(packed_block))
    mem = normal_eq_write_traffic(n, packed_block, r, itemsize=itemsize)
    overhead = solve_dispatch_calls(n, packed_block) * machine.launch_overhead_s
    return gram_s + max(compute_s, mem / machine.hbm_bw) + overhead


def _flop_split(op, algorithm, m, n, k, n_base):
    """(mult_flops, add_flops) for one candidate — adds = total − mults."""
    if algorithm == "dense":
        # one classical TN dot over the whole operand (no recursion)
        mult = classical_gemm_flops(m, n, k)
        return mult, 0
    winograd = algorithm == "winograd"
    if op == "ata":
        total = ata_flops(m, n, n_base, winograd=winograd)
        mult = _ata_mult_flops(m, n, n_base)
    else:
        s = strassen_tn_flops_winograd if winograd else strassen_tn_flops
        total = s(m, n, k, n_base)
        mult = _strassen_mult_flops(m, n, k, n_base)
    return mult, max(total - mult, 0)


def _output_bytes(op, out, n, k, packed_block, itemsize) -> int:
    """HBM bytes written for the final output (roofline join point)."""
    from repro.analysis.roofline import syrk_write_traffic

    if op == "ata":
        mode = "packed" if out == "packed" else "dual"
        return syrk_write_traffic(n, packed_block, mode, itemsize)
    return n * k * itemsize


def retrieval_bytes(
    out: str,
    nb: int,
    tile_w: int,
    itemsize: int = 4,
) -> int:
    """Retrieval payload of the distributed tile schedule, per device.

    Both terms are functions of the padded stripe grid alone.
    ``out='packed'`` ships the psum'd/gathered tile stack itself —
    ``T·w² ≈ n²/2`` words (paper Prop. 4.2's low(C) saving as collective
    bytes). ``out='dense'`` additionally materializes the mirrored
    ``(nb·w)²`` square on every device — the dense-replication cost the
    packed mode removes.
    """
    t_total = nb * (nb + 1) // 2
    stack = t_total * tile_w * tile_w * itemsize
    if out == "packed":
        return stack
    return (nb * tile_w) ** 2 * itemsize


def predict_seconds(
    op: str,
    algorithm: str,
    m: int,
    n: int,
    k: int,
    n_base: int,
    *,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
    packed_block: int = defaults.DEFAULT_PACKED_BLOCK,
    machine: Optional[Machine] = None,
    backend: str = "cpu",
    blocks: Optional[Tuple[int, int]] = None,
    devices: int = 1,
    nb: Optional[int] = None,
    tile_w: Optional[int] = None,
    leaf_dispatch: str = "unrolled",
) -> float:
    """Roofline prediction for one candidate configuration.

    ``blocks``: the (bn, bk) output tile of the base matmul engine — the
    plan's Pallas blocks when kernels are in play, the backend's nominal
    XLA tiling otherwise. With ``devices > 1`` (the planner's distributed
    branch) the output term becomes the tile schedule's *retrieval* payload
    (:func:`retrieval_bytes`) — packed tile stack vs replicated dense
    square — for the ``nb``/``tile_w`` stripe tiling.

    ``leaf_dispatch`` moves two terms in opposite directions: ``'unrolled'``
    pays :func:`dispatch_calls` × ``launch_overhead_s`` (one dispatched op
    per leaf — the term that was silently zero before and made tiny-leaf
    recursions look free); ``'batched'`` pays O(levels) calls but its
    operand-combination adds are *materialized* stacks the leaf dot then
    re-reads, charged ``stack_word_cost`` words per add (nominal write+read
    = 2.0, measured higher on cpu); ``'fused'`` pays neither — its stack
    charge drops to ~0, replaced by the slot-gather read amplification
    (each root leaf block is read once per nonzero slot: Strassen's combos
    total 12 terms per 7 children per side, so L levels amplify the operand
    read by (12/4)^L = 3^L) plus the coefficient tables themselves.

    The combine/add traffic is charged *additively* on top of the
    compute/memory roofline max, not inside it: on every backend we
    measured, the operand-combination passes serialize with the leaf
    matmuls (XLA:CPU runs them as separate thunks; the fused kernel runs
    them in the same launch but on the VPU ahead of each MXU tile), and
    folding them into the max() hid them entirely at compute-bound shapes
    — which is exactly where the bench measurements show the dispatches
    separating.
    """
    mach = machine or machine_for(backend)
    itemsize = _ITEMSIZE.get(dtype, 4)
    b = max(batch, 1)

    mult, adds = _flop_split(op, algorithm, m, n, k, n_base)
    d_base = min(n_base, m, n, k) if algorithm != "dense" else min(m, n, k)
    compute_s = b * mult / (mach.peak_flops * mach.mxu_eff(d_base))

    # memory: operand streaming of the blocked base matmuls (each output
    # tile re-reads its operand panels: (mult/2)·(1/bn + 1/bk) words), the
    # fused-add traffic, and the output writes per the roofline model.
    bn, bk = blocks or (mach.xla_tile, mach.xla_tile)
    bn = min(bn, max(d_base, 1))
    bk = min(bk, max(d_base, 1))
    stream_bytes = (mult / 2) * (1.0 / bn + 1.0 / bk) * itemsize
    if leaf_dispatch == "fused" and algorithm != "dense":
        # no materialized stacks: the slot gather reads each root leaf
        # block once per nonzero slot (3^L amplification, see docstring),
        # plus the six (7^L, 2^L) int32 coefficient tables.
        lv = _levels(op, m, n, k, n_base)
        operand_words = (m * n + m * k) if op == "gemm_tn" else 2 * m * n
        combine_bytes = operand_words * 3.0**lv * itemsize + 6 * 14**lv * 4
        if not mach.kernels:
            # interpret/XLA fallback: the gathered combinations still
            # materialize per leaf (briefly — never as cross-leaf stacks)
            # and are re-read by the leaf dot; charge the addition flops
            # like the unrolled form on top of the gather reads.
            combine_bytes += mach.add_word_cost * adds * itemsize
    else:
        add_word_cost = (
            mach.stack_word_cost
            if leaf_dispatch == "batched" and algorithm != "dense"
            else mach.add_word_cost
        )
        combine_bytes = add_word_cost * adds * itemsize
    if devices > 1 and op == "ata":
        if nb is None or tile_w is None:
            nb, tile_w = distributed_tiling(
                n, devices, out=out, packed_block=packed_block
            )
        out_bytes = retrieval_bytes(out, nb, tile_w, itemsize)
    else:
        out_bytes = _output_bytes(op, out, n, k, packed_block, itemsize)
    memory_s = b * (stream_bytes + out_bytes) / mach.hbm_bw
    combine_s = b * combine_bytes / mach.hbm_bw
    overhead_s = (
        dispatch_calls(op, algorithm, m, n, k, n_base, leaf_dispatch)
        * mach.launch_overhead_s
    )
    return max(compute_s, memory_s) + combine_s + overhead_s


# ---------------------------------------------------------------------------
# candidate enumeration and the analytic argmin
# ---------------------------------------------------------------------------


def _kernel_blocks(machine):
    """Best feasible (syrk_blocks, gemm_blocks) under the VMEM budget.

    Blocks only move the memory term: minimize output-tile streaming
    (1/bn [+ 1/bk]), tie-break on the smaller VMEM footprint.
    """
    vmem = 12 * 2**20  # leave headroom below the ~16 MB VMEM
    syrk = [
        (bm, bn)
        for bm, bn in defaults.SYRK_BLOCK_CANDIDATES
        if 2 * bm * bn * 4 + bn * bn * 4 <= vmem
    ]
    gemm = [
        (bm, bn, bk)
        for bm, bn, bk in defaults.GEMM_BLOCK_CANDIDATES
        if bm * (bn + bk) * 4 + bn * bk * 4 <= vmem
    ]
    syrk = sorted(
        syrk or [defaults.SYRK_BLOCKS],
        key=lambda b: (2.0 / b[1], 2 * b[0] * b[1] + b[1] * b[1]),
    )
    gemm = sorted(
        gemm or [defaults.GEMM_BLOCKS],
        key=lambda b: (1.0 / b[1] + 1.0 / b[2], b[0] * (b[1] + b[2]) + b[1] * b[2]),
    )
    return syrk[0], gemm[0]


def candidates(
    op: str,
    m: int,
    n: int,
    k: Optional[int] = None,
    *,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
    backend: str = "cpu",
    devices: int = 1,
) -> list:
    """Enumerate scored candidate Plans, best predicted first.

    Scoring uses ``out='dense'`` for the algorithm/n_base choice (see module
    docstring: out-invariance keeps packed results bitwise equal to dense),
    then attaches the requested ``out`` and its write-traffic prediction.

    ``op='solve'`` (``k`` = RHS count) enumerates the two solver methods —
    the factor pipeline inheriting the best packed-gram candidate's
    algorithm tunables, and matrix-free CG inheriting the best TN-product
    candidate's — scored by :func:`_solve_predict`.
    """
    k = n if k is None else k
    mach = machine_for(backend)
    if op == "solve":
        return _solve_candidates(
            m, n, k, batch=batch, dtype=dtype, out=out, backend=backend
        )
    syrk_bs, gemm_bs = _kernel_blocks(mach)
    base_tile = (
        (syrk_bs[1], syrk_bs[1]) if op == "ata" else (gemm_bs[1], gemm_bs[2])
    ) if mach.kernels else None
    nb, tile_w = (None, None)
    if devices > 1:
        # the requested out feeds the tiling so packed plans snap tile_w
        # to the packed block grid (pure-slice retrieval, no repack)
        nb, tile_w = distributed_tiling(
            n, devices, out=out, packed_block=defaults.DEFAULT_PACKED_BLOCK
        )

    algos = ["dense", "strassen", "winograd"]
    n_bases = sorted({min(nb_c, max(m, n, k)) for nb_c in defaults.N_BASE_CANDIDATES})
    scored = []
    seen_degenerate = False
    for algo in algos:
        for n_base in n_bases if algo != "dense" else [defaults.DEFAULT_N_BASE]:
            lds = defaults.LEAF_DISPATCH_CANDIDATES
            if algo != "strassen":
                # fused slot tables encode the classical 7-term combos
                # only — winograd's chained within-level sums don't fit
                # (core.strassen raises), and dense has nothing to fuse.
                lds = tuple(ld for ld in lds if ld != "fused")
            if algo == "dense":
                lds = ("unrolled",)  # one classical dot — nothing to batch
            elif min(m, n, k) <= n_base:
                # recursion bottoms out immediately — all such cutoffs (and
                # both leaf dispatches: one leaf IS one call) are the same
                # dispatch; keep one canonical representative.
                if seen_degenerate:
                    continue
                seen_degenerate = True
                lds = ("unrolled",)
            for ld in lds:
                pred = predict_seconds(
                    op, algo, m, n, k, n_base,
                    batch=batch, dtype=dtype, out="dense", machine=mach,
                    blocks=base_tile, leaf_dispatch=ld,
                )
                scored.append((pred, algo, n_base, ld))
    scored.sort(key=lambda s: s[0])

    plans = []
    for pred, algo, n_base, ld in scored:
        pred_out = predict_seconds(
            op, algo, m, n, k, n_base,
            batch=batch, dtype=dtype, out=out, machine=mach, blocks=base_tile,
            devices=devices, nb=nb, tile_w=tile_w, leaf_dispatch=ld,
        )
        plans.append(
            Plan(
                op=op, m=m, n=n, k=k, batch=batch, dtype=dtype,
                backend=backend, out=out, algorithm=algo, n_base=n_base,
                packed_block=defaults.DEFAULT_PACKED_BLOCK,
                use_kernels=mach.kernels,
                syrk_blocks=syrk_bs, gemm_blocks=gemm_bs,
                leaf_dispatch=ld,
                devices=devices, nb=nb, tile_w=tile_w,
                source="analytic", predicted_s=pred_out,
            )
        )
    return plans


def _solve_candidates(
    m: int,
    n: int,
    r: int,
    *,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "packed",
    backend: str = "cpu",
) -> list:
    """Scored op='solve' candidates, best predicted first.

    The factor candidate carries the best *packed-gram* candidate's
    algorithm tunables (the gram dominates its cost and the factor walk
    has no algorithm choice of its own); the CG candidate carries the best
    TN-product candidate's (its iterations are ``Aᵀ(A·p)`` pairs).
    """
    if batch:
        raise ValueError("op='solve' plans are unbatched (lstsq is 2-D); "
                         f"got batch={batch}")
    mach = machine_for(backend)
    syrk_bs, gemm_bs = _kernel_blocks(mach)
    base_tile = (syrk_bs[1], syrk_bs[1]) if mach.kernels else None
    common = dict(
        op="solve", m=m, n=n, k=r, batch=batch, dtype=dtype,
        backend=backend, out=out,
        packed_block=defaults.DEFAULT_PACKED_BLOCK,
        use_kernels=mach.kernels,
        syrk_blocks=syrk_bs, gemm_blocks=gemm_bs, source="analytic",
    )
    gram = candidates(
        "ata", m, n, batch=batch, dtype=dtype, out="packed", backend=backend
    )[0]
    gemm = candidates(
        "gemm_tn", m, n, r, batch=batch, dtype=dtype, out="dense",
        backend=backend,
    )[0]
    plans = []
    for method, donor in (("factor", gram), ("cg", gemm)):
        pred = _solve_predict(
            method, donor.algorithm, m, n, r, donor.n_base,
            dtype=dtype, packed_block=donor.packed_block, machine=mach,
            blocks=base_tile, leaf_dispatch=donor.leaf_dispatch,
        )
        plans.append(
            Plan(
                algorithm=donor.algorithm, n_base=donor.n_base,
                leaf_dispatch=donor.leaf_dispatch, method=method,
                predicted_s=pred, **common,
            )
        )
    plans.sort(key=lambda p: p.predicted_s)
    return plans


def analytic_plan(op, m, n, k=None, **kw) -> Plan:
    """The analytic argmin — what ``repro.tune.plan`` returns on cache miss."""
    return candidates(op, m, n, k, **kw)[0]


def default_plan(
    op: str,
    m: int,
    n: int,
    k: Optional[int] = None,
    *,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
    backend: str = "cpu",
    devices: int = 1,
) -> Plan:
    """The pre-tune-subsystem hardcoded configuration, as a Plan.

    This is the baseline `bench_tune` measures the planner against, and the
    fallback consumers use when a caller pins *some* tunables manually.
    """
    k = n if k is None else k
    mach = machine_for(backend)
    nb, tile_w = (None, None)
    if devices > 1:
        nb, tile_w = distributed_tiling(
            n, devices, out=out, packed_block=defaults.DEFAULT_PACKED_BLOCK
        )
    return Plan(
        op=op, m=m, n=n, k=k, batch=batch, dtype=dtype, backend=backend,
        out=out, algorithm=defaults.DEFAULT_VARIANT,
        n_base=defaults.DEFAULT_N_BASE,
        packed_block=defaults.DEFAULT_PACKED_BLOCK,
        use_kernels=mach.kernels,
        syrk_blocks=defaults.SYRK_BLOCKS, gemm_blocks=defaults.GEMM_BLOCKS,
        leaf_dispatch=defaults.DEFAULT_LEAF_DISPATCH,
        method=defaults.DEFAULT_SOLVE_METHOD if op == "solve" else None,
        devices=devices, nb=nb, tile_w=tile_w, source="default",
    )


# ---------------------------------------------------------------------------
# distributed branch: lower-triangle tile search (ex core.distributed)
# ---------------------------------------------------------------------------


def distributed_tiling(
    n: int,
    p: int,
    target_tiles_per_dev: Optional[int] = None,
    *,
    out: str = "dense",
    packed_block: Optional[int] = None,
    n_base: Optional[int] = None,
):
    """Pick (nb, w): stripe count and stripe width (multiple of 8) for the
    block-cyclic lower-triangle schedule of ``ata_tile_parallel``.

    Wants: T = nb(nb+1)/2 ≥ p (enough tasks), small T mod p (balance),
    w reasonably large (MXU efficiency). Searches a small static range.

    With ``out='packed'``, stripe widths that **snap to the packed block
    grid** (``w == symmetric.default_block_size(n, packed_block)``) are
    preferred, and the exactly-aligned stripe count ``⌈n/bn⌉`` joins the
    candidate set: an aligned tiling makes the packed retrieval a pure
    slice of the psum'd tile stack (no repack pass). Two things outrank
    alignment, in order: **balance** (a misaligned zero-waste tiling beats
    an aligned one that idles devices) and **leaf Strassen depth** — a
    candidate whose stripes are wide enough for more recursion levels
    (``⌈log₂(w/n_base)⌉``, ``n_base`` defaulting to the static cutoff)
    keeps the 7/8-mult saving that narrow aligned stripes would forfeit,
    which is worth far more than the repack copy it costs. For
    ``out='dense'`` both new terms are order-compatible with the
    historical (waste, −w) search, so dense tilings are unchanged.
    """
    from repro.core.symmetric import default_block_size

    if target_tiles_per_dev is None:
        target_tiles_per_dev = defaults.TARGET_TILES_PER_DEVICE
    if n_base is None:
        n_base = defaults.DEFAULT_N_BASE
    bn_pack = None
    if out == "packed":
        bn_pack = default_block_size(
            n, packed_block or defaults.DEFAULT_PACKED_BLOCK
        )

    def strassen_depth(w: int) -> int:
        d = 0
        while w > n_base:
            w -= w // 2  # ceil-halving, as the recursion splits
            d += 1
        return d

    nb_min = max(1, math.ceil((math.sqrt(8 * p + 1) - 1) / 2))
    cand = list(range(nb_min, 4 * nb_min + 8))
    if bn_pack is not None:
        nb_aligned = -(-n // bn_pack)
        if nb_aligned >= nb_min and nb_aligned not in cand:
            cand.append(nb_aligned)
    best = None
    for nb in cand:
        t = nb * (nb + 1) // 2
        if t < p:
            continue
        per = -(-t // p)
        waste = per * p - t
        w = -(-n // nb)
        w = -(-w // 8) * 8  # round width up to sublane multiple
        # order: balance → leaf Strassen depth → (packed) grid alignment →
        # width. For out='dense', misaligned ≡ 0 and depth is monotone in
        # w, so the argmin coincides with the historical (waste·w², −w).
        misaligned = 1 if (bn_pack is not None and w != bn_pack) else 0
        score = (waste * w * w, -strassen_depth(w), misaligned, -w)
        if best is None or score < best[0]:
            best = (score, nb, w)
        if t >= target_tiles_per_dev * p and waste == 0 and not misaligned:
            break
    _, nb, w = best
    return nb, w
