"""Analytic cost model: predict the best dispatch plan for an ATA product.

The model joins the two quantitative assets the repo already owns:

* the **exact flop counters** of `repro.core.reference` (they walk the same
  floor/ceil recursion as the implementations, so counts are exact for any
  rectangular shape and cutoff), split here into MXU multiply flops and VPU
  addition flops, and
* the **write-traffic model** of `repro.analysis.roofline`
  (`syrk_write_traffic`: packed vs dual-write vs mirrored output bytes).

Per candidate the prediction is a two-term roofline

    compute_s = mult_flops / (peak · mxu_eff(d_base))
    memory_s  = (add_bytes + stream_bytes + output_bytes) / hbm_bw
    predicted = max(compute_s, memory_s)

where ``mxu_eff(d) = d / (d + d_half)`` models the efficiency loss of small
base matmuls (``d_half`` = tile size at which the matmul engine reaches half
its peak). This term is what creates the Strassen crossover the paper
engineers around: each extra recursion level multiplies mult flops by 7/8
but halves the base dimension, so the analytic argmin lands at a finite
``n_base`` instead of "recurse forever".

The memory terms: ``stream_bytes`` is the blocked-matmul operand traffic
``(mult/2)·(1/bn + 1/bk)`` of the *kernel output tile* (the plan's Pallas
blocks on TPU, XLA's ~256 tiling elsewhere) — the same for the one big
dense dot and for the recursion's base tiles, which is what makes the
comparison honest; ``combine_bytes`` charges the operand-combination
traffic — each VPU addition flop ``add_word_cost`` words for unrolled
(≈1 on TPU where XLA fuses operand combinations into the consuming dot's
reads; higher on CPU), ``stack_word_cost`` words for batched's
materialized stacks, and the 3^L slot-gather amplification for fused —
the Strassen memory overhead the paper's Section 3.3 engineers around.
It is an *additive* term, not part of the compute/memory max: the combine
passes serialize with the leaf matmuls on every measured backend.

A third, previously-unpriced term joins the roofline in this revision:
**per-call launch/graph overhead** (``dispatch_calls × launch_overhead_s``).
The unrolled recursion hands the runtime one op per leaf — ``7^L`` dots —
and on small leaves that dispatch tax, not flops, is what loses to a single
plain dot (BENCH_strassen's 0.19–0.61 speedups). The level-synchronous
``leaf_dispatch='batched'`` formulation collapses it to O(levels) calls at
the price of materialized (un-fused) operand-combination stacks;
``leaf_dispatch='fused'`` collapses both at once — one launch per level
and zero materialized stacks, paying only the slot-gather read
amplification (3^L) and the coefficient tables. The model prices all
three so the argmin can pick per shape.

Candidate axes (``candidates``): algorithm (dense-dot vs strassen vs
winograd vs the ATA recursion), output mode (dense vs packed), recursion
cutoff ``n_base``, leaf dispatch (unrolled vs batched vs fused —
value-identical, speed-different; fused is classical-variant-only), and
the Pallas kernel block shapes. The algorithm /
``n_base`` choice is deliberately **out-invariant** (scored with the dense
output term) so that ``out='packed'`` and ``out='dense'`` plans of one
problem always run the identical recursion — packed results stay bitwise
equal to dense ones regardless of cache state (``leaf_dispatch`` cannot
break this: both dispatches are bitwise-equal by construction, tested).

``distributed_tiling`` is the planner's distributed branch: the lower
triangle tiling search that used to live in ``core.distributed
.choose_tiling`` (which now delegates here).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

from repro.core.reference import (
    blocked_potrf_flops,
    cg_iteration_flops,
    classical_gemm_flops,
    classical_syrk_flops,
    ata_flops,
    strassen_tn_flops,
    strassen_tn_flops_winograd,
    trsm_flops,
)
from repro.tune import defaults

__all__ = [
    "Plan",
    "Machine",
    "MACHINES",
    "machine_for",
    "predict_seconds",
    "retrieval_bytes",
    "comm_levels",
    "comm_seconds",
    "comm_memory_bytes",
    "comm_schedule_candidates",
    "choose_comm_schedule",
    "dispatch_calls",
    "solve_dispatch_calls",
    "candidates",
    "analytic_plan",
    "default_plan",
    "distributed_tiling",
    "bfs_tiling",
]

_ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}


# ---------------------------------------------------------------------------
# the frozen dispatch plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    """One fully-resolved ATA/gemm dispatch: problem key + every tunable.

    Frozen and JSON-serializable (``to_json``/``from_json``) — this is the
    value the plan cache stores and the consumers (`core.ata`,
    `core.strassen`, `core.distributed`, `kernels.ops`) read instead of
    loose ints. ``algorithm`` semantics: for ``op='ata'``, 'strassen' /
    'winograd' select the C21 variant of the ATA recursion and 'dense' means
    one classical TN dot; for ``op='gemm_tn'``, they select the FastStrassen
    variant.
    """

    op: str                      # 'ata' | 'gemm_tn' | 'solve'
    m: int
    n: int
    k: int                       # == n for op='ata'; rhs count for op='solve'
    batch: int                   # leading batch size (0 = unbatched)
    dtype: str
    backend: str                 # jax.default_backend() at planning time
    out: str                     # 'dense' | 'packed'
    algorithm: str               # 'dense' | 'strassen' | 'winograd'
    n_base: int
    packed_block: int
    use_kernels: bool            # Pallas base kernels (TPU) vs dot_general
    syrk_blocks: Tuple[int, int]
    gemm_blocks: Tuple[int, int, int]
    # how the recursion's leaves reach the hardware: 'unrolled' = one
    # dot/syrk op per leaf (7^L dots in the jaxpr), 'batched' = the
    # level-synchronous formulation (all leaves in one batched call,
    # bitwise-equal values). Pre-leaf_dispatch cache entries deserialize to
    # 'unrolled' — exactly what they were measured with.
    leaf_dispatch: str = "unrolled"
    # op='solve' only: 'factor' (packed gram → packed Cholesky → two
    # substitutions) or 'cg' (matrix-free CG on the gram operator). None
    # for the product ops — and for pre-solve cache entries, which is why
    # the default keeps them deserializable unchanged.
    method: Optional[str] = None
    devices: int = 1             # distributed branch: task-axis size
    nb: Optional[int] = None     # distributed stripe count (devices > 1)
    tile_w: Optional[int] = None  # distributed stripe width (devices > 1)
    # distributed branch, devices > 1 only: row (reduction) axis size of
    # the two-level ATA-D mesh, and the BFS/DFS interleaving string of the
    # CAPS-style schedule ('B'/'D' per recursion level — the contract of
    # core.distributed.bfs_dfs_assignment). None = the plain-psum schedule
    # (ata_tile_parallel); pre-v4 cache entries deserialize to exactly
    # that, which is what they were measured with.
    row_devices: int = 1
    comm_schedule: Optional[str] = None
    source: str = "analytic"     # 'analytic' | 'measured' | 'cache' | 'default'
    predicted_s: Optional[float] = None
    measured_s: Optional[float] = None
    # seconds of the hardcoded-default dispatch, measured interleaved with
    # this plan by the autotuner (time_pair) — baseline_s/measured_s is the
    # drift-resistant speedup-vs-default the tuning run actually observed.
    baseline_s: Optional[float] = None

    @property
    def variant(self) -> str:
        """Strassen variant usable by the recursion ('dense' plans included:
        the recursion never splits because n_base covers the whole tile)."""
        return "winograd" if self.algorithm == "winograd" else "strassen"

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["syrk_blocks"] = list(self.syrk_blocks)
        d["gemm_blocks"] = list(self.gemm_blocks)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Plan":
        d = dict(d)
        d["syrk_blocks"] = tuple(d["syrk_blocks"])
        d["gemm_blocks"] = tuple(d["gemm_blocks"])
        return cls(**d)


# ---------------------------------------------------------------------------
# machine models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Machine:
    """Roofline parameters of one backend."""

    name: str
    peak_flops: float      # matmul peak, flops/s
    hbm_bw: float          # bytes/s
    d_half: int            # matmul dim at which efficiency reaches 1/2
    kernels: bool          # Pallas kernels compile natively (not interpret)
    add_word_cost: float   # extra HBM words charged per VPU addition flop
    # words charged per addition flop of the *batched* dispatch, whose
    # operand combinations materialize as (7^ℓ,…) stacks the leaf dot then
    # re-reads. Nominally write+read = 2.0; the cpu model carries a larger
    # measured value (see MACHINES) because the block-major relayout and
    # stack concats thrash caches far beyond their linear byte count.
    stack_word_cost: float = 2.0
    xla_tile: int = 256    # nominal output tile of the non-Pallas matmul
    # per dispatched op: runtime launch/dispatch + amortized graph/compile
    # overhead. This is the term the batched leaf dispatch exists to kill:
    # unrolled recursion pays it 7^L times, batched O(L) times.
    launch_overhead_s: float = 5e-6
    # α-β collective model (distributed branch): per-message latency and
    # per-byte transfer time of one collective step. α is what the psum
    # schedule's single all-reduce amortizes and the BFS scatter+gather
    # pair pays twice; β is what the scattered retrieval halves. The cpu
    # values are calibrated on the 8-fake-device container (see MACHINES).
    alpha_s: float = 1e-6
    beta_s_per_byte: float = 2.5e-11
    # per-device memory budget the interleaving choice is priced against
    # (CAPS's memory-vs-bandwidth rule): schedules whose per-device
    # residency exceeds it are infeasible.
    device_memory_bytes: float = 16e9

    def mxu_eff(self, d: int) -> float:
        d = max(int(d), 1)
        return d / (d + self.d_half)


def _tpu_machine() -> Machine:
    # join with the dry-run roofline model so both analyses share one v5e
    # parameterization (PEAK_FLOPS / HBM_BW are defined there).
    from repro.analysis import roofline

    return Machine(
        "tpu", roofline.PEAK_FLOPS, roofline.HBM_BW, 128, True, 1.0,
        launch_overhead_s=1.5e-6,
        # ICI-class interconnect: ~1 µs collective step, ~9e10 B/s per link
        alpha_s=1e-6, beta_s_per_byte=1.1e-11, device_memory_bytes=16e9,
    )


MACHINES = {
    "tpu": _tpu_machine,
    # Container-class CPU, recalibrated against the min-of-interleaved
    # floors of the batched-leaf PR's measurement sweep (the old 1e11-peak/
    # d_half=48 numbers predated the per-call overhead term and let deep
    # tiny-leaf recursions look free): XLA's dense dot sustains ~205 GFLOP/s
    # at 1024³ on this container (peak 2.2e11), while 256-leaf recursions
    # run at <0.4 of that (d_half 512 — CPU matmul efficiency falls off far
    # harder than the MXU's), and each dispatched op costs ~50 µs of thunk
    # overhead. ``stack_word_cost`` is re-fit against the fused-leaf PR's
    # min-of-interleaved sweep at 2048³/n_base=1024: the batched dispatch
    # trails the unrolled one by ~0.022 s there, which against its ~1.9e7
    # addition flops prices each materialized-stack add at ≈5.5 words —
    # the nominal 2.0 hid behind the compute roofline and ranked batched
    # above unrolled, inverting the measured order. Under this model the
    # argmin at the bench shapes matches the measured per-shape ranking:
    # dense < unrolled(L=1) < fused(L=1) < batched(L=1) < deep recursions.
    # α-β terms calibrated on the 8-fake-device container via the
    # obs.calibrate drift rows of the distributed sweep (fake devices
    # share one memory): a collective "message" costs a thunk dispatch
    # ≈ the 5e-5 launch floor; β from the same-compute psum-vs-scatter
    # differential at the (1,8) rowshard mesh — Δ2.6 ms over Δ3.9 MB of
    # collective payload ≈ 7e-10 s/B (fake-device "links" run at shared-
    # memcpy-under-contention speed, ~1.4e9 B/s, not the 1e10 B/s a real
    # socket-local memcpy would suggest).
    "cpu": lambda: Machine("cpu", 2.2e11, 2.0e10, 512, False, 1.5,
                           stack_word_cost=5.5, launch_overhead_s=5e-5,
                           alpha_s=5e-5, beta_s_per_byte=7e-10,
                           device_memory_bytes=2e9),
    # A100-class default for completeness (untuned; autotune refines).
    "gpu": lambda: Machine("gpu", 1.56e14, 1.6e12, 128, False, 1.0,
                           launch_overhead_s=8e-6,
                           alpha_s=4e-6, beta_s_per_byte=4e-12,
                           device_memory_bytes=8e10),
}


def machine_for(backend: str) -> Machine:
    return MACHINES.get(backend, MACHINES["cpu"])()


# ---------------------------------------------------------------------------
# mult/add flop split (exact, mirrors repro.core.reference recursions)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _strassen_mult_flops(m: int, n: int, k: int, n_base: int) -> int:
    """MXU flops of the TN Strassen recursion (base matmuls only)."""
    if min(m, n, k) <= n_base:
        return classical_gemm_flops(m, n, k)
    mp, np_, kp = m + (m & 1), n + (n & 1), k + (k & 1)
    return 7 * _strassen_mult_flops(mp // 2, np_ // 2, kp // 2, n_base)


@functools.lru_cache(maxsize=None)
def _ata_mult_flops(m: int, n: int, n_base: int) -> int:
    """MXU flops of the ATA recursion (classical-syrk base tiles + Strassen
    leaves; the C11/C22/C21 accumulations are VPU adds, not counted here)."""
    if min(m, n) <= n_base:
        return classical_syrk_flops(m, n)
    mp, np_ = m + (m & 1), n + (n & 1)
    m2, n2 = mp // 2, np_ // 2
    return 4 * _ata_mult_flops(m2, n2, n_base) + 2 * _strassen_mult_flops(
        m2, n2, n2, n_base
    )


@functools.lru_cache(maxsize=None)
def _strassen_leaves(m: int, n: int, k: int, n_base: int) -> int:
    """Leaf (base-matmul) count of the TN Strassen recursion."""
    if min(m, n, k) <= n_base:
        return 1
    mp, np_, kp = m + (m & 1), n + (n & 1), k + (k & 1)
    return 7 * _strassen_leaves(mp // 2, np_ // 2, kp // 2, n_base)


@functools.lru_cache(maxsize=None)
def _ata_leaves(m: int, n: int, n_base: int) -> Tuple[int, int]:
    """(syrk_leaves, gemm_leaves) of the ATA tree (4 sub-ATAs + 2 Strassen
    off-diagonal products per level, mirroring `_ata_mult_flops`)."""
    if min(m, n) <= n_base:
        return 1, 0
    mp, np_ = m + (m & 1), n + (n & 1)
    m2, n2 = mp // 2, np_ // 2
    s, g = _ata_leaves(m2, n2, n_base)
    return 4 * s, 4 * g + 2 * _strassen_leaves(m2, n2, n2, n_base)


def _levels(op, m, n, k, n_base) -> int:
    # the recursion's own depth rule — pricing must count the exact tree
    # the dispatch executes (core.strassen only reaches back into tune
    # lazily, so this import is cycle-free, like core.reference above)
    from repro.core.strassen import tree_depth

    return tree_depth((m, n, k) if op == "gemm_tn" else (m, n), n_base)


def dispatch_calls(op, algorithm, m, n, k, n_base, leaf_dispatch) -> int:
    """Ops the dispatch hands the runtime — the per-call-overhead multiplier.

    ``'unrolled'`` pays one dispatched dot/syrk per leaf (``7^L`` for
    Strassen, ``4^L`` syrks + the off-diagonal leaf dots for ATA);
    ``'batched'`` pays the two batched leaf calls plus O(levels)
    encode/decode stack ops. ``'fused'`` is cheapest of all: the slot
    gather lives inside the kernel prologue, so Strassen is one fused
    leaf launch plus one decode pass per level, and ATA is one gathered
    diagonal syrk plus one fused off-diagonal launch and one decode pass
    per level — one launch per *level*, never per leaf. 'dense' is the
    single classical dot.
    """
    if algorithm == "dense":
        return 1
    if leaf_dispatch == "fused":
        lv = _levels(op, m, n, k, n_base)
        if op == "ata":
            return 2 + 2 * lv
        return 1 + lv
    if leaf_dispatch == "batched":
        return 2 + 4 * _levels(op, m, n, k, n_base)
    if op == "ata":
        s, g = _ata_leaves(m, n, n_base)
        return s + g
    return _strassen_leaves(m, n, k, n_base)


def solve_dispatch_calls(n: int, packed_block: int) -> int:
    """Ops the packed factor-and-substitute pipeline hands the runtime
    beyond the gram product itself: per block column one potrf, one batched
    panel trsm and up to two Schur-update einsums; per substitution pass
    one diagonal solve and one update einsum per block row, twice.
    """
    nb = -(-n // packed_block)
    factor = nb + (nb - 1) + 2 * max(nb - 1, 0)   # potrf + trsm + updates
    substitute = 2 * 2 * nb                        # two passes, solve+update
    return factor + substitute


def _solve_predict(
    method: str,
    algorithm: str,
    m: int,
    n: int,
    r: int,
    n_base: int,
    *,
    dtype: str,
    packed_block: int,
    machine: "Machine",
    blocks,
    leaf_dispatch: str = "unrolled",
) -> float:
    """Roofline prediction for one op='solve' candidate.

    ``method='factor'``: the planned packed gram (priced by the product
    model below) plus the factorization/substitution tail — potrf/trsm
    flops from the exact `core.reference` counters, and the **packed**
    write traffic of the factor (the `analysis.roofline` solve model: the
    factor overwrites T·bn² packed words, never an n² square).
    ``method='cg'``: `CG_MAX_ITERS`-capped iterations, each streaming the
    operand twice through the two planned TN products.
    """
    from repro.analysis.roofline import normal_eq_write_traffic

    itemsize = _ITEMSIZE.get(dtype, 4)
    if method == "cg":
        iters = min(n, defaults.CG_MAX_ITERS)
        flops = iters * cg_iteration_flops(m, n, r)
        d = min(m, n)
        compute_s = flops / (machine.peak_flops * machine.mxu_eff(d))
        # each iteration streams A twice (A·p, then Aᵀ(A·p)) + the vectors
        mem = iters * (2 * m * n + 6 * n * r) * itemsize
        overhead = iters * 8 * machine.launch_overhead_s
        return max(compute_s, mem / machine.hbm_bw) + overhead

    gram_s = predict_seconds(
        "ata", algorithm, m, n, n, n_base,
        dtype=dtype, out="packed", packed_block=packed_block,
        machine=machine, blocks=blocks, leaf_dispatch=leaf_dispatch,
    )
    flops = blocked_potrf_flops(n, packed_block) + 2 * trsm_flops(n, r)
    compute_s = flops / (machine.peak_flops * machine.mxu_eff(packed_block))
    mem = normal_eq_write_traffic(n, packed_block, r, itemsize=itemsize)
    overhead = solve_dispatch_calls(n, packed_block) * machine.launch_overhead_s
    return gram_s + max(compute_s, mem / machine.hbm_bw) + overhead


def _flop_split(op, algorithm, m, n, k, n_base):
    """(mult_flops, add_flops) for one candidate — adds = total − mults."""
    if algorithm == "dense":
        # one classical TN dot over the whole operand (no recursion)
        mult = classical_gemm_flops(m, n, k)
        return mult, 0
    winograd = algorithm == "winograd"
    if op == "ata":
        total = ata_flops(m, n, n_base, winograd=winograd)
        mult = _ata_mult_flops(m, n, n_base)
    else:
        s = strassen_tn_flops_winograd if winograd else strassen_tn_flops
        total = s(m, n, k, n_base)
        mult = _strassen_mult_flops(m, n, k, n_base)
    return mult, max(total - mult, 0)


def _output_bytes(op, out, n, k, packed_block, itemsize) -> int:
    """HBM bytes written for the final output (roofline join point)."""
    from repro.analysis.roofline import syrk_write_traffic

    if op == "ata":
        mode = "packed" if out == "packed" else "dual"
        return syrk_write_traffic(n, packed_block, mode, itemsize)
    return n * k * itemsize


def retrieval_bytes(
    out: str,
    nb: int,
    tile_w: int,
    itemsize: int = 4,
) -> int:
    """Retrieval payload of the distributed tile schedule, per device.

    Both terms are functions of the padded stripe grid alone.
    ``out='packed'`` ships the psum'd/gathered tile stack itself —
    ``T·w² ≈ n²/2`` words (paper Prop. 4.2's low(C) saving as collective
    bytes). ``out='dense'`` additionally materializes the mirrored
    ``(nb·w)²`` square on every device — the dense-replication cost the
    packed mode removes.
    """
    t_total = nb * (nb + 1) // 2
    stack = t_total * tile_w * tile_w * itemsize
    if out == "packed":
        return stack
    return (nb * tile_w) ** 2 * itemsize


# ---------------------------------------------------------------------------
# α-β communication model of the BFS/DFS schedule (CAPS-style, paper §5)
# ---------------------------------------------------------------------------


def _bfs_makespan(nb: int, devices: int, comm_schedule: Optional[str]) -> int:
    """Tiles on the busiest task device under the interleaving (== the
    contiguous ``ceil(T/devices)`` for pure DFS / the psum schedule)."""
    t_total = nb * (nb + 1) // 2
    if not comm_schedule or "B" not in comm_schedule:
        return -(-t_total // devices)
    from repro.core.distributed import bfs_dfs_assignment

    owned, _ = bfs_dfs_assignment(nb, devices, comm_schedule)
    return max(len(o) for o in owned)


def comm_levels(
    comm_schedule: Optional[str],
    nb: int,
    tile_w: int,
    devices: int,
    row_devices: int = 1,
    *,
    out: str = "packed",
    itemsize: int = 4,
) -> list:
    """Per-level (messages, words) attribution of one interleaving.

    Two realized exchange patterns, priced with the standard
    ring-collective α-β counts and attributed to the levels whose tag
    induces them:

    * any ``'B'`` level switches the whole root exchange to the
      **tri-direct reduce-scatter**: one collective over the merged
      ``P = devices·row_devices`` pool moves the ``T``-padded staging
      stack ``S_pad = T_pad·w²`` — ``P−1`` steps, ``S_pad·(P−1)/P``
      words — simultaneously reducing the row-wise partials and dealing
      tri-order chunks, after which the packed retrieval is a pure slice
      (no root gather). Attributed evenly to the ``'B'`` levels (the
      redistribution is what BFS means); dense out adds the
      ``T``-stack gather the mirrored-square assembly forces, at the
      last level;
    * a pure-``'D'`` string (or ``None`` — the psum schedule) pays the
      **row-axis all-reduce** of the slot stack ``S = s_eff·w²``
      (``2(d−1)`` steps, ``2·S·(d−1)/d`` words), attributed evenly to
      the ``'D'`` levels, plus the **root gather** replicating the
      packed result (dense adds the mirrored square) across the pool —
      ``P−1`` steps, ``R·(P−1)/P`` words — and the **diag-symmetrization
      gather**: ``from_tile_stack`` on the pool-sharded stack lowers
      ``_symmetrize_diag``'s cross-shard diag-tile read as a masked
      all-reduce (``P−1`` steps, ``nb·w²`` words — the term the scatter
      schedule deletes by symmetrizing its chunk locally), both at the
      last level.

    Returned as one ``{'tag', 'msgs', 'words'}`` dict per level — the
    per-level ``prop42_msgs``/``prop42_words`` columns of
    ``bench_distributed``.
    """
    sched = comm_schedule or "D"
    t_total = nb * (nb + 1) // 2
    pool = devices * max(row_devices, 1)
    scatter = "B" in sched and pool > 1
    levels = [dict(tag=c, msgs=0.0, words=0.0) for c in sched]
    if scatter:
        t_pad = -(-t_total // pool) * pool
        s_pad = t_pad * tile_w * tile_w
        red_msgs, red_words = pool - 1, s_pad * (pool - 1) / pool
        carriers = [lv for lv in levels if lv["tag"] == "B"]
        for lv in carriers:
            lv["msgs"] += red_msgs / len(carriers)
            lv["words"] += red_words / len(carriers)
        if out == "dense":
            # to_dense gathers the chunked tri stack for the mirrored
            # square on every device
            levels[-1]["msgs"] += pool - 1
            levels[-1]["words"] += s_pad * (pool - 1) / pool
        return levels
    s_max = _bfs_makespan(nb, devices, sched)
    stack_words = s_max * tile_w * tile_w
    d = max(row_devices, 1)
    if d > 1:
        red_msgs, red_words = 2 * (d - 1), 2 * stack_words * (d - 1) / d
        carriers = [lv for lv in levels if lv["tag"] == "D"] or levels
        for lv in carriers:
            lv["msgs"] += red_msgs / len(carriers)
            lv["words"] += red_words / len(carriers)
    res_words = t_total * tile_w * tile_w
    if out == "dense":
        res_words += (nb * tile_w) ** 2
    levels[-1]["msgs"] += pool - 1
    levels[-1]["words"] += res_words * (pool - 1) / pool
    if pool > 1:
        # retrieval's _symmetrize_diag over the pool-sharded stack
        levels[-1]["msgs"] += pool - 1
        levels[-1]["words"] += nb * tile_w * tile_w
    return levels


def comm_seconds(
    machine: Machine,
    comm_schedule: Optional[str],
    nb: int,
    tile_w: int,
    devices: int,
    row_devices: int = 1,
    *,
    out: str = "packed",
    itemsize: int = 4,
) -> float:
    """Total α-β time of one interleaving: ``Σ msgs·α + Σ bytes·β``."""
    levels = comm_levels(comm_schedule, nb, tile_w, devices, row_devices,
                         out=out, itemsize=itemsize)
    msgs = sum(lv["msgs"] for lv in levels)
    words = sum(lv["words"] for lv in levels)
    return msgs * machine.alpha_s + words * itemsize * machine.beta_s_per_byte


def comm_memory_bytes(
    comm_schedule: Optional[str],
    nb: int,
    tile_w: int,
    devices: int,
    row_devices: int = 1,
    *,
    m: int,
    out: str = "packed",
    itemsize: int = 4,
) -> int:
    """Per-device residency of one interleaving (the CAPS memory side).

    The textbook CAPS trade: a ``'B'`` level buys its bandwidth saving
    with memory — every device stages its partial tiles in a **full
    ``T``-padded tri-order buffer** (plus the operand slab, the local
    partial stack, and the scattered ``T/P`` chunk it keeps); a
    pure-``'D'`` string stays lean — operand slab + slot stack + the
    all-reduce's full reduced copy + its share of the packed result.
    """
    sched = comm_schedule or "D"
    t_total = nb * (nb + 1) // 2
    d = max(row_devices, 1)
    pool = devices * d
    scatter = "B" in sched and pool > 1
    s_max = _bfs_makespan(nb, devices, sched)
    tile = tile_w * tile_w * itemsize
    operand = (m // d) * nb * tile_w * itemsize
    local_stack = s_max * tile
    if scatter:
        t_pad = -(-t_total // pool) * pool
        staging = (t_pad + 1) * tile
        chunk = (t_pad // pool) * tile
        result = chunk if out == "packed" else (nb * tile_w) ** 2 * itemsize
        return operand + local_stack + staging + result
    reduced = s_max * tile if d > 1 else 0
    result = t_total * tile
    if out == "dense":
        result += (nb * tile_w) ** 2 * itemsize
    return operand + local_stack + reduced + result


def comm_schedule_candidates(nb: int, max_levels: Optional[int] = None) -> list:
    """Interleaving strings the planner enumerates for one stripe grid:
    every string over {'B','D'} up to ``min(max_levels, tree depth)``
    characters (``None`` — the psum schedule — is always candidate 0)."""
    if max_levels is None:
        max_levels = defaults.MAX_COMM_SCHEDULE_LEVELS
    depth = max(1, (nb - 1).bit_length())  # ceil(log2(nb)): tile-tree depth
    max_levels = min(max_levels, depth)
    out = [None]
    frontier = [""]
    for _ in range(max_levels):
        frontier = [s + c for s in frontier for c in ("D", "B")]
        out.extend(frontier)
    return out


def choose_comm_schedule(
    nb: int,
    tile_w: int,
    devices: int,
    row_devices: int = 1,
    *,
    m: int,
    out: str = "packed",
    itemsize: int = 4,
    machine: Optional[Machine] = None,
    backend: str = "cpu",
    n: Optional[int] = None,
) -> Optional[str]:
    """The planner's interleaving argmin for one (shape, mesh, memory).

    Scores every candidate string by α-β communication time plus the
    compute-imbalance penalty of its subgroup assignment (makespan tiles
    over the balanced ``ceil(T/P)``), discards candidates whose
    per-device residency exceeds the machine's memory budget (falling
    back to the minimum-memory candidate when all bust it), and returns
    the argmin — ``None`` means the plain psum schedule wins. With ``n``
    given, BFS-containing candidates are priced at their own
    pool-divisible :func:`bfs_tiling` grid (the grid the dispatch will
    actually run them on) instead of the psum schedule's ``(nb, tile_w)``.
    """
    mach = machine or machine_for(backend)
    pool = devices * max(row_devices, 1)
    scored, overflow = [], []
    for sched in comm_schedule_candidates(nb):
        nb_s, w_s = (nb, tile_w)
        if sched and "B" in sched and pool > 1 and n is not None:
            nb_s, w_s = bfs_tiling(n, pool, devices=devices, out=out)
        secs = comm_seconds(mach, sched, nb_s, w_s, devices, row_devices,
                            out=out, itemsize=itemsize)
        # imbalance: extra tiles on the busiest device, priced as extra
        # launches (the dominant per-tile cost at bench scale is the leaf
        # dispatch; exact flops would need m and double-count compute_s)
        t_per = -(-(nb_s * (nb_s + 1) // 2) // devices)
        extra = _bfs_makespan(nb_s, devices, sched) - t_per
        secs += extra * mach.launch_overhead_s
        mem = comm_memory_bytes(sched, nb_s, w_s, devices, row_devices,
                                m=m, out=out, itemsize=itemsize)
        (scored if mem <= mach.device_memory_bytes else overflow).append(
            (secs, mem, sched))
    if not scored:
        # every candidate busts the budget: least-memory one, by the rule
        return min(overflow, key=lambda t: (t[1], t[0]))[2]
    return min(scored, key=lambda t: t[0])[2]


def predict_seconds(
    op: str,
    algorithm: str,
    m: int,
    n: int,
    k: int,
    n_base: int,
    *,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
    packed_block: int = defaults.DEFAULT_PACKED_BLOCK,
    machine: Optional[Machine] = None,
    backend: str = "cpu",
    blocks: Optional[Tuple[int, int]] = None,
    devices: int = 1,
    nb: Optional[int] = None,
    tile_w: Optional[int] = None,
    leaf_dispatch: str = "unrolled",
    row_devices: int = 1,
    comm_schedule: Optional[str] = None,
) -> float:
    """Roofline prediction for one candidate configuration.

    ``blocks``: the (bn, bk) output tile of the base matmul engine — the
    plan's Pallas blocks when kernels are in play, the backend's nominal
    XLA tiling otherwise. With ``devices > 1`` (the planner's distributed
    branch) the output term becomes the tile schedule's *retrieval* payload
    (:func:`retrieval_bytes`) — packed tile stack vs replicated dense
    square — for the ``nb``/``tile_w`` stripe tiling.

    ``leaf_dispatch`` moves two terms in opposite directions: ``'unrolled'``
    pays :func:`dispatch_calls` × ``launch_overhead_s`` (one dispatched op
    per leaf — the term that was silently zero before and made tiny-leaf
    recursions look free); ``'batched'`` pays O(levels) calls but its
    operand-combination adds are *materialized* stacks the leaf dot then
    re-reads, charged ``stack_word_cost`` words per add (nominal write+read
    = 2.0, measured higher on cpu); ``'fused'`` pays neither — its stack
    charge drops to ~0, replaced by the slot-gather read amplification
    (each root leaf block is read once per nonzero slot: Strassen's combos
    total 12 terms per 7 children per side, so L levels amplify the operand
    read by (12/4)^L = 3^L) plus the coefficient tables themselves.

    The combine/add traffic is charged *additively* on top of the
    compute/memory roofline max, not inside it: on every backend we
    measured, the operand-combination passes serialize with the leaf
    matmuls (XLA:CPU runs them as separate thunks; the fused kernel runs
    them in the same launch but on the VPU ahead of each MXU tile), and
    folding them into the max() hid them entirely at compute-bound shapes
    — which is exactly where the bench measurements show the dispatches
    separating.
    """
    mach = machine or machine_for(backend)
    itemsize = _ITEMSIZE.get(dtype, 4)
    b = max(batch, 1)

    mult, adds = _flop_split(op, algorithm, m, n, k, n_base)
    d_base = min(n_base, m, n, k) if algorithm != "dense" else min(m, n, k)
    compute_s = b * mult / (mach.peak_flops * mach.mxu_eff(d_base))

    # memory: operand streaming of the blocked base matmuls (each output
    # tile re-reads its operand panels: (mult/2)·(1/bn + 1/bk) words), the
    # fused-add traffic, and the output writes per the roofline model.
    bn, bk = blocks or (mach.xla_tile, mach.xla_tile)
    bn = min(bn, max(d_base, 1))
    bk = min(bk, max(d_base, 1))
    stream_bytes = (mult / 2) * (1.0 / bn + 1.0 / bk) * itemsize
    if leaf_dispatch == "fused" and algorithm != "dense":
        # no materialized stacks: the slot gather reads each root leaf
        # block once per nonzero slot (3^L amplification, see docstring),
        # plus the six (7^L, 2^L) int32 coefficient tables.
        lv = _levels(op, m, n, k, n_base)
        operand_words = (m * n + m * k) if op == "gemm_tn" else 2 * m * n
        combine_bytes = operand_words * 3.0**lv * itemsize + 6 * 14**lv * 4
        if not mach.kernels:
            # interpret/XLA fallback: the gathered combinations still
            # materialize per leaf (briefly — never as cross-leaf stacks)
            # and are re-read by the leaf dot; charge the addition flops
            # like the unrolled form on top of the gather reads.
            combine_bytes += mach.add_word_cost * adds * itemsize
    else:
        add_word_cost = (
            mach.stack_word_cost
            if leaf_dispatch == "batched" and algorithm != "dense"
            else mach.add_word_cost
        )
        combine_bytes = add_word_cost * adds * itemsize
    comm_s = 0.0
    pool = devices * max(row_devices, 1)
    if op == "ata" and pool > 1:
        if nb is None or tile_w is None:
            if comm_schedule and "B" in comm_schedule:
                nb, tile_w = bfs_tiling(n, pool, devices=devices, out=out)
            else:
                # pure row-shard (devices == 1): one full-width stripe —
                # gram_rowshard's whole-matrix row all-reduce
                nb, tile_w = distributed_tiling(
                    n, devices, out=out, packed_block=packed_block
                )
        out_bytes = retrieval_bytes(out, nb, tile_w, itemsize)
        # the α-β collective term: message latency (the piece that was
        # silently zero before this revision) + transfer time of the
        # schedule's reduction and root-gather phases, plus the subgroup
        # assignment's compute-imbalance penalty (makespan tiles over the
        # balanced split, priced like choose_comm_schedule does).
        comm_s = comm_seconds(
            mach, comm_schedule, nb, tile_w, devices, row_devices,
            out=out, itemsize=itemsize,
        )
        t_per = -(-(nb * (nb + 1) // 2) // devices)
        comm_s += (
            _bfs_makespan(nb, devices, comm_schedule) - t_per
        ) * mach.launch_overhead_s
    else:
        out_bytes = _output_bytes(op, out, n, k, packed_block, itemsize)
    memory_s = b * (stream_bytes + out_bytes) / mach.hbm_bw
    combine_s = b * combine_bytes / mach.hbm_bw
    overhead_s = (
        dispatch_calls(op, algorithm, m, n, k, n_base, leaf_dispatch)
        * mach.launch_overhead_s
    )
    return max(compute_s, memory_s) + combine_s + overhead_s + comm_s


# ---------------------------------------------------------------------------
# candidate enumeration and the analytic argmin
# ---------------------------------------------------------------------------


def _kernel_blocks(machine):
    """Best feasible (syrk_blocks, gemm_blocks) under the VMEM budget.

    Blocks only move the memory term: minimize output-tile streaming
    (1/bn [+ 1/bk]), tie-break on the smaller VMEM footprint.
    """
    vmem = 12 * 2**20  # leave headroom below the ~16 MB VMEM
    syrk = [
        (bm, bn)
        for bm, bn in defaults.SYRK_BLOCK_CANDIDATES
        if 2 * bm * bn * 4 + bn * bn * 4 <= vmem
    ]
    gemm = [
        (bm, bn, bk)
        for bm, bn, bk in defaults.GEMM_BLOCK_CANDIDATES
        if bm * (bn + bk) * 4 + bn * bk * 4 <= vmem
    ]
    syrk = sorted(
        syrk or [defaults.SYRK_BLOCKS],
        key=lambda b: (2.0 / b[1], 2 * b[0] * b[1] + b[1] * b[1]),
    )
    gemm = sorted(
        gemm or [defaults.GEMM_BLOCKS],
        key=lambda b: (1.0 / b[1] + 1.0 / b[2], b[0] * (b[1] + b[2]) + b[1] * b[2]),
    )
    return syrk[0], gemm[0]


def candidates(
    op: str,
    m: int,
    n: int,
    k: Optional[int] = None,
    *,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
    backend: str = "cpu",
    devices: int = 1,
    row_devices: int = 1,
) -> list:
    """Enumerate scored candidate Plans, best predicted first.

    Scoring uses ``out='dense'`` for the algorithm/n_base choice (see module
    docstring: out-invariance keeps packed results bitwise equal to dense),
    then attaches the requested ``out`` and its write-traffic prediction.

    ``op='solve'`` (``k`` = RHS count) enumerates the two solver methods —
    the factor pipeline inheriting the best packed-gram candidate's
    algorithm tunables, and matrix-free CG inheriting the best TN-product
    candidate's — scored by :func:`_solve_predict`.
    """
    k = n if k is None else k
    mach = machine_for(backend)
    if op == "solve":
        return _solve_candidates(
            m, n, k, batch=batch, dtype=dtype, out=out, backend=backend
        )
    syrk_bs, gemm_bs = _kernel_blocks(mach)
    base_tile = (
        (syrk_bs[1], syrk_bs[1]) if op == "ata" else (gemm_bs[1], gemm_bs[2])
    ) if mach.kernels else None
    nb, tile_w = (None, None)
    comm_scheds = [None]
    sched_tiling = {}
    pool = devices * max(row_devices, 1)
    if devices > 1:
        # the requested out feeds the tiling so packed plans snap tile_w
        # to the packed block grid (pure-slice retrieval, no repack)
        nb, tile_w = distributed_tiling(
            n, devices, out=out, packed_block=defaults.DEFAULT_PACKED_BLOCK
        )
    if op == "ata" and pool > 1:
        # the comm_schedule axis: every interleaving within the
        # per-device memory budget (CAPS's memory-vs-bandwidth rule);
        # if all bust it, the least-memory one via the argmin helper.
        # BFS-containing strings run — and are priced — on their own
        # pool-divisible grid (bfs_tiling): exact scatter chunking is
        # what keeps their root retrieval collective-free. A pure
        # row-sharded mesh (devices == 1, row_devices > 1) enumerates
        # only None + BFS strings — the tri-direct reduce-scatter works
        # over the merged pool, replacing the rowshard all-reduce, while
        # pure-'D' strings have no task axis to interleave and would
        # duplicate the psum plan.
        nb_b, w_b = bfs_tiling(n, pool, devices=devices, out=out)
        for cs in comm_schedule_candidates(nb if nb is not None else nb_b):
            bfs = bool(cs) and "B" in cs
            if devices == 1 and cs is not None and not bfs:
                continue
            sched_tiling[cs] = (nb_b, w_b) if bfs else (nb, tile_w)
        comm_scheds = [
            cs for cs, (nb_s, w_s) in sched_tiling.items()
            if nb_s is None or comm_memory_bytes(
                cs, nb_s, w_s, devices, row_devices,
                m=m, out=out, itemsize=_ITEMSIZE.get(dtype, 4),
            ) <= mach.device_memory_bytes
        ] or [choose_comm_schedule(
            nb_b, w_b, devices, row_devices, m=m, out=out,
            itemsize=_ITEMSIZE.get(dtype, 4), machine=mach, n=n,
        )]

    algos = ["dense", "strassen", "winograd"]
    n_bases = sorted({min(nb_c, max(m, n, k)) for nb_c in defaults.N_BASE_CANDIDATES})
    scored = []
    seen_degenerate = False
    for algo in algos:
        for n_base in n_bases if algo != "dense" else [defaults.DEFAULT_N_BASE]:
            lds = defaults.LEAF_DISPATCH_CANDIDATES
            if algo != "strassen":
                # fused slot tables encode the classical 7-term combos
                # only — winograd's chained within-level sums don't fit
                # (core.strassen raises), and dense has nothing to fuse.
                lds = tuple(ld for ld in lds if ld != "fused")
            if algo == "dense":
                lds = ("unrolled",)  # one classical dot — nothing to batch
            elif min(m, n, k) <= n_base:
                # recursion bottoms out immediately — all such cutoffs (and
                # both leaf dispatches: one leaf IS one call) are the same
                # dispatch; keep one canonical representative.
                if seen_degenerate:
                    continue
                seen_degenerate = True
                lds = ("unrolled",)
            for ld in lds:
                pred = predict_seconds(
                    op, algo, m, n, k, n_base,
                    batch=batch, dtype=dtype, out="dense", machine=mach,
                    blocks=base_tile, leaf_dispatch=ld,
                )
                scored.append((pred, algo, n_base, ld))
    scored.sort(key=lambda s: s[0])

    plans = []
    for pred, algo, n_base, ld in scored:
        variants = []
        for cs in comm_scheds:
            nb_s, w_s = sched_tiling.get(cs, (nb, tile_w))
            # BFS plans carry their own aligned packed grid: tile_w IS the
            # packed block, so the scattered chunks slice straight into
            # packed storage (see bfs_tiling)
            pb = (w_s if cs and "B" in cs and w_s is not None
                  else defaults.DEFAULT_PACKED_BLOCK)
            pred_out = predict_seconds(
                op, algo, m, n, k, n_base,
                batch=batch, dtype=dtype, out=out, machine=mach,
                blocks=base_tile, devices=devices, nb=nb_s, tile_w=w_s,
                leaf_dispatch=ld, row_devices=row_devices, comm_schedule=cs,
            )
            variants.append(
                Plan(
                    op=op, m=m, n=n, k=k, batch=batch, dtype=dtype,
                    backend=backend, out=out, algorithm=algo, n_base=n_base,
                    packed_block=pb,
                    use_kernels=mach.kernels,
                    syrk_blocks=syrk_bs, gemm_blocks=gemm_bs,
                    leaf_dispatch=ld,
                    devices=devices, nb=nb_s, tile_w=w_s,
                    row_devices=row_devices, comm_schedule=cs,
                    source="analytic", predicted_s=pred_out,
                )
            )
        # comm_schedule is ranked *within* each algorithm entry (the α-β
        # term is algorithm-invariant), preserving the out-invariant
        # algorithm/n_base ordering above.
        variants.sort(key=lambda p: p.predicted_s)
        plans.extend(variants)
    return plans


def _solve_candidates(
    m: int,
    n: int,
    r: int,
    *,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "packed",
    backend: str = "cpu",
) -> list:
    """Scored op='solve' candidates, best predicted first.

    The factor candidate carries the best *packed-gram* candidate's
    algorithm tunables (the gram dominates its cost and the factor walk
    has no algorithm choice of its own); the CG candidate carries the best
    TN-product candidate's (its iterations are ``Aᵀ(A·p)`` pairs).
    """
    if batch:
        raise ValueError("op='solve' plans are unbatched (lstsq is 2-D); "
                         f"got batch={batch}")
    mach = machine_for(backend)
    syrk_bs, gemm_bs = _kernel_blocks(mach)
    base_tile = (syrk_bs[1], syrk_bs[1]) if mach.kernels else None
    common = dict(
        op="solve", m=m, n=n, k=r, batch=batch, dtype=dtype,
        backend=backend, out=out,
        packed_block=defaults.DEFAULT_PACKED_BLOCK,
        use_kernels=mach.kernels,
        syrk_blocks=syrk_bs, gemm_blocks=gemm_bs, source="analytic",
    )
    gram = candidates(
        "ata", m, n, batch=batch, dtype=dtype, out="packed", backend=backend
    )[0]
    gemm = candidates(
        "gemm_tn", m, n, r, batch=batch, dtype=dtype, out="dense",
        backend=backend,
    )[0]
    plans = []
    for method, donor in (("factor", gram), ("cg", gemm)):
        pred = _solve_predict(
            method, donor.algorithm, m, n, r, donor.n_base,
            dtype=dtype, packed_block=donor.packed_block, machine=mach,
            blocks=base_tile, leaf_dispatch=donor.leaf_dispatch,
        )
        plans.append(
            Plan(
                algorithm=donor.algorithm, n_base=donor.n_base,
                leaf_dispatch=donor.leaf_dispatch, method=method,
                predicted_s=pred, **common,
            )
        )
    plans.sort(key=lambda p: p.predicted_s)
    return plans


def analytic_plan(op, m, n, k=None, **kw) -> Plan:
    """The analytic argmin — what ``repro.tune.plan`` returns on cache miss."""
    return candidates(op, m, n, k, **kw)[0]


def default_plan(
    op: str,
    m: int,
    n: int,
    k: Optional[int] = None,
    *,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
    backend: str = "cpu",
    devices: int = 1,
    row_devices: int = 1,
) -> Plan:
    """The pre-tune-subsystem hardcoded configuration, as a Plan.

    This is the baseline `bench_tune` measures the planner against, and the
    fallback consumers use when a caller pins *some* tunables manually.
    The distributed default keeps ``comm_schedule=None`` — the plain psum
    schedule the BFS/DFS planner is measured against.
    """
    k = n if k is None else k
    mach = machine_for(backend)
    nb, tile_w = (None, None)
    if devices > 1:
        nb, tile_w = distributed_tiling(
            n, devices, out=out, packed_block=defaults.DEFAULT_PACKED_BLOCK
        )
    return Plan(
        op=op, m=m, n=n, k=k, batch=batch, dtype=dtype, backend=backend,
        out=out, algorithm=defaults.DEFAULT_VARIANT,
        n_base=defaults.DEFAULT_N_BASE,
        packed_block=defaults.DEFAULT_PACKED_BLOCK,
        use_kernels=mach.kernels,
        syrk_blocks=defaults.SYRK_BLOCKS, gemm_blocks=defaults.GEMM_BLOCKS,
        leaf_dispatch=defaults.DEFAULT_LEAF_DISPATCH,
        method=defaults.DEFAULT_SOLVE_METHOD if op == "solve" else None,
        devices=devices, nb=nb, tile_w=tile_w, row_devices=row_devices,
        source="default",
    )


# ---------------------------------------------------------------------------
# distributed branch: lower-triangle tile search (ex core.distributed)
# ---------------------------------------------------------------------------


def distributed_tiling(
    n: int,
    p: int,
    target_tiles_per_dev: Optional[int] = None,
    *,
    out: str = "dense",
    packed_block: Optional[int] = None,
    n_base: Optional[int] = None,
):
    """Pick (nb, w): stripe count and stripe width (multiple of 8) for the
    block-cyclic lower-triangle schedule of ``ata_tile_parallel``.

    Wants: T = nb(nb+1)/2 ≥ p (enough tasks), small T mod p (balance),
    w reasonably large (MXU efficiency). Searches a small static range.

    With ``out='packed'``, stripe widths that **snap to the packed block
    grid** (``w == symmetric.default_block_size(n, packed_block)``) are
    preferred, and the exactly-aligned stripe count ``⌈n/bn⌉`` joins the
    candidate set: an aligned tiling makes the packed retrieval a pure
    slice of the psum'd tile stack (no repack pass). Two things outrank
    alignment, in order: **balance** (a misaligned zero-waste tiling beats
    an aligned one that idles devices) and **leaf Strassen depth** — a
    candidate whose stripes are wide enough for more recursion levels
    (``⌈log₂(w/n_base)⌉``, ``n_base`` defaulting to the static cutoff)
    keeps the 7/8-mult saving that narrow aligned stripes would forfeit,
    which is worth far more than the repack copy it costs. For
    ``out='dense'`` both new terms are order-compatible with the
    historical (waste, −w) search, so dense tilings are unchanged.
    """
    from repro.core.symmetric import default_block_size

    if target_tiles_per_dev is None:
        target_tiles_per_dev = defaults.TARGET_TILES_PER_DEVICE
    if n_base is None:
        n_base = defaults.DEFAULT_N_BASE
    bn_pack = None
    if out == "packed":
        bn_pack = default_block_size(
            n, packed_block or defaults.DEFAULT_PACKED_BLOCK
        )

    def strassen_depth(w: int) -> int:
        d = 0
        while w > n_base:
            w -= w // 2  # ceil-halving, as the recursion splits
            d += 1
        return d

    nb_min = max(1, math.ceil((math.sqrt(8 * p + 1) - 1) / 2))
    cand = list(range(nb_min, 4 * nb_min + 8))
    if bn_pack is not None:
        nb_aligned = -(-n // bn_pack)
        if nb_aligned >= nb_min and nb_aligned not in cand:
            cand.append(nb_aligned)
    best = None
    for nb in cand:
        t = nb * (nb + 1) // 2
        if t < p:
            continue
        per = -(-t // p)
        waste = per * p - t
        w = -(-n // nb)
        w = -(-w // 8) * 8  # round width up to sublane multiple
        # order: balance → leaf Strassen depth → (packed) grid alignment →
        # width. For out='dense', misaligned ≡ 0 and depth is monotone in
        # w, so the argmin coincides with the historical (waste·w², −w).
        misaligned = 1 if (bn_pack is not None and w != bn_pack) else 0
        score = (waste * w * w, -strassen_depth(w), misaligned, -w)
        if best is None or score < best[0]:
            best = (score, nb, w)
        if t >= target_tiles_per_dev * p and waste == 0 and not misaligned:
            break
    _, nb, w = best
    return nb, w


def bfs_tiling(
    n: int,
    pool: int,
    *,
    devices: Optional[int] = None,
    out: str = "packed",
    packed_block: Optional[int] = None,
    n_base: Optional[int] = None,
):
    """Pick (nb, w) for the BFS tri-direct reduce-scatter schedule.

    The scatter deals the reduced tri stack in ``T/pool``-tile chunks over
    the merged ``(task, row)`` device pool, so the stripe count must make
    ``T = nb(nb+1)/2`` **divisible by the pool** — then the chunking is
    exact, the packed retrieval is an identity slice, and the compiled
    program's only collective is the one chunk-sized reduce-scatter (an
    uneven ``T`` forces GSPMD to all-gather the whole stack at the root
    slice, which is exactly the cost the schedule exists to avoid).
    Among the divisible stripe counts the scoring mirrors
    :func:`distributed_tiling`: **subgroup balance** first (with
    ``devices`` given — the task-axis size — the representative
    single-``'B'`` assignment's makespan excess over ``ceil(T/devices)``,
    weighted ``w²`` like the waste term there; region-proportional device
    allotment rounds to integers, and a grid whose region sizes land near
    those multiples idles nobody), then leaf Strassen depth, then packed
    grid alignment (``w == default_block_size(n, w)`` — the dispatch
    passes the chosen width as the packed block so retrieval stays a pure
    slice), then width. A pool-divisible ``nb`` always exists within
    ``2·pool`` candidates (``nb = 2·pool−1`` gives ``T = pool·(2·pool−1)``).
    """
    from repro.core.symmetric import default_block_size

    if pool <= 1:
        return distributed_tiling(n, pool, out=out, packed_block=packed_block)
    if n_base is None:
        n_base = defaults.DEFAULT_N_BASE

    def strassen_depth(w: int) -> int:
        d = 0
        while w > n_base:
            w -= w // 2
            d += 1
        return d

    nb_min = max(1, math.ceil((math.sqrt(8 * pool + 1) - 1) / 2))
    best = None
    for nb in range(nb_min, nb_min + 2 * pool + 8):
        t = nb * (nb + 1) // 2
        if t < pool or t % pool:
            continue
        w = -(-n // nb)
        w = -(-w // 8) * 8
        grid = default_block_size(n, packed_block or w)
        misaligned = 1 if w != grid else 0
        extra = 0
        if devices is not None and devices > 1:
            extra = _bfs_makespan(nb, devices, "B") - (-(-t // devices))
        score = (extra * w * w, -strassen_depth(w), misaligned, -w, nb)
        if best is None or score < best[0]:
            best = (score, nb, w)
    _, nb, w = best
    return nb, w
