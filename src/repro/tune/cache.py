"""Persistent plan cache + the ``repro.tune.plan(...)`` front door.

Resolution order for one problem key:

1. **in-process memo** — every resolved Plan is memoized, so a jit trace
   that dispatches the same shape hundreds of times pays for planning once;
2. **JSON cache file** — *measured* plans persist across processes, keyed
   by ``(op, m, n, k, batch, dtype, out, backend, devices, jax version)``
   (see :func:`plan_key`; the jax version is in the key because a runtime
   upgrade can move the Strassen crossover);
3. **analytic model** (`tune.cost.analytic_plan`) on a cache miss — or the
   **measured autotuner** (`tune.search.autotune`) when ``autotune=True``,
   whose result is written back to the JSON cache.

Only measured plans are persisted: the analytic model is deterministic and
free to recompute, so writing it to disk would only let a stale file shadow
model improvements. Consequently ``plan(...)`` is deterministic for a given
cache state, and a cache file round-trips through JSON bit-exactly
(`Plan.to_json`/`from_json` — tested in ``tests/test_tune.py``).

Cache location: ``$REPRO_TUNE_CACHE`` if set, else
``~/.cache/repro/tune_plans.json``. ``bench_tune`` regenerates tuned plans
(see DESIGN.md §7): ``PYTHONPATH=src python -m benchmarks.run tune``.
"""

from __future__ import annotations

import json
import logging
import os
import threading
from typing import Optional

import jax

from repro.obs import metrics
from repro.tune import cost, defaults

__all__ = [
    "plan",
    "plan_key",
    "cache_path",
    "load_cache",
    "save_cache",
    "clear_memo",
    "cache_stats",
    "warm",
    "cache_prefetch",
]

_LOG = logging.getLogger("repro.tune.cache")

# metric names of the plan-cache counters (repro.obs.metrics registry) —
# hit/miss count *front-door resolutions* (plan() calls), the load-side
# counters count per-load events (load_cache runs on every non-memo
# resolution, so a migrated entry is counted once per load, not once ever).
_STAT_NAMES = (
    "memo_hit",        # resolved from the in-process memo
    "hit",             # resolved from the persistent JSON cache
    "miss",            # fell through to the analytic model
    "autotuned",       # resolved by a measured autotune run (persisted)
    "migrated",        # old-schema keys migrated on load
    "sanitized",       # unknown-leaf_dispatch entries sanitized on load
    "skipped_entries", # corrupt/undeserializable entries skipped on load
    "load_failure",    # unreadable/corrupt cache file tolerated
    "warm_hit",        # warm(): resolved from the persistent JSON cache
    "warm_miss",       # warm(): fell through to the analytic model
    "warm_memo",       # warm(): key already memoized (left untouched)
)


def cache_stats() -> dict:
    """Current plan-cache counters, ``{short_name: count}``.

    The counters live in the ``repro.obs.metrics`` registry under
    ``tune.cache.<name>`` (always on — see the registry's module
    docstring); this accessor is the stable public view of them.
    """
    return {name: metrics.get(f"tune.cache.{name}") for name in _STAT_NAMES}

_MEMO: dict = {}
_LOCK = threading.Lock()
# v4: the distributed branch gained the `row_devices` key segment
# (``r=<row axis size>``, inserted before ``jax=``) and Plans gained
# `comm_schedule` (the BFS/DFS interleaving string). v3 added the 'fused'
# leaf_dispatch; v2 introduced op='solve' and the `method` field.
# Older-schema ("v1|…".."v3|…") cache files still load: old entries
# deserialize (missing fields default to the psum schedule they were
# measured with) and their keys are migrated on load — prefix swapped and
# the ``r=1`` segment inserted — so old measured plans keep serving.
# Symmetrically, entries written by a *newer* schema may carry
# leaf_dispatch or comm_schedule values this revision has never heard of:
# leaf_dispatch sanitizes to 'unrolled', comm_schedule to None (the psum
# schedule — always valid, bitwise-identical output) instead of raising
# at every planned dispatch.
_SCHEMA = "v4"
_COMPAT_SCHEMAS = ("v1", "v2", "v3")

# every leaf_dispatch this revision's recursions accept (mirrors
# core.strassen.resolve_tunables; kept literal so load never imports jax)
_KNOWN_LEAF_DISPATCHES = ("unrolled", "batched", "fused")


def _valid_comm_schedule(cs) -> bool:
    """True iff ``cs`` is a value this revision's schedules accept: None
    (psum) or a non-empty {'B','D'} string (bfs_dfs_assignment's contract)."""
    return cs is None or (
        isinstance(cs, str) and bool(cs) and all(c in "BD" for c in cs)
    )


def cache_path() -> str:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "tune_plans.json"
    )


def plan_key(
    op: str,
    m: int,
    n: int,
    k: int,
    batch: int,
    dtype: str,
    out: str,
    backend: str,
    devices: int = 1,
    row_devices: int = 1,
) -> str:
    """The cache key: problem identity + runtime identity (jax version).

    ``row_devices`` (the reduction-axis size of the two-level distributed
    mesh) joined the key in schema v4 — the BFS/DFS interleaving choice
    depends on it; pre-v4 keys migrate with ``r=1`` on load.
    """
    return (
        f"{_SCHEMA}|{op}|m={m}|n={n}|k={k}|b={batch}|{dtype}|{out}"
        f"|{backend}|p={devices}|r={row_devices}|jax={jax.__version__}"
    )


def load_cache(path: Optional[str] = None) -> dict:
    """{key: Plan} from the JSON file (empty on missing/corrupt file)."""
    path = path or cache_path()
    try:
        with open(path) as f:
            raw = json.load(f)
    except FileNotFoundError:
        # no cache yet is the normal first-run state, not a failure
        return {}
    except (OSError, json.JSONDecodeError) as e:
        # a present-but-unreadable file is tolerated (the analytic model
        # covers every key) but no longer silent: one line names the path
        # and the reason so a corrupt cache stops masquerading as a miss.
        metrics.inc("tune.cache.load_failure")
        _LOG.warning(
            "plan cache %s unreadable (%s: %s); continuing with empty cache",
            path, type(e).__name__, e,
        )
        return {}
    out = {}
    skipped = 0
    for key, d in raw.get("plans", {}).items():
        for old in _COMPAT_SCHEMAS:
            # older-schema keys are migrated in place, so pre-bump measured
            # plans keep serving: prefix swapped to the current schema and
            # (pre-v4 layouts) the row-devices segment inserted with its
            # single-possible historical value.
            if key.startswith(old + "|"):
                key = _SCHEMA + key[len(old):]
                if "|r=" not in key and "|jax=" in key:
                    key = key.replace("|jax=", "|r=1|jax=", 1)
                metrics.inc("tune.cache.migrated")
                break
        try:
            p = cost.Plan.from_json(d)
        except (TypeError, KeyError, ValueError):
            # schema drift (TypeError), truncated/hand-edited entries
            # (KeyError on a missing field, ValueError on a non-dict value):
            # skip the entry; the analytic model covers the key instead of
            # one bad line crashing every planned dispatch in the process.
            skipped += 1
            continue
        if p.leaf_dispatch not in _KNOWN_LEAF_DISPATCHES:
            # a future schema's dispatch value: fall back to the always-
            # valid unrolled form (bitwise-identical output) rather than
            # letting resolve_tunables raise on every dispatch of the key.
            import dataclasses

            p = dataclasses.replace(p, leaf_dispatch="unrolled")
            metrics.inc("tune.cache.sanitized")
        if not _valid_comm_schedule(p.comm_schedule):
            # same policy for a future schema's interleaving value: the
            # psum schedule (comm_schedule=None) is always valid and
            # bitwise-identical, so the entry keeps serving instead of
            # bfs_dfs_assignment raising on every planned dispatch.
            import dataclasses

            p = dataclasses.replace(p, comm_schedule=None)
            metrics.inc("tune.cache.sanitized")
        out[key] = p
    if skipped:
        metrics.inc("tune.cache.skipped_entries", skipped)
        _LOG.warning(
            "plan cache %s: skipped %d undeserializable entr%s",
            path, skipped, "y" if skipped == 1 else "ies",
        )
    return out


def save_cache(plans: dict, path: Optional[str] = None) -> str:
    path = path or cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {
        "schema": _SCHEMA,
        "plans": {key: p.to_json() for key, p in sorted(plans.items())},
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def clear_memo() -> None:
    """Drop the in-process memo (tests; cache-file experiments)."""
    with _LOCK:
        _MEMO.clear()


def warm(specs, *, cache_file: Optional[str] = None) -> list:
    """Bulk-resolve plan keys into the in-process memo in ONE file read.

    The pre-warm API of the serve layer: a server warming dozens of
    buckets would otherwise pay one ``load_cache`` (a full JSON parse) per
    ``plan()`` miss. ``warm`` reads the cache file once, resolves every
    spec against it (persisted plan → ``source='cache'``, else the
    analytic model — the same resolution ``plan()`` performs without
    ``autotune``), and installs the results in the memo so the subsequent
    per-dispatch ``plan()`` calls are memo hits.

    Args:
      specs: iterable of dicts of ``plan()`` keyword arguments, e.g.
        ``{"op": "solve", "m": 96, "n": 64, "k": 8, "out": "packed"}``
        (defaults match ``plan()``: ``k=n``, ``batch=0``,
        ``dtype='float32'``, ``out='dense'``, backend auto).
      cache_file: cache path override (default: :func:`cache_path`).

    Returns:
      The resolved Plans, in spec order. Counters: ``warm_hit`` /
      ``warm_miss`` per resolution, ``warm_memo`` when a key was already
      memoized (the memoized plan wins — warm never clobbers, so an
      autotuned plan resolved earlier in the process keeps serving).
    """
    persisted = load_cache(cache_file)      # the ONE file read
    resolved_plans = []
    for spec in specs:
        kw = dict(spec)
        op = kw.pop("op", "ata")
        if op not in ("ata", "gemm_tn", "solve"):
            raise ValueError(
                f"unknown op {op!r}; use 'ata', 'gemm_tn' or 'solve'")
        m, n = kw.pop("m"), kw.pop("n")
        k = kw.pop("k", None)
        k = n if k is None else k
        batch = kw.pop("batch", 0)
        if op == "solve" and batch:
            raise ValueError("op='solve' plans are unbatched (lstsq is 2-D); "
                             f"got batch={batch}")
        dtype = kw.pop("dtype", "float32")
        out = kw.pop("out", "dense")
        backend = kw.pop("backend", None) or jax.default_backend()
        devices = kw.pop("devices", 1)
        row_devices = kw.pop("row_devices", 1)
        if kw:
            raise TypeError(f"warm spec has unknown keys {sorted(kw)}")
        key = plan_key(op, m, n, k, batch, dtype, out, backend, devices,
                       row_devices)
        hit = persisted.get(key)
        if hit is not None:
            import dataclasses

            metrics.inc("tune.cache.warm_hit")
            resolved = dataclasses.replace(hit, source="cache")
        else:
            metrics.inc("tune.cache.warm_miss")
            resolved = cost.analytic_plan(
                op, m, n, k, batch=batch, dtype=dtype, out=out,
                backend=backend, devices=devices, row_devices=row_devices,
            )
        memo_key = (key, cache_file, False)
        with _LOCK:
            if memo_key in _MEMO:
                metrics.inc("tune.cache.warm_memo")
                resolved = _MEMO[memo_key]
            else:
                _MEMO[memo_key] = resolved
        resolved_plans.append(resolved)
    return resolved_plans


# the serve layer's historical name for the same operation
cache_prefetch = warm


def plan(
    op: str = "ata",
    *,
    m: int,
    n: int,
    k: Optional[int] = None,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
    backend: Optional[str] = None,
    devices: int = 1,
    row_devices: int = 1,
    autotune: bool = False,
    cache_file: Optional[str] = None,
) -> cost.Plan:
    """The front door: one frozen Plan for every ATA dispatch.

    Args:
      op: ``'ata'`` (``C = AᵀA``), ``'gemm_tn'`` (``C = AᵀB``), or
        ``'solve'`` (the normal-equations pipeline of ``repro.solve`` —
        the plan then carries ``method`` ∈ {'factor', 'cg'}).
      m, n, k: operand shape — A is (m, n), B is (m, k); k defaults to n.
        For ``op='solve'``, k is the right-hand-side count.
      batch: leading batch size (0 = unbatched).
      dtype: operand dtype string (``str(a.dtype)``).
      out: ``'dense'`` or ``'packed'`` output.
      backend: defaults to ``jax.default_backend()``.
      devices: task-axis size for the distributed schedules (fills the
        plan's ``nb``/``tile_w`` stripe tiling — the planner's distributed
        branch).
      row_devices: row (reduction) axis size of the two-level distributed
        mesh — with ``devices > 1`` the planner prices the BFS/DFS
        interleaving candidates against it (α-β communication model +
        per-device memory budget) and fills ``plan.comm_schedule``.
      autotune: measure candidates instead of trusting the analytic model;
        the winner persists to the JSON cache for future processes.
        Single-device only — with ``devices > 1`` the plan stays analytic
        (the autotuner cannot time the distributed schedule).
      cache_file: cache path override (default: :func:`cache_path`).

    Returns:
      A frozen, JSON-serializable :class:`repro.tune.cost.Plan`.
    """
    if op not in ("ata", "gemm_tn", "solve"):
        raise ValueError(f"unknown op {op!r}; use 'ata', 'gemm_tn' or 'solve'")
    if op == "solve" and batch:
        # lstsq takes one 2-D design matrix; a batched solve plan would be
        # unexecutable (and untimeable by the autotuner)
        raise ValueError("op='solve' plans are unbatched (lstsq is 2-D); "
                         f"got batch={batch}")
    backend = backend or jax.default_backend()
    k = n if k is None else k
    key = plan_key(op, m, n, k, batch, dtype, out, backend, devices,
                   row_devices)
    memo_key = (key, cache_file, autotune)

    with _LOCK:
        hit = _MEMO.get(memo_key)
    if hit is not None:
        metrics.inc("tune.cache.memo_hit")
        return hit

    measured_now = False
    persisted = load_cache(cache_file).get(key)
    if persisted is not None and (persisted.source == "measured" or not autotune):
        import dataclasses

        metrics.inc("tune.cache.hit")
        resolved = dataclasses.replace(persisted, source="cache")
    elif autotune and devices == 1:
        from repro.tune import search

        metrics.inc("tune.cache.autotuned")
        resolved = search.autotune(
            op, m, n, k, batch=batch, dtype=dtype, out=out,
            backend=backend, devices=devices,
        )
        plans = load_cache(cache_file)
        plans[key] = resolved
        save_cache(plans, cache_file)
        measured_now = True
    else:
        # devices > 1 with autotune lands here too: the autotuner's timed
        # callable is the single-device op, which says nothing about the
        # distributed tile schedule — distributed plans stay analytic.
        metrics.inc("tune.cache.miss")
        resolved = cost.analytic_plan(
            op, m, n, k, batch=batch, dtype=dtype, out=out,
            backend=backend, devices=devices, row_devices=row_devices,
        )

    with _LOCK:
        _MEMO[memo_key] = resolved
        if measured_now:
            # the cache state just changed: refresh the non-autotune memo
            # slot so default dispatches in THIS process see the measured
            # plan, exactly as a fresh process reading the file would.
            _MEMO[(key, cache_file, False)] = resolved
    return resolved
