"""Thread a frozen Plan into the ATA stack's executables.

The consumers (`core.ata`, `core.strassen`, `core.distributed`,
`kernels.ops`) accept ``plan=`` and resolve their tunables from it; this
module holds the pieces that need to look *down* the stack — building base
kernels from a plan's block shapes and building the jitted callable the
autotuner times — so `core` never imports `kernels` and `tune.search`
never special-cases ops.
"""

from __future__ import annotations

import functools

import jax

from repro.tune import cost

__all__ = [
    "base_fns",
    "fused_fns",
    "build_callable",
    "ata_with_plan",
    "ata_distributed_with_plan",
    "gemm_tn_with_plan",
    "lstsq_with_plan",
]


def base_fns(plan: cost.Plan):
    """(base_syrk, base_dot) for the recursion bottom under this plan.

    ``use_kernels=True`` → the Pallas kernels with the plan's block shapes
    (compiled on TPU, interpret elsewhere — `kernels.ops` decides);
    otherwise ``(None, None)`` so the recursion keeps its MXU-native
    ``dot_general`` base case.
    """
    if not plan.use_kernels:
        return None, None
    from repro.kernels import ops

    base_syrk = functools.partial(ops.syrk, blocks=plan.syrk_blocks)
    base_dot = functools.partial(ops.gemm_tn, blocks=plan.gemm_blocks)
    return base_syrk, base_dot


def fused_fns(plan: cost.Plan):
    """(fused_syrk, fused_dot) for ``leaf_dispatch='fused'`` under this plan.

    The fused leaf launches of the ``repro.kernels`` coefficient-table
    contract, with the plan's block shapes: ``fused_dot(A, B, tables)``
    runs every leaf product of one level as ONE ``ops.gemm_tn_fused``
    launch, ``fused_syrk(ab, rows, cols)`` every gathered diagonal leaf as
    ONE ``ops.syrk_gather`` launch. ``(None, None)`` when the plan doesn't
    use kernels — the recursion then falls back to its trace-time slot
    gathers (same values, XLA path).
    """
    if not plan.use_kernels:
        return None, None
    from repro.kernels import ops

    fused_syrk = functools.partial(ops.syrk_gather, blocks=plan.syrk_blocks)
    fused_dot = functools.partial(ops.gemm_tn_fused, blocks=plan.gemm_blocks)
    return fused_syrk, fused_dot


def ata_with_plan(a, plan: cost.Plan, **kw):
    """``ata``/``ata_batched`` dispatched exactly as the plan says."""
    from repro.core.ata import ata, ata_batched

    fn = ata_batched if plan.batch else ata
    return fn(a, plan=plan, out=plan.out, **kw)


def ata_distributed_with_plan(
    a, mesh, plan: cost.Plan, *, task_axis: str = "model",
    row_axis=None, **kw,
):
    """Distributed ATA dispatched exactly as the plan says.

    The ``comm_schedule`` axis picks the SPMD schedule itself: a
    BFS-containing interleaving runs :func:`~repro.core.distributed.
    ata_bfs_dfs` (tri-direct reduce-scatter over the merged device pool);
    ``None`` or a pure-``'D'`` string runs the owner-computes psum
    schedule (:func:`~repro.core.distributed.ata_tile_parallel` — which a
    pure-``'D'`` ``ata_bfs_dfs`` degenerates to bitwise anyway, so the
    front door dispatches both to the same compiled program family).
    """
    from repro.core.distributed import ata_bfs_dfs, ata_tile_parallel

    cs = getattr(plan, "comm_schedule", None)
    if cs and "B" in cs:
        return ata_bfs_dfs(
            a, mesh, task_axis=task_axis, row_axis=row_axis, plan=plan,
            interleaving=cs, out=plan.out, **kw,
        )
    return ata_tile_parallel(
        a, mesh, task_axis=task_axis, row_axis=row_axis, plan=plan,
        out=plan.out, **kw,
    )


def gemm_tn_with_plan(a, b, plan: cost.Plan, **kw):
    from repro.core.strassen import strassen_tn

    return strassen_tn(a, b, plan=plan, **kw)


def lstsq_with_plan(a, b, plan: cost.Plan, **kw):
    """``solve.lstsq`` dispatched exactly as the plan says (method, gram
    tunables, base kernels)."""
    from repro.solve.lstsq import lstsq

    return lstsq(a, b, plan=plan, **kw)


def build_callable(plan: cost.Plan):
    """One jitted function executing the plan (what the autotuner times)."""
    if plan.op == "gemm_tn":
        return jax.jit(lambda a, b: gemm_tn_with_plan(a, b, plan))
    if plan.op == "solve":
        return jax.jit(lambda a, b: lstsq_with_plan(a, b, plan))
    return jax.jit(lambda a: ata_with_plan(a, plan))
